//! Interconnect study: how inter-chiplet latency AND bandwidth shape the
//! best pipeline schedule (extends the paper's Figure 9 with a bandwidth
//! axis — the "future work" interconnect dimension the paper motivates via
//! Simba's heterogeneous interconnect).
//!
//! ```sh
//! cargo run --release --example latency_sweep
//! ```

use shisha::explore::shisha::{ShishaExplorer, ShishaOptions};
use shisha::explore::{Evaluator, Explorer};
use shisha::metrics::fmt_duration;
use shisha::metrics::table::{f, Table};
use shisha::model::networks;
use shisha::perfdb::{CostModel, PerfDb};
use shisha::platform::configs;

fn main() {
    let net = networks::yolov3();

    let mut table = Table::new([
        "latency",
        "link GB/s",
        "best throughput (img/s)",
        "stages chosen",
        "configs tried",
    ]);
    for &lat in &[1e-9, 1e-6, 1e-3, 0.1] {
        for &bw in &[1.0, 8.0, 32.0, 128.0] {
            let mut plat = configs::fig4_platform();
            plat.link.latency_s = lat;
            plat.link.bandwidth_gbs = bw;
            let db = PerfDb::build(&net, &plat, &CostModel::default());
            let mut eval = Evaluator::new(&net, &plat, &db);
            let sol = ShishaExplorer::new(ShishaOptions::default()).explore(&mut eval);
            table.row([
                fmt_duration(lat),
                f(bw, 0),
                f(sol.best_throughput, 4),
                sol.best_config.n_stages().to_string(),
                sol.n_evals.to_string(),
            ]);
        }
    }
    println!("YOLOv3 on 8 EPs — Shisha under interconnect sweeps:\n{}", table.to_markdown());
    println!(
        "shape: latency ≤ 1ms is invisible (paper Fig. 9); starving bandwidth (1 GB/s)\n\
         pushes Shisha towards fewer, fatter stages to avoid chip-to-chip transfers."
    );
}
