//! Adaptive re-tuning under DVFS drift — the scenario the paper uses to
//! motivate *online* tuning (§1: offline cost models "are sensitive to
//! changes in the execution environment (e.g., DVFS)").
//!
//! A tuned ResNet50 pipeline runs on C2; at epoch 5 the fastest EP is
//! clocked down 2.5× (thermal throttling), at epoch 12 a SEP degrades.
//! The adaptive controller detects each regression and re-runs Algorithm 2
//! warm from the running configuration, recovering most of the lost
//! throughput within tens of trials.
//!
//! ```sh
//! cargo run --release --example adaptive_dvfs
//! ```

use shisha::coordinator::{AdaptiveController, DriftEvent};
use shisha::explore::shisha::{ShishaAuto};
use shisha::explore::{Evaluator, Explorer};
use shisha::metrics::table::{f, Table};
use shisha::model::networks;
use shisha::perfdb::{CostModel, PerfDb};
use shisha::pipeline::simulator;
use shisha::platform::configs;

fn main() {
    let net = networks::resnet50();
    let plat = configs::c2();
    let model = CostModel::default();
    let db = PerfDb::build(&net, &plat, &model);

    // cold start: full Shisha
    let mut eval = Evaluator::new(&net, &plat, &db);
    let sol = ShishaAuto::new().explore(&mut eval);
    println!(
        "cold-start schedule {} @ {:.2} img/s ({} trials)",
        sol.best_config.describe(),
        sol.best_throughput,
        sol.n_evals
    );

    // drift scenario: throttle the EP hosting the heaviest stage, then a SEP
    let victim_fast = sol.best_config.assignment[simulator::slowest_stage(&net, &plat, &db, &sol.best_config)];
    let victim_slow = *sol.best_config.assignment.iter().max().unwrap();
    let events = [
        DriftEvent { epoch: 5, ep: victim_fast, slowdown: 2.5 },
        DriftEvent { epoch: 12, ep: victim_slow, slowdown: 2.0 },
    ];
    println!(
        "drift events: epoch 5 -> EP{victim_fast} x2.5 slowdown; epoch 12 -> EP{victim_slow} x2.0\n"
    );

    let ctl = AdaptiveController::new(net.clone(), plat.clone(), model.clone());
    let report = ctl.run(sol.best_config.clone(), 18, &events);

    let mut table = Table::new(["epoch", "throughput (img/s)", "config", "re-tuned", "trials"]);
    for e in &report.epochs {
        table.row([
            e.epoch.to_string(),
            f(e.throughput, 3),
            e.config.describe(),
            if e.retuned { "yes" } else { "" }.to_string(),
            if e.retuned { e.retune_trials.to_string() } else { String::new() },
        ]);
    }
    println!("{}", table.to_markdown());
    println!(
        "{} re-tunes, {} total warm trials (cold start took {}); final throughput {:.2} img/s",
        report.n_retunes,
        report.total_trials,
        sol.n_evals,
        report.final_throughput()
    );

    // static baseline: never re-tune
    let mut db2 = PerfDb::build(&net, &plat, &model);
    for ev in &events {
        db2.scale_ep(ev.ep, ev.slowdown);
    }
    let static_tp = simulator::throughput(&net, &plat, &db2, &sol.best_config);
    println!(
        "static schedule under the same drift: {:.2} img/s -> adaptation recovers {:.1}% more",
        static_tp,
        100.0 * (report.final_throughput() / static_tp - 1.0)
    );
    assert!(report.final_throughput() >= static_tp, "adaptation must not lose to static");
}
