//! Serving storm: three tenants share one chiplet platform while a bursty
//! neighbour periodically floods it.
//!
//! * `steady`  — AlexNet under constant Poisson load (40% of its capacity);
//! * `bursty`  — SynthNet driven by a Markov-modulated process that
//!   switches between a whisper and 3× its own capacity;
//! * `diurnal` — synthnet_small with a day/night load curve.
//!
//! Every tenant starts from its own Shisha-tuned configuration; when the
//! burst saturates shared EPs, time-slicing slows its neighbours, the SLO
//! goodput regresses, and the engine warm re-tunes the victims online.
//! The bursty tenant runs sharded with the **runtime autoscaler** live,
//! so its replicas park through the whispers and re-activate for the
//! floods; a second run under the **cross-tenant co-planner** (disjoint
//! EP budgets, weighted water-filling) shows what isolating the storm
//! costs and saves.
//!
//! The storm run is captured by the **flight recorder** (`serve_traced`)
//! and saved as a binary `.trace`; at the end the recorded arrival
//! streams replay under a doubled shard budget (`replay_whatif`) to
//! answer the counterfactual — would more replicas have held goodput
//! through the same storm? — without re-rolling any randomness.
//!
//! ```sh
//! cargo run --release --example serving_storm
//! ```

use shisha::metrics::table::{f, latency_table, Table};
use shisha::perfdb::{CostModel, PerfDb};
use shisha::pipeline::simulator;
use shisha::platform::configs;
use shisha::serve::{
    replay_whatif, serve, serve_traced, shisha_config, ArrivalProcess, AutoscaleOptions,
    BalancerPolicy, ServeOptions, TenantSpec, WhatIf,
};

fn main() {
    let plat = configs::c4();
    let model = CostModel::default();

    let nets = [
        ("steady", shisha::model::networks::alexnet()),
        ("bursty", shisha::model::networks::synthnet()),
        ("diurnal", shisha::model::networks::synthnet_small()),
    ];

    // per-tenant Shisha-tuned configs and contention-free capacities
    let mut tenants = Vec::new();
    let mut caps = Vec::new();
    for (name, net) in &nets {
        let config = shisha_config(net, &plat);
        let db = PerfDb::build(net, &plat, &model);
        let cap = simulator::throughput(net, &plat, &db, &config);
        println!("{name}: capacity {:.1} req/s with {}", cap, config.describe());
        caps.push(cap);
        tenants.push((name, net.clone(), config));
    }

    let duration = 120.0;
    let arrivals = [
        ArrivalProcess::Poisson { rate: 0.4 * caps[0] },
        ArrivalProcess::Mmpp {
            low_rate: 0.05 * caps[1],
            high_rate: 3.0 * caps[1],
            mean_low_s: 20.0,
            mean_high_s: 10.0,
        },
        ArrivalProcess::Diurnal { base_rate: 0.3 * caps[2], amplitude: 0.9, period_s: 40.0 },
    ];

    let specs: Vec<(TenantSpec, _)> = tenants
        .into_iter()
        .zip(arrivals)
        .map(|((name, net, config), arr)| {
            let slo = 0.100; // 100 ms SLO for everyone
            let mut spec =
                TenantSpec::new(*name, net, arr).with_slo(slo).with_queue_capacity(128);
            if *name == "bursty" {
                // the storm source runs replicated: up to two pipelines on
                // disjoint EP subsets behind a join-shortest-queue
                // balancer, weighted double for the co-planned run below
                spec = spec
                    .with_shards(2)
                    .with_balancer(BalancerPolicy::JoinShortestQueue)
                    .with_weight(2.0);
            }
            (spec, config)
        })
        .collect();

    let opts = ServeOptions {
        duration_s: duration,
        seed: 7,
        control_epoch_s: 5.0,
        // the autoscaler parks the bursty tenant's spare replica between
        // floods and re-activates it when the MMPP switches high
        autoscale: AutoscaleOptions::enabled(),
        ..Default::default()
    };
    // Record the storm while serving it: the capture taps the hashed
    // event stream without perturbing the simulation.
    let (report, trace) = serve_traced(&plat, specs.clone(), &opts).expect("serve run");
    let trace_path = std::env::temp_dir().join("serving_storm.trace");
    trace.save(&trace_path).expect("save storm trace");
    println!(
        "recorded {} event(s) + {} control record(s) to {} (log_hash {:016x})",
        trace.events.len(),
        trace.controls.len(),
        trace_path.display(),
        report.log_hash
    );

    println!("\nper-epoch goodput (req/s), * marks a warm re-tune:");
    let mut timeline = Table::new(["t (s)", "steady", "bursty", "diurnal"]);
    let n_epochs = report.tenants[0].epochs.len();
    for e in 0..n_epochs {
        let cell = |ti: usize| {
            let ep = &report.tenants[ti].epochs[e];
            format!("{}{}", f(ep.goodput, 1), if ep.retuned { " *" } else { "" })
        };
        timeline.row([
            f(report.tenants[0].epochs[e].end_s, 0),
            cell(0),
            cell(1),
            cell(2),
        ]);
    }
    println!("{}", timeline.to_markdown());

    let table = latency_table(report.tenants.iter().map(|t| t.latency_row(report.duration_s)));
    println!("{}", table.to_markdown());

    for t in &report.tenants {
        println!(
            "{}: {} re-tune(s), final config {}",
            t.name,
            t.retunes,
            t.final_config.describe()
        );
        if t.shards.len() > 1 {
            for (i, s) in t.shards.iter().enumerate() {
                println!(
                    "  shard {i} on EPs {:?}: routed {}, completed {}, {} scale event(s), \
                     {} at horizon, final {}",
                    s.eps,
                    s.offered,
                    s.completed,
                    s.scale_events.len(),
                    s.final_state.name(),
                    s.final_config.describe()
                );
            }
            println!(
                "  EP-epochs {} (always-on would pay {})",
                t.ep_epochs(),
                t.always_on_ep_epochs()
            );
        }
    }
    println!(
        "fairness (Jain) {:.4} over {} events",
        report.fairness(),
        report.n_events
    );

    // --- the same storm under the cross-tenant co-planner: disjoint EP
    // budgets (bursty weighted 2×) mean the flood can no longer slow its
    // neighbours — at the price of capping everyone at their own budget
    let co_opts = ServeOptions { coplan: true, ..opts };
    let co = serve(&plat, specs, &co_opts).expect("co-planned serve run");
    println!("\nco-planned rerun (disjoint EP budgets, bursty weighted 2x):");
    for (t, shared) in co.tenants.iter().zip(&report.tenants) {
        let eps: Vec<_> = t.shards.iter().flat_map(|s| s.eps.iter().copied()).collect();
        println!(
            "{}: budget EPs {:?}, goodput {} req/s (shared run: {}), {} re-tune(s)",
            t.name,
            eps,
            f(t.goodput(co.duration_s), 1),
            f(shared.goodput(report.duration_s), 1),
            t.retunes
        );
    }
    println!(
        "co-planned fairness (Jain) {:.4} over {} events",
        co.fairness(),
        co.n_events
    );

    // --- what-if replay: the *same* storm (the captured arrival streams,
    // replayed verbatim — no re-rolled randomness) under a doubled shard
    // budget. Request conservation is checked inside replay_whatif, so
    // the goodput deltas below compare like with like.
    let what_if = WhatIf { shards: Some(4), ..Default::default() };
    let wi = replay_whatif(&trace, &what_if).expect("what-if replay");
    println!("\nwhat-if replay of the recorded storm ({}):", what_if.describe());
    for (t, rec) in wi.tenants.iter().zip(&report.tenants) {
        let recorded = rec.goodput(report.duration_s);
        let counterfactual = t.goodput(wi.duration_s);
        println!(
            "{}: goodput {} req/s recorded -> {} req/s at shards=4 ({:+.1})",
            t.name,
            f(recorded, 1),
            f(counterfactual, 1),
            counterfactual - recorded
        );
    }
    println!(
        "what-if fairness (Jain) {:.4} over {} events — replay the trace yourself with \
         `shisha serve --replay {}`",
        wi.fairness(),
        wi.n_events,
        trace_path.display()
    );
}
