//! END-TO-END driver (DESIGN.md §4): the full three-layer stack on a real
//! workload.
//!
//! 1. loads the AOT artifacts produced by `make artifacts` — each conv
//!    layer is a Pallas im2col+GEMM kernel lowered through JAX to HLO text;
//! 2. builds a heterogeneous 4-EP platform (C2) with emulated EP service
//!    rates calibrated from the analytic chiplet model;
//! 3. generates the Algorithm-1 seed, then runs Algorithm-2 online tuning
//!    against *measured* throughput of the live threaded pipeline (one
//!    worker per stage, each with its own PJRT CPU client);
//! 4. serves a 200-image streaming workload on the tuned configuration and
//!    reports throughput/latency before vs after tuning.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use anyhow::{Context, Result};
use shisha::coordinator::{EpEmulation, OnlineTuner, PipelineRuntime};
use shisha::explore::shisha::{generate_seed, AssignmentChoice};
use shisha::metrics::table::{f, Table};
use shisha::model::networks;
use shisha::perfdb::{CostModel, PerfDb};
use shisha::platform::configs;
use shisha::runtime::Manifest;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let workload: usize = 200;

    // --- load artifacts and cross-check against the rust layer table ----
    let manifest = Manifest::load(&dir).context("run `make artifacts` first")?;
    let net = networks::synthnet_small();
    manifest.check_against(&net)?;
    println!(
        "artifacts: {} modules for {} ({} layers), hash {}",
        manifest.artifacts.len(),
        manifest.network,
        manifest.layers,
        manifest.layer_hash
    );

    // --- heterogeneous platform (emulated service rates) ----------------
    let plat = configs::c2();
    let model = CostModel::default();
    let emu = EpEmulation::from_model(&net, &plat, &model);
    println!("platform {}: EP slowdown factors {:?}", plat.name, emu.factors);
    let rt = PipelineRuntime::new(manifest, emu)?;

    // --- Algorithm 1 seed ------------------------------------------------
    let seed = generate_seed(&net, &plat, AssignmentChoice::RankW, 0);
    println!("\nAlgorithm-1 seed: {}", seed.config.describe());
    // warm-up run (PJRT compilation happens on first use per worker)
    let _ = rt.measure(&seed.config, 8)?;
    let seed_run = rt.measure(&seed.config, 64)?;
    println!("seed measured throughput: {:.1} img/s", seed_run.throughput);

    // --- Algorithm 2 online tuning against live measurements -------------
    let mut tuner = OnlineTuner::new(&rt, &plat);
    tuner.alpha = 6;
    tuner.probe_inputs = 32;
    let report = tuner.tune(seed.config.clone())?;
    let mut trials = Table::new(["trial", "config", "img/s", "slowest stage (ms)"]);
    for t in &report.trials {
        trials.row([
            t.trial.to_string(),
            t.config.describe(),
            f(t.throughput, 1),
            f(t.stage_times.iter().cloned().fold(0.0, f64::max) * 1e3, 2),
        ]);
    }
    println!("\nonline tuning ({} trials, {:.1}s):\n{}", report.trials.len(), report.total_wall_s, trials.to_markdown());

    // --- serve the workload on the tuned configuration -------------------
    let tuned_run = rt.measure(&report.best_config, workload)?;
    let mut summary = Table::new(["configuration", "img/s", "workload wall (s)", "improvement"]);
    let seed_serve = rt.measure(&seed.config, workload)?;
    summary.row([
        format!("seed  {}", seed.config.describe()),
        f(seed_serve.throughput, 1),
        f(seed_serve.wall_s, 2),
        "1.00x".into(),
    ]);
    summary.row([
        format!("tuned {}", report.best_config.describe()),
        f(tuned_run.throughput, 1),
        f(tuned_run.wall_s, 2),
        format!("{:.2}x", tuned_run.throughput / seed_serve.throughput),
    ]);
    println!("\nserving {workload} images:\n{}", summary.to_markdown());

    // --- sanity: measured ranking agrees with the analytic simulator -----
    let db = PerfDb::build(&net, &plat, &model);
    let sim_seed = shisha::pipeline::simulator::throughput(&net, &plat, &db, &seed.config);
    let sim_tuned = shisha::pipeline::simulator::throughput(&net, &plat, &db, &report.best_config);
    let consistent = report.best_config == seed.config
        || (sim_tuned >= sim_seed) == (tuned_run.throughput >= 0.95 * seed_serve.throughput);
    println!(
        "\nanalytic model agrees on ranking: sim(tuned) {:.2} vs sim(seed) {:.2} img/s ({})",
        sim_tuned,
        sim_seed,
        if consistent { "consistent" } else { "INCONSISTENT" }
    );
    assert!(
        tuned_run.throughput >= 0.9 * seed_serve.throughput,
        "tuning must not materially regress"
    );

    // --- open-loop serving latency (router-view, simulator-backed) -------
    use shisha::coordinator::workload::{serve, Arrivals};
    let tuned_eval = shisha::pipeline::simulator::evaluate(&net, &plat, &db, &report.best_config);
    let lambda = 0.7 / tuned_eval.bottleneck_s; // 70% utilisation
    let mut lat = Table::new(["configuration", "util", "p50 (ms)", "p99 (ms)"]);
    for (label, cfg) in [("seed", &seed.config), ("tuned", &report.best_config)] {
        let r = serve(&net, &plat, &db, cfg, Arrivals::Poisson(lambda), 2000, 7);
        lat.row([
            label.to_string(),
            f(r.utilisation, 2),
            f(r.p50_s * 1e3, 3),
            f(r.p99_s * 1e3, 3),
        ]);
    }
    println!("\nopen-loop Poisson serving at 70% of tuned capacity (simulated):\n{}", lat.to_markdown());
    Ok(())
}
