//! Heterogeneity study: what happens to Shisha's schedule as the platform
//! becomes more/less heterogeneous — the motivating scenario of §2 (thread
//! and data assignment under memory heterogeneity) projected onto the
//! pipeline problem.
//!
//! Sweeps the Big:Little compute ratio and the fast:slow bandwidth ratio
//! by scaling the cost model, and reports where Shisha places the heavy
//! ResNet50 stages.
//!
//! ```sh
//! cargo run --release --example heterogeneity_study
//! ```

use shisha::explore::shisha::{generate_seed, AssignmentChoice, ShishaExplorer, ShishaOptions};
use shisha::explore::{Evaluator, Explorer};
use shisha::metrics::table::{f, Table};
use shisha::model::networks;
use shisha::perfdb::{CostModel, PerfDb};
use shisha::platform::configs;

fn main() {
    let net = networks::resnet50();
    let plat = configs::c2(); // 2 FEP + 2 SEP

    let mut table = Table::new([
        "gemm efficiency",
        "sigma (par. loss)",
        "best throughput (img/s)",
        "layers on FEPs",
        "layers on SEPs",
        "weight share on FEPs",
    ]);
    for &eff in &[0.25, 0.5, 0.8] {
        for &sigma in &[0.0, 0.04, 0.15] {
            let model = CostModel { gemm_efficiency: eff, sigma, ..Default::default() };
            let db = PerfDb::build(&net, &plat, &model);
            let mut eval = Evaluator::new(&net, &plat, &db);
            let sol = ShishaExplorer::new(ShishaOptions::default()).explore(&mut eval);
            let cfg = &sol.best_config;
            let mut fep_layers = 0usize;
            let mut fep_weight = 0u64;
            for (si, &(lo, hi)) in cfg.stage_bounds().iter().enumerate() {
                if plat.eps[cfg.assignment[si]].is_fep() {
                    fep_layers += hi - lo;
                    fep_weight += net.range_weight(lo, hi);
                }
            }
            table.row([
                f(eff, 2),
                f(sigma, 2),
                f(sol.best_throughput, 3),
                fep_layers.to_string(),
                (net.len() - fep_layers).to_string(),
                format!("{:.0}%", 100.0 * fep_weight as f64 / net.total_weight() as f64),
            ]);
        }
    }
    println!("ResNet50 on C2 — schedule vs heterogeneity parameters:\n{}", table.to_markdown());

    // The Rank_w premise: heavy stages land on FEPs at the seed already.
    let seed = generate_seed(&net, &plat, AssignmentChoice::RankW, 0);
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let eval = shisha::pipeline::simulator::evaluate(&net, &plat, &db, &seed.config);
    let mut seed_t = Table::new(["stage", "layers", "EP", "is FEP", "time (ms)"]);
    for (i, st) in eval.stages.iter().enumerate() {
        let ep = &plat.eps[seed.config.assignment[i]];
        seed_t.row([
            i.to_string(),
            seed.config.stages[i].to_string(),
            ep.describe(),
            ep.is_fep().to_string(),
            f(st.total() * 1e3, 2),
        ]);
    }
    println!("Rank_w seed placement:\n{}", seed_t.to_markdown());
    println!("expected: the FEP share of weight grows as heterogeneity sharpens —\nShisha shifts load towards fast EPs exactly when they are relatively faster.");
}
