//! Quickstart: schedule a CNN pipeline on a heterogeneous chiplet platform
//! with Shisha, in ~20 lines of library use.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use shisha::explore::shisha::{ShishaExplorer, ShishaOptions};
use shisha::explore::{Evaluator, Explorer};
use shisha::model::networks;
use shisha::perfdb::{CostModel, PerfDb};
use shisha::pipeline::space;
use shisha::platform::configs;

fn main() {
    // 1. Pick a CNN and a platform (Table 3 C3: 4 fast + 2 slow EPs).
    let net = networks::resnet50();
    let plat = configs::c3();

    // 2. Build the per-layer execution-time database (the paper queries a
    //    gem5-generated database; we use the analytic chiplet model).
    let db = PerfDb::build(&net, &plat, &CostModel::default());

    // 3. Run Shisha: Algorithm-1 seed + Algorithm-2 online tuning (H3, α=10).
    let mut eval = Evaluator::new(&net, &plat, &db);
    let sol = ShishaExplorer::new(ShishaOptions::default()).explore(&mut eval);

    // 4. Inspect the schedule.
    let space = space::full_space_size(net.len(), plat.n_eps());
    println!("network      : {} ({} layers)", net.name, net.len());
    println!("platform     : {} ({} EPs)", plat.name, plat.n_eps());
    println!("schedule     : {}", sol.best_config.describe());
    println!("throughput   : {:.3} img/s", sol.best_throughput);
    println!("configs tried: {} ({:.4}% of the design space)", sol.n_evals, 100.0 * sol.explored_fraction(space));
    println!("online cost  : {:.2} virtual seconds", sol.virtual_time_s);

    assert!(sol.best_config.validate(net.len(), &plat).is_ok());
}
