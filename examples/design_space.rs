//! Design-space analysis: how the configuration space explodes with CNN
//! depth and EP count, and how little of it each algorithm needs — the
//! scalability argument of §7.2/§7.3 (Pipe-Search's database "is
//! prohibitively slow for larger systems and deeper CNNs").
//!
//! ```sh
//! cargo run --release --example design_space
//! ```

use shisha::explore::pipe_search::{PipeSearch, PsOptions};
use shisha::explore::shisha::{ShishaExplorer, ShishaOptions};
use shisha::explore::{EvalOptions, Evaluator, Explorer};
use shisha::metrics::table::{f, Table};
use shisha::model::networks;
use shisha::perfdb::{CostModel, PerfDb};
use shisha::pipeline::space;
use shisha::platform::configs;

fn main() {
    // 1. Space growth with depth and EPs.
    let mut growth = Table::new(["layers", "EPs", "full space", "depth<=4 space"]);
    for (l, e) in [(5usize, 2usize), (18, 4), (18, 8), (50, 4), (50, 8), (52, 8), (104, 8)] {
        growth.row([
            l.to_string(),
            e.to_string(),
            format!("{:.3e}", space::full_space_size(l, e) as f64),
            format!("{:.3e}", space::space_size(l, e, 4) as f64),
        ]);
    }
    println!("design-space growth:\n{}", growth.to_markdown());

    // 2. Exploration economics: Shisha vs Pipe-Search on growing SynthNets.
    let plat = configs::fig4_platform();
    let mut econ = Table::new([
        "network",
        "layers",
        "Shisha configs",
        "Shisha explored %",
        "PS db size (depth<=4)",
        "PS setup (virt s)",
        "Shisha total (virt s)",
    ]);
    for n in [9usize, 18, 36, 72] {
        let net = networks::synthnet_n(n);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let sp = space::full_space_size(net.len(), plat.n_eps());

        let mut eval = Evaluator::new(&net, &plat, &db);
        let shisha = ShishaExplorer::new(ShishaOptions::default()).explore(&mut eval);

        // PS database size = partitions up to depth 4
        let ps_db = PipeSearch::new(PsOptions::default()).generate_database(&net, plat.n_eps());
        let ps_setup = ps_db.len() as f64 * EvalOptions::default().db_gen_per_config_s;

        econ.row([
            net.name.clone(),
            n.to_string(),
            shisha.n_evals.to_string(),
            format!("{:.6}%", 100.0 * shisha.explored_fraction(sp)),
            ps_db.len().to_string(),
            f(ps_setup, 1),
            f(shisha.virtual_time_s, 2),
        ]);
    }
    println!("exploration economics (8-EP platform):\n{}", econ.to_markdown());
    println!(
        "note how the Pipe-Search database grows combinatorially with depth while\n\
         Shisha's trials stay ~constant (α-bounded) — the paper's scalability claim."
    );
}
