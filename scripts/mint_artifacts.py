#!/usr/bin/env python3
"""Copy CI-measured artifacts over committed placeholders — and only over
placeholders.

The repository is grown from environments that do not always have a Rust
toolchain, so two kinds of measured artifact start life as committed
placeholders:

* `rust/tests/golden/serve_fingerprints.txt` — header-only until a test
  run mints the absolute `log_hash` pins;
* `BENCH_*.json` at the repository root — full metric-key schema with
  `null` for every value until a bench run records real numbers.

CI regenerates both with real measurements on every run (uploaded as the
`golden-fingerprints` and `bench-json` artifacts). This script, run by the
gated `mint-artifacts` job on pushes to main, copies a fresh artifact over
its committed counterpart **iff the committed copy is still a
placeholder**. Committed real measurements are never overwritten, so the
perf trajectory stays a deliberate, reviewed signal rather than CI churn.

Usage:
    mint_artifacts.py --fingerprints FRESH_PINS.txt --bench-dir FRESH_DIR

Run from the repository root. Exits 0 whether or not anything was minted;
the workflow decides whether to commit based on `git diff`.
"""

import argparse
import json
import pathlib
import shutil
import sys

REPO_FINGERPRINTS = pathlib.Path("rust/tests/golden/serve_fingerprints.txt")


def has_pins(path: pathlib.Path) -> bool:
    """True when the fingerprint file carries at least one pin line."""
    if not path.is_file():
        return False
    for line in path.read_text(encoding="utf-8").splitlines():
        s = line.strip()
        if s and not s.startswith("#"):
            return True
    return False


def bench_is_placeholder(path: pathlib.Path) -> bool:
    """True when every metric value in the committed bench file is null
    (or the file has no cases at all)."""
    try:
        doc = json.loads(path.read_text(encoding="utf-8"))
    except (OSError, json.JSONDecodeError):
        return False  # unreadable committed copy: leave it for the schema check
    cases = doc.get("cases")
    if not isinstance(cases, dict) or not cases:
        return True
    for metrics in cases.values():
        if isinstance(metrics, dict) and any(v is not None for v in metrics.values()):
            return False
    return True


def bench_has_measurements(path: pathlib.Path) -> bool:
    """True when the fresh bench file parses and carries a real number."""
    try:
        doc = json.loads(path.read_text(encoding="utf-8"))
    except (OSError, json.JSONDecodeError):
        return False
    cases = doc.get("cases")
    if not isinstance(cases, dict) or not cases:
        return False
    return any(
        isinstance(metrics, dict) and any(v is not None for v in metrics.values())
        for metrics in cases.values()
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fingerprints", type=pathlib.Path, required=True,
                    help="freshly minted serve_fingerprints.txt from the CI artifact")
    ap.add_argument("--bench-dir", type=pathlib.Path, required=True,
                    help="directory of freshly measured BENCH_*.json files")
    args = ap.parse_args()

    minted = []

    if has_pins(REPO_FINGERPRINTS):
        print(f"{REPO_FINGERPRINTS}: already carries pins, leaving committed copy alone")
    elif has_pins(args.fingerprints):
        shutil.copyfile(args.fingerprints, REPO_FINGERPRINTS)
        minted.append(str(REPO_FINGERPRINTS))
    else:
        print(f"{args.fingerprints}: fresh artifact has no pins either, nothing to mint")

    for fresh in sorted(args.bench_dir.glob("BENCH_*.json")):
        committed = pathlib.Path(fresh.name)
        if not committed.is_file():
            print(f"{committed}: not committed at the repo root, skipping")
            continue
        if not bench_is_placeholder(committed):
            print(f"{committed}: committed copy carries measurements, leaving it alone")
            continue
        if not bench_has_measurements(fresh):
            print(f"{fresh}: fresh artifact carries no measurements, nothing to mint")
            continue
        shutil.copyfile(fresh, committed)
        minted.append(str(committed))

    if minted:
        print("minted over placeholders:")
        for path in minted:
            print(f"  {path}")
    else:
        print("nothing minted")
    return 0


if __name__ == "__main__":
    sys.exit(main())
