#!/usr/bin/env python3
"""CI guard for the committed BENCH_*.json perf-trajectory files.

The quick bench profiles overwrite BENCH_hotpath.json / BENCH_serve.json in
the CI checkout; this script then compares each freshly generated file
against the copy committed at HEAD:

* the fresh file must parse, carry the `shisha-bench-v1` schema tag, and
  contain at least one case (the benches just ran — an empty file means the
  writer regressed);
* if the committed copy has cases, every case name shared with the fresh
  run must expose the **same metric-key set** — a renamed or dropped metric
  fails CI so the committed trajectory cannot silently diverge from what
  the benches emit;
* a committed copy with zero cases is a placeholder (authored without a
  Rust toolchain): that emits a loud GitHub warning annotation telling the
  next committer to refresh it from the `bench-json` artifact, but does not
  fail — refusing would wedge CI on the very commit that adds the check;
* `BENCH_plan.json` additionally gets an envelope check on the fresh run:
  the `aggregate` case must carry the planner fast-path metrics, the
  warm-vs-cold `plan_speedup` must exceed 1 (the ISSUE-5 acceptance bar —
  the bench itself asserts this before writing, so a violation here means
  the file was produced some other way), and the cache hit rate must be a
  valid fraction;
* `BENCH_replay.json` gets the flight-recorder envelope on the fresh run:
  the `aggregate` case must carry the recorder metrics, the recording
  overhead fraction must be below 1 (a capture tap that halves the engine
  is a regression whatever the trajectory says), and the full-replay
  throughput must be positive (replay_full verified at least one event
  per wall-second — zero means replay never ran);
* `BENCH_fault.json` gets the fault-plane recovery envelope on the fresh
  run: the `aggregate` case must carry the recovery metrics, failover
  must settle within 2 control epochs (the PR-7 acceptance bar — the
  bench asserts this before writing, so a violation here means the file
  was produced some other way), and the goodput retained under the
  strongest-EP fail-stop must be a valid positive fraction;
* `BENCH_elastic.json` gets the elastic-loop envelope on the fresh run:
  the `aggregate` case must carry the re-planning metrics, the live
  weighted goodput must hold the static co-plan's
  (`weighted_goodput_ratio` >= 1, the PR-8 acceptance bar — the bench
  asserts this before writing), the live cells may not consume extra
  EP-epochs (`ep_epoch_ratio` <= 1), and at least one re-partition must
  have been adopted (zero would make the comparison vacuous);
* `BENCH_obs.json` gets the telemetry-plane envelope on the fresh run:
  the `aggregate` case must carry the observability metrics, the
  sampling overhead fraction must stay below 0.05 (the PR-9 acceptance
  bar — telemetry is derived beside the hash funnel and must cost the
  engine essentially nothing), and the epoch-sample rate must be
  positive (zero samples means the observed run never ticked);
* `BENCH_retry.json` gets the request-lifecycle envelope on the fresh
  run: the `aggregate` case must carry the lifecycle metrics, the
  goodput retained through the faulted storm with the lifecycle on
  must be at least 0.95 (the PR-10 acceptance bar — the bench asserts
  this before writing, so a violation here means the file was produced
  some other way), and the hedge win rate must be a valid fraction.

Usage: check_bench_schema.py BENCH_a.json [BENCH_b.json ...]
(paths relative to the repository root; run from anywhere inside the repo).
"""

import json
import subprocess
import sys

SCHEMA = "shisha-bench-v1"

# Fresh-run envelope for BENCH_plan.json: aggregate metrics the planner
# fast-path trajectory is meaningless without.
PLAN_AGGREGATE_KEYS = {
    "plan_speedup",
    "shard_plan_speedup",
    "parallel_speedup",
    "cache_hit_rate",
    "cache_entries",
    "threads",
    "warm_plans_per_s",
}


def check_plan_envelope(path: str, fresh_cases: dict) -> list[str]:
    """Extra validation applied to a freshly generated BENCH_plan.json."""
    problems = []
    aggregate = fresh_cases.get("aggregate")
    if not isinstance(aggregate, dict):
        return [f"{path}: fresh run has no 'aggregate' case"]
    missing = PLAN_AGGREGATE_KEYS - set(aggregate)
    if missing:
        problems.append(f"{path}: aggregate case lacks {sorted(missing)}")
    speedup = aggregate.get("plan_speedup")
    if isinstance(speedup, (int, float)) and speedup <= 1.0:
        problems.append(
            f"{path}: warm-vs-cold plan_speedup {speedup} must exceed 1 "
            "(memoized planning regressed to cold-plan cost)"
        )
    hit_rate = aggregate.get("cache_hit_rate")
    if isinstance(hit_rate, (int, float)) and not 0.0 <= hit_rate <= 1.0:
        problems.append(f"{path}: cache_hit_rate {hit_rate} is not a fraction")
    return problems


# Fresh-run envelope for BENCH_replay.json: the flight-recorder cost and
# replay-throughput metrics the trace subsystem is tracked by.
REPLAY_AGGREGATE_KEYS = {
    "record_overhead_frac",
    "live_events_per_s",
    "recorded_events_per_s",
    "replay_events_per_s",
    "reps",
}


def check_replay_envelope(path: str, fresh_cases: dict) -> list[str]:
    """Extra validation applied to a freshly generated BENCH_replay.json."""
    problems = []
    aggregate = fresh_cases.get("aggregate")
    if not isinstance(aggregate, dict):
        return [f"{path}: fresh run has no 'aggregate' case"]
    missing = REPLAY_AGGREGATE_KEYS - set(aggregate)
    if missing:
        problems.append(f"{path}: aggregate case lacks {sorted(missing)}")
    overhead = aggregate.get("record_overhead_frac")
    if isinstance(overhead, (int, float)) and overhead >= 1.0:
        problems.append(
            f"{path}: record_overhead_frac {overhead} must stay below 1 "
            "(the capture tap ate the whole engine throughput)"
        )
    replay_eps = aggregate.get("replay_events_per_s")
    if not isinstance(replay_eps, (int, float)) or replay_eps <= 0.0:
        problems.append(
            f"{path}: replay_events_per_s {replay_eps!r} must be a positive number "
            "(full replay never re-simulated anything)"
        )
    return problems


# Fresh-run envelope for BENCH_fault.json: the fault-plane recovery
# metrics the failover path is tracked by.
FAULT_AGGREGATE_KEYS = {
    "recovery_epochs",
    "goodput_retained_frac",
    "surviving_capacity_frac",
    "replan_warm_ms",
    "replan_speedup",
    "reps",
}


def check_fault_envelope(path: str, fresh_cases: dict) -> list[str]:
    """Extra validation applied to a freshly generated BENCH_fault.json."""
    problems = []
    aggregate = fresh_cases.get("aggregate")
    if not isinstance(aggregate, dict):
        return [f"{path}: fresh run has no 'aggregate' case"]
    missing = FAULT_AGGREGATE_KEYS - set(aggregate)
    if missing:
        problems.append(f"{path}: aggregate case lacks {sorted(missing)}")
    epochs = aggregate.get("recovery_epochs")
    if not isinstance(epochs, (int, float)) or epochs > 2.0:
        problems.append(
            f"{path}: recovery_epochs {epochs!r} must be a number <= 2 "
            "(failover is required to settle within two control epochs)"
        )
    retained = aggregate.get("goodput_retained_frac")
    if not isinstance(retained, (int, float)) or not 0.0 < retained <= 1.1:
        problems.append(
            f"{path}: goodput_retained_frac {retained!r} is not a valid positive "
            "fraction (the faulted run lost its goodput entirely, or the ratio "
            "was computed against the wrong baseline)"
        )
    return problems


# Fresh-run envelope for BENCH_elastic.json: the demand-driven
# re-planning metrics the elastic control loop is tracked by.
ELASTIC_AGGREGATE_KEYS = {
    "weighted_goodput_ratio",
    "ep_epoch_ratio",
    "repartitions",
    "reps",
}


def check_elastic_envelope(path: str, fresh_cases: dict) -> list[str]:
    """Extra validation applied to a freshly generated BENCH_elastic.json."""
    problems = []
    aggregate = fresh_cases.get("aggregate")
    if not isinstance(aggregate, dict):
        return [f"{path}: fresh run has no 'aggregate' case"]
    missing = ELASTIC_AGGREGATE_KEYS - set(aggregate)
    if missing:
        problems.append(f"{path}: aggregate case lacks {sorted(missing)}")
    ratio = aggregate.get("weighted_goodput_ratio")
    if not isinstance(ratio, (int, float)) or ratio < 1.0:
        problems.append(
            f"{path}: weighted_goodput_ratio {ratio!r} must be a number >= 1 "
            "(live re-planning lost to the static co-plan it started from)"
        )
    ep_ratio = aggregate.get("ep_epoch_ratio")
    if not isinstance(ep_ratio, (int, float)) or ep_ratio > 1.0:
        problems.append(
            f"{path}: ep_epoch_ratio {ep_ratio!r} must be a number <= 1 "
            "(the elastic win may not come from holding extra EPs active)"
        )
    repartitions = aggregate.get("repartitions")
    if not isinstance(repartitions, (int, float)) or repartitions < 1:
        problems.append(
            f"{path}: repartitions {repartitions!r} must be >= 1 "
            "(the elastic loop never moved, so the comparison is vacuous)"
        )
    return problems


# Fresh-run envelope for BENCH_obs.json: the telemetry-plane overhead
# metrics the observability tap is tracked by.
OBS_AGGREGATE_KEYS = {
    "sampling_overhead_frac",
    "samples_per_s",
    "live_events_per_s",
    "observed_events_per_s",
    "reps",
}


def check_obs_envelope(path: str, fresh_cases: dict) -> list[str]:
    """Extra validation applied to a freshly generated BENCH_obs.json."""
    problems = []
    aggregate = fresh_cases.get("aggregate")
    if not isinstance(aggregate, dict):
        return [f"{path}: fresh run has no 'aggregate' case"]
    missing = OBS_AGGREGATE_KEYS - set(aggregate)
    if missing:
        problems.append(f"{path}: aggregate case lacks {sorted(missing)}")
    overhead = aggregate.get("sampling_overhead_frac")
    if not isinstance(overhead, (int, float)) or overhead >= 0.05:
        problems.append(
            f"{path}: sampling_overhead_frac {overhead!r} must be a number below 0.05 "
            "(the telemetry tap is required to be near-free on the hot path)"
        )
    samples = aggregate.get("samples_per_s")
    if not isinstance(samples, (int, float)) or samples <= 0.0:
        problems.append(
            f"{path}: samples_per_s {samples!r} must be a positive number "
            "(the observed run never froze an epoch sample)"
        )
    return problems


# Fresh-run envelope for BENCH_retry.json: the request-lifecycle
# metrics the deadline/retry/hedge layer is tracked by.
RETRY_AGGREGATE_KEYS = {
    "goodput_retained_frac",
    "hedge_fire_rate",
    "hedge_win_rate",
    "hedge_cancel_rate",
    "p99_hedged_s",
    "p99_blind_s",
}


def check_retry_envelope(path: str, fresh_cases: dict) -> list[str]:
    """Extra validation applied to a freshly generated BENCH_retry.json."""
    problems = []
    aggregate = fresh_cases.get("aggregate")
    if not isinstance(aggregate, dict):
        return [f"{path}: fresh run has no 'aggregate' case"]
    missing = RETRY_AGGREGATE_KEYS - set(aggregate)
    if missing:
        problems.append(f"{path}: aggregate case lacks {sorted(missing)}")
    retained = aggregate.get("goodput_retained_frac")
    if not isinstance(retained, (int, float)) or retained < 0.95:
        problems.append(
            f"{path}: goodput_retained_frac {retained!r} must be a number >= 0.95 "
            "(the lifecycle layer is required to carry the faulted storm)"
        )
    win_rate = aggregate.get("hedge_win_rate")
    if not isinstance(win_rate, (int, float)) or not 0.0 <= win_rate <= 1.0:
        problems.append(
            f"{path}: hedge_win_rate {win_rate!r} is not a fraction in [0, 1] "
            "(hedge races cannot be won more often than they are fired)"
        )
    return problems


def load_fresh(path: str) -> dict:
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def load_committed(path: str):
    """The copy at HEAD, or None when the file is new in this change."""
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:{path}"],
            capture_output=True,
            check=True,
            text=True,
        ).stdout
    except subprocess.CalledProcessError:
        return None
    return json.loads(out)


def main(paths: list[str]) -> int:
    failures = []
    for path in paths:
        try:
            fresh = load_fresh(path)
        except (OSError, json.JSONDecodeError) as e:
            failures.append(f"{path}: fresh bench output unreadable: {e}")
            continue
        if fresh.get("schema") != SCHEMA:
            failures.append(f"{path}: fresh schema tag {fresh.get('schema')!r} != {SCHEMA!r}")
            continue
        fresh_cases = fresh.get("cases")
        if not isinstance(fresh_cases, dict) or not fresh_cases:
            failures.append(f"{path}: fresh bench output has no cases — writer regressed?")
            continue
        if path.rsplit("/", 1)[-1] == "BENCH_plan.json":
            failures.extend(check_plan_envelope(path, fresh_cases))
        if path.rsplit("/", 1)[-1] == "BENCH_replay.json":
            failures.extend(check_replay_envelope(path, fresh_cases))
        if path.rsplit("/", 1)[-1] == "BENCH_fault.json":
            failures.extend(check_fault_envelope(path, fresh_cases))
        if path.rsplit("/", 1)[-1] == "BENCH_elastic.json":
            failures.extend(check_elastic_envelope(path, fresh_cases))
        if path.rsplit("/", 1)[-1] == "BENCH_obs.json":
            failures.extend(check_obs_envelope(path, fresh_cases))
        if path.rsplit("/", 1)[-1] == "BENCH_retry.json":
            failures.extend(check_retry_envelope(path, fresh_cases))

        committed = load_committed(path)
        if committed is None:
            print(f"{path}: no committed copy at HEAD (new file), skipping diff")
            continue
        if committed.get("schema") != SCHEMA:
            failures.append(
                f"{path}: committed schema tag {committed.get('schema')!r} != {SCHEMA!r}"
            )
            continue
        committed_cases = committed.get("cases") or {}
        if not committed_cases:
            print(
                f"::warning file={path}::{path} is still a schema placeholder (no cases); "
                "refresh it from this run's `bench-json` artifact so the committed perf "
                "trajectory carries real measurements."
            )
            continue
        shared = sorted(set(committed_cases) & set(fresh_cases))
        if not shared:
            failures.append(
                f"{path}: committed cases {sorted(committed_cases)[:5]}... share no names "
                f"with the fresh run {sorted(fresh_cases)[:5]}... — bench case naming drifted"
            )
            continue
        for case in shared:
            want = set(committed_cases[case])
            got = set(fresh_cases[case])
            if want != got:
                failures.append(
                    f"{path}: case {case!r} metric keys drifted: committed {sorted(want)} "
                    f"vs fresh {sorted(got)}"
                )
        print(f"{path}: OK ({len(shared)} shared case(s) schema-checked)")

    for msg in failures:
        print(f"::error::{msg}")
    return 1 if failures else 0


if __name__ == "__main__":
    if len(sys.argv) < 2:
        print("usage: check_bench_schema.py BENCH_a.json [BENCH_b.json ...]", file=sys.stderr)
        sys.exit(2)
    sys.exit(main(sys.argv[1:]))
