//! CLI smoke tests: drive the `shisha` binary end-to-end per subcommand
//! and assert on its output and exit codes (failure paths included).

use std::process::{Command, Output};

fn shisha(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_shisha"))
        .args(args)
        .output()
        .expect("binary runs")
}

fn stdout(o: &Output) -> String {
    String::from_utf8_lossy(&o.stdout).into_owned()
}

fn stderr(o: &Output) -> String {
    String::from_utf8_lossy(&o.stderr).into_owned()
}

#[test]
fn no_args_prints_usage() {
    let o = shisha(&[]);
    assert!(o.status.success());
    assert!(stdout(&o).contains("USAGE"));
    assert!(stdout(&o).contains("explore"));
}

#[test]
fn version_prints_version() {
    let o = shisha(&["version"]);
    assert!(o.status.success());
    assert!(stdout(&o).contains(env!("CARGO_PKG_VERSION")));
}

#[test]
fn platforms_lists_all_configs() {
    let o = shisha(&["platforms"]);
    assert!(o.status.success());
    for c in ["C1", "C2", "C3", "C4", "C5"] {
        assert!(stdout(&o).contains(c), "missing {c}");
    }
}

#[test]
fn explore_shisha_on_synthnet() {
    let o = shisha(&["explore", "--net", "synthnet", "--platform", "c2", "--algo", "shisha"]);
    assert!(o.status.success(), "{}", stderr(&o));
    let out = stdout(&o);
    assert!(out.contains("design space"));
    assert!(out.contains("Shisha"));
    assert!(out.contains("img/s"));
}

#[test]
fn explore_rejects_unknown_network() {
    let o = shisha(&["explore", "--net", "vgg16"]);
    assert!(!o.status.success());
    assert!(stderr(&o).contains("unknown network"));
}

#[test]
fn explore_rejects_unknown_option() {
    let o = shisha(&["explore", "--nett", "synthnet"]);
    assert!(!o.status.success());
    assert!(stderr(&o).contains("unknown option"));
}

#[test]
fn unknown_subcommand_fails() {
    let o = shisha(&["frobnicate"]);
    assert!(!o.status.success());
    assert!(stderr(&o).contains("unknown subcommand"));
}

#[test]
fn designspace_matches_formula() {
    let o = shisha(&["designspace", "--net", "alexnet", "--eps", "2"]);
    assert!(o.status.success());
    // full space for 5 layers / 2 EPs = 2 + C(4,1)*2 = 10; cumulative "10"
    assert!(stdout(&o).contains("10"), "{}", stdout(&o));
}

#[test]
fn stream_reports_split_win() {
    let o = shisha(&["stream", "--size", "19"]);
    assert!(o.status.success());
    assert!(stdout(&o).contains("DDR only"));
    assert!(stdout(&o).contains("cache mode"));
    assert!(stdout(&o).contains("split"));
}

#[test]
fn seed_shows_stage_table() {
    let o = shisha(&["seed", "--net", "yolov3", "--platform", "c5", "--choice", "rankw"]);
    assert!(o.status.success(), "{}", stderr(&o));
    let out = stdout(&o);
    assert!(out.contains("seed throughput"));
    assert!(out.contains("EP"));
}

#[test]
fn seed_rejects_bad_choice() {
    let o = shisha(&["seed", "--choice", "bogus"]);
    assert!(!o.status.success());
}

#[test]
fn explore_with_config_file() {
    let dir = std::env::temp_dir().join("shisha_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("exp.toml");
    std::fs::write(
        &cfg,
        "[experiment]\nnetwork = \"alexnet\"\nplatform = \"c1\"\n",
    )
    .unwrap();
    let o = shisha(&["explore", "--config", cfg.to_str().unwrap(), "--algo", "shisha"]);
    assert!(o.status.success(), "{}", stderr(&o));
    assert!(stdout(&o).contains("alexnet"));
}

#[test]
fn run_fails_gracefully_without_artifacts() {
    let o = shisha(&["run", "--artifacts", "/nonexistent/dir"]);
    assert!(!o.status.success());
    assert!(stderr(&o).contains("make artifacts"), "{}", stderr(&o));
}

#[test]
fn serve_reports_percentiles_per_tenant() {
    // tiny deterministic run: 2 synthnet_small tenants, short horizon
    let o = shisha(&[
        "serve",
        "--tenants",
        "2",
        "--nets",
        "synthnet_small",
        "--platform",
        "c2",
        "--arrivals",
        "poisson:50",
        "--duration",
        "2",
        "--seed",
        "7",
    ]);
    assert!(o.status.success(), "{}", stderr(&o));
    let out = stdout(&o);
    assert!(out.contains("p50 (ms)"), "{out}");
    assert!(out.contains("p99 (ms)"), "{out}");
    assert!(out.contains("goodput (req/s)"), "{out}");
    assert!(out.contains("drop rate"), "{out}");
    assert!(out.contains("fairness (Jain)"), "{out}");
    assert!(out.contains("synthnet_small-0"), "{out}");
    assert!(out.contains("synthnet_small-1"), "{out}");
}

#[test]
fn serve_is_deterministic_across_invocations() {
    let args = [
        "serve",
        "--tenants",
        "1",
        "--nets",
        "synthnet_small",
        "--platform",
        "c1",
        "--arrivals",
        "mmpp:20,200,1,0.5",
        "--duration",
        "2",
        "--seed",
        "11",
    ];
    let a = shisha(&args);
    let b = shisha(&args);
    assert!(a.status.success(), "{}", stderr(&a));
    assert_eq!(stdout(&a), stdout(&b), "same seed must reproduce the report");
}

#[test]
fn serve_rejects_bad_arrival_spec() {
    let o = shisha(&["serve", "--arrivals", "warp:9"]);
    assert!(!o.status.success());
    assert!(stderr(&o).contains("unknown arrival kind"), "{}", stderr(&o));
}

fn sweep_args(threads: &'static str) -> Vec<&'static str> {
    vec![
        "serve",
        "--sweep",
        "--nets",
        "synthnet_small",
        "--platform",
        "c1",
        "--tenant-grid",
        "1,2",
        "--rho-grid",
        "0.4",
        "--seeds",
        "7",
        "--duration",
        "2",
        "--epoch",
        "0.5",
        "--threads",
        threads,
    ]
}

#[test]
fn serve_sweep_runs_and_reports_event_rates() {
    let o = shisha(&sweep_args("2"));
    assert!(o.status.success(), "{}", stderr(&o));
    let out = stdout(&o);
    assert!(out.contains("sweeping 2 scenario(s)"), "{out}");
    assert!(out.contains("log_hash"), "{out}");
    assert!(out.contains("events/s"), "{out}");
    assert!(out.contains("rho=0.4"), "{out}");
}

#[test]
fn serve_sweep_outcomes_invariant_to_thread_count() {
    // the table (scenario names, event counts, log hashes, goodput) must
    // not depend on parallelism; only the timing summary lines may differ
    let table_of = |o: &Output| -> Vec<String> {
        stdout(o).lines().filter(|l| l.starts_with('|')).map(str::to_string).collect()
    };
    let a = shisha(&sweep_args("1"));
    let b = shisha(&sweep_args("4"));
    assert!(a.status.success(), "{}", stderr(&a));
    assert!(b.status.success(), "{}", stderr(&b));
    let ta = table_of(&a);
    let tb = table_of(&b);
    assert!(!ta.is_empty());
    assert_eq!(ta, tb, "sweep outcomes must be thread-count invariant");
}

#[test]
fn serve_sweep_rejects_bad_grid() {
    let o = shisha(&["serve", "--sweep", "--tenant-grid", "0", "--duration", "1"]);
    assert!(!o.status.success());
    assert!(stderr(&o).contains("tenant-grid"), "{}", stderr(&o));
}

#[test]
fn serve_sharded_reports_replicas() {
    // --shards 2 on C1 (2 EPs): a tiny but real sharded run; per-replica
    // lines appear whenever the placement search actually replicates
    let o = shisha(&[
        "serve",
        "--tenants",
        "1",
        "--nets",
        "synthnet_small",
        "--platform",
        "c1",
        "--arrivals",
        "poisson:120",
        "--duration",
        "2",
        "--shards",
        "2",
        "--balancer",
        "jsq",
        "--seed",
        "5",
    ]);
    assert!(o.status.success(), "{}", stderr(&o));
    let out = stdout(&o);
    assert!(out.contains("synthnet_small-0"), "{out}");
    // the run must succeed and stay conserved regardless of whether the
    // planner chose 1 or 2 replicas; replica detail lines are shard-only
    if out.contains("shard 0") {
        assert!(out.contains("shard 1"), "{out}");
        assert!(out.contains("predicted"), "{out}");
    }
}

#[test]
fn serve_rejects_bad_balancer() {
    let o = shisha(&["serve", "--balancer", "warp", "--duration", "1"]);
    assert!(!o.status.success());
    assert!(stderr(&o).contains("unknown balancer"), "{}", stderr(&o));
}

#[test]
fn serve_sweep_shard_grid_compares_shard_counts() {
    let o = shisha(&[
        "serve",
        "--sweep",
        "--nets",
        "synthnet_small",
        "--platform",
        "c2",
        "--shard-grid",
        "1,2",
        "--rho-grid",
        "1.0",
        "--seeds",
        "7",
        "--duration",
        "2",
        "--epoch",
        "0.5",
        "--threads",
        "2",
    ]);
    assert!(o.status.success(), "{}", stderr(&o));
    let out = stdout(&o);
    assert!(out.contains("sweeping 2 scenario(s)"), "{out}");
    assert!(out.contains("shards=1"), "{out}");
    assert!(out.contains("shards=2"), "{out}");
    assert!(out.contains("goodput (req/s)"), "{out}");
}

#[test]
fn serve_sweep_rejects_bad_shard_grid() {
    let o = shisha(&["serve", "--sweep", "--shard-grid", "0", "--duration", "1"]);
    assert!(!o.status.success());
    assert!(stderr(&o).contains("shard-grid"), "{}", stderr(&o));
}

#[test]
fn usage_lists_shard_flags() {
    let o = shisha(&[]);
    assert!(o.status.success());
    let out = stdout(&o);
    assert!(out.contains("--shards"), "{out}");
    assert!(out.contains("--balancer"), "{out}");
    assert!(out.contains("--shard-grid"), "{out}");
    assert!(out.contains("--coplan"), "{out}");
    assert!(out.contains("--autoscale"), "{out}");
    assert!(out.contains("--autoscale-grid"), "{out}");
}

#[test]
fn serve_coplan_autoscale_runs_deterministically() {
    // two tenants co-planned onto disjoint budgets of C2, autoscaler live
    let args = [
        "serve",
        "--tenants",
        "2",
        "--nets",
        "synthnet_small",
        "--platform",
        "c2",
        "--arrivals",
        "poisson:80",
        "--duration",
        "2",
        "--epoch",
        "0.25",
        "--shards",
        "2",
        "--coplan",
        "--autoscale",
        "--seed",
        "13",
    ];
    let a = shisha(&args);
    assert!(a.status.success(), "{}", stderr(&a));
    let out = stdout(&a);
    assert!(out.contains("co-planning"), "{out}");
    assert!(out.contains("autoscaling"), "{out}");
    assert!(out.contains("EP-epochs"), "{out}");
    let b = shisha(&args);
    assert_eq!(stdout(&a), stdout(&b), "coplan+autoscale must be deterministic");
}

#[test]
fn serve_coplan_rejects_more_tenants_than_eps() {
    let o = shisha(&[
        "serve",
        "--tenants",
        "3",
        "--nets",
        "synthnet_small",
        "--platform",
        "c1",
        "--arrivals",
        "poisson:10",
        "--duration",
        "1",
        "--coplan",
    ]);
    assert!(!o.status.success());
    assert!(stderr(&o).contains("coplan"), "{}", stderr(&o));
}

#[test]
fn serve_sweep_autoscale_grid_compares_static_and_auto() {
    let o = shisha(&[
        "serve",
        "--sweep",
        "--nets",
        "synthnet_small",
        "--platform",
        "c2",
        "--autoscale-grid",
        "1,2",
        "--rho-grid",
        "1.0",
        "--seeds",
        "7",
        "--duration",
        "4",
        "--threads",
        "2",
    ]);
    assert!(o.status.success(), "{}", stderr(&o));
    let out = stdout(&o);
    assert!(out.contains("sweeping 3 scenario(s)"), "{out}");
    assert!(out.contains("static-k1"), "{out}");
    assert!(out.contains("static-k2"), "{out}");
    assert!(out.contains("autoscale-k2"), "{out}");
    assert!(out.contains("EP-epochs"), "{out}");
}

#[test]
fn usage_lists_elastic_flags() {
    let o = shisha(&[]);
    assert!(o.status.success());
    let out = stdout(&o);
    assert!(out.contains("--elastic"), "{out}");
    assert!(out.contains("--elastic-grid"), "{out}");
}

#[test]
fn serve_elastic_requires_coplan() {
    let o = shisha(&[
        "serve",
        "--tenants",
        "2",
        "--nets",
        "synthnet_small",
        "--platform",
        "c2",
        "--arrivals",
        "poisson:40",
        "--duration",
        "1",
        "--elastic",
    ]);
    assert!(!o.status.success());
    assert!(stderr(&o).contains("coplan"), "{}", stderr(&o));
}

#[test]
fn serve_elastic_runs_deterministically() {
    let args = [
        "serve",
        "--tenants",
        "2",
        "--nets",
        "synthnet_small",
        "--platform",
        "c2",
        "--arrivals",
        "poisson:120;poisson:5",
        "--duration",
        "2",
        "--epoch",
        "0.2",
        "--coplan",
        "--elastic",
        "--seed",
        "17",
    ];
    let a = shisha(&args);
    assert!(a.status.success(), "{}", stderr(&a));
    let out = stdout(&a);
    assert!(out.contains("elastic: re-planning"), "{out}");
    let b = shisha(&args);
    assert_eq!(stdout(&a), stdout(&b), "elastic serving must be deterministic");
}

#[test]
fn serve_sweep_elastic_grid_compares_static_and_live() {
    let o = shisha(&[
        "serve",
        "--sweep",
        "--nets",
        "synthnet_small",
        "--platform",
        "c2",
        "--elastic-grid",
        "--rho-grid",
        "1.0",
        "--seeds",
        "7",
        "--duration",
        "4",
        "--threads",
        "2",
    ]);
    assert!(o.status.success(), "{}", stderr(&o));
    let out = stdout(&o);
    assert!(out.contains("sweeping 2 scenario(s)"), "{out}");
    assert!(out.contains("static rho=1"), "{out}");
    assert!(out.contains("elastic rho=1"), "{out}");
    assert!(out.contains("repartitions"), "{out}");
}

#[test]
fn serve_sweep_rejects_conflicting_grids() {
    let o = shisha(&[
        "serve",
        "--sweep",
        "--shard-grid",
        "1,2",
        "--autoscale-grid",
        "1,2",
        "--duration",
        "1",
    ]);
    assert!(!o.status.success());
    assert!(stderr(&o).contains("mutually exclusive"), "{}", stderr(&o));
}

#[test]
fn usage_lists_fault_flags() {
    let o = shisha(&[]);
    assert!(o.status.success());
    let out = stdout(&o);
    assert!(out.contains("--faults"), "{out}");
    assert!(out.contains("--chaos"), "{out}");
    assert!(out.contains("--fault-grid"), "{out}");
    assert!(out.contains("epfail"), "{out}");
    assert!(out.contains("linkcut"), "{out}");
}

#[test]
fn serve_with_faults_runs_deterministically() {
    let args = [
        "serve",
        "--tenants",
        "1",
        "--nets",
        "synthnet_small",
        "--platform",
        "c1",
        "--arrivals",
        "poisson:80",
        "--duration",
        "2",
        "--epoch",
        "0.25",
        "--faults",
        "epstall:1@0.5+0.5",
        "--seed",
        "9",
    ];
    let a = shisha(&args);
    assert!(a.status.success(), "{}", stderr(&a));
    let out = stdout(&a);
    assert!(out.contains("fault plane:"), "{out}");
    assert!(out.contains("epstall"), "{out}");
    let b = shisha(&args);
    assert_eq!(stdout(&a), stdout(&b), "faulted serve must be deterministic");
}

#[test]
fn serve_chaos_generates_a_valid_script() {
    let args = [
        "serve",
        "--tenants",
        "1",
        "--nets",
        "synthnet_small",
        "--platform",
        "c2",
        "--arrivals",
        "poisson:40",
        "--duration",
        "2",
        "--epoch",
        "0.25",
        "--chaos",
        "3",
        "--seed",
        "9",
    ];
    let a = shisha(&args);
    assert!(a.status.success(), "{}", stderr(&a));
    assert!(stdout(&a).contains("fault plane:"), "{}", stdout(&a));
    let b = shisha(&args);
    assert_eq!(stdout(&a), stdout(&b), "chaos script must be seed-deterministic");
}

#[test]
fn serve_rejects_bad_fault_script() {
    let o = shisha(&["serve", "--faults", "warpcore:0@1", "--duration", "1"]);
    assert!(!o.status.success());
    assert!(stderr(&o).contains("fault"), "{}", stderr(&o));
}

#[test]
fn serve_rejects_faults_with_chaos() {
    let o = shisha(&[
        "serve",
        "--faults",
        "epfail:0@1",
        "--chaos",
        "7",
        "--duration",
        "1",
    ]);
    assert!(!o.status.success());
    assert!(stderr(&o).contains("mutually exclusive"), "{}", stderr(&o));
}

#[test]
fn serve_rejects_faults_with_replay() {
    // the conflict is rejected before the trace file is ever opened
    let o = shisha(&[
        "serve",
        "--replay",
        "/nonexistent/t.trace",
        "--faults",
        "epfail:0@1",
    ]);
    assert!(!o.status.success());
    assert!(stderr(&o).contains("what-if faults="), "{}", stderr(&o));
}

#[test]
fn serve_sweep_fault_grid_compares_severities() {
    let o = shisha(&[
        "serve",
        "--sweep",
        "--nets",
        "synthnet_small",
        "--platform",
        "c1",
        "--fault-grid",
        "4",
        "--rho-grid",
        "0.8",
        "--seeds",
        "7",
        "--duration",
        "2",
        "--epoch",
        "0.5",
        "--threads",
        "2",
    ]);
    assert!(o.status.success(), "{}", stderr(&o));
    let out = stdout(&o);
    assert!(out.contains("sweeping 3 scenario(s)"), "{out}");
    assert!(out.contains("fault-free"), "{out}");
    assert!(out.contains("epslow-x4"), "{out}");
    assert!(out.contains("epfail"), "{out}");
}

#[test]
fn serve_sweep_rejects_bad_fault_grid() {
    let o = shisha(&["serve", "--sweep", "--fault-grid", "0.5", "--duration", "1"]);
    assert!(!o.status.success());
    assert!(stderr(&o).contains("fault-grid"), "{}", stderr(&o));
}

#[test]
fn serve_sweep_rejects_fault_grid_with_shard_grid() {
    let o = shisha(&[
        "serve",
        "--sweep",
        "--fault-grid",
        "2",
        "--shard-grid",
        "1,2",
        "--duration",
        "1",
    ]);
    assert!(!o.status.success());
    assert!(stderr(&o).contains("mutually exclusive"), "{}", stderr(&o));
}
