//! Fault-plane integration tests: scripted disasters end to end.
//!
//! Four contracts, strongest first:
//!
//! 1. **Failover never places work on a dead EP** — across a family of
//!    chaos-generated scripts (property-style, many seeds), every replica
//!    still active at the horizon runs on EPs that are healthy at the
//!    horizon, both in its EP set and in its stage assignment.
//! 2. **Requests are conserved through fail → recover cycles** — offered
//!    always equals completed + rejected + dropped + in-flight, across
//!    seeds and across scripts that take EPs down and bring them back.
//! 3. **The acceptance disaster** — the tidal MMPP storm on C5 with a
//!    mid-run fail-stop of the *strongest* EP: zero requests lost,
//!    goodput within 15% of the fault-free run scaled by the surviving
//!    capacity, the failover re-plan settled within two control epochs,
//!    and the whole thing deterministic across invocations.
//! 4. **Faulted runs record and replay bit-identically** — the flight
//!    recorder captures the script inside the trace, a binary round trip
//!    survives, `replay_full` re-simulates to the same hash, and a
//!    `faults=none` what-if strips the script while conserving the
//!    captured workload.
//! 5. **The CLI grammar is total over its own output** — property-style:
//!    chaos scripts across seeds, platforms and sizes render through
//!    `describe()` and re-`parse()` to bit-identical scripts *and*
//!    bit-identical re-rendered strings; a strided single-character
//!    corruption corpus over valid script strings always fails to parse,
//!    and every rejection names the offending event spec.

use shisha::model::networks;
use shisha::perfdb::{CostModel, PerfDb};
use shisha::pipeline::simulator;
use shisha::platform::{configs, Platform};
use shisha::serve::{
    replay_full, replay_whatif, serve, serve_traced, shisha_config, AdmissionPolicy,
    ArrivalProcess, BalancerPolicy, ControlKind, FaultEvent, FaultKind, FaultScript, ReplicaState,
    ServeOptions, TenantReport, TenantSpec, Trace, WhatIf,
};

fn assert_conserved(t: &TenantReport, label: &str) {
    assert_eq!(
        t.offered,
        t.completed + t.rejected + t.dropped + t.in_flight,
        "{label}/{}: offered must equal completed + rejected + dropped + in-flight",
        t.name
    );
}

/// EPs that are down at time `at_s` under `script`: fail-stops and
/// chiplet failures forever after their begin time, stalls only while
/// their window covers `at_s`.
fn downed_at(script: &FaultScript, plat: &Platform, at_s: f64) -> Vec<usize> {
    let mut down = vec![false; plat.n_eps()];
    for ev in &script.events {
        match ev.kind {
            FaultKind::EpFail { ep } if ev.t_s <= at_s => down[ep] = true,
            FaultKind::ChipFail { chiplet } if ev.t_s <= at_s => {
                for ep in &plat.eps {
                    if ep.chiplet == chiplet {
                        down[ep.id] = true;
                    }
                }
            }
            FaultKind::EpStall { ep, down_s } if ev.t_s <= at_s && at_s < ev.t_s + down_s => {
                down[ep] = true;
            }
            _ => {}
        }
    }
    (0..plat.n_eps()).filter(|&e| down[e]).collect()
}

fn storm_tenant(net_cap: f64, shards: usize) -> TenantSpec {
    TenantSpec::new(
        "storm",
        networks::synthnet(),
        ArrivalProcess::Mmpp {
            low_rate: 0.25 * net_cap,
            high_rate: 1.3 * net_cap,
            mean_low_s: 100.0 / net_cap,
            mean_high_s: 100.0 / net_cap,
        },
    )
    .with_shards(shards)
    .with_balancer(BalancerPolicy::JoinShortestQueue)
    .with_queue_capacity(32)
    .with_admission(AdmissionPolicy::DropOldest)
    .with_slo(500.0 / net_cap)
}

// ---------------------------------------------------------------------------
// 1. Property: no post-failover placement touches a dead EP.
// ---------------------------------------------------------------------------

#[test]
fn chaos_failover_never_places_work_on_a_dead_ep() {
    let plat = configs::c5();
    let net = networks::synthnet();
    let config = shisha_config(&net, &plat);
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let cap = simulator::throughput(&net, &plat, &db, &config);
    let duration_s = 400.0 / cap;
    for seed in 1..=6u64 {
        let script = FaultScript::chaos(seed, &plat, duration_s, 5);
        script.validate(&plat).expect("chaos scripts are valid by construction");
        let opts = ServeOptions {
            duration_s,
            seed,
            control_epoch_s: 20.0 / cap,
            faults: script.clone(),
            ..Default::default()
        };
        let report = serve(&plat, vec![(storm_tenant(cap, 2), config.clone())], &opts)
            .unwrap_or_else(|e| panic!("chaos seed {seed}: {e:#}"));
        let dead = downed_at(&script, &plat, duration_s);
        for t in &report.tenants {
            assert_conserved(t, &format!("chaos seed {seed}"));
            for (si, s) in t.shards.iter().enumerate() {
                if s.final_state != ReplicaState::Active {
                    continue;
                }
                for ep in &dead {
                    assert!(
                        !s.eps.contains(ep),
                        "seed {seed} shard {si}: EP set {:?} contains dead EP {ep} \
                         (script: {})",
                        s.eps,
                        script.describe()
                    );
                    assert!(
                        !s.final_config.assignment.contains(ep),
                        "seed {seed} shard {si}: assignment {:?} places a stage on dead \
                         EP {ep} (script: {})",
                        s.final_config.assignment,
                        script.describe()
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 2. Conservation through fail → recover cycles, across seeds.
// ---------------------------------------------------------------------------

#[test]
fn fail_recover_cycles_conserve_requests_across_seeds() {
    let plat = configs::c1();
    let net = networks::synthnet_small();
    let config = shisha_config(&net, &plat);
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let cap = simulator::throughput(&net, &plat, &db, &config);
    let d = |x: f64| x / cap;
    // Two stall cycles on alternating EPs, a throttle, and a link cut:
    // the platform goes down and comes back twice within the horizon.
    let script = FaultScript {
        events: vec![
            FaultEvent { t_s: d(50.0), kind: FaultKind::EpStall { ep: 0, down_s: d(40.0) } },
            FaultEvent { t_s: d(150.0), kind: FaultKind::EpStall { ep: 1, down_s: d(40.0) } },
            FaultEvent {
                t_s: d(250.0),
                kind: FaultKind::EpSlow { ep: 0, factor: 3.0, down_s: d(50.0) },
            },
            FaultEvent { t_s: d(320.0), kind: FaultKind::LinkCut { down_s: d(30.0) } },
        ],
    };
    script.validate(&plat).expect("cycle script is valid");
    for seed in [3u64, 5, 9] {
        let tenant = TenantSpec::new(
            "cycles",
            net.clone(),
            ArrivalProcess::Poisson { rate: 0.8 * cap },
        )
        .with_queue_capacity(24)
        .with_admission(AdmissionPolicy::DropOldest)
        .with_slo(100.0 / cap);
        let opts = ServeOptions {
            duration_s: d(400.0),
            seed,
            control_epoch_s: d(20.0),
            faults: script.clone(),
            ..Default::default()
        };
        let report = serve(&plat, vec![(tenant, config.clone())], &opts)
            .unwrap_or_else(|e| panic!("seed {seed}: {e:#}"));
        let t = &report.tenants[0];
        assert_conserved(t, &format!("seed {seed}"));
        assert!(t.completed > 0, "seed {seed}: the tenant must keep serving through cycles");
    }
}

// ---------------------------------------------------------------------------
// 3. The acceptance disaster: strongest-EP fail-stop mid-storm.
// ---------------------------------------------------------------------------

#[test]
fn strongest_ep_failstop_recovers_fast_and_keeps_scaled_goodput() {
    let plat = configs::c5();
    let net = networks::synthnet();
    let config = shisha_config(&net, &plat);
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let cap = simulator::throughput(&net, &plat, &db, &config);
    let duration_s = 400.0 / cap;
    let epoch_s = 10.0 / cap;
    let failed = plat.eps_by_rank()[0];
    let fault_t = duration_s / 3.0;
    let base = ServeOptions {
        duration_s,
        seed: 47,
        control_epoch_s: epoch_s,
        ..Default::default()
    };
    let tenants = || vec![(storm_tenant(cap, 2), config.clone())];

    let free = serve(&plat, tenants(), &base).expect("fault-free storm");
    assert_conserved(&free.tenants[0], "fault-free");
    let goodput_free = free.goodputs()[0];
    assert!(goodput_free > 0.0);

    let faulted_opts = ServeOptions {
        faults: FaultScript {
            events: vec![FaultEvent { t_s: fault_t, kind: FaultKind::EpFail { ep: failed } }],
        },
        ..base.clone()
    };
    let (rep, trace) = serve_traced(&plat, tenants(), &faulted_opts).expect("faulted storm");
    assert_conserved(&rep.tenants[0], "faulted");
    let goodput_faulted = rep.goodputs()[0];

    // Determinism: a second invocation reproduces the stream bit for bit.
    let (rep2, _) = serve_traced(&plat, tenants(), &faulted_opts).expect("second faulted storm");
    assert_eq!(rep.log_hash, rep2.log_hash, "faulted runs must be deterministic");
    assert_eq!(rep.n_events, rep2.n_events);

    // Goodput envelope: within 15% of the fault-free run scaled by the
    // surviving capacity (the analytic throughput of the platform minus
    // the dead EP over the full platform's — conservative, because the
    // first third of the horizon ran at full capacity).
    let surviving: Vec<usize> = (0..plat.n_eps()).filter(|&e| e != failed).collect();
    let sub = plat.subset(&surviving);
    let sub_db = PerfDb::build(&net, &sub, &CostModel::default());
    let cap_surv = simulator::throughput(&net, &sub, &sub_db, &shisha_config(&net, &sub));
    let frac = cap_surv / cap;
    assert!(frac > 0.0 && frac < 1.0, "losing the strongest EP must cost capacity ({frac})");
    assert!(
        goodput_faulted >= 0.85 * frac * goodput_free,
        "goodput {goodput_faulted:.2} req/s fell below 85% of the surviving-capacity-scaled \
         fault-free goodput ({:.2} of {goodput_free:.2} req/s, capacity frac {frac:.3})",
        0.85 * frac * goodput_free
    );

    // Recovery: detection is the tag-7 event, and every failover re-plan
    // record lands within two control epochs of it.
    let t_inject = trace
        .events
        .iter()
        .find(|e| e.tag == 7 && e.b == 1)
        .expect("the injection is a hashed trace event")
        .t_s;
    assert!((t_inject - fault_t).abs() < 1e-9, "injection at the scripted time");
    assert!(
        trace.controls.iter().any(|c| c.kind == ControlKind::Fault),
        "detection must be recorded as a fault control record"
    );
    let failovers: Vec<f64> = trace
        .controls
        .iter()
        .filter(|c| c.kind == ControlKind::Failover)
        .map(|c| c.t_s)
        .collect();
    assert!(!failovers.is_empty(), "the fail-stop must trigger a failover re-plan");
    for t in &failovers {
        assert!(
            *t >= t_inject && *t <= t_inject + 2.0 * epoch_s,
            "failover at t={t:.4}s is outside two control epochs of the injection \
             (t={t_inject:.4}s, epoch {epoch_s:.4}s)"
        );
    }

    // No active replica still references the dead EP at the horizon.
    for s in &rep.tenants[0].shards {
        if s.final_state == ReplicaState::Active {
            assert!(!s.eps.contains(&failed), "active replica on dead EP {failed}: {:?}", s.eps);
        }
    }
}

// ---------------------------------------------------------------------------
// 5. Property: the CLI grammar round-trips its own output bit-identically,
//    and a corrupted-script corpus always fails with an actionable error.
// ---------------------------------------------------------------------------

#[test]
fn grammar_round_trips_chaos_scripts_bit_identically() {
    // describe() → parse() → describe() must be a fixpoint: the f64
    // Display form round-trips exactly, so the re-rendered string — not
    // just the re-parsed script — must match byte for byte.
    for plat in [configs::c1(), configs::c2(), configs::c5()] {
        for seed in 1..=20u64 {
            let n = 1 + (seed as usize % 7);
            let script = FaultScript::chaos(seed, &plat, 60.0 + seed as f64, n);
            script.validate(&plat).expect("chaos scripts are valid by construction");
            let rendered = script.describe();
            let reparsed = FaultScript::parse(&rendered)
                .unwrap_or_else(|e| panic!("{}/{seed}: reparse {rendered:?}: {e:#}", plat.name));
            assert_eq!(
                reparsed, script,
                "{}/{seed}: describe→parse must reproduce the script exactly",
                plat.name
            );
            assert_eq!(
                reparsed.describe(),
                rendered,
                "{}/{seed}: re-rendering must be bit-identical",
                plat.name
            );
        }
    }
}

#[test]
fn grammar_rejects_corrupted_scripts_with_actionable_errors() {
    // Strided single-character corruption: replace every 1st, 2nd, 3rd...
    // character of a valid script string with '~' (a byte no token of the
    // grammar accepts). Every corrupted string must fail to parse, and
    // the error chain must quote the offending event spec so the user can
    // find it inside a long script.
    let base = "epfail:1@5; epstall:0@2+1.5; epslow:2x2.5@3+4; chipfail:1@8; \
                linkslow:3@1+2; linkcut@10+0.5";
    assert!(FaultScript::parse(base).is_ok(), "the base corpus string must be valid");
    let mut corrupted = 0usize;
    for stride in 1..=3usize {
        for start in 0..stride {
            for i in (start..base.len()).step_by(stride) {
                let mut s: Vec<u8> = base.as_bytes().to_vec();
                if s[i] == b'~' {
                    continue;
                }
                s[i] = b'~';
                let s = String::from_utf8(s).expect("ASCII corpus");
                let err = FaultScript::parse(&s).map(|sc| sc.describe()).expect_err(&format!(
                    "corrupting byte {i} ({:?}) must break the parse: {s:?}",
                    &base[i..=i]
                ));
                let msg = format!("{err:#}");
                assert!(
                    msg.contains("fault spec") || msg.contains('~'),
                    "byte {i}: error must point at the offending spec, got {msg:?}"
                );
                corrupted += 1;
            }
        }
    }
    assert!(corrupted >= base.len(), "the corpus must cover every byte at stride 1");

    // Structural corruption: valid events recombined into invalid scripts
    // still fail with messages naming the broken invariant.
    let plat = configs::c2();
    for (bad, needle) in [
        ("epstall:0@1+3; epslow:0x2@2+5", "overlapping windows on EP 0"),
        ("linkcut@1+3; linkslow:2@2+1", "link windows"),
        ("epfail:0@1; epfail:1@1; epfail:2@1; epfail:3@1", "fail-stops all"),
        ("epfail:99@1", "out of range"),
    ] {
        let err = FaultScript::parse(bad)
            .expect("these parse; validation rejects them")
            .validate(&plat)
            .expect_err(bad);
        assert!(format!("{err:#}").contains(needle), "{bad:?}: {err:#}");
    }
}

// ---------------------------------------------------------------------------
// 4. Faulted runs record, round-trip, and replay bit-identically.
// ---------------------------------------------------------------------------

#[test]
fn faulted_trace_replays_bit_identically_and_whatif_strips_faults() {
    let plat = configs::c5();
    let net = networks::synthnet();
    let config = shisha_config(&net, &plat);
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let cap = simulator::throughput(&net, &plat, &db, &config);
    let duration_s = 300.0 / cap;
    let failed = plat.eps_by_rank()[0];
    let opts = ServeOptions {
        duration_s,
        seed: 47,
        control_epoch_s: 10.0 / cap,
        faults: FaultScript {
            events: vec![
                FaultEvent { t_s: duration_s / 3.0, kind: FaultKind::EpFail { ep: failed } },
                FaultEvent {
                    t_s: duration_s / 2.0,
                    kind: FaultKind::LinkSlow { factor: 2.0, down_s: duration_s / 10.0 },
                },
            ],
        },
        ..Default::default()
    };
    let (live, trace) = serve_traced(
        &plat,
        vec![(storm_tenant(cap, 2), config.clone())],
        &opts,
    )
    .expect("faulted record run");
    assert_conserved(&live.tenants[0], "recorded");
    assert!(
        trace.events.iter().any(|e| e.tag == 7),
        "fault events must be part of the hashed, captured stream"
    );

    // Binary + disk round trip, then bit-identical re-simulation.
    let bytes = trace.to_bytes();
    let back = Trace::from_bytes(&bytes).expect("decode faulted trace");
    assert_eq!(back.to_bytes(), bytes, "canonical re-encoding");
    assert_eq!(
        back.opts.faults.describe(),
        opts.faults.describe(),
        "the script rides inside the serialized serve options"
    );
    let file_name = format!("shisha_fault_plane_{}.trace", std::process::id());
    let path = std::env::temp_dir().join(file_name);
    trace.save(&path).expect("save faulted trace");
    let loaded = Trace::load(&path).expect("load faulted trace");
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.to_bytes(), bytes, "disk round trip is byte-identical");
    let replayed = replay_full(&loaded).expect("full replay under faults");
    assert_eq!(replayed.log_hash, live.log_hash, "faulted replay must be bit-identical");
    assert_eq!(replayed.n_events, live.n_events);

    // What-if faults=none: same captured storm, healthy platform.
    let captured = trace.arrival_times(0).len() as u64;
    assert_eq!(captured, live.tenants[0].offered);
    let stripped = replay_whatif(
        &trace,
        &WhatIf { faults: Some(FaultScript::default()), ..Default::default() },
    )
    .expect("faults=none what-if");
    assert_eq!(
        stripped.tenants[0].offered, captured,
        "the counterfactual must replay exactly the captured workload"
    );
    assert_conserved(&stripped.tenants[0], "faults=none what-if");
    // And a *different* script over the same arrivals also conserves.
    let stall_spec = format!("epstall:0@{}+{}", duration_s / 4.0, duration_s / 8.0);
    let harsher = replay_whatif(
        &trace,
        &WhatIf {
            faults: Some(FaultScript::parse(&stall_spec).expect("parse")),
            ..Default::default()
        },
    )
    .expect("harsher what-if");
    assert_eq!(harsher.tenants[0].offered, captured);
    assert_conserved(&harsher.tenants[0], "harsher what-if");
}
