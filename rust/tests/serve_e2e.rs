//! Serving-engine end-to-end tests: two tenants contending on one
//! platform, and the acceptance scenario for the online control loop —
//! arrival-rate drift regresses a tenant's SLO goodput, the engine warm
//! re-tunes it through the `AdaptiveController`, and goodput recovers to
//! ≥ 90% of its pre-drift level.
//!
//! All absolute rates and times are derived from the analytic capacity of
//! the configurations under test, so the scenarios are platform-constant
//! and fully deterministic for the fixed seeds.

use shisha::model::networks;
use shisha::perfdb::{CostModel, PerfDb};
use shisha::pipeline::{simulator, PipelineConfig};
use shisha::platform::configs;
use shisha::serve::{
    serve, shisha_config, ArrivalProcess, ServeOptions, TenantSpec,
};

#[test]
fn two_tenants_end_to_end() {
    let plat = configs::c3();
    let model = CostModel::default();

    let net_a = networks::synthnet();
    let cfg_a = shisha_config(&net_a, &plat);
    let db_a = PerfDb::build(&net_a, &plat, &model);
    let cap_a = simulator::throughput(&net_a, &plat, &db_a, &cfg_a);

    let net_b = networks::alexnet();
    let cfg_b = shisha_config(&net_b, &plat);
    let db_b = PerfDb::build(&net_b, &plat, &model);
    let cap_b = simulator::throughput(&net_b, &plat, &db_b, &cfg_b);

    let lat_a = simulator::evaluate(&net_a, &plat, &db_a, &cfg_a).latency_s;
    let lat_b = simulator::evaluate(&net_b, &plat, &db_b, &cfg_b).latency_s;
    let slo = 40.0 * lat_a.max(lat_b);

    let duration = 400.0 / cap_a.min(cap_b);
    let tenants = vec![
        (
            TenantSpec::new("a", net_a, ArrivalProcess::Poisson { rate: 0.35 * cap_a })
                .with_slo(slo),
            cfg_a.clone(),
        ),
        (
            TenantSpec::new("b", net_b, ArrivalProcess::Poisson { rate: 0.35 * cap_b })
                .with_slo(slo),
            cfg_b.clone(),
        ),
    ];
    let opts = ServeOptions {
        duration_s: duration,
        seed: 3,
        control_epoch_s: duration / 10.0,
        ..Default::default()
    };
    let report = serve(&plat, tenants, &opts).unwrap();
    assert!(!report.truncated);
    assert_eq!(report.tenants.len(), 2);
    for t in &report.tenants {
        assert!(t.offered > 50, "{}: expected real traffic, got {}", t.name, t.offered);
        assert!(t.completed > 0, "{}: nothing completed", t.name);
        assert!(t.conserved(), "{}: conservation violated: {t:?}", t.name);
        assert!(t.latency.p50() > 0.0);
        assert!(t.latency.p99() >= t.latency.p50());
        assert!(!t.epochs.is_empty());
    }
    let fairness = report.fairness();
    assert!(
        fairness > 0.5 && fairness <= 1.0 + 1e-12,
        "two same-load tenants should split goodput fairly, Jain = {fairness}"
    );
}

/// The acceptance scenario: a tenant served comfortably by a mediocre
/// configuration is hit by an arrival-rate surge beyond that
/// configuration's capacity. Queues build, latencies blow through the
/// SLO, goodput collapses — and the control loop's warm re-tune finds a
/// better layer split / EP assignment whose capacity clears the new rate,
/// so the backlog drains and goodput recovers.
#[test]
fn arrival_drift_triggers_retune_and_recovers_goodput() {
    let plat = configs::c2(); // 2× big8 + 2× little8
    let model = CostModel::default();
    let net = networks::synthnet(); // 18 layers

    // Deliberately mediocre initial config: the two heaviest chunks sit on
    // the little/slow EPs. Plenty of warm-tuning headroom (move layers,
    // swap the bottleneck onto a big EP).
    let bad = PipelineConfig::new(vec![5, 5, 4, 4], vec![2, 3, 0, 1]);
    let db = PerfDb::build(&net, &plat, &model);
    let cap_bad = simulator::throughput(&net, &plat, &db, &bad);
    let t_unit = 1.0 / cap_bad; // one bottleneck period of the bad config
    let lat_bad = simulator::evaluate(&net, &plat, &db, &bad).latency_s;

    // rate: 0.5× capacity until the drift point, 1.3× capacity afterwards
    let drift_at = 126.0 * t_unit;
    let arrivals = ArrivalProcess::Piecewise {
        segments: vec![(0.0, 0.5 * cap_bad), (drift_at, 1.3 * cap_bad)],
    };
    // SLO generous in steady state, violated once ~20 requests queue up
    let slo = 8.0 * lat_bad;

    let spec = TenantSpec::new("drifter", net.clone(), arrivals)
        .with_slo(slo)
        .with_queue_capacity(32);

    // a second, nearly idle tenant keeps the multi-tenant paths exercised
    // without perturbing the capacity math (≈1% duty cycle on EP 3)
    let net_b = networks::synthnet_small();
    let cfg_b = PipelineConfig::single_stage(net_b.len(), 3);
    let db_b = PerfDb::build(&net_b, &plat, &model);
    let cap_b = simulator::throughput(&net_b, &plat, &db_b, &cfg_b);
    let spec_b = TenantSpec::new("background", net_b, ArrivalProcess::Poisson {
        rate: 0.01 * cap_b,
    })
    .with_slo(100.0 / cap_b);

    let epoch = 30.0 * t_unit;
    let opts = ServeOptions {
        duration_s: 600.0 * t_unit,
        seed: 17,
        control: true,
        control_epoch_s: epoch,
        retune_threshold: 0.6,
        retune_cooldown_epochs: 1,
        reconfig_penalty_s: 2.0 * t_unit,
        ..Default::default()
    };
    let report = serve(&plat, vec![(spec, bad.clone()), (spec_b, cfg_b)], &opts).unwrap();
    assert!(!report.truncated);
    let t = &report.tenants[0];
    assert!(t.conserved(), "conservation: {t:?}");

    // pre-drift epochs (ending before the drift point) must be healthy and
    // untouched by the control loop
    let pre: Vec<_> = t.epochs.iter().filter(|e| e.end_s <= drift_at + 1e-9).collect();
    assert!(pre.len() >= 3, "want ≥3 pre-drift epochs, got {}", pre.len());
    assert!(pre.iter().all(|e| !e.retuned), "no re-tune before the drift");
    let pre_goodput = pre.iter().map(|e| e.goodput).fold(0.0f64, f64::max);
    assert!(
        pre_goodput > 0.35 * cap_bad,
        "pre-drift goodput {pre_goodput} vs rate {}",
        0.5 * cap_bad
    );

    // the drift must demonstrably trigger the AdaptiveController
    assert!(t.retunes >= 1, "arrival drift must trigger a warm re-tune: {:#?}", t.epochs);
    assert!(t.retune_trials > 0);
    assert_ne!(
        t.final_config, t.initial_config,
        "re-tune must change the configuration"
    );
    let new_cap = simulator::throughput(&net, &plat, &db, &t.final_config);
    assert!(
        new_cap > 1.3 * cap_bad,
        "re-tuned capacity {new_cap} must clear the drifted rate {}",
        1.3 * cap_bad
    );

    // ... and goodput must recover to ≥ 90% of its pre-drift level
    let last = t.epochs.last().expect("epochs recorded");
    assert!(
        last.goodput >= 0.9 * pre_goodput,
        "final-epoch goodput {} must recover ≥90% of pre-drift {pre_goodput}\n{:#?}",
        last.goodput,
        t.epochs
    );
    // the backlog must actually have drained, not merely shifted
    assert!(
        last.backlog < 32,
        "backlog should drain after recovery, still {}",
        last.backlog
    );
}

/// Determinism across the full e2e path (engine + control loop): a fixed
/// seed reproduces the event stream bit-for-bit.
#[test]
fn e2e_runs_are_deterministic() {
    let run = || {
        let plat = configs::c2();
        let net = networks::synthnet();
        let bad = PipelineConfig::new(vec![5, 5, 4, 4], vec![2, 3, 0, 1]);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &bad);
        let lat = simulator::evaluate(&net, &plat, &db, &bad).latency_s;
        let spec = TenantSpec::new(
            "d",
            net,
            ArrivalProcess::Piecewise {
                segments: vec![(0.0, 0.5 * cap), (126.0 / cap, 1.3 * cap)],
            },
        )
        .with_slo(8.0 * lat)
        .with_queue_capacity(32);
        let opts = ServeOptions {
            duration_s: 400.0 / cap,
            seed: 17,
            control_epoch_s: 30.0 / cap,
            retune_cooldown_epochs: 1,
            reconfig_penalty_s: 2.0 / cap,
            record_log: true,
            ..Default::default()
        };
        serve(&plat, vec![(spec, bad)], &opts).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.log_hash, b.log_hash);
    assert_eq!(a.event_log, b.event_log);
    assert_eq!(a.tenants[0].completed, b.tenants[0].completed);
    assert_eq!(a.tenants[0].retunes, b.tenants[0].retunes);
    assert_eq!(a.tenants[0].latency.p99(), b.tenants[0].latency.p99());
}
