//! Golden determinism tests for the serving engine.
//!
//! Three layers of protection, strongest first:
//!
//! 1. **Cross-mode equality** — every scenario runs under both
//!    [`PumpMode`]s: the event-driven settle must reproduce the PR-1
//!    whole-pipeline fixpoint rescan (`FullRescan`) **byte-for-byte**
//!    (`log_hash`, event log, epoch series, every report counter). Any
//!    engine change that alters the settle propagation breaks this
//!    loudly.
//! 2. **Rerun equality** — each scenario runs twice under the default
//!    mode; a nondeterministic engine (hash iteration, RNG misuse,
//!    uninitialised state) fails immediately.
//! 3. **Absolute pinning** — each scenario's `log_hash`/event count is
//!    asserted against the committed fingerprint file
//!    `tests/golden/serve_fingerprints.txt`. Unlike 1–2 this catches
//!    drift that hits *both* modes (e.g. a bug in the shared arena
//!    plumbing, a cost-model change leaking into the engine). Scenarios
//!    missing from the file are **minted into it** on first run — run
//!    `cargo test --test serve_golden` once and commit the updated file;
//!    from then on any absolute outcome change fails with no environment
//!    variables involved. To intentionally re-bless after a semantic
//!    engine change, delete the affected lines (or the file) and rerun.
//!    (This replaces the PR-2 `SHISHA_GOLDEN_*` env-var stopgap.)
//!
//! Scenario families: steady Poisson multi-tenant (batching + DropOldest
//! backpressure), MMPP plus piecewise arrival drift that triggers a warm
//! re-tune (the scratch observed-database path), trace-driven replay, and
//! two **sharded** scenarios (round-robin and throughput-weighted
//! balancers, the second with the control loop live) covering replica
//! routing, disjoint placement and per-replica re-tuning.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::PathBuf;
use std::sync::Mutex;

use shisha::model::networks;
use shisha::perfdb::{CostModel, PerfDb};
use shisha::pipeline::{simulator, PipelineConfig};
use shisha::platform::configs;
use shisha::serve::{
    serve, ArrivalProcess, BalancerPolicy, PumpMode, ServeOptions, ServeReport, TenantSpec,
};

/// Every observable of the two reports must match exactly.
fn assert_identical(a: &ServeReport, b: &ServeReport, what: &str) {
    assert_eq!(a.log_hash, b.log_hash, "{what}: log_hash");
    assert_eq!(a.event_log, b.event_log, "{what}: event log");
    assert_eq!(a.n_events, b.n_events, "{what}: event count");
    assert_eq!(a.truncated, b.truncated, "{what}: truncation");
    assert_eq!(a.tenants.len(), b.tenants.len(), "{what}: tenant count");
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        let name = &x.name;
        assert_eq!(x.name, y.name, "{what}/{name}");
        assert_eq!(x.offered, y.offered, "{what}/{name}: offered");
        assert_eq!(x.rejected, y.rejected, "{what}/{name}: rejected");
        assert_eq!(x.dropped, y.dropped, "{what}/{name}: dropped");
        assert_eq!(x.expired, y.expired, "{what}/{name}: expired");
        assert_eq!(x.cancelled, y.cancelled, "{what}/{name}: cancelled");
        assert_eq!(x.retried, y.retried, "{what}/{name}: retried");
        assert_eq!(x.hedged, y.hedged, "{what}/{name}: hedged");
        assert_eq!(x.completed, y.completed, "{what}/{name}: completed");
        assert_eq!(x.slo_ok, y.slo_ok, "{what}/{name}: slo_ok");
        assert_eq!(x.in_flight, y.in_flight, "{what}/{name}: in_flight");
        assert_eq!(x.max_queue_len, y.max_queue_len, "{what}/{name}: max_queue_len");
        assert_eq!(x.arena_peak, y.arena_peak, "{what}/{name}: arena_peak");
        assert_eq!(x.retunes, y.retunes, "{what}/{name}: retunes");
        assert_eq!(x.retune_trials, y.retune_trials, "{what}/{name}: retune_trials");
        assert_eq!(x.final_config, y.final_config, "{what}/{name}: final config");
        assert_eq!(x.epochs, y.epochs, "{what}/{name}: epoch series");
        assert_eq!(x.latency.p50().to_bits(), y.latency.p50().to_bits(), "{what}/{name}: p50");
        assert_eq!(x.latency.p95().to_bits(), y.latency.p95().to_bits(), "{what}/{name}: p95");
        assert_eq!(x.latency.p99().to_bits(), y.latency.p99().to_bits(), "{what}/{name}: p99");
        assert_eq!(
            x.latency.max_s().to_bits(),
            y.latency.max_s().to_bits(),
            "{what}/{name}: max latency"
        );
        assert!(x.conserved(), "{what}/{name}: conservation");
        assert!(
            x.epoch_conserved(),
            "{what}/{name}: per-epoch flow conservation (incl. expired + cancelled)"
        );
        // per-replica observables (length 1 for unsharded tenants)
        assert_eq!(x.shards.len(), y.shards.len(), "{what}/{name}: replica count");
        for (sx, sy) in x.shards.iter().zip(&y.shards) {
            assert_eq!(sx.eps, sy.eps, "{what}/{name}: replica EPs");
            assert_eq!(sx.offered, sy.offered, "{what}/{name}: replica offered");
            assert_eq!(sx.completed, sy.completed, "{what}/{name}: replica completed");
            assert_eq!(sx.final_config, sy.final_config, "{what}/{name}: replica config");
            assert_eq!(sx.retunes, sy.retunes, "{what}/{name}: replica retunes");
            assert_eq!(sx.epochs, sy.epochs, "{what}/{name}: replica epochs");
            assert_eq!(sx.scale_events, sy.scale_events, "{what}/{name}: scale events");
            assert_eq!(sx.final_state, sy.final_state, "{what}/{name}: replica state");
        }
    }
}

/// Serialises fingerprint-file access across concurrently running tests.
static PINS: Mutex<()> = Mutex::new(());

fn pin_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/serve_fingerprints.txt")
}

/// Assert `what`'s fingerprint against the committed file, or mint the
/// entry when absent (commit the updated file to lock it in).
fn check_pin(what: &str, log_hash: u64, n_events: u64) {
    assert!(
        !what.contains(char::is_whitespace),
        "scenario keys are whitespace-free: {what:?}"
    );
    let _guard = PINS.lock().expect("fingerprint lock poisoned");
    let path = pin_path();
    let text = std::fs::read_to_string(&path).unwrap_or_default();
    let mut pins: BTreeMap<&str, (&str, &str)> = BTreeMap::new();
    for line in text.lines() {
        let s = line.trim();
        if s.is_empty() || s.starts_with('#') {
            continue;
        }
        let mut it = s.split_whitespace();
        match (it.next(), it.next(), it.next()) {
            (Some(k), Some(h), Some(n)) => {
                pins.insert(k, (h, n));
            }
            _ => panic!("malformed fingerprint line: {line:?}"),
        }
    }
    let hash_hex = format!("{log_hash:016x}");
    match pins.get(what) {
        Some(&(h, n)) => {
            assert_eq!(
                hash_hex, h,
                "{what}: log_hash drifted from the committed golden fingerprint \
                 ({path:?}); if the change is intentional, delete the line and rerun \
                 to re-mint",
            );
            assert_eq!(
                n_events.to_string(),
                n,
                "{what}: event count drifted from the committed golden fingerprint"
            );
        }
        None => {
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)
                .expect("open fingerprint file for minting");
            writeln!(f, "{what} {hash_hex} {n_events}").expect("mint fingerprint");
            println!(
                "{what}: minted fingerprint {hash_hex} ({n_events} events) into {path:?} — \
                 commit the file to pin it"
            );
        }
    }
}

/// Run the scenario builder under both pump modes (and the event-driven
/// mode twice), require byte-identical outcomes, and pin the absolute
/// fingerprint against the committed golden file.
fn check_golden(
    what: &str,
    build: impl Fn() -> (shisha::platform::Platform, Vec<(TenantSpec, PipelineConfig)>, ServeOptions),
) -> ServeReport {
    let run = |pump: PumpMode| {
        let (plat, tenants, mut opts) = build();
        opts.pump = pump;
        opts.record_log = true;
        serve(&plat, tenants, &opts).expect("serve run")
    };
    let ev = run(PumpMode::EventDriven);
    let ev2 = run(PumpMode::EventDriven);
    assert_identical(&ev, &ev2, &format!("{what} (rerun)"));
    let fr = run(PumpMode::FullRescan);
    assert_identical(&ev, &fr, &format!("{what} (vs full-rescan)"));
    // for the record (visible with --nocapture): the pinned fingerprint
    println!("{what}: log_hash {:016x}, {} events", ev.log_hash, ev.n_events);
    check_pin(what, ev.log_hash, ev.n_events);
    ev
}

#[test]
fn golden_poisson_multi_tenant() {
    let report = check_golden("poisson", || {
        let plat = configs::c1();
        let net = networks::synthnet_small();
        let cfg = PipelineConfig::new(vec![3, 3], vec![0, 1]);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        let heavy = TenantSpec::new(
            "heavy",
            net.clone(),
            ArrivalProcess::Poisson { rate: 2.5 * cap },
        )
        .with_batch(4)
        .with_queue_capacity(12)
        .with_admission(shisha::serve::AdmissionPolicy::DropOldest)
        .with_slo(20.0 / cap);
        let light = TenantSpec::new(
            "light",
            net.clone(),
            ArrivalProcess::Poisson { rate: 0.4 * cap },
        )
        .with_slo(20.0 / cap);
        let opts = ServeOptions {
            duration_s: 300.0 / cap,
            seed: 11,
            control: false,
            control_epoch_s: 40.0 / cap,
            ..Default::default()
        };
        (plat, vec![(heavy, cfg.clone()), (light, cfg)], opts)
    });
    let heavy = &report.tenants[0];
    assert!(heavy.dropped > 0, "backpressure path must be exercised");
    assert!(heavy.completed > 0);
}

#[test]
fn golden_mmpp_with_drift_triggered_retune() {
    let report = check_golden("mmpp+drift", || {
        let plat = configs::c2();
        let net = networks::synthnet();
        // deliberately mediocre start so the warm re-tune has headroom
        let bad = PipelineConfig::new(vec![5, 5, 4, 4], vec![2, 3, 0, 1]);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &bad);
        let lat = simulator::evaluate(&net, &plat, &db, &bad).latency_s;
        let drifter = TenantSpec::new(
            "drifter",
            net.clone(),
            ArrivalProcess::Piecewise {
                segments: vec![(0.0, 0.5 * cap), (126.0 / cap, 1.3 * cap)],
            },
        )
        .with_slo(8.0 * lat)
        .with_queue_capacity(32);
        let small = networks::synthnet_small();
        let cfg_b = PipelineConfig::single_stage(small.len(), 3);
        let db_b = PerfDb::build(&small, &plat, &CostModel::default());
        let cap_b = simulator::throughput(&small, &plat, &db_b, &cfg_b);
        let bursty = TenantSpec::new(
            "bursty",
            small,
            ArrivalProcess::Mmpp {
                low_rate: 0.05 * cap_b,
                high_rate: 0.3 * cap_b,
                mean_low_s: 40.0 / cap,
                mean_high_s: 15.0 / cap,
            },
        )
        .with_slo(60.0 / cap_b);
        let opts = ServeOptions {
            duration_s: 420.0 / cap,
            seed: 17,
            control: true,
            control_epoch_s: 30.0 / cap,
            retune_threshold: 0.6,
            retune_cooldown_epochs: 1,
            reconfig_penalty_s: 2.0 / cap,
            ..Default::default()
        };
        (plat, vec![(drifter, bad), (bursty, cfg_b)], opts)
    });
    let drifter = &report.tenants[0];
    assert!(
        drifter.retunes >= 1,
        "the drift must trigger the warm re-tune (scratch-db path): {:#?}",
        drifter.epochs
    );
    assert_ne!(drifter.final_config, drifter.initial_config);
}

#[test]
fn golden_trace_driven_replay() {
    let report = check_golden("trace", || {
        let plat = configs::c1();
        let net = networks::synthnet_small();
        let cfg = PipelineConfig::new(vec![3, 3], vec![0, 1]);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        // recorded workload: 8 bursts of 10 back-to-back requests
        let mut times = Vec::new();
        for burst in 0..8u32 {
            for k in 0..10u32 {
                times.push((f64::from(burst) * 30.0 + f64::from(k) * 0.25) / cap);
            }
        }
        let tenant = TenantSpec::new("replay", net, ArrivalProcess::Trace { times })
            .with_batch(2)
            .with_queue_capacity(6)
            .with_admission(shisha::serve::AdmissionPolicy::DropOldest)
            .with_slo(15.0 / cap);
        let opts = ServeOptions {
            duration_s: 300.0 / cap,
            seed: 23,
            control: false,
            control_epoch_s: 0.0,
            ..Default::default()
        };
        (plat, vec![(tenant, cfg)], opts)
    });
    let t = &report.tenants[0];
    assert_eq!(t.offered, 80, "trace replays every recorded arrival");
    assert!(t.completed > 0);
}

/// Shared builder for the sharded scenarios: SynthNet on C5 (the fixture
/// where replication provably adds capacity) under a saturating burst.
fn sharded_scenario(
    shards: usize,
    balancer: BalancerPolicy,
    control: bool,
    seed: u64,
) -> (shisha::platform::Platform, Vec<(TenantSpec, PipelineConfig)>, ServeOptions) {
    let plat = configs::c5();
    let net = networks::synthnet();
    let cfg = shisha::serve::shisha_config(&net, &plat);
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let cap = simulator::throughput(&net, &plat, &db, &cfg);
    let tenant = TenantSpec::new(
        "sharded",
        net,
        ArrivalProcess::Mmpp {
            low_rate: 0.5 * cap,
            high_rate: 2.5 * cap,
            mean_low_s: 50.0 / cap,
            mean_high_s: 50.0 / cap,
        },
    )
    .with_shards(shards)
    .with_balancer(balancer)
    .with_queue_capacity(16)
    .with_admission(shisha::serve::AdmissionPolicy::DropOldest)
    .with_slo(200.0 / cap);
    let opts = ServeOptions {
        duration_s: 300.0 / cap,
        seed,
        control,
        control_epoch_s: if control { 30.0 / cap } else { 0.0 },
        retune_cooldown_epochs: 1,
        ..Default::default()
    };
    (plat, vec![(tenant, cfg)], opts)
}

#[test]
fn golden_sharded_round_robin() {
    let report = check_golden("shard2-rr", || {
        sharded_scenario(2, BalancerPolicy::RoundRobin, false, 41)
    });
    let t = &report.tenants[0];
    assert_eq!(t.shards.len(), 2, "C5/SynthNet must replicate at budget 2");
    assert!(t.shards.iter().all(|s| s.completed > 0), "both replicas served");
    assert!(t.dropped > 0, "the burst must exercise DropOldest per replica");
}

#[test]
fn golden_sharded_weighted_with_control() {
    let report = check_golden("shard4-wtp-control", || {
        sharded_scenario(4, BalancerPolicy::WeightedThroughput, true, 43)
    });
    let t = &report.tenants[0];
    assert!(t.shards.len() > 1, "budget 4 must replicate");
    assert!(t.completed > 0);
    // weighted routing: every replica receives traffic
    assert!(t.shards.iter().all(|s| s.offered > 0));
}

#[test]
fn golden_autoscale_tidal() {
    // the cluster autoscaler on a tidal MMPP load: replicas park through
    // the lulls and re-activate for the bursts; the scale transitions are
    // part of the hashed event stream, so this pin covers the whole
    // controller (decision rule, drain protocol, balancer refresh)
    let report = check_golden("autoscale-tidal", || {
        let plat = configs::c5();
        let net = networks::synthnet();
        let cfg = shisha::serve::shisha_config(&net, &plat);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        let tenant = TenantSpec::new(
            "tidal",
            net,
            ArrivalProcess::Mmpp {
                low_rate: 0.2 * cap,
                high_rate: 1.3 * cap,
                mean_low_s: 100.0 / cap,
                mean_high_s: 100.0 / cap,
            },
        )
        .with_shards(4)
        .with_balancer(BalancerPolicy::JoinShortestQueue)
        .with_queue_capacity(32)
        .with_admission(shisha::serve::AdmissionPolicy::DropOldest)
        .with_slo(500.0 / cap);
        let opts = ServeOptions {
            duration_s: 400.0 / cap,
            seed: 47,
            control: false,
            control_epoch_s: 4.0 / cap,
            autoscale: shisha::serve::AutoscaleOptions::enabled(),
            ..Default::default()
        };
        (plat, vec![(tenant, cfg)], opts)
    });
    let t = &report.tenants[0];
    assert!(t.conserved(), "conservation across scale transitions");
    let events: usize = t.shards.iter().map(|s| s.scale_events.len()).sum();
    assert!(events > 0, "the tide must move the autoscaler");
    assert!(
        t.ep_epochs() < t.epochs.len() as u64 * 8,
        "parked replicas must shrink the EP-epoch meter"
    );
}

#[test]
fn golden_coplan_three_tenants() {
    // cross-tenant co-planning: three weighted tenants serve on jointly
    // allocated disjoint EP budgets of C5
    let report = check_golden("coplan3", || {
        let plat = configs::c5();
        let mk = |name: &str, net: shisha::model::Network, weight: f64, shards: usize| {
            let cfg = shisha::serve::shisha_config(&net, &plat);
            let db = PerfDb::build(&net, &plat, &CostModel::default());
            let cap = simulator::throughput(&net, &plat, &db, &cfg);
            (
                TenantSpec::new(name, net, ArrivalProcess::Poisson { rate: 0.4 * cap })
                    .with_weight(weight)
                    .with_shards(shards)
                    .with_slo(200.0 / cap),
                cfg,
            )
        };
        let tenants = vec![
            mk("hot", networks::synthnet(), 2.0, 2),
            mk("warm", networks::alexnet(), 1.0, 2),
            mk("cold", networks::synthnet_small(), 1.0, 1),
        ];
        let opts = ServeOptions {
            duration_s: 1.5,
            seed: 53,
            control: false,
            control_epoch_s: 0.25,
            coplan: true,
            ..Default::default()
        };
        (plat, tenants, opts)
    });
    // budgets are disjoint across the whole cluster
    let mut seen = vec![false; 8];
    for t in &report.tenants {
        assert!(t.conserved(), "{}: conservation", t.name);
        assert!(t.completed > 0, "{}: budget starved the tenant", t.name);
        for s in &t.shards {
            for &e in &s.eps {
                assert!(!seen[e], "EP {e} allocated to two tenants");
                seen[e] = true;
            }
        }
    }
}
