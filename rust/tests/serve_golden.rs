//! Golden determinism tests for the serving engine's hot-path refactor.
//!
//! The arena/settle/scratch-db rework (PR 2) must not change a single
//! simulated outcome. Rather than committing literal hash constants —
//! which would have to be produced by the same binary they test — these
//! tests pin the optimised engine against the in-tree reference:
//! [`PumpMode::FullRescan`] forces the PR-1 whole-pipeline fixpoint
//! rescan on every event, so for each fixed-seed scenario the
//! event-driven settle must reproduce its `log_hash`, event log, epoch
//! series and every report counter **byte-for-byte**. Any future engine
//! change that alters simulated outcomes breaks the cross-mode equality
//! (or the rerun equality) loudly.
//!
//! Three scenario families, per the acceptance criteria: steady Poisson
//! multi-tenant (batching + DropOldest backpressure), MMPP plus
//! piecewise arrival drift that triggers a warm re-tune (exercising the
//! scratch observed-database path), and trace-driven replay.

use shisha::model::networks;
use shisha::perfdb::{CostModel, PerfDb};
use shisha::pipeline::{simulator, PipelineConfig};
use shisha::platform::configs;
use shisha::serve::{
    serve, ArrivalProcess, PumpMode, ServeOptions, ServeReport, TenantSpec,
};

/// Every observable of the two reports must match exactly.
fn assert_identical(a: &ServeReport, b: &ServeReport, what: &str) {
    assert_eq!(a.log_hash, b.log_hash, "{what}: log_hash");
    assert_eq!(a.event_log, b.event_log, "{what}: event log");
    assert_eq!(a.n_events, b.n_events, "{what}: event count");
    assert_eq!(a.truncated, b.truncated, "{what}: truncation");
    assert_eq!(a.tenants.len(), b.tenants.len(), "{what}: tenant count");
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        let name = &x.name;
        assert_eq!(x.name, y.name, "{what}/{name}");
        assert_eq!(x.offered, y.offered, "{what}/{name}: offered");
        assert_eq!(x.rejected, y.rejected, "{what}/{name}: rejected");
        assert_eq!(x.dropped, y.dropped, "{what}/{name}: dropped");
        assert_eq!(x.completed, y.completed, "{what}/{name}: completed");
        assert_eq!(x.slo_ok, y.slo_ok, "{what}/{name}: slo_ok");
        assert_eq!(x.in_flight, y.in_flight, "{what}/{name}: in_flight");
        assert_eq!(x.max_queue_len, y.max_queue_len, "{what}/{name}: max_queue_len");
        assert_eq!(x.arena_peak, y.arena_peak, "{what}/{name}: arena_peak");
        assert_eq!(x.retunes, y.retunes, "{what}/{name}: retunes");
        assert_eq!(x.retune_trials, y.retune_trials, "{what}/{name}: retune_trials");
        assert_eq!(x.final_config, y.final_config, "{what}/{name}: final config");
        assert_eq!(x.epochs, y.epochs, "{what}/{name}: epoch series");
        assert_eq!(x.latency.p50().to_bits(), y.latency.p50().to_bits(), "{what}/{name}: p50");
        assert_eq!(x.latency.p95().to_bits(), y.latency.p95().to_bits(), "{what}/{name}: p95");
        assert_eq!(x.latency.p99().to_bits(), y.latency.p99().to_bits(), "{what}/{name}: p99");
        assert_eq!(
            x.latency.max_s().to_bits(),
            y.latency.max_s().to_bits(),
            "{what}/{name}: max latency"
        );
        assert!(x.conserved(), "{what}/{name}: conservation");
    }
}

/// Run the scenario builder under both pump modes (and the event-driven
/// mode twice) and require byte-identical outcomes.
fn check_golden(
    what: &str,
    build: impl Fn() -> (shisha::platform::Platform, Vec<(TenantSpec, PipelineConfig)>, ServeOptions),
) -> ServeReport {
    let run = |pump: PumpMode| {
        let (plat, tenants, mut opts) = build();
        opts.pump = pump;
        opts.record_log = true;
        serve(&plat, tenants, &opts).expect("serve run")
    };
    let ev = run(PumpMode::EventDriven);
    let ev2 = run(PumpMode::EventDriven);
    assert_identical(&ev, &ev2, &format!("{what} (rerun)"));
    let fr = run(PumpMode::FullRescan);
    assert_identical(&ev, &fr, &format!("{what} (vs full-rescan)"));
    // for the record (visible with --nocapture): the pinned fingerprint
    println!("{what}: log_hash {:016x}, {} events", ev.log_hash, ev.n_events);
    // Absolute pinning hook: cross-mode equality cannot catch drift that
    // hits BOTH modes (e.g. a bug in the shared arena plumbing). Once a
    // toolchain run has printed the fingerprints above, export them —
    //   SHISHA_GOLDEN_POISSON=<hex> SHISHA_GOLDEN_MMPP_DRIFT=<hex>
    //   SHISHA_GOLDEN_TRACE=<hex> cargo test --test serve_golden
    // — and any absolute outcome change fails here.
    let key = format!(
        "SHISHA_GOLDEN_{}",
        what.to_uppercase().replace(|c: char| !c.is_ascii_alphanumeric(), "_")
    );
    if let Ok(want) = std::env::var(&key) {
        assert_eq!(
            format!("{:016x}", ev.log_hash),
            want.trim().to_lowercase(),
            "{what}: log_hash drifted from the pinned {key}"
        );
    }
    ev
}

#[test]
fn golden_poisson_multi_tenant() {
    let report = check_golden("poisson", || {
        let plat = configs::c1();
        let net = networks::synthnet_small();
        let cfg = PipelineConfig::new(vec![3, 3], vec![0, 1]);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        let heavy = TenantSpec::new(
            "heavy",
            net.clone(),
            ArrivalProcess::Poisson { rate: 2.5 * cap },
        )
        .with_batch(4)
        .with_queue_capacity(12)
        .with_admission(shisha::serve::AdmissionPolicy::DropOldest)
        .with_slo(20.0 / cap);
        let light = TenantSpec::new(
            "light",
            net.clone(),
            ArrivalProcess::Poisson { rate: 0.4 * cap },
        )
        .with_slo(20.0 / cap);
        let opts = ServeOptions {
            duration_s: 300.0 / cap,
            seed: 11,
            control: false,
            control_epoch_s: 40.0 / cap,
            ..Default::default()
        };
        (plat, vec![(heavy, cfg.clone()), (light, cfg)], opts)
    });
    let heavy = &report.tenants[0];
    assert!(heavy.dropped > 0, "backpressure path must be exercised");
    assert!(heavy.completed > 0);
}

#[test]
fn golden_mmpp_with_drift_triggered_retune() {
    let report = check_golden("mmpp+drift", || {
        let plat = configs::c2();
        let net = networks::synthnet();
        // deliberately mediocre start so the warm re-tune has headroom
        let bad = PipelineConfig::new(vec![5, 5, 4, 4], vec![2, 3, 0, 1]);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &bad);
        let lat = simulator::evaluate(&net, &plat, &db, &bad).latency_s;
        let drifter = TenantSpec::new(
            "drifter",
            net.clone(),
            ArrivalProcess::Piecewise {
                segments: vec![(0.0, 0.5 * cap), (126.0 / cap, 1.3 * cap)],
            },
        )
        .with_slo(8.0 * lat)
        .with_queue_capacity(32);
        let small = networks::synthnet_small();
        let cfg_b = PipelineConfig::single_stage(small.len(), 3);
        let db_b = PerfDb::build(&small, &plat, &CostModel::default());
        let cap_b = simulator::throughput(&small, &plat, &db_b, &cfg_b);
        let bursty = TenantSpec::new(
            "bursty",
            small,
            ArrivalProcess::Mmpp {
                low_rate: 0.05 * cap_b,
                high_rate: 0.3 * cap_b,
                mean_low_s: 40.0 / cap,
                mean_high_s: 15.0 / cap,
            },
        )
        .with_slo(60.0 / cap_b);
        let opts = ServeOptions {
            duration_s: 420.0 / cap,
            seed: 17,
            control: true,
            control_epoch_s: 30.0 / cap,
            retune_threshold: 0.6,
            retune_cooldown_epochs: 1,
            reconfig_penalty_s: 2.0 / cap,
            ..Default::default()
        };
        (plat, vec![(drifter, bad), (bursty, cfg_b)], opts)
    });
    let drifter = &report.tenants[0];
    assert!(
        drifter.retunes >= 1,
        "the drift must trigger the warm re-tune (scratch-db path): {:#?}",
        drifter.epochs
    );
    assert_ne!(drifter.final_config, drifter.initial_config);
}

#[test]
fn golden_trace_driven_replay() {
    let report = check_golden("trace", || {
        let plat = configs::c1();
        let net = networks::synthnet_small();
        let cfg = PipelineConfig::new(vec![3, 3], vec![0, 1]);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        // recorded workload: 8 bursts of 10 back-to-back requests
        let mut times = Vec::new();
        for burst in 0..8u32 {
            for k in 0..10u32 {
                times.push((f64::from(burst) * 30.0 + f64::from(k) * 0.25) / cap);
            }
        }
        let tenant = TenantSpec::new("replay", net, ArrivalProcess::Trace { times })
            .with_batch(2)
            .with_queue_capacity(6)
            .with_admission(shisha::serve::AdmissionPolicy::DropOldest)
            .with_slo(15.0 / cap);
        let opts = ServeOptions {
            duration_s: 300.0 / cap,
            seed: 23,
            control: false,
            control_epoch_s: 0.0,
            ..Default::default()
        };
        (plat, vec![(tenant, cfg)], opts)
    });
    let t = &report.tenants[0];
    assert_eq!(t.offered, 80, "trace replays every recorded arrival");
    assert!(t.completed > 0);
}
