//! Telemetry zero-perturbation invariance tests.
//!
//! The telemetry plane ([`shisha::serve::obs`]) is derived **beside** the
//! engine's event-hash funnel, never inside it — so turning it on must
//! not change a single observable bit of the simulation. Each golden
//! scenario family asserts, with telemetry on vs off:
//!
//! 1. **blind vs observed** — [`serve`] and [`serve_observed`] produce
//!    identical `log_hash`, event count and per-tenant counters;
//! 2. **recording invariance** — [`serve_traced`] and
//!    [`serve_traced_observed`] encode byte-identical `.trace` files;
//! 3. **retroactive derivation** — [`replay_observed`] of the recording
//!    (after a to/from-bytes round trip) yields an [`ObsReport`] whose
//!    JSONL export and Prometheus snapshot are byte-identical to the
//!    live observed run's — `trace analyze` can never drift from
//!    `serve --metrics`;
//! 4. **non-vacuity** — the epoch series is non-empty, and scenarios
//!    with an active control plane journal at least one decision.
//!
//! The six families mirror `tests/serve_golden.rs`: steady Poisson,
//! MMPP + piecewise drift (warm re-tune), sharded JSQ, autoscaled tidal,
//! chaos-faulted, and elastic co-planned anti-phase tides.

use shisha::model::networks;
use shisha::perfdb::{CostModel, PerfDb};
use shisha::pipeline::{simulator, PipelineConfig};
use shisha::platform::configs;
use shisha::serve::{
    replay_observed, serve, serve_observed, serve_traced, serve_traced_observed, ArrivalProcess,
    BalancerPolicy, FaultScript, ObsReport, ServeOptions, ServeReport, TenantSpec, Trace,
};

type Scenario = (shisha::platform::Platform, Vec<(TenantSpec, PipelineConfig)>, ServeOptions);

/// Every simulation observable of the two reports must match exactly —
/// the telemetry tap is not allowed to perturb any of them.
fn assert_same_simulation(a: &ServeReport, b: &ServeReport, what: &str) {
    assert_eq!(a.log_hash, b.log_hash, "{what}: log_hash");
    assert_eq!(a.n_events, b.n_events, "{what}: event count");
    assert_eq!(a.truncated, b.truncated, "{what}: truncation");
    assert_eq!(a.plan_cache, b.plan_cache, "{what}: plan-cache counters");
    assert_eq!(a.tenants.len(), b.tenants.len(), "{what}: tenant count");
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        let name = &x.name;
        assert_eq!(x.offered, y.offered, "{what}/{name}: offered");
        assert_eq!(x.completed, y.completed, "{what}/{name}: completed");
        assert_eq!(x.rejected, y.rejected, "{what}/{name}: rejected");
        assert_eq!(x.dropped, y.dropped, "{what}/{name}: dropped");
        assert_eq!(x.slo_ok, y.slo_ok, "{what}/{name}: slo_ok");
        assert_eq!(x.in_flight, y.in_flight, "{what}/{name}: in_flight");
        assert_eq!(x.retunes, y.retunes, "{what}/{name}: retunes");
        assert_eq!(x.epochs, y.epochs, "{what}/{name}: epoch series");
        assert_eq!(x.final_config, y.final_config, "{what}/{name}: final config");
        assert_eq!(x.latency.p99().to_bits(), y.latency.p99().to_bits(), "{what}/{name}: p99");
        assert_eq!(x.shards.len(), y.shards.len(), "{what}/{name}: replica count");
        for (sx, sy) in x.shards.iter().zip(&y.shards) {
            assert_eq!(sx.eps, sy.eps, "{what}/{name}: replica EPs");
            assert_eq!(sx.completed, sy.completed, "{what}/{name}: replica completed");
            assert_eq!(sx.scale_events, sy.scale_events, "{what}/{name}: scale events");
        }
    }
}

/// Run one scenario family through all three invariance layers and
/// return the live observed telemetry for family-specific assertions.
fn check_invariance(
    what: &str,
    expect_journal: bool,
    build: impl Fn() -> Scenario,
) -> (ServeReport, ObsReport) {
    // 1. blind vs observed: bit-identical simulation
    let blind = {
        let (plat, tenants, opts) = build();
        serve(&plat, tenants, &opts).expect("blind serve")
    };
    let (observed, obs_live) = {
        let (plat, tenants, opts) = build();
        serve_observed(&plat, tenants, &opts).expect("observed serve")
    };
    assert_same_simulation(&blind, &observed, what);

    // 2. recording invariance: byte-identical .trace files
    let (_, trace_blind) = {
        let (plat, tenants, opts) = build();
        serve_traced(&plat, tenants, &opts).expect("blind recording")
    };
    let (rep_obs, trace_obs, obs_rec) = {
        let (plat, tenants, opts) = build();
        serve_traced_observed(&plat, tenants, &opts).expect("observed recording")
    };
    let bytes = trace_blind.to_bytes();
    assert_eq!(
        bytes,
        trace_obs.to_bytes(),
        "{what}: telemetry must not change a recorded trace byte"
    );
    assert_same_simulation(&blind, &rep_obs, &format!("{what} (recorded)"));
    assert_eq!(
        obs_live.to_jsonl(),
        obs_rec.to_jsonl(),
        "{what}: recording must not change the telemetry either"
    );

    // 3. retroactive derivation: trace analyze == live --metrics
    let back = Trace::from_bytes(&bytes).expect("trace round trip");
    let (rep_replay, obs_replay) = replay_observed(&back).expect("replay_observed");
    assert_eq!(rep_replay.log_hash, blind.log_hash, "{what}: replay log_hash");
    let live_jsonl = obs_live.to_jsonl();
    let derived_jsonl = obs_replay.to_jsonl();
    assert_eq!(
        live_jsonl.lines().count(),
        derived_jsonl.lines().count(),
        "{what}: derived JSONL row count"
    );
    for (i, (l, d)) in live_jsonl.lines().zip(derived_jsonl.lines()).enumerate() {
        assert_eq!(l, d, "{what}: JSONL row {i} diverged between live and trace analyze");
    }
    assert_eq!(obs_live.prom, obs_replay.prom, "{what}: Prometheus snapshot");

    // 4. non-vacuity
    assert!(!obs_live.samples.is_empty(), "{what}: epoch series must be non-empty");
    for line in live_jsonl.lines() {
        assert!(line.starts_with("{\"schema\":\"shisha-obs-v1\""), "{what}: schema tag");
    }
    if expect_journal {
        assert!(
            !obs_live.journal.entries.is_empty(),
            "{what}: an active control plane must journal decisions"
        );
    }
    (observed, obs_live)
}

#[test]
fn obs_invariant_poisson_multi_tenant() {
    let (report, obs) = check_invariance("poisson", false, || {
        let plat = configs::c1();
        let net = networks::synthnet_small();
        let cfg = PipelineConfig::new(vec![3, 3], vec![0, 1]);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        let heavy = TenantSpec::new("heavy", net.clone(), ArrivalProcess::Poisson {
            rate: 2.5 * cap,
        })
        .with_batch(4)
        .with_queue_capacity(12)
        .with_admission(shisha::serve::AdmissionPolicy::DropOldest)
        .with_slo(20.0 / cap);
        let light =
            TenantSpec::new("light", net.clone(), ArrivalProcess::Poisson { rate: 0.4 * cap })
                .with_slo(20.0 / cap);
        let opts = ServeOptions {
            duration_s: 300.0 / cap,
            seed: 11,
            control: false,
            control_epoch_s: 40.0 / cap,
            ..Default::default()
        };
        (plat, vec![(heavy, cfg.clone()), (light, cfg)], opts)
    });
    assert!(report.tenants[0].dropped > 0, "backpressure path must be exercised");
    // the admission census reaches the samples: the heavy tenant drops
    let last = obs.samples.last().expect("samples");
    assert!(last.tenants[0].dropped > 0);
}

#[test]
fn obs_invariant_mmpp_drift_retune() {
    let (report, obs) = check_invariance("mmpp+drift", true, || {
        let plat = configs::c2();
        let net = networks::synthnet();
        let bad = PipelineConfig::new(vec![5, 5, 4, 4], vec![2, 3, 0, 1]);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &bad);
        let lat = simulator::evaluate(&net, &plat, &db, &bad).latency_s;
        let drifter = TenantSpec::new("drifter", net.clone(), ArrivalProcess::Piecewise {
            segments: vec![(0.0, 0.5 * cap), (126.0 / cap, 1.3 * cap)],
        })
        .with_slo(8.0 * lat)
        .with_queue_capacity(32);
        let opts = ServeOptions {
            duration_s: 420.0 / cap,
            seed: 17,
            control: true,
            control_epoch_s: 30.0 / cap,
            retune_threshold: 0.6,
            retune_cooldown_epochs: 1,
            reconfig_penalty_s: 2.0 / cap,
            ..Default::default()
        };
        (plat, vec![(drifter, bad)], opts)
    });
    assert!(report.tenants[0].retunes >= 1, "drift must trigger the warm re-tune");
    // the journal explains the re-tune with its triggering signals
    let retunes: Vec<_> = obs
        .journal
        .entries
        .iter()
        .filter(|e| e.kind == shisha::serve::ControlKind::Retune)
        .collect();
    assert!(!retunes.is_empty(), "re-tunes must be journaled");
    assert!(
        retunes.iter().all(|e| !e.signals.is_empty()),
        "journaled re-tunes carry triggering signals"
    );
}

#[test]
fn obs_invariant_sharded_jsq() {
    let (report, obs) = check_invariance("shard2-jsq", false, || {
        let plat = configs::c5();
        let net = networks::synthnet();
        let cfg = shisha::serve::shisha_config(&net, &plat);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        let tenant = TenantSpec::new("sharded", net, ArrivalProcess::Mmpp {
            low_rate: 0.5 * cap,
            high_rate: 2.5 * cap,
            mean_low_s: 50.0 / cap,
            mean_high_s: 50.0 / cap,
        })
        .with_shards(2)
        .with_balancer(BalancerPolicy::JoinShortestQueue)
        .with_queue_capacity(16)
        .with_admission(shisha::serve::AdmissionPolicy::DropOldest)
        .with_slo(200.0 / cap);
        let opts = ServeOptions {
            duration_s: 300.0 / cap,
            seed: 41,
            control: false,
            control_epoch_s: 30.0 / cap,
            ..Default::default()
        };
        (plat, vec![(tenant, cfg)], opts)
    });
    assert_eq!(report.tenants[0].shards.len(), 2, "C5/SynthNet replicates at budget 2");
    // per-replica telemetry: both replicas appear in every sample
    for s in &obs.samples {
        assert_eq!(s.tenants[0].replicas.len(), 2);
    }
    // utilization integrates to something sane: busy fractions in [0, 1]
    for s in &obs.samples {
        for ep in &s.eps {
            assert!((0.0..=1.0 + 1e-9).contains(&ep.busy_frac), "busy_frac {}", ep.busy_frac);
        }
    }
}

#[test]
fn obs_invariant_autoscale_tidal() {
    let (report, obs) = check_invariance("autoscale-tidal", true, || {
        let plat = configs::c5();
        let net = networks::synthnet();
        let cfg = shisha::serve::shisha_config(&net, &plat);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        let tenant = TenantSpec::new("tidal", net, ArrivalProcess::Mmpp {
            low_rate: 0.2 * cap,
            high_rate: 1.3 * cap,
            mean_low_s: 100.0 / cap,
            mean_high_s: 100.0 / cap,
        })
        .with_shards(4)
        .with_balancer(BalancerPolicy::JoinShortestQueue)
        .with_queue_capacity(32)
        .with_admission(shisha::serve::AdmissionPolicy::DropOldest)
        .with_slo(500.0 / cap);
        let opts = ServeOptions {
            duration_s: 400.0 / cap,
            seed: 47,
            control: false,
            control_epoch_s: 4.0 / cap,
            autoscale: shisha::serve::AutoscaleOptions::enabled(),
            ..Default::default()
        };
        (plat, vec![(tenant, cfg)], opts)
    });
    let scale_events: usize = report.tenants[0].shards.iter().map(|s| s.scale_events.len()).sum();
    assert!(scale_events > 0, "the tide must move the autoscaler");
    // every hashed scale transition has a journaled explanation
    let journaled = obs
        .journal
        .entries
        .iter()
        .filter(|e| e.kind == shisha::serve::ControlKind::Scale)
        .count();
    assert!(journaled > 0, "scale decisions must be journaled");
}

#[test]
fn obs_invariant_chaos_faulted() {
    let (report, obs) = check_invariance("chaos-faulted", true, || {
        let plat = configs::c5();
        let net = networks::synthnet();
        let cfg = shisha::serve::shisha_config(&net, &plat);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        let duration_s = 300.0 / cap;
        let tenant = TenantSpec::new("survivor", net, ArrivalProcess::Poisson {
            rate: 0.8 * cap,
        })
        .with_shards(2)
        .with_balancer(BalancerPolicy::JoinShortestQueue)
        .with_queue_capacity(32)
        .with_admission(shisha::serve::AdmissionPolicy::DropOldest)
        .with_slo(500.0 / cap);
        let opts = ServeOptions {
            duration_s,
            seed: 61,
            control: false,
            control_epoch_s: 15.0 / cap,
            faults: FaultScript::chaos(9, &plat, duration_s, 4),
            ..Default::default()
        };
        (plat, vec![(tenant, cfg)], opts)
    });
    assert!(report.tenants[0].conserved(), "conservation through the chaos script");
    // fault onsets/clears are journaled alongside the hashed records
    let faults = obs
        .journal
        .entries
        .iter()
        .filter(|e| e.kind == shisha::serve::ControlKind::Fault)
        .count();
    assert!(faults > 0, "chaos faults must be journaled");
}

#[test]
fn obs_invariant_elastic_coplan() {
    let (report, obs) = check_invariance("elastic-antiphase", true, || {
        let plat = configs::c5();
        let net = networks::synthnet();
        let cfg = shisha::serve::shisha_config(&net, &plat);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        let duration_s = 400.0 / cap;
        let flip_s = duration_s / 2.0;
        let hot = 1.0 * cap;
        let idle = 0.05 * cap;
        let mk = |name: &str, early: f64, late: f64| {
            TenantSpec::new(name, net.clone(), ArrivalProcess::Piecewise {
                segments: vec![(0.0, early), (flip_s, late)],
            })
            .with_queue_capacity(32)
            .with_admission(shisha::serve::AdmissionPolicy::DropOldest)
            .with_slo(500.0 / cap)
        };
        let tenants = vec![(mk("ebb", hot, idle), cfg.clone()), (mk("flow", idle, hot), cfg)];
        let opts = ServeOptions {
            duration_s,
            seed: 5,
            control: false,
            control_epoch_s: duration_s / 40.0,
            coplan: true,
            elastic: shisha::serve::ElasticOptions {
                enabled: true,
                ..Default::default()
            },
            ..Default::default()
        };
        (plat, tenants, opts)
    });
    // the co-plan's t=0 allocations seed the journal for every tenant
    let coplans = obs
        .journal
        .entries
        .iter()
        .filter(|e| e.kind == shisha::serve::ControlKind::Coplan)
        .count();
    assert!(coplans >= 2, "both tenants' co-plan allocations must be journaled");
    // plan-cache counters reach both the report and the samples
    let total = report.plan_cache.hits + report.plan_cache.misses;
    assert!(total > 0, "co-planning must exercise the plan cache");
    let last = obs.samples.last().expect("samples");
    assert_eq!(last.cache.hits + last.cache.misses, total, "samples carry cache counters");
}
