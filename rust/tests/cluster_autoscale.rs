//! Cluster co-planner + runtime autoscaler acceptance tests.
//!
//! The two headline obligations of the `serve/cluster` subsystem:
//!
//! * **Co-planner** — on a weighted 3-tenant C5 mix, the joint plan's
//!   total weighted predicted throughput is at least the greedy
//!   first-come allocation's (the planner returns the better of
//!   water-filling and greedy by construction; this pins that the
//!   construction holds end-to-end, with budgets disjoint and every
//!   placement valid on its sub-platform).
//! * **Autoscaler** — on the MMPP tidal sweep
//!   ([`shisha::serve::sweep::autoscale_grid`]), the autoscaled
//!   deployment's goodput is within 2% of the best static shard count
//!   while consuming strictly fewer EP-epochs than static max-k.
//!
//! * **Elastic loop** — on the anti-phase tidal mix
//!   ([`shisha::serve::sweep::elastic_grid`]), live re-planning on
//!   observed demand holds at least the static co-plan's weighted
//!   goodput at no more EP-epochs, and strictly beats it on the grid.
//!
//! Plus the safety properties: request conservation across scale
//! transitions (no arrival lost or double-served over a replica drain),
//! hysteresis (a constant-rate workload never scales), two-run
//! determinism of `serve --coplan --autoscale`, and per-tenant
//! conservation under arbitrary interleavings of autoscale drains,
//! elastic re-partitions and chaos faults.

use shisha::model::networks;
use shisha::perfdb::{CostModel, PerfDb};
use shisha::pipeline::simulator;
use shisha::platform::configs;
use shisha::serve::cluster::coplan::{coplan, greedy_plan};
use shisha::serve::sweep::{self, autoscale_grid};
use shisha::serve::{
    serve, ArrivalProcess, AutoscaleOptions, BalancerPolicy, ElasticOptions, FaultScript,
    ReplicaState, ScenarioStats, ServeOptions, TenantSpec,
};

/// The weighted 3-tenant C5 mix used across the acceptance tests.
fn c5_three_tenant_specs() -> (shisha::platform::Platform, Vec<TenantSpec>) {
    let plat = configs::c5();
    let mk = |name: &str, net: shisha::model::Network, weight: f64, shards: usize| {
        TenantSpec::new(name, net, ArrivalProcess::Poisson { rate: 5.0 })
            .with_weight(weight)
            .with_shards(shards)
    };
    let specs = vec![
        mk("hot", networks::synthnet(), 2.0, 2),
        mk("warm", networks::alexnet(), 1.0, 2),
        mk("cold", networks::synthnet_small(), 1.0, 1),
    ];
    (plat, specs)
}

#[test]
fn coplan_beats_greedy_on_three_tenant_c5() {
    let (plat, specs) = c5_three_tenant_specs();
    let joint = coplan(&plat, &specs).expect("coplan");
    let greedy = greedy_plan(&plat, &specs).expect("greedy plan");
    assert!(
        joint.objective() >= greedy.objective(),
        "acceptance: joint weighted predicted throughput {} below greedy {}",
        joint.objective(),
        greedy.objective()
    );
    assert!(joint.objective() > 0.0);
    // budgets disjoint, every tenant provisioned, placements valid
    let mut seen = vec![false; plat.n_eps()];
    for (alloc, spec) in joint.allocations.iter().zip(&specs) {
        assert!(!alloc.eps.is_empty(), "{}: empty budget", spec.name);
        for &e in &alloc.eps {
            assert!(!seen[e], "EP {e} in two budgets");
            seen[e] = true;
        }
        assert!(
            alloc.placements.len() <= spec.shards.max(1),
            "{}: more replicas than the shard budget",
            spec.name
        );
        for (eps, cfg) in &alloc.placements {
            let sub = plat.subset(eps);
            assert!(
                cfg.validate(spec.net.len(), &sub).is_ok(),
                "{}: invalid placement {}",
                spec.name,
                cfg.describe()
            );
        }
    }
}

#[test]
fn coplan_is_deterministic_across_calls() {
    let (plat, specs) = c5_three_tenant_specs();
    let a = coplan(&plat, &specs).expect("coplan");
    let b = coplan(&plat, &specs).expect("coplan");
    assert_eq!(a.strategy, b.strategy);
    assert_eq!(a.objective().to_bits(), b.objective().to_bits());
    for (x, y) in a.allocations.iter().zip(&b.allocations) {
        assert_eq!(x.eps, y.eps);
    }
}

/// Tidal sweep on the C5/SynthNet sharding fixture: static shard budgets
/// {1, 2, 4} against the autoscaler at budget 4, identical arrivals.
fn tidal_outcomes() -> (Vec<usize>, Vec<ScenarioStats>) {
    let plat = configs::c5();
    let net = networks::synthnet();
    let cfg = shisha::serve::shisha_config(&net, &plat);
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let cap = simulator::throughput(&net, &plat, &db, &cfg);
    let base = ServeOptions {
        duration_s: 400.0 / cap,
        control: false,
        control_epoch_s: 4.0 / cap,
        ..Default::default()
    };
    let counts = vec![1usize, 2, 4];
    let scenarios = autoscale_grid(
        &plat,
        &net,
        &cfg,
        &counts,
        BalancerPolicy::JoinShortestQueue,
        &[1.0],
        &[61],
        &base,
    );
    assert_eq!(scenarios.len(), counts.len() + 1);
    let outcomes = sweep::run_sweep(scenarios, sweep::available_threads());
    let stats: Vec<ScenarioStats> = outcomes
        .iter()
        .map(|o| ScenarioStats::from_report(o.report.as_ref().expect("tidal serve run")))
        .collect();
    (counts, stats)
}

#[test]
fn autoscaled_matches_best_static_goodput_with_fewer_ep_epochs() {
    let (counts, stats) = tidal_outcomes();
    let static_stats = &stats[..counts.len()];
    let auto = &stats[counts.len()];
    let best_static = static_stats.iter().map(|s| s.goodput_rps).fold(0.0, f64::max);
    let static_kmax_ep = static_stats.last().expect("static cells").ep_epochs;
    assert!(best_static > 0.0, "static cells must serve traffic");
    assert!(
        auto.goodput_rps >= 0.98 * best_static,
        "acceptance: autoscaled goodput {} below 98% of best static {}",
        auto.goodput_rps,
        best_static
    );
    assert!(
        auto.ep_epochs < static_kmax_ep,
        "acceptance: autoscaled EP-epochs {} not below static max-k {}",
        auto.ep_epochs,
        static_kmax_ep
    );
    assert!(auto.scale_events > 0, "the tide must move the autoscaler");
    // static cells never scale
    for s in static_stats {
        assert_eq!(s.scale_events, 0, "static cells must not scale");
    }
}

#[test]
fn scale_transitions_conserve_requests() {
    // run the autoscaled tidal cell directly and check conservation at
    // replica granularity: every offered request is rejected, dropped,
    // completed or still in flight — across multiple drain/re-activate
    // cycles, nothing is lost and nothing double-served
    let plat = configs::c5();
    let net = networks::synthnet();
    let cfg = shisha::serve::shisha_config(&net, &plat);
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let cap = simulator::throughput(&net, &plat, &db, &cfg);
    for seed in [5u64, 29, 71] {
        let tenant = TenantSpec::new(
            "tidal",
            net.clone(),
            ArrivalProcess::Mmpp {
                low_rate: 0.25 * cap,
                high_rate: 1.3 * cap,
                mean_low_s: 100.0 / cap,
                mean_high_s: 100.0 / cap,
            },
        )
        .with_shards(4)
        .with_balancer(BalancerPolicy::JoinShortestQueue)
        .with_queue_capacity(32)
        .with_admission(shisha::serve::AdmissionPolicy::DropOldest)
        .with_slo(500.0 / cap);
        let opts = ServeOptions {
            duration_s: 400.0 / cap,
            seed,
            control: false,
            control_epoch_s: 4.0 / cap,
            autoscale: AutoscaleOptions::enabled(),
            ..Default::default()
        };
        let report = serve(&plat, vec![(tenant, cfg.clone())], &opts).expect("serve");
        let t = &report.tenants[0];
        assert!(t.conserved(), "seed {seed}: conservation violated: {t:?}");
        assert_eq!(
            t.offered,
            t.shards.iter().map(|s| s.offered).sum::<u64>(),
            "seed {seed}: balancer lost or duplicated arrivals"
        );
        assert_eq!(
            t.completed,
            t.shards.iter().map(|s| s.completed).sum::<u64>(),
            "seed {seed}: replica completions disagree with the tenant"
        );
        // every replica that was drained ended with an empty backlog
        for (i, s) in t.shards.iter().enumerate() {
            if s.final_state == ReplicaState::Parked {
                assert_eq!(s.in_flight, 0, "seed {seed}: parked replica {i} holds requests");
            }
        }
    }
}

#[test]
fn constant_rate_never_triggers_scale_events() {
    // hysteresis: a steady load inside the deadband (well under active
    // capacity, above the scale-down gate) must never scale in either
    // direction, no matter how long it runs
    let plat = configs::c5();
    let net = networks::synthnet();
    let cfg = shisha::serve::shisha_config(&net, &plat);
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let cap = simulator::throughput(&net, &plat, &db, &cfg);
    // 0.7 × single-pipeline capacity sits squarely in the deadband: far
    // under the 2-replica plan's capacity (no pressure; and with every
    // replica active there is nothing to scale up anyway) yet far above
    // the scale-down gate. The long epoch (~28 arrivals each) keeps the
    // per-epoch observed rate concentrated, so Poisson noise cannot fake
    // a slack epoch.
    let tenant = TenantSpec::new(
        "steady",
        net,
        ArrivalProcess::Poisson { rate: 0.7 * cap },
    )
    .with_shards(2)
    .with_balancer(BalancerPolicy::JoinShortestQueue)
    .with_queue_capacity(64)
    .with_slo(500.0 / cap);
    let opts = ServeOptions {
        duration_s: 400.0 / cap,
        seed: 11,
        control: false,
        control_epoch_s: 40.0 / cap,
        autoscale: AutoscaleOptions::enabled(),
        ..Default::default()
    };
    let report = serve(&plat, vec![(tenant, cfg)], &opts).expect("serve");
    let t = &report.tenants[0];
    assert!(t.shards.len() > 1, "fixture must replicate for the test to bite");
    for (i, s) in t.shards.iter().enumerate() {
        assert!(
            s.scale_events.is_empty(),
            "replica {i} scaled under constant load: {:?}",
            s.scale_events
        );
        assert_eq!(s.final_state, ReplicaState::Active);
    }
    assert_eq!(
        t.ep_epochs(),
        t.epochs.len() as u64 * plat.n_eps() as u64,
        "no epoch may run below full capacity under steady load"
    );
    assert!(t.conserved());
}

#[test]
fn elastic_replan_beats_static_coplan_on_the_tidal_mix() {
    // acceptance: on the anti-phase tidal mix, equal tenant weights make
    // aggregate goodput the weighted objective — the live cells must hold
    // at least the static cells' goodput at no more EP-epochs on every
    // (rho, seed), and strictly beat them somewhere on the grid
    let plat = configs::c5();
    let net = networks::synthnet_small();
    let cfg = shisha::serve::shisha_config(&net, &plat);
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let cap = simulator::throughput(&net, &plat, &db, &cfg);
    let base = ServeOptions {
        duration_s: 300.0 / cap,
        control: false,
        // 40 epochs: 20 on each side of the tide flip
        control_epoch_s: 7.5 / cap,
        ..Default::default()
    };
    let cells = sweep::elastic_grid(&plat, &net, &cfg, &[1.0], &[13, 37], &base);
    assert_eq!(cells.len(), 4, "one static + one live cell per seed");
    let out = sweep::run_sweep(cells, sweep::available_threads());
    let stats: Vec<ScenarioStats> = out
        .iter()
        .map(|o| ScenarioStats::from_report(o.report.as_ref().expect("elastic grid cell")))
        .collect();
    let mut static_total = 0.0f64;
    let mut live_total = 0.0f64;
    for pair in stats.chunks(2) {
        let (st, live) = (&pair[0], &pair[1]);
        assert!(st.goodput_rps > 0.0, "static cells must serve traffic");
        assert_eq!(st.repartitions, 0, "static cells must never re-partition");
        assert!(live.repartitions >= 1, "the tide must move the elastic loop");
        assert!(
            live.goodput_rps >= st.goodput_rps,
            "acceptance: live goodput {} below static {}",
            live.goodput_rps,
            st.goodput_rps
        );
        assert!(
            live.ep_epochs <= st.ep_epochs,
            "acceptance: live EP-epochs {} above static {}",
            live.ep_epochs,
            st.ep_epochs
        );
        static_total += st.goodput_rps;
        live_total += live.goodput_rps;
    }
    assert!(
        live_total > static_total,
        "acceptance: live re-planning must strictly beat the static co-plan \
         somewhere on the grid (live {live_total}, static {static_total})"
    );
}

#[test]
fn chaotic_scale_fault_repartition_interleavings_conserve_requests() {
    // property: whatever interleaving of autoscale drains, elastic
    // cross-arena migrations and chaos faults a seed produces, every
    // tenant conserves requests — over the whole run and epoch by epoch —
    // and the interleaving is a pure function of the seed (two runs agree
    // bit for bit)
    let plat = configs::c5();
    let net = networks::synthnet_small();
    let cfg = shisha::serve::shisha_config(&net, &plat);
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let cap = simulator::throughput(&net, &plat, &db, &cfg);
    for seed in [3u64, 41, 89] {
        let run = || {
            let mk = |name: &str, weight: f64, shards: usize| {
                TenantSpec::new(
                    name,
                    net.clone(),
                    ArrivalProcess::Mmpp {
                        low_rate: 0.1 * cap,
                        high_rate: 0.8 * cap,
                        mean_low_s: 40.0 / cap,
                        mean_high_s: 40.0 / cap,
                    },
                )
                .with_weight(weight)
                .with_shards(shards)
                .with_balancer(BalancerPolicy::JoinShortestQueue)
                .with_queue_capacity(32)
                .with_admission(shisha::serve::AdmissionPolicy::DropOldest)
                .with_slo(500.0 / cap)
            };
            let tenants = vec![
                (mk("chaos-hot", 2.0, 2), cfg.clone()),
                (mk("chaos-warm", 1.0, 2), cfg.clone()),
                (mk("chaos-cold", 1.0, 1), cfg.clone()),
            ];
            let opts = ServeOptions {
                duration_s: 300.0 / cap,
                seed,
                control: false,
                control_epoch_s: 6.0 / cap,
                record_log: true,
                coplan: true,
                autoscale: AutoscaleOptions::enabled(),
                elastic: ElasticOptions::enabled(),
                faults: FaultScript::chaos(seed, &plat, 300.0 / cap, 3),
                ..Default::default()
            };
            serve(&plat, tenants, &opts).expect("chaos serve")
        };
        let a = run();
        let b = run();
        assert_eq!(a.log_hash, b.log_hash, "seed {seed}: interleaving must replay identically");
        assert_eq!(a.event_log, b.event_log, "seed {seed}: event streams diverged");
        for t in &a.tenants {
            assert!(t.offered > 0, "seed {seed}/{}: fixture must offer traffic", t.name);
            assert!(
                t.conserved(),
                "seed {seed}/{}: run-total conservation violated \
                 (offered {} != rejected {} + dropped {} + completed {} + in-flight {})",
                t.name,
                t.offered,
                t.rejected,
                t.dropped,
                t.completed,
                t.in_flight
            );
            assert!(
                t.epoch_conserved(),
                "seed {seed}/{}: per-epoch flow identity violated across the interleaving",
                t.name
            );
        }
    }
}

#[test]
fn coplan_autoscale_serve_is_deterministic() {
    let run = || {
        let (plat, specs) = c5_three_tenant_specs();
        let tenants: Vec<(TenantSpec, shisha::pipeline::PipelineConfig)> = specs
            .into_iter()
            .map(|s| {
                let cfg = shisha::serve::shisha_config(&s.net, &plat);
                (s, cfg)
            })
            .collect();
        let opts = ServeOptions {
            duration_s: 1.2,
            seed: 97,
            control: false,
            control_epoch_s: 0.1,
            record_log: true,
            coplan: true,
            autoscale: AutoscaleOptions::enabled(),
            ..Default::default()
        };
        serve(&plat, tenants, &opts).expect("serve")
    };
    let a = run();
    let b = run();
    assert_eq!(a.log_hash, b.log_hash, "event streams must be identical");
    assert_eq!(a.event_log, b.event_log);
    assert_eq!(a.n_events, b.n_events);
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!(x.offered, y.offered);
        assert_eq!(x.completed, y.completed);
        assert_eq!(x.ep_epochs(), y.ep_epochs());
        for (sx, sy) in x.shards.iter().zip(&y.shards) {
            assert_eq!(sx.scale_events, sy.scale_events);
            assert_eq!(sx.final_state, sy.final_state);
        }
    }
}
