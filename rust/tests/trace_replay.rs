//! Flight-recorder integration tests: capture → serialize → replay.
//!
//! Four contracts, strongest first:
//!
//! 1. **Recording is free of observable side effects** — `serve_traced`
//!    must produce the *same* `log_hash` / counters as a plain `serve` of
//!    the same inputs (the capture tap sits beside the hash fold, never
//!    inside it), so every committed golden fingerprint stays valid.
//! 2. **Full replay is bit-identical** — for every golden scenario family
//!    (steady Poisson, MMPP + drift re-tune, trace-driven, sharded with
//!    control, autoscale tidal, three-tenant co-plan) a recorded trace
//!    replays to the same event stream, hash, and per-tenant counters —
//!    including after a round trip through the binary format and disk.
//! 3. **Malformed traces are rejected, never trusted** — truncation at
//!    every byte boundary and single-byte corruption anywhere in the file
//!    yield errors, not panics and not silently-wrong traces.
//! 4. **What-if replay conserves the workload** — arrivals-only re-runs
//!    under different shard counts / balancers / autoscaling offer exactly
//!    the captured arrival stream, per tenant, across the whole
//!    [`whatif_grid`] (conservation is how we know the counterfactual
//!    answers are about the *same* storm).
//! 5. **Trace format v4 (request lifecycle)** — a recording with
//!    deadline/retry/hedge policies negotiates wire version 4, survives
//!    binary + disk round trips, replays bit-identically, and is rejected
//!    at every truncation boundary; a lifecycle-off recording still
//!    negotiates v3 so its bytes match a pre-lifecycle build's.

use shisha::model::networks;
use shisha::perfdb::{CostModel, PerfDb};
use shisha::pipeline::{simulator, PipelineConfig};
use shisha::platform::configs;
use shisha::serve::{
    replay_full, replay_whatif, serve, serve_traced, sweep, ArrivalProcess, BalancerPolicy,
    ControlKind, ControlRecord, ServeOptions, TenantSpec, Trace, WhatIf,
};

fn controls_of(trace: &Trace, kind: ControlKind) -> Vec<ControlRecord> {
    trace.controls.iter().copied().filter(|r| r.kind == kind).collect()
}

type Inputs = (shisha::platform::Platform, Vec<(TenantSpec, PipelineConfig)>, ServeOptions);

// ---------------------------------------------------------------------------
// Scenario builders — the same families the golden fingerprint tests pin
// (tests/serve_golden.rs); kept in sync by construction, not by import,
// so a drift there cannot silently weaken the replay coverage here.
// ---------------------------------------------------------------------------

fn poisson_scenario() -> Inputs {
    let plat = configs::c1();
    let net = networks::synthnet_small();
    let cfg = PipelineConfig::new(vec![3, 3], vec![0, 1]);
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let cap = simulator::throughput(&net, &plat, &db, &cfg);
    let heavy = TenantSpec::new("heavy", net.clone(), ArrivalProcess::Poisson { rate: 2.5 * cap })
        .with_batch(4)
        .with_queue_capacity(12)
        .with_admission(shisha::serve::AdmissionPolicy::DropOldest)
        .with_slo(20.0 / cap);
    let light = TenantSpec::new("light", net.clone(), ArrivalProcess::Poisson { rate: 0.4 * cap })
        .with_slo(20.0 / cap);
    let opts = ServeOptions {
        duration_s: 300.0 / cap,
        seed: 11,
        control: false,
        control_epoch_s: 40.0 / cap,
        ..Default::default()
    };
    (plat, vec![(heavy, cfg.clone()), (light, cfg)], opts)
}

fn drift_scenario() -> Inputs {
    let plat = configs::c2();
    let net = networks::synthnet();
    let bad = PipelineConfig::new(vec![5, 5, 4, 4], vec![2, 3, 0, 1]);
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let cap = simulator::throughput(&net, &plat, &db, &bad);
    let lat = simulator::evaluate(&net, &plat, &db, &bad).latency_s;
    let drifter = TenantSpec::new(
        "drifter",
        net.clone(),
        ArrivalProcess::Piecewise { segments: vec![(0.0, 0.5 * cap), (126.0 / cap, 1.3 * cap)] },
    )
    .with_slo(8.0 * lat)
    .with_queue_capacity(32);
    let small = networks::synthnet_small();
    let cfg_b = PipelineConfig::single_stage(small.len(), 3);
    let db_b = PerfDb::build(&small, &plat, &CostModel::default());
    let cap_b = simulator::throughput(&small, &plat, &db_b, &cfg_b);
    let bursty = TenantSpec::new(
        "bursty",
        small,
        ArrivalProcess::Mmpp {
            low_rate: 0.05 * cap_b,
            high_rate: 0.3 * cap_b,
            mean_low_s: 40.0 / cap,
            mean_high_s: 15.0 / cap,
        },
    )
    .with_slo(60.0 / cap_b);
    let opts = ServeOptions {
        duration_s: 420.0 / cap,
        seed: 17,
        control: true,
        control_epoch_s: 30.0 / cap,
        retune_threshold: 0.6,
        retune_cooldown_epochs: 1,
        reconfig_penalty_s: 2.0 / cap,
        ..Default::default()
    };
    (plat, vec![(drifter, bad), (bursty, cfg_b)], opts)
}

fn trace_driven_scenario() -> Inputs {
    let plat = configs::c1();
    let net = networks::synthnet_small();
    let cfg = PipelineConfig::new(vec![3, 3], vec![0, 1]);
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let cap = simulator::throughput(&net, &plat, &db, &cfg);
    let mut times = Vec::new();
    for burst in 0..8u32 {
        for k in 0..10u32 {
            times.push((f64::from(burst) * 30.0 + f64::from(k) * 0.25) / cap);
        }
    }
    let tenant = TenantSpec::new("replay", net, ArrivalProcess::Trace { times })
        .with_batch(2)
        .with_queue_capacity(6)
        .with_admission(shisha::serve::AdmissionPolicy::DropOldest)
        .with_slo(15.0 / cap);
    let opts = ServeOptions {
        duration_s: 300.0 / cap,
        seed: 23,
        control: false,
        control_epoch_s: 0.0,
        ..Default::default()
    };
    (plat, vec![(tenant, cfg)], opts)
}

fn sharded_scenario(shards: usize, balancer: BalancerPolicy, control: bool, seed: u64) -> Inputs {
    let plat = configs::c5();
    let net = networks::synthnet();
    let cfg = shisha::serve::shisha_config(&net, &plat);
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let cap = simulator::throughput(&net, &plat, &db, &cfg);
    let tenant = TenantSpec::new(
        "sharded",
        net,
        ArrivalProcess::Mmpp {
            low_rate: 0.5 * cap,
            high_rate: 2.5 * cap,
            mean_low_s: 50.0 / cap,
            mean_high_s: 50.0 / cap,
        },
    )
    .with_shards(shards)
    .with_balancer(balancer)
    .with_queue_capacity(16)
    .with_admission(shisha::serve::AdmissionPolicy::DropOldest)
    .with_slo(200.0 / cap);
    let opts = ServeOptions {
        duration_s: 300.0 / cap,
        seed,
        control,
        control_epoch_s: if control { 30.0 / cap } else { 0.0 },
        retune_cooldown_epochs: 1,
        ..Default::default()
    };
    (plat, vec![(tenant, cfg)], opts)
}

fn autoscale_scenario() -> Inputs {
    let plat = configs::c5();
    let net = networks::synthnet();
    let cfg = shisha::serve::shisha_config(&net, &plat);
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let cap = simulator::throughput(&net, &plat, &db, &cfg);
    let tenant = TenantSpec::new(
        "tidal",
        net,
        ArrivalProcess::Mmpp {
            low_rate: 0.2 * cap,
            high_rate: 1.3 * cap,
            mean_low_s: 100.0 / cap,
            mean_high_s: 100.0 / cap,
        },
    )
    .with_shards(4)
    .with_balancer(BalancerPolicy::JoinShortestQueue)
    .with_queue_capacity(32)
    .with_admission(shisha::serve::AdmissionPolicy::DropOldest)
    .with_slo(500.0 / cap);
    let opts = ServeOptions {
        duration_s: 400.0 / cap,
        seed: 47,
        control: false,
        control_epoch_s: 4.0 / cap,
        autoscale: shisha::serve::AutoscaleOptions::enabled(),
        ..Default::default()
    };
    (plat, vec![(tenant, cfg)], opts)
}

fn coplan_scenario() -> Inputs {
    let plat = configs::c5();
    let mk = |name: &str, net: shisha::model::Network, weight: f64, shards: usize| {
        let cfg = shisha::serve::shisha_config(&net, &plat);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        (
            TenantSpec::new(name, net, ArrivalProcess::Poisson { rate: 0.4 * cap })
                .with_weight(weight)
                .with_shards(shards)
                .with_slo(200.0 / cap),
            cfg,
        )
    };
    let tenants = vec![
        mk("hot", networks::synthnet(), 2.0, 2),
        mk("warm", networks::alexnet(), 1.0, 2),
        mk("cold", networks::synthnet_small(), 1.0, 1),
    ];
    let opts = ServeOptions {
        duration_s: 1.5,
        seed: 53,
        control: false,
        control_epoch_s: 0.25,
        coplan: true,
        ..Default::default()
    };
    (plat, tenants, opts)
}

// ---------------------------------------------------------------------------
// 1. Recording has no observable side effect on the run itself.
// ---------------------------------------------------------------------------

#[test]
fn recording_does_not_perturb_the_run() {
    let wtp: fn() -> Inputs = || sharded_scenario(4, BalancerPolicy::WeightedThroughput, true, 43);
    for (what, build) in [
        ("poisson", poisson_scenario as fn() -> Inputs),
        ("shard4-wtp-control", wtp),
        ("autoscale-tidal", autoscale_scenario),
    ] {
        let (plat, tenants, opts) = build();
        let plain = serve(&plat, tenants.clone(), &opts).expect("plain serve");
        let (recorded, trace) = serve_traced(&plat, tenants, &opts).expect("traced serve");
        assert_eq!(plain.log_hash, recorded.log_hash, "{what}: log_hash must not move");
        assert_eq!(plain.n_events, recorded.n_events, "{what}: n_events must not move");
        assert_eq!(plain.truncated, recorded.truncated, "{what}: truncation must not move");
        for (a, b) in plain.tenants.iter().zip(&recorded.tenants) {
            assert_eq!(a.offered, b.offered, "{what}/{}: offered", a.name);
            assert_eq!(a.completed, b.completed, "{what}/{}: completed", a.name);
            assert_eq!(a.slo_ok, b.slo_ok, "{what}/{}: slo_ok", a.name);
            assert_eq!(a.retunes, b.retunes, "{what}/{}: retunes", a.name);
        }
        assert!(!trace.events.is_empty(), "{what}: the capture must see the event stream");
        assert_eq!(trace.summary.log_hash, plain.log_hash, "{what}: summary hash");
    }
}

// ---------------------------------------------------------------------------
// 2. Full replay is bit-identical, for every golden scenario family —
//    including after a round trip through bytes and disk.
// ---------------------------------------------------------------------------

fn check_full_replay(what: &str, build: impl Fn() -> Inputs) -> Trace {
    let (plat, tenants, opts) = build();
    let (live, trace) = serve_traced(&plat, tenants, &opts).expect("record run");
    // Round-trip through the binary format first: replay certifies the
    // *serialized* trace, the thing a user actually has on disk.
    let bytes = trace.to_bytes();
    let back = Trace::from_bytes(&bytes).expect("decode recorded trace");
    assert_eq!(back.to_bytes(), bytes, "{what}: canonical re-encoding");
    let replayed = replay_full(&back).unwrap_or_else(|e| panic!("{what}: full replay: {e:#}"));
    assert_eq!(replayed.log_hash, live.log_hash, "{what}: replay hash");
    assert_eq!(replayed.n_events, live.n_events, "{what}: replay event count");
    back
}

#[test]
fn full_replay_poisson() {
    check_full_replay("poisson", poisson_scenario);
}

#[test]
fn full_replay_mmpp_drift_retune() {
    let trace = check_full_replay("mmpp+drift", drift_scenario);
    // The warm re-tune decisions must land in the control-record channel.
    let retunes = controls_of(&trace, ControlKind::Retune);
    assert!(!retunes.is_empty(), "the drift scenario re-tunes; capture must see it");
    assert!(
        retunes.iter().any(|r| r.b == 1),
        "at least one re-tune changes the configuration (b=1): {retunes:?}"
    );
    assert!(
        trace.summary.tenants.iter().any(|t| t.retunes > 0),
        "summary counters must agree with the control records on re-tuning"
    );
}

#[test]
fn full_replay_trace_driven() {
    let trace = check_full_replay("trace", trace_driven_scenario);
    assert_eq!(trace.summary.tenants[0].offered, 80);
    assert_eq!(trace.arrival_times(0).len(), 80, "every burst arrival is captured");
}

#[test]
fn full_replay_sharded_with_control() {
    check_full_replay("shard4-wtp-control", || {
        sharded_scenario(4, BalancerPolicy::WeightedThroughput, true, 43)
    });
}

#[test]
fn full_replay_autoscale_tidal() {
    let trace = check_full_replay("autoscale-tidal", autoscale_scenario);
    // Every autoscaler transition is mirrored as a control record, and the
    // counts must agree with the per-replica report summary.
    let scales = controls_of(&trace, ControlKind::Scale).len() as u64;
    let summary: u64 = trace.summary.tenants.iter().map(|t| t.scale_events).sum();
    assert!(scales > 0, "the tide must move the autoscaler");
    assert_eq!(scales, summary, "control records mirror the scale-event log 1:1");
}

#[test]
fn full_replay_coplan_three_tenants() {
    let trace = check_full_replay("coplan3", coplan_scenario);
    let coplans = controls_of(&trace, ControlKind::Coplan);
    assert_eq!(coplans.len(), 3, "one co-plan allocation record per tenant");
    for (ti, rec) in coplans.iter().enumerate() {
        assert_eq!(rec.tenant as usize, ti);
        assert!(rec.a > 0, "tenant {ti} got a non-empty EP budget");
    }
}

#[test]
fn full_replay_survives_disk_round_trip() {
    let (plat, tenants, opts) = trace_driven_scenario();
    let (_, trace) = serve_traced(&plat, tenants, &opts).expect("record run");
    let path =
        std::env::temp_dir().join(format!("shisha_trace_replay_{}.trace", std::process::id()));
    trace.save(&path).expect("save trace");
    let loaded = Trace::load(&path).expect("load trace");
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.to_bytes(), trace.to_bytes(), "disk round trip is byte-identical");
    replay_full(&loaded).expect("full replay of the loaded trace");
}

// ---------------------------------------------------------------------------
// 3. Malformed traces are rejected, never trusted.
// ---------------------------------------------------------------------------

#[test]
fn malformed_traces_are_rejected() {
    let (plat, tenants, opts) = trace_driven_scenario();
    let (_, trace) = serve_traced(&plat, tenants, &opts).expect("record run");
    let bytes = trace.to_bytes();
    // Truncation at every byte boundary.
    for cut in 0..bytes.len() {
        assert!(
            Trace::from_bytes(&bytes[..cut]).is_err(),
            "a {cut}-byte prefix of a {}-byte trace must be rejected",
            bytes.len()
        );
    }
    // Single-byte corruption: every byte of the file is covered by the
    // magic, the version check, or a section CRC, so any flip must error.
    // (Stride 3 keeps the test fast; the offset sweeps all residues.)
    for start in 0..3 {
        for i in (start..bytes.len()).step_by(3) {
            let mut bad = bytes.clone();
            bad[i] ^= 0x40;
            assert!(Trace::from_bytes(&bad).is_err(), "flip at byte {i} must be rejected");
        }
    }
    // Garbage that is not a trace at all.
    assert!(Trace::from_bytes(&[]).is_err());
    assert!(Trace::from_bytes(b"not a trace file").is_err());
}

// ---------------------------------------------------------------------------
// 4. What-if replay conserves the captured workload under policy overrides.
// ---------------------------------------------------------------------------

#[test]
fn whatif_conserves_requests_across_policies() {
    let (plat, tenants, opts) = sharded_scenario(2, BalancerPolicy::RoundRobin, false, 41);
    let (live, trace) = serve_traced(&plat, tenants, &opts).expect("record run");
    assert!(!live.truncated);
    let captured = trace.arrival_times(0).len() as u64;
    assert_eq!(captured, live.tenants[0].offered, "capture sees every offered arrival");

    let overrides = [
        WhatIf::default(),
        WhatIf { shards: Some(1), ..Default::default() },
        WhatIf {
            shards: Some(4),
            balancer: Some(BalancerPolicy::WeightedThroughput),
            ..Default::default()
        },
        WhatIf { balancer: Some(BalancerPolicy::JoinShortestQueue), ..Default::default() },
        WhatIf {
            shards: Some(4),
            autoscale: Some(true),
            min_shards: Some(1),
            ..Default::default()
        },
    ];
    for what_if in &overrides {
        let report = replay_whatif(&trace, what_if)
            .unwrap_or_else(|e| panic!("what-if {}: {e:#}", what_if.describe()));
        // replay_whatif checks conservation internally; re-assert here so
        // the contract is pinned by the test, not just by the library.
        assert_eq!(
            report.tenants[0].offered,
            captured,
            "what-if {} must offer exactly the captured workload",
            what_if.describe()
        );
        assert!(
            report.tenants[0].completed > 0,
            "what-if {} completed nothing",
            what_if.describe()
        );
    }
}

#[test]
fn whatif_replay_is_deterministic() {
    let (plat, tenants, opts) = sharded_scenario(2, BalancerPolicy::RoundRobin, false, 41);
    let (_, trace) = serve_traced(&plat, tenants, &opts).expect("record run");
    let what_if = WhatIf { shards: Some(4), ..Default::default() };
    let a = replay_whatif(&trace, &what_if).expect("first what-if");
    let b = replay_whatif(&trace, &what_if).expect("second what-if");
    assert_eq!(a.log_hash, b.log_hash, "what-if replay must be reproducible");
    assert_eq!(a.n_events, b.n_events);
}

#[test]
fn whatif_grid_runs_and_conserves() {
    let (plat, tenants, opts) = sharded_scenario(2, BalancerPolicy::RoundRobin, false, 41);
    let (_, trace) = serve_traced(&plat, tenants, &opts).expect("record run");
    let captured = trace.arrival_times(0).len() as u64;

    let counts = [1usize, 2];
    let balancers = [BalancerPolicy::RoundRobin, BalancerPolicy::JoinShortestQueue];
    let scenarios = sweep::whatif_grid(&trace, &counts, &balancers).expect("build grid");
    assert_eq!(scenarios.len(), counts.len() * balancers.len());
    let mut names: Vec<&str> = scenarios.iter().map(|s| s.name.as_str()).collect();
    names.sort_unstable();
    names.dedup();
    assert_eq!(names.len(), scenarios.len(), "scenario names must be unique");

    let outcomes = sweep::run_sweep(scenarios, 2);
    for outcome in &outcomes {
        let report = outcome.report.as_ref().unwrap_or_else(|e| panic!("{}: {e:#}", outcome.name));
        assert_eq!(
            report.tenants[0].offered,
            captured,
            "{}: the grid replays the same captured storm everywhere",
            outcome.name
        );
    }
}

// ---------------------------------------------------------------------------
// 5. Trace format v4: lifecycle recordings round-trip and replay.
// ---------------------------------------------------------------------------

/// The sharded storm with every lifecycle policy live: a finite deadline,
/// deterministic retry, and hedging across the two replicas.
fn lifecycle_scenario() -> Inputs {
    let (plat, mut tenants, opts) = sharded_scenario(2, BalancerPolicy::JoinShortestQueue, false, 61);
    for (spec, _) in &mut tenants {
        let slo = spec.slo_latency_s;
        *spec = spec
            .clone()
            .with_deadline(4.0 * slo)
            .with_retry(shisha::serve::RetryPolicy {
                max_attempts: 2,
                base_s: slo / 10.0,
                cap_s: 4.0 * slo,
            })
            .with_hedge(shisha::serve::HedgePolicy { quantile: 0.90, min_delay_s: slo / 20.0 });
    }
    (plat, tenants, opts)
}

#[test]
fn lifecycle_recordings_negotiate_v4_and_replay_bit_identically() {
    let (plat, tenants, opts) = lifecycle_scenario();
    let (live, trace) = serve_traced(&plat, tenants, &opts).expect("lifecycle record run");
    let t = &live.tenants[0];
    assert!(
        t.retried + t.hedged + t.expired > 0,
        "the storm must exercise at least one lifecycle mechanism \
         (retried {}, hedged {}, expired {})",
        t.retried,
        t.hedged,
        t.expired
    );

    // Wire negotiation: lifecycle-active tenants bump the header to v4.
    let bytes = trace.to_bytes();
    assert_eq!(bytes[4], 4, "lifecycle recordings carry wire version 4");
    let back = Trace::from_bytes(&bytes).expect("decode v4 trace");
    assert_eq!(back.to_bytes(), bytes, "v4 canonical re-encoding");
    // The lifecycle counters ride in the v4 summary tail.
    assert_eq!(back.summary.tenants[0].retried, t.retried);
    assert_eq!(back.summary.tenants[0].hedged, t.hedged);
    assert_eq!(back.summary.tenants[0].expired, t.expired);
    assert_eq!(back.summary.tenants[0].cancelled, t.cancelled);
    // And the policies themselves round-trip through the tenant specs.
    let (spec, _) = &back.tenants[0];
    assert!(spec.lifecycle_active());
    assert_eq!(spec.retry, trace.tenants[0].0.retry);
    assert_eq!(spec.hedge, trace.tenants[0].0.hedge);
    assert_eq!(spec.deadline_s.to_bits(), trace.tenants[0].0.deadline_s.to_bits());

    // Disk round trip, then bit-identical re-simulation.
    let path =
        std::env::temp_dir().join(format!("shisha_lifecycle_v4_{}.trace", std::process::id()));
    trace.save(&path).expect("save v4 trace");
    let loaded = Trace::load(&path).expect("load v4 trace");
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.to_bytes(), bytes, "disk round trip is byte-identical");
    let replayed = replay_full(&loaded).expect("full replay of the lifecycle run");
    assert_eq!(replayed.log_hash, live.log_hash, "lifecycle replay must be bit-identical");
    assert_eq!(replayed.n_events, live.n_events);
    assert_eq!(replayed.tenants[0].retried, t.retried, "replay reproduces the retry schedule");
    assert_eq!(replayed.tenants[0].hedged, t.hedged, "replay reproduces the hedge decisions");
}

#[test]
fn truncated_v4_traces_are_rejected() {
    let (plat, tenants, opts) = lifecycle_scenario();
    let (_, trace) = serve_traced(&plat, tenants, &opts).expect("lifecycle record run");
    let bytes = trace.to_bytes();
    assert_eq!(bytes[4], 4);
    for cut in 0..bytes.len() {
        assert!(
            Trace::from_bytes(&bytes[..cut]).is_err(),
            "a {cut}-byte prefix of a {}-byte v4 trace must be rejected",
            bytes.len()
        );
    }
}

#[test]
fn lifecycle_off_recordings_stay_on_wire_v3() {
    // No lifecycle policy anywhere → the recorder negotiates v3, so the
    // bytes are exactly what a pre-lifecycle build would have written.
    let (plat, tenants, opts) = sharded_scenario(2, BalancerPolicy::RoundRobin, false, 41);
    assert!(tenants.iter().all(|(s, _)| !s.lifecycle_active()));
    let (_, trace) = serve_traced(&plat, tenants, &opts).expect("record run");
    let bytes = trace.to_bytes();
    assert_eq!(bytes[4], 3, "lifecycle-off recordings keep wire version 3");
    let back = Trace::from_bytes(&bytes).expect("decode v3 trace");
    assert_eq!(back.to_bytes(), bytes, "v3 canonical re-encoding");
    assert!(
        back.summary.tenants.iter().all(|t| t.expired + t.cancelled + t.retried + t.hedged == 0),
        "pre-v4 summaries decode with zeroed lifecycle counters"
    );
}

#[test]
fn inspect_output_names_the_scenario() {
    let (plat, tenants, opts) = coplan_scenario();
    let (_, trace) = serve_traced(&plat, tenants, &opts).expect("record run");
    let text = trace.describe();
    for needle in ["hot", "warm", "cold", "coplan", "event census", "hash"] {
        assert!(text.contains(needle), "describe() must mention {needle:?}:\n{text}");
    }
}
