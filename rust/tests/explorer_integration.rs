//! Integration tests across explore × perfdb × pipeline × platform:
//! every algorithm on every platform and network must produce valid,
//! sensible solutions, and the paper's qualitative relationships must hold.

use shisha::explore::exhaustive::{EsOptions, ExhaustiveSearch};
use shisha::explore::hill_climbing::{HcOptions, HillClimbing};
use shisha::explore::pipe_search::{PipeSearch, PsOptions};
use shisha::explore::random_walk::{RandomWalk, RwOptions};
use shisha::explore::shisha::{
    generate_seed, AssignmentChoice, Heuristic, ShishaExplorer, ShishaOptions,
};
use shisha::explore::simulated_annealing::{SaOptions, SimulatedAnnealing};
use shisha::explore::{EvalOptions, Evaluator, Explorer, Solution};
use shisha::model::networks;
use shisha::perfdb::{CostModel, PerfDb};
use shisha::pipeline::{simulator, space, PipelineConfig};
use shisha::platform::configs;

fn run_all(net_name: &str, plat_name: &str, max_evals: u64) -> Vec<Solution> {
    let net = networks::by_name(net_name).unwrap();
    let plat = configs::by_name(plat_name).unwrap();
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let opts = EvalOptions { max_evals: Some(max_evals), ..Default::default() };
    let mut out = Vec::new();
    let mut explorers: Vec<Box<dyn Explorer>> = vec![
        Box::new(ShishaExplorer::new(ShishaOptions::default())),
        Box::new(SimulatedAnnealing::new(SaOptions::default())),
        Box::new(HillClimbing::new(HcOptions::default())),
        Box::new(RandomWalk::new(RwOptions::default())),
        Box::new(ExhaustiveSearch::new(EsOptions { max_depth: 3 })),
        Box::new(PipeSearch::new(PsOptions::default())),
    ];
    for ex in explorers.iter_mut() {
        let mut eval = Evaluator::with_options(&net, &plat, &db, opts.clone());
        let sol = ex.explore(&mut eval);
        assert!(
            sol.best_config.validate(net.len(), &plat).is_ok(),
            "{}: invalid config {}",
            sol.algorithm,
            sol.best_config.describe()
        );
        assert!(sol.best_throughput > 0.0);
        assert!(sol.n_evals > 0);
        assert!(!sol.trace.is_empty());
        // trace monotone in both axes
        for w in sol.trace.windows(2) {
            assert!(w[1].time_s >= w[0].time_s, "{}: time monotone", sol.algorithm);
            assert!(w[1].throughput >= w[0].throughput, "{}: best monotone", sol.algorithm);
        }
        out.push(sol);
    }
    out
}

#[test]
fn all_explorers_all_platforms_synthnet() {
    for plat in ["c1", "c2", "c3", "c4", "c5"] {
        run_all("synthnet", plat, 2_000);
    }
}

#[test]
fn all_explorers_large_nets() {
    run_all("resnet50", "c2", 1_500);
    run_all("yolov3", "c3", 1_500);
}

#[test]
fn shisha_matches_es_on_small_exhaustible_space() {
    // AlexNet (5 layers) on C1 (2 EPs): full space is tiny; ES is exact.
    let net = networks::alexnet();
    let plat = configs::c1();
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let mut eval = Evaluator::new(&net, &plat, &db);
    let es = ExhaustiveSearch::new(EsOptions { max_depth: 2 }).explore(&mut eval);
    let mut eval2 = Evaluator::new(&net, &plat, &db);
    let sh = ShishaExplorer::new(ShishaOptions::default()).explore(&mut eval2);
    assert!(
        sh.best_throughput >= 0.95 * es.best_throughput,
        "Shisha {} vs ES {}",
        sh.best_throughput,
        es.best_throughput
    );
}

#[test]
fn shisha_converges_much_faster_than_blind_search() {
    // The headline mechanism on SynthNet/C2: Shisha's total online time is
    // far below SA/RW/ES's time-to-equal-quality.
    let net = networks::synthnet();
    let plat = configs::c2();
    let db = PerfDb::build(&net, &plat, &CostModel::default());

    let mut eval = Evaluator::new(&net, &plat, &db);
    let sh = ShishaExplorer::new(ShishaOptions::default()).explore(&mut eval);

    let opts = EvalOptions { max_evals: Some(3_000), ..Default::default() };
    let mut eval2 = Evaluator::with_options(&net, &plat, &db, opts);
    let es = ExhaustiveSearch::new(EsOptions { max_depth: 4 }).explore(&mut eval2);

    assert!(
        es.virtual_time_s > 5.0 * sh.virtual_time_s,
        "ES {} vs Shisha {}",
        es.virtual_time_s,
        sh.virtual_time_s
    );
}

#[test]
fn seeded_variants_never_worse_than_seed() {
    let net = networks::synthnet();
    let plat = configs::c5();
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let seed = generate_seed(&net, &plat, AssignmentChoice::RankW, 0);
    let seed_tp = simulator::throughput(&net, &plat, &db, &seed.config);

    let opts = EvalOptions { max_evals: Some(400), ..Default::default() };
    let mut e1 = Evaluator::with_options(&net, &plat, &db, opts.clone());
    let sa = SimulatedAnnealing::seeded(seed.config.clone()).explore(&mut e1);
    let mut e2 = Evaluator::with_options(&net, &plat, &db, opts);
    let hc = HillClimbing::seeded(seed.config.clone()).explore(&mut e2);
    assert!(sa.best_throughput >= seed_tp);
    assert!(hc.best_throughput >= seed_tp);
}

#[test]
fn heuristics_all_valid_on_all_platforms() {
    let net = networks::yolov3();
    for plat in configs::all_c() {
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        for h in Heuristic::ALL {
            let mut eval = Evaluator::new(&net, &plat, &db);
            let sol = ShishaExplorer::heuristic(h).explore(&mut eval);
            assert!(sol.best_config.validate(net.len(), &plat).is_ok());
            assert!(sol.n_evals <= 200, "{} evals on {}", sol.n_evals, plat.name);
        }
    }
}

#[test]
fn explored_fraction_tiny_for_big_networks() {
    // §7.3: ~0.1% for ResNet50/YOLOv3 class networks on 4 EPs.
    for name in ["resnet50", "yolov3"] {
        let net = networks::by_name(name).unwrap();
        let plat = configs::fig5_platform();
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let mut eval = Evaluator::new(&net, &plat, &db);
        let sol = ShishaExplorer::new(ShishaOptions::default()).explore(&mut eval);
        let frac = sol.explored_fraction(space::full_space_size(net.len(), plat.n_eps()));
        assert!(frac < 0.002, "{name}: explored {frac}");
    }
}

#[test]
fn es_optimum_dominates_everyone_small_space() {
    let net = networks::synthnet();
    let plat = configs::c2();
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let mut eval = Evaluator::new(&net, &plat, &db);
    let es = ExhaustiveSearch::new(EsOptions { max_depth: 4 }).explore(&mut eval);
    for sol in run_all("synthnet", "c2", 2_000) {
        assert!(
            sol.best_throughput <= es.best_throughput + 1e-9,
            "{} beat full-depth ES?!",
            sol.algorithm
        );
    }
}

#[test]
fn evaluator_time_accounting_consistent() {
    // virtual time equals sum of per-trial makespans + overheads (+ setup)
    let net = networks::alexnet();
    let plat = configs::c1();
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let opts = EvalOptions::default();
    let mut eval = Evaluator::with_options(&net, &plat, &db, opts.clone());
    let cfgs = [
        PipelineConfig::new(vec![5], vec![0]),
        PipelineConfig::new(vec![2, 3], vec![0, 1]),
    ];
    let mut expect = 0.0;
    for cfg in &cfgs {
        eval.evaluate(cfg);
        expect += simulator::makespan(&net, &plat, &db, cfg, opts.probe_inputs)
            + opts.trial_overhead_s;
    }
    assert!((eval.virtual_time_s() - expect).abs() < 1e-9);
}

/// Run one named explorer with its default (fixed-seed) options.
fn run_named(which: &str, net_name: &str, plat_name: &str, max_evals: u64) -> Solution {
    let net = networks::by_name(net_name).unwrap();
    let plat = configs::by_name(plat_name).unwrap();
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let opts = EvalOptions { max_evals: Some(max_evals), ..Default::default() };
    let mut eval = Evaluator::with_options(&net, &plat, &db, opts);
    match which {
        "shisha" => ShishaExplorer::new(ShishaOptions::default()).explore(&mut eval),
        "sa" => SimulatedAnnealing::new(SaOptions::default()).explore(&mut eval),
        "hc" => HillClimbing::new(HcOptions::default()).explore(&mut eval),
        "ps" => PipeSearch::new(PsOptions::default()).explore(&mut eval),
        other => unreachable!("unknown explorer {other}"),
    }
}

#[test]
fn explorers_deterministic_from_fixed_seed() {
    // Shisha, SA, Hill Climbing and Pipe-Search must each reproduce the
    // exact same schedule (and cost accounting) across two runs — the
    // engine-level determinism the serving golden tests build on.
    for which in ["shisha", "sa", "hc", "ps"] {
        let a = run_named(which, "synthnet", "c2", 800);
        let b = run_named(which, "synthnet", "c2", 800);
        assert_eq!(a.best_config, b.best_config, "{which}: schedule diverged");
        assert_eq!(a.n_evals, b.n_evals, "{which}: eval count diverged");
        assert_eq!(
            a.best_throughput.to_bits(),
            b.best_throughput.to_bits(),
            "{which}: throughput diverged"
        );
        assert_eq!(
            a.virtual_time_s.to_bits(),
            b.virtual_time_s.to_bits(),
            "{which}: virtual clock diverged"
        );
        assert_eq!(a.trace.len(), b.trace.len(), "{which}: trace diverged");
        for (x, y) in a.trace.iter().zip(&b.trace) {
            assert_eq!(x.evals, y.evals, "{which}: trace evals diverged");
            assert_eq!(
                x.throughput.to_bits(),
                y.throughput.to_bits(),
                "{which}: trace throughput diverged"
            );
        }
    }
}

#[test]
fn shisha_converges_in_fewer_evals_than_blind_search_on_resnet50() {
    // The paper's headline (~35x faster convergence on big CNNs),
    // asserted loosely as a ratio > 1: on the ResNet-50 fixture, Shisha's
    // total evaluation count stays below the evaluation index at which
    // SA/HC found their final improvement.
    let sh = run_named("shisha", "resnet50", "c2", 10_000);
    let sa = run_named("sa", "resnet50", "c2", 3_000);
    let hc = run_named("hc", "resnet50", "c2", 3_000);
    // last *improvement*, not trace.last(): budget-capped runs now end
    // their trace with an exhaustion marker at the full budget, which
    // would make this ratio pass vacuously whenever SA/HC hit the cap
    let conv_evals = |s: &Solution| s.convergence_evals();
    assert!(
        sh.n_evals <= 200,
        "Shisha must stay cheap on ResNet-50: {} evals",
        sh.n_evals
    );
    let sa_ratio = conv_evals(&sa) as f64 / sh.n_evals as f64;
    let hc_ratio = conv_evals(&hc) as f64 / sh.n_evals as f64;
    assert!(
        sa_ratio > 1.0,
        "SA converged in {} evals vs Shisha's {} (ratio {sa_ratio:.2})",
        conv_evals(&sa),
        sh.n_evals
    );
    assert!(
        hc_ratio > 1.0,
        "HC converged in {} evals vs Shisha's {} (ratio {hc_ratio:.2})",
        conv_evals(&hc),
        sh.n_evals
    );
    // cheapness must not cost solution quality catastrophically
    let best_blind = sa.best_throughput.max(hc.best_throughput);
    assert!(
        sh.best_throughput > 0.8 * best_blind,
        "Shisha quality collapsed: {} vs blind {}",
        sh.best_throughput,
        best_blind
    );
}

#[test]
fn deeper_pipelines_win_when_eps_available() {
    // On C5 (8 EPs) the best Shisha schedule for an 18-layer net should
    // use several stages, not collapse to one.
    let net = networks::synthnet();
    let plat = configs::c5();
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let mut eval = Evaluator::new(&net, &plat, &db);
    let sol = ShishaExplorer::new(ShishaOptions::default()).explore(&mut eval);
    assert!(sol.best_config.n_stages() >= 4, "{}", sol.best_config.describe());
    let single = simulator::throughput(&net, &plat, &db, &PipelineConfig::single_stage(18, 0));
    assert!(sol.best_throughput > 1.5 * single);
}
