//! Request-lifecycle integration tests: deadlines, deterministic
//! retry/backoff, and hedged requests, end to end.
//!
//! Five contracts, strongest first:
//!
//! 1. **Policy-off runs are byte-identical to a blind build** — a run
//!    whose tenants carry no lifecycle policy (or only *inert* ones: an
//!    infinite deadline, a zero-attempt retry) produces the same
//!    `log_hash`, event log, trace bytes (wire v3) and telemetry exports
//!    as a run of plain default specs. The lifecycle layer must be
//!    invisible until switched on.
//! 2. **Lifecycle runs are deterministic** — retry jitter and hedge
//!    delays derive from hashes, not RNG state: two invocations of a
//!    faulted, hedged, retrying storm match bit for bit.
//! 3. **Hedge racing conserves requests** — across chaos fault scripts,
//!    every offered request (including every retry re-arrival and hedge
//!    twin) ends in exactly one bucket, per run and per epoch.
//! 4. **Infinite deadlines never fire** — no tag-9 event ever enters the
//!    stream without a finite deadline, while a tight deadline under
//!    overload reaps visibly.
//! 5. **The acceptance storm** — a tidal MMPP storm through an EP stall
//!    plus a link slowdown, with retry + hedging on, retains ≥ 95% of the
//!    fault-free goodput, conserves every request, is back at fault-free
//!    goodput within two control epochs of the last fault clearing, and
//!    records/replays through the v4 trace format bit-identically.

use shisha::model::networks;
use shisha::perfdb::{CostModel, PerfDb};
use shisha::pipeline::simulator;
use shisha::platform::configs;
use shisha::serve::{
    replay_full, replay_whatif, serve, serve_observed, serve_traced, shisha_config,
    AdmissionPolicy, ArrivalProcess, BalancerPolicy, FaultEvent, FaultKind, FaultScript,
    HedgePolicy, RetryPolicy, ServeOptions, ServeReport, TenantSpec, WhatIf,
};

/// C5 + SynthNet storm fixture: capacity, strongest EP, and a tidal
/// two-replica tenant, optionally with the full lifecycle layer on.
fn c5_cap() -> (shisha::platform::Platform, f64) {
    let plat = configs::c5();
    let net = networks::synthnet();
    let config = shisha_config(&net, &plat);
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let cap = simulator::throughput(&net, &plat, &db, &config);
    (plat, cap)
}

fn storm_tenant(cap: f64, lifecycle: bool) -> TenantSpec {
    let mut spec = TenantSpec::new(
        "storm",
        networks::synthnet(),
        ArrivalProcess::Mmpp {
            low_rate: 0.25 * cap,
            high_rate: 1.1 * cap,
            mean_low_s: 100.0 / cap,
            mean_high_s: 100.0 / cap,
        },
    )
    .with_shards(2)
    .with_balancer(BalancerPolicy::JoinShortestQueue)
    .with_queue_capacity(32)
    .with_admission(AdmissionPolicy::DropOldest)
    .with_slo(500.0 / cap);
    if lifecycle {
        spec = spec
            .with_deadline(1000.0 / cap)
            .with_retry(RetryPolicy { max_attempts: 3, base_s: 5.0 / cap, cap_s: 100.0 / cap })
            .with_hedge(HedgePolicy { quantile: 0.95, min_delay_s: 20.0 / cap });
    }
    spec
}

fn assert_flow_conserved(r: &ServeReport, label: &str) {
    for t in &r.tenants {
        assert!(
            t.conserved(),
            "{label}/{}: offered {} != rejected {} + dropped {} + expired {} + cancelled {} \
             + completed {} + in-flight {}",
            t.name,
            t.offered,
            t.rejected,
            t.dropped,
            t.expired,
            t.cancelled,
            t.completed,
            t.in_flight
        );
        assert!(t.epoch_conserved(), "{label}/{}: per-epoch flow conservation", t.name);
    }
}

/// Every observable of the two reports must match exactly (the lifecycle
/// analogue of the golden-test identity check, including the new
/// counters).
fn assert_identical(a: &ServeReport, b: &ServeReport, what: &str) {
    assert_eq!(a.log_hash, b.log_hash, "{what}: log_hash");
    assert_eq!(a.event_log, b.event_log, "{what}: event log");
    assert_eq!(a.n_events, b.n_events, "{what}: event count");
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        let name = &x.name;
        assert_eq!(x.offered, y.offered, "{what}/{name}: offered");
        assert_eq!(x.rejected, y.rejected, "{what}/{name}: rejected");
        assert_eq!(x.dropped, y.dropped, "{what}/{name}: dropped");
        assert_eq!(x.expired, y.expired, "{what}/{name}: expired");
        assert_eq!(x.cancelled, y.cancelled, "{what}/{name}: cancelled");
        assert_eq!(x.retried, y.retried, "{what}/{name}: retried");
        assert_eq!(x.hedged, y.hedged, "{what}/{name}: hedged");
        assert_eq!(x.hedge_wins, y.hedge_wins, "{what}/{name}: hedge wins");
        assert_eq!(x.completed, y.completed, "{what}/{name}: completed");
        assert_eq!(x.slo_ok, y.slo_ok, "{what}/{name}: slo_ok");
        assert_eq!(x.in_flight, y.in_flight, "{what}/{name}: in_flight");
        assert_eq!(x.epochs, y.epochs, "{what}/{name}: epoch series");
    }
}

// ---------------------------------------------------------------------------
// 1. Policy-off invariance: blind vs lifecycle-off runs are byte-identical.
// ---------------------------------------------------------------------------

#[test]
fn inert_lifecycle_policies_leave_runs_byte_identical() {
    let (plat, cap) = c5_cap();
    let net = networks::synthnet();
    let config = shisha_config(&net, &plat);
    let opts = ServeOptions {
        duration_s: 300.0 / cap,
        seed: 41,
        control_epoch_s: 15.0 / cap,
        record_log: true,
        ..Default::default()
    };
    // "Blind": a spec that never heard of the lifecycle layer.
    let blind = || vec![(storm_tenant(cap, false), config.clone())];
    // "Inert": lifecycle knobs present but semantically off — an infinite
    // deadline and a zero-attempt retry schedule nothing.
    let inert = || {
        let spec = storm_tenant(cap, false)
            .with_deadline(f64::INFINITY)
            .with_retry(RetryPolicy { max_attempts: 0, ..Default::default() });
        assert!(!spec.lifecycle_active(), "∞ deadline + 0 attempts must stay inert");
        vec![(spec, config.clone())]
    };

    let a = serve(&plat, blind(), &opts).expect("blind run");
    let b = serve(&plat, inert(), &opts).expect("inert run");
    assert_identical(&a, &b, "blind vs inert");
    assert_eq!(
        a.tenants[0].expired + a.tenants[0].cancelled + a.tenants[0].retried
            + a.tenants[0].hedged,
        0,
        "no lifecycle activity without an active policy"
    );

    // The recorded traces are the same bytes, and both stay on wire v3 —
    // exactly what a pre-lifecycle build would have written.
    let (_, trace_a) = serve_traced(&plat, blind(), &opts).expect("blind record");
    let (_, trace_b) = serve_traced(&plat, inert(), &opts).expect("inert record");
    let bytes_a = trace_a.to_bytes();
    assert_eq!(bytes_a[4], 3, "policy-off recordings negotiate wire v3");
    assert_eq!(bytes_a, trace_b.to_bytes(), "trace bytes must match byte for byte");
    assert!(!trace_a.events.iter().any(|e| (9..=12).contains(&e.tag)));

    // The telemetry exports match too: no lifecycle series, no lifecycle
    // JSONL fields, identical bytes.
    let (_, obs_a) = serve_observed(&plat, blind(), &opts).expect("blind observed");
    let (_, obs_b) = serve_observed(&plat, inert(), &opts).expect("inert observed");
    assert_eq!(obs_a.prom, obs_b.prom, "Prometheus snapshots must match");
    assert_eq!(obs_a.to_jsonl(), obs_b.to_jsonl(), "JSONL exports must match");
    assert!(!obs_a.prom.contains("tag=\"expire\""), "no lifecycle series when off");
    assert!(!obs_a.to_jsonl().contains("\"expired\""), "no lifecycle JSONL fields when off");
}

// ---------------------------------------------------------------------------
// 2. Lifecycle runs are deterministic across invocations.
// ---------------------------------------------------------------------------

#[test]
fn retry_and_hedge_runs_are_bit_identical_across_invocations() {
    let (plat, cap) = c5_cap();
    let net = networks::synthnet();
    let config = shisha_config(&net, &plat);
    let opts = ServeOptions {
        duration_s: 400.0 / cap,
        seed: 61,
        control_epoch_s: 10.0 / cap,
        record_log: true,
        ..Default::default()
    };
    let tenants = || vec![(storm_tenant(cap, true), config.clone())];
    let a = serve(&plat, tenants(), &opts).expect("first lifecycle run");
    let b = serve(&plat, tenants(), &opts).expect("second lifecycle run");
    assert_identical(&a, &b, "lifecycle rerun");
    assert_flow_conserved(&a, "lifecycle rerun");
    let t = &a.tenants[0];
    assert!(
        t.retried + t.hedged > 0,
        "the storm must exercise retry or hedging (retried {}, hedged {})",
        t.retried,
        t.hedged
    );
}

// ---------------------------------------------------------------------------
// 3. Hedge racing conserves requests across chaos scripts.
// ---------------------------------------------------------------------------

#[test]
fn hedge_cancel_conserves_requests_across_chaos_seeds() {
    let (plat, cap) = c5_cap();
    let net = networks::synthnet();
    let config = shisha_config(&net, &plat);
    let duration_s = 400.0 / cap;
    for seed in [3u64, 5, 9] {
        let script = FaultScript::chaos(seed, &plat, duration_s, 4);
        script.validate(&plat).expect("chaos scripts are valid by construction");
        let opts = ServeOptions {
            duration_s,
            seed,
            control_epoch_s: 10.0 / cap,
            faults: script,
            ..Default::default()
        };
        let report = serve(&plat, vec![(storm_tenant(cap, true), config.clone())], &opts)
            .unwrap_or_else(|e| panic!("chaos seed {seed}: {e:#}"));
        assert_flow_conserved(&report, &format!("chaos seed {seed}"));
        let t = &report.tenants[0];
        // Hedge accounting: each race cancels at most one loser, and the
        // twin can only win a race it entered.
        assert!(t.cancelled <= t.hedged, "seed {seed}: cancelled {} > hedged {}", t.cancelled, t.hedged);
        assert!(t.hedge_wins <= t.hedged, "seed {seed}: wins {} > hedged {}", t.hedge_wins, t.hedged);
        // Retry re-arrivals are a subset of what was offered.
        assert!(t.retried + t.hedged <= t.offered, "seed {seed}: re-arrivals exceed offered");
        assert!(t.completed > 0, "seed {seed}: the tenant must keep serving through chaos");
    }
}

// ---------------------------------------------------------------------------
// 4. Infinite deadlines never fire; tight ones reap visibly.
// ---------------------------------------------------------------------------

#[test]
fn infinite_deadline_never_schedules_expiry() {
    let plat = configs::c1();
    let net = networks::synthnet_small();
    let config = shisha_config(&net, &plat);
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let cap = simulator::throughput(&net, &plat, &db, &config);
    let opts = ServeOptions {
        duration_s: 200.0 / cap,
        seed: 7,
        control: false,
        control_epoch_s: 20.0 / cap,
        ..Default::default()
    };
    // Overloaded tenant with retry on (so the run is lifecycle-active and
    // records on wire v4) but an infinite deadline: tag 9 must never fire.
    let mk = |deadline_s: f64| {
        vec![(
            TenantSpec::new("q", net.clone(), ArrivalProcess::Poisson { rate: 3.0 * cap })
                .with_queue_capacity(32)
                .with_slo(50.0 / cap)
                .with_deadline(deadline_s)
                .with_retry(RetryPolicy { max_attempts: 1, base_s: 5.0 / cap, cap_s: 50.0 / cap }),
            config.clone(),
        )]
    };
    let (report, trace) =
        serve_traced(&plat, mk(f64::INFINITY), &opts).expect("infinite-deadline run");
    assert!(
        !trace.events.iter().any(|e| e.tag == 9),
        "an infinite deadline must never produce a tag-9 expiry event"
    );
    assert_eq!(report.tenants[0].expired, 0);
    assert_flow_conserved(&report, "infinite deadline");

    // Control: the same overload with a deadline shorter than the queue
    // wait must reap — proving the negative above is not vacuous.
    let (tight, tight_trace) =
        serve_traced(&plat, mk(10.0 / cap), &opts).expect("tight-deadline run");
    assert!(
        tight.tenants[0].expired > 0,
        "a tight deadline under 3× overload must expire requests"
    );
    assert!(tight_trace.events.iter().any(|e| e.tag == 9));
    assert_flow_conserved(&tight, "tight deadline");
}

// ---------------------------------------------------------------------------
// 5. The acceptance storm: chaos faults with the lifecycle layer on.
// ---------------------------------------------------------------------------

#[test]
fn acceptance_storm_retains_goodput_and_replays_through_v4() {
    let (plat, cap) = c5_cap();
    let net = networks::synthnet();
    let config = shisha_config(&net, &plat);
    let duration_s = 400.0 / cap;
    let epoch_s = 10.0 / cap;
    let strongest = plat.eps_by_rank()[0];
    let stall_t = 100.0 / cap;
    let stall_d = 50.0 / cap;
    let slow_t = 200.0 / cap;
    let slow_d = 40.0 / cap;
    let base = ServeOptions {
        duration_s,
        seed: 47,
        control_epoch_s: epoch_s,
        ..Default::default()
    };
    let tenants = || vec![(storm_tenant(cap, true), config.clone())];

    let free = serve(&plat, tenants(), &base).expect("fault-free lifecycle storm");
    assert_flow_conserved(&free, "fault-free");
    let goodput_free = free.goodputs()[0];
    assert!(goodput_free > 0.0);

    let faulted_opts = ServeOptions {
        faults: FaultScript {
            events: vec![
                FaultEvent { t_s: stall_t, kind: FaultKind::EpStall { ep: strongest, down_s: stall_d } },
                FaultEvent {
                    t_s: slow_t,
                    kind: FaultKind::LinkSlow { factor: 2.0, down_s: slow_d },
                },
            ],
        },
        ..base.clone()
    };
    let (rep, trace) = serve_traced(&plat, tenants(), &faulted_opts).expect("faulted storm");
    assert_flow_conserved(&rep, "faulted");
    let t = &rep.tenants[0];
    assert!(
        t.retried + t.hedged > 0,
        "the faults must push the lifecycle layer into action \
         (retried {}, hedged {})",
        t.retried,
        t.hedged
    );

    // Headline: ≥ 95% of the fault-free goodput retained, at zero loss.
    let goodput_faulted = rep.goodputs()[0];
    assert!(
        goodput_faulted >= 0.95 * goodput_free,
        "goodput {goodput_faulted:.2} req/s fell below 95% of the fault-free \
         {goodput_free:.2} req/s"
    );

    // Recovery: once the last fault clears (plus two control epochs of
    // slack to drain the backlog), the faulted run serves at fault-free
    // goodput again. Both runs share the same epoch grid, so the
    // per-epoch series compare directly.
    let recovered_t = (slow_t + slow_d) + 2.0 * epoch_s;
    let tail = |r: &ServeReport| -> f64 {
        r.tenants[0]
            .epochs
            .iter()
            .filter(|e| e.end_s > recovered_t + 1e-9)
            .map(|e| e.goodput)
            .sum()
    };
    let (tail_faulted, tail_free) = (tail(&rep), tail(&free));
    assert!(tail_free > 0.0, "the comparison window must contain epochs");
    assert!(
        tail_faulted >= 0.95 * tail_free,
        "post-recovery goodput {tail_faulted:.2} is below 95% of fault-free {tail_free:.2} \
         — the storm did not recover within two epochs of the fault clearing"
    );

    // Determinism: a second faulted invocation reproduces the stream.
    let (rep2, _) = serve_traced(&plat, tenants(), &faulted_opts).expect("second faulted storm");
    assert_eq!(rep.log_hash, rep2.log_hash, "faulted lifecycle runs must be deterministic");
    assert_eq!(rep.n_events, rep2.n_events);

    // The whole thing records on wire v4 and replays bit-identically.
    let bytes = trace.to_bytes();
    assert_eq!(bytes[4], 4, "lifecycle recordings negotiate wire v4");
    let replayed = replay_full(&trace).expect("full replay of the faulted storm");
    assert_eq!(replayed.log_hash, rep.log_hash, "v4 replay must be bit-identical");

    // And the hedge=off counterfactual answers "what did hedging buy?"
    // over the same captured storm, still conserving every request.
    let stripped = replay_whatif(&trace, &WhatIf { hedge: Some(false), ..Default::default() })
        .expect("hedge=off what-if");
    assert_eq!(stripped.tenants[0].hedged, 0, "hedge=off must strip every hedge");
    assert!(stripped.tenants[0].completed > 0);
}
