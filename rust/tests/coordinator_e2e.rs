//! Coordinator end-to-end: the threaded pipeline runtime over real PJRT
//! artifacts — throughput measurement, backpressure, EP emulation effects
//! and live online tuning. Skipped when artifacts are absent.

use shisha::coordinator::{EpEmulation, OnlineTuner, PipelineRuntime};
use shisha::explore::shisha::{generate_seed, AssignmentChoice};
use shisha::model::networks;
use shisha::perfdb::CostModel;
use shisha::pipeline::PipelineConfig;
use shisha::platform::configs;
use shisha::runtime::Manifest;

fn runtime(emu: EpEmulation) -> Option<PipelineRuntime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    let manifest = Manifest::load(dir).unwrap();
    Some(PipelineRuntime::new(manifest, emu).unwrap())
}

#[test]
fn single_stage_pipeline_streams_all_inputs() {
    let Some(rt) = runtime(EpEmulation::none(2)) else { return };
    let cfg = PipelineConfig::single_stage(rt.n_layers(), 0);
    let run = rt.measure(&cfg, 12).unwrap();
    assert_eq!(run.n_inputs, 12);
    assert!(run.throughput > 0.0);
    assert_eq!(run.stage_times.len(), 1);
    assert!(run.stage_times[0] > 0.0);
}

#[test]
fn multi_stage_pipeline_measures_each_stage() {
    let Some(rt) = runtime(EpEmulation::none(4)) else { return };
    let cfg = PipelineConfig::new(vec![2, 2, 2], vec![0, 1, 2]);
    let run = rt.measure(&cfg, 16).unwrap();
    assert_eq!(run.stage_times.len(), 3);
    assert!(run.stage_times.iter().all(|&t| t > 0.0));
    assert!(run.throughput > 0.0);
}

#[test]
fn emulated_slow_ep_becomes_bottleneck() {
    // EP1 heavily slowed: the stage mapped to it must dominate.
    let Some(rt) = runtime(EpEmulation::explicit(vec![1.0, 8.0])) else { return };
    let cfg = PipelineConfig::new(vec![3, 3], vec![0, 1]);
    // warmup (first run pays PJRT compilation in each worker)
    let _ = rt.measure(&cfg, 4).unwrap();
    let run = rt.measure(&cfg, 24).unwrap();
    assert_eq!(run.slowest_stage(), 1, "stage times {:?}", run.stage_times);
    assert!(run.stage_times[1] > 2.0 * run.stage_times[0], "{:?}", run.stage_times);
}

#[test]
fn invalid_configs_rejected() {
    let Some(rt) = runtime(EpEmulation::none(2)) else { return };
    // wrong layer count
    assert!(rt.measure(&PipelineConfig::new(vec![3], vec![0]), 4).is_err());
    // EP outside emulation table
    assert!(rt
        .measure(&PipelineConfig::new(vec![3, 3], vec![0, 7]), 4)
        .is_err());
}

#[test]
fn online_tuner_improves_or_holds_seed() {
    let net = networks::synthnet_small();
    let plat = configs::c1();
    let emu = EpEmulation::from_model(&net, &plat, &CostModel::default());
    let Some(rt) = runtime(emu) else { return };
    let seed = generate_seed(&net, &plat, AssignmentChoice::RankW, 0);
    // warmup to amortise PJRT compilation before measuring
    let _ = rt.measure(&seed.config, 4).unwrap();
    let mut tuner = OnlineTuner::new(&rt, &plat);
    tuner.alpha = 3;
    tuner.probe_inputs = 12;
    let report = tuner.tune(seed.config).unwrap();
    assert!(!report.trials.is_empty());
    assert!(report.best_throughput >= 0.8 * report.seed_throughput(), "noise tolerance");
    for t in &report.trials {
        assert!(t.config.validate(net.len(), &plat).is_ok());
    }
}

#[test]
fn measured_inputs_flow_in_order_and_complete() {
    let Some(rt) = runtime(EpEmulation::none(4)) else { return };
    for n in [1usize, 2, 7] {
        let cfg = PipelineConfig::new(vec![4, 2], vec![0, 1]);
        let run = rt.measure(&cfg, n).unwrap();
        assert_eq!(run.n_inputs, n);
    }
}
