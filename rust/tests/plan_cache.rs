//! Plan-cache correctness acceptance tests (ISSUE 5).
//!
//! The planner fast path — memoized subset tuning, parallel candidate
//! tuning, incremental stage-time evaluation — must never change a chosen
//! plan. These tests pin that end to end:
//!
//! * warm (cache-hit) `plan_shards` / `coplan` results are **bit-identical**
//!   to cold runs across randomized platforms and networks, at any thread
//!   count;
//! * the database scale is part of the cache key: a scaled-database probe
//!   must miss (and a unit scale must not);
//! * the Shisha-tuning walk driven by the incremental
//!   [`shisha::pipeline::simulator::StageTimes`] produces the same best
//!   configuration and the same bit-exact virtual-time accounting as the
//!   evaluator reports (the per-step bit-identity of `StageTimes` itself
//!   is property-tested in `pipeline::simulator`).

use shisha::explore::partition::{tune_subset, tune_subset_scaled};
use shisha::explore::PlanCache;
use shisha::model::networks;
use shisha::platform::configs;
use shisha::serve::cluster::coplan::{coplan, coplan_with};
use shisha::serve::shard::{plan_shards, plan_shards_with};
use shisha::serve::{ArrivalProcess, TenantSpec};
use shisha::testutil::{self, same_cluster_plan, same_shard_plan};

#[test]
fn warm_plan_shards_bit_identical_to_cold_randomized() {
    // randomized platforms (2–6 EPs) and networks (4–14 layers): the
    // cached, warm and parallel searches must reproduce the plain search
    // bit-for-bit
    testutil::check("warm plan_shards == cold", 0x9A5C_AC4E, 12, |g| {
        let plat = g.platform(2, 7);
        let net = g.network(4, 15);
        let k = g.usize(1, plat.n_eps() + 1);
        let cold = plan_shards(&net, &plat, k).map_err(|e| e.to_string())?;
        let cache = PlanCache::new();
        let first = plan_shards_with(&net, &plat, k, 1, &cache).map_err(|e| e.to_string())?;
        same_shard_plan(&cold, &first)?;
        let misses = cache.stats().misses;
        // warm: every subset answered from the memo
        let warm = plan_shards_with(&net, &plat, k, 1, &cache).map_err(|e| e.to_string())?;
        same_shard_plan(&cold, &warm)?;
        if cache.stats().misses != misses {
            return Err("warm run re-tuned a memoized subset".into());
        }
        // parallel worklist over the same warm cache
        let par = plan_shards_with(&net, &plat, k, 4, &cache).map_err(|e| e.to_string())?;
        same_shard_plan(&cold, &par)
    });
}

#[test]
fn warm_coplan_bit_identical_to_cold_randomized() {
    testutil::check("warm coplan == cold", 0xC0_91A4, 6, |g| {
        let plat = g.platform(2, 6);
        let n_tenants = g.usize(1, plat.n_eps().min(3) + 1);
        let specs: Vec<TenantSpec> = (0..n_tenants)
            .map(|i| {
                let net = g.network(3, 10);
                TenantSpec::new(
                    format!("t{i}"),
                    net,
                    ArrivalProcess::Poisson { rate: 1.0 },
                )
                .with_weight(g.f64(0.5, 3.0))
                .with_shards(g.usize(1, 3))
            })
            .collect();
        let cold = coplan_with(&plat, &specs, 1, &PlanCache::new()).map_err(|e| e.to_string())?;
        let cache = PlanCache::new();
        let first = coplan_with(&plat, &specs, 2, &cache).map_err(|e| e.to_string())?;
        let misses = cache.stats().misses;
        let warm = coplan_with(&plat, &specs, 2, &cache).map_err(|e| e.to_string())?;
        same_cluster_plan(&cold, &first)?;
        same_cluster_plan(&cold, &warm)?;
        if cache.stats().misses != misses {
            return Err("warm co-plan re-tuned a memoized subset".into());
        }
        // the default entry point (own cache, core-sized pool) agrees too
        let default_run = coplan(&plat, &specs).map_err(|e| e.to_string())?;
        same_cluster_plan(&cold, &default_run)
    });
}

#[test]
fn perfdb_scaling_is_part_of_the_cache_key() {
    let net = networks::synthnet();
    let plat = configs::c5();
    let cache = PlanCache::new();
    let eps = [0usize, 4];
    let unscaled = cache.tune_subset(&net, &plat, &eps, None, 400);
    assert_eq!(cache.stats().misses, 1);

    // scaled database: must miss, must match the uncached scaled tuner
    let scale = [3.0, 1.0];
    let scaled = cache.tune_subset(&net, &plat, &eps, Some(&scale), 400);
    assert_eq!(
        cache.stats().misses,
        2,
        "a scaled database must never hit an unscaled entry"
    );
    let scaled_cold = tune_subset_scaled(&net, &plat, &eps, Some(&scale), 400);
    assert_eq!(scaled.config, scaled_cold.config);
    assert_eq!(
        scaled.predicted_throughput.to_bits(),
        scaled_cold.predicted_throughput.to_bits()
    );
    assert_ne!(
        scaled.predicted_throughput.to_bits(),
        unscaled.predicted_throughput.to_bits(),
        "crippling the FEP must change the prediction"
    );

    // re-probing either key is a pure hit
    cache.tune_subset(&net, &plat, &eps, None, 400);
    cache.tune_subset(&net, &plat, &eps, Some(&scale), 400);
    // and unit factors canonicalise onto the unscaled entry
    let unit = cache.tune_subset(&net, &plat, &eps, Some(&[1.0, 1.0]), 400);
    assert_eq!(cache.stats().hits, 3);
    assert_eq!(cache.stats().misses, 2);
    assert_eq!(unit.config, unscaled.config);
    assert_eq!(
        unit.predicted_throughput.to_bits(),
        unscaled.predicted_throughput.to_bits()
    );
}

#[test]
fn cached_subset_tuning_bit_identical_randomized() {
    // the cache's unit of work, across randomized platforms/networks and
    // both tuning paths (exhaustive for small subsets, Shisha fallback
    // for large ones)
    testutil::check("cached tune_subset == cold", 0x7A5E_754E, 15, |g| {
        let plat = g.platform(2, 8);
        let net = g.network(3, 16);
        let n = g.usize(1, plat.n_eps() + 1);
        // a deterministic-but-arbitrary subset of n EPs
        let mut eps: Vec<usize> = (0..plat.n_eps()).collect();
        g.rng().shuffle(&mut eps);
        eps.truncate(n);
        let cold = tune_subset(&net, &plat, &eps, 350);
        let cache = PlanCache::new();
        let via_cache = cache.tune_subset(&net, &plat, &eps, None, 350);
        let rehit = cache.tune_subset(&net, &plat, &eps, None, 350);
        for (what, plan) in [("miss", &via_cache), ("hit", &rehit)] {
            if plan.config != cold.config {
                return Err(format!("{what}: config diverged for subset {eps:?}"));
            }
            if plan.predicted_throughput.to_bits() != cold.predicted_throughput.to_bits() {
                return Err(format!("{what}: predicted bits diverged for subset {eps:?}"));
            }
            if plan.exhaustive != cold.exhaustive {
                return Err(format!("{what}: tuning path diverged for subset {eps:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn shisha_tuning_walk_unchanged_by_incremental_evaluation() {
    // tune() now walks on incremental StageTimes; the evaluator's virtual
    // clock, trial count and best configuration must be exactly what the
    // pre-fast-path implementation produced. The C5/SynthNet numbers here
    // double as a fixed reference: identical across the full-recompute
    // and incremental paths because both feed the evaluator bit-identical
    // throughput/latency/bottleneck values.
    use shisha::explore::shisha::{generate_seed, AssignmentChoice, BalancingChoice};
    use shisha::explore::Evaluator;
    use shisha::perfdb::{CostModel, PerfDb};
    use shisha::pipeline::simulator;

    let net = networks::synthnet();
    let plat = configs::c5();
    let db = PerfDb::build(&net, &plat, &CostModel::default());
    let seed = generate_seed(&net, &plat, AssignmentChoice::RankW, 0);

    let mut eval = Evaluator::new(&net, &plat, &db);
    let walked = shisha::explore::shisha::tune(
        &mut eval,
        seed.config.clone(),
        BalancingChoice::NlFep,
        10,
    );
    let (best_cfg, best_tp) = eval.best().expect("tuned").clone();

    // the walked and best configurations are honest evaluations
    assert!(walked.validate(net.len(), &plat).is_ok());
    assert!(best_cfg.validate(net.len(), &plat).is_ok());
    assert_eq!(
        best_tp.to_bits(),
        simulator::throughput(&net, &plat, &db, &best_cfg).to_bits(),
        "reported best must be the full recompute of the best config"
    );
    let seed_tp = simulator::throughput(&net, &plat, &db, &seed.config);
    assert!(best_tp >= seed_tp);

    // two runs remain bit-deterministic
    let mut eval2 = Evaluator::new(&net, &plat, &db);
    let walked2 =
        shisha::explore::shisha::tune(&mut eval2, seed.config, BalancingChoice::NlFep, 10);
    assert_eq!(walked, walked2);
    assert_eq!(eval.n_evals(), eval2.n_evals());
    assert_eq!(
        eval.virtual_time_s().to_bits(),
        eval2.virtual_time_s().to_bits()
    );
}
