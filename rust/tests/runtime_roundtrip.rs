//! Round-trip integration: the AOT artifacts (Pallas conv layers lowered
//! through JAX to HLO text) must load, compile and compute **correct
//! numbers** through the rust PJRT runtime.
//!
//! Correctness oracle: a naive rust convolution implemented here from the
//! manifest geometry — an independent third implementation (after the
//! Pallas kernel and the jnp reference), so agreement means the whole
//! python→HLO→rust path preserves semantics.
//!
//! These tests require `make artifacts` **and** the `pjrt` cargo feature
//! (the whole file is compiled out otherwise); they are additionally
//! skipped (with a notice) when the artifact directory is absent so
//! `cargo test --features pjrt` stays green on a fresh checkout.
#![cfg(feature = "pjrt")]

use shisha::model::networks;
use shisha::runtime::{synth_params, ArtifactKind, Manifest, Runtime};

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(Manifest::load(dir).expect("manifest parses"))
}

/// Naive conv+bias+ReLU oracle: x (H,W,C), w (R,S,C,K) -> (OH,OW,K).
#[allow(clippy::too_many_arguments)]
fn naive_conv(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    h: usize,
    wd: usize,
    c: usize,
    r: usize,
    s: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> Vec<f32> {
    let oh = (h + 2 * pad - r) / stride + 1;
    let ow = (wd + 2 * pad - s) / stride + 1;
    let mut out = vec![0f32; oh * ow * k];
    for oy in 0..oh {
        for ox in 0..ow {
            for kk in 0..k {
                let mut acc = b[kk];
                for rr in 0..r {
                    for ss in 0..s {
                        let iy = oy * stride + rr;
                        let ix = ox * stride + ss;
                        if iy < pad || ix < pad {
                            continue;
                        }
                        let (iy, ix) = (iy - pad, ix - pad);
                        if iy >= h || ix >= wd {
                            continue;
                        }
                        for cc in 0..c {
                            acc += x[(iy * wd + ix) * c + cc]
                                * w[((rr * s + ss) * c + cc) * k + kk];
                        }
                    }
                }
                out[(oy * ow + ox) * k + kk] = acc.max(0.0);
            }
        }
    }
    out
}

fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = shisha::rng::Xoshiro256::seed_from(seed);
    (0..n).map(|_| (rng.gen_f64() * 2.0 - 1.0) as f32).collect()
}

#[test]
fn manifest_matches_rust_layer_table() {
    let Some(m) = manifest() else { return };
    m.check_against(&networks::synthnet_small()).expect("no drift");
    assert_eq!(m.network, "synthnet_small");
    assert_eq!(m.layers, 6);
}

#[test]
fn every_layer_artifact_computes_correct_numbers() {
    let Some(m) = manifest() else { return };
    let mut rt = Runtime::new().unwrap();
    for meta in m.layer_artifacts() {
        rt.load(&m, &meta.name).unwrap();
        let (h, wd, c) = (meta.in_shape[0] as usize, meta.in_shape[1] as usize, meta.in_shape[2] as usize);
        let ws = meta.w_shape.as_ref().unwrap();
        let (r, s, k) = (ws[0] as usize, ws[1] as usize, ws[3] as usize);
        let stride = meta.stride.unwrap() as usize;
        let pad = meta.pad.unwrap() as usize;

        let x = rand_vec(h * wd * c, 42 + meta.index as u64);
        let w = rand_vec(r * s * c * k, 77 + meta.index as u64);
        let b = rand_vec(k, 99 + meta.index as u64);

        let got = rt.execute_layer(&meta.name, &x, &w, &b).unwrap();
        let want = naive_conv(&x, &w, &b, h, wd, c, r, s, k, stride, pad);
        assert_eq!(got.len(), want.len(), "{}", meta.name);
        let mut max_err = 0f32;
        for (g, e) in got.iter().zip(&want) {
            max_err = max_err.max((g - e).abs());
        }
        assert!(max_err < 1e-3, "{}: max abs err {max_err}", meta.name);
    }
}

#[test]
fn fused_network_artifact_matches_layer_chain() {
    let Some(m) = manifest() else { return };
    let mut rt = Runtime::new().unwrap();
    rt.load_all(&m).unwrap();

    // chain per-layer execution
    let layers = m.layer_artifacts();
    let first = layers[0];
    let mut x = rand_vec(first.in_elems(), 7);
    let input = x.clone();
    let mut params: Vec<(Vec<f32>, Vec<i64>)> = Vec::new();
    for meta in &layers {
        let (w, b) = synth_params(meta, 1000 + meta.index as u64).unwrap();
        x = rt.execute_layer(&meta.name, &x, &w, &b).unwrap();
        params.push((w.clone(), meta.w_shape.clone().unwrap()));
        params.push((b.clone(), vec![meta.bias.unwrap()]));
    }

    // fused artifact with identical params
    let fused = rt.execute_stage("net_synthnet_small", &input, &params).unwrap();
    assert_eq!(fused.len(), x.len());
    let max_err = fused
        .iter()
        .zip(&x)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-3, "fused vs chained max err {max_err}");
}

#[test]
fn gemm_probe_computes_matmul() {
    let Some(m) = manifest() else { return };
    let meta = m.get("gemm_probe").expect("probe artifact");
    assert_eq!(meta.kind, ArtifactKind::Gemm);
    let mut rt = Runtime::new().unwrap();
    rt.load(&m, "gemm_probe").unwrap();
    // 256x256 @ 256x256: check a few entries against a naive dot product
    let n = 256usize;
    let a = rand_vec(n * n, 5);
    let b = rand_vec(n * n, 6);
    let got = rt.execute_raw("gemm_probe", &[(&a, &[n as i64, n as i64]), (&b, &[n as i64, n as i64])]).unwrap();
    assert_eq!(got.len(), n * n);
    let mut rng = shisha::rng::Xoshiro256::seed_from(9);
    for _ in 0..20 {
        let i = rng.gen_range(0, n);
        let j = rng.gen_range(0, n);
        let want: f32 = (0..n).map(|t| a[i * n + t] * b[t * n + j]).sum();
        let g = got[i * n + j];
        assert!((g - want).abs() < 1e-2 * (1.0 + want.abs()), "({i},{j}): {g} vs {want}");
    }
}

#[test]
fn execute_layer_rejects_wrong_input_size() {
    let Some(m) = manifest() else { return };
    let mut rt = Runtime::new().unwrap();
    rt.load(&m, "conv_s0").unwrap();
    let bad = vec![0f32; 10];
    let (w, b) = synth_params(rt.meta("conv_s0").unwrap(), 0).unwrap();
    assert!(rt.execute_layer("conv_s0", &bad, &w, &b).is_err());
}

#[test]
fn deterministic_across_executions_and_runtimes() {
    let Some(m) = manifest() else { return };
    let meta = m.get("conv_s2").unwrap().clone();
    let x = rand_vec(meta.in_elems(), 3);
    let (w, b) = synth_params(&meta, 4).unwrap();
    let mut rt1 = Runtime::new().unwrap();
    rt1.load(&m, "conv_s2").unwrap();
    let y1 = rt1.execute_layer("conv_s2", &x, &w, &b).unwrap();
    let y2 = rt1.execute_layer("conv_s2", &x, &w, &b).unwrap();
    let mut rt2 = Runtime::new().unwrap();
    rt2.load(&m, "conv_s2").unwrap();
    let y3 = rt2.execute_layer("conv_s2", &x, &w, &b).unwrap();
    assert_eq!(y1, y2, "same runtime deterministic");
    assert_eq!(y1, y3, "fresh runtime deterministic");
}
