//! Property test for `serve::sweep::run_sweep`: outcomes are **invariant
//! to the worker-thread count** on randomized scenario grids.
//!
//! PR 2's docs claim thread-count invariance (every scenario owns its RNG
//! stream and report slot); until now only two fixed grids asserted it.
//! Here randomized grids — mixed networks, tenant counts, load factors,
//! seeds, control on/off, and shard budgets — run once on one thread and
//! once on all available threads, and every observable of every outcome
//! must match bit-for-bit.

use shisha::perfdb::{CostModel, PerfDb};
use shisha::pipeline::{simulator, PipelineConfig};
use shisha::platform::configs;
use shisha::serve::sweep::{available_threads, run_sweep};
use shisha::serve::{
    ArrivalProcess, BalancerPolicy, Scenario, ServeOptions, TenantSpec,
};
use shisha::testutil;

/// Build a randomized scenario grid (2–4 cells) from the generator.
fn random_grid(g: &mut testutil::Gen) -> Vec<Scenario> {
    let n_cells = g.usize(2, 5);
    let mut cells = Vec::with_capacity(n_cells);
    for c in 0..n_cells {
        // small fixtures keep the property fast; both platforms exercise
        // multi-EP contention
        let (plat, net, cfg) = if g.usize(0, 2) == 0 {
            (
                configs::c1(),
                shisha::model::networks::synthnet_small(),
                PipelineConfig::new(vec![3, 3], vec![0, 1]),
            )
        } else {
            (
                configs::c2(),
                shisha::model::networks::synthnet_small(),
                PipelineConfig::new(vec![2, 4], vec![0, 2]),
            )
        };
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        let rho = g.f64(0.2, 2.0);
        let n_tenants = g.usize(1, 3);
        let control = g.usize(0, 2) == 0;
        let shards = if g.usize(0, 3) == 0 { 2 } else { 1 };
        let tenants = (0..n_tenants)
            .map(|i| {
                let spec = TenantSpec::new(
                    format!("c{c}t{i}"),
                    net.clone(),
                    ArrivalProcess::Poisson { rate: rho * cap / n_tenants as f64 },
                )
                .with_queue_capacity(g.usize(4, 32))
                .with_slo(g.f64(10.0, 80.0) / cap)
                .with_shards(if i == 0 { shards } else { 1 })
                .with_balancer(BalancerPolicy::JoinShortestQueue);
                (spec, cfg.clone())
            })
            .collect();
        let duration_s = g.f64(40.0, 120.0) / cap;
        cells.push(Scenario {
            name: format!("cell{c}"),
            plat,
            tenants,
            opts: ServeOptions {
                duration_s,
                seed: g.usize(1, 1 << 20) as u64,
                control,
                control_epoch_s: if control { duration_s / 5.0 } else { 0.0 },
                record_log: true,
                ..Default::default()
            },
        });
    }
    cells
}

#[test]
fn run_sweep_outcomes_invariant_to_thread_count_property() {
    let threads = available_threads();
    testutil::check("sweep thread invariance", 0x5EED_5117, 6, |g| {
        let grid = random_grid(g);
        let a = run_sweep(grid.clone(), 1);
        let b = run_sweep(grid, threads);
        if a.len() != b.len() {
            return Err(format!("outcome counts differ: {} vs {}", a.len(), b.len()));
        }
        for (x, y) in a.iter().zip(&b) {
            if x.name != y.name {
                return Err(format!("order diverged: {} vs {}", x.name, y.name));
            }
            let rx = x.report.as_ref().map_err(|e| format!("{}: {e:#}", x.name))?;
            let ry = y.report.as_ref().map_err(|e| format!("{}: {e:#}", y.name))?;
            if rx.log_hash != ry.log_hash {
                return Err(format!("{}: log_hash diverged across thread counts", x.name));
            }
            if rx.event_log != ry.event_log {
                return Err(format!("{}: event log diverged", x.name));
            }
            if rx.n_events != ry.n_events {
                return Err(format!("{}: event count diverged", x.name));
            }
            for (tx, ty) in rx.tenants.iter().zip(&ry.tenants) {
                if tx.offered != ty.offered
                    || tx.completed != ty.completed
                    || tx.slo_ok != ty.slo_ok
                    || tx.rejected != ty.rejected
                    || tx.dropped != ty.dropped
                    || tx.retunes != ty.retunes
                    || tx.final_config != ty.final_config
                    || tx.latency.p99().to_bits() != ty.latency.p99().to_bits()
                {
                    return Err(format!("{}/{}: tenant report diverged", x.name, tx.name));
                }
                if !tx.conserved() {
                    return Err(format!("{}/{}: conservation violated", x.name, tx.name));
                }
            }
        }
        Ok(())
    });
}
