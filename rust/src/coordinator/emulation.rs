//! EP heterogeneity emulation for the real-execution path.
//!
//! The paper's EPs differ in core count/type and memory bandwidth; our host
//! is one homogeneous CPU. To exercise the *scheduling* problem unchanged,
//! each EP gets a service-rate factor ≥ 1 derived from the analytic cost
//! model: factor = (EP's serial network time) / (fastest EP's serial
//! network time). A worker that computes a layer in `t` seconds then busy
//! waits `t · (factor − 1)`, so the relative stage times across EPs match
//! the modelled platform — which is all Shisha observes.

use crate::model::Network;
use crate::perfdb::{CostModel, PerfDb};
use crate::platform::Platform;

/// Per-EP service-rate slowdown factors (1.0 = fastest EP).
#[derive(Debug, Clone)]
pub struct EpEmulation {
    /// factor[ep] ≥ 1.0.
    pub factors: Vec<f64>,
}

impl EpEmulation {
    /// No emulation: every EP at native speed.
    pub fn none(n_eps: usize) -> Self {
        Self { factors: vec![1.0; n_eps] }
    }

    /// Derive factors from the analytic model for `net` on `plat`.
    pub fn from_model(net: &Network, plat: &Platform, model: &CostModel) -> Self {
        let db = PerfDb::build(net, plat, model);
        let times: Vec<f64> = (0..plat.n_eps()).map(|ep| db.network_time(ep)).collect();
        let fastest = times.iter().cloned().fold(f64::INFINITY, f64::min);
        Self { factors: times.iter().map(|t| t / fastest).collect() }
    }

    /// Explicit factors (tests, what-if studies).
    pub fn explicit(factors: Vec<f64>) -> Self {
        assert!(factors.iter().all(|&f| f >= 1.0), "factors must be >= 1");
        Self { factors }
    }

    /// Busy-wait so that total service time becomes `compute_s · factor`.
    /// Busy-waiting (not sleeping) keeps timing accurate at sub-millisecond
    /// service times.
    pub fn pad(&self, ep: usize, compute_s: f64) {
        let extra = compute_s * (self.factors[ep] - 1.0);
        if extra <= 0.0 {
            return;
        }
        let t0 = std::time::Instant::now();
        while t0.elapsed().as_secs_f64() < extra {
            std::hint::spin_loop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::networks;
    use crate::platform::configs;

    #[test]
    fn factors_reflect_heterogeneity() {
        let net = networks::synthnet_small();
        let plat = configs::c2();
        let emu = EpEmulation::from_model(&net, &plat, &CostModel::default());
        assert_eq!(emu.factors.len(), 4);
        // fastest EP factor 1.0
        let min = emu.factors.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((min - 1.0).abs() < 1e-12);
        // SEPs (ids 2,3) slower than FEPs (0,1)
        assert!(emu.factors[2] > emu.factors[0]);
        assert!(emu.factors[3] > emu.factors[1]);
        // big:little compute is 4x; with memory effects expect 2..8x
        assert!((1.5..10.0).contains(&emu.factors[2]), "factor {}", emu.factors[2]);
    }

    #[test]
    fn pad_extends_service_time() {
        let emu = EpEmulation::explicit(vec![1.0, 3.0]);
        let t0 = std::time::Instant::now();
        emu.pad(1, 0.005); // 5ms compute -> +10ms padding
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt >= 0.009, "padded {dt}");
        let t1 = std::time::Instant::now();
        emu.pad(0, 0.005); // factor 1: no padding
        assert!(t1.elapsed().as_secs_f64() < 0.002);
    }

    #[test]
    #[should_panic]
    fn explicit_rejects_sub_unity() {
        EpEmulation::explicit(vec![0.5]);
    }
}
