//! Adaptive re-tuning under environment drift (the paper's stated reason
//! for *online* tuning: cost models "are sensitive to changes in the
//! execution environment (e.g., DVFS)", §1).
//!
//! Two drift sources feed this controller:
//!
//! * **DVFS-style events** — [`DriftEvent`] rescales an EP's service rate
//!   directly, driven by [`AdaptiveController::run`]'s epoch loop;
//! * **arrival-rate drift** — the serving engine
//!   ([`crate::serve::engine`]) observes per-EP slowdowns and SLO-goodput
//!   regressions under live traffic (load surges, cross-tenant
//!   contention) and calls [`AdaptiveController::warm_retune`] with the
//!   observed database.
//!
//! Either way, when throughput regresses below
//! `retune_threshold × baseline` the controller re-runs Algorithm 2
//! **warm** (from the current configuration, not from a fresh seed), plus
//! a local reassign/swap pass for the bottleneck stage so the walk can
//! escape a drifted or contended EP — the cheap recovery the online
//! design enables. The simulated clock charges monitoring epochs and
//! every re-tuning trial, so recovery cost is measurable.

use crate::explore::shisha::{tune, BalancingChoice};
use crate::explore::{EvalOptions, Evaluator};
use crate::model::Network;
use crate::perfdb::{CostModel, PerfDb};
use crate::pipeline::{simulator, PipelineConfig};
use crate::platform::Platform;

/// An environment change at a point in (epoch) time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftEvent {
    /// Epoch at which the change takes effect.
    pub epoch: usize,
    /// EP whose service rate changes.
    pub ep: usize,
    /// Multiplier on that EP's layer times (2.0 = halved clock).
    pub slowdown: f64,
}

/// One epoch record of the adaptive run.
#[derive(Debug, Clone)]
pub struct EpochLog {
    /// Epoch index.
    pub epoch: usize,
    /// Configuration in service during the epoch.
    pub config: PipelineConfig,
    /// Observed throughput.
    pub throughput: f64,
    /// Whether a re-tune was triggered this epoch.
    pub retuned: bool,
    /// Trials spent re-tuning this epoch.
    pub retune_trials: u64,
}

/// Outcome of an adaptive run.
#[derive(Debug, Clone)]
pub struct AdaptiveReport {
    /// Per-epoch log.
    pub epochs: Vec<EpochLog>,
    /// Number of re-tunes triggered.
    pub n_retunes: usize,
    /// Total re-tuning trials.
    pub total_trials: u64,
}

impl AdaptiveReport {
    /// Throughput of the final epoch.
    pub fn final_throughput(&self) -> f64 {
        self.epochs.last().map_or(0.0, |e| e.throughput)
    }
}

/// Monitors a running pipeline and re-tunes on drift.
pub struct AdaptiveController {
    net: Network,
    plat: Platform,
    model: CostModel,
    /// Re-tune when throughput falls below this fraction of the rolling
    /// baseline (default 0.9).
    pub retune_threshold: f64,
    /// α for warm re-tuning (smaller than the cold α: we start near-optimal).
    pub alpha: u32,
    /// Balancing choice for re-tuning.
    pub balancing: BalancingChoice,
}

impl AdaptiveController {
    /// New controller with default thresholds.
    pub fn new(net: Network, plat: Platform, model: CostModel) -> Self {
        Self {
            net,
            plat,
            model,
            retune_threshold: 0.9,
            alpha: 5,
            balancing: BalancingChoice::NlFep,
        }
    }

    /// Warm re-tune `current` against an (observed or drifted) database:
    /// Algorithm 2 from the current configuration, then a local
    /// reassign/swap pass for the bottleneck stage so the tuner can move
    /// off an EP whose observed service rate collapsed (DVFS, or
    /// cross-tenant contention measured by the serving engine). Returns
    /// the best configuration found — `current` itself when nothing
    /// better — and the number of online trials charged.
    pub fn warm_retune(&self, db: &PerfDb, current: PipelineConfig) -> (PipelineConfig, u64) {
        let opts = EvalOptions { max_evals: Some(200), ..Default::default() };
        let mut eval = Evaluator::with_options(&self.net, &self.plat, db, opts);
        tune(&mut eval, current.clone(), self.balancing, self.alpha);
        let walked = eval.best().expect("tune evaluates at least once").0.clone();
        // escape pass: try every reassignment of the bottleneck stage to a
        // free EP, and every EP swap with another stage
        let slow = simulator::slowest_stage(&self.net, &self.plat, db, &walked);
        let mut candidates = Vec::new();
        for ep in 0..self.plat.n_eps() {
            if let Some(c) = walked.reassign(slow, ep) {
                candidates.push(c);
            }
        }
        for other in 0..walked.n_stages() {
            if other != slow {
                if let Some(c) = walked.swap_eps(slow, other) {
                    candidates.push(c);
                }
            }
        }
        for c in candidates {
            eval.evaluate(&c);
        }
        let (best, best_tp) = eval.best().expect("evaluated above").clone();
        let current_tp = simulator::throughput(&self.net, &self.plat, db, &current);
        let trials = eval.n_evals();
        if best_tp > current_tp {
            (best, trials)
        } else {
            (current, trials)
        }
    }

    /// Run `epochs` monitoring epochs starting from `initial`, applying
    /// `events` as they come due. Returns the per-epoch log.
    pub fn run(
        &self,
        initial: PipelineConfig,
        epochs: usize,
        events: &[DriftEvent],
    ) -> AdaptiveReport {
        let mut db = PerfDb::build(&self.net, &self.plat, &self.model);
        let mut conf = initial;
        let mut baseline = simulator::throughput(&self.net, &self.plat, &db, &conf);
        let mut log = Vec::with_capacity(epochs);
        let mut n_retunes = 0;
        let mut total_trials = 0;

        for epoch in 0..epochs {
            // apply due drift events to the environment
            for ev in events.iter().filter(|e| e.epoch == epoch) {
                db.scale_ep(ev.ep, ev.slowdown);
            }
            // observe the running configuration
            let observed = simulator::throughput(&self.net, &self.plat, &db, &conf);
            let mut retuned = false;
            let mut trials = 0;
            if observed < self.retune_threshold * baseline {
                // warm re-tune from the current configuration
                let (best, n) = self.warm_retune(&db, conf.clone());
                trials = n;
                conf = best;
                baseline = simulator::throughput(&self.net, &self.plat, &db, &conf);
                retuned = true;
                n_retunes += 1;
                total_trials += trials;
            } else {
                baseline = baseline.max(observed);
            }
            log.push(EpochLog {
                epoch,
                config: conf.clone(),
                throughput: simulator::throughput(&self.net, &self.plat, &db, &conf),
                retuned,
                retune_trials: trials,
            });
        }
        AdaptiveReport { epochs: log, n_retunes, total_trials }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::shisha::{generate_seed, AssignmentChoice};
    use crate::model::networks;
    use crate::platform::configs;

    fn controller() -> (AdaptiveController, PipelineConfig) {
        let net = networks::synthnet();
        let plat = configs::c2();
        let model = CostModel::default();
        let seed = generate_seed(&net, &plat, AssignmentChoice::RankW, 0);
        let db = PerfDb::build(&net, &plat, &model);
        // tune once to a good starting configuration
        let mut eval = Evaluator::new(&net, &plat, &db);
        tune(&mut eval, seed.config, BalancingChoice::NlFep, 10);
        let start = eval.best().unwrap().0.clone();
        (AdaptiveController::new(net, plat, model), start)
    }

    #[test]
    fn no_drift_no_retune() {
        let (ctl, start) = controller();
        let report = ctl.run(start, 10, &[]);
        assert_eq!(report.n_retunes, 0);
        assert_eq!(report.epochs.len(), 10);
        let t0 = report.epochs[0].throughput;
        assert!(report.epochs.iter().all(|e| (e.throughput - t0).abs() < 1e-12));
    }

    #[test]
    fn drift_triggers_retune_and_recovers() {
        let (ctl, start) = controller();
        // find the EP hosting the biggest stage and cripple it 3x at epoch 3
        let victim = start.assignment[0];
        let events = [DriftEvent { epoch: 3, ep: victim, slowdown: 3.0 }];
        let report = ctl.run(start.clone(), 12, &events);
        assert!(report.n_retunes >= 1, "drift must trigger re-tuning");
        // throughput after recovery must beat the un-tuned drifted config
        let mut db = PerfDb::build(&ctl.net, &ctl.plat, &ctl.model);
        db.scale_ep(victim, 3.0);
        let untuned = simulator::throughput(&ctl.net, &ctl.plat, &db, &start);
        assert!(
            report.final_throughput() > untuned,
            "recovered {} must beat untuned {untuned}",
            report.final_throughput()
        );
    }

    #[test]
    fn small_drift_below_threshold_ignored() {
        let (mut ctl, start) = controller();
        ctl.retune_threshold = 0.5; // tolerate up to 2x loss
        let victim = start.assignment[0];
        let events = [DriftEvent { epoch: 2, ep: victim, slowdown: 1.05 }];
        let report = ctl.run(start, 6, &events);
        assert_eq!(report.n_retunes, 0);
    }

    #[test]
    fn repeated_drift_multiple_retunes() {
        let (ctl, start) = controller();
        let a = start.assignment[0];
        let b = *start.assignment.last().unwrap();
        let events = [
            DriftEvent { epoch: 2, ep: a, slowdown: 2.5 },
            DriftEvent { epoch: 6, ep: b, slowdown: 2.5 },
        ];
        let report = ctl.run(start, 10, &events);
        assert!(report.n_retunes >= 2, "got {}", report.n_retunes);
        assert!(report.total_trials > 0);
    }

    #[test]
    fn warm_retune_is_cheap() {
        // recovery should take far fewer trials than a cold Shisha run's
        // full auto sweep (the point of warm-starting from the running cfg)
        let (ctl, start) = controller();
        let victim = start.assignment[0];
        let events = [DriftEvent { epoch: 1, ep: victim, slowdown: 3.0 }];
        let report = ctl.run(start, 5, &events);
        let per_retune = report.total_trials as f64 / report.n_retunes.max(1) as f64;
        assert!(per_retune <= 60.0, "warm retune used {per_retune} trials");
    }
}
