//! The threaded pipeline runtime.
//!
//! [`PipelineRuntime::measure`] materialises a [`PipelineConfig`] as real
//! OS threads — one worker per stage — streams `n` inputs through it and
//! reports measured throughput and per-stage service times:
//!
//! ```text
//!  feeder ──ch0──▶ [stage 0 worker] ──ch1──▶ [stage 1 worker] ──▶ sink
//!                   own PJRT runtime          own PJRT runtime
//!                   layers lo0..hi0           layers lo1..hi1
//!                   EP emulation pad          EP emulation pad
//! ```
//!
//! Channels are bounded (`CHANNEL_DEPTH`) so a slow stage backpressures
//! upstream instead of queueing unboundedly — the steady-state behaviour
//! the paper's throughput model (1 / max stage time) assumes.

use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::emulation::EpEmulation;
use crate::pipeline::PipelineConfig;
use crate::runtime::{synth_params, Manifest, Runtime};

/// Bounded channel depth between stages (small: backpressure, not queueing).
pub const CHANNEL_DEPTH: usize = 4;

/// Measured result of streaming `n_inputs` through one configuration.
#[derive(Debug, Clone)]
pub struct MeasuredRun {
    /// The measured configuration.
    pub config: PipelineConfig,
    /// Inputs streamed.
    pub n_inputs: usize,
    /// Steady-state throughput, images/s (first output excluded — fill).
    pub throughput: f64,
    /// Mean service time per stage, seconds (compute + emulation pad).
    pub stage_times: Vec<f64>,
    /// Wall-clock of the whole run (including pipeline fill), seconds.
    pub wall_s: f64,
}

impl MeasuredRun {
    /// Index of the slowest stage (Algorithm 2 line 5, measured online).
    pub fn slowest_stage(&self) -> usize {
        self.stage_times
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    }
}

/// Factory for measured pipeline runs over the AOT artifacts.
pub struct PipelineRuntime {
    manifest: Manifest,
    emulation: EpEmulation,
    /// Layer artifact names in network order.
    layer_names: Vec<String>,
    /// Parameter seed (synth weights are deterministic per layer).
    pub param_seed: u64,
}

impl PipelineRuntime {
    /// Create from a loaded manifest and EP emulation table.
    pub fn new(manifest: Manifest, emulation: EpEmulation) -> Result<Self> {
        let layer_names: Vec<String> =
            manifest.layer_artifacts().iter().map(|a| a.name.clone()).collect();
        if layer_names.is_empty() {
            bail!("manifest has no layer artifacts");
        }
        Ok(Self { manifest, emulation, layer_names, param_seed: 0xC0DE })
    }

    /// Number of layers available for pipelining.
    pub fn n_layers(&self) -> usize {
        self.layer_names.len()
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Deterministic input image `i` for the first layer (f32 in [-1, 1]).
    pub fn make_input(&self, i: u64) -> Vec<f32> {
        let meta = self.manifest.get(&self.layer_names[0]).unwrap();
        let n = meta.in_elems();
        let mut rng = crate::rng::Xoshiro256::seed_from(0x1317 + i);
        (0..n).map(|_| (rng.gen_f64() * 2.0 - 1.0) as f32).collect()
    }

    /// Run `cfg` with `n_inputs` streamed inputs; returns measurements.
    ///
    /// Validation: `cfg` must partition exactly the manifest's layers and
    /// reference EPs covered by the emulation table.
    pub fn measure(&self, cfg: &PipelineConfig, n_inputs: usize) -> Result<MeasuredRun> {
        if cfg.n_layers() != self.layer_names.len() {
            bail!("config covers {} layers, artifacts have {}", cfg.n_layers(), self.layer_names.len());
        }
        for &ep in &cfg.assignment {
            if ep >= self.emulation.factors.len() {
                bail!("EP {ep} outside emulation table");
            }
        }
        let n_stages = cfg.n_stages();
        let bounds = cfg.stage_bounds();

        // channels: feeder -> s0 -> s1 ... -> sink
        let mut senders: Vec<mpsc::SyncSender<Vec<f32>>> = Vec::with_capacity(n_stages + 1);
        let mut receivers: Vec<mpsc::Receiver<Vec<f32>>> = Vec::with_capacity(n_stages + 1);
        for _ in 0..=n_stages {
            let (tx, rx) = mpsc::sync_channel::<Vec<f32>>(CHANNEL_DEPTH);
            senders.push(tx);
            receivers.push(rx);
        }

        let t0 = Instant::now();
        let result: Result<(Vec<f64>, f64, usize)> = thread::scope(|scope| {
            // stage workers (consume receivers[i], produce into senders[i+1])
            let mut stage_handles = Vec::with_capacity(n_stages);
            let mut rx_iter = receivers.into_iter();
            let first_rx = rx_iter.next().unwrap();
            let mut rxs: Vec<mpsc::Receiver<Vec<f32>>> = rx_iter.collect(); // n_stages receivers
            // senders[0] feeds stage 0; worker i sends into senders[i+1]
            let mut txs: Vec<mpsc::SyncSender<Vec<f32>>> = senders.split_off(1);
            let feeder_tx = senders.pop().unwrap();
            let sink_rx = rxs.pop().unwrap(); // last receiver is the sink's
            rxs.insert(0, first_rx);
            let sink_tx = txs.pop(); // worker of last stage sends here
            txs.push(sink_tx.context("stage sender")?);

            for (si, (rx, tx)) in rxs.into_iter().zip(txs.into_iter()).enumerate() {
                let (lo, hi) = bounds[si];
                let names: Vec<String> = self.layer_names[lo..hi].to_vec();
                let ep = cfg.assignment[si];
                let emu = self.emulation.clone();
                let manifest = &self.manifest;
                let seed = self.param_seed;
                stage_handles.push(scope.spawn(move || -> Result<f64> {
                    // per-thread PJRT runtime with only this stage's layers
                    let mut rt = Runtime::new()?;
                    let mut params = Vec::with_capacity(names.len());
                    for (li, name) in names.iter().enumerate() {
                        rt.load(manifest, name)?;
                        let meta = rt.meta(name).unwrap();
                        params.push(synth_params(meta, seed + (lo + li) as u64)?);
                    }
                    let mut busy = 0.0f64;
                    let mut count = 0u64;
                    while let Ok(mut x) = rx.recv() {
                        let t = Instant::now();
                        for (name, (w, b)) in names.iter().zip(&params) {
                            x = rt.execute_layer(name, &x, w, b)?;
                        }
                        let compute = t.elapsed().as_secs_f64();
                        emu.pad(ep, compute);
                        busy += t.elapsed().as_secs_f64();
                        count += 1;
                        if tx.send(x).is_err() {
                            break; // sink gone
                        }
                    }
                    Ok(if count > 0 { busy / count as f64 } else { 0.0 })
                }));
            }

            // feeder
            let feeder = scope.spawn(move || {
                for i in 0..n_inputs {
                    let x = self.make_input(i as u64);
                    if feeder_tx.send(x).is_err() {
                        break;
                    }
                }
                // dropping feeder_tx closes the pipeline
            });

            // sink: timestamps
            let mut first: Option<Instant> = None;
            let mut last: Option<Instant> = None;
            let mut n_out = 0usize;
            while let Ok(_y) = sink_rx.recv() {
                let now = Instant::now();
                if first.is_none() {
                    first = Some(now);
                }
                last = Some(now);
                n_out += 1;
            }
            feeder.join().expect("feeder panicked");
            let mut stage_times = Vec::with_capacity(n_stages);
            for h in stage_handles {
                stage_times.push(h.join().expect("stage worker panicked")?);
            }
            let throughput = match (first, last) {
                (Some(f), Some(l)) if n_out > 1 => (n_out - 1) as f64 / (l - f).as_secs_f64(),
                _ => 0.0,
            };
            Ok((stage_times, throughput, n_out))
        });
        let (stage_times, throughput, n_out) = result?;
        if n_out != n_inputs {
            bail!("pipeline dropped inputs: {n_out}/{n_inputs}");
        }
        Ok(MeasuredRun {
            config: cfg.clone(),
            n_inputs,
            throughput,
            stage_times,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }
}

// Integration tests that need real artifacts live in
// rust/tests/coordinator_e2e.rs (after `make artifacts`).
