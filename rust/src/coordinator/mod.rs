//! The L3 coordinator: a *real* threaded CNN pipeline runtime with online
//! Shisha tuning.
//!
//! Where `explore::Evaluator` answers "what would this configuration do"
//! from the perf database (the paper's gem5-database mode), this module
//! actually **runs** the pipeline: one worker thread per stage, each with
//! its own per-thread PJRT [`crate::runtime::Runtime`] executing the AOT
//! Pallas/JAX conv artifacts, bounded channels for backpressure, and a
//! sink measuring real throughput. The online tuner (Algorithm 2) then
//! drives reconfiguration against these *measured* numbers — the fully
//! online mode the paper targets on real hardware.
//!
//! Heterogeneity emulation: the host is a homogeneous CPU, so each EP
//! applies a calibrated service-rate factor (busy-wait after compute)
//! derived from the analytic cost model — Big/fast EPs run at measured
//! speed, Little/slow EPs proportionally slower (DESIGN.md §1).

pub mod adaptive;
pub mod emulation;
pub mod pipeline_rt;
pub mod tuner;
pub mod workload;

pub use adaptive::{AdaptiveController, AdaptiveReport, DriftEvent};
pub use emulation::EpEmulation;
pub use pipeline_rt::{MeasuredRun, PipelineRuntime};
pub use tuner::{OnlineTuner, TrialLog, TuneReport};
