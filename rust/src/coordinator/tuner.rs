//! Online tuning against *measured* throughput (Algorithm 2, live mode).
//!
//! Mirrors `explore::shisha::tuning` but drives the real
//! [`PipelineRuntime`]: every trial spawns the pipeline, streams probe
//! inputs, reads measured per-stage service times, and moves one layer off
//! the measured-slowest stage. This is the fully online deployment the
//! paper targets: no database, no model — the running system is the
//! oracle.

use anyhow::Result;

use super::pipeline_rt::{MeasuredRun, PipelineRuntime};
use crate::explore::shisha::BalancingChoice;
use crate::pipeline::PipelineConfig;
use crate::platform::Platform;

/// One tuning trial.
#[derive(Debug, Clone)]
pub struct TrialLog {
    /// Trial index (0 = seed).
    pub trial: usize,
    /// Configuration measured.
    pub config: PipelineConfig,
    /// Measured throughput, images/s.
    pub throughput: f64,
    /// Measured mean service time per stage.
    pub stage_times: Vec<f64>,
    /// Wall-clock spent measuring, seconds.
    pub wall_s: f64,
}

/// Outcome of an online tuning session.
#[derive(Debug, Clone)]
pub struct TuneReport {
    /// All trials in order (first = seed).
    pub trials: Vec<TrialLog>,
    /// Best configuration observed.
    pub best_config: PipelineConfig,
    /// Its measured throughput.
    pub best_throughput: f64,
    /// Total tuning wall-clock, seconds.
    pub total_wall_s: f64,
}

impl TuneReport {
    /// Throughput of the seed (trial 0).
    pub fn seed_throughput(&self) -> f64 {
        self.trials[0].throughput
    }

    /// Improvement of best over seed (≥ 1 when tuning helped or held).
    pub fn improvement(&self) -> f64 {
        self.best_throughput / self.seed_throughput()
    }
}

/// Online Shisha tuner over a live pipeline.
pub struct OnlineTuner<'a> {
    rt: &'a PipelineRuntime,
    plat: &'a Platform,
    /// α — consecutive non-improvements before stopping.
    pub alpha: u32,
    /// Balancing choice (nFEP / nlFEP).
    pub balancing: BalancingChoice,
    /// Probe inputs streamed per trial.
    pub probe_inputs: usize,
}

impl<'a> OnlineTuner<'a> {
    /// New tuner with the paper's α = 10.
    pub fn new(rt: &'a PipelineRuntime, plat: &'a Platform) -> Self {
        Self { rt, plat, alpha: 10, balancing: BalancingChoice::NlFep, probe_inputs: 16 }
    }

    /// Pick the move target next to `slowest` using *measured* stage times.
    fn pick_target(&self, cfg: &PipelineConfig, run: &MeasuredRun, slowest: usize) -> Option<usize> {
        if cfg.stages[slowest] <= 1 {
            return None;
        }
        let mut candidates: Vec<usize> = Vec::with_capacity(2);
        if slowest > 0 {
            candidates.push(slowest - 1);
        }
        if slowest + 1 < cfg.n_stages() {
            candidates.push(slowest + 1);
        }
        if candidates.is_empty() {
            return None;
        }
        match self.balancing {
            BalancingChoice::NFep => candidates.into_iter().max_by(|&a, &b| {
                let pa = self.plat.eps[cfg.assignment[a]].perf_score();
                let pb = self.plat.eps[cfg.assignment[b]].perf_score();
                pa.partial_cmp(&pb).unwrap().then(b.cmp(&a))
            }),
            BalancingChoice::NlFep => candidates.into_iter().min_by(|&a, &b| {
                run.stage_times[a]
                    .partial_cmp(&run.stage_times[b])
                    .unwrap()
                    .then_with(|| {
                        let pa = self.plat.eps[cfg.assignment[a]].perf_score();
                        let pb = self.plat.eps[cfg.assignment[b]].perf_score();
                        pb.partial_cmp(&pa).unwrap()
                    })
                    .then(a.cmp(&b))
            }),
        }
    }

    /// Run Algorithm 2 from `seed` against the live pipeline.
    pub fn tune(&self, seed: PipelineConfig) -> Result<TuneReport> {
        let t0 = std::time::Instant::now();
        let mut trials = Vec::new();

        let mut conf = seed;
        let mut run = self.rt.measure(&conf, self.probe_inputs)?;
        let mut throughput = run.throughput;
        let mut best = (conf.clone(), run.throughput);
        trials.push(TrialLog {
            trial: 0,
            config: conf.clone(),
            throughput: run.throughput,
            stage_times: run.stage_times.clone(),
            wall_s: run.wall_s,
        });

        let mut gamma = 0u32;
        while gamma < self.alpha {
            let slowest = run.slowest_stage();
            let next = match self.pick_target(&conf, &run, slowest) {
                Some(target) => conf.move_layer(slowest, target).expect("legal move"),
                None => {
                    // Deployment-mode extension (not in Algorithm 2): a
                    // single-layer slowest stage cannot shed load, but it
                    // can trade EPs with the *fastest* stage when that one
                    // sits on a stronger EP — the only greedy move that can
                    // still reduce the bottleneck. Non-improving swaps are
                    // bounded by gamma like any other trial.
                    let fastest = run
                        .stage_times
                        .iter()
                        .enumerate()
                        .min_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
                        .map(|(i, _)| i)
                        .unwrap();
                    let stronger = self.plat.eps[conf.assignment[fastest]].perf_score()
                        > self.plat.eps[conf.assignment[slowest]].perf_score();
                    match (fastest != slowest && stronger, conf.swap_eps(slowest, fastest)) {
                        (true, Some(swapped)) => swapped,
                        _ => {
                            gamma += 1;
                            continue;
                        }
                    }
                }
            };
            conf = next;
            run = self.rt.measure(&conf, self.probe_inputs)?;
            trials.push(TrialLog {
                trial: trials.len(),
                config: conf.clone(),
                throughput: run.throughput,
                stage_times: run.stage_times.clone(),
                wall_s: run.wall_s,
            });
            if run.throughput > best.1 {
                best = (conf.clone(), run.throughput);
            }
            if run.throughput <= throughput {
                gamma += 1;
            } else {
                gamma = 0;
                throughput = run.throughput;
            }
        }

        Ok(TuneReport {
            trials,
            best_config: best.0,
            best_throughput: best.1,
            total_wall_s: t0.elapsed().as_secs_f64(),
        })
    }
}

// Live-pipeline tests require artifacts: see rust/tests/coordinator_e2e.rs.
