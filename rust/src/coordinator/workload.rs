//! Open-loop serving workloads and latency statistics (extension).
//!
//! The paper measures steady-state throughput of closed pipelines; a
//! serving deployment sees an *open-loop* arrival process and cares about
//! tail latency. This module models request arrivals (deterministic,
//! Poisson, bursty) over the pipeline simulator's timing and reports
//! queueing + service latency percentiles — the metrics a router/batcher
//! above Shisha would track.
//!
//! The model is a single-server queue at the bottleneck stage (service =
//! one bottleneck period per image, which is exactly the steady-state
//! abstraction the paper uses) plus the pipeline fill latency for each
//! request's own pass.

use crate::metrics::Stats;
use crate::model::Network;
use crate::perfdb::PerfDb;
use crate::pipeline::{simulator, PipelineConfig};
use crate::platform::Platform;
use crate::rng::Xoshiro256;

/// Arrival process of an open-loop workload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrivals {
    /// Fixed inter-arrival gap (seconds).
    Uniform(f64),
    /// Poisson with rate λ (requests/second).
    Poisson(f64),
    /// Bursts of `k` back-to-back requests every `gap` seconds.
    Bursty {
        /// Requests per burst.
        k: u32,
        /// Gap between burst starts, seconds.
        gap: f64,
    },
}

impl Arrivals {
    /// Generate `n` arrival timestamps.
    pub fn timestamps(&self, n: usize, rng: &mut Xoshiro256) -> Vec<f64> {
        let mut out = Vec::with_capacity(n);
        match *self {
            Arrivals::Uniform(gap) => {
                for i in 0..n {
                    out.push(i as f64 * gap);
                }
            }
            Arrivals::Poisson(lambda) => {
                let mut t = 0.0;
                for _ in 0..n {
                    // exponential inter-arrival via inverse CDF
                    let u = rng.gen_f64().max(1e-12);
                    t += -u.ln() / lambda;
                    out.push(t);
                }
            }
            Arrivals::Bursty { k, gap } => {
                let mut i = 0usize;
                let mut burst = 0u64;
                while i < n {
                    for _ in 0..k {
                        if i >= n {
                            break;
                        }
                        out.push(burst as f64 * gap);
                        i += 1;
                    }
                    burst += 1;
                }
            }
        }
        out
    }
}

/// Latency report of a served workload.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Requests served.
    pub n: usize,
    /// Offered load vs pipeline capacity (ρ = λ · bottleneck).
    pub utilisation: f64,
    /// Mean end-to-end latency, seconds.
    pub mean_s: f64,
    /// Median latency.
    pub p50_s: f64,
    /// 99th percentile latency.
    pub p99_s: f64,
    /// Achieved throughput over the run, images/s.
    pub throughput: f64,
}

/// Serve `n` requests with the given arrival process through `cfg`,
/// reporting latency percentiles. Deterministic given `seed`.
pub fn serve(
    net: &Network,
    plat: &Platform,
    db: &PerfDb,
    cfg: &PipelineConfig,
    arrivals: Arrivals,
    n: usize,
    seed: u64,
) -> ServeReport {
    let eval = simulator::evaluate(net, plat, db, cfg);
    let service = eval.bottleneck_s;
    let fill = eval.latency_s;
    let mut rng = Xoshiro256::seed_from(seed);
    let ts = arrivals.timestamps(n, &mut rng);

    let mut stats = Stats::new();
    let mut free_at = 0.0f64; // bottleneck server free time
    let mut last_done = 0.0f64;
    for &arr in &ts {
        let start = arr.max(free_at);
        free_at = start + service;
        // completion = admission to bottleneck + its service + remaining fill
        let done = start + service + (fill - service).max(0.0);
        last_done = last_done.max(done);
        stats.push(done - arr);
    }
    let span = last_done - ts.first().copied().unwrap_or(0.0);
    let offered_rate = if ts.len() > 1 {
        (ts.len() - 1) as f64 / (ts.last().unwrap() - ts[0]).max(1e-12)
    } else {
        0.0
    };
    ServeReport {
        n,
        utilisation: offered_rate * service,
        mean_s: stats.mean(),
        p50_s: stats.median(),
        p99_s: stats.percentile(99.0),
        throughput: n as f64 / span.max(1e-12),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::networks;
    use crate::perfdb::CostModel;
    use crate::platform::configs;

    fn setup() -> (Network, Platform, PerfDb, PipelineConfig) {
        let net = networks::synthnet();
        let plat = configs::c2();
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cfg = PipelineConfig::new(vec![9, 9], vec![0, 1]);
        (net, plat, db, cfg)
    }

    #[test]
    fn arrivals_counts_and_monotonicity() {
        let mut rng = Xoshiro256::seed_from(1);
        for a in [Arrivals::Uniform(0.1), Arrivals::Poisson(10.0), Arrivals::Bursty { k: 4, gap: 1.0 }] {
            let ts = a.timestamps(50, &mut rng);
            assert_eq!(ts.len(), 50);
            for w in ts.windows(2) {
                assert!(w[1] >= w[0], "{a:?} non-decreasing");
            }
        }
    }

    #[test]
    fn underload_latency_is_fill_time() {
        let (net, plat, db, cfg) = setup();
        let eval = simulator::evaluate(&net, &plat, &db, &cfg);
        // arrivals far slower than service: no queueing
        let r = serve(&net, &plat, &db, &cfg, Arrivals::Uniform(10.0 * eval.bottleneck_s), 100, 1);
        assert!(r.utilisation < 0.2);
        assert!((r.p50_s - eval.latency_s).abs() < 1e-9, "p50 {} vs fill {}", r.p50_s, eval.latency_s);
        assert!((r.p99_s - r.p50_s).abs() < 1e-9, "no tail without queueing");
    }

    #[test]
    fn overload_latency_grows() {
        let (net, plat, db, cfg) = setup();
        let eval = simulator::evaluate(&net, &plat, &db, &cfg);
        // offered load 2x capacity: queue builds, p99 >> p50 of underload
        let r = serve(&net, &plat, &db, &cfg, Arrivals::Uniform(eval.bottleneck_s / 2.0), 200, 1);
        assert!(r.utilisation > 1.5);
        assert!(r.p99_s > 10.0 * eval.latency_s, "p99 {} under overload", r.p99_s);
        // throughput caps at pipeline capacity
        assert!(r.throughput <= 1.05 / eval.bottleneck_s);
    }

    #[test]
    fn bursts_create_tail() {
        let (net, plat, db, cfg) = setup();
        let eval = simulator::evaluate(&net, &plat, &db, &cfg);
        let burst = serve(
            &net,
            &plat,
            &db,
            &cfg,
            Arrivals::Bursty { k: 16, gap: 32.0 * eval.bottleneck_s },
            160,
            2,
        );
        let smooth = serve(
            &net,
            &plat,
            &db,
            &cfg,
            Arrivals::Uniform(2.0 * eval.bottleneck_s),
            160,
            2,
        );
        assert!(burst.p99_s > smooth.p99_s, "bursty tail {} vs smooth {}", burst.p99_s, smooth.p99_s);
    }

    #[test]
    fn poisson_rate_respected() {
        let mut rng = Xoshiro256::seed_from(3);
        let ts = Arrivals::Poisson(100.0).timestamps(2000, &mut rng);
        let rate = (ts.len() - 1) as f64 / (ts.last().unwrap() - ts[0]);
        assert!((rate - 100.0).abs() < 10.0, "empirical rate {rate}");
    }

    #[test]
    fn better_schedule_lower_tail_at_same_load() {
        let (net, plat, db, _) = setup();
        let good = PipelineConfig::new(vec![9, 9], vec![0, 1]);
        let bad = PipelineConfig::new(vec![1, 17], vec![0, 1]);
        let good_eval = simulator::evaluate(&net, &plat, &db, &good);
        let arr = Arrivals::Poisson(0.5 / good_eval.bottleneck_s);
        let rg = serve(&net, &plat, &db, &good, arr, 300, 4);
        let rb = serve(&net, &plat, &db, &bad, arr, 300, 4);
        assert!(rg.p99_s < rb.p99_s, "good p99 {} < bad p99 {}", rg.p99_s, rb.p99_s);
    }
}
