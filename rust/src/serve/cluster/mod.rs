//! Cluster-level planning and control: the two cooperating pieces that
//! lift serving from per-tenant decisions to whole-platform ones.
//!
//! * [`coplan`] — the **cross-tenant co-planner**: jointly allocates
//!   disjoint EP budgets across every tenant (water-filling on predicted
//!   marginal throughput, with per-tenant priority weights), provably
//!   never worse than greedy first-come allocation on total weighted
//!   predicted throughput. Enabled per run via
//!   [`crate::serve::ServeOptions::coplan`].
//! * [`autoscale`] — the **runtime shard autoscaler**: an epoch-driven,
//!   deterministic controller that activates, drains and parks a tenant's
//!   planned replicas as the observed load moves, with hysteresis so
//!   oscillating traffic cannot thrash. Enabled via
//!   [`crate::serve::ServeOptions::autoscale`].
//!
//! Planning runs at serve start and — when the **elastic control loop**
//! ([`crate::serve::ServeOptions::elastic`]) is on — again at every
//! control epoch on the *observed* per-tenant demand
//! ([`coplan::coplan_observed_with`]); scaling happens at every control
//! epoch. All of it is a pure function of its inputs, so co-planned,
//! autoscaled and elastically re-partitioned runs keep the serving
//! engine's one-seed-one-event-log determinism guarantee (pinned by
//! `tests/serve_golden.rs`).

pub mod autoscale;
pub mod coplan;

pub use autoscale::{AutoscaleOptions, ElasticOptions, ReplicaState, ScaleEvent};
pub use coplan::{
    coplan, coplan_with, greedy_plan, water_fill_plan, ClusterPlan, TenantAllocation,
    TenantDemand,
};
