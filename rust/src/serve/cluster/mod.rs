//! Cluster-level planning and control: the two cooperating pieces that
//! lift serving from per-tenant decisions to whole-platform ones.
//!
//! * [`coplan`] — the **cross-tenant co-planner**: jointly allocates
//!   disjoint EP budgets across every tenant (water-filling on predicted
//!   marginal throughput, with per-tenant priority weights), provably
//!   never worse than greedy first-come allocation on total weighted
//!   predicted throughput. Enabled per run via
//!   [`crate::serve::ServeOptions::coplan`].
//! * [`autoscale`] — the **runtime shard autoscaler**: an epoch-driven,
//!   deterministic controller that activates, drains and parks a tenant's
//!   planned replicas as the observed load moves, with hysteresis so
//!   oscillating traffic cannot thrash. Enabled via
//!   [`crate::serve::ServeOptions::autoscale`].
//!
//! Planning happens once at serve start; scaling happens at every control
//! epoch. Both are pure functions of their inputs, so co-planned and
//! autoscaled runs keep the serving engine's one-seed-one-event-log
//! determinism guarantee (pinned by `tests/serve_golden.rs`).

pub mod autoscale;
pub mod coplan;

pub use autoscale::{AutoscaleOptions, ReplicaState, ScaleEvent};
pub use coplan::{
    coplan, coplan_with, greedy_plan, water_fill_plan, ClusterPlan, TenantAllocation,
};
