//! Cross-tenant co-planning: jointly allocate **disjoint** EP budgets to
//! every tenant of a serving deployment.
//!
//! Without co-planning each tenant plans against the *full* platform
//! (greedy per-tenant placement) and the engine's time-slicing contention
//! model arbitrates the overlap at run time. On shared heterogeneous
//! chiplets that leaves throughput on the table: inter-model planners that
//! partition the hardware up front avoid contention entirely (Odema et
//! al., 2312.09401; Scope, 2602.14393). This module is that planner:
//!
//! * [`greedy_plan`] — the baseline: tenants grab ranked EPs
//!   first-come-first-served in fair-count chunks;
//! * [`water_fill_plan`] — marginal-throughput water-filling: every tenant
//!   starts with one ranked EP, then each remaining EP (best first) goes
//!   to the tenant whose **weighted predicted throughput** gains the most
//!   from it, re-planning the tenant's shard placement on the grown
//!   budget each time ([`crate::serve::shard::plan_shards`] on the
//!   [`crate::platform::Platform::subset`] sub-platform — exhaustive on
//!   small restricted spaces via [`crate::explore::partition`], Shisha
//!   otherwise);
//! * [`coplan`] — the entry point, with a **proof obligation by
//!   construction**: it evaluates both plans above under the joint
//!   objective `Σ weight_i × predicted_throughput_i` and returns the
//!   better one, so a co-planned deployment is never worse than the
//!   greedy first-come allocation on total weighted predicted throughput
//!   (`tests/cluster_autoscale.rs` asserts this on a 3-tenant C5
//!   scenario).
//!
//! Everything is deterministic: EP ranking, tie-breaks and the
//! partition-then-tune driver are all RNG-free or fixed-seed, so a
//! co-planned serving run keeps the engine's one-seed-one-event-log
//! guarantee. The serving engine consumes a [`ClusterPlan`] through
//! [`crate::serve::ServeOptions::coplan`].

use anyhow::{bail, Result};

use crate::explore::PlanCache;
use crate::model::Network;
use crate::pipeline::PipelineConfig;
use crate::platform::{EpId, Platform};

use super::super::shard::plan_shards_with;
use super::super::tenant::TenantSpec;

/// One tenant's share of a [`ClusterPlan`].
#[derive(Debug, Clone)]
pub struct TenantAllocation {
    /// The tenant's disjoint EP budget (global ids, ascending).
    pub eps: Vec<EpId>,
    /// Replica placements within the budget: each entry is the replica's
    /// global EP subset plus its tuned configuration in the **local** ids
    /// of that subset's sub-platform — exactly the shape the serving
    /// engine materialises replicas from.
    pub placements: Vec<(Vec<EpId>, PipelineConfig)>,
    /// Total predicted throughput of the placements, img/s.
    pub predicted: f64,
    /// The tenant's priority weight (copied from
    /// [`TenantSpec::weight`]).
    pub weight: f64,
}

/// A joint allocation of the platform across all tenants.
#[derive(Debug, Clone)]
pub struct ClusterPlan {
    /// Per-tenant allocations, in input order. Budgets are pairwise
    /// disjoint; EPs no tenant benefits from may stay unallocated.
    pub allocations: Vec<TenantAllocation>,
    /// Which strategy produced the plan (`"water-fill"` or `"greedy"`).
    pub strategy: &'static str,
}

impl ClusterPlan {
    /// The joint objective: total weighted predicted throughput.
    pub fn objective(&self) -> f64 {
        self.allocations.iter().map(|a| a.weight * a.predicted).sum()
    }
}

/// Plan a tenant's shard placement inside an EP budget: tune on the
/// budget's sub-platform and translate the chosen partition back to
/// global ids. The returned configurations stay in the local ids of each
/// replica's own sub-platform ([`Platform::subset`] composes: restricting
/// the budget view to a partition entry yields the same sub-platform as
/// restricting the full platform to the translated global ids).
pub fn plan_budget(
    net: &Network,
    plat: &Platform,
    budget: &[EpId],
    max_shards: usize,
) -> Result<(Vec<(Vec<EpId>, PipelineConfig)>, f64)> {
    plan_budget_with(net, plat, budget, max_shards, 1, &PlanCache::new())
}

/// [`plan_budget`] through a shared subset-tuning memo and worker budget —
/// the co-planner's fast path. Budgets are canonically ascending-sorted by
/// the callers, and candidate subsets of a budget's sub-platform
/// fingerprint by their underlying hardware, so water-filling's repeated
/// probes of the same (tenant, budget) pair — and of hardware-isomorphic
/// budgets across tenants — hit the cache. Bit-identical to the uncached
/// call.
pub fn plan_budget_with(
    net: &Network,
    plat: &Platform,
    budget: &[EpId],
    max_shards: usize,
    threads: usize,
    cache: &PlanCache,
) -> Result<(Vec<(Vec<EpId>, PipelineConfig)>, f64)> {
    let sub = plat.subset(budget);
    let plan = plan_shards_with(net, &sub, max_shards.max(1), threads, cache)?;
    let total = plan.total_predicted();
    let placements = plan
        .partitions
        .into_iter()
        .zip(plan.configs)
        .map(|(part, cfg)| {
            let global: Vec<EpId> = part.iter().map(|&e| budget[e]).collect();
            (global, cfg)
        })
        .collect();
    Ok((placements, total))
}

fn check_specs(plat: &Platform, specs: &[TenantSpec]) -> Result<()> {
    if specs.is_empty() {
        bail!("coplan: at least one tenant required");
    }
    if specs.len() > plat.n_eps() {
        bail!(
            "coplan: {} tenants need at least as many EPs (platform {} has {})",
            specs.len(),
            plat.name,
            plat.n_eps()
        );
    }
    for s in specs {
        if s.net.is_empty() {
            bail!("coplan: tenant {} has an empty network", s.name);
        }
        if !(s.weight.is_finite() && s.weight > 0.0) {
            bail!("coplan: tenant {} weight must be positive and finite", s.name);
        }
    }
    Ok(())
}

fn build_plan(
    plat: &Platform,
    specs: &[TenantSpec],
    budgets: Vec<Vec<EpId>>,
    strategy: &'static str,
    threads: usize,
    cache: &PlanCache,
) -> Result<ClusterPlan> {
    let mut allocations = Vec::with_capacity(specs.len());
    for (spec, mut eps) in specs.iter().zip(budgets) {
        eps.sort_unstable();
        let (placements, predicted) =
            plan_budget_with(&spec.net, plat, &eps, spec.shards, threads, cache)?;
        allocations.push(TenantAllocation { eps, placements, predicted, weight: spec.weight });
    }
    Ok(ClusterPlan { allocations, strategy })
}

/// The first-come baseline: tenants in **input order** each grab a
/// fair-count chunk of the best remaining ranked EPs (tenant `i` of `r`
/// remaining takes `ceil(remaining_eps / r)`). This mirrors what
/// sequential per-tenant onboarding would do on a shared cluster, made
/// disjoint — the allocation the co-planner must never lose to.
pub fn greedy_plan(plat: &Platform, specs: &[TenantSpec]) -> Result<ClusterPlan> {
    greedy_plan_with(plat, specs, 1, &PlanCache::new())
}

/// [`greedy_plan`] with an explicit subset-tuning memo and worker budget.
pub fn greedy_plan_with(
    plat: &Platform,
    specs: &[TenantSpec],
    threads: usize,
    cache: &PlanCache,
) -> Result<ClusterPlan> {
    check_specs(plat, specs)?;
    let ranked = plat.eps_by_rank();
    let mut budgets: Vec<Vec<EpId>> = Vec::with_capacity(specs.len());
    let mut next = 0usize;
    for i in 0..specs.len() {
        let remaining_eps = ranked.len() - next;
        let remaining_tenants = specs.len() - i;
        let take = remaining_eps.div_euclid(remaining_tenants)
            + usize::from(remaining_eps % remaining_tenants != 0);
        budgets.push(ranked[next..next + take].to_vec());
        next += take;
    }
    build_plan(plat, specs, budgets, "greedy", threads, cache)
}

/// Water-filling on predicted marginal throughput: seed every tenant with
/// one ranked EP (heaviest weight gets the best EP; ties keep input
/// order), then hand each remaining EP — best first — to the tenant whose
/// weighted predicted throughput grows the most when its shard placement
/// is re-planned on the enlarged budget. An EP nobody gains from
/// (`weighted marginal gain ≤ 0` for every tenant) stays unallocated
/// rather than being parked on an arbitrary tenant.
pub fn water_fill_plan(plat: &Platform, specs: &[TenantSpec]) -> Result<ClusterPlan> {
    water_fill_plan_with(plat, specs, 1, &PlanCache::new())
}

/// [`water_fill_plan`] with an explicit subset-tuning memo and worker
/// budget. Every candidate-grant probe and the final plan-build pass
/// share `cache`, so re-planning a budget the loop has already tuned —
/// the common case: the winning probe's budget is re-planned verbatim at
/// build time, and losing tenants are re-probed on unchanged budgets —
/// costs lookups, not tuning runs. Bit-identical to the uncached planner.
pub fn water_fill_plan_with(
    plat: &Platform,
    specs: &[TenantSpec],
    threads: usize,
    cache: &PlanCache,
) -> Result<ClusterPlan> {
    check_specs(plat, specs)?;
    let ranked = plat.eps_by_rank();

    // seeding order: descending weight, ties by input order
    let mut order: Vec<usize> = (0..specs.len()).collect();
    order.sort_by(|&a, &b| {
        specs[b].weight.total_cmp(&specs[a].weight).then(a.cmp(&b))
    });
    let mut budgets: Vec<Vec<EpId>> = vec![Vec::new(); specs.len()];
    for (rank_ix, &t) in order.iter().enumerate() {
        budgets[t].push(ranked[rank_ix]);
    }
    let mut predicted: Vec<f64> = Vec::with_capacity(specs.len());
    for (spec, budget) in specs.iter().zip(&budgets) {
        let (_, p) = plan_budget_with(&spec.net, plat, budget, spec.shards, threads, cache)?;
        predicted.push(p);
    }

    for &ep in &ranked[specs.len()..] {
        // offer this EP to every tenant; the best weighted marginal gain
        // wins (ties: fewer EPs so far, then lower tenant index)
        let mut best: Option<(usize, f64, f64)> = None; // (tenant, gain, new predicted)
        for (t, spec) in specs.iter().enumerate() {
            let mut cand = budgets[t].clone();
            cand.push(ep);
            cand.sort_unstable();
            let (_, p) = plan_budget_with(&spec.net, plat, &cand, spec.shards, threads, cache)?;
            let gain = spec.weight * (p - predicted[t]);
            let better = match best {
                None => true,
                Some((bt, bg, _)) => {
                    gain > bg
                        || (gain == bg
                            && (budgets[t].len() < budgets[bt].len()
                                || (budgets[t].len() == budgets[bt].len() && t < bt)))
                }
            };
            if better {
                best = Some((t, gain, p));
            }
        }
        if let Some((t, gain, p)) = best {
            if gain > 0.0 {
                budgets[t].push(ep);
                budgets[t].sort_unstable();
                predicted[t] = p;
            }
        }
    }
    build_plan(plat, specs, budgets, "water-fill", threads, cache)
}

/// One tenant's **observed** demand over the last control epoch, as the
/// elastic loop sees it — the same serving signals the autoscaler
/// watches, aggregated across the tenant's replicas.
#[derive(Debug, Clone, Copy)]
pub struct TenantDemand {
    /// Arrivals offered during the last epoch, per second.
    pub offered_rate: f64,
    /// Requests shed (rejected or dropped) during the last epoch, per
    /// second.
    pub shed_rate: f64,
    /// Requests waiting in the tenant's queues right now.
    pub backlog: u64,
}

impl TenantDemand {
    /// Scalar demand pressure: the offered rate plus the unmet part
    /// (shed requests and standing backlog both mean the allocation is
    /// too small, so they push the tenant's effective weight up).
    pub fn pressure(&self) -> f64 {
        self.offered_rate + self.shed_rate + self.backlog as f64
    }
}

/// Demand-weight smoothing floor: keeps an idle tenant's effective weight
/// positive (so `check_specs` holds and the tenant keeps at least one EP)
/// and damps the swing when every tenant is near-idle.
const DEMAND_EPSILON: f64 = 1.0;

/// Per-tenant demand-pressure scale factors: `(ε + pressure_i) / (ε +
/// mean pressure)`. Multiplying each tenant's spec weight by its factor
/// yields the **effective** weights the demand-driven plan is derived
/// and scored under; the elastic gain bar must score the *live*
/// allocation under the same factors, so they are exposed rather than
/// buried in [`coplan_observed_with`]. Under uniform (or uniformly zero)
/// pressure every factor is exactly `1.0`.
pub fn demand_factors(demands: &[TenantDemand]) -> Vec<f64> {
    if demands.is_empty() {
        return Vec::new();
    }
    let mean = demands.iter().map(|d| d.pressure()).sum::<f64>() / demands.len() as f64;
    demands
        .iter()
        .map(|d| (DEMAND_EPSILON + d.pressure()) / (DEMAND_EPSILON + mean))
        .collect()
}

/// Re-derive a cluster plan from **observed** per-tenant demand.
///
/// Each tenant's spec weight is scaled by its [`demand_factors`] entry,
/// then the ordinary [`coplan_with`] runs on the re-weighted specs, so
/// EPs flow toward tenants whose observed pressure (offered + shed +
/// backlog) outruns their share. Under uniform pressure every factor is
/// exactly `1.0` and the demand-driven plan degenerates to the static
/// co-plan bit-for-bit — the elastic loop sees no spurious gain.
/// `shard_caps` pins each tenant's `max_shards` to its live replica
/// count so the re-derived placements always fit the engine's
/// materialised replica arrays.
///
/// The returned plan's allocations carry the **effective** weights, so
/// [`ClusterPlan::objective`] scores demand-weighted predicted
/// throughput; compare it against the live allocation scored under the
/// same factors.
pub fn coplan_observed_with(
    plat: &Platform,
    specs: &[TenantSpec],
    demands: &[TenantDemand],
    shard_caps: &[usize],
    threads: usize,
    cache: &PlanCache,
) -> Result<ClusterPlan> {
    if demands.len() != specs.len() || shard_caps.len() != specs.len() {
        bail!(
            "coplan_observed: {} tenants but {} demands / {} shard caps",
            specs.len(),
            demands.len(),
            shard_caps.len()
        );
    }
    let factors = demand_factors(demands);
    let mut scaled: Vec<TenantSpec> = Vec::with_capacity(specs.len());
    for ((spec, &factor), &cap) in specs.iter().zip(&factors).zip(shard_caps) {
        let mut s = spec.clone();
        s.weight = spec.weight * factor;
        s.shards = cap.max(1);
        scaled.push(s);
    }
    coplan_with(plat, &scaled, threads, cache)
}

/// Co-plan the platform across all tenants.
///
/// Evaluates the water-filling plan and the greedy first-come baseline
/// under the joint objective (total weighted predicted throughput) and
/// returns whichever scores higher — water-filling on ties. The returned
/// plan is therefore **never worse than greedy first-come allocation** by
/// construction; [`ClusterPlan::strategy`] records which side won.
///
/// Runs through a run-local [`PlanCache`] shared by both strategies and a
/// core-sized worker pool ([`plan_shards_with`] tunes candidate partitions
/// in parallel with a deterministic reduction), so multi-tenant co-plan
/// startup scales with cores while remaining a pure function of its
/// inputs. Callers that co-plan repeatedly (periodic re-planning, plan
/// sweeps) should hold their own cache and call [`coplan_with`].
pub fn coplan(plat: &Platform, specs: &[TenantSpec]) -> Result<ClusterPlan> {
    coplan_with(plat, specs, crate::serve::sweep::available_threads(), &PlanCache::new())
}

/// [`coplan`] with an explicit subset-tuning memo and worker budget;
/// results are bit-identical for any `threads`/cache state.
pub fn coplan_with(
    plat: &Platform,
    specs: &[TenantSpec],
    threads: usize,
    cache: &PlanCache,
) -> Result<ClusterPlan> {
    let wf = water_fill_plan_with(plat, specs, threads, cache)?;
    let gd = greedy_plan_with(plat, specs, threads, cache)?;
    Ok(if wf.objective() >= gd.objective() { wf } else { gd })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::networks;
    use crate::platform::configs;
    use crate::serve::arrivals::ArrivalProcess;

    fn spec(name: &str, net: crate::model::Network, weight: f64, shards: usize) -> TenantSpec {
        TenantSpec::new(name, net, ArrivalProcess::Poisson { rate: 1.0 })
            .with_weight(weight)
            .with_shards(shards)
    }

    fn assert_disjoint(plan: &ClusterPlan, n_eps: usize) {
        let mut seen = vec![false; n_eps];
        for a in &plan.allocations {
            assert!(!a.eps.is_empty(), "every tenant gets at least one EP");
            for &e in &a.eps {
                assert!(e < n_eps, "EP {e} out of range");
                assert!(!seen[e], "EP {e} allocated twice");
                seen[e] = true;
            }
            // placements partition the budget
            let mut in_budget = vec![false; n_eps];
            for &e in &a.eps {
                in_budget[e] = true;
            }
            let mut covered = 0usize;
            for (eps, _) in &a.placements {
                for &e in eps {
                    assert!(in_budget[e], "placement EP {e} escaped its budget");
                    covered += 1;
                }
            }
            assert_eq!(covered, a.eps.len(), "placements must cover the budget exactly");
        }
    }

    #[test]
    fn greedy_chunks_ranked_eps_in_input_order() {
        let plat = configs::c5();
        let specs = vec![
            spec("a", networks::synthnet_small(), 1.0, 1),
            spec("b", networks::synthnet_small(), 1.0, 1),
            spec("c", networks::synthnet_small(), 1.0, 1),
        ];
        let plan = greedy_plan(&plat, &specs).unwrap();
        assert_eq!(plan.strategy, "greedy");
        assert_disjoint(&plan, plat.n_eps());
        // fair-count chunks of 8 EPs over 3 tenants: 3 + 3 + 2
        assert_eq!(plan.allocations[0].eps.len(), 3);
        assert_eq!(plan.allocations[1].eps.len(), 3);
        assert_eq!(plan.allocations[2].eps.len(), 2);
        // first-come: tenant 0 holds the top-ranked EP
        let top = plat.eps_by_rank()[0];
        assert!(plan.allocations[0].eps.contains(&top));
    }

    #[test]
    fn water_fill_allocates_disjoint_budgets() {
        let plat = configs::c2();
        let specs = vec![
            spec("heavy", networks::synthnet(), 2.0, 2),
            spec("light", networks::synthnet_small(), 1.0, 1),
        ];
        let plan = water_fill_plan(&plat, &specs).unwrap();
        assert_eq!(plan.strategy, "water-fill");
        assert_disjoint(&plan, plat.n_eps());
        assert!(plan.objective() > 0.0);
        // placements carry valid configs on their sub-platforms
        for a in &plan.allocations {
            assert!(!a.placements.is_empty());
        }
        for (a, s) in plan.allocations.iter().zip(&specs) {
            for (eps, cfg) in &a.placements {
                let sub = plat.subset(eps);
                assert!(cfg.validate(s.net.len(), &sub).is_ok(), "{}", cfg.describe());
            }
        }
    }

    #[test]
    fn coplan_never_below_greedy() {
        let plat = configs::c2();
        let specs = vec![
            spec("a", networks::synthnet(), 2.0, 2),
            spec("b", networks::alexnet(), 1.0, 1),
        ];
        let joint = coplan(&plat, &specs).unwrap();
        let greedy = greedy_plan(&plat, &specs).unwrap();
        assert!(
            joint.objective() >= greedy.objective(),
            "proof obligation: joint {} < greedy {}",
            joint.objective(),
            greedy.objective()
        );
        assert_disjoint(&joint, plat.n_eps());
    }

    #[test]
    fn coplan_is_deterministic() {
        let plat = configs::c2();
        let specs = vec![
            spec("a", networks::synthnet(), 1.5, 2),
            spec("b", networks::synthnet_small(), 1.0, 1),
        ];
        let p1 = coplan(&plat, &specs).unwrap();
        let p2 = coplan(&plat, &specs).unwrap();
        assert_eq!(p1.strategy, p2.strategy);
        assert_eq!(p1.objective().to_bits(), p2.objective().to_bits());
        for (a, b) in p1.allocations.iter().zip(&p2.allocations) {
            assert_eq!(a.eps, b.eps);
            assert_eq!(a.placements.len(), b.placements.len());
            for ((ea, ca), (eb, cb)) in a.placements.iter().zip(&b.placements) {
                assert_eq!(ea, eb);
                assert_eq!(ca, cb);
            }
        }
    }

    #[test]
    fn cached_and_parallel_coplan_match_uncached_bitwise() {
        let plat = configs::c2();
        let specs = vec![
            spec("heavy", networks::synthnet(), 2.0, 2),
            spec("light", networks::synthnet_small(), 1.0, 1),
        ];
        let baseline = coplan_with(&plat, &specs, 1, &PlanCache::new()).unwrap();
        let cache = PlanCache::new();
        let cold = coplan_with(&plat, &specs, 4, &cache).unwrap();
        let misses_after_cold = cache.stats().misses;
        let warm = coplan_with(&plat, &specs, 4, &cache).unwrap();
        assert!(
            cache.stats().misses == misses_after_cold,
            "warm co-plan must be pure cache hits"
        );
        assert!(cache.stats().hits > 0, "water-filling re-probes must hit the memo");
        for (what, plan) in [("cold", &cold), ("warm", &warm)] {
            crate::testutil::same_cluster_plan(plan, &baseline)
                .unwrap_or_else(|e| panic!("{what}: {e}"));
        }
    }

    #[test]
    fn water_filling_reprobes_hit_the_cache() {
        // the motivating pathology: one coplan() run used to re-tune the
        // same (tenant, budget) subsets dozens of times; through the memo
        // the duplicate probes must all be hits
        let plat = configs::c5();
        let specs = vec![
            spec("a", networks::synthnet(), 2.0, 2),
            spec("b", networks::alexnet(), 1.0, 2),
            spec("c", networks::synthnet_small(), 1.0, 1),
        ];
        let cache = PlanCache::new();
        coplan_with(&plat, &specs, 1, &cache).unwrap();
        let s = cache.stats();
        assert!(
            s.hits > s.misses,
            "a 3-tenant C5 co-plan must hit the memo more than it tunes: {s:?}"
        );
    }

    #[test]
    fn uniform_demand_reproduces_the_static_coplan() {
        // equal pressure on every tenant scales all weights by exactly 1,
        // so the demand-driven plan must match the static plan bit-wise —
        // the elastic loop's no-spurious-repartition guarantee
        let plat = configs::c2();
        let specs = vec![
            spec("a", networks::synthnet(), 2.0, 2),
            spec("b", networks::synthnet_small(), 1.0, 1),
        ];
        let cache = PlanCache::new();
        let baseline = coplan_with(&plat, &specs, 1, &cache).unwrap();
        for demands in [
            vec![
                TenantDemand { offered_rate: 0.0, shed_rate: 0.0, backlog: 0 },
                TenantDemand { offered_rate: 0.0, shed_rate: 0.0, backlog: 0 },
            ],
            vec![
                TenantDemand { offered_rate: 5.0, shed_rate: 0.0, backlog: 0 },
                TenantDemand { offered_rate: 5.0, shed_rate: 0.0, backlog: 0 },
            ],
        ] {
            let observed =
                coplan_observed_with(&plat, &specs, &demands, &[2, 1], 1, &cache).unwrap();
            crate::testutil::same_cluster_plan(&observed, &baseline)
                .unwrap_or_else(|e| panic!("uniform demand diverged: {e}"));
        }
    }

    #[test]
    fn skewed_demand_shifts_budget_toward_the_pressured_tenant() {
        let plat = configs::c5();
        let specs = vec![
            spec("hot", networks::synthnet_small(), 1.0, 1),
            spec("cold", networks::synthnet_small(), 1.0, 1),
        ];
        let cache = PlanCache::new();
        let baseline = coplan_with(&plat, &specs, 1, &cache).unwrap();
        let demands = vec![
            TenantDemand { offered_rate: 50.0, shed_rate: 20.0, backlog: 32 },
            TenantDemand { offered_rate: 0.5, shed_rate: 0.0, backlog: 0 },
        ];
        let observed =
            coplan_observed_with(&plat, &specs, &demands, &[1, 1], 1, &cache).unwrap();
        assert!(
            observed.allocations[0].eps.len() >= baseline.allocations[0].eps.len(),
            "pressure must not shrink the hot tenant's budget: {} < {}",
            observed.allocations[0].eps.len(),
            baseline.allocations[0].eps.len()
        );
        assert!(!observed.allocations[1].eps.is_empty(), "idle tenant keeps ≥ 1 EP");
        // the plan is scored under the effective (demand-scaled) weights
        let factors = demand_factors(&demands);
        assert!(factors[0] > 1.0 && factors[1] < 1.0, "skew must split the factors");
        let by_hand: f64 = observed
            .allocations
            .iter()
            .zip(&specs)
            .zip(&factors)
            .map(|((a, s), f)| s.weight * f * a.predicted)
            .sum();
        assert_eq!(observed.objective().to_bits(), by_hand.to_bits());
    }

    #[test]
    fn observed_coplan_respects_shard_caps_and_arity() {
        let plat = configs::c2();
        let specs = vec![
            spec("a", networks::synthnet(), 2.0, 2),
            spec("b", networks::synthnet_small(), 1.0, 1),
        ];
        let d = TenantDemand { offered_rate: 1.0, shed_rate: 0.0, backlog: 0 };
        let cache = PlanCache::new();
        // capping tenant 0 to one replica keeps its placements ≤ 1
        let capped =
            coplan_observed_with(&plat, &specs, &[d, d], &[1, 1], 1, &cache).unwrap();
        assert!(capped.allocations[0].placements.len() <= 1);
        // arity mismatches are rejected
        assert!(coplan_observed_with(&plat, &specs, &[d], &[1, 1], 1, &cache).is_err());
        assert!(coplan_observed_with(&plat, &specs, &[d, d], &[1], 1, &cache).is_err());
    }

    #[test]
    fn coplan_rejects_bad_inputs() {
        let plat = configs::c1(); // 2 EPs
        assert!(coplan(&plat, &[]).is_err());
        let three = vec![
            spec("a", networks::synthnet_small(), 1.0, 1),
            spec("b", networks::synthnet_small(), 1.0, 1),
            spec("c", networks::synthnet_small(), 1.0, 1),
        ];
        assert!(coplan(&plat, &three).is_err(), "3 tenants cannot split 2 EPs");
        let bad_weight =
            vec![spec("a", networks::synthnet_small(), 1.0, 1).with_weight(0.0)];
        assert!(coplan(&plat, &bad_weight).is_err());
    }

    #[test]
    fn single_tenant_gets_whole_platform_value() {
        // with one tenant, water-filling degenerates to plan_shards on a
        // budget that absorbs every EP it benefits from
        let plat = configs::c1();
        let specs = vec![spec("solo", networks::synthnet_small(), 1.0, 2)];
        let plan = coplan(&plat, &specs).unwrap();
        assert_eq!(plan.allocations.len(), 1);
        assert!(!plan.allocations[0].eps.is_empty());
        assert!(plan.objective() > 0.0);
    }
}
