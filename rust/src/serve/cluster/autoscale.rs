//! Runtime shard autoscaling: an epoch-driven controller that grows and
//! shrinks a tenant's **live** replica count within its planned budget.
//!
//! The shard planner ([`crate::serve::shard::plan_shards`]) sizes a
//! deployment for peak load; serving it statically burns every replica's
//! EPs through the quiet hours too. The autoscaler instead watches each
//! tenant's per-epoch serving signals (offered rate, shed requests,
//! queued backlog) and moves replicas between three states:
//!
//! * **Active** — receives balancer traffic and serves;
//! * **Draining** — receives no new arrivals but keeps serving its
//!   backlog; once empty it parks (no request is ever lost to a scale
//!   event — the conservation property tests pin this);
//! * **Parked** — idle; its EPs are free (they stop accruing in the
//!   [`crate::serve::EpochStats::active_eps`] meter) until a scale-up
//!   re-activates the replica.
//!
//! The decision rule ([`decide`]) is a pure, RNG-free function of the
//! observed load, so autoscaled runs keep the engine's determinism
//! guarantee. It is deliberately **asymmetric**:
//!
//! * *scale up fast* — one pressure epoch (shed requests, queued backlog
//!   beyond [`AutoscaleOptions::backlog_frac`] of the queue slots, or an
//!   offered rate above active capacity) activates as many replicas as
//!   needed to bring the offered rate under
//!   [`AutoscaleOptions::target_util`] of capacity, highest-weight
//!   replicas first;
//! * *scale down slowly* — retiring the weakest active replica requires
//!   [`AutoscaleOptions::down_epochs`] consecutive slack epochs (nothing
//!   shed, nothing queued, and the offered rate low enough that the
//!   *remaining* replicas stay under [`AutoscaleOptions::scale_down_util`]
//!   utilisation), plus a cooldown after every scale event. The deadband
//!   between the up- and down-conditions is the hysteresis: a
//!   constant-rate workload inside it never triggers a scale event
//!   (property-tested), so oscillating load cannot thrash replicas.

use anyhow::{bail, Result};

/// State of one pipeline replica under autoscaling.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReplicaState {
    /// Routed to by the balancer and serving.
    #[default]
    Active,
    /// No longer routed to; serving out its backlog before parking.
    Draining,
    /// Idle with empty queues; its EPs are free until re-activated.
    Parked,
}

impl ReplicaState {
    /// Short display name (also the event-log spelling).
    pub fn name(self) -> &'static str {
        match self {
            ReplicaState::Active => "active",
            ReplicaState::Draining => "draining",
            ReplicaState::Parked => "parked",
        }
    }

    /// Stable code hashed into the serving event log.
    pub fn code(self) -> u64 {
        match self {
            ReplicaState::Active => 0,
            ReplicaState::Draining => 1,
            ReplicaState::Parked => 2,
        }
    }
}

/// One scale transition of a replica, recorded in
/// [`crate::serve::ShardReport::scale_events`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScaleEvent {
    /// Simulated time of the transition (an epoch tick), seconds.
    pub t_s: f64,
    /// The state the replica moved to.
    pub to: ReplicaState,
}

/// Autoscaler configuration (engine-wide; carried on
/// [`crate::serve::ServeOptions::autoscale`]).
#[derive(Debug, Clone)]
pub struct AutoscaleOptions {
    /// Master switch; when false the engine never changes replica states.
    pub enabled: bool,
    /// Floor on the number of active replicas per tenant (≥ 1).
    pub min_shards: usize,
    /// Scale-up sizing target: activate replicas until the offered rate
    /// is at most this fraction of active predicted capacity.
    pub target_util: f64,
    /// Scale-down gate: the weakest active replica retires only if the
    /// offered rate stays under this fraction of the *remaining* active
    /// capacity. Must sit below `target_util` — the gap is the hysteresis
    /// deadband.
    pub scale_down_util: f64,
    /// Pressure threshold: queued requests beyond this fraction of the
    /// active replicas' entry-queue slots count as pressure.
    pub backlog_frac: f64,
    /// Consecutive pressure epochs before scaling up (≥ 1).
    pub up_epochs: u32,
    /// Consecutive slack epochs before scaling down (≥ 1).
    pub down_epochs: u32,
    /// Hold epochs after any scale event before the next one.
    pub cooldown_epochs: u32,
}

impl Default for AutoscaleOptions {
    fn default() -> Self {
        Self {
            enabled: false,
            min_shards: 1,
            target_util: 0.75,
            scale_down_util: 0.6,
            backlog_frac: 0.25,
            up_epochs: 1,
            down_epochs: 2,
            cooldown_epochs: 1,
        }
    }
}

impl AutoscaleOptions {
    /// Enabled with defaults.
    pub fn enabled() -> Self {
        Self { enabled: true, ..Default::default() }
    }

    /// Validate the knobs (called by the engine when enabled).
    pub fn validate(&self) -> Result<()> {
        if self.min_shards == 0 {
            bail!("autoscale: min_shards must be ≥ 1");
        }
        if !(self.target_util > 0.0 && self.target_util <= 1.0) {
            bail!("autoscale: target_util must be in (0, 1]");
        }
        if !(self.scale_down_util > 0.0 && self.scale_down_util < self.target_util) {
            bail!("autoscale: scale_down_util must be in (0, target_util)");
        }
        if !(self.backlog_frac >= 0.0 && self.backlog_frac.is_finite()) {
            bail!("autoscale: backlog_frac must be finite and ≥ 0");
        }
        if self.up_epochs == 0 || self.down_epochs == 0 {
            bail!("autoscale: up_epochs and down_epochs must be ≥ 1");
        }
        Ok(())
    }
}

/// Elastic re-partition configuration (engine-wide; carried on
/// [`crate::serve::ServeOptions::elastic`]).
///
/// Where [`AutoscaleOptions`] moves replicas *within* a tenant's planned
/// EP budget, the elastic loop re-runs the cluster co-planner every
/// control epoch on the **observed** per-tenant demand and, when the
/// re-derived allocation is enough better than the one being served,
/// migrates queued requests onto the new EP partition. Both knobs below
/// exist to keep that loop from thrashing: a re-partition is a real
/// reconfiguration (arena migration + warm re-tune), so it must clear a
/// relative-gain bar and then hold through a cooldown.
#[derive(Debug, Clone)]
pub struct ElasticOptions {
    /// Master switch; when false the serve-start co-plan is final.
    pub enabled: bool,
    /// Minimum relative objective gain (fraction) the demand-driven plan
    /// must show over the live allocation before a re-partition fires.
    pub min_gain_frac: f64,
    /// Hold epochs after a re-partition before the next one may fire.
    pub cooldown_epochs: u32,
}

impl Default for ElasticOptions {
    fn default() -> Self {
        Self { enabled: false, min_gain_frac: 0.02, cooldown_epochs: 2 }
    }
}

impl ElasticOptions {
    /// Enabled with defaults.
    pub fn enabled() -> Self {
        Self { enabled: true, ..Default::default() }
    }

    /// Validate the knobs (called by the engine when enabled).
    pub fn validate(&self) -> Result<()> {
        if !(self.min_gain_frac >= 0.0 && self.min_gain_frac.is_finite()) {
            bail!("elastic: min_gain_frac must be finite and ≥ 0");
        }
        Ok(())
    }
}

/// Cooldown state for the elastic loop, one per run (engine-internal).
#[derive(Debug, Clone, Copy, Default)]
pub struct ElasticState {
    /// Epochs remaining before another re-partition may fire.
    pub cooldown: u32,
}

/// Decide whether a freshly derived demand-driven plan should replace
/// the live allocation this epoch.
///
/// Pure and deterministic, mirroring [`decide`]: the candidate plan's
/// objective must beat the live objective by at least
/// [`ElasticOptions::min_gain_frac`] (relative), and the loop must be
/// out of cooldown. A firing re-partition re-arms the cooldown.
pub fn decide_repartition(
    st: &mut ElasticState,
    opts: &ElasticOptions,
    live_objective: f64,
    plan_objective: f64,
) -> bool {
    if st.cooldown > 0 {
        st.cooldown -= 1;
        return false;
    }
    let bar = live_objective * (1.0 + opts.min_gain_frac);
    if plan_objective.is_finite() && plan_objective > bar {
        st.cooldown = opts.cooldown_epochs;
        return true;
    }
    false
}

/// Hysteresis state, one per tenant (engine-internal).
#[derive(Debug, Clone, Copy, Default)]
pub struct AutoscaleState {
    /// Consecutive pressure epochs observed.
    pub pressure_run: u32,
    /// Consecutive slack epochs observed.
    pub slack_run: u32,
    /// Epochs remaining before another scale event may fire.
    pub cooldown: u32,
}

/// One epoch's observation of a tenant, as the engine sees it at the
/// epoch tick (counters from the epoch that just closed, queue state and
/// replica states as of now).
#[derive(Debug, Clone)]
pub struct TenantLoad {
    /// Arrivals offered during the last epoch, per second.
    pub offered_rate: f64,
    /// Requests rejected or dropped during the last epoch.
    pub shed: u64,
    /// Requests currently waiting in the active replicas' queues
    /// (excludes batches in service).
    pub queued: u64,
    /// Entry-queue slots across active replicas (pressure denominator).
    pub queue_slots: u64,
    /// Currently active replicas.
    pub active: usize,
    /// Σ predicted throughput of the active replicas, req/s.
    pub active_capacity: f64,
    /// Smallest active replica's predicted throughput (the scale-down
    /// candidate), req/s.
    pub weakest_active: f64,
    /// Predicted throughputs of the non-active (draining or parked)
    /// replicas, **descending** — the scale-up candidates in activation
    /// order.
    pub inactive_weights: Vec<f64>,
}

/// What the controller decided this epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    /// No change.
    Hold,
    /// Activate the first `activate` replicas of
    /// [`TenantLoad::inactive_weights`] (highest predicted throughput
    /// first).
    Up {
        /// How many replicas to activate (≥ 1, ≤ inactive count).
        activate: usize,
    },
    /// Drain the weakest active replica.
    Down,
}

/// Advance the hysteresis state by one epoch and decide.
///
/// Pure and deterministic: same state + options + load always yields the
/// same decision. Pressure/slack runs keep accumulating through a
/// cooldown, so a sustained condition acts the moment the cooldown
/// expires.
pub fn decide(
    st: &mut AutoscaleState,
    opts: &AutoscaleOptions,
    load: &TenantLoad,
) -> ScaleDecision {
    let pressure = load.shed > 0
        || load.offered_rate > load.active_capacity
        || (load.queue_slots > 0
            && load.queued as f64 > opts.backlog_frac * load.queue_slots as f64);
    let can_shrink = load.active > opts.min_shards.max(1);
    let slack = !pressure
        && load.shed == 0
        && load.queued == 0
        && can_shrink
        && load.offered_rate
            <= opts.scale_down_util * (load.active_capacity - load.weakest_active);
    if pressure {
        st.pressure_run += 1;
        st.slack_run = 0;
    } else if slack {
        st.slack_run += 1;
        st.pressure_run = 0;
    } else {
        st.pressure_run = 0;
        st.slack_run = 0;
    }
    if st.cooldown > 0 {
        st.cooldown -= 1;
        return ScaleDecision::Hold;
    }
    if pressure && st.pressure_run >= opts.up_epochs && !load.inactive_weights.is_empty() {
        // activate until the offered rate fits under target utilisation
        let mut cap = load.active_capacity;
        let mut n = 0usize;
        for &w in &load.inactive_weights {
            if load.offered_rate <= opts.target_util * cap {
                break;
            }
            cap += w;
            n += 1;
        }
        let activate = n.clamp(1, load.inactive_weights.len());
        st.cooldown = opts.cooldown_epochs;
        st.pressure_run = 0;
        st.slack_run = 0;
        return ScaleDecision::Up { activate };
    }
    if slack && st.slack_run >= opts.down_epochs {
        st.cooldown = opts.cooldown_epochs;
        st.pressure_run = 0;
        st.slack_run = 0;
        return ScaleDecision::Down;
    }
    ScaleDecision::Hold
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> AutoscaleOptions {
        AutoscaleOptions::enabled()
    }

    fn load(rate: f64, active: usize, per_replica: f64, inactive: usize) -> TenantLoad {
        TenantLoad {
            offered_rate: rate,
            shed: 0,
            queued: 0,
            queue_slots: active as u64 * 64,
            active,
            active_capacity: active as f64 * per_replica,
            weakest_active: per_replica,
            inactive_weights: vec![per_replica; inactive],
        }
    }

    #[test]
    fn options_validate() {
        assert!(opts().validate().is_ok());
        assert!(AutoscaleOptions { min_shards: 0, ..opts() }.validate().is_err());
        assert!(AutoscaleOptions { target_util: 0.0, ..opts() }.validate().is_err());
        assert!(AutoscaleOptions { scale_down_util: 0.9, ..opts() }.validate().is_err());
        assert!(AutoscaleOptions { up_epochs: 0, ..opts() }.validate().is_err());
        assert!(AutoscaleOptions { backlog_frac: f64::NAN, ..opts() }.validate().is_err());
    }

    #[test]
    fn overload_scales_up_to_fit_target() {
        let o = opts();
        let mut st = AutoscaleState::default();
        // 1 active replica of capacity 10, rate 38: needs ≥ 51 capacity at
        // target 0.75 → activate all 4 remaining? 38/0.75 = 50.7 → cap
        // reaches 50 after 4 adds; the loop adds until ≤ target×cap
        let l = load(38.0, 1, 10.0, 4);
        match decide(&mut st, &o, &l) {
            ScaleDecision::Up { activate } => assert_eq!(activate, 4),
            other => panic!("expected Up, got {other:?}"),
        }
        assert_eq!(st.cooldown, o.cooldown_epochs);
    }

    #[test]
    fn mild_pressure_activates_at_least_one() {
        let o = opts();
        let mut st = AutoscaleState::default();
        let mut l = load(5.0, 2, 10.0, 2);
        l.shed = 3; // transient burst shed something but rate is low
        match decide(&mut st, &o, &l) {
            ScaleDecision::Up { activate } => assert_eq!(activate, 1),
            other => panic!("expected Up, got {other:?}"),
        }
    }

    #[test]
    fn steady_moderate_load_holds_forever() {
        let o = opts();
        let mut st = AutoscaleState::default();
        // 2 active × 10: rate 12 sits in the deadband (pressure needs
        // > 20, slack needs ≤ 0.6 × 10 = 6)
        for _ in 0..200 {
            assert_eq!(decide(&mut st, &o, &load(12.0, 2, 10.0, 2)), ScaleDecision::Hold);
        }
        assert_eq!(st.pressure_run, 0);
        assert_eq!(st.slack_run, 0);
    }

    #[test]
    fn slack_needs_consecutive_epochs_and_respects_floor() {
        let o = AutoscaleOptions { down_epochs: 3, cooldown_epochs: 0, ..opts() };
        let mut st = AutoscaleState::default();
        let quiet = load(2.0, 3, 10.0, 1); // 2 ≤ 0.6 × 20: slack
        assert_eq!(decide(&mut st, &o, &quiet), ScaleDecision::Hold);
        assert_eq!(decide(&mut st, &o, &quiet), ScaleDecision::Hold);
        assert_eq!(decide(&mut st, &o, &quiet), ScaleDecision::Down);
        // a pressure epoch resets the slack run
        let mut st = AutoscaleState::default();
        assert_eq!(decide(&mut st, &o, &quiet), ScaleDecision::Hold);
        let mut burst = quiet.clone();
        burst.shed = 1;
        assert!(matches!(decide(&mut st, &o, &burst), ScaleDecision::Up { .. }));
        // at the floor, slack never fires
        let mut st = AutoscaleState::default();
        let floor = load(0.1, 1, 10.0, 2);
        for _ in 0..10 {
            assert_eq!(decide(&mut st, &o, &floor), ScaleDecision::Hold);
        }
    }

    #[test]
    fn cooldown_defers_but_runs_accumulate() {
        let o = AutoscaleOptions { cooldown_epochs: 2, down_epochs: 2, ..opts() };
        let mut st = AutoscaleState::default();
        let over = load(35.0, 1, 10.0, 3);
        assert!(matches!(decide(&mut st, &o, &over), ScaleDecision::Up { .. }));
        // overload persists but cooldown holds two epochs
        assert_eq!(decide(&mut st, &o, &over), ScaleDecision::Hold);
        assert_eq!(decide(&mut st, &o, &over), ScaleDecision::Hold);
        // cooldown expired, the accumulated pressure run fires immediately
        assert!(matches!(decide(&mut st, &o, &over), ScaleDecision::Up { .. }));
    }

    #[test]
    fn queued_backlog_counts_as_pressure() {
        let o = opts();
        let mut st = AutoscaleState::default();
        let mut l = load(5.0, 2, 10.0, 1);
        l.queued = 40; // > 0.25 × 128 slots
        assert!(matches!(decide(&mut st, &o, &l), ScaleDecision::Up { .. }));
    }

    #[test]
    fn no_inactive_replicas_means_no_up() {
        let o = opts();
        let mut st = AutoscaleState::default();
        let l = load(35.0, 2, 10.0, 0);
        assert_eq!(decide(&mut st, &o, &l), ScaleDecision::Hold);
    }

    #[test]
    fn elastic_options_validate() {
        assert!(ElasticOptions::enabled().validate().is_ok());
        assert!(ElasticOptions { min_gain_frac: f64::NAN, ..ElasticOptions::enabled() }
            .validate()
            .is_err());
        assert!(ElasticOptions { min_gain_frac: -0.1, ..ElasticOptions::enabled() }
            .validate()
            .is_err());
    }

    #[test]
    fn repartition_needs_relative_gain() {
        let o =
            ElasticOptions { min_gain_frac: 0.05, cooldown_epochs: 0, ..ElasticOptions::enabled() };
        let mut st = ElasticState::default();
        // 4% better: under the 5% bar
        assert!(!decide_repartition(&mut st, &o, 100.0, 104.0));
        // 6% better: fires
        assert!(decide_repartition(&mut st, &o, 100.0, 106.0));
        // non-finite candidate never fires
        assert!(!decide_repartition(&mut st, &o, 100.0, f64::INFINITY));
        assert!(!decide_repartition(&mut st, &o, 100.0, f64::NAN));
    }

    #[test]
    fn repartition_cooldown_defers() {
        let o =
            ElasticOptions { min_gain_frac: 0.0, cooldown_epochs: 2, ..ElasticOptions::enabled() };
        let mut st = ElasticState::default();
        assert!(decide_repartition(&mut st, &o, 10.0, 11.0));
        assert_eq!(st.cooldown, 2);
        // the next two epochs hold even though the gain persists
        assert!(!decide_repartition(&mut st, &o, 10.0, 11.0));
        assert!(!decide_repartition(&mut st, &o, 10.0, 11.0));
        // cooldown expired: fires again
        assert!(decide_repartition(&mut st, &o, 10.0, 11.0));
    }

    #[test]
    fn replica_state_names_and_codes() {
        for (s, n, c) in [
            (ReplicaState::Active, "active", 0),
            (ReplicaState::Draining, "draining", 1),
            (ReplicaState::Parked, "parked", 2),
        ] {
            assert_eq!(s.name(), n);
            assert_eq!(s.code(), c);
        }
        assert_eq!(ReplicaState::default(), ReplicaState::Active);
    }
}
