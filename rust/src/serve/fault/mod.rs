//! Deterministic fault plane for the serving engine: scripted EP and
//! inter-chiplet-link faults injected as heap events.
//!
//! A [`FaultScript`] is a validated list of timed [`FaultEvent`]s against
//! platform resources:
//!
//! * **EP fail-stop** ([`FaultKind::EpFail`]) — the EP dies at `t` and
//!   never returns; in-flight batches on it are interrupted and requeued.
//! * **EP transient stall** ([`FaultKind::EpStall`]) — the EP stops
//!   serving for a window `[t, t + down_s)`, then comes back; the engine
//!   re-plans away from it and re-adopts it on recovery.
//! * **EP slowdown** ([`FaultKind::EpSlow`]) — a thermal-throttle style
//!   degradation: every batch on the EP runs `factor`× slower for the
//!   window, and the control loop folds the factor into its scratch
//!   re-tune database (`PerfDb::copy_scaled_from`) so warm re-tunes see
//!   the throttled machine.
//! * **Chiplet fail-stop** ([`FaultKind::ChipFail`]) — every EP on the
//!   chiplet fail-stops at once (partial-good die, power-domain loss).
//! * **Link degradation / cut** ([`FaultKind::LinkSlow`],
//!   [`FaultKind::LinkCut`]) — inter-chiplet transfers run `factor`×
//!   slower, or are blocked outright, for the window.
//!
//! Scripts are **deterministic by construction**: either hand-written
//! (CLI grammar below, `serve --faults`) or generated from a seed through
//! the repo's own [`crate::rng::Xoshiro256`] ([`FaultScript::chaos`],
//! `serve --chaos SEED`). The engine hashes every fault begin/end into
//! the event log (tag 7), so golden fingerprints pin faulted runs and the
//! flight recorder replays them bit-identically.
//!
//! # CLI grammar
//!
//! Events are `;`-separated (comma-free on purpose — the what-if override
//! parser splits its spec on commas, and `--what-if faults=...` embeds a
//! whole script as one value):
//!
//! ```text
//! epfail:EP@T            EP fail-stop at T seconds
//! epstall:EP@T+D         EP down for [T, T+D)
//! epslow:EPxF@T+D        EP runs F× slower for [T, T+D)
//! chipfail:C@T           chiplet C fail-stop at T
//! linkslow:F@T+D         inter-chiplet link F× slower for [T, T+D)
//! linkcut@T+D            inter-chiplet link blocked for [T, T+D)
//! ```
//!
//! e.g. `epslow:0x2.5@3+4; epfail:1@10; linkcut@12+1`.
//!
//! [`FaultScript::validate`] rejects out-of-range EP/chiplet ids,
//! non-finite or negative times, empty windows, factors ≤ 1, overlapping
//! windows on the same resource, and scripts that fail-stop every EP on
//! the platform (nothing could ever be served again — reject loudly at
//! construction instead of wedging the run).

use anyhow::{bail, Context, Result};

use crate::platform::{EpId, Platform};
use crate::rng::Xoshiro256;

/// One kind of resource fault. Windowed kinds carry their duration; the
/// fail-stop kinds are permanent (`[t, ∞)`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// EP fail-stop: dead from the event time onward.
    EpFail {
        /// Global EP id on the serving platform.
        ep: EpId,
    },
    /// EP transient stall: down for `[t, t + down_s)`, then healthy.
    EpStall {
        /// Global EP id on the serving platform.
        ep: EpId,
        /// Stall window length, seconds (> 0).
        down_s: f64,
    },
    /// EP slowdown: batches run `factor`× slower for the window.
    EpSlow {
        /// Global EP id on the serving platform.
        ep: EpId,
        /// Service-time multiplier (> 1).
        factor: f64,
        /// Throttle window length, seconds (> 0).
        down_s: f64,
    },
    /// Chiplet fail-stop: every EP on the chiplet dies at once.
    ChipFail {
        /// Chiplet id (must match at least one EP's `chiplet`).
        chiplet: u32,
    },
    /// Inter-chiplet link degradation: transfers run `factor`× slower.
    LinkSlow {
        /// Transfer-time multiplier (> 1).
        factor: f64,
        /// Degradation window length, seconds (> 0).
        down_s: f64,
    },
    /// Inter-chiplet link cut: cross-chiplet transfers blocked outright.
    LinkCut {
        /// Cut window length, seconds (> 0).
        down_s: f64,
    },
}

impl FaultKind {
    /// Stable wire/trace code (also the low byte of the hashed tag-7
    /// `a` word).
    pub fn code(self) -> u8 {
        match self {
            FaultKind::EpFail { .. } => 1,
            FaultKind::EpStall { .. } => 2,
            FaultKind::EpSlow { .. } => 3,
            FaultKind::ChipFail { .. } => 4,
            FaultKind::LinkSlow { .. } => 5,
            FaultKind::LinkCut { .. } => 6,
        }
    }

    /// CLI spelling (also used by `describe`/`trace inspect`).
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::EpFail { .. } => "epfail",
            FaultKind::EpStall { .. } => "epstall",
            FaultKind::EpSlow { .. } => "epslow",
            FaultKind::ChipFail { .. } => "chipfail",
            FaultKind::LinkSlow { .. } => "linkslow",
            FaultKind::LinkCut { .. } => "linkcut",
        }
    }

    /// Window length for transient kinds; `None` for the permanent
    /// fail-stops.
    pub fn window_s(self) -> Option<f64> {
        match self {
            FaultKind::EpFail { .. } | FaultKind::ChipFail { .. } => None,
            FaultKind::EpStall { down_s, .. }
            | FaultKind::EpSlow { down_s, .. }
            | FaultKind::LinkSlow { down_s, .. }
            | FaultKind::LinkCut { down_s } => Some(down_s),
        }
    }
}

/// One scripted fault: a kind and the simulated time it begins.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Simulated begin time, seconds from serve start.
    pub t_s: f64,
    /// What breaks.
    pub kind: FaultKind,
}

impl FaultEvent {
    /// Render in the CLI grammar (`parse(describe())` round-trips).
    fn describe(&self) -> String {
        let t = self.t_s;
        match self.kind {
            FaultKind::EpFail { ep } => format!("epfail:{ep}@{t}"),
            FaultKind::EpStall { ep, down_s } => format!("epstall:{ep}@{t}+{down_s}"),
            FaultKind::EpSlow { ep, factor, down_s } => {
                format!("epslow:{ep}x{factor}@{t}+{down_s}")
            }
            FaultKind::ChipFail { chiplet } => format!("chipfail:{chiplet}@{t}"),
            FaultKind::LinkSlow { factor, down_s } => format!("linkslow:{factor}@{t}+{down_s}"),
            FaultKind::LinkCut { down_s } => format!("linkcut@{t}+{down_s}"),
        }
    }
}

/// A validated, ordered list of scripted faults — the whole fault plane
/// of one serving run. The empty script (the default) injects nothing
/// and leaves every engine hash byte-identical to a fault-free build.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultScript {
    /// Scripted faults, in script order (times need not be sorted; the
    /// engine's event heap orders them).
    pub events: Vec<FaultEvent>,
}

impl FaultScript {
    /// True when the script injects nothing.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse the `;`-separated CLI grammar (see the module docs).
    pub fn parse(s: &str) -> Result<FaultScript> {
        let mut events = Vec::new();
        for item in s.split(';') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            events.push(parse_event(item).with_context(|| format!("fault spec {item:?}"))?);
        }
        Ok(FaultScript { events })
    }

    /// Render the whole script in the CLI grammar; `parse` round-trips
    /// it. The empty script renders as `"none"`.
    pub fn describe(&self) -> String {
        if self.events.is_empty() {
            return "none".to_string();
        }
        let parts: Vec<String> = self.events.iter().map(FaultEvent::describe).collect();
        parts.join("; ")
    }

    /// Check the script against the serving platform. Rejects (with one
    /// actionable error each):
    ///
    /// * EP ids ≥ `plat.n_eps()` and chiplet ids no EP lives on;
    /// * non-finite or negative begin times;
    /// * windows with `down_s` ≤ 0 or non-finite, factors ≤ 1 or
    ///   non-finite;
    /// * overlapping windows on the same EP (a fail-stop counts as
    ///   `[t, ∞)`, a chiplet fail covers all its EPs) or on the link —
    ///   overlap would make "which fault owns this resource now"
    ///   ambiguous;
    /// * fail-stopping every EP on the platform.
    pub fn validate(&self, plat: &Platform) -> Result<()> {
        let n_eps = plat.n_eps();
        // (start, end) windows per EP and for the link, for overlap checks.
        let mut ep_windows: Vec<Vec<(f64, f64)>> = vec![Vec::new(); n_eps];
        let mut link_windows: Vec<(f64, f64)> = Vec::new();
        let mut failed = vec![false; n_eps];
        for ev in &self.events {
            let t = ev.t_s;
            if !t.is_finite() || t < 0.0 {
                bail!("fault {}: begin time {t} must be finite and ≥ 0", ev.describe());
            }
            if let Some(d) = ev.kind.window_s() {
                if !d.is_finite() || d <= 0.0 {
                    bail!("fault {}: window {d} must be finite and > 0", ev.describe());
                }
            }
            match ev.kind {
                FaultKind::EpFail { ep } => {
                    check_ep(ep, n_eps, ev)?;
                    ep_windows[ep].push((t, f64::INFINITY));
                    failed[ep] = true;
                }
                FaultKind::EpStall { ep, down_s } => {
                    check_ep(ep, n_eps, ev)?;
                    ep_windows[ep].push((t, t + down_s));
                }
                FaultKind::EpSlow { ep, factor, down_s } => {
                    check_ep(ep, n_eps, ev)?;
                    check_factor(factor, ev)?;
                    ep_windows[ep].push((t, t + down_s));
                }
                FaultKind::ChipFail { chiplet } => {
                    let mut any = false;
                    for (ep, place) in plat.eps.iter().enumerate() {
                        if place.chiplet == chiplet {
                            ep_windows[ep].push((t, f64::INFINITY));
                            failed[ep] = true;
                            any = true;
                        }
                    }
                    if !any {
                        bail!(
                            "fault {}: no EP on platform {} lives on chiplet {chiplet}",
                            ev.describe(),
                            plat.name
                        );
                    }
                }
                FaultKind::LinkSlow { factor, down_s } => {
                    check_factor(factor, ev)?;
                    link_windows.push((t, t + down_s));
                }
                FaultKind::LinkCut { down_s } => {
                    link_windows.push((t, t + down_s));
                }
            }
        }
        if !failed.is_empty() && failed.iter().all(|&f| f) {
            bail!(
                "fault script fail-stops all {n_eps} EPs of platform {} — nothing could ever \
                 be served again (keep at least one EP alive)",
                plat.name
            );
        }
        for (ep, windows) in ep_windows.iter_mut().enumerate() {
            if let Some((a, b)) = overlapping(windows) {
                bail!(
                    "fault script has overlapping windows on EP {ep}: [{}, {}) and [{}, {}) — \
                     one fault per resource at a time",
                    a.0,
                    a.1,
                    b.0,
                    b.1
                );
            }
        }
        if let Some((a, b)) = overlapping(&mut link_windows) {
            bail!(
                "fault script has overlapping inter-chiplet link windows: [{}, {}) and \
                 [{}, {}) — one fault per resource at a time",
                a.0,
                a.1,
                b.0,
                b.1
            );
        }
        Ok(())
    }

    /// Generate a valid-by-construction chaos script: `n` faults dealt
    /// into disjoint time slots across the middle 80% of the horizon,
    /// each window confined to its slot (so windows never overlap),
    /// permanently failed EPs never re-targeted, and never failing the
    /// last healthy EP. Deterministic in `(seed, plat, duration_s, n)`.
    pub fn chaos(seed: u64, plat: &Platform, duration_s: f64, n: usize) -> FaultScript {
        let n_eps = plat.n_eps();
        let mut rng = Xoshiro256::seed_from(seed);
        let mut failed = vec![false; n_eps];
        let mut events = Vec::with_capacity(n);
        if n == 0 || duration_s <= 0.0 || n_eps == 0 {
            return FaultScript { events };
        }
        let t0 = 0.1 * duration_s;
        let slot = 0.8 * duration_s / n as f64;
        for i in 0..n {
            let start = t0 + i as f64 * slot;
            let down = 0.5 * slot;
            let alive: Vec<EpId> = (0..n_eps).filter(|&e| !failed[e]).collect();
            let factor = 1.5 + 2.0 * rng.gen_f64();
            let kind = match rng.gen_range(0, 6) {
                0 if alive.len() > 1 => {
                    let ep = *rng.choose(&alive);
                    failed[ep] = true;
                    FaultKind::EpFail { ep }
                }
                0 | 1 => FaultKind::EpStall { ep: *rng.choose(&alive), down_s: down },
                2 | 3 => FaultKind::EpSlow { ep: *rng.choose(&alive), factor, down_s: down },
                4 => FaultKind::LinkSlow { factor, down_s: down },
                _ => FaultKind::LinkCut { down_s: down },
            };
            events.push(FaultEvent { t_s: start, kind });
        }
        let script = FaultScript { events };
        debug_assert!(script.validate(plat).is_ok(), "chaos generated an invalid script");
        script
    }
}

fn check_ep(ep: EpId, n_eps: usize, ev: &FaultEvent) -> Result<()> {
    if ep >= n_eps {
        bail!(
            "fault {}: EP {ep} is out of range (platform has {n_eps} EPs, ids 0..{n_eps})",
            ev.describe()
        );
    }
    Ok(())
}

fn check_factor(factor: f64, ev: &FaultEvent) -> Result<()> {
    if !factor.is_finite() || factor <= 1.0 {
        bail!(
            "fault {}: slowdown factor {factor} must be finite and > 1 (it multiplies \
             service time)",
            ev.describe()
        );
    }
    Ok(())
}

/// Find one overlapping pair among `[start, end)` windows, if any. Sorts
/// in place; touching endpoints (`end == next start`) are allowed.
fn overlapping(windows: &mut [(f64, f64)]) -> Option<((f64, f64), (f64, f64))> {
    windows.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
    windows.windows(2).find(|w| w[1].0 < w[0].1).map(|w| (w[0], w[1]))
}

/// Parse one event in the CLI grammar.
fn parse_event(item: &str) -> Result<FaultEvent> {
    let (head, when) = item
        .split_once('@')
        .context("expected KIND[:ARGS]@T or KIND[:ARGS]@T+D (no '@' found)")?;
    let (t_s, down_s) = match when.split_once('+') {
        Some((t, d)) => (parse_f64(t, "begin time")?, Some(parse_f64(d, "window length")?)),
        None => (parse_f64(when, "begin time")?, None),
    };
    let (kind_name, args) = match head.split_once(':') {
        Some((k, a)) => (k.trim(), Some(a.trim())),
        None => (head.trim(), None),
    };
    let need_window = |kind: &str| {
        down_s.with_context(|| {
            format!("{kind} is transient: expected a window, e.g. {kind}...@T+D")
        })
    };
    let no_window = |kind: &str| -> Result<()> {
        if down_s.is_some() {
            bail!("{kind} is permanent: use {kind}:ID@T (no +D window)");
        }
        Ok(())
    };
    let kind = match kind_name.to_ascii_lowercase().as_str() {
        "epfail" => {
            no_window("epfail")?;
            FaultKind::EpFail { ep: parse_id(args.context("epfail needs an EP id")?, "EP id")? }
        }
        "epstall" => FaultKind::EpStall {
            ep: parse_id(args.context("epstall needs an EP id")?, "EP id")?,
            down_s: need_window("epstall")?,
        },
        "epslow" => {
            let args = args.context("epslow needs EPxFACTOR, e.g. epslow:0x2.5@3+4")?;
            let (ep, factor) = args
                .split_once(|c| c == 'x' || c == 'X')
                .context("epslow needs EPxFACTOR (no 'x' found)")?;
            FaultKind::EpSlow {
                ep: parse_id(ep, "EP id")?,
                factor: parse_f64(factor, "slowdown factor")?,
                down_s: need_window("epslow")?,
            }
        }
        "chipfail" => {
            no_window("chipfail")?;
            FaultKind::ChipFail {
                chiplet: parse_id(args.context("chipfail needs a chiplet id")?, "chiplet id")?
                    as u32,
            }
        }
        "linkslow" => FaultKind::LinkSlow {
            factor: parse_f64(args.context("linkslow needs a factor")?, "slowdown factor")?,
            down_s: need_window("linkslow")?,
        },
        "linkcut" => {
            if args.is_some() {
                bail!("linkcut takes no arguments: linkcut@T+D");
            }
            FaultKind::LinkCut { down_s: need_window("linkcut")? }
        }
        other => bail!(
            "unknown fault kind {other:?} (epfail, epstall, epslow, chipfail, linkslow, linkcut)"
        ),
    };
    Ok(FaultEvent { t_s, kind })
}

fn parse_f64(s: &str, what: &str) -> Result<f64> {
    s.trim().parse::<f64>().with_context(|| format!("bad {what} {:?}", s.trim()))
}

fn parse_id(s: &str, what: &str) -> Result<usize> {
    s.trim().parse::<usize>().with_context(|| format!("bad {what} {:?}", s.trim()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::configs;

    #[test]
    fn parse_round_trips_every_kind() {
        let s = "epfail:1@5; epstall:0@2+1.5; epslow:2x2.5@3+4; chipfail:1@8; \
                 linkslow:3@1+2; linkcut@10+0.5";
        let script = FaultScript::parse(s).unwrap();
        assert_eq!(script.events.len(), 6);
        assert_eq!(script.events[0].kind, FaultKind::EpFail { ep: 1 });
        assert_eq!(script.events[2].kind, FaultKind::EpSlow { ep: 2, factor: 2.5, down_s: 4.0 });
        assert_eq!(script.events[3].kind, FaultKind::ChipFail { chiplet: 1 });
        let reparsed = FaultScript::parse(&script.describe()).unwrap();
        assert_eq!(script, reparsed);
        assert_eq!(FaultScript::default().describe(), "none");
        assert_eq!(FaultScript::parse("").unwrap(), FaultScript::default());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "epfail:1",        // no time
            "epfail:1@5+2",    // permanent kind with a window
            "epstall:1@5",     // transient kind without a window
            "epslow:1@3+4",    // missing factor
            "linkcut:3@1+2",   // linkcut takes no args
            "explode:1@5",     // unknown kind
            "epfail:xyz@5",    // bad id
            "epfail:1@lots",   // bad time
        ] {
            assert!(FaultScript::parse(bad).is_err(), "{bad:?} should fail to parse");
        }
    }

    #[test]
    fn validate_rejects_each_audit_case() {
        let plat = configs::c2(); // 4 EPs, chiplets 0 and 1
        let n = plat.n_eps();
        let ok = |s: &str| FaultScript::parse(s).unwrap().validate(&plat);
        // Out-of-range EP id.
        let err = ok(&format!("epfail:{n}@1")).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        // Unknown chiplet id.
        let err = ok("chipfail:99@1").unwrap_err();
        assert!(err.to_string().contains("chiplet 99"), "{err}");
        // Negative / non-finite times and empty windows.
        assert!(ok("epfail:0@-1").is_err());
        assert!(ok("epfail:0@nan").is_err());
        assert!(ok("epstall:0@1+0").is_err());
        assert!(ok("epstall:0@1+-2").is_err());
        // Factors must exceed 1.
        assert!(ok("epslow:0x1.0@1+2").is_err());
        assert!(ok("linkslow:0.5@1+2").is_err());
        // Overlapping windows on one EP (stall/slow mix counts).
        let err = ok("epstall:0@1+3; epslow:0x2@2+5").unwrap_err();
        assert!(err.to_string().contains("overlapping windows on EP 0"), "{err}");
        // A fail-stop owns [t, ∞): later windows on the same EP overlap.
        assert!(ok("epfail:0@1; epstall:0@5+1").is_err());
        // Chiplet fail covers its member EPs.
        assert!(ok("chipfail:0@1; epstall:0@5+1").is_err());
        // Overlapping link windows.
        let err = ok("linkcut@1+3; linkslow:2@2+1").unwrap_err();
        assert!(err.to_string().contains("link windows"), "{err}");
        // Failing every EP is rejected.
        let all: Vec<String> = (0..n).map(|e| format!("epfail:{e}@1")).collect();
        let err = ok(&all.join("; ")).unwrap_err();
        assert!(err.to_string().contains("fail-stops all"), "{err}");
        // Touching windows and disjoint windows pass.
        assert!(ok("epstall:0@1+2; epstall:0@3+2").is_ok());
        assert!(ok("epfail:0@1; epstall:1@5+1; linkcut@1+1; linkslow:2@2+1").is_ok());
    }

    #[test]
    fn chaos_is_deterministic_and_valid() {
        let plat = configs::c5();
        let a = FaultScript::chaos(7, &plat, 60.0, 12);
        let b = FaultScript::chaos(7, &plat, 60.0, 12);
        assert_eq!(a, b, "same seed must generate the same script");
        assert_eq!(a.events.len(), 12);
        a.validate(&plat).expect("chaos scripts are valid by construction");
        let c = FaultScript::chaos(8, &plat, 60.0, 12);
        assert_ne!(a, c, "different seeds should differ");
        // Round-trips through the CLI grammar too.
        assert_eq!(FaultScript::parse(&a.describe()).unwrap(), a);
        // Never fails the last EP: at least one survives any chaos script.
        let many = FaultScript::chaos(3, &plat, 1000.0, 200);
        many.validate(&plat).unwrap();
        let mut failed = vec![false; plat.n_eps()];
        for ev in &many.events {
            if let FaultKind::EpFail { ep } = ev.kind {
                failed[ep] = true;
            }
        }
        assert!(failed.iter().any(|&f| !f));
    }
}
