//! Sharded serving: replicate a tenant's pipeline across disjoint EP
//! subsets behind a front-end load balancer.
//!
//! A single Shisha pipeline is throughput-bound by its slowest stage; once
//! that stage is a single indivisible layer, adding EPs to the *same*
//! pipeline cannot help. Replication can: partition the platform's EPs
//! into `k` disjoint subsets, run one tuned replica per subset, and split
//! arrivals across the replicas — the ROADMAP's "sharded serving" item,
//! and the inter-layer multi-instance placement argument of Odema et al.
//! (2312.09401) / Scope (2602.14393).
//!
//! This module is the **placement search**:
//!
//! * [`candidate_partitions`] proposes deterministic ways of dealing the
//!   platform's EPs (ranked by [`crate::platform::Platform::eps_by_rank`])
//!   into `k` disjoint, heterogeneity-balanced or class-contiguous bins;
//! * [`plan_shards`] tunes every candidate partition for every shard count
//!   `1..=k` through the partition-then-tune driver
//!   ([`crate::explore::partition`] — exhaustive on small restricted
//!   spaces, Shisha otherwise) and keeps the plan with the highest total
//!   predicted throughput. Because the 1-shard plan is always a candidate,
//!   the chosen plan's predicted throughput is **monotonically
//!   non-decreasing in `k`**: asking for more replicas never plans a
//!   slower deployment ("shards" on [`super::TenantSpec`] is a maximum,
//!   not a mandate).
//!
//! The serving engine ([`super::engine`]) materialises a plan as one
//! replica runtime per subset (own queues, slab arena, scratch re-tune
//! database, sub-platform view) and routes each arrival through the
//! tenant's [`BalancerPolicy`].

use anyhow::{bail, Result};

use crate::explore::partition::{tune_partition_cached, SubsetPlan};
use crate::explore::PlanCache;
use crate::model::Network;
use crate::pipeline::PipelineConfig;
use crate::platform::{EpId, Platform};

/// How a sharded tenant's front-end spreads arrivals over its replicas.
///
/// All policies are deterministic (no RNG): a serving run remains a pure
/// function of its seed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BalancerPolicy {
    /// Cycle through replicas in index order.
    #[default]
    RoundRobin,
    /// Route to the least-loaded replica: smallest total backlog (queued
    /// plus in-service requests across all stages), with replicas frozen
    /// in a reconfiguration penalty window deprioritized outright. Ties
    /// break on the lowest replica index.
    JoinShortestQueue,
    /// Smooth weighted round-robin with each replica weighted by its
    /// predicted (analytic) throughput — faster replicas receive
    /// proportionally more arrivals.
    WeightedThroughput,
}

impl BalancerPolicy {
    /// Short display name (also the CLI spelling).
    pub fn name(self) -> &'static str {
        match self {
            BalancerPolicy::RoundRobin => "rr",
            BalancerPolicy::JoinShortestQueue => "jsq",
            BalancerPolicy::WeightedThroughput => "wtp",
        }
    }

    /// Parse a CLI spelling (`rr`, `jsq`, `wtp` and long aliases).
    pub fn parse(s: &str) -> Result<BalancerPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" | "roundrobin" => Ok(BalancerPolicy::RoundRobin),
            "jsq" | "join-shortest-queue" => Ok(BalancerPolicy::JoinShortestQueue),
            "wtp" | "weighted" | "weighted-throughput" => Ok(BalancerPolicy::WeightedThroughput),
            other => bail!("unknown balancer {other:?} (rr, jsq, wtp)"),
        }
    }
}

/// Pick the replica a queued straggler is hedged onto: the least-loaded
/// eligible sibling — the same `(backlog, index)` key JSQ routes with —
/// never the replica the straggler already waits on. `candidates` holds
/// `(replica index, total backlog)` pairs the caller has already filtered
/// to Active, un-frozen, alive replicas; ties break on the lowest index,
/// so hedge placement is as deterministic as every other routing
/// decision. Returns `None` when no sibling is eligible (hedging is then
/// skipped for this request, never queued for later).
pub fn hedge_sibling(primary: usize, candidates: &[(usize, u64)]) -> Option<usize> {
    candidates
        .iter()
        .filter(|(ix, _)| *ix != primary)
        .min_by_key(|(ix, backlog)| (*backlog, *ix))
        .map(|(ix, _)| *ix)
}

/// A concrete shard placement: disjoint EP subsets with one tuned replica
/// configuration per subset.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// Disjoint EP subsets (global EP ids); `partitions[s]` is also the
    /// local-to-global id map of shard `s`'s sub-platform.
    pub partitions: Vec<Vec<EpId>>,
    /// Replica configuration per shard, in the **local** EP ids of that
    /// shard's sub-platform ([`Platform::subset`] of the partition entry).
    pub configs: Vec<PipelineConfig>,
    /// Analytic steady-state throughput per replica, img/s.
    pub predicted: Vec<f64>,
    /// Which candidate strategy produced the winning partition.
    pub strategy: &'static str,
}

impl ShardPlan {
    /// Number of replicas.
    pub fn n_shards(&self) -> usize {
        self.partitions.len()
    }

    /// Sum of per-replica predicted throughputs (the planning objective).
    pub fn total_predicted(&self) -> f64 {
        self.predicted.iter().sum()
    }

    /// Replica configurations translated to **global** EP ids (display /
    /// reporting; the engine keeps local ids internally).
    pub fn global_configs(&self) -> Vec<PipelineConfig> {
        self.configs
            .iter()
            .zip(&self.partitions)
            .map(|(cfg, map)| to_global(cfg, map))
            .collect()
    }
}

/// Translate a local-EP-id configuration to global ids via the shard's
/// local-to-global map.
pub fn to_global(cfg: &PipelineConfig, ep_map: &[EpId]) -> PipelineConfig {
    PipelineConfig::new(
        cfg.stages.clone(),
        cfg.assignment.iter().map(|&e| ep_map[e]).collect(),
    )
}

/// Deal `items` round-robin into `k` bins: bin `i` gets items `i`, `i+k`, …
fn deal<T: Copy>(items: &[T], k: usize) -> Vec<Vec<T>> {
    let mut bins = vec![Vec::new(); k];
    for (i, &x) in items.iter().enumerate() {
        bins[i % k].push(x);
    }
    bins
}

/// Snake-deal `items` into `k` bins (0,1,…,k−1,k−1,…,1,0,0,1,…): pairs the
/// best remaining EP with the worst-served bin, balancing aggregate
/// performance more tightly than a plain deal.
fn snake<T: Copy>(items: &[T], k: usize) -> Vec<Vec<T>> {
    let mut bins = vec![Vec::new(); k];
    for (i, &x) in items.iter().enumerate() {
        let lap = i / k;
        let pos = i % k;
        let bin = if lap % 2 == 0 { pos } else { k - 1 - pos };
        bins[bin].push(x);
    }
    bins
}

/// Split `items` into `k` contiguous blocks of near-equal size (earlier
/// blocks take the remainder) — class-contiguous partitions: on a ranked
/// EP list this groups FEPs with FEPs and SEPs with SEPs.
fn blocks<T: Copy>(items: &[T], k: usize) -> Vec<Vec<T>> {
    let n = items.len();
    let base = n / k;
    let extra = n % k;
    let mut bins = Vec::with_capacity(k);
    let mut lo = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        bins.push(items[lo..lo + len].to_vec());
        lo += len;
    }
    bins
}

/// Deterministic candidate partitions of the platform's EPs into `k`
/// disjoint, non-empty subsets, each tagged with its strategy name.
/// Requires `1 ≤ k ≤ n_eps`. For `k = 1` the single candidate keeps EPs in
/// **platform id order**, so a 1-shard plan tunes exactly the full
/// platform (byte-identical to [`super::shisha_config`]'s search).
pub fn candidate_partitions(plat: &Platform, k: usize) -> Vec<(&'static str, Vec<Vec<EpId>>)> {
    assert!(
        (1..=plat.n_eps()).contains(&k),
        "candidate_partitions: 1 <= k <= n_eps"
    );
    if k == 1 {
        return vec![("full", vec![(0..plat.n_eps()).collect()])];
    }
    let ranked = plat.eps_by_rank();
    let mut out: Vec<(&'static str, Vec<Vec<EpId>>)> = Vec::new();
    for (name, parts) in [
        ("rank-deal", deal(&ranked, k)),
        ("rank-snake", snake(&ranked, k)),
        ("rank-blocks", blocks(&ranked, k)),
    ] {
        debug_assert!(parts.iter().all(|p| !p.is_empty()), "k <= n_eps keeps bins non-empty");
        // skip duplicates (e.g. deal == snake when each bin holds one EP)
        if !out.iter().any(|(_, seen)| *seen == parts) {
            out.push((name, parts));
        }
    }
    out
}

/// Search shard placements for up to `max_shards` replicas of `net` on
/// `plat` and return the best plan by total predicted throughput.
///
/// Every shard count `1..=min(max_shards, n_eps)` and every candidate
/// partition is tuned; ties keep the earlier (fewer-shard,
/// earlier-strategy) plan, so results are deterministic and
/// `plan_shards(net, plat, k+1)` never predicts below
/// `plan_shards(net, plat, k)` (the candidate sets nest).
///
/// Convenience wrapper over [`plan_shards_with`] with a fresh (single-use)
/// plan cache and no worker threads — callers that plan repeatedly (the
/// co-planner, sweeps over shard budgets, benches) should hold a shared
/// [`PlanCache`] and call [`plan_shards_with`] instead.
pub fn plan_shards(net: &Network, plat: &Platform, max_shards: usize) -> Result<ShardPlan> {
    plan_shards_with(net, plat, max_shards, 1, &PlanCache::new())
}

/// [`plan_shards`] with an explicit subset-tuning memo and a worker-thread
/// budget.
///
/// The `(shard count, candidate partition)` pairs form a worklist tuned
/// across up to `threads` workers (the same fixed-pool/atomic-counter
/// pattern as [`crate::serve::sweep`]; `threads <= 1` stays inline), all
/// sharing `cache`. The reduction then scans results **in the sequential
/// worklist order** with the same strict-improvement comparison, so the
/// chosen plan is bit-identical to the single-threaded, uncached search
/// regardless of thread count or cache history.
pub fn plan_shards_with(
    net: &Network,
    plat: &Platform,
    max_shards: usize,
    threads: usize,
    cache: &PlanCache,
) -> Result<ShardPlan> {
    if max_shards == 0 {
        bail!("plan_shards: at least one shard required");
    }
    if net.is_empty() {
        bail!("plan_shards: empty network");
    }
    let kmax = max_shards.min(plat.n_eps());
    let mut jobs: Vec<(&'static str, Vec<Vec<EpId>>)> = Vec::new();
    for k in 1..=kmax {
        jobs.extend(candidate_partitions(plat, k));
    }
    // a fully-warm worklist is pure hash lookups — spawning a pool for it
    // would cost orders of magnitude more than the lookups themselves
    // (the common case for the co-planner's water-filling re-probes and
    // any periodic re-plan), so only fan out when real tuning remains
    let any_cold = |jobs: &[(&'static str, Vec<Vec<EpId>>)]| {
        jobs.iter().any(|(_, parts)| {
            parts.iter().any(|eps| !cache.contains(net, plat, eps, None, SHARD_TUNE_EVALS))
        })
    };
    let tuned: Vec<Vec<SubsetPlan>> = if threads <= 1 || jobs.len() <= 1 || !any_cold(&jobs) {
        jobs.iter()
            .map(|(_, parts)| tune_partition_cached(net, plat, parts, SHARD_TUNE_EVALS, cache))
            .collect()
    } else {
        tune_jobs_parallel(net, plat, &jobs, threads, cache)
    };
    let mut best: Option<ShardPlan> = None;
    for ((strategy, parts), plans) in jobs.into_iter().zip(tuned) {
        let plan = ShardPlan {
            predicted: plans.iter().map(|p| p.predicted_throughput).collect(),
            configs: plans.into_iter().map(|p| p.config).collect(),
            partitions: parts,
            strategy,
        };
        if best.as_ref().map_or(true, |b| plan.total_predicted() > b.total_predicted()) {
            best = Some(plan);
        }
    }
    Ok(best.expect("kmax >= 1 evaluates at least one candidate"))
}

/// Fan the candidate worklist over a fixed thread pool (results land in
/// per-job slots, so the caller's reduction order is input order).
fn tune_jobs_parallel(
    net: &Network,
    plat: &Platform,
    jobs: &[(&'static str, Vec<Vec<EpId>>)],
    threads: usize,
    cache: &PlanCache,
) -> Vec<Vec<SubsetPlan>> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<Vec<SubsetPlan>>> = Vec::new();
    slots.resize_with(jobs.len(), || None);
    let results = Mutex::new(slots);
    std::thread::scope(|s| {
        for _ in 0..threads.min(jobs.len()) {
            s.spawn(|| loop {
                let ix = next.fetch_add(1, Ordering::Relaxed);
                if ix >= jobs.len() {
                    break;
                }
                let plans =
                    tune_partition_cached(net, plat, &jobs[ix].1, SHARD_TUNE_EVALS, cache);
                results.lock().expect("plan worklist mutex poisoned")[ix] = Some(plans);
            });
        }
    });
    results
        .into_inner()
        .expect("plan worklist mutex poisoned")
        .into_iter()
        .map(|o| o.expect("every job index was claimed exactly once"))
        .collect()
}

/// Shisha evaluation budget per subset when the restricted space is too
/// large to enumerate — matches [`super::shisha_config`]'s budget so the
/// 1-shard plan reproduces the unsharded initial configuration.
pub const SHARD_TUNE_EVALS: u64 = 500;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::networks;
    use crate::platform::configs;

    fn assert_disjoint_cover(parts: &[Vec<EpId>], n_eps: usize) {
        let mut seen = vec![false; n_eps];
        for p in parts {
            assert!(!p.is_empty(), "no empty bins");
            for &e in p {
                assert!(e < n_eps);
                assert!(!seen[e], "EP {e} in two bins");
                seen[e] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every EP covered");
    }

    #[test]
    fn candidates_are_disjoint_covering_partitions() {
        for plat in configs::all_c() {
            for k in 1..=plat.n_eps().min(4) {
                let cands = candidate_partitions(&plat, k);
                assert!(!cands.is_empty());
                for (name, parts) in &cands {
                    assert_eq!(parts.len(), k, "{name} on {}", plat.name);
                    assert_disjoint_cover(parts, plat.n_eps());
                }
            }
        }
    }

    #[test]
    fn snake_balances_rank_pairs() {
        // 8 ranked items into 4 bins: snake pairs best with worst
        let items: Vec<usize> = (0..8).collect();
        let bins = snake(&items, 4);
        assert_eq!(bins[0], vec![0, 7]);
        assert_eq!(bins[3], vec![3, 4]);
    }

    #[test]
    fn balancer_policy_parses_and_names() {
        for (s, want) in [
            ("rr", BalancerPolicy::RoundRobin),
            ("round-robin", BalancerPolicy::RoundRobin),
            ("jsq", BalancerPolicy::JoinShortestQueue),
            ("wtp", BalancerPolicy::WeightedThroughput),
            ("weighted", BalancerPolicy::WeightedThroughput),
        ] {
            let got = BalancerPolicy::parse(s).unwrap();
            assert_eq!(got, want);
            assert_eq!(BalancerPolicy::parse(got.name()).unwrap(), got);
        }
        assert!(BalancerPolicy::parse("random").is_err());
    }

    #[test]
    fn hedge_sibling_is_least_loaded_and_never_primary() {
        // lowest backlog wins; the primary is excluded even when emptiest
        assert_eq!(hedge_sibling(0, &[(0, 0), (1, 5), (2, 3)]), Some(2));
        // ties break on the lowest index
        assert_eq!(hedge_sibling(1, &[(0, 2), (1, 0), (2, 2)]), Some(0));
        // no eligible sibling → no hedge
        assert_eq!(hedge_sibling(0, &[(0, 7)]), None);
        assert_eq!(hedge_sibling(0, &[]), None);
    }

    #[test]
    fn plan_configs_valid_on_their_subsets() {
        let net = networks::synthnet();
        let plat = configs::c5();
        let plan = plan_shards(&net, &plat, 4).unwrap();
        assert!((1..=4).contains(&plan.n_shards()));
        assert_disjoint_cover_subsets(&plan, plat.n_eps());
        for (cfg, eps) in plan.configs.iter().zip(&plan.partitions) {
            let sub = plat.subset(eps);
            assert!(cfg.validate(net.len(), &sub).is_ok(), "{}", cfg.describe());
        }
        // global translation stays inside the shard's subset
        for (g, eps) in plan.global_configs().iter().zip(&plan.partitions) {
            for ep in &g.assignment {
                assert!(eps.contains(ep), "global id {ep} outside its partition");
            }
        }
    }

    fn assert_disjoint_cover_subsets(plan: &ShardPlan, n_eps: usize) {
        let mut seen = vec![false; n_eps];
        for p in &plan.partitions {
            for &e in p {
                assert!(!seen[e], "shard subsets overlap on EP {e}");
                seen[e] = true;
            }
        }
    }

    #[test]
    fn predicted_throughput_monotone_in_max_shards() {
        let net = networks::synthnet();
        let plat = configs::c5();
        let mut prev = 0.0;
        for k in [1usize, 2, 4] {
            let plan = plan_shards(&net, &plat, k).unwrap();
            let total = plan.total_predicted();
            assert!(
                total >= prev,
                "max_shards {k}: predicted {total} fell below {prev}"
            );
            prev = total;
        }
    }

    #[test]
    fn replication_beats_single_pipeline_on_c5() {
        // The headline: SynthNet's bottleneck layer caps any single
        // pipeline, while 4 replicas of (1 FEP + 1 SEP) each add capacity.
        let net = networks::synthnet();
        let plat = configs::c5();
        let single = plan_shards(&net, &plat, 1).unwrap();
        let quad = plan_shards(&net, &plat, 4).unwrap();
        assert!(quad.n_shards() > 1, "planner should actually replicate");
        assert!(
            quad.total_predicted() > 1.02 * single.total_predicted(),
            "replication headroom: {} vs {}",
            quad.total_predicted(),
            single.total_predicted()
        );
    }

    #[test]
    fn planning_is_deterministic() {
        let net = networks::synthnet();
        let plat = configs::c4();
        let a = plan_shards(&net, &plat, 3).unwrap();
        let b = plan_shards(&net, &plat, 3).unwrap();
        assert_eq!(a.partitions, b.partitions);
        assert_eq!(a.configs, b.configs);
        assert_eq!(a.strategy, b.strategy);
        assert_eq!(a.total_predicted().to_bits(), b.total_predicted().to_bits());
    }

    fn assert_same_plan(a: &ShardPlan, b: &ShardPlan, what: &str) {
        crate::testutil::same_shard_plan(a, b).unwrap_or_else(|e| panic!("{what}: {e}"));
    }

    #[test]
    fn parallel_and_cached_planning_match_sequential_bitwise() {
        let net = networks::synthnet();
        let plat = configs::c5();
        let baseline = plan_shards(&net, &plat, 4).unwrap();
        // parallel worklist, fresh cache
        let par = plan_shards_with(&net, &plat, 4, 4, &PlanCache::new()).unwrap();
        assert_same_plan(&baseline, &par, "parallel");
        // warm cache: second run answers every subset from the memo
        let cache = PlanCache::new();
        let cold = plan_shards_with(&net, &plat, 4, 1, &cache).unwrap();
        let misses_after_cold = cache.stats().misses;
        let warm = plan_shards_with(&net, &plat, 4, 1, &cache).unwrap();
        assert_same_plan(&baseline, &cold, "cold cached");
        assert_same_plan(&baseline, &warm, "warm cached");
        assert_eq!(
            cache.stats().misses,
            misses_after_cold,
            "warm run must add no tuning work"
        );
        assert!(cache.stats().hits > 0);
        // parallel + warm cache together
        let both = plan_shards_with(&net, &plat, 4, 4, &cache).unwrap();
        assert_same_plan(&baseline, &both, "parallel warm");
    }

    #[test]
    fn plan_rejects_zero_shards() {
        let net = networks::synthnet_small();
        assert!(plan_shards(&net, &configs::c1(), 0).is_err());
    }

    #[test]
    fn max_shards_capped_at_ep_count() {
        let net = networks::synthnet_small();
        let plat = configs::c1(); // 2 EPs
        let plan = plan_shards(&net, &plat, 16).unwrap();
        assert!(plan.n_shards() <= 2);
    }
}
