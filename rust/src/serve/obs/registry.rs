//! Allocation-free metrics registry.
//!
//! Every series is **pre-registered** at serve start, which hands back a
//! typed index (`CounterId` / `GaugeId` / `HistId`). Hot-path updates are
//! plain indexed stores — no hashing, no string formatting, no allocation
//! after warmup — so telemetry costs one branch plus one array write per
//! touch. Rendering (the Prometheus text exposition) walks the same flat
//! vectors at end of run.
//!
//! Histograms use fixed log₂ buckets: bucket `k` covers values `≤ 2^k`
//! (bucket 0 covers 0 and 1, the last bucket is `+Inf`). Queue depths and
//! batch fills span decades; power-of-two edges keep resolution where the
//! distribution lives without per-registry bucket configuration.

/// Log₂ histogram bucket count: bucket 17 covers values up to 2^17 =
/// 131072, the 18th (index [`HIST_BUCKETS`]-1) is the `+Inf` overflow.
pub const HIST_BUCKETS: usize = 18;

/// Index of a pre-registered u64 counter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CounterId(usize);

/// Index of a pre-registered f64 gauge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GaugeId(usize);

/// Index of a pre-registered log₂ histogram.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistId(usize);

#[derive(Debug)]
struct Counter {
    family: &'static str,
    /// Rendered label set (the text inside `{}`), e.g. `tag="arrival"`.
    labels: String,
    value: u64,
}

#[derive(Debug)]
struct Gauge {
    family: &'static str,
    labels: String,
    value: f64,
}

#[derive(Debug)]
struct Hist {
    family: &'static str,
    labels: String,
    buckets: [u64; HIST_BUCKETS],
    count: u64,
    sum: f64,
}

/// The flat registry. All series live in registration order, which is also
/// the (deterministic) exposition order.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Vec<Counter>,
    gauges: Vec<Gauge>,
    hists: Vec<Hist>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a counter; `labels` is the rendered label set (empty for
    /// none). Called only during serve-start warmup.
    pub fn counter(&mut self, family: &'static str, labels: impl Into<String>) -> CounterId {
        self.counters.push(Counter { family, labels: labels.into(), value: 0 });
        CounterId(self.counters.len() - 1)
    }

    /// Register a gauge.
    pub fn gauge(&mut self, family: &'static str, labels: impl Into<String>) -> GaugeId {
        self.gauges.push(Gauge { family, labels: labels.into(), value: 0.0 });
        GaugeId(self.gauges.len() - 1)
    }

    /// Register a log₂ histogram.
    pub fn hist(&mut self, family: &'static str, labels: impl Into<String>) -> HistId {
        self.hists.push(Hist {
            family,
            labels: labels.into(),
            buckets: [0; HIST_BUCKETS],
            count: 0,
            sum: 0.0,
        });
        HistId(self.hists.len() - 1)
    }

    /// Hot path: bump a counter by one.
    #[inline]
    pub fn inc(&mut self, id: CounterId) {
        self.counters[id.0].value += 1;
    }

    /// Hot path: bump a counter by `n`.
    #[inline]
    pub fn add(&mut self, id: CounterId, n: u64) {
        self.counters[id.0].value += n;
    }

    /// Current value of a counter.
    #[inline]
    pub fn counter_value(&self, id: CounterId) -> u64 {
        self.counters[id.0].value
    }

    /// Hot path: overwrite a gauge.
    #[inline]
    pub fn set(&mut self, id: GaugeId, v: f64) {
        self.gauges[id.0].value = v;
    }

    /// Hot path: record one observation into a histogram.
    #[inline]
    pub fn observe(&mut self, id: HistId, v: u64) {
        let h = &mut self.hists[id.0];
        h.buckets[Self::bucket(v)] += 1;
        h.count += 1;
        h.sum += v as f64;
    }

    /// Log₂ bucket index of `v`: the smallest `k` with `v <= 2^k`, clamped
    /// to the overflow bucket.
    #[inline]
    pub fn bucket(v: u64) -> usize {
        if v <= 1 {
            0
        } else {
            let k = (64 - (v - 1).leading_zeros()) as usize;
            k.min(HIST_BUCKETS - 1)
        }
    }

    /// Upper bound of bucket `k` as exposition text (`+Inf` for the last).
    pub fn bucket_le(k: usize) -> String {
        if k >= HIST_BUCKETS - 1 {
            "+Inf".to_string()
        } else {
            (1u64 << k).to_string()
        }
    }

    /// Render the whole registry in Prometheus text-exposition format.
    /// Deterministic: registration order, `{}` float formatting.
    pub fn prom(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let mut last_family = "";
        for c in &self.counters {
            if c.family != last_family {
                let _ = writeln!(out, "# TYPE {} counter", c.family);
                last_family = c.family;
            }
            let _ = writeln!(out, "{}{} {}", c.family, braced(&c.labels), c.value);
        }
        last_family = "";
        for g in &self.gauges {
            if g.family != last_family {
                let _ = writeln!(out, "# TYPE {} gauge", g.family);
                last_family = g.family;
            }
            let _ = writeln!(out, "{}{} {}", g.family, braced(&g.labels), g.value);
        }
        last_family = "";
        for h in &self.hists {
            if h.family != last_family {
                let _ = writeln!(out, "# TYPE {} histogram", h.family);
                last_family = h.family;
            }
            let mut cum = 0u64;
            for (k, &n) in h.buckets.iter().enumerate() {
                cum += n;
                let le = Self::bucket_le(k);
                let labels = if h.labels.is_empty() {
                    format!("le=\"{le}\"")
                } else {
                    format!("{},le=\"{le}\"", h.labels)
                };
                let _ = writeln!(out, "{}_bucket{{{labels}}} {cum}", h.family);
            }
            let _ = writeln!(out, "{}_sum{} {}", h.family, braced(&h.labels), h.sum);
            let _ = writeln!(out, "{}_count{} {}", h.family, braced(&h.labels), h.count);
        }
        out
    }
}

/// Wrap a rendered label set in braces, or nothing when it is empty.
fn braced(labels: &str) -> String {
    if labels.is_empty() {
        String::new()
    } else {
        format!("{{{labels}}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges() {
        assert_eq!(Registry::bucket(0), 0);
        assert_eq!(Registry::bucket(1), 0);
        assert_eq!(Registry::bucket(2), 1);
        assert_eq!(Registry::bucket(3), 2);
        assert_eq!(Registry::bucket(4), 2);
        assert_eq!(Registry::bucket(5), 3);
        assert_eq!(Registry::bucket(1 << 16), 16);
        assert_eq!(Registry::bucket((1 << 17) + 1), HIST_BUCKETS - 1);
        assert_eq!(Registry::bucket(u64::MAX), HIST_BUCKETS - 1);
        // Every bucket's upper bound actually admits its values.
        for v in [0u64, 1, 2, 3, 7, 8, 9, 100, 1000, 131072] {
            let k = Registry::bucket(v);
            if k < HIST_BUCKETS - 1 {
                assert!(v <= (1u64 << k), "v={v} overflows bucket {k}");
            }
            if k > 0 {
                assert!(v > (1u64 << (k - 1)), "v={v} belongs below bucket {k}");
            }
        }
    }

    #[test]
    fn prom_exposition_shape() {
        let mut r = Registry::new();
        let c = r.counter("shisha_events_total", "tag=\"arrival\"");
        let g = r.gauge("shisha_link_busy_frac", "");
        let h = r.hist("shisha_batch_fill", "");
        r.add(c, 3);
        r.set(g, 0.5);
        r.observe(h, 4);
        r.observe(h, 5);
        let text = r.prom();
        assert!(text.contains("# TYPE shisha_events_total counter"));
        assert!(text.contains("shisha_events_total{tag=\"arrival\"} 3"));
        assert!(text.contains("shisha_link_busy_frac 0.5"));
        assert!(text.contains("# TYPE shisha_batch_fill histogram"));
        // 4 lands in le="4", 5 in le="8"; cumulative counts.
        assert!(text.contains("shisha_batch_fill_bucket{le=\"4\"} 1"));
        assert!(text.contains("shisha_batch_fill_bucket{le=\"8\"} 2"));
        assert!(text.contains("shisha_batch_fill_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("shisha_batch_fill_sum 9"));
        assert!(text.contains("shisha_batch_fill_count 2"));
    }

    #[test]
    fn updates_by_index() {
        let mut r = Registry::new();
        let a = r.counter("f", "x=\"1\"");
        let b = r.counter("f", "x=\"2\"");
        r.inc(a);
        r.inc(b);
        r.inc(b);
        assert_eq!(r.counter_value(a), 1);
        assert_eq!(r.counter_value(b), 2);
    }
}
