//! Control-plane causality journal.
//!
//! Every control decision the engine hashes into the flight-recorder
//! control section ([`ControlRecord`]) answers *what* happened; the
//! journal records *why* — the triggering signals the control loop read
//! immediately before deciding (slowdown EWMAs, demand factors, pressure
//! and slack counters, plan-objective gaps vs `min_gain_frac`). Entries
//! live **beside** the hashed records: the journal is capture-style
//! telemetry and never feeds `log_hash`, so journaled runs stay
//! byte-identical to bare ones.
//!
//! Signal names are `&'static str` supplied at the decision site, so a
//! journal push costs one `Vec` of `(name, f64)` pairs per *decision* —
//! decisions fire at control-epoch cadence, not per event, so this is off
//! the hot path by construction.

use crate::serve::trace::{ControlKind, ControlRecord};

/// One journaled decision: the hashed control record plus the signals
/// that triggered it.
#[derive(Debug, Clone)]
pub struct JournalEntry {
    /// Simulated decision time, seconds.
    pub t_s: f64,
    /// Which control mechanism fired.
    pub kind: ControlKind,
    /// Tenant index.
    pub tenant: u32,
    /// Replica index (mechanism-specific; see [`ControlKind`] docs).
    pub shard: u32,
    /// Mechanism-specific payload `a` (matches the hashed record).
    pub a: u64,
    /// Mechanism-specific payload `b` (matches the hashed record).
    pub b: u64,
    /// Named triggering signals, in the order the decision site read them.
    pub signals: Vec<(&'static str, f64)>,
}

/// Append-only decision journal for one serve run.
#[derive(Debug, Clone, Default)]
pub struct Journal {
    /// Entries in decision order (simulated time is non-decreasing).
    pub entries: Vec<JournalEntry>,
}

impl Journal {
    /// Journal a control decision beside its hashed record.
    pub fn push(&mut self, rec: &ControlRecord, signals: &[(&'static str, f64)]) {
        self.entries.push(JournalEntry {
            t_s: rec.t_s,
            kind: rec.kind,
            tenant: rec.tenant,
            shard: rec.shard,
            a: rec.a,
            b: rec.b,
            signals: signals.to_vec(),
        });
    }

    /// Entries with `prev < t_s <= upto` — the decisions belonging to the
    /// epoch sample closing at `upto` (serve-start decisions at `t = 0`
    /// belong to the first sample via `prev = -inf`).
    pub fn in_window(&self, prev: f64, upto: f64) -> impl Iterator<Item = &JournalEntry> {
        self.entries.iter().filter(move |e| e.t_s > prev && e.t_s <= upto)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(t_s: f64, kind: ControlKind) -> ControlRecord {
        ControlRecord { t_s, kind, tenant: 0, shard: 0, a: 1, b: 2 }
    }

    #[test]
    fn windows_partition_the_timeline() {
        let mut j = Journal::default();
        j.push(&rec(0.0, ControlKind::Coplan), &[("eps", 4.0)]);
        j.push(&rec(5.0, ControlKind::Retune), &[("goodput", 10.0), ("baseline", 12.0)]);
        j.push(&rec(10.0, ControlKind::Repartition), &[]);
        let first: Vec<_> = j.in_window(f64::NEG_INFINITY, 5.0).collect();
        assert_eq!(first.len(), 2);
        assert_eq!(first[0].kind, ControlKind::Coplan);
        assert_eq!(first[1].signals[1], ("baseline", 12.0));
        let second: Vec<_> = j.in_window(5.0, 10.0).collect();
        assert_eq!(second.len(), 1);
        assert_eq!(second[0].kind, ControlKind::Repartition);
    }
}
