//! `serve/obs` — the telemetry plane: zero-perturbation observability for
//! the serving engine.
//!
//! Three cooperating pieces, all derived **beside** the event-hash funnel
//! (the same discipline as the flight recorder): a run with telemetry on
//! produces the byte-identical `log_hash`, event log, report and golden
//! fingerprints as one with telemetry off — pinned by
//! `tests/obs_invariance.rs`.
//!
//! * [`registry`] — allocation-free metrics registry: counters, gauges and
//!   log₂ histograms pre-registered at serve start, updated by index on
//!   the hot path;
//! * [`journal`] — control-plane causality journal: every hashed
//!   Retune/Coplan/Scale/Fault/Failover/Shed/Repartition decision is
//!   journaled with the signals that triggered it;
//! * [`prof`] — monotonic-clock self-profiling spans (event pump, settle,
//!   re-tune, coplan, drain/migrate, sampling), excluded from all hashes
//!   *and* from the deterministic exports.
//!
//! At every control-epoch tick the engine snapshots the registry and
//! utilization meters into an [`EpochSample`]; the horizon yields an
//! [`ObsReport`] with the sample series, the journal, a Prometheus text
//! snapshot and the self-profile. `trace analyze FILE.trace` re-derives
//! the same report retroactively from any recorded trace (v1–v3) by
//! re-simulating through the same sink, so live `--metrics` JSONL and
//! trace-derived JSONL are byte-for-byte equal.
//!
//! # JSONL schema (`serve --metrics FILE.jsonl`)
//!
//! One JSON object per line, schema-versioned. Per epoch sample:
//!
//! ```json
//! {
//!   "schema": "shisha-obs-v1",
//!   "t_s": 5e0,              // epoch tick, simulated seconds
//!   "n_events": 1234,        // events processed so far
//!   "cache": {"hits": 3, "misses": 1, "entries": 1},   // PlanCache
//!   "eps": [{"busy_frac": 4.2e-1, "avg_inflight": 6e-1}, ...],
//!   "link": {"busy_frac": 1e-1, "avg_inflight": 2e-1},
//!   "tenants": [
//!     {"name": "a", "offered": 10, "completed": 9, "slo_ok": 9,
//!      "rejected": 0, "dropped": 1, "goodput": 1.8e0,
//!      "throughput": 1.8e0, "backlog": 0, "load_shed": false,
//!      // lifecycle-enabled runs only (any tenant with a deadline,
//!      // retry or hedge policy) — absent otherwise so lifecycle-off
//!      // JSONL is byte-identical to a pre-lifecycle build:
//!      "expired": 0, "cancelled": 0, "retried": 0, "hedged": 0,
//!      "replicas": [
//!        {"state": "active", "dead": false, "eps": 2, "queued": 0,
//!         "stage_queue_hw": [3, 1], "slab_live": 1, "slab_cap": 8,
//!         "retuned": false}, ...]}
//!   ],
//!   "decisions": [ ... ]     // journal entries in (prev, t_s]
//! }
//! ```
//!
//! `busy_frac` is the fraction of the epoch window the resource had at
//! least one service in flight; `avg_inflight` the time-average of its
//! in-flight count. `stage_queue_hw` is the per-stage queue-depth
//! high-water since the previous sample; `slab_live`/`slab_cap` the
//! request-arena occupancy at the tick. Each journal decision renders as
//!
//! ```json
//! {"t_s": 5e0, "kind": "retune", "tenant": 0, "shard": 1,
//!  "a": 24, "b": 1, "signals": {"goodput": 1.8e0, "baseline": 2e0}}
//! ```
//!
//! Decisions after the last epoch tick (e.g. a fault at the horizon) are
//! appended as one trailing `{"schema": "shisha-obs-v1", "record":
//! "tail", "decisions": [...]}` line. Wall-clock self-profiling is
//! deliberately **absent** from the JSONL and the Prometheus snapshot —
//! both surfaces must be bit-reproducible from a trace.

pub mod journal;
pub mod prof;
pub mod registry;

pub use journal::{Journal, JournalEntry};
pub use prof::{Prof, ProfReport, ProfRow, Span};
pub use registry::{CounterId, GaugeId, HistId, Registry, HIST_BUCKETS};

use crate::explore::CacheStats;
use crate::metrics::emit;
use crate::serve::trace::TraceEvent;

/// Admission outcome codes for [`Obs::on_admission`].
pub const ADM_ADMIT: usize = 0;
/// Rejected at the entry queue (bounded queue full, policy Reject).
pub const ADM_REJECT: usize = 1;
/// Admitted then displaced (policy DropOldest).
pub const ADM_DROP: usize = 2;
/// Rejected by graceful-degradation load shedding.
pub const ADM_SHED: usize = 3;
const ADM_NAMES: [&str; 4] = ["admit", "reject", "drop", "shed"];

/// Time-integrating utilization meter for the EPs and the inter-chiplet
/// link. Fed from the engine's busy-counter transitions (exact event
/// times), flushed at every epoch tick — a pure function of the event
/// stream, so live and trace-derived series agree bit-for-bit.
#[derive(Debug, Default)]
pub struct UtilMeter {
    win_start: f64,
    ep_last: Vec<f64>,
    ep_busy_s: Vec<f64>,
    ep_units_s: Vec<f64>,
    link_last: f64,
    link_busy_s: f64,
    link_units_s: f64,
}

impl UtilMeter {
    fn new(n_eps: usize) -> Self {
        Self {
            win_start: 0.0,
            ep_last: vec![0.0; n_eps],
            ep_busy_s: vec![0.0; n_eps],
            ep_units_s: vec![0.0; n_eps],
            link_last: 0.0,
            link_busy_s: 0.0,
            link_units_s: 0.0,
        }
    }

    /// Integrate EP `gep` up to `now` at its *pre-transition* in-flight
    /// count `old_units`. Call immediately before mutating the counter.
    #[inline]
    pub fn ep_touch(&mut self, gep: usize, old_units: u32, now: f64) {
        let dt = now - self.ep_last[gep];
        if dt > 0.0 {
            if old_units > 0 {
                self.ep_busy_s[gep] += dt;
            }
            self.ep_units_s[gep] += dt * old_units as f64;
        }
        self.ep_last[gep] = now;
    }

    /// Same for the inter-chiplet link.
    #[inline]
    pub fn link_touch(&mut self, old_units: u32, now: f64) {
        let dt = now - self.link_last;
        if dt > 0.0 {
            if old_units > 0 {
                self.link_busy_s += dt;
            }
            self.link_units_s += dt * old_units as f64;
        }
        self.link_last = now;
    }

    /// Close the window at `now` using the *current* counter values, emit
    /// per-EP + link utilization, and start the next window.
    pub fn flush(&mut self, now: f64, ep_busy: &[u32], link_busy: u32) -> (Vec<EpSample>, EpSample) {
        let win = now - self.win_start;
        let mut eps = Vec::with_capacity(ep_busy.len());
        for (gep, &units) in ep_busy.iter().enumerate() {
            self.ep_touch(gep, units, now);
            let (busy_frac, avg_inflight) = if win > 0.0 {
                (self.ep_busy_s[gep] / win, self.ep_units_s[gep] / win)
            } else {
                (0.0, 0.0)
            };
            eps.push(EpSample { busy_frac, avg_inflight });
            self.ep_busy_s[gep] = 0.0;
            self.ep_units_s[gep] = 0.0;
        }
        self.link_touch(link_busy, now);
        let link = if win > 0.0 {
            EpSample {
                busy_frac: self.link_busy_s / win,
                avg_inflight: self.link_units_s / win,
            }
        } else {
            EpSample { busy_frac: 0.0, avg_inflight: 0.0 }
        };
        self.link_busy_s = 0.0;
        self.link_units_s = 0.0;
        self.win_start = now;
        (eps, link)
    }
}

/// Utilization of one EP (or the link) over one epoch window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EpSample {
    /// Fraction of the window with at least one service in flight.
    pub busy_frac: f64,
    /// Time-average in-flight count over the window.
    pub avg_inflight: f64,
}

/// One replica's slice of an epoch sample.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicaSample {
    /// Autoscaler state name at the tick (`active`/`draining`/`parked`).
    pub state: &'static str,
    /// True when the replica's whole home EP set is faulted.
    pub dead: bool,
    /// EPs the replica currently runs on.
    pub eps: u64,
    /// Requests waiting in its stage queues at the tick.
    pub queued: u64,
    /// Per-stage queue-depth high-water since the previous sample.
    pub stage_queue_hw: Vec<u32>,
    /// Live requests in the slab arena at the tick.
    pub slab_live: u64,
    /// Slab arena capacity (high-water of allocated slots).
    pub slab_cap: u64,
    /// Whether a warm re-tune ran this epoch.
    pub retuned: bool,
}

/// One tenant's slice of an epoch sample (epoch-delta counters summed
/// across its replicas).
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSample {
    /// Arrivals offered during the epoch.
    pub offered: u64,
    /// Completions during the epoch.
    pub completed: u64,
    /// SLO-conform completions during the epoch.
    pub slo_ok: u64,
    /// Rejections during the epoch.
    pub rejected: u64,
    /// DropOldest drops during the epoch.
    pub dropped: u64,
    /// Deadline expiries during the epoch (0 unless the tenant has a
    /// finite deadline; emitted in JSONL only for lifecycle-enabled runs).
    pub expired: u64,
    /// Hedge-loser cancellations during the epoch (lifecycle runs only).
    pub cancelled: u64,
    /// Retry re-arrivals during the epoch (lifecycle runs only).
    pub retried: u64,
    /// Hedge twins placed during the epoch (lifecycle runs only).
    pub hedged: u64,
    /// SLO goodput over the epoch, requests/second.
    pub goodput: f64,
    /// Raw completion throughput over the epoch, requests/second.
    pub throughput: f64,
    /// Backlog at the tick.
    pub backlog: u64,
    /// Whether graceful degradation is shedding this tenant.
    pub load_shed: bool,
    /// Per-replica samples.
    pub replicas: Vec<ReplicaSample>,
}

/// One control-epoch telemetry snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochSample {
    /// Epoch tick, simulated seconds.
    pub t_s: f64,
    /// Events processed up to the tick.
    pub n_events: u64,
    /// Planner memo counters at the tick.
    pub cache: CacheStats,
    /// Per-EP utilization over the closed window (global EP ids).
    pub eps: Vec<EpSample>,
    /// Inter-chiplet link utilization over the closed window.
    pub link: EpSample,
    /// Per-tenant samples, in input order.
    pub tenants: Vec<TenantSample>,
}

/// The live telemetry sink: owned by the engine (boxed inside its shared
/// state) only when telemetry was requested, so unobserved runs pay one
/// `Option` branch per touch.
#[derive(Debug)]
pub struct Obs {
    /// The flat metrics registry.
    pub reg: Registry,
    /// The decision journal.
    pub journal: Journal,
    /// Self-profiling accumulators.
    pub prof: Prof,
    /// EP/link utilization integrator.
    pub util: UtilMeter,
    samples: Vec<EpochSample>,
    tenant_names: Vec<String>,
    /// Per-[tenant][replica][stage] queue-depth high-water since the last
    /// sample; inner vecs sized lazily (stage counts differ per replica).
    queue_hw: Vec<Vec<Vec<u32>>>,
    /// Whether any tenant runs with a lifecycle policy: gates the tag
    /// 9–12 event counters and the per-tenant lifecycle JSONL fields, so
    /// a lifecycle-off run's exports are byte-identical to a
    /// pre-lifecycle build (every registered series renders in the
    /// Prometheus snapshot, zero-valued or not).
    lifecycle: bool,
    // Pre-registered ids (hot path updates by index only).
    tag_ids: Vec<CounterId>,
    adm_ids: Vec<[CounterId; 4]>,
    batch_hist: HistId,
    queue_hist: HistId,
    samples_c: CounterId,
    ep_busy_g: Vec<GaugeId>,
    link_busy_g: GaugeId,
    tenant_backlog_g: Vec<GaugeId>,
    tenant_goodput_g: Vec<GaugeId>,
    cache_hits_c: CounterId,
    cache_misses_c: CounterId,
    cache_entries_g: GaugeId,
}

impl Obs {
    /// Pre-register every series: `n_eps` global EPs, one `(name,
    /// n_replicas)` pair per tenant. `lifecycle` additionally registers
    /// the expire/retry/hedge/cancel event counters (tags 9–12) — gated
    /// so a lifecycle-off run's Prometheus snapshot is byte-identical to
    /// a pre-lifecycle build. This is the only allocating phase.
    pub fn new(n_eps: usize, tenants: &[(String, usize)], lifecycle: bool) -> Self {
        let mut reg = Registry::new();
        let n_tags = if lifecycle { 13 } else { 9 };
        let tag_ids = (0..n_tags)
            .map(|tag| {
                let name = if tag == 0 { "other" } else { TraceEvent::tag_name(tag as u64) };
                reg.counter("shisha_events_total", format!("tag=\"{name}\""))
            })
            .collect();
        let mut adm_ids = Vec::with_capacity(tenants.len());
        for (name, _) in tenants {
            adm_ids.push(std::array::from_fn(|o| {
                reg.counter(
                    "shisha_admissions_total",
                    format!("tenant=\"{name}\",outcome=\"{}\"", ADM_NAMES[o]),
                )
            }));
        }
        let batch_hist = reg.hist("shisha_batch_fill", "");
        let queue_hist = reg.hist("shisha_queue_depth", "");
        let samples_c = reg.counter("shisha_epoch_samples_total", "");
        let ep_busy_g = (0..n_eps)
            .map(|gep| reg.gauge("shisha_ep_busy_frac", format!("ep=\"{gep}\"")))
            .collect();
        let link_busy_g = reg.gauge("shisha_link_busy_frac", "");
        let tenant_backlog_g = tenants
            .iter()
            .map(|(name, _)| reg.gauge("shisha_tenant_backlog", format!("tenant=\"{name}\"")))
            .collect();
        let tenant_goodput_g = tenants
            .iter()
            .map(|(name, _)| reg.gauge("shisha_tenant_goodput_rps", format!("tenant=\"{name}\"")))
            .collect();
        let cache_hits_c = reg.counter("shisha_plan_cache_hits_total", "");
        let cache_misses_c = reg.counter("shisha_plan_cache_misses_total", "");
        let cache_entries_g = reg.gauge("shisha_plan_cache_entries", "");
        Self {
            reg,
            journal: Journal::default(),
            prof: Prof::default(),
            util: UtilMeter::new(n_eps),
            samples: Vec::new(),
            tenant_names: tenants.iter().map(|(n, _)| n.clone()).collect(),
            queue_hw: tenants.iter().map(|&(_, shards)| vec![Vec::new(); shards]).collect(),
            lifecycle,
            tag_ids,
            adm_ids,
            batch_hist,
            queue_hist,
            samples_c,
            ep_busy_g,
            link_busy_g,
            tenant_backlog_g,
            tenant_goodput_g,
            cache_hits_c,
            cache_misses_c,
            cache_entries_g,
        }
    }

    /// Hot path: one hashed event of tag `tag` went through the funnel.
    #[inline]
    pub fn on_event(&mut self, tag: u64) {
        let ix = if (tag as usize) < self.tag_ids.len() { tag as usize } else { 0 };
        self.reg.inc(self.tag_ids[ix]);
    }

    /// Hot path: one admission decision for tenant `ti` (`ADM_*` code).
    #[inline]
    pub fn on_admission(&mut self, ti: usize, outcome: usize) {
        self.reg.inc(self.adm_ids[ti][outcome]);
    }

    /// Hot path: a batch of `b` requests entered service.
    #[inline]
    pub fn on_batch(&mut self, b: u64) {
        self.reg.observe(self.batch_hist, b);
    }

    /// Track the per-stage queue high-water of one replica (settle
    /// epilogue).
    #[inline]
    pub fn queue_mark(&mut self, ti: usize, shard: usize, stage: usize, len: u32) {
        let hw = &mut self.queue_hw[ti][shard];
        if hw.len() <= stage {
            hw.resize(stage + 1, 0);
        }
        if len > hw[stage] {
            hw[stage] = len;
        }
    }

    /// Observe a replica's total waiting-queue depth (settle epilogue).
    #[inline]
    pub fn queue_total(&mut self, total: u64) {
        self.reg.observe(self.queue_hist, total);
    }

    /// Take (and reset) the queue high-water of one replica for a sample.
    pub fn take_queue_hw(&mut self, ti: usize, shard: usize) -> Vec<u32> {
        let hw = &mut self.queue_hw[ti][shard];
        let out = hw.clone();
        for x in hw.iter_mut() {
            *x = 0;
        }
        out
    }

    /// Append an epoch sample and mirror its headline series into the
    /// registry gauges (so the Prometheus snapshot carries the last tick).
    pub fn push_sample(&mut self, sample: EpochSample) {
        self.reg.inc(self.samples_c);
        for (gep, ep) in sample.eps.iter().enumerate() {
            self.reg.set(self.ep_busy_g[gep], ep.busy_frac);
        }
        self.reg.set(self.link_busy_g, sample.link.busy_frac);
        for (ti, t) in sample.tenants.iter().enumerate() {
            self.reg.set(self.tenant_backlog_g[ti], t.backlog as f64);
            self.reg.set(self.tenant_goodput_g[ti], t.goodput);
        }
        self.samples.push(sample);
    }

    /// Number of epoch samples taken so far.
    pub fn n_samples(&self) -> u64 {
        self.samples.len() as u64
    }

    /// Close the run: fold the final plan-cache counters into the
    /// registry and freeze everything into an [`ObsReport`].
    pub fn finish(mut self, cache: CacheStats) -> ObsReport {
        self.reg.add(self.cache_hits_c, cache.hits);
        self.reg.add(self.cache_misses_c, cache.misses);
        self.reg.set(self.cache_entries_g, cache.entries as f64);
        ObsReport {
            prom: self.reg.prom(),
            samples: self.samples,
            journal: self.journal,
            prof: self.prof.report(),
            cache,
            tenant_names: self.tenant_names,
            lifecycle: self.lifecycle,
        }
    }
}

/// The frozen telemetry of one serve run (live or trace-derived).
#[derive(Debug, Clone)]
pub struct ObsReport {
    /// Epoch sample series, in tick order.
    pub samples: Vec<EpochSample>,
    /// The causality journal.
    pub journal: Journal,
    /// Self-profiling breakdown (wall clock; excluded from the
    /// deterministic exports).
    pub prof: ProfReport,
    /// Prometheus text-exposition snapshot at the horizon.
    pub prom: String,
    /// Final planner memo counters.
    pub cache: CacheStats,
    /// Tenant names, in input order (JSONL row labels).
    pub tenant_names: Vec<String>,
    /// Whether the run had any lifecycle-enabled tenant: mirrors the
    /// extra per-tenant lifecycle fields into the JSONL rows. Kept off
    /// for lifecycle-off runs so their JSONL stays byte-identical to a
    /// pre-lifecycle build.
    pub lifecycle: bool,
}

impl ObsReport {
    /// Render the epoch series + journal as schema-versioned JSONL —
    /// the `serve --metrics` surface. Deterministic: byte-identical
    /// between a live run and `trace analyze` of its recording.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        let mut prev = f64::NEG_INFINITY;
        for s in &self.samples {
            out.push_str(&self.sample_json(s, prev));
            out.push('\n');
            prev = s.t_s;
        }
        let tail: Vec<&JournalEntry> =
            self.journal.entries.iter().filter(|e| e.t_s > prev).collect();
        if !tail.is_empty() {
            out.push_str("{\"schema\":\"shisha-obs-v1\",\"record\":\"tail\",\"decisions\":[");
            for (i, e) in tail.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&decision_json(e));
            }
            out.push_str("]}\n");
        }
        out
    }

    fn sample_json(&self, s: &EpochSample, prev: f64) -> String {
        use std::fmt::Write as _;
        let mut o = String::with_capacity(256);
        let _ = write!(
            o,
            "{{\"schema\":\"shisha-obs-v1\",\"t_s\":{},\"n_events\":{}",
            emit::num(s.t_s),
            s.n_events
        );
        let _ = write!(
            o,
            ",\"cache\":{{\"hits\":{},\"misses\":{},\"entries\":{}}}",
            s.cache.hits, s.cache.misses, s.cache.entries
        );
        o.push_str(",\"eps\":[");
        for (i, ep) in s.eps.iter().enumerate() {
            if i > 0 {
                o.push(',');
            }
            let _ = write!(
                o,
                "{{\"busy_frac\":{},\"avg_inflight\":{}}}",
                emit::num(ep.busy_frac),
                emit::num(ep.avg_inflight)
            );
        }
        let _ = write!(
            o,
            "],\"link\":{{\"busy_frac\":{},\"avg_inflight\":{}}}",
            emit::num(s.link.busy_frac),
            emit::num(s.link.avg_inflight)
        );
        o.push_str(",\"tenants\":[");
        for (ti, t) in s.tenants.iter().enumerate() {
            if ti > 0 {
                o.push(',');
            }
            let name = self.tenant_names.get(ti).map(String::as_str).unwrap_or("");
            let _ = write!(
                o,
                "{{\"name\":{},\"offered\":{},\"completed\":{},\"slo_ok\":{},\
                 \"rejected\":{},\"dropped\":{},\"goodput\":{},\"throughput\":{},\
                 \"backlog\":{},\"load_shed\":{}",
                emit::str_lit(name),
                t.offered,
                t.completed,
                t.slo_ok,
                t.rejected,
                t.dropped,
                emit::num(t.goodput),
                emit::num(t.throughput),
                t.backlog,
                t.load_shed
            );
            if self.lifecycle {
                let _ = write!(
                    o,
                    ",\"expired\":{},\"cancelled\":{},\"retried\":{},\"hedged\":{}",
                    t.expired, t.cancelled, t.retried, t.hedged
                );
            }
            o.push_str(",\"replicas\":[");
            for (si, r) in t.replicas.iter().enumerate() {
                if si > 0 {
                    o.push(',');
                }
                let _ = write!(
                    o,
                    "{{\"state\":{},\"dead\":{},\"eps\":{},\"queued\":{},\"stage_queue_hw\":[",
                    emit::str_lit(r.state),
                    r.dead,
                    r.eps,
                    r.queued
                );
                for (qi, q) in r.stage_queue_hw.iter().enumerate() {
                    if qi > 0 {
                        o.push(',');
                    }
                    let _ = write!(o, "{q}");
                }
                let _ = write!(
                    o,
                    "],\"slab_live\":{},\"slab_cap\":{},\"retuned\":{}}}",
                    r.slab_live, r.slab_cap, r.retuned
                );
            }
            o.push_str("]}");
        }
        o.push_str("],\"decisions\":[");
        for (i, e) in self.journal.in_window(prev, s.t_s).enumerate() {
            if i > 0 {
                o.push(',');
            }
            o.push_str(&decision_json(e));
        }
        o.push_str("]}");
        o
    }

    /// Human-readable analysis: per-tenant epoch counts and the decision
    /// timeline with triggering signals — the shared body of `trace
    /// inspect` and `trace analyze`, also printed after live `--metrics`
    /// runs.
    pub fn analysis(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "  epoch samples: {}", self.samples.len());
        for (ti, name) in self.tenant_names.iter().enumerate() {
            let epochs = self
                .samples
                .iter()
                .filter(|s| s.tenants.get(ti).is_some_and(|t| !t.replicas.is_empty()))
                .count();
            let (mut offered, mut slo_ok) = (0u64, 0u64);
            for s in &self.samples {
                if let Some(t) = s.tenants.get(ti) {
                    offered += t.offered;
                    slo_ok += t.slo_ok;
                }
            }
            let _ = writeln!(
                out,
                "  tenant {name}: {epochs} epochs, offered {offered}, slo_ok {slo_ok}"
            );
        }
        let _ = writeln!(
            out,
            "  plan cache: {} hits / {} misses ({} entries)",
            self.cache.hits, self.cache.misses, self.cache.entries
        );
        if self.journal.entries.is_empty() {
            let _ = writeln!(out, "  control decisions: none");
        } else {
            let _ = writeln!(out, "  control decisions ({}):", self.journal.entries.len());
            for e in &self.journal.entries {
                let _ = writeln!(
                    out,
                    "    {}",
                    decision_line(e.t_s, e.kind.name(), e.tenant, e.shard, e.a, e.b, &e.signals)
                );
            }
        }
        out
    }
}

/// One line of the control-decision timeline: decision time in seconds,
/// mechanism, addressing and payload words, then any triggering signals.
/// `trace inspect` ([`crate::serve::Trace::describe`], without signals)
/// and `trace analyze` / live `--metrics` ([`ObsReport::analysis`], with
/// them) both render through this, so the two commands cannot drift.
pub fn decision_line(
    t_s: f64,
    kind: &str,
    tenant: u32,
    shard: u32,
    a: u64,
    b: u64,
    signals: &[(&'static str, f64)],
) -> String {
    let sig =
        signals.iter().map(|(k, v)| format!("{k}={v:.4}")).collect::<Vec<_>>().join(", ");
    format!(
        "t={t_s:>9.4}s {kind:<11} tenant={tenant} shard={shard} a={a} b={b}{}{sig}",
        if sig.is_empty() { "" } else { " | " },
    )
}

fn decision_json(e: &JournalEntry) -> String {
    use std::fmt::Write as _;
    let mut o = String::with_capacity(96);
    let _ = write!(
        o,
        "{{\"t_s\":{},\"kind\":{},\"tenant\":{},\"shard\":{},\"a\":{},\"b\":{},\"signals\":{{",
        emit::num(e.t_s),
        emit::str_lit(e.kind.name()),
        e.tenant,
        e.shard,
        e.a,
        e.b
    );
    for (i, (k, v)) in e.signals.iter().enumerate() {
        if i > 0 {
            o.push(',');
        }
        let _ = write!(o, "{}:{}", emit::str_lit(k), emit::num(*v));
    }
    o.push_str("}}");
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::trace::{ControlKind, ControlRecord};

    fn sample(t_s: f64) -> EpochSample {
        EpochSample {
            t_s,
            n_events: 10,
            cache: CacheStats::default(),
            eps: vec![EpSample { busy_frac: 0.5, avg_inflight: 0.75 }],
            link: EpSample { busy_frac: 0.0, avg_inflight: 0.0 },
            tenants: vec![TenantSample {
                offered: 4,
                completed: 3,
                slo_ok: 3,
                rejected: 1,
                dropped: 0,
                expired: 0,
                cancelled: 0,
                retried: 0,
                hedged: 0,
                goodput: 0.6,
                throughput: 0.6,
                backlog: 1,
                load_shed: false,
                replicas: vec![ReplicaSample {
                    state: "active",
                    dead: false,
                    eps: 2,
                    queued: 1,
                    stage_queue_hw: vec![2, 0],
                    slab_live: 1,
                    slab_cap: 4,
                    retuned: true,
                }],
            }],
        }
    }

    #[test]
    fn util_meter_integrates_exactly() {
        let mut m = UtilMeter::new(1);
        // One unit in flight over [1, 3) of a [0, 4) window.
        m.ep_touch(0, 0, 1.0);
        m.ep_touch(0, 1, 3.0);
        m.link_touch(0, 2.0);
        let (eps, link) = m.flush(4.0, &[0], 1);
        assert!((eps[0].busy_frac - 0.5).abs() < 1e-12);
        assert!((eps[0].avg_inflight - 0.5).abs() < 1e-12);
        // Link held 1 unit over [2, 4).
        assert!((link.busy_frac - 0.5).abs() < 1e-12);
        // Next window starts clean.
        let (eps, _) = m.flush(8.0, &[0], 0);
        assert_eq!(eps[0].busy_frac, 0.0);
    }

    #[test]
    fn obs_counts_and_exports() {
        let mut o = Obs::new(2, &[("a".to_string(), 1)], false);
        o.on_event(1);
        o.on_event(1);
        o.on_event(3);
        o.on_admission(0, ADM_ADMIT);
        o.on_admission(0, ADM_REJECT);
        o.on_batch(4);
        o.queue_mark(0, 0, 1, 7);
        o.queue_total(7);
        assert_eq!(o.take_queue_hw(0, 0), vec![0, 7]);
        assert_eq!(o.take_queue_hw(0, 0), vec![0, 0], "high-water resets on take");
        o.journal.push(
            &ControlRecord { t_s: 0.0, kind: ControlKind::Coplan, tenant: 0, shard: 1, a: 2, b: 0 },
            &[("eps", 2.0)],
        );
        o.push_sample(sample(5.0));
        o.journal.push(
            &ControlRecord {
                t_s: 7.0,
                kind: ControlKind::Fault,
                tenant: 0,
                shard: 0,
                a: 1,
                b: 0,
            },
            &[],
        );
        let rep = o.finish(CacheStats { hits: 3, misses: 1, entries: 1 });
        assert!(rep.prom.contains("shisha_events_total{tag=\"arrival\"} 2"));
        assert!(rep.prom.contains("shisha_admissions_total{tenant=\"a\",outcome=\"reject\"} 1"));
        assert!(rep.prom.contains("shisha_plan_cache_hits_total 3"));
        assert!(rep.prom.contains("shisha_epoch_samples_total 1"));
        let jsonl = rep.to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 2, "one sample line + one tail line: {jsonl}");
        assert!(lines[0].contains("\"schema\":\"shisha-obs-v1\""));
        assert!(lines[0].contains("\"kind\":\"coplan\""), "t=0 decision in first sample");
        assert!(lines[0].contains("\"stage_queue_hw\":[2,0]"));
        assert!(lines[1].contains("\"record\":\"tail\""));
        assert!(lines[1].contains("\"kind\":\"fault\""));
        let text = rep.analysis();
        assert!(text.contains("tenant a"));
        assert!(text.contains("coplan"));
        assert!(text.contains("eps=2.0000"));
        // Lifecycle-off: the tag 9–12 counters are not registered and the
        // per-tenant lifecycle fields are absent from the JSONL.
        assert!(!rep.prom.contains("tag=\"expire\""));
        assert!(!lines[0].contains("\"expired\""));
    }

    #[test]
    fn obs_lifecycle_gates_series_and_jsonl_fields() {
        let mut o = Obs::new(1, &[("a".to_string(), 1)], true);
        o.on_event(9);
        o.on_event(11);
        o.on_event(12);
        let mut s = sample(5.0);
        s.tenants[0].expired = 2;
        s.tenants[0].hedged = 1;
        o.push_sample(s);
        let rep = o.finish(CacheStats::default());
        assert!(rep.prom.contains("shisha_events_total{tag=\"expire\"} 1"));
        assert!(rep.prom.contains("shisha_events_total{tag=\"hedge\"} 1"));
        assert!(rep.prom.contains("shisha_events_total{tag=\"cancel\"} 1"));
        assert!(rep.prom.contains("shisha_events_total{tag=\"retry\"} 0"));
        let jsonl = rep.to_jsonl();
        assert!(jsonl.contains("\"expired\":2,\"cancelled\":0,\"retried\":0,\"hedged\":1"));
    }
}
