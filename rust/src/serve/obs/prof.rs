//! Engine self-profiling: monotonic-clock section timers.
//!
//! Wall-clock spans around the engine's major sections (event pump,
//! settle, warm re-tune, co-plan, drain/migrate, telemetry sampling),
//! reported as a time-breakdown table. Spans use [`std::time::Instant`]
//! and are therefore **non-deterministic across runs**; they are excluded
//! from every hash, from the JSONL epoch series, and from the Prometheus
//! snapshot — profiling is printed separately so the deterministic
//! surfaces stay byte-identical between live and replayed runs.

use std::time::Instant;

/// Number of profiled sections.
pub const N_SPANS: usize = 6;

/// A profiled engine section.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Span {
    /// The whole event pump (all other spans nest inside it).
    Pump,
    /// Dirty-stage settling after each event.
    Settle,
    /// Warm re-tune at an epoch tick (scratch PerfDb + controller).
    Retune,
    /// Elastic co-plan evaluation (`coplan_observed_with`).
    Coplan,
    /// Replica drain/migrate/rehome during failover or re-partition.
    DrainMigrate,
    /// Telemetry epoch sampling itself (the observer observing itself).
    Sample,
}

impl Span {
    /// Table row label.
    pub fn name(self) -> &'static str {
        match self {
            Span::Pump => "event pump",
            Span::Settle => "settle",
            Span::Retune => "re-tune",
            Span::Coplan => "coplan",
            Span::DrainMigrate => "drain/migrate",
            Span::Sample => "obs sample",
        }
    }

    fn index(self) -> usize {
        match self {
            Span::Pump => 0,
            Span::Settle => 1,
            Span::Retune => 2,
            Span::Coplan => 3,
            Span::DrainMigrate => 4,
            Span::Sample => 5,
        }
    }

    /// All spans in table order.
    pub fn all() -> [Span; N_SPANS] {
        [Span::Pump, Span::Settle, Span::Retune, Span::Coplan, Span::DrainMigrate, Span::Sample]
    }
}

/// Accumulated wall-clock per section.
#[derive(Debug, Clone, Default)]
pub struct Prof {
    calls: [u64; N_SPANS],
    total_s: [f64; N_SPANS],
}

impl Prof {
    /// Start a span (just a monotonic clock read; pair with [`Prof::add`]).
    #[inline]
    pub fn start() -> Instant {
        Instant::now()
    }

    /// Close a span opened with [`Prof::start`].
    #[inline]
    pub fn add(&mut self, span: Span, since: Instant) {
        let i = span.index();
        self.calls[i] += 1;
        self.total_s[i] += since.elapsed().as_secs_f64();
    }

    /// Freeze into the report rows.
    pub fn report(&self) -> ProfReport {
        ProfReport {
            rows: Span::all()
                .iter()
                .map(|&s| ProfRow {
                    name: s.name(),
                    calls: self.calls[s.index()],
                    total_s: self.total_s[s.index()],
                })
                .collect(),
        }
    }
}

/// One row of the self-profiling breakdown.
#[derive(Debug, Clone)]
pub struct ProfRow {
    /// Section label.
    pub name: &'static str,
    /// Times the section ran.
    pub calls: u64,
    /// Total wall-clock spent inside it, seconds.
    pub total_s: f64,
}

/// The self-profiling time breakdown of one serve run.
#[derive(Debug, Clone, Default)]
pub struct ProfReport {
    /// Rows in [`Span::all`] order; `rows[0]` is the whole event pump.
    pub rows: Vec<ProfRow>,
}

impl ProfReport {
    /// Wall-clock of the whole event pump (0 when profiling never ran).
    pub fn pump_s(&self) -> f64 {
        self.rows.first().map_or(0.0, |r| r.total_s)
    }

    /// Render the time-breakdown table (section, calls, total, % of pump).
    pub fn table(&self) -> String {
        use std::fmt::Write as _;
        let pump = self.pump_s();
        let mut out = String::new();
        let _ = writeln!(out, "self-profile (wall clock, excluded from all hashes):");
        let _ = writeln!(out, "  {:<14} {:>9} {:>12} {:>8}", "section", "calls", "total", "pump%");
        for r in &self.rows {
            let frac = if pump > 0.0 { 100.0 * r.total_s / pump } else { 0.0 };
            let _ = writeln!(
                out,
                "  {:<14} {:>9} {:>9.3} ms {:>7.1}%",
                r.name,
                r.calls,
                r.total_s * 1e3,
                frac
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_accumulate() {
        let mut p = Prof::default();
        let t0 = Prof::start();
        p.add(Span::Settle, t0);
        p.add(Span::Settle, t0);
        p.add(Span::Pump, t0);
        let rep = p.report();
        assert_eq!(rep.rows.len(), N_SPANS);
        let settle = rep.rows.iter().find(|r| r.name == "settle").unwrap();
        assert_eq!(settle.calls, 2);
        assert!(settle.total_s >= 0.0);
        let table = rep.table();
        assert!(table.contains("settle"));
        assert!(table.contains("event pump"));
    }
}
