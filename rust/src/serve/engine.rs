//! The discrete-event serving engine.
//!
//! [`serve`] pushes timestamped inference requests through Shisha-configured
//! pipelines on one shared [`Platform`]. The event model:
//!
//! * **Events** — request arrivals, stage-service completions, control-epoch
//!   ticks and post-reconfiguration resumes, ordered by `(time, sequence)`
//!   on a binary heap; ties break on scheduling order, so a run is fully
//!   deterministic for a fixed seed.
//! * **Stages** — each tenant stage owns a bounded FIFO queue and serves at
//!   most one batch at a time. Service time comes from the tenant's
//!   batch-aware [`PerfDb`] plus the inter-chiplet transfer cost, exactly
//!   as in [`crate::pipeline::simulator`], so with one tenant and no
//!   contention the engine's steady-state throughput equals the analytic
//!   `1/max_stage_time`.
//! * **Contention** — EPs are time-sliced: a batch dispatched while `k`
//!   other services are active on its EP runs `k+1`× slower (the factor is
//!   frozen at dispatch, a standard processor-sharing approximation);
//!   concurrent inter-chiplet transfers share the link the same way.
//! * **Backpressure** — a completed batch may only move into the downstream
//!   queue while there is room; otherwise the stage holds it (compute
//!   resources already released) and stalls until the downstream stage
//!   dispatches. Admission at the entry queue follows the tenant's
//!   [`AdmissionPolicy`].
//! * **Online control** — every control epoch the engine compares each
//!   tenant's SLO goodput against its rolling baseline; a regression under
//!   queue pressure (the signature of arrival-rate drift or cross-tenant
//!   contention) triggers an [`AdaptiveController`] **warm re-tune** on the
//!   per-layer database rescaled by the observed per-EP slowdown EWMA. A
//!   changed configuration is applied by interrupting in-flight batches
//!   (their requests are re-queued at their completed-layer position, so no
//!   request is ever lost) and freezing dispatch for a short
//!   reconfiguration penalty. Re-binning on a new stage structure may
//!   transiently overshoot queue bounds; the bound is a steady-state
//!   admission bound.

use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, VecDeque};

use anyhow::{bail, Result};

use crate::coordinator::AdaptiveController;
use crate::perfdb::{batch, CostModel, PerfDb};
use crate::pipeline::PipelineConfig;
use crate::platform::{topology, Platform};
use crate::rng::Xoshiro256;

use super::arrivals::ArrivalSampler;
use super::slo::{jain_fairness, QuantileSketch};
use super::tenant::{AdmissionPolicy, TenantSpec};

/// Engine-level options (tenant-level knobs live on [`TenantSpec`]).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Simulated horizon, seconds: arrivals and completions beyond it are
    /// not processed (work still running at the horizon counts in-flight).
    pub duration_s: f64,
    /// Master seed; every tenant's arrival stream forks from it.
    pub seed: u64,
    /// Enable the online re-tuning control loop.
    pub control: bool,
    /// Control/metrics epoch length, seconds (≤ 0 disables epochs).
    pub control_epoch_s: f64,
    /// Re-tune when epoch goodput falls below this fraction of baseline.
    pub retune_threshold: f64,
    /// Minimum epochs between warm re-tunes of one tenant.
    pub retune_cooldown_epochs: u32,
    /// Dispatch freeze after applying a new configuration, seconds.
    pub reconfig_penalty_s: f64,
    /// Model EP/link contention (off = tenants run as if isolated).
    pub contention: bool,
    /// Keep a human-readable event log in the report (tests/debugging).
    pub record_log: bool,
    /// Safety valve: abort (with `truncated = true`) past this many events.
    pub max_events: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            duration_s: 60.0,
            seed: 42,
            control: true,
            control_epoch_s: 5.0,
            retune_threshold: 0.7,
            retune_cooldown_epochs: 2,
            reconfig_penalty_s: 0.05,
            contention: true,
            record_log: false,
            max_events: 20_000_000,
        }
    }
}

/// One request travelling through a tenant's pipeline.
#[derive(Debug, Clone)]
struct Request {
    id: u64,
    arrival_s: f64,
    /// Layers completed so far (used to re-bin across reconfigurations).
    layers_done: usize,
}

/// A batch being serviced (or completed and awaiting downstream room).
#[derive(Debug, Clone)]
struct InFlight {
    reqs: Vec<Request>,
    ep: usize,
    uses_link: bool,
    done_s: f64,
    /// Observed slowdown vs the contention-free service time.
    factor: f64,
    completed: bool,
    layers_after: usize,
}

#[derive(Debug, Default)]
struct StageRt {
    queue: VecDeque<Request>,
    busy: Option<InFlight>,
}

/// Per-epoch record of one tenant.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochStats {
    /// Epoch end time, seconds.
    pub end_s: f64,
    /// Arrivals offered during the epoch.
    pub offered: u64,
    /// Completions during the epoch.
    pub completed: u64,
    /// Completions within the SLO during the epoch.
    pub slo_ok: u64,
    /// Rejected arrivals during the epoch.
    pub rejected: u64,
    /// Dropped (DropOldest) requests during the epoch.
    pub dropped: u64,
    /// SLO goodput, requests/second.
    pub goodput: f64,
    /// Raw completion throughput, requests/second.
    pub throughput: f64,
    /// Requests queued or in service at the epoch tick.
    pub backlog: u64,
    /// Whether a warm re-tune ran this epoch.
    pub retuned: bool,
    /// Evaluator trials the re-tune consumed.
    pub retune_trials: u64,
}

/// Final per-tenant report.
#[derive(Debug, Clone)]
pub struct TenantReport {
    /// Tenant name.
    pub name: String,
    /// Configuration the run started with.
    pub initial_config: PipelineConfig,
    /// Configuration in service at the horizon.
    pub final_config: PipelineConfig,
    /// Total arrivals offered.
    pub offered: u64,
    /// Arrivals rejected at admission.
    pub rejected: u64,
    /// Admitted requests dropped later (DropOldest).
    pub dropped: u64,
    /// Requests fully completed.
    pub completed: u64,
    /// Completions within the SLO.
    pub slo_ok: u64,
    /// Requests still queued or in service at the horizon.
    pub in_flight: u64,
    /// Largest per-stage queue length observed (steady-state admissions).
    pub max_queue_len: usize,
    /// Latency sketch over completed requests.
    pub latency: QuantileSketch,
    /// Per-epoch time series.
    pub epochs: Vec<EpochStats>,
    /// Warm re-tunes triggered.
    pub retunes: u32,
    /// Total evaluator trials across re-tunes.
    pub retune_trials: u64,
}

impl TenantReport {
    /// Requests admitted past the entry queue.
    pub fn admitted(&self) -> u64 {
        self.offered - self.rejected
    }

    /// SLO goodput over the whole run, requests/second.
    pub fn goodput(&self, duration_s: f64) -> f64 {
        if duration_s > 0.0 {
            self.slo_ok as f64 / duration_s
        } else {
            0.0
        }
    }

    /// Fraction of offered requests rejected or dropped.
    pub fn drop_rate(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            (self.rejected + self.dropped) as f64 / self.offered as f64
        }
    }

    /// Request conservation: every offered request is accounted for.
    pub fn conserved(&self) -> bool {
        self.offered == self.rejected + self.dropped + self.completed + self.in_flight
    }

    /// Row for [`crate::metrics::table::latency_table`] — the one mapping
    /// from a tenant report to the shared percentile renderer.
    pub fn latency_row(&self, duration_s: f64) -> crate::metrics::table::LatencyRow {
        crate::metrics::table::LatencyRow {
            label: self.name.clone(),
            p50_s: self.latency.p50(),
            p95_s: self.latency.p95(),
            p99_s: self.latency.p99(),
            max_s: self.latency.max_s(),
            goodput_rps: self.goodput(duration_s),
            drop_rate: self.drop_rate(),
        }
    }
}

/// Result of a serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Simulated horizon, seconds.
    pub duration_s: f64,
    /// Per-tenant reports, in input order.
    pub tenants: Vec<TenantReport>,
    /// Events processed.
    pub n_events: u64,
    /// FNV-1a hash of the full event stream (determinism witness).
    pub log_hash: u64,
    /// Human-readable event log (only when `record_log`).
    pub event_log: Vec<String>,
    /// True when the `max_events` safety valve fired.
    pub truncated: bool,
}

impl ServeReport {
    /// Per-tenant SLO goodputs, requests/second.
    pub fn goodputs(&self) -> Vec<f64> {
        self.tenants.iter().map(|t| t.goodput(self.duration_s)).collect()
    }

    /// Jain fairness index over per-tenant goodputs.
    pub fn fairness(&self) -> f64 {
        jain_fairness(&self.goodputs())
    }
}

// ---------------------------------------------------------------------------
// event plumbing

#[derive(Debug, Clone, Copy)]
enum EvKind {
    Arrival { tenant: usize },
    StageDone { tenant: usize, stage: usize, gen: u64 },
    Epoch,
    Resume { tenant: usize },
}

#[derive(Debug, Clone, Copy)]
struct Ev {
    t: f64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

/// State shared across tenants: the event heap and contention counters.
struct Shared {
    heap: BinaryHeap<Reverse<Ev>>,
    seq: u64,
    /// Services currently computing on each EP (all tenants).
    ep_busy: Vec<u32>,
    /// Inter-chiplet transfers currently in flight (all tenants).
    link_busy: u32,
    contention: bool,
    n_events: u64,
    log_hash: u64,
    log: Vec<String>,
    record_log: bool,
}

impl Shared {
    fn schedule(&mut self, t: f64, kind: EvKind) {
        self.seq += 1;
        self.heap.push(Reverse(Ev { t, seq: self.seq, kind }));
    }

    fn note(&mut self, t: f64, tag: u64, a: u64, b: u64, text: impl FnOnce() -> String) {
        for x in [tag, a, b, t.to_bits()] {
            for byte in x.to_le_bytes() {
                self.log_hash ^= byte as u64;
                self.log_hash = self.log_hash.wrapping_mul(0x0000_0100_0000_01B3);
            }
        }
        if self.record_log {
            let line = text();
            self.log.push(line);
        }
    }
}

/// EWMA weight for the per-EP observed-slowdown estimate.
const EWMA_GAIN: f64 = 0.2;
/// Per-epoch relaxation of the slowdown estimate towards 1.0, so an EP the
/// tenant no longer touches (after migrating away) does not keep a stale
/// contention penalty forever and can be re-adopted by a later re-tune.
const EWMA_EPOCH_RELAX: f64 = 0.5;
/// Per-epoch decay of the goodput baseline: a *rolling* max that follows
/// genuine load declines (diurnal lulls) within ~20 epochs instead of
/// ratcheting to the all-time peak and firing re-tunes all night.
const BASELINE_DECAY: f64 = 0.95;

struct TenantRt {
    spec: TenantSpec,
    config: PipelineConfig,
    initial_config: PipelineConfig,
    bounds: Vec<(usize, usize)>,
    /// Batch-aware databases: `dbs[b-1]` holds per-stage times at batch `b`.
    dbs: Vec<PerfDb>,
    stages: Vec<StageRt>,
    sampler: ArrivalSampler,
    controller: AdaptiveController,
    /// Reconfiguration generation; stale StageDone events are ignored.
    gen: u64,
    frozen_until: f64,
    /// Observed per-EP slowdown EWMA (1.0 = uncontended).
    ep_slow: Vec<f64>,
    next_id: u64,
    // cumulative counters
    offered: u64,
    rejected: u64,
    dropped: u64,
    completed: u64,
    slo_ok: u64,
    max_queue_len: usize,
    latency: QuantileSketch,
    // epoch accumulators
    ep_offered: u64,
    ep_completed: u64,
    ep_slo_ok: u64,
    ep_rejected: u64,
    ep_dropped: u64,
    baseline_goodput: f64,
    epochs_since_retune: u32,
    retunes: u32,
    retune_trials: u64,
    epochs: Vec<EpochStats>,
}

impl TenantRt {
    fn backlog(&self) -> u64 {
        self.stages
            .iter()
            .map(|s| {
                s.queue.len() as u64
                    + s.busy.as_ref().map_or(0, |inf| inf.reqs.len() as u64)
            })
            .sum()
    }

    /// Requests *waiting* in queues (excludes batches in service): the
    /// pressure signal — a lone in-flight request is normal operation,
    /// a non-empty queue means demand outruns service.
    fn queued(&self) -> u64 {
        self.stages.iter().map(|s| s.queue.len() as u64).sum()
    }
}

// ---------------------------------------------------------------------------
// per-stage mechanics (free functions keep the borrows simple)

/// Move a completed batch forward: finish requests on the last stage, or
/// shift them into the downstream queue while it has room. Returns true on
/// any progress.
fn deliver_stage(t: &mut TenantRt, si: usize) -> bool {
    let is_completed = matches!(&t.stages[si].busy, Some(inf) if inf.completed);
    if !is_completed {
        return false;
    }
    let n_layers = t.spec.net.len();
    let finishes = t.stages[si].busy.as_ref().map_or(false, |inf| inf.layers_after >= n_layers);
    if finishes {
        let inf = t.stages[si].busy.take().expect("checked above");
        let slo = t.spec.slo_latency_s;
        for req in inf.reqs {
            let lat = inf.done_s - req.arrival_s;
            t.completed += 1;
            t.ep_completed += 1;
            if lat <= slo {
                t.slo_ok += 1;
                t.ep_slo_ok += 1;
            }
            t.latency.record(lat);
        }
        return true;
    }
    if si + 1 >= t.stages.len() {
        // layers_after < n_layers can only happen mid-reconfig; re-binning
        // handles it, never ordinary delivery
        return false;
    }
    let cap = t.spec.queue_capacity;
    let mut moved = false;
    let drained = {
        let (left, right) = t.stages.split_at_mut(si + 1);
        let cur = &mut left[si];
        let next = &mut right[0];
        let inf = cur.busy.as_mut().expect("checked above");
        while !inf.reqs.is_empty() && next.queue.len() < cap {
            next.queue.push_back(inf.reqs.remove(0));
            moved = true;
        }
        inf.reqs.is_empty()
    };
    if drained {
        t.stages[si].busy = None;
    }
    if moved {
        let l = t.stages[si + 1].queue.len();
        if l > t.max_queue_len {
            t.max_queue_len = l;
        }
    }
    moved
}

/// Start servicing a batch on stage `si` if it is idle and has queued work.
/// Returns true when a service was started.
#[allow(clippy::too_many_arguments)]
fn dispatch_stage(
    t: &mut TenantRt,
    sh: &mut Shared,
    plat: &Platform,
    ti: usize,
    si: usize,
    now: f64,
    duration_s: f64,
) -> bool {
    if now < t.frozen_until {
        return false;
    }
    if t.stages[si].busy.is_some() || t.stages[si].queue.is_empty() {
        return false;
    }
    let b = t.spec.batch.min(t.stages[si].queue.len());
    let (lo, hi) = t.bounds[si];
    let ep = t.config.assignment[si];
    let compute = t.dbs[b - 1].range_time(lo, hi, ep);
    let transfer = if si == 0 {
        0.0
    } else {
        let prev = t.config.assignment[si - 1];
        topology::transfer_time(plat, prev, ep, t.spec.net.layers[lo - 1].output_bytes() * b as u64)
    };
    let uses_link = transfer > 0.0;
    let ep_factor = if sh.contention { (sh.ep_busy[ep] + 1) as f64 } else { 1.0 };
    let link_factor =
        if sh.contention && uses_link { (sh.link_busy + 1) as f64 } else { 1.0 };
    let base = compute + transfer;
    let actual = compute * ep_factor + transfer * link_factor;
    let mut reqs = Vec::with_capacity(b);
    for _ in 0..b {
        reqs.push(t.stages[si].queue.pop_front().expect("len checked"));
    }
    sh.ep_busy[ep] += 1;
    if uses_link {
        sh.link_busy += 1;
    }
    let done = now + actual;
    let factor = if base > 0.0 { actual / base } else { 1.0 };
    t.stages[si].busy =
        Some(InFlight { reqs, ep, uses_link, done_s: done, factor, completed: false, layers_after: hi });
    if done <= duration_s {
        sh.schedule(done, EvKind::StageDone { tenant: ti, stage: si, gen: t.gen });
    }
    true
}

/// Settle a tenant's pipeline after any state change: repeatedly deliver
/// completed batches and dispatch idle stages until a fixpoint.
fn pump(t: &mut TenantRt, sh: &mut Shared, plat: &Platform, ti: usize, now: f64, duration_s: f64) {
    loop {
        let mut progress = false;
        for si in (0..t.stages.len()).rev() {
            progress |= deliver_stage(t, si);
            progress |= dispatch_stage(t, sh, plat, ti, si, now, duration_s);
        }
        if !progress {
            break;
        }
    }
}

/// Apply a new configuration: interrupt in-flight work (requests are
/// re-queued at their completed-layer position; partial stage work is
/// lost), rebuild the stage array, and freeze dispatch for the penalty.
fn apply_reconfig(
    t: &mut TenantRt,
    sh: &mut Shared,
    ti: usize,
    now: f64,
    new_config: PipelineConfig,
    penalty_s: f64,
    duration_s: f64,
) {
    t.gen += 1;
    let mut orphans: Vec<Request> = Vec::new();
    for st in &mut t.stages {
        if let Some(inf) = st.busy.take() {
            if !inf.completed {
                sh.ep_busy[inf.ep] = sh.ep_busy[inf.ep].saturating_sub(1);
                if inf.uses_link {
                    sh.link_busy = sh.link_busy.saturating_sub(1);
                }
            }
            orphans.extend(inf.reqs);
        }
        orphans.extend(st.queue.drain(..));
    }
    // oldest requests re-queue first (deterministic, arrival-order fair)
    orphans.sort_by_key(|r| r.id);
    t.config = new_config;
    t.bounds = t.config.stage_bounds();
    t.stages = (0..t.config.n_stages()).map(|_| StageRt::default()).collect();
    let n_layers = t.spec.net.len();
    for req in orphans {
        // completed-but-undelivered batches sit at a stage boundary; resume
        // from the stage owning the next layer (never past the last stage)
        let si = if req.layers_done >= n_layers {
            t.stages.len() - 1
        } else {
            t.config.stage_of_layer(req.layers_done).expect("layer in range")
        };
        t.stages[si].queue.push_back(req);
    }
    t.frozen_until = now + penalty_s;
    if t.frozen_until <= duration_s {
        sh.schedule(t.frozen_until, EvKind::Resume { tenant: ti });
    }
}

/// Finalize one tenant's control epoch: record stats and, under goodput
/// regression with queue pressure, run the warm re-tune.
#[allow(clippy::too_many_arguments)]
fn epoch_tick(
    t: &mut TenantRt,
    sh: &mut Shared,
    ti: usize,
    now: f64,
    opts: &ServeOptions,
    plat: &Platform,
) {
    let epoch_s = opts.control_epoch_s;
    let goodput = t.ep_slo_ok as f64 / epoch_s;
    let throughput = t.ep_completed as f64 / epoch_s;
    let backlog = t.backlog();
    let pressure = t.queued() > 0 || t.ep_rejected > 0 || t.ep_dropped > 0;
    let mut retuned = false;
    let mut trials = 0u64;
    // rolling-max baseline: tracks the best recently sustained goodput,
    // decaying ~5%/epoch so genuine load declines stop looking like drift
    t.baseline_goodput = (t.baseline_goodput * BASELINE_DECAY).max(goodput);
    if opts.control
        && pressure
        && t.epochs_since_retune >= opts.retune_cooldown_epochs
        && t.baseline_goodput > 0.0
        && goodput < opts.retune_threshold * t.baseline_goodput
    {
        // observed database: contention-free costs at the tenant's service
        // batch size (what dispatch actually charges), rescaled by the
        // per-EP slowdown the tenant experienced
        let mut db = t.dbs[t.spec.batch - 1].clone();
        for ep in 0..plat.n_eps() {
            let f = t.ep_slow[ep].max(1.0);
            if f > 1.001 {
                db.scale_ep(ep, f);
            }
        }
        let (best, n) = t.controller.warm_retune(&db, t.config.clone());
        trials = n;
        t.retunes += 1;
        t.retune_trials += n;
        t.epochs_since_retune = 0;
        retuned = true;
        if best != t.config {
            apply_reconfig(t, sh, ti, now, best, opts.reconfig_penalty_s, opts.duration_s);
        }
    }
    if !retuned {
        t.epochs_since_retune = t.epochs_since_retune.saturating_add(1);
    }
    t.epochs.push(EpochStats {
        end_s: now,
        offered: t.ep_offered,
        completed: t.ep_completed,
        slo_ok: t.ep_slo_ok,
        rejected: t.ep_rejected,
        dropped: t.ep_dropped,
        goodput,
        throughput,
        backlog,
        retuned,
        retune_trials: trials,
    });
    t.ep_offered = 0;
    t.ep_completed = 0;
    t.ep_slo_ok = 0;
    t.ep_rejected = 0;
    t.ep_dropped = 0;
    // stale contention estimates relax towards 1.0 (uncontended) between
    // epochs so EPs the tenant migrated away from — which no longer
    // produce completions to update the EWMA — become eligible again
    for f in &mut t.ep_slow {
        *f = 1.0 + (*f - 1.0) * EWMA_EPOCH_RELAX;
    }
}

// ---------------------------------------------------------------------------
// the engine proper

/// Serve `tenants` (spec + initial pipeline configuration) on `plat` for
/// `opts.duration_s` simulated seconds. Deterministic for a fixed
/// `opts.seed`.
pub fn serve(
    plat: &Platform,
    tenants: Vec<(TenantSpec, PipelineConfig)>,
    opts: &ServeOptions,
) -> Result<ServeReport> {
    if tenants.is_empty() {
        bail!("serve: at least one tenant required");
    }
    if opts.duration_s <= 0.0 {
        bail!("serve: duration must be positive");
    }
    let model = CostModel::default();
    let mut master = Xoshiro256::seed_from(opts.seed);
    let mut rts: Vec<TenantRt> = Vec::with_capacity(tenants.len());
    for (spec, config) in tenants {
        spec.validate(plat, &config)?;
        let mut dbs = Vec::with_capacity(spec.batch);
        for b in 1..=spec.batch {
            dbs.push(if b == 1 {
                PerfDb::build(&spec.net, plat, &model)
            } else {
                batch::build_batched(&spec.net, plat, &model, b as u32)
            });
        }
        let sampler = spec.arrivals.sampler(master.fork());
        let controller = AdaptiveController::new(spec.net.clone(), plat.clone(), model.clone());
        let bounds = config.stage_bounds();
        let n_stages = config.n_stages();
        rts.push(TenantRt {
            initial_config: config.clone(),
            config,
            bounds,
            dbs,
            stages: (0..n_stages).map(|_| StageRt::default()).collect(),
            sampler,
            controller,
            gen: 0,
            frozen_until: 0.0,
            ep_slow: vec![1.0; plat.n_eps()],
            next_id: 0,
            offered: 0,
            rejected: 0,
            dropped: 0,
            completed: 0,
            slo_ok: 0,
            max_queue_len: 0,
            latency: QuantileSketch::new(),
            ep_offered: 0,
            ep_completed: 0,
            ep_slo_ok: 0,
            ep_rejected: 0,
            ep_dropped: 0,
            baseline_goodput: 0.0,
            epochs_since_retune: opts.retune_cooldown_epochs,
            retunes: 0,
            retune_trials: 0,
            epochs: Vec::new(),
            spec,
        });
    }

    let mut sh = Shared {
        heap: BinaryHeap::new(),
        seq: 0,
        ep_busy: vec![0; plat.n_eps()],
        link_busy: 0,
        contention: opts.contention,
        n_events: 0,
        log_hash: 0xCBF2_9CE4_8422_2325,
        log: Vec::new(),
        record_log: opts.record_log,
    };

    for (ti, t) in rts.iter_mut().enumerate() {
        if let Some(first) = t.sampler.next_after(0.0) {
            if first <= opts.duration_s {
                sh.schedule(first, EvKind::Arrival { tenant: ti });
            }
        }
    }
    if opts.control_epoch_s > 0.0 && opts.control_epoch_s <= opts.duration_s {
        sh.schedule(opts.control_epoch_s, EvKind::Epoch);
    }

    let mut truncated = false;
    while let Some(Reverse(ev)) = sh.heap.pop() {
        sh.n_events += 1;
        if sh.n_events > opts.max_events {
            truncated = true;
            break;
        }
        let now = ev.t;
        match ev.kind {
            EvKind::Arrival { tenant } => {
                let t = &mut rts[tenant];
                sh.note(now, 1, tenant as u64, t.next_id, || {
                    format!("{now:.6} arrival {}#{}", t.spec.name, t.next_id)
                });
                t.offered += 1;
                t.ep_offered += 1;
                let req = Request { id: t.next_id, arrival_s: now, layers_done: 0 };
                t.next_id += 1;
                let cap = t.spec.queue_capacity;
                if t.stages[0].queue.len() >= cap {
                    match t.spec.admission {
                        AdmissionPolicy::Reject => {
                            t.rejected += 1;
                            t.ep_rejected += 1;
                        }
                        AdmissionPolicy::DropOldest => {
                            t.stages[0].queue.pop_front();
                            t.dropped += 1;
                            t.ep_dropped += 1;
                            t.stages[0].queue.push_back(req);
                        }
                    }
                } else {
                    t.stages[0].queue.push_back(req);
                    let l = t.stages[0].queue.len();
                    if l > t.max_queue_len {
                        t.max_queue_len = l;
                    }
                }
                if let Some(next) = t.sampler.next_after(now) {
                    if next <= opts.duration_s {
                        sh.schedule(next, EvKind::Arrival { tenant });
                    }
                }
                pump(t, &mut sh, plat, tenant, now, opts.duration_s);
            }
            EvKind::StageDone { tenant, stage, gen } => {
                let t = &mut rts[tenant];
                if gen != t.gen {
                    // the batch was interrupted by a reconfiguration
                    sh.note(now, 2, tenant as u64, stage as u64, || {
                        format!("{now:.6} stale-done {} s{stage}", t.spec.name)
                    });
                    continue;
                }
                sh.note(now, 3, tenant as u64, stage as u64, || {
                    format!("{now:.6} done {} s{stage}", t.spec.name)
                });
                if let Some(inf) = t.stages[stage].busy.as_mut() {
                    if !inf.completed {
                        inf.completed = true;
                        let la = inf.layers_after;
                        for r in &mut inf.reqs {
                            r.layers_done = la;
                        }
                        let (ep, uses_link, factor) = (inf.ep, inf.uses_link, inf.factor);
                        sh.ep_busy[ep] = sh.ep_busy[ep].saturating_sub(1);
                        if uses_link {
                            sh.link_busy = sh.link_busy.saturating_sub(1);
                        }
                        t.ep_slow[ep] =
                            (1.0 - EWMA_GAIN) * t.ep_slow[ep] + EWMA_GAIN * factor;
                    }
                }
                pump(t, &mut sh, plat, tenant, now, opts.duration_s);
            }
            EvKind::Resume { tenant } => {
                let t = &mut rts[tenant];
                sh.note(now, 4, tenant as u64, 0, || {
                    format!("{now:.6} resume {}", t.spec.name)
                });
                pump(t, &mut sh, plat, tenant, now, opts.duration_s);
            }
            EvKind::Epoch => {
                sh.note(now, 5, 0, 0, || format!("{now:.6} epoch"));
                for (ti, t) in rts.iter_mut().enumerate() {
                    epoch_tick(t, &mut sh, ti, now, opts, plat);
                    pump(t, &mut sh, plat, ti, now, opts.duration_s);
                }
                let next = now + opts.control_epoch_s;
                if next <= opts.duration_s {
                    sh.schedule(next, EvKind::Epoch);
                }
            }
        }
    }

    let tenants = rts
        .into_iter()
        .map(|t| {
            let in_flight = t.backlog();
            TenantReport {
                name: t.spec.name.clone(),
                initial_config: t.initial_config,
                final_config: t.config,
                offered: t.offered,
                rejected: t.rejected,
                dropped: t.dropped,
                completed: t.completed,
                slo_ok: t.slo_ok,
                in_flight,
                max_queue_len: t.max_queue_len,
                latency: t.latency,
                epochs: t.epochs,
                retunes: t.retunes,
                retune_trials: t.retune_trials,
            }
        })
        .collect();
    Ok(ServeReport {
        duration_s: opts.duration_s,
        tenants,
        n_events: sh.n_events,
        log_hash: sh.log_hash,
        event_log: sh.log,
        truncated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::networks;
    use crate::pipeline::simulator;
    use crate::serve::arrivals::ArrivalProcess;

    /// synthnet_small split across the two EP classes of C1.
    fn small_tenant(name: &str, rate: f64) -> (TenantSpec, PipelineConfig) {
        let net = networks::synthnet_small();
        let cfg = PipelineConfig::new(vec![3, 3], vec![0, 1]);
        let spec = TenantSpec::new(name, net, ArrivalProcess::Poisson { rate });
        (spec, cfg)
    }

    fn capacity(spec: &TenantSpec, plat: &Platform, cfg: &PipelineConfig) -> f64 {
        let db = PerfDb::build(&spec.net, plat, &CostModel::default());
        simulator::throughput(&spec.net, plat, &db, cfg)
    }

    fn base_opts(duration_s: f64) -> ServeOptions {
        ServeOptions { duration_s, control: false, control_epoch_s: 0.0, ..Default::default() }
    }

    #[test]
    fn zero_rate_serves_nothing() {
        let plat = crate::platform::configs::c1();
        let (spec, cfg) = small_tenant("idle", 0.0);
        let report = serve(&plat, vec![(spec, cfg)], &base_opts(1.0)).unwrap();
        let t = &report.tenants[0];
        assert_eq!(t.offered, 0);
        assert_eq!(t.completed, 0);
        assert!(t.conserved());
    }

    #[test]
    fn underload_completes_everything_and_conserves() {
        let plat = crate::platform::configs::c1();
        let (spec, cfg) = small_tenant("t0", 0.0);
        let cap = capacity(&spec, &plat, &cfg);
        let (spec, cfg) = small_tenant("t0", 0.3 * cap);
        let spec = spec.with_slo(100.0 / cap);
        let dur = 200.0 / cap;
        let report = serve(&plat, vec![(spec, cfg)], &base_opts(dur)).unwrap();
        let t = &report.tenants[0];
        assert!(t.offered > 20, "expected real traffic, got {}", t.offered);
        assert!(t.conserved(), "conservation: {t:?}");
        assert_eq!(t.rejected + t.dropped, 0, "underload must not shed load");
        assert!(t.completed as f64 >= 0.8 * t.offered as f64);
        assert_eq!(t.slo_ok, t.completed, "generous SLO: everything on time");
        assert!(t.latency.p50() > 0.0);
        assert!(t.latency.p99() >= t.latency.p50());
    }

    #[test]
    fn overload_sheds_load_but_conserves() {
        let plat = crate::platform::configs::c1();
        let (probe, cfg) = small_tenant("x", 0.0);
        let cap = capacity(&probe, &plat, &cfg);
        for policy in [AdmissionPolicy::Reject, AdmissionPolicy::DropOldest] {
            let (spec, cfg) = small_tenant("t0", 4.0 * cap);
            let spec = spec.with_queue_capacity(16).with_admission(policy);
            let dur = 300.0 / cap;
            let report = serve(&plat, vec![(spec, cfg)], &base_opts(dur)).unwrap();
            let t = &report.tenants[0];
            assert!(t.conserved(), "conservation under {policy:?}: {t:?}");
            assert!(t.rejected + t.dropped > 0, "overload must shed load ({policy:?})");
            assert!(t.completed > 0);
            match policy {
                AdmissionPolicy::Reject => assert_eq!(t.dropped, 0),
                AdmissionPolicy::DropOldest => assert_eq!(t.rejected, 0),
            }
        }
    }

    #[test]
    fn queue_bound_respected_without_reconfig() {
        let plat = crate::platform::configs::c1();
        let (probe, cfg) = small_tenant("x", 0.0);
        let cap = capacity(&probe, &plat, &cfg);
        let (spec, cfg) = small_tenant("t0", 5.0 * cap);
        let spec = spec.with_queue_capacity(7);
        let report = serve(&plat, vec![(spec, cfg)], &base_opts(200.0 / cap)).unwrap();
        let t = &report.tenants[0];
        assert!(t.max_queue_len <= 7, "queue bound violated: {}", t.max_queue_len);
        assert!(t.conserved());
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let plat = crate::platform::configs::c2();
        let run = |seed: u64| {
            let (probe, cfg) = small_tenant("x", 0.0);
            let cap = capacity(&probe, &plat, &cfg);
            let (a, ca) = small_tenant("a", 0.8 * cap);
            let b_net = networks::synthnet_small();
            let b_spec = TenantSpec::new(
                "b",
                b_net,
                ArrivalProcess::Mmpp {
                    low_rate: 0.1 * cap,
                    high_rate: 1.5 * cap,
                    mean_low_s: 20.0 / cap,
                    mean_high_s: 10.0 / cap,
                },
            );
            let cb = PipelineConfig::new(vec![3, 3], vec![2, 3]);
            let mut opts = base_opts(300.0 / cap);
            opts.seed = seed;
            opts.record_log = true;
            serve(&plat, vec![(a, ca), (b_spec, cb)], &opts).unwrap()
        };
        let r1 = run(9);
        let r2 = run(9);
        assert_eq!(r1.log_hash, r2.log_hash, "event streams must be identical");
        assert_eq!(r1.event_log, r2.event_log);
        assert_eq!(r1.n_events, r2.n_events);
        for (a, b) in r1.tenants.iter().zip(&r2.tenants) {
            assert_eq!(a.offered, b.offered);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.latency.p99(), b.latency.p99());
        }
        let r3 = run(10);
        assert_ne!(r1.log_hash, r3.log_hash, "different seeds should differ");
    }

    #[test]
    fn contention_halves_co_located_tenants() {
        let plat = crate::platform::configs::c1();
        let net = networks::synthnet_small();
        let cfg = PipelineConfig::single_stage(net.len(), 0);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        let dur = 400.0 / cap;
        let mk = |name: &str| {
            (
                TenantSpec::new(name, net.clone(), ArrivalProcess::Poisson { rate: 3.0 * cap })
                    .with_queue_capacity(16),
                cfg.clone(),
            )
        };
        let solo = serve(&plat, vec![mk("solo")], &base_opts(dur)).unwrap();
        let duo = serve(&plat, vec![mk("a"), mk("b")], &base_opts(dur)).unwrap();
        let c_solo = solo.tenants[0].completed as f64;
        let c_a = duo.tenants[0].completed as f64;
        let c_b = duo.tenants[1].completed as f64;
        assert!(
            c_a < 0.75 * c_solo && c_b < 0.75 * c_solo,
            "time-slicing must slow co-located tenants: solo {c_solo}, duo {c_a}/{c_b}"
        );
        assert!(
            (c_a + c_b) < 1.3 * c_solo,
            "shared EP cannot serve much more than its capacity"
        );
        for t in &duo.tenants {
            assert!(t.conserved());
        }
    }

    #[test]
    fn batching_reduces_event_count() {
        let plat = crate::platform::configs::c1();
        let (probe, cfg) = small_tenant("x", 0.0);
        let cap = capacity(&probe, &plat, &cfg);
        let run = |batch: usize| {
            let (spec, cfg) = small_tenant("t0", 2.0 * cap);
            let spec = spec.with_batch(batch).with_queue_capacity(64);
            serve(&plat, vec![(spec, cfg)], &base_opts(300.0 / cap)).unwrap()
        };
        let b1 = run(1);
        let b8 = run(8);
        assert!(b1.tenants[0].conserved());
        assert!(b8.tenants[0].conserved());
        assert!(b8.tenants[0].completed > 0);
        assert!(
            b8.n_events < b1.n_events,
            "batching must amortise events: {} vs {}",
            b8.n_events,
            b1.n_events
        );
        // batch-aware service amortises overhead: more goodput under load
        assert!(
            b8.tenants[0].completed as f64 > 0.8 * b1.tenants[0].completed as f64,
            "batched run should not collapse: {} vs {}",
            b8.tenants[0].completed,
            b1.tenants[0].completed
        );
    }

    #[test]
    fn epochs_recorded_when_enabled() {
        let plat = crate::platform::configs::c1();
        let (probe, cfg) = small_tenant("x", 0.0);
        let cap = capacity(&probe, &plat, &cfg);
        let (spec, cfg) = small_tenant("t0", 0.5 * cap);
        let mut opts = base_opts(100.0 / cap);
        opts.control_epoch_s = 20.0 / cap;
        let report = serve(&plat, vec![(spec, cfg)], &opts).unwrap();
        let t = &report.tenants[0];
        // 100/20 = 5 ticks, minus possibly one to floating-point accumulation
        assert!((4..=5).contains(&t.epochs.len()), "epochs {}", t.epochs.len());
        let total: u64 = t.epochs.iter().map(|e| e.offered).sum();
        assert!(total <= t.offered);
        assert!(t.epochs.iter().all(|e| !e.retuned), "control disabled");
    }

    #[test]
    fn rejects_invalid_inputs() {
        let plat = crate::platform::configs::c1();
        assert!(serve(&plat, vec![], &ServeOptions::default()).is_err());
        let (spec, cfg) = small_tenant("t0", 1.0);
        let opts = ServeOptions { duration_s: 0.0, ..Default::default() };
        assert!(serve(&plat, vec![(spec, cfg)], &opts).is_err());
        let (spec, _) = small_tenant("t0", 1.0);
        let bad = PipelineConfig::new(vec![2], vec![0]);
        assert!(serve(&plat, vec![(spec, bad)], &ServeOptions::default()).is_err());
    }
}
