//! The discrete-event serving engine.
//!
//! [`serve`] pushes timestamped inference requests through Shisha-configured
//! pipelines on one shared [`Platform`]. The event model:
//!
//! * **Events** — request arrivals, stage-service completions, control-epoch
//!   ticks and post-reconfiguration resumes, ordered by `(time, sequence)`
//!   on a binary heap; ties break on scheduling order, so a run is fully
//!   deterministic for a fixed seed.
//! * **Stages** — each tenant stage owns a bounded FIFO queue and serves at
//!   most one batch at a time. Service time comes from the tenant's
//!   batch-aware [`PerfDb`] plus the inter-chiplet transfer cost, through
//!   the **shared** per-stage formula
//!   [`crate::pipeline::simulator::stage_service_time`], so with one tenant
//!   and no contention the engine's steady-state throughput equals the
//!   analytic `1/max_stage_time` and the contention model cannot drift from
//!   the analytic model.
//! * **Contention** — EPs are time-sliced: a batch dispatched while `k`
//!   other services are active on its EP runs `k+1`× slower (the factor is
//!   frozen at dispatch, a standard processor-sharing approximation);
//!   concurrent inter-chiplet transfers share the link the same way.
//! * **Backpressure** — a completed batch may only move into the downstream
//!   queue while there is room; otherwise the stage holds it (compute
//!   resources already released) and stalls until the downstream stage
//!   dispatches. Admission at the entry queue follows the tenant's
//!   [`AdmissionPolicy`].
//! * **Online control** — every control epoch the engine compares each
//!   tenant's SLO goodput against its rolling baseline; a regression under
//!   queue pressure (the signature of arrival-rate drift or cross-tenant
//!   contention) triggers an [`AdaptiveController`] **warm re-tune** on the
//!   per-layer database rescaled by the observed per-EP slowdown EWMA. A
//!   changed configuration is applied by interrupting in-flight batches
//!   (their requests are re-queued at their completed-layer position, so no
//!   request is ever lost) and freezing dispatch for a short
//!   reconfiguration penalty. Re-binning on a new stage structure may
//!   transiently overshoot queue bounds; the bound is a steady-state
//!   admission bound.
//!
//! ## Hot-path design (§Perf)
//!
//! The event loop is the hottest code in the crate, so its steady state is
//! **allocation-free**:
//!
//! * Requests live in a per-tenant **slab arena** (`TenantRt::arena`) with
//!   a free-slot list; stage queues and in-flight batches carry `u32`
//!   arena indices, and the `Vec<u32>` batch buffers are recycled through
//!   a per-tenant pool. Partial downstream delivery advances a cursor
//!   instead of shifting the buffer.
//! * After each event the pipeline is **settled event-driven**: only the
//!   stages the event could have enabled (and, transitively, their
//!   neighbours) are visited, via a dirty-stage bitmask processed in
//!   descending stage order — the exact action order of the old
//!   whole-pipeline fixpoint rescan, without touching quiescent stages.
//!   [`ServeOptions::pump`] can select [`PumpMode::FullRescan`] to force
//!   the old scan; golden tests assert both modes produce byte-identical
//!   event streams and reports.
//! * Warm re-tunes reuse a preallocated scratch [`PerfDb`]
//!   ([`PerfDb::copy_scaled_from`]) instead of cloning the database every
//!   control epoch.
//!
//! ## Sharding
//!
//! A tenant with `TenantSpec::shards > 1` runs as `k` **replica
//! pipelines** on disjoint EP subsets, planned by
//! [`crate::serve::shard::plan_shards`] at serve start. Each replica
//! (`ShardRt`) owns the full per-pipeline runtime — bounded queues, slab
//! arena, batch buffers, scratch re-tune database, adaptive controller —
//! against a *sub-platform view* ([`Platform::subset`]) whose EP ids are
//! local; a per-replica `ep_map` translates to global ids for the shared
//! contention counters, so replicas of one tenant never contend on
//! compute (disjoint EPs) but do share the inter-chiplet link. Arrivals
//! route through the tenant's [`BalancerPolicy`] (round-robin,
//! join-shortest-queue, or throughput-weighted smooth round-robin — all
//! RNG-free), admission applies at the chosen replica's entry queue, and
//! warm re-tunes run per replica against its own sub-platform, so a
//! re-tuned replica can never migrate onto a sibling's EPs.
//!
//! ## Cluster control
//!
//! Two opt-in layers sit above per-tenant sharding
//! ([`crate::serve::cluster`]):
//!
//! * [`ServeOptions::coplan`] replaces the per-tenant placement with one
//!   **joint, disjoint** EP allocation across all tenants
//!   (water-filling on weighted predicted marginal throughput, never
//!   worse than greedy first-come allocation on the joint objective);
//! * [`ServeOptions::autoscale`] turns the replica set dynamic: at every
//!   control epoch a deterministic controller activates, drains or parks
//!   replicas within the planned budget. Draining replicas stop
//!   receiving arrivals but serve out their backlog before parking, so
//!   request conservation holds across every scale transition; parked
//!   replicas stop accruing [`EpochStats::active_eps`] (the EP-epoch
//!   meter). Scale transitions are hashed into the event log (tag 6) and
//!   recorded per replica in [`ShardReport::scale_events`].
//! * [`ServeOptions::elastic`] closes the demand loop on the plan itself:
//!   every control epoch the co-planner re-runs on the **observed**
//!   per-tenant demand (offered rate, shed flow, backlog) off a shared
//!   [`PlanCache`], and when the re-derived allocation beats the live one
//!   by the configured gain bar the deployment migrates onto it — queued
//!   requests move across replica slab arenas with zero loss, and
//!   scale-to-1 collapses a tenant onto one replica holding its full
//!   budget. Re-partitions are hashed into the event log (tag 8).
//!
//! `benches/serve_scale.rs` tracks simulated events/second per scenario in
//! `BENCH_serve.json` at the repository root.

use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, VecDeque};

use anyhow::{bail, Result};

use crate::coordinator::AdaptiveController;
use crate::explore::{CacheStats, PlanCache};
use crate::perfdb::{batch, CostModel, PerfDb};
use crate::pipeline::{simulator, PipelineConfig};
use crate::platform::{EpId, Platform};
use crate::rng::Xoshiro256;

use super::arrivals::ArrivalSampler;
use super::cluster::autoscale::{
    self, AutoscaleOptions, AutoscaleState, ElasticOptions, ElasticState, ReplicaState,
    ScaleDecision, ScaleEvent, TenantLoad,
};
use super::cluster::coplan::{self, TenantDemand};
use super::fault::{FaultKind, FaultScript};
use super::lifecycle::{self, RetryPolicy};
use super::obs::{
    self, EpochSample, Obs, ObsReport, Prof, ReplicaSample, Span, TenantSample,
};
use super::shard::{self, BalancerPolicy};
use super::slo::{jain_fairness, QuantileSketch};
use super::tenant::{AdmissionPolicy, TenantSpec};
use super::trace::{Capture, ControlKind, ControlRecord, Trace};

/// How the engine settles a tenant's pipeline after each event.
///
/// Both modes produce **identical** simulated outcomes (event stream,
/// `log_hash`, reports); `FullRescan` exists as the always-correct
/// reference the golden determinism tests pin [`EventDriven`] against.
///
/// [`EventDriven`]: PumpMode::EventDriven
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PumpMode {
    /// Visit only the stages an event could have enabled (plus the
    /// neighbours each action enables, transitively). The fast default.
    #[default]
    EventDriven,
    /// The PR-1 fixpoint loop, reproduced verbatim: scan **every** stage
    /// in descending order, and repeat whole-pipeline passes until one
    /// makes no progress — independent of the event-driven dirty-mask
    /// propagation, so the golden tests comparing the two modes catch a
    /// missed enablement channel instead of inheriting it.
    FullRescan,
}

/// Engine-level options (tenant-level knobs live on [`TenantSpec`]).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Simulated horizon, seconds: arrivals and completions beyond it are
    /// not processed (work still running at the horizon counts in-flight).
    pub duration_s: f64,
    /// Master seed; every tenant's arrival stream forks from it.
    pub seed: u64,
    /// Enable the online re-tuning control loop.
    pub control: bool,
    /// Control/metrics epoch length, seconds (≤ 0 disables epochs).
    pub control_epoch_s: f64,
    /// Re-tune when epoch goodput falls below this fraction of baseline.
    pub retune_threshold: f64,
    /// Minimum epochs between warm re-tunes of one tenant.
    pub retune_cooldown_epochs: u32,
    /// Dispatch freeze after applying a new configuration, seconds.
    pub reconfig_penalty_s: f64,
    /// Model EP/link contention (off = tenants run as if isolated).
    pub contention: bool,
    /// Keep a human-readable event log in the report (tests/debugging).
    pub record_log: bool,
    /// Safety valve: abort (with `truncated = true`) past this many events.
    pub max_events: u64,
    /// Settling strategy; see [`PumpMode`].
    pub pump: PumpMode,
    /// Cross-tenant co-planning: jointly allocate **disjoint** EP budgets
    /// across all tenants at serve start
    /// ([`crate::serve::cluster::coplan`]) instead of letting every
    /// tenant plan against the full platform. Tenants then never contend
    /// on compute (the inter-chiplet link stays shared), and the joint
    /// plan is never worse than greedy first-come allocation on total
    /// weighted predicted throughput.
    pub coplan: bool,
    /// Runtime shard autoscaler: at every control epoch, grow or shrink
    /// each tenant's live replica count within its planned budget
    /// ([`crate::serve::cluster::autoscale`]). Requires
    /// `control_epoch_s > 0`.
    pub autoscale: AutoscaleOptions,
    /// Deterministic fault plane: scripted EP fail-stop/stall/slowdown and
    /// inter-chiplet link degradation/cut, injected as heap events and
    /// hashed into the event log (tag 7). An empty script schedules
    /// nothing — fault-free runs keep their exact event stream. See
    /// [`FaultScript`] and the crate docs §Fault tolerance & graceful
    /// degradation.
    pub faults: FaultScript,
    /// Elastic control loop: re-run the cross-tenant co-planner every
    /// control epoch on the **observed** per-tenant demand (offered rate,
    /// shed, backlog) and, when the re-derived allocation clears the gain
    /// bar, live-migrate queued requests onto the new EP partition
    /// ([`crate::serve::cluster::autoscale::ElasticOptions`]). Requires
    /// `coplan` and `control_epoch_s > 0`. Re-partitions are hashed into
    /// the event log (tag 8) and recorded as
    /// [`ControlKind::Repartition`] control records.
    pub elastic: ElasticOptions,
}

impl ServeOptions {
    /// Validate the options against the platform they will serve on:
    /// positive horizon, a coherent autoscaler setup, and a fault script
    /// whose every event references in-range resources with well-formed,
    /// non-overlapping windows ([`FaultScript::validate`]). Called by
    /// [`serve`] before any state is built, so a bad script is rejected
    /// at construction time, not mid-run.
    pub fn validate(&self, plat: &Platform) -> Result<()> {
        if self.duration_s <= 0.0 {
            bail!("serve: duration must be positive");
        }
        if self.autoscale.enabled {
            self.autoscale.validate()?;
            if self.control_epoch_s <= 0.0 {
                bail!("serve: the autoscaler is epoch-driven — set control_epoch_s > 0");
            }
        }
        if self.elastic.enabled {
            self.elastic.validate()?;
            if !self.coplan {
                bail!("serve: the elastic loop re-partitions the co-plan — enable coplan");
            }
            if self.control_epoch_s <= 0.0 {
                bail!("serve: the elastic loop is epoch-driven — set control_epoch_s > 0");
            }
        }
        self.faults.validate(plat)?;
        Ok(())
    }
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            duration_s: 60.0,
            seed: 42,
            control: true,
            control_epoch_s: 5.0,
            retune_threshold: 0.7,
            retune_cooldown_epochs: 2,
            reconfig_penalty_s: 0.05,
            contention: true,
            record_log: false,
            max_events: 20_000_000,
            pump: PumpMode::EventDriven,
            coplan: false,
            autoscale: AutoscaleOptions::default(),
            faults: FaultScript::default(),
            elastic: ElasticOptions::default(),
        }
    }
}

/// One request travelling through a tenant's pipeline. Lives in the
/// tenant's slab arena; queues and batches refer to it by index. A hedged
/// logical request exists as **two** arena entries (possibly in different
/// replicas' arenas) sharing one `id`; the lifecycle flags below resolve
/// the race when both copies run.
#[derive(Debug, Clone)]
struct Request {
    id: u64,
    arrival_s: f64,
    /// Layers completed so far (used to re-bin across reconfigurations).
    layers_done: usize,
    /// Admission attempt this entry arrived under (1 = the original
    /// arrival; retries re-arrive with 2, 3, …) — read back when the
    /// request is rejected/dropped/expired to compute the next backoff.
    attempt: u32,
    /// This id has (or had) a hedge twin; completion must consult the
    /// tenant's hedge registry to decide winner vs late loser.
    hedged: bool,
    /// The other copy already won: discard at delivery, count `cancelled`,
    /// never record a latency.
    doomed: bool,
    /// This entry is the duplicated copy (distinguishes hedge *wins* from
    /// primaries finishing first; accounting only).
    twin: bool,
}

/// A batch being serviced (or completed and awaiting downstream room).
#[derive(Debug, Clone)]
struct InFlight {
    /// Arena indices of the batch; `reqs[..taken]` are already delivered
    /// downstream (partial delivery under backpressure).
    reqs: Vec<u32>,
    /// Delivery cursor into `reqs`.
    taken: usize,
    ep: usize,
    uses_link: bool,
    done_s: f64,
    /// Observed slowdown vs the contention-free service time.
    factor: f64,
    completed: bool,
    layers_after: usize,
}

impl InFlight {
    /// Requests not yet delivered downstream.
    fn pending(&self) -> usize {
        self.reqs.len() - self.taken
    }
}

#[derive(Debug, Default)]
struct StageRt {
    queue: VecDeque<u32>,
    busy: Option<InFlight>,
}

/// Per-epoch record of one tenant.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochStats {
    /// Epoch end time, seconds.
    pub end_s: f64,
    /// Arrivals offered during the epoch.
    pub offered: u64,
    /// Completions during the epoch.
    pub completed: u64,
    /// Completions within the SLO during the epoch.
    pub slo_ok: u64,
    /// Rejected arrivals during the epoch.
    pub rejected: u64,
    /// Dropped (DropOldest) requests during the epoch.
    pub dropped: u64,
    /// Deadline-expired requests reaped from queues during the epoch.
    pub expired: u64,
    /// Hedge losers cancelled during the epoch (queued reaps plus doomed
    /// in-service copies discarded at delivery).
    pub cancelled: u64,
    /// Re-arrivals (retry attempts ≥ 2) offered during the epoch — a
    /// subset of `offered`.
    pub retried: u64,
    /// Hedge twins placed during the epoch — a subset of `offered`.
    pub hedged: u64,
    /// SLO goodput, requests/second.
    pub goodput: f64,
    /// Raw completion throughput, requests/second.
    pub throughput: f64,
    /// Requests queued or in service at the epoch tick.
    pub backlog: u64,
    /// Whether a warm re-tune ran this epoch.
    pub retuned: bool,
    /// Evaluator trials the re-tune consumed.
    pub retune_trials: u64,
    /// EPs held (active or draining) during the epoch — the autoscaler's
    /// resource meter. For a replica this is its subset size or 0 when
    /// parked; tenant-level series sum across replicas. `Σ active_eps`
    /// over a run's epochs is its EP-epoch cost
    /// ([`TenantReport::ep_epochs`]).
    pub active_eps: u64,
}

/// Final report for one pipeline replica of a tenant (tenants without
/// sharding have exactly one). Configurations are reported in **global**
/// EP ids (translated from the replica's sub-platform).
#[derive(Debug, Clone)]
pub struct ShardReport {
    /// Global EP ids this replica runs on (disjoint from its siblings).
    pub eps: Vec<EpId>,
    /// Replica configuration at serve start (global EP ids).
    pub initial_config: PipelineConfig,
    /// Replica configuration at the horizon (global EP ids).
    pub final_config: PipelineConfig,
    /// Analytic throughput the placement search predicted, img/s (the
    /// weight under [`BalancerPolicy::WeightedThroughput`]).
    pub predicted_throughput: f64,
    /// Arrivals the balancer routed to this replica.
    pub offered: u64,
    /// Routed arrivals rejected at this replica's entry queue.
    pub rejected: u64,
    /// Admitted requests dropped later (DropOldest).
    pub dropped: u64,
    /// Deadline-expired requests reaped from this replica's queues.
    pub expired: u64,
    /// Hedge-loser copies cancelled on this replica.
    pub cancelled: u64,
    /// Re-arrivals (retry attempts) routed to this replica — a subset of
    /// `offered`.
    pub retried: u64,
    /// Hedge twins placed onto this replica — a subset of `offered`.
    pub hedged: u64,
    /// Hedged races won by a *twin* completing on this replica.
    pub hedge_wins: u64,
    /// Requests completed by this replica.
    pub completed: u64,
    /// Completions within the SLO.
    pub slo_ok: u64,
    /// Requests still queued or in service at the horizon.
    pub in_flight: u64,
    /// Largest per-stage queue length observed.
    pub max_queue_len: usize,
    /// Replica slab high-water mark.
    pub arena_peak: usize,
    /// Warm re-tunes of this replica.
    pub retunes: u32,
    /// Evaluator trials across this replica's re-tunes.
    pub retune_trials: u64,
    /// Latency sketch over this replica's completions.
    pub latency: QuantileSketch,
    /// Per-epoch time series of this replica.
    pub epochs: Vec<EpochStats>,
    /// Scale transitions the autoscaler put this replica through (empty
    /// without autoscaling); each records the epoch-tick time and the
    /// state entered.
    pub scale_events: Vec<ScaleEvent>,
    /// Replica state at the horizon.
    pub final_state: ReplicaState,
}

/// Final per-tenant report. All counters aggregate over the tenant's
/// replicas ([`TenantReport::shards`]); `initial_config`/`final_config`
/// are replica 0's (in global EP ids), `max_queue_len` is the max across
/// replicas, and `arena_peak` sums replica slabs.
#[derive(Debug, Clone)]
pub struct TenantReport {
    /// Tenant name.
    pub name: String,
    /// Configuration the run started with.
    pub initial_config: PipelineConfig,
    /// Configuration in service at the horizon.
    pub final_config: PipelineConfig,
    /// Total arrivals offered.
    pub offered: u64,
    /// Arrivals rejected at admission.
    pub rejected: u64,
    /// Admitted requests dropped later (DropOldest).
    pub dropped: u64,
    /// Deadline-expired requests reaped from queues (0 without a finite
    /// [`TenantSpec::deadline_s`]).
    pub expired: u64,
    /// Hedge-loser copies cancelled after the sibling copy won the race
    /// (0 without a hedge policy).
    pub cancelled: u64,
    /// Re-arrivals offered by the retry policy — a subset of `offered`
    /// (0 without a retry policy).
    pub retried: u64,
    /// Hedge twins placed onto sibling replicas — a subset of `offered`
    /// (0 without a hedge policy).
    pub hedged: u64,
    /// Hedged races won by the duplicated *twin* rather than the primary.
    pub hedge_wins: u64,
    /// Requests fully completed.
    pub completed: u64,
    /// Completions within the SLO.
    pub slo_ok: u64,
    /// Requests still queued or in service at the horizon.
    pub in_flight: u64,
    /// Largest per-stage queue length observed (steady-state admissions).
    pub max_queue_len: usize,
    /// Request-slab high-water mark: the most requests simultaneously
    /// alive (queued or in service). Slot recycling keeps this bounded by
    /// queue depth × stages, not by `offered`.
    pub arena_peak: usize,
    /// Latency sketch over completed requests.
    pub latency: QuantileSketch,
    /// Per-epoch time series.
    pub epochs: Vec<EpochStats>,
    /// Warm re-tunes triggered.
    pub retunes: u32,
    /// Total evaluator trials across re-tunes.
    pub retune_trials: u64,
    /// Elastic EP-budget re-partitions applied to this tenant (0 without
    /// `--elastic`).
    pub repartitions: u32,
    /// Per-replica reports (length 1 for unsharded tenants).
    pub shards: Vec<ShardReport>,
}

impl TenantReport {
    /// Requests admitted past the entry queue.
    pub fn admitted(&self) -> u64 {
        self.offered - self.rejected
    }

    /// SLO goodput over the whole run, requests/second.
    pub fn goodput(&self, duration_s: f64) -> f64 {
        if duration_s > 0.0 {
            self.slo_ok as f64 / duration_s
        } else {
            0.0
        }
    }

    /// Fraction of offered requests rejected or dropped.
    pub fn drop_rate(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            (self.rejected + self.dropped) as f64 / self.offered as f64
        }
    }

    /// Request conservation: every offered request is accounted for.
    pub fn conserved(&self) -> bool {
        self.offered
            == self.rejected
                + self.dropped
                + self.expired
                + self.cancelled
                + self.completed
                + self.in_flight
    }

    /// Per-epoch request conservation: for every epoch of the aggregated
    /// series, `offered + backlog_prev == completed + rejected + dropped
    /// + expired + cancelled + backlog` (the first epoch starts from an
    /// empty system). This is
    /// the flow identity the epoch shed meter is derived from — a request
    /// admitted and later dropped in the same epoch counts once, as a
    /// drop, never as both an admission and a shed. Trivially true for an
    /// empty series; runs truncated by the `max_events` valve may close
    /// their last epoch early and are the caller's business to exclude.
    pub fn epoch_conserved(&self) -> bool {
        let mut backlog_prev = 0u64;
        for e in &self.epochs {
            if e.offered + backlog_prev
                != e.completed + e.rejected + e.dropped + e.expired + e.cancelled + e.backlog
            {
                return false;
            }
            backlog_prev = e.backlog;
        }
        true
    }

    /// EP-epochs consumed: Σ over the epoch series of the EPs held active
    /// (or draining) that epoch. A statically sharded tenant pays
    /// `n_epochs × Σ replica EPs`; the autoscaler's win is the same
    /// goodput at a smaller meter.
    pub fn ep_epochs(&self) -> u64 {
        self.epochs.iter().map(|e| e.active_eps).sum()
    }

    /// What the EP-epoch meter would read had every replica stayed
    /// active all run: `n_epochs × Σ replica EPs` — the static-deployment
    /// baseline [`TenantReport::ep_epochs`] is compared against.
    pub fn always_on_ep_epochs(&self) -> u64 {
        self.epochs.len() as u64 * self.shards.iter().map(|s| s.eps.len() as u64).sum::<u64>()
    }

    /// Row for [`crate::metrics::table::latency_table`] — the one mapping
    /// from a tenant report to the shared percentile renderer.
    pub fn latency_row(&self, duration_s: f64) -> crate::metrics::table::LatencyRow {
        crate::metrics::table::LatencyRow {
            label: self.name.clone(),
            p50_s: self.latency.p50(),
            p95_s: self.latency.p95(),
            p99_s: self.latency.p99(),
            max_s: self.latency.max_s(),
            goodput_rps: self.goodput(duration_s),
            drop_rate: self.drop_rate(),
        }
    }
}

/// Result of a serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Simulated horizon, seconds.
    pub duration_s: f64,
    /// Per-tenant reports, in input order.
    pub tenants: Vec<TenantReport>,
    /// Events processed.
    pub n_events: u64,
    /// FNV-1a hash of the full event stream (determinism witness).
    pub log_hash: u64,
    /// Human-readable event log (only when `record_log`).
    pub event_log: Vec<String>,
    /// True when the `max_events` safety valve fired.
    pub truncated: bool,
    /// Planner-memo counters of the run's shared [`PlanCache`] (failover
    /// and elastic re-plans probe it; all-zero when neither ran).
    pub plan_cache: CacheStats,
}

impl ServeReport {
    /// Per-tenant SLO goodputs, requests/second.
    pub fn goodputs(&self) -> Vec<f64> {
        self.tenants.iter().map(|t| t.goodput(self.duration_s)).collect()
    }

    /// Total EP-epochs across tenants (see [`TenantReport::ep_epochs`]).
    pub fn ep_epochs(&self) -> u64 {
        self.tenants.iter().map(|t| t.ep_epochs()).sum()
    }

    /// Jain fairness index over per-tenant goodputs.
    pub fn fairness(&self) -> f64 {
        jain_fairness(&self.goodputs())
    }
}

// ---------------------------------------------------------------------------
// event plumbing

#[derive(Debug, Clone, Copy)]
enum EvKind {
    Arrival { tenant: usize },
    StageDone { tenant: usize, shard: usize, stage: usize, gen: u64 },
    Epoch,
    Resume { tenant: usize, shard: usize },
    /// Deadline check for request `id`: any copy still **queued** at fire
    /// time is reaped (`expired`); in-service copies are left to finish.
    /// Scheduled only for tenants with a finite [`TenantSpec::deadline_s`].
    Expire { tenant: usize, id: u64 },
    /// A backed-off re-arrival (attempt ≥ 2); admitted through the normal
    /// front door under a fresh request id.
    Retry { tenant: usize, attempt: u32 },
    /// Hedge check for request `id`: if it is still waiting in an entry
    /// queue, duplicate it onto the least-loaded sibling replica.
    Hedge { tenant: usize, id: u64 },
    /// A scripted fault boundary: `ix` indexes [`ServeOptions::faults`],
    /// `begin` distinguishes the window start from its end (fail-stops
    /// have no end event).
    Fault { ix: usize, begin: bool },
}

/// Pack a (tenant, shard) pair into one hash/log word. Shard counts are
/// bounded by the EP count (≤ 64 in any supported platform), so 8 bits
/// for the shard index are plenty.
#[inline]
fn pack_ts(tenant: usize, shard: usize) -> u64 {
    ((tenant as u64) << 8) | shard as u64
}

#[derive(Debug, Clone, Copy)]
struct Ev {
    t: f64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

/// State shared across tenants: the event heap and contention counters.
struct Shared {
    heap: BinaryHeap<Reverse<Ev>>,
    seq: u64,
    /// Services currently computing on each EP (all tenants).
    ep_busy: Vec<u32>,
    /// Inter-chiplet transfers currently in flight (all tenants).
    link_busy: u32,
    contention: bool,
    n_events: u64,
    log_hash: u64,
    log: Vec<String>,
    record_log: bool,
    /// Flight-recorder sink ([`super::trace`]); `None` outside recorded
    /// runs, so the unrecorded hot path pays one branch per event.
    capture: Option<Capture>,
    /// Telemetry sink ([`super::obs`]); `None` outside observed runs, so
    /// the unobserved hot path pays one branch per touch. Boxed: the
    /// registry is fat and the engine only chases the pointer when
    /// telemetry is on.
    obs: Option<Box<Obs>>,
    /// Simulated time of the event being pumped (0.0 before the first).
    /// Telemetry-only convenience so deep callees (e.g. replica
    /// detachment) can timestamp utilization transitions without
    /// threading `now` through every signature; **never** read by
    /// simulation logic.
    now: f64,
    // Fault-plane state. Transient windows are stored as "until"
    // timestamps, so resource health is a pure function of `now` — window
    // ends never have to *clear* anything, they only trigger recovery.
    /// Permanently fail-stopped EPs (global ids).
    ep_failed: Vec<bool>,
    /// Per-EP transient-stall window end (0.0 = none active).
    ep_stall_until: Vec<f64>,
    /// Per-EP thermal-throttle factor, in force while `now` is before the
    /// matching `ep_throttle_until` entry (1.0 otherwise).
    ep_throttle: Vec<f64>,
    ep_throttle_until: Vec<f64>,
    /// Inter-chiplet link cut window end.
    link_cut_until: f64,
    /// Link degradation factor + window end, same shape as EP throttle.
    link_throttle: f64,
    link_throttle_until: f64,
}

impl Shared {
    fn schedule(&mut self, t: f64, kind: EvKind) {
        self.seq += 1;
        self.heap.push(Reverse(Ev { t, seq: self.seq, kind }));
    }

    fn note(&mut self, t: f64, tag: u64, a: u64, b: u64, text: impl FnOnce() -> String) {
        for x in [tag, a, b, t.to_bits()] {
            for byte in x.to_le_bytes() {
                self.log_hash ^= byte as u64;
                self.log_hash = self.log_hash.wrapping_mul(0x0000_0100_0000_01B3);
            }
        }
        if let Some(cap) = &mut self.capture {
            cap.event(t, tag, a, b);
        }
        if let Some(o) = self.obs.as_deref_mut() {
            o.on_event(tag);
        }
        if self.record_log {
            let line = text();
            self.log.push(line);
        }
    }

    /// Record a control-plane decision beside (not inside) the hashed
    /// event stream: recorded runs keep the exact `log_hash` of
    /// unrecorded ones. `signals` are the observations the decision was
    /// made on; they go to the causality journal only (never hashed, never
    /// captured), so every call site documents *why* the mechanism fired.
    fn control(&mut self, rec: ControlRecord, signals: &[(&'static str, f64)]) {
        if let Some(o) = self.obs.as_deref_mut() {
            o.journal.push(&rec, signals);
        }
        if let Some(cap) = &mut self.capture {
            cap.control(rec);
        }
    }

    /// Acquire one in-flight unit on global EP `gep` (and the link when
    /// `uses_link`), integrating the utilization meters up to `self.now`
    /// at the pre-transition counts first.
    #[inline]
    fn ep_acquire(&mut self, gep: usize, uses_link: bool) {
        if let Some(o) = self.obs.as_deref_mut() {
            o.util.ep_touch(gep, self.ep_busy[gep], self.now);
            if uses_link {
                o.util.link_touch(self.link_busy, self.now);
            }
        }
        self.ep_busy[gep] += 1;
        if uses_link {
            self.link_busy += 1;
        }
    }

    /// Release one in-flight unit on global EP `gep` (and the link when
    /// `uses_link`); the saturating arithmetic mirrors the original
    /// release sites (detach may race a completion during reconfig).
    #[inline]
    fn ep_release(&mut self, gep: usize, uses_link: bool) {
        if let Some(o) = self.obs.as_deref_mut() {
            o.util.ep_touch(gep, self.ep_busy[gep], self.now);
            if uses_link {
                o.util.link_touch(self.link_busy, self.now);
            }
        }
        self.ep_busy[gep] = self.ep_busy[gep].saturating_sub(1);
        if uses_link {
            self.link_busy = self.link_busy.saturating_sub(1);
        }
    }

    /// Telemetry tap: a batch of `b` requests entered service.
    #[inline]
    fn obs_batch(&mut self, b: u64) {
        if let Some(o) = self.obs.as_deref_mut() {
            o.on_batch(b);
        }
    }

    /// Telemetry tap: one admission decision (`obs::ADM_*` outcome).
    #[inline]
    fn obs_admit(&mut self, ti: usize, outcome: usize) {
        if let Some(o) = self.obs.as_deref_mut() {
            o.on_admission(ti, outcome);
        }
    }

    /// Open a self-profiling span (None when telemetry is off — the
    /// unobserved run never reads the clock).
    #[inline]
    fn prof_start(&self) -> Option<std::time::Instant> {
        if self.obs.is_some() {
            Some(Prof::start())
        } else {
            None
        }
    }

    /// Close a span opened with [`Shared::prof_start`].
    #[inline]
    fn prof_end(&mut self, span: Span, t0: Option<std::time::Instant>) {
        if let (Some(o), Some(t0)) = (self.obs.as_deref_mut(), t0) {
            o.prof.add(span, t0);
        }
    }

    /// Is global EP `gep` unable to serve at `now` (failed or stalled)?
    fn ep_down(&self, gep: usize, now: f64) -> bool {
        self.ep_failed[gep] || now < self.ep_stall_until[gep]
    }

    /// Thermal-throttle slowdown of global EP `gep` at `now` (1.0 when
    /// healthy; multiplying by it is bit-exact identity for fault-free
    /// runs).
    fn ep_fault_factor(&self, gep: usize, now: f64) -> f64 {
        if now < self.ep_throttle_until[gep] {
            self.ep_throttle[gep]
        } else {
            1.0
        }
    }

    /// Is the inter-chiplet link cut at `now`?
    fn link_cut(&self, now: f64) -> bool {
        now < self.link_cut_until
    }

    /// Link degradation factor at `now` (1.0 when healthy).
    fn link_fault_factor(&self, now: f64) -> f64 {
        if now < self.link_throttle_until {
            self.link_throttle
        } else {
            1.0
        }
    }

    /// Any fault in force at `now`? Gates graceful degradation: with no
    /// active fault every shed tenant is re-admitted.
    fn any_fault_active(&self, now: f64) -> bool {
        self.link_cut(now)
            || now < self.link_throttle_until
            || self.ep_failed.iter().any(|&f| f)
            || self.ep_stall_until.iter().any(|&u| now < u)
            || self.ep_throttle_until.iter().any(|&u| now < u)
    }
}

/// EWMA weight for the per-EP observed-slowdown estimate.
const EWMA_GAIN: f64 = 0.2;
/// Per-epoch relaxation of the slowdown estimate towards 1.0, so an EP the
/// tenant no longer touches (after migrating away) does not keep a stale
/// contention penalty forever and can be re-adopted by a later re-tune.
const EWMA_EPOCH_RELAX: f64 = 0.5;
/// Per-epoch decay of the goodput baseline: a *rolling* max that follows
/// genuine load declines (diurnal lulls) within ~20 epochs instead of
/// ratcheting to the all-time peak and firing re-tunes all night.
const BASELINE_DECAY: f64 = 0.95;

/// One pipeline replica of a tenant: the full per-pipeline runtime
/// (queues, slab arena, scratch re-tune database, adaptive controller)
/// against the replica's sub-platform view. Unsharded tenants run exactly
/// one with an identity `ep_map`.
struct ShardRt {
    /// Restriction of the serving platform to this replica's EPs
    /// ([`Platform::subset`]); all configs/databases use its local ids.
    subplat: Platform,
    /// Local EP id → global EP id (shared contention counters are global).
    ep_map: Vec<EpId>,
    config: PipelineConfig,
    initial_config: PipelineConfig,
    bounds: Vec<(usize, usize)>,
    /// Batch-aware databases: `dbs[b-1]` holds per-stage times at batch `b`.
    dbs: Vec<PerfDb>,
    stages: Vec<StageRt>,
    controller: AdaptiveController,
    /// Reconfiguration generation; stale StageDone events are ignored.
    gen: u64,
    frozen_until: f64,
    /// A reconfiguration froze dispatch; the first settle at or past
    /// `frozen_until` must reconsider every stage (dispatch was globally
    /// blocked, so any stage may have become runnable).
    thaw_pending: bool,
    /// Observed per-EP slowdown EWMA (1.0 = uncontended), local ids.
    ep_slow: Vec<f64>,
    /// Request slab; queues and batches hold indices into it.
    arena: Vec<Request>,
    /// Recycled arena slots of completed/dropped requests.
    free_slots: Vec<u32>,
    /// Recycled batch buffers (at most one per stage alive at a time).
    buf_pool: Vec<Vec<u32>>,
    /// Preallocated observed database for warm re-tunes; overwritten in
    /// place each control epoch (no per-epoch clone).
    scratch_db: PerfDb,
    /// Preallocated per-EP factor buffer feeding `scratch_db`.
    scale_buf: Vec<f64>,
    /// Predicted analytic throughput (smooth-WRR balancer weight).
    weight: f64,
    /// Smooth-WRR credit accumulator (deterministic, RNG-free).
    credit: f64,
    /// Autoscaler state: Active replicas receive traffic, Draining ones
    /// serve out their backlog, Parked ones idle (EPs free). Always
    /// Active when autoscaling is disabled.
    state: ReplicaState,
    /// Scale transitions (time + state entered), for the report.
    scale_log: Vec<ScaleEvent>,
    /// The EP subset this replica was planned onto at serve start (global
    /// ids), frozen for the report: `initial_config` translates through
    /// it. Elastic re-partitions move `home_eps`, never this.
    natal_eps: Vec<EpId>,
    /// The replica's current *planned* EP subset (global ids). Failover
    /// re-plans onto `home_eps` minus currently-faulted EPs; recovery
    /// re-adopts back toward the full home set. An elastic re-partition
    /// re-homes the replica onto its slice of the new budget.
    home_eps: Vec<EpId>,
    /// Health flag: true while the replica's entire home set is faulted
    /// (no surviving subset to re-plan onto). A dead replica serves
    /// nothing and is invisible to the autoscaler until recovery.
    dead: bool,
    // cumulative counters (per replica)
    offered: u64,
    rejected: u64,
    dropped: u64,
    expired: u64,
    cancelled: u64,
    retried: u64,
    hedged: u64,
    hedge_wins: u64,
    completed: u64,
    slo_ok: u64,
    max_queue_len: usize,
    latency: QuantileSketch,
    // epoch accumulators
    ep_offered: u64,
    ep_completed: u64,
    ep_slo_ok: u64,
    ep_rejected: u64,
    ep_dropped: u64,
    ep_expired: u64,
    ep_cancelled: u64,
    ep_retried: u64,
    ep_hedged: u64,
    baseline_goodput: f64,
    epochs_since_retune: u32,
    retunes: u32,
    retune_trials: u64,
    epochs: Vec<EpochStats>,
}

impl ShardRt {
    fn backlog(&self) -> u64 {
        self.stages
            .iter()
            .map(|s| {
                s.queue.len() as u64 + s.busy.as_ref().map_or(0, |inf| inf.pending() as u64)
            })
            .sum()
    }

    /// Requests *waiting* in queues (excludes batches in service): the
    /// pressure signal — a lone in-flight request is normal operation,
    /// a non-empty queue means demand outruns service.
    fn queued(&self) -> u64 {
        self.stages.iter().map(|s| s.queue.len() as u64).sum()
    }

    /// Place a new request in the arena, reusing a freed slot when one
    /// exists (steady state: no allocation).
    fn alloc(&mut self, id: u64, arrival_s: f64) -> u32 {
        let req = Request {
            id,
            arrival_s,
            layers_done: 0,
            attempt: 1,
            hedged: false,
            doomed: false,
            twin: false,
        };
        if let Some(ix) = self.free_slots.pop() {
            self.arena[ix as usize] = req;
            ix
        } else {
            let ix = self.arena.len() as u32;
            self.arena.push(req);
            ix
        }
    }

    /// Return a drained batch buffer to the pool.
    fn recycle(&mut self, mut buf: Vec<u32>) {
        buf.clear();
        self.buf_pool.push(buf);
    }

    /// Bring the replica back into service. A parked (or draining)
    /// replica's slowdown EWMA is stale history: it produced no
    /// completions while out of rotation (the EWMA only updates on
    /// completions), so without this reset a Draining → Parked → Active
    /// cycle would warm-re-tune against ghost contention from before the
    /// park. Every activation path funnels through here so none can skip
    /// the relax (pinned by `reactivation_relaxes_the_slowdown_ewma`).
    fn reactivate(&mut self) {
        self.state = ReplicaState::Active;
        for f in &mut self.ep_slow {
            *f = 1.0;
        }
    }
}

/// One logical tenant: the arrival stream, the front-end balancer state,
/// and the replica runtimes it routes into.
struct TenantRt {
    spec: TenantSpec,
    sampler: ArrivalSampler,
    next_id: u64,
    /// Arrivals offered to the tenant (= Σ replica `offered`).
    offered: u64,
    /// Round-robin cursor.
    rr: u64,
    /// Autoscaler hysteresis state.
    auto: AutoscaleState,
    /// Cached count of Active replicas, maintained by the autoscaler on
    /// every transition — keeps the per-arrival balancer free of state
    /// scans (round-robin stays O(1) while all replicas are active, the
    /// static-sharding hot path PR 2 optimised).
    n_active: usize,
    /// Graceful degradation: while set, every arrival to this tenant is
    /// counted and rejected at admission (capacity under faults no longer
    /// covers demand and this tenant lost the weighted-priority cover).
    /// Toggled by `degrade_tick`; conservation is untouched — shed
    /// arrivals are ordinary rejections.
    load_shed: bool,
    /// Elastic EP-budget re-partitions applied to this tenant.
    repartitions: u32,
    /// Request-lifecycle state (hedge registry, pending winner list, the
    /// derived hedge delay). Inert unless the spec enables a policy.
    lc: TenantLc,
    shards: Vec<ShardRt>,
}

/// Per-tenant request-lifecycle runtime state.
#[derive(Debug, Default)]
struct TenantLc {
    /// Ids with a live hedged pair (both copies still racing). BTreeSet
    /// for deterministic iteration; removed at the first completion,
    /// expiry, or eviction of either copy.
    hedges: std::collections::BTreeSet<u64>,
    /// Ids whose winning copy just completed; the surviving loser copy is
    /// reaped (queued) or doomed (in service) by [`reap_hedge_losers`]
    /// right after the settle pass that delivered the winner.
    won: Vec<u64>,
    /// Current hedge-fire delay, seconds: the tenant's observed p9x
    /// latency (merged across replicas) floored by the policy's
    /// `min_delay_s`; falls back to the SLO budget while the latency
    /// sketch is cold. Re-derived every control epoch.
    hedge_delay_s: f64,
}

impl TenantRt {
    /// Route one arrival at simulated time `now`: pick the replica per
    /// the tenant's balancer, considering only **Active** replicas
    /// (draining and parked ones receive no new arrivals; without
    /// autoscaling every replica is Active and this reduces exactly to
    /// the original policies). Deterministic — every policy is a pure
    /// function of engine state.
    fn pick_shard(&mut self, now: f64) -> usize {
        if self.shards.len() == 1 {
            return 0;
        }
        let n_active = self.n_active;
        debug_assert!(n_active >= 1, "the autoscaler never drains the last active replica");
        debug_assert_eq!(
            n_active,
            self.shards.iter().filter(|s| s.state == ReplicaState::Active).count(),
            "cached active-replica count out of sync"
        );
        match self.spec.balancer {
            BalancerPolicy::RoundRobin => {
                let mut pos = (self.rr % n_active.max(1) as u64) as usize;
                self.rr += 1;
                if n_active == self.shards.len() {
                    // all replicas active (always true without
                    // autoscaling): the pos-th active replica IS index
                    // pos — the original O(1) path
                    return pos;
                }
                // cycle through the active replicas in index order
                let mut fallback = 0;
                for (i, srt) in self.shards.iter().enumerate() {
                    if srt.state != ReplicaState::Active {
                        continue;
                    }
                    fallback = i;
                    if pos == 0 {
                        return i;
                    }
                    pos -= 1;
                }
                fallback
            }
            BalancerPolicy::JoinShortestQueue => {
                // least-loaded by *total* backlog, not just the entry
                // queue: after a reconfiguration the orphaned requests sit
                // at downstream stages and dispatch is frozen, so an
                // entry-queue-only rule would flood exactly the replica
                // that cannot serve. Frozen replicas are deprioritized
                // outright; ties break on the lowest index.
                let mut best: Option<usize> = None;
                let mut best_key = (true, u64::MAX);
                for (i, srt) in self.shards.iter().enumerate() {
                    if srt.state != ReplicaState::Active {
                        continue;
                    }
                    let key = (now < srt.frozen_until, srt.backlog());
                    if best.is_none() || key < best_key {
                        best_key = key;
                        best = Some(i);
                    }
                }
                best.unwrap_or(0)
            }
            BalancerPolicy::WeightedThroughput => {
                // smooth weighted round-robin: every active replica
                // accrues its weight, the highest credit serves and pays
                // the total — over time replica `i` receives
                // weight_i/Σweights of the arrivals with no bursts
                // towards any single replica. Credits reset on scale
                // events so a re-activated replica starts neutral.
                let mut total = 0.0;
                let mut best: Option<usize> = None;
                let mut best_credit = f64::NEG_INFINITY;
                for (i, srt) in self.shards.iter_mut().enumerate() {
                    if srt.state != ReplicaState::Active {
                        continue;
                    }
                    total += srt.weight;
                    srt.credit += srt.weight;
                    if best.is_none() || srt.credit > best_credit {
                        best_credit = srt.credit;
                        best = Some(i);
                    }
                }
                let best = best.unwrap_or(0);
                self.shards[best].credit -= total;
                best
            }
        }
    }
}

// ---------------------------------------------------------------------------
// per-stage mechanics (free functions keep the borrows simple)

/// Move a completed batch forward: finish requests on the last stage, or
/// shift them into the downstream queue while it has room. Returns true on
/// any progress.
#[allow(clippy::too_many_arguments)]
fn deliver_stage(
    spec: &TenantSpec,
    t: &mut ShardRt,
    lc: &mut TenantLc,
    sh: &mut Shared,
    ti: usize,
    shard_ix: usize,
    si: usize,
    now: f64,
) -> bool {
    let is_completed = matches!(&t.stages[si].busy, Some(inf) if inf.completed);
    if !is_completed {
        return false;
    }
    let n_layers = spec.net.len();
    let finishes = t.stages[si].busy.as_ref().map_or(false, |inf| inf.layers_after >= n_layers);
    if finishes {
        let inf = t.stages[si].busy.take().expect("checked above");
        let slo = spec.slo_latency_s;
        for &ix in &inf.reqs[inf.taken..] {
            let req = t.arena[ix as usize].clone();
            if req.doomed || (req.hedged && !lc.hedges.contains(&req.id)) {
                // Hedge loser: the sibling copy already won this race.
                // Discard the result — counted `cancelled`, never a
                // completion, never a latency sample (quantiles stay over
                // logical requests, not copies).
                t.cancelled += 1;
                t.ep_cancelled += 1;
                sh.note(now, 12, pack_ts(ti, shard_ix), req.id, || {
                    format!("{now:.6} cancel {}#{} r{shard_ix} lost-race", spec.name, req.id)
                });
                t.free_slots.push(ix);
                continue;
            }
            if req.hedged {
                // First completion of a live hedged pair: this copy wins;
                // the surviving loser is reaped/doomed right after this
                // settle pass (see `reap_hedge_losers`).
                lc.hedges.remove(&req.id);
                lc.won.push(req.id);
                if req.twin {
                    t.hedge_wins += 1;
                }
            }
            let lat = inf.done_s - req.arrival_s;
            t.completed += 1;
            t.ep_completed += 1;
            if lat <= slo {
                t.slo_ok += 1;
                t.ep_slo_ok += 1;
            }
            t.latency.record(lat);
            t.free_slots.push(ix);
        }
        t.recycle(inf.reqs);
        return true;
    }
    if si + 1 >= t.stages.len() {
        // layers_after < n_layers can only happen mid-reconfig; re-binning
        // handles it, never ordinary delivery
        return false;
    }
    let cap = spec.queue_capacity;
    let mut moved = false;
    let drained = {
        let (left, right) = t.stages.split_at_mut(si + 1);
        let cur = &mut left[si];
        let next = &mut right[0];
        let inf = cur.busy.as_mut().expect("checked above");
        while inf.taken < inf.reqs.len() && next.queue.len() < cap {
            next.queue.push_back(inf.reqs[inf.taken]);
            inf.taken += 1;
            moved = true;
        }
        inf.taken == inf.reqs.len()
    };
    if drained {
        let inf = t.stages[si].busy.take().expect("checked above");
        t.recycle(inf.reqs);
    }
    if moved {
        let l = t.stages[si + 1].queue.len();
        if l > t.max_queue_len {
            t.max_queue_len = l;
        }
    }
    moved
}

/// Start servicing a batch on stage `si` if it is idle and has queued work.
/// Returns true when a service was started.
///
/// EP ids in the replica's configuration are **local** to its
/// sub-platform; the shared contention counters are indexed through
/// `t.ep_map`, so co-located stages of *different* tenants (or the shared
/// inter-chiplet link across sibling replicas) still contend globally.
#[allow(clippy::too_many_arguments)]
fn dispatch_stage(
    spec: &TenantSpec,
    t: &mut ShardRt,
    sh: &mut Shared,
    ti: usize,
    shard_ix: usize,
    si: usize,
    now: f64,
    duration_s: f64,
) -> bool {
    if now < t.frozen_until {
        return false;
    }
    if t.stages[si].busy.is_some() || t.stages[si].queue.is_empty() {
        return false;
    }
    let b = spec.batch.min(t.stages[si].queue.len());
    let (lo, hi) = t.bounds[si];
    let ep = t.config.assignment[si];
    let from_ep = if si == 0 { None } else { Some(t.config.assignment[si - 1]) };
    let (compute, transfer) = simulator::stage_service_time(
        &spec.net,
        &t.subplat,
        &t.dbs[b - 1],
        lo,
        hi,
        ep,
        from_ep,
        b as u64,
    );
    let gep = t.ep_map[ep];
    let uses_link = transfer > 0.0;
    if sh.ep_down(gep, now) || (uses_link && sh.link_cut(now)) {
        // the EP (or the link this batch needs) is faulted: hold the
        // queue — failover re-plans replicas off failed EPs, and
        // transient windows end with a settle that re-dispatches
        return false;
    }
    let contended_ep = if sh.contention { (sh.ep_busy[gep] + 1) as f64 } else { 1.0 };
    let contended_link =
        if sh.contention && uses_link { (sh.link_busy + 1) as f64 } else { 1.0 };
    // fault throttles stack on contention; both are exactly 1.0 on a
    // healthy platform, keeping fault-free service times bit-identical
    let ep_factor = contended_ep * sh.ep_fault_factor(gep, now);
    let link_factor = contended_link * sh.link_fault_factor(now);
    let base = compute + transfer;
    let actual = compute * ep_factor + transfer * link_factor;
    let mut reqs = t.buf_pool.pop().unwrap_or_default();
    debug_assert!(reqs.is_empty(), "pooled buffers are returned drained");
    for _ in 0..b {
        reqs.push(t.stages[si].queue.pop_front().expect("len checked"));
    }
    sh.ep_acquire(gep, uses_link);
    sh.obs_batch(b as u64);
    let done = now + actual;
    let factor = if base > 0.0 { actual / base } else { 1.0 };
    t.stages[si].busy = Some(InFlight {
        reqs,
        taken: 0,
        ep,
        uses_link,
        done_s: done,
        factor,
        completed: false,
        layers_after: hi,
    });
    if done <= duration_s {
        sh.schedule(
            done,
            EvKind::StageDone { tenant: ti, shard: shard_ix, stage: si, gen: t.gen },
        );
    }
    true
}

/// Bitmask with one bit per stage (the engine caps pipelines at 64 stages).
fn all_mask(n_stages: usize) -> u64 {
    if n_stages >= 64 {
        u64::MAX
    } else {
        (1u64 << n_stages) - 1
    }
}

/// Debug-only oracle: could stage `si` make any progress right now?
/// Mirrors the `deliver_stage` / `dispatch_stage` preconditions; `settle`
/// asserts it is false everywhere on exit, so a missed enablement channel
/// fails loudly under `cargo test` instead of silently stalling a queue.
#[cfg(debug_assertions)]
fn can_progress(spec: &TenantSpec, t: &ShardRt, sh: &Shared, si: usize, now: f64) -> bool {
    let n_layers = spec.net.len();
    if let Some(inf) = &t.stages[si].busy {
        if inf.completed {
            if inf.layers_after >= n_layers {
                return true;
            }
            if si + 1 < t.stages.len()
                && inf.pending() > 0
                && t.stages[si + 1].queue.len() < spec.queue_capacity
            {
                return true;
            }
        }
        false
    } else {
        if now < t.frozen_until || t.stages[si].queue.is_empty() {
            return false;
        }
        // mirror dispatch_stage's fault blockers: a queued batch whose EP
        // is down (or whose transfer needs a cut link) is legitimately
        // stuck, not a missed enablement
        let b = spec.batch.min(t.stages[si].queue.len());
        let (lo, hi) = t.bounds[si];
        let ep = t.config.assignment[si];
        let from_ep = if si == 0 { None } else { Some(t.config.assignment[si - 1]) };
        let (_compute, transfer) = simulator::stage_service_time(
            &spec.net,
            &t.subplat,
            &t.dbs[b - 1],
            lo,
            hi,
            ep,
            from_ep,
            b as u64,
        );
        let gep = t.ep_map[ep];
        !(sh.ep_down(gep, now) || (transfer > 0.0 && sh.link_cut(now)))
    }
}

/// Settle a tenant's pipeline after a state change: repeatedly deliver
/// completed batches and dispatch idle stages until a fixpoint, visiting
/// only stages marked dirty (plus the neighbours each action enables).
///
/// `dirty` seeds the worklist: bit `s` means stage `s` may have been
/// enabled by the triggering event (arrival → bit 0, stage completion →
/// that stage's bit, resume/reconfig/epoch → all). Stages are processed in
/// **descending** index order within a round, exactly like the old
/// whole-pipeline rescan, and marks at or above the scan position are
/// deferred to the next round — so the action sequence (and therefore
/// every frozen contention factor and event sequence number) is identical
/// to scanning all stages, as the `FullRescan` golden tests verify.
#[allow(clippy::too_many_arguments)]
fn settle(
    spec: &TenantSpec,
    t: &mut ShardRt,
    lc: &mut TenantLc,
    sh: &mut Shared,
    ti: usize,
    shard_ix: usize,
    now: f64,
    duration_s: f64,
    dirty: u64,
    full_rescan: bool,
) {
    let prof_t0 = sh.prof_start();
    let n = t.stages.len();
    let all = all_mask(n);
    let mut cur = if full_rescan { all } else { dirty & all };
    if t.thaw_pending && now >= t.frozen_until {
        // dispatch was frozen pipeline-wide: every stage may be runnable
        t.thaw_pending = false;
        cur = all;
    }
    let mut next: u64 = 0;
    loop {
        let mut progress = false;
        while cur != 0 {
            let si = 63 - cur.leading_zeros() as usize;
            cur &= !(1u64 << si);
            if deliver_stage(spec, t, lc, sh, ti, shard_ix, si, now) {
                // the downstream queue grew and this stage may deliver
                // again / have been freed: both are at or above the scan
                // position, so they belong to the next round
                progress = true;
                next |= 1u64 << si;
                if si + 1 < n {
                    next |= 1u64 << (si + 1);
                }
            }
            if dispatch_stage(spec, t, sh, ti, shard_ix, si, now, duration_s) {
                // queue `si` shrank: the upstream stage blocked on it can
                // deliver now, and si-1 is still ahead of this scan
                progress = true;
                if si > 0 {
                    cur |= 1u64 << (si - 1);
                }
            }
        }
        if full_rescan {
            // reference mode: ignore the dirty-mask bookkeeping entirely
            // and repeat full descending passes until a pass is quiet —
            // the PR-1 loop, kept independent of the propagation rules
            next = 0;
            if !progress {
                break;
            }
            cur = all;
            continue;
        }
        if next == 0 {
            break;
        }
        cur = next;
        next = 0;
    }
    #[cfg(debug_assertions)]
    for si in 0..n {
        debug_assert!(!can_progress(spec, t, sh, si, now), "settle fixpoint missed stage {si}");
    }
    if let Some(o) = sh.obs.as_deref_mut() {
        // Post-fixpoint queue scan: per-stage high-water for the epoch
        // samples plus one depth observation for the queue histogram.
        // O(n_stages) per settle, pure reads — never perturbs the sim.
        let mut total = 0u64;
        for (si, st) in t.stages.iter().enumerate() {
            let l = st.queue.len() as u64;
            total += l;
            o.queue_mark(ti, shard_ix, si, l as u32);
        }
        o.queue_total(total);
    }
    sh.prof_end(Span::Settle, prof_t0);
}

/// Interrupt one replica's in-flight work and drain its queues: bump the
/// generation (pending StageDone events go stale), release the shared
/// contention counters for batches still computing — through the **old**
/// `ep_map`, before any caller swaps it — and return every undelivered
/// request's arena index, oldest first. Partial batch work is lost;
/// requests never are. The caller re-queues them ([`requeue_orphans`])
/// after swapping whatever it is swapping: a configuration, or on
/// failover the whole sub-platform.
fn detach_replica(t: &mut ShardRt, sh: &mut Shared) -> Vec<u32> {
    t.gen += 1;
    let mut orphans: Vec<u32> = Vec::new();
    let mut spare_bufs: Vec<Vec<u32>> = Vec::new();
    for st in &mut t.stages {
        if let Some(inf) = st.busy.take() {
            if !inf.completed {
                let gep = t.ep_map[inf.ep];
                sh.ep_release(gep, inf.uses_link);
            }
            orphans.extend_from_slice(&inf.reqs[inf.taken..]);
            spare_bufs.push(inf.reqs);
        }
        orphans.extend(st.queue.drain(..));
    }
    for buf in spare_bufs {
        t.recycle(buf);
    }
    // oldest requests re-queue first (deterministic, arrival-order fair)
    orphans.sort_by_key(|&ix| t.arena[ix as usize].id);
    orphans
}

/// Re-queue detached requests at the stage owning each one's next layer.
fn requeue_orphans(spec: &TenantSpec, t: &mut ShardRt, orphans: Vec<u32>) {
    let n_layers = spec.net.len();
    for ix in orphans {
        // completed-but-undelivered batches sit at a stage boundary; resume
        // from the stage owning the next layer (never past the last stage)
        let layers_done = t.arena[ix as usize].layers_done;
        let si = if layers_done >= n_layers {
            t.stages.len() - 1
        } else {
            t.config.stage_of_layer(layers_done).expect("layer in range")
        };
        t.stages[si].queue.push_back(ix);
    }
}

// ---------------------------------------------------------------------------
// request lifecycle: deadlines, retry/backoff, hedging

/// Schedule a backed-off re-arrival for a refused request, if the tenant
/// has a retry policy with budget left. `attempt` is the ordinal of the
/// attempt that just failed (1 = the original arrival), so a policy with
/// `max_attempts = k` produces at most `k` re-arrivals per logical
/// request. The jitter is a pure hash of `(seed, tenant, id, attempt)` —
/// RNG-free, so recorded traces replay bit-identically.
fn schedule_retry(
    sh: &mut Shared,
    retry: Option<RetryPolicy>,
    opts: &ServeOptions,
    ti: usize,
    id: u64,
    attempt: u32,
    now: f64,
) {
    let Some(rp) = retry else { return };
    if rp.max_attempts == 0 || attempt > rp.max_attempts {
        return;
    }
    let u = lifecycle::jitter_u01(opts.seed, ti as u64, id, attempt);
    let at = now + rp.delay_s(attempt, u);
    if at <= opts.duration_s {
        sh.schedule(at, EvKind::Retry { tenant: ti, attempt: attempt + 1 });
    }
}

/// One copy of a hedged pair left the system abnormally (evicted by
/// DropOldest or reaped by a deadline): dissolve the hedge. The surviving
/// copy — wherever it is queued, in service, or mid-migration — becomes an
/// ordinary request again, so its eventual completion counts normally.
fn unhedge(t: &mut TenantRt, id: u64) {
    t.lc.hedges.remove(&id);
    for srt in &mut t.shards {
        // ids are never reused within a tenant, so scanning the arena is
        // safe: stale freed slots with this id are unreachable and
        // clearing their flags is harmless
        for req in &mut srt.arena {
            if req.id == id {
                req.hedged = false;
                req.twin = false;
            }
        }
    }
}

/// Run one request through tenant `ti`'s admission front door at replica
/// `s` — shared by first arrivals and retry re-arrivals. Counts it
/// offered, applies load-shed / queue-capacity policy, and on admission
/// arms the request's deadline and hedge events. With every lifecycle
/// policy off this is byte-for-byte the pre-lifecycle admission path: no
/// extra events are scheduled and no extra notes are hashed.
#[allow(clippy::too_many_arguments)]
fn admit_request(
    t: &mut TenantRt,
    sh: &mut Shared,
    opts: &ServeOptions,
    ti: usize,
    s: usize,
    now: f64,
    id: u64,
    attempt: u32,
) {
    t.offered += 1;
    t.next_id += 1;
    let cap = t.spec.queue_capacity;
    let admission = t.spec.admission;
    let load_shed = t.load_shed;
    let retry = t.spec.retry;
    let deadline_s = t.spec.deadline_s;
    let hedge_armed = t.spec.hedge.is_some() && t.shards.len() > 1;
    let hedge_delay_s = t.lc.hedge_delay_s;
    let srt = &mut t.shards[s];
    srt.offered += 1;
    srt.ep_offered += 1;
    let mut evicted: Option<Request> = None;
    if load_shed {
        // gracefully degraded: the tenant is shed this epoch — the
        // arrival is counted and rejected at admission regardless of
        // queue room (offered == rejected for shed arrivals, so
        // conservation holds untouched). Sheds are *intentional*
        // capacity decisions, so they are never retried: a retry would
        // re-offer the exact demand the control plane just shed.
        srt.rejected += 1;
        srt.ep_rejected += 1;
        sh.obs_admit(ti, obs::ADM_SHED);
        return;
    } else if srt.stages[0].queue.len() >= cap {
        match admission {
            AdmissionPolicy::Reject => {
                srt.rejected += 1;
                srt.ep_rejected += 1;
                sh.obs_admit(ti, obs::ADM_REJECT);
                schedule_retry(sh, retry, opts, ti, id, attempt, now);
                return;
            }
            AdmissionPolicy::DropOldest => {
                if let Some(old) = srt.stages[0].queue.pop_front() {
                    evicted = Some(srt.arena[old as usize].clone());
                    srt.free_slots.push(old);
                }
                srt.dropped += 1;
                srt.ep_dropped += 1;
                sh.obs_admit(ti, obs::ADM_DROP);
                let ix = srt.alloc(id, now);
                srt.arena[ix as usize].attempt = attempt;
                srt.stages[0].queue.push_back(ix);
            }
        }
    } else {
        sh.obs_admit(ti, obs::ADM_ADMIT);
        let ix = srt.alloc(id, now);
        srt.arena[ix as usize].attempt = attempt;
        srt.stages[0].queue.push_back(ix);
        let l = srt.stages[0].queue.len();
        if l > srt.max_queue_len {
            srt.max_queue_len = l;
        }
    }
    // the new request was admitted: arm its lifecycle events
    if deadline_s.is_finite() {
        let at = now + deadline_s;
        if at <= opts.duration_s {
            sh.schedule(at, EvKind::Expire { tenant: ti, id });
        }
    }
    if hedge_armed {
        let at = now + hedge_delay_s;
        if at <= opts.duration_s {
            sh.schedule(at, EvKind::Hedge { tenant: ti, id });
        }
    }
    if let Some(victim) = evicted {
        if victim.hedged {
            // the evicted copy's sibling still carries the logical
            // request: dissolve the hedge, never retry
            unhedge(t, victim.id);
        } else {
            schedule_retry(sh, retry, opts, ti, victim.id, victim.attempt, now);
        }
    }
}

/// Resolve freshly-won hedge races: for every id whose winning copy just
/// completed (`lc.won`), cancel the losing copy. A loser still queued is
/// reaped on the spot — its slot freed, its queue position released
/// (which can unblock an upstream stage stalled on the full queue, hence
/// the re-settle) — while a loser already in service is doomed and
/// discarded at delivery without a latency sample. Re-settling can
/// complete further hedged winners; the loop drains until quiet.
fn reap_hedge_losers(
    t: &mut TenantRt,
    sh: &mut Shared,
    ti: usize,
    now: f64,
    opts: &ServeOptions,
    full_rescan: bool,
) {
    while let Some(id) = t.lc.won.pop() {
        let wtp = t.spec.balancer == BalancerPolicy::WeightedThroughput;
        for si in 0..t.shards.len() {
            let mut touched = false;
            let mut found = false;
            {
                let srt = &mut t.shards[si];
                for st_ix in 0..srt.stages.len() {
                    let pos = srt.stages[st_ix]
                        .queue
                        .iter()
                        .position(|&ix| srt.arena[ix as usize].id == id);
                    if let Some(p) = pos {
                        let ix = srt.stages[st_ix].queue.remove(p).expect("position just found");
                        let was_twin = srt.arena[ix as usize].twin;
                        srt.cancelled += 1;
                        srt.ep_cancelled += 1;
                        srt.free_slots.push(ix);
                        sh.note(now, 12, pack_ts(ti, si), id, || {
                            format!("{now:.6} cancel {}#{id} r{si} reaped", t.spec.name)
                        });
                        if was_twin && wtp {
                            // the twin was charged one smooth-WRR credit
                            // at placement but never served: refund it
                            srt.credit += srt.weight;
                        }
                        touched = true;
                        found = true;
                        break;
                    }
                }
                if !found {
                    'doom: for st in &mut srt.stages {
                        if let Some(inf) = st.busy.as_mut() {
                            for &ix in &inf.reqs[inf.taken..] {
                                if srt.arena[ix as usize].id == id {
                                    srt.arena[ix as usize].doomed = true;
                                    found = true;
                                    break 'doom;
                                }
                            }
                        }
                    }
                }
            }
            if touched {
                settle(
                    &t.spec,
                    &mut t.shards[si],
                    &mut t.lc,
                    sh,
                    ti,
                    si,
                    now,
                    opts.duration_s,
                    u64::MAX,
                    full_rescan,
                );
            }
            if found {
                break;
            }
        }
    }
}

/// Freeze the replica's dispatch for the reconfiguration penalty and
/// schedule the thaw.
fn freeze_replica(
    t: &mut ShardRt,
    sh: &mut Shared,
    ti: usize,
    shard_ix: usize,
    now: f64,
    penalty_s: f64,
    duration_s: f64,
) {
    t.frozen_until = now + penalty_s;
    t.thaw_pending = true;
    if t.frozen_until <= duration_s {
        sh.schedule(t.frozen_until, EvKind::Resume { tenant: ti, shard: shard_ix });
    }
}

/// Apply a new configuration to one replica: interrupt in-flight work
/// (requests are re-queued at their completed-layer position; partial
/// stage work is lost), rebuild the stage array, and freeze dispatch for
/// the penalty.
#[allow(clippy::too_many_arguments)]
fn apply_reconfig(
    spec: &TenantSpec,
    t: &mut ShardRt,
    sh: &mut Shared,
    ti: usize,
    shard_ix: usize,
    now: f64,
    new_config: PipelineConfig,
    penalty_s: f64,
    duration_s: f64,
) {
    let orphans = detach_replica(t, sh);
    t.config = new_config;
    t.bounds = t.config.stage_bounds();
    // the WTP balancer weight tracks current capacity: a re-tuned replica
    // immediately receives its new proportional share of arrivals
    t.weight = simulator::throughput(&spec.net, &t.subplat, &t.dbs[0], &t.config);
    t.stages = (0..t.config.n_stages()).map(|_| StageRt::default()).collect();
    requeue_orphans(spec, t, orphans);
    freeze_replica(t, sh, ti, shard_ix, now, penalty_s, duration_s);
}

/// Re-plan one replica onto a different EP subset — failover off faulted
/// EPs, or re-adoption when a transient fault clears. Detaches all work,
/// rebuilds every platform-derived artifact (sub-platform view, batch
/// databases, scratch re-tune database, adaptive controller) against the
/// subset, plans a fresh configuration through the shared memoized subset
/// tuner (a warm [`PlanCache`] hit when this subset was planned before),
/// re-queues the detached requests on the new stage structure and freezes
/// for the reconfiguration penalty. Returns the plan's predicted
/// throughput.
#[allow(clippy::too_many_arguments)]
fn rebuild_replica(
    spec: &TenantSpec,
    t: &mut ShardRt,
    sh: &mut Shared,
    ti: usize,
    shard_ix: usize,
    now: f64,
    plat: &Platform,
    eps: Vec<EpId>,
    cache: &PlanCache,
    opts: &ServeOptions,
) -> Result<f64> {
    debug_assert!(!eps.is_empty(), "rebuild needs at least one EP");
    let prof_t0 = sh.prof_start();
    let model = CostModel::default();
    let orphans = detach_replica(t, sh);
    let subplat = plat.subset(&eps);
    let plan = shard::plan_shards_with(&spec.net, &subplat, 1, 1, cache)?;
    let config = plan.configs.into_iter().next().expect("plan_shards returns >= 1 replica");
    let predicted = plan.predicted.first().copied().unwrap_or(0.0);
    let mut dbs = Vec::with_capacity(spec.batch);
    for b in 1..=spec.batch {
        dbs.push(if b == 1 {
            PerfDb::build(&spec.net, &subplat, &model)
        } else {
            batch::build_batched(&spec.net, &subplat, &model, b as u32)
        });
    }
    t.scratch_db = dbs[spec.batch - 1].clone();
    t.controller = AdaptiveController::new(spec.net.clone(), subplat.clone(), model);
    t.ep_slow = vec![1.0; subplat.n_eps()];
    t.scale_buf = vec![1.0; subplat.n_eps()];
    t.dbs = dbs;
    t.config = config;
    t.bounds = t.config.stage_bounds();
    t.weight = simulator::throughput(&spec.net, &subplat, &t.dbs[0], &t.config);
    t.stages = (0..t.config.n_stages()).map(|_| StageRt::default()).collect();
    t.subplat = subplat;
    t.ep_map = eps;
    requeue_orphans(spec, t, orphans);
    freeze_replica(t, sh, ti, shard_ix, now, opts.reconfig_penalty_s, opts.duration_s);
    sh.prof_end(Span::DrainMigrate, prof_t0);
    Ok(predicted)
}

/// Detect → drain → re-plan: walk every replica whose current EP set
/// touches a downed EP and fail it over onto the surviving part of its
/// home set via [`rebuild_replica`]. A replica with no surviving home EP
/// is marked dead: its detached backlog migrates into the strongest
/// healthy sibling's arena (zero request loss — conservation is pinned by
/// tests) and it parks, activating the sibling if necessary. With no
/// healthy sibling anywhere the replica stays put holding its re-queued
/// backlog: dispatch is blocked by the fault state, so requests pool and
/// count in-flight until recovery.
fn fault_failover(
    rts: &mut [TenantRt],
    sh: &mut Shared,
    plat: &Platform,
    cache: &PlanCache,
    opts: &ServeOptions,
    now: f64,
    full_rescan: bool,
) -> Result<()> {
    for (ti, t) in rts.iter_mut().enumerate() {
        for si in 0..t.shards.len() {
            if !t.shards[si].ep_map.iter().any(|&e| sh.ep_down(e, now)) {
                continue;
            }
            let lost =
                t.shards[si].ep_map.iter().filter(|&&e| sh.ep_down(e, now)).count();
            let home = t.shards[si].home_eps.len();
            let surviving: Vec<EpId> = t.shards[si]
                .home_eps
                .iter()
                .copied()
                .filter(|&e| !sh.ep_down(e, now))
                .collect();
            if !surviving.is_empty() {
                let predicted = rebuild_replica(
                    &t.spec,
                    &mut t.shards[si],
                    sh,
                    ti,
                    si,
                    now,
                    plat,
                    surviving,
                    cache,
                    opts,
                )?;
                t.shards[si].dead = false;
                sh.control(
                    ControlRecord {
                        t_s: now,
                        kind: ControlKind::Failover,
                        tenant: ti as u32,
                        shard: si as u32,
                        a: t.shards[si].ep_map.len() as u64,
                        b: predicted.to_bits(),
                    },
                    &[
                        ("eps_lost", lost as f64),
                        ("eps_surviving", t.shards[si].ep_map.len() as f64),
                        ("home_eps", home as f64),
                        ("predicted_throughput", predicted),
                    ],
                );
                continue;
            }
            // the whole home set is down: the replica is dead
            let orphans = detach_replica(&mut t.shards[si], sh);
            t.shards[si].dead = true;
            // strongest sibling with a live home EP, preferring Active
            // ones (a parked/draining sibling is activated to take over)
            let mut target: Option<(usize, f64, bool)> = None;
            for (sj, s) in t.shards.iter().enumerate() {
                if sj == si || !s.home_eps.iter().any(|&e| !sh.ep_down(e, now)) {
                    continue;
                }
                let act = s.state == ReplicaState::Active;
                let better = match target {
                    None => true,
                    Some((_, tw, tact)) => (act && !tact) || (act == tact && s.weight > tw),
                };
                if better {
                    target = Some((sj, s.weight, act));
                }
            }
            match target {
                Some((sj, _, act)) => {
                    // cross-replica migration: re-admit every orphan into
                    // the sibling's arena at its completed-layer position
                    let n_orphans = orphans.len();
                    let sibling_weight = t.shards[sj].weight;
                    let n_layers = t.spec.net.len();
                    for ix in orphans {
                        let r = t.shards[si].arena[ix as usize].clone();
                        t.shards[si].free_slots.push(ix);
                        let ld = r.layers_done;
                        let dst = &mut t.shards[sj];
                        let jx = dst.alloc(r.id, r.arrival_s);
                        // migration preserves the full lifecycle state
                        // (attempt / hedged / doomed / twin), not just
                        // the layer position
                        dst.arena[jx as usize] = r;
                        let stage = if ld >= n_layers {
                            dst.stages.len() - 1
                        } else {
                            dst.config.stage_of_layer(ld).expect("layer in range")
                        };
                        dst.stages[stage].queue.push_back(jx);
                        let l = dst.stages[stage].queue.len();
                        if l > dst.max_queue_len {
                            dst.max_queue_len = l;
                        }
                    }
                    if !act {
                        t.shards[sj].reactivate();
                        t.n_active += 1;
                        t.shards[sj]
                            .scale_log
                            .push(ScaleEvent { t_s: now, to: ReplicaState::Active });
                        sh.note(now, 6, pack_ts(ti, sj), ReplicaState::Active.code(), || {
                            format!("{now:.6} scale {} r{sj} active", t.spec.name)
                        });
                        sh.control(
                            ControlRecord {
                                t_s: now,
                                kind: ControlKind::Scale,
                                tenant: ti as u32,
                                shard: sj as u32,
                                a: 0,
                                b: ReplicaState::Active.code(),
                            },
                            &[
                                ("migrated_backlog", n_orphans as f64),
                                ("sibling_weight", sibling_weight),
                            ],
                        );
                    }
                    // the dead replica parks (not drains: its backlog just
                    // moved), freeing its EP meter
                    if t.shards[si].state == ReplicaState::Active {
                        t.n_active -= 1;
                    }
                    if t.shards[si].state != ReplicaState::Parked {
                        t.shards[si].state = ReplicaState::Parked;
                        t.shards[si]
                            .scale_log
                            .push(ScaleEvent { t_s: now, to: ReplicaState::Parked });
                        sh.note(now, 6, pack_ts(ti, si), ReplicaState::Parked.code(), || {
                            format!("{now:.6} scale {} r{si} parked", t.spec.name)
                        });
                        sh.control(
                            ControlRecord {
                                t_s: now,
                                kind: ControlKind::Scale,
                                tenant: ti as u32,
                                shard: si as u32,
                                a: 0,
                                b: ReplicaState::Parked.code(),
                            },
                            &[
                                ("replica_dead", 1.0),
                                ("migrated_backlog", n_orphans as f64),
                            ],
                        );
                    }
                    for srt in &mut t.shards {
                        srt.credit = 0.0;
                    }
                    // the sibling's queues grew: settle it now
                    settle(
                        &t.spec,
                        &mut t.shards[sj],
                        &mut t.lc,
                        sh,
                        ti,
                        sj,
                        now,
                        opts.duration_s,
                        u64::MAX,
                        full_rescan,
                    );
                }
                None => {
                    requeue_orphans(&t.spec, &mut t.shards[si], orphans);
                }
            }
        }
    }
    Ok(())
}

/// A transient stall window closed: re-adopt the recovered EPs. Every
/// replica whose home set contains one rebuilds onto home-minus-still-
/// faulted (a warm [`PlanCache`] hit for the common full-home case), dead
/// replicas come back to life, and a dead-parked one re-activates
/// immediately — re-admission does not wait for the autoscaler.
fn fault_recover(
    rts: &mut [TenantRt],
    sh: &mut Shared,
    plat: &Platform,
    cache: &PlanCache,
    opts: &ServeOptions,
    now: f64,
    recovered: &[EpId],
) -> Result<()> {
    for (ti, t) in rts.iter_mut().enumerate() {
        for si in 0..t.shards.len() {
            if !t.shards[si].home_eps.iter().any(|e| recovered.contains(e)) {
                continue;
            }
            let desired: Vec<EpId> = t.shards[si]
                .home_eps
                .iter()
                .copied()
                .filter(|&e| !sh.ep_down(e, now))
                .collect();
            if desired.is_empty() || desired == t.shards[si].ep_map {
                continue;
            }
            let was_dead = t.shards[si].dead;
            let predicted = rebuild_replica(
                &t.spec, &mut t.shards[si], sh, ti, si, now, plat, desired, cache, opts,
            )?;
            t.shards[si].dead = false;
            sh.control(
                ControlRecord {
                    t_s: now,
                    kind: ControlKind::Failover,
                    tenant: ti as u32,
                    shard: si as u32,
                    a: t.shards[si].ep_map.len() as u64,
                    b: predicted.to_bits(),
                },
                &[
                    ("eps_recovered", recovered.len() as f64),
                    ("eps_adopted", t.shards[si].ep_map.len() as f64),
                    ("was_dead", f64::from(u8::from(was_dead))),
                    ("predicted_throughput", predicted),
                ],
            );
            if was_dead && t.shards[si].state != ReplicaState::Active {
                t.shards[si].state = ReplicaState::Active;
                t.n_active += 1;
                t.shards[si].scale_log.push(ScaleEvent { t_s: now, to: ReplicaState::Active });
                sh.note(now, 6, pack_ts(ti, si), ReplicaState::Active.code(), || {
                    format!("{now:.6} scale {} r{si} active", t.spec.name)
                });
                sh.control(
                    ControlRecord {
                        t_s: now,
                        kind: ControlKind::Scale,
                        tenant: ti as u32,
                        shard: si as u32,
                        a: 0,
                        b: ReplicaState::Active.code(),
                    },
                    &[("was_dead", 1.0)],
                );
                for srt in &mut t.shards {
                    srt.credit = 0.0;
                }
            }
        }
    }
    Ok(())
}

/// Graceful degradation, run at every epoch tick of a faulted run: when
/// live serving capacity no longer covers observed demand, shed whole
/// tenants — lowest [`TenantSpec::weight`] first — by rejecting their
/// arrivals at admission, and re-admit them automatically once the faults
/// clear or capacity returns. The cover is greedy by descending weight;
/// the first demanding tenant always admits (degraded service beats
/// none), and with no fault in force everything admits — overload on a
/// healthy platform stays the admission policies' job. Transitions emit
/// [`ControlKind::Shed`] records.
fn degrade_tick(rts: &mut [TenantRt], sh: &mut Shared, now: f64, opts: &ServeOptions) {
    let epoch_s = opts.control_epoch_s;
    if epoch_s <= 0.0 {
        return;
    }
    let fault_active = sh.any_fault_active(now);
    let mut demand: Vec<f64> = Vec::with_capacity(rts.len());
    let mut capacity = 0.0f64;
    for t in rts.iter() {
        let offered: u64 =
            t.shards.iter().filter_map(|s| s.epochs.last()).map(|e| e.offered).sum();
        demand.push(offered as f64 / epoch_s);
        for s in &t.shards {
            if s.state == ReplicaState::Active && !s.dead {
                capacity += s.weight;
            }
        }
    }
    // Cover order: descending weight, ties broken by **ascending tenant
    // index** — the tie-break is part of the engine's determinism
    // contract (equal-weight tenants must shed/re-admit identically on
    // every run and on replay, never by incidental iteration order), so
    // among equal weights the lower-index tenant is covered first and
    // the higher-index one sheds first. Pinned by the equal-weight shed
    // test.
    let mut order: Vec<usize> = (0..rts.len()).collect();
    order.sort_by(|&a, &b| rts[b].spec.weight.total_cmp(&rts[a].spec.weight).then(a.cmp(&b)));
    let mut used = 0.0f64;
    let mut admitted_any = false;
    for ti in order {
        let admit = !fault_active
            || demand[ti] == 0.0
            || (capacity > 0.0 && (!admitted_any || used + demand[ti] <= capacity));
        if admit {
            used += demand[ti];
            if demand[ti] > 0.0 {
                admitted_any = true;
            }
        }
        let t = &mut rts[ti];
        let shed = !admit;
        if t.load_shed != shed {
            t.load_shed = shed;
            sh.control(
                ControlRecord {
                    t_s: now,
                    kind: ControlKind::Shed,
                    tenant: ti as u32,
                    shard: 0,
                    a: 0,
                    b: u64::from(shed),
                },
                &[
                    ("demand_rps", demand[ti]),
                    ("capacity_rps", capacity),
                    ("covered_rps", used),
                    ("fault_active", f64::from(u8::from(fault_active))),
                ],
            );
        }
    }
}

/// Finalize one replica's control epoch: record stats and, under goodput
/// regression with queue pressure, run the warm re-tune. Sharded tenants
/// tick every replica independently — a regressing replica re-tunes on
/// its own sub-platform without touching its siblings' EPs.
#[allow(clippy::too_many_arguments)]
fn epoch_tick(
    spec: &TenantSpec,
    t: &mut ShardRt,
    sh: &mut Shared,
    ti: usize,
    shard_ix: usize,
    now: f64,
    opts: &ServeOptions,
) {
    let epoch_s = opts.control_epoch_s;
    let goodput = t.ep_slo_ok as f64 / epoch_s;
    let throughput = t.ep_completed as f64 / epoch_s;
    let backlog = t.backlog();
    let pressure = t.queued() > 0 || t.ep_rejected > 0 || t.ep_dropped > 0;
    let mut retuned = false;
    let mut trials = 0u64;
    // rolling-max baseline: tracks the best recently sustained goodput,
    // decaying ~5%/epoch so genuine load declines stop looking like drift
    t.baseline_goodput = (t.baseline_goodput * BASELINE_DECAY).max(goodput);
    if opts.control
        && pressure
        && t.epochs_since_retune >= opts.retune_cooldown_epochs
        && t.baseline_goodput > 0.0
        && goodput < opts.retune_threshold * t.baseline_goodput
    {
        let prof_t0 = sh.prof_start();
        // observed database: contention-free costs at the tenant's service
        // batch size (what dispatch actually charges), rescaled by the
        // per-EP slowdown the replica experienced — written into the
        // preallocated scratch database, so a warm re-tune epoch allocates
        // nothing for its observed-cost model
        for ep in 0..t.subplat.n_eps() {
            // observed contention EWMA × any thermal throttle in force on
            // the EP, so a warm re-tune plans against the machine as it
            // is; the fault factor is exactly 1.0 on a healthy platform
            let f = t.ep_slow[ep].max(1.0) * sh.ep_fault_factor(t.ep_map[ep], now);
            t.scale_buf[ep] = if f > 1.001 { f } else { 1.0 };
        }
        t.scratch_db.copy_scaled_from(&t.dbs[spec.batch - 1], &t.scale_buf);
        let (best, n) = t.controller.warm_retune(&t.scratch_db, t.config.clone());
        trials = n;
        t.retunes += 1;
        t.retune_trials += n;
        t.epochs_since_retune = 0;
        retuned = true;
        let changed = best != t.config;
        sh.control(
            ControlRecord {
                t_s: now,
                kind: ControlKind::Retune,
                tenant: ti as u32,
                shard: shard_ix as u32,
                a: trials,
                b: u64::from(changed),
            },
            &[
                ("goodput_rps", goodput),
                ("baseline_rps", t.baseline_goodput),
                ("threshold_rps", opts.retune_threshold * t.baseline_goodput),
                ("queued", t.queued() as f64),
                ("epoch_rejected", t.ep_rejected as f64),
                ("epoch_dropped", t.ep_dropped as f64),
                ("backlog", backlog as f64),
            ],
        );
        if changed {
            apply_reconfig(
                spec,
                t,
                sh,
                ti,
                shard_ix,
                now,
                best,
                opts.reconfig_penalty_s,
                opts.duration_s,
            );
        }
        sh.prof_end(Span::Retune, prof_t0);
    }
    if !retuned {
        t.epochs_since_retune = t.epochs_since_retune.saturating_add(1);
    }
    t.epochs.push(EpochStats {
        end_s: now,
        offered: t.ep_offered,
        completed: t.ep_completed,
        slo_ok: t.ep_slo_ok,
        rejected: t.ep_rejected,
        dropped: t.ep_dropped,
        expired: t.ep_expired,
        cancelled: t.ep_cancelled,
        retried: t.ep_retried,
        hedged: t.ep_hedged,
        goodput,
        throughput,
        backlog,
        retuned,
        retune_trials: trials,
        // the EP meter: a parked replica's EPs are free; active and
        // draining replicas hold theirs (recorded before this tick's
        // scale decisions, so the epoch that *ends* now is charged for
        // the state it ran under)
        active_eps: if t.state == ReplicaState::Parked { 0 } else { t.ep_map.len() as u64 },
    });
    t.ep_offered = 0;
    t.ep_completed = 0;
    t.ep_slo_ok = 0;
    t.ep_rejected = 0;
    t.ep_dropped = 0;
    t.ep_expired = 0;
    t.ep_cancelled = 0;
    t.ep_retried = 0;
    t.ep_hedged = 0;
    // stale contention estimates relax towards 1.0 (uncontended) between
    // epochs so EPs the tenant migrated away from — which no longer
    // produce completions to update the EWMA — become eligible again
    for f in &mut t.ep_slow {
        *f = 1.0 + (*f - 1.0) * EWMA_EPOCH_RELAX;
    }
}

/// Run one autoscaler step for a tenant at an epoch tick: finish pending
/// drains, assemble the load observation from the epoch that just
/// closed, and apply the (pure, deterministic) [`autoscale::decide`]
/// verdict — activating parked/draining replicas highest-predicted-first
/// on scale-up, or draining the weakest active replica on scale-down.
/// Every transition is hashed into the event log (tag 6) and recorded in
/// the replica's scale log. Balancer credits reset on any transition so
/// routing restarts neutral over the new active set.
fn autoscale_tick(t: &mut TenantRt, sh: &mut Shared, ti: usize, now: f64, opts: &ServeOptions) {
    // 1. a draining replica with an empty backlog parks (its EPs go idle)
    for si in 0..t.shards.len() {
        if t.shards[si].state == ReplicaState::Draining && t.shards[si].backlog() == 0 {
            t.shards[si].state = ReplicaState::Parked;
            t.shards[si].scale_log.push(ScaleEvent { t_s: now, to: ReplicaState::Parked });
            sh.note(now, 6, pack_ts(ti, si), ReplicaState::Parked.code(), || {
                format!("{now:.6} scale {} r{si} parked", t.spec.name)
            });
            sh.control(
                ControlRecord {
                    t_s: now,
                    kind: ControlKind::Scale,
                    tenant: ti as u32,
                    shard: si as u32,
                    a: 0,
                    b: ReplicaState::Parked.code(),
                },
                &[("drained_backlog", 0.0)],
            );
        }
    }
    // 2. observe the epoch that just closed. The shed meter is the
    // epoch's unmet flow, derived from the per-epoch conservation
    // identity `offered + backlog_prev == completed + rejected + dropped
    // + backlog` (asserted by [`TenantReport::epoch_conserved`]) instead
    // of summing the rejected and dropped meters: the flow form counts a
    // request exactly once however it leaves the system, so an arrival
    // admitted under DropOldest (which evicts the oldest queued request
    // in the same epoch) can never be charged both as an admission and
    // as a shed. The identity is a **tenant-level** invariant — failover
    // and elastic re-partitions migrate requests across replica arenas,
    // which cancels in the aggregate but not per replica — so the terms
    // are summed across replicas before the subtraction. When the meters
    // are consistent the two forms agree bit-for-bit, so existing event
    // logs and replays are unchanged.
    let mut offered = 0u64;
    let mut flow_in = 0u64;
    let mut flow_out = 0u64;
    for srt in &t.shards {
        if let Some(e) = srt.epochs.last() {
            offered += e.offered;
            let backlog_prev = if srt.epochs.len() >= 2 {
                srt.epochs[srt.epochs.len() - 2].backlog
            } else {
                0
            };
            flow_in += e.offered + backlog_prev;
            // expired and hedge-cancelled requests left the system served
            // by *policy*, not shed by capacity — they sit on the outflow
            // side of the identity so the shed meter stays a pure
            // unmet-demand signal
            flow_out += e.completed + e.backlog + e.expired + e.cancelled;
        }
    }
    let shed = flow_in.saturating_sub(flow_out);
    let mut queued = 0u64;
    let mut active = 0usize;
    let mut active_capacity = 0.0f64;
    let mut weakest_active = f64::INFINITY;
    for srt in &t.shards {
        if srt.state == ReplicaState::Active {
            active += 1;
            queued += srt.queued();
            // a dead replica (whole home EP set faulted) serves nothing:
            // it contributes no capacity, so the autoscaler sees the real
            // post-fault headroom
            let w = if srt.dead { 0.0 } else { srt.weight };
            active_capacity += w;
            if w < weakest_active {
                weakest_active = w;
            }
        }
    }
    // scale-up candidates: highest predicted throughput first, ties on
    // the lower replica index; dead replicas cannot be activated
    let mut inactive: Vec<(usize, f64)> = t
        .shards
        .iter()
        .enumerate()
        .filter(|(_, s)| s.state != ReplicaState::Active && !s.dead)
        .map(|(i, s)| (i, s.weight))
        .collect();
    inactive.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    let epoch_s = opts.control_epoch_s;
    let load = TenantLoad {
        offered_rate: if epoch_s > 0.0 { offered as f64 / epoch_s } else { 0.0 },
        shed,
        queued,
        queue_slots: active as u64 * t.spec.queue_capacity as u64,
        active,
        active_capacity,
        weakest_active: if weakest_active.is_finite() { weakest_active } else { 0.0 },
        inactive_weights: inactive.iter().map(|&(_, w)| w).collect(),
    };
    match autoscale::decide(&mut t.auto, &opts.autoscale, &load) {
        ScaleDecision::Hold => {}
        ScaleDecision::Up { activate } => {
            for &(si, _) in inactive.iter().take(activate) {
                t.shards[si].reactivate();
                t.n_active += 1;
                t.shards[si].scale_log.push(ScaleEvent { t_s: now, to: ReplicaState::Active });
                sh.note(now, 6, pack_ts(ti, si), ReplicaState::Active.code(), || {
                    format!("{now:.6} scale {} r{si} active", t.spec.name)
                });
                sh.control(
                    ControlRecord {
                        t_s: now,
                        kind: ControlKind::Scale,
                        tenant: ti as u32,
                        shard: si as u32,
                        a: 0,
                        b: ReplicaState::Active.code(),
                    },
                    &[
                        ("offered_rps", load.offered_rate),
                        ("shed", load.shed as f64),
                        ("queued", load.queued as f64),
                        ("active", load.active as f64),
                        ("active_capacity_rps", load.active_capacity),
                    ],
                );
            }
            for srt in &mut t.shards {
                srt.credit = 0.0;
            }
        }
        ScaleDecision::Down => {
            // retire the weakest active replica; ties drain the highest
            // index (later replicas go first, replica 0 is the keeper)
            let mut pick: Option<(usize, f64)> = None;
            for (si, srt) in t.shards.iter().enumerate() {
                if srt.state != ReplicaState::Active {
                    continue;
                }
                let better = match pick {
                    None => true,
                    Some((_, pw)) => srt.weight <= pw,
                };
                if better {
                    pick = Some((si, srt.weight));
                }
            }
            if let Some((si, _)) = pick {
                // an empty replica has nothing to serve out: it parks in
                // one transition; otherwise it drains first and parks at
                // a later tick once its backlog is gone
                let to = if t.shards[si].backlog() == 0 {
                    ReplicaState::Parked
                } else {
                    ReplicaState::Draining
                };
                t.shards[si].state = to;
                t.n_active -= 1;
                t.shards[si].scale_log.push(ScaleEvent { t_s: now, to });
                sh.note(now, 6, pack_ts(ti, si), to.code(), || {
                    format!("{now:.6} scale {} r{si} {}", t.spec.name, to.name())
                });
                sh.control(
                    ControlRecord {
                        t_s: now,
                        kind: ControlKind::Scale,
                        tenant: ti as u32,
                        shard: si as u32,
                        a: 0,
                        b: to.code(),
                    },
                    &[
                        ("offered_rps", load.offered_rate),
                        ("shed", load.shed as f64),
                        ("queued", load.queued as f64),
                        ("active", load.active as f64),
                        ("active_capacity_rps", load.active_capacity),
                        ("weakest_active_rps", load.weakest_active),
                    ],
                );
                for srt in &mut t.shards {
                    srt.credit = 0.0;
                }
            }
        }
    }
}

/// Re-home one replica onto a planner-chosen EP subset **with** its
/// planner-chosen configuration — the elastic loop's plan-application
/// primitive. Same artifact rebuild as [`rebuild_replica`] (sub-platform
/// view, batch databases, scratch re-tune database, controller, EWMA
/// reset, orphan re-queue, reconfiguration freeze), but the configuration
/// comes from the cluster plan instead of a fresh per-subset search: the
/// co-planner already tuned every placement, so applying it verbatim is
/// both cheaper and exactly the allocation the gain bar scored.
/// `home_eps` moves with the replica — subsequent failover re-plans
/// within the *new* budget.
#[allow(clippy::too_many_arguments)]
fn rehome_replica(
    spec: &TenantSpec,
    t: &mut ShardRt,
    sh: &mut Shared,
    ti: usize,
    shard_ix: usize,
    now: f64,
    plat: &Platform,
    eps: Vec<EpId>,
    config: PipelineConfig,
    opts: &ServeOptions,
) {
    debug_assert!(!eps.is_empty(), "rehome needs at least one EP");
    let prof_t0 = sh.prof_start();
    let model = CostModel::default();
    let orphans = detach_replica(t, sh);
    let subplat = plat.subset(&eps);
    let mut dbs = Vec::with_capacity(spec.batch);
    for b in 1..=spec.batch {
        dbs.push(if b == 1 {
            PerfDb::build(&spec.net, &subplat, &model)
        } else {
            batch::build_batched(&spec.net, &subplat, &model, b as u32)
        });
    }
    t.scratch_db = dbs[spec.batch - 1].clone();
    t.controller = AdaptiveController::new(spec.net.clone(), subplat.clone(), model);
    t.ep_slow = vec![1.0; subplat.n_eps()];
    t.scale_buf = vec![1.0; subplat.n_eps()];
    t.dbs = dbs;
    t.config = config;
    t.bounds = t.config.stage_bounds();
    t.weight = simulator::throughput(&spec.net, &subplat, &t.dbs[0], &t.config);
    t.stages = (0..t.config.n_stages()).map(|_| StageRt::default()).collect();
    t.subplat = subplat;
    t.home_eps = eps.clone();
    t.ep_map = eps;
    requeue_orphans(spec, t, orphans);
    freeze_replica(t, sh, ti, shard_ix, now, opts.reconfig_penalty_s, opts.duration_s);
    sh.prof_end(Span::DrainMigrate, prof_t0);
}

/// The elastic control loop, run at every epoch tick when
/// [`ServeOptions::elastic`] is enabled: re-derive the cluster plan from
/// the **observed** per-tenant demand of the epoch that just closed
/// ([`coplan::coplan_observed_with`], off the shared [`PlanCache`] so a
/// repeat of a previously scored allocation costs lookups, not tuning
/// runs), and when the candidate clears the gain bar
/// ([`autoscale::decide_repartition`]) migrate the live deployment onto
/// it:
///
/// * every replica whose planned EP slice changed is re-homed onto it
///   with the plan's tuned configuration ([`rehome_replica`]) — its
///   queued requests re-queue on the new stage structure, none are lost;
/// * when the plan collapses a tenant onto fewer replicas (scale-to-1
///   gives one replica the full budget), the surplus replicas' backlogs
///   migrate across slab arenas into the surviving replicas — the fault
///   plane's drain → re-admit machinery — and the surplus replicas park
///   dead (invisible to the autoscaler, EP meter free) until a later
///   re-partition grows the tenant again, which revives and re-activates
///   them.
///
/// Each re-partitioned tenant hashes one tag-8 event into the log and
/// emits a [`ControlKind::Repartition`] record, so elastic runs replay
/// bit-identically. The loop holds while any fault is in force —
/// failover owns the EP map then, and a demand-driven plan knows nothing
/// about downed EPs.
#[allow(clippy::too_many_arguments)]
fn elastic_tick(
    rts: &mut [TenantRt],
    sh: &mut Shared,
    plat: &Platform,
    est: &mut ElasticState,
    cache: &PlanCache,
    opts: &ServeOptions,
    now: f64,
    full_rescan: bool,
) -> Result<()> {
    if sh.any_fault_active(now) {
        return Ok(());
    }
    let epoch_s = opts.control_epoch_s;
    // observed demand, aggregated per tenant from the epoch that just
    // closed (same tenant-level flow derivation as the autoscaler's shed
    // meter) plus the standing backlog right now
    let mut specs: Vec<TenantSpec> = Vec::with_capacity(rts.len());
    let mut demands: Vec<TenantDemand> = Vec::with_capacity(rts.len());
    let mut caps: Vec<usize> = Vec::with_capacity(rts.len());
    for t in rts.iter() {
        let mut offered = 0u64;
        let mut flow_in = 0u64;
        let mut flow_out = 0u64;
        let mut backlog = 0u64;
        for srt in &t.shards {
            if let Some(e) = srt.epochs.last() {
                offered += e.offered;
                let backlog_prev = if srt.epochs.len() >= 2 {
                    srt.epochs[srt.epochs.len() - 2].backlog
                } else {
                    0
                };
                flow_in += e.offered + backlog_prev;
                // policy exits (expiry, hedge cancellation) are outflow,
                // not shed — same identity as the autoscaler's meter
                flow_out += e.completed + e.backlog + e.expired + e.cancelled;
            }
            backlog += srt.backlog();
        }
        let shed = flow_in.saturating_sub(flow_out);
        specs.push(t.spec.clone());
        demands.push(TenantDemand {
            offered_rate: offered as f64 / epoch_s,
            shed_rate: shed as f64 / epoch_s,
            backlog,
        });
        caps.push(t.shards.len());
    }
    let prof_t0 = sh.prof_start();
    let plan = coplan::coplan_observed_with(plat, &specs, &demands, &caps, 1, cache)?;
    sh.prof_end(Span::Coplan, prof_t0);
    // live objective in the same units as the plan's: Σ effective weight ×
    // analytic capacity of the replicas that can actually serve. Both
    // sides are scored under the same demand factors — capacity parked on
    // an idle tenant counts for little on either side, so the bar only
    // clears when moving EPs toward the pressure genuinely helps.
    let factors = coplan::demand_factors(&demands);
    let live: f64 = rts
        .iter()
        .zip(&factors)
        .map(|(t, f)| {
            t.spec.weight
                * f
                * t.shards.iter().filter(|s| !s.dead).map(|s| s.weight).sum::<f64>()
        })
        .sum();
    if !autoscale::decide_repartition(est, &opts.elastic, live, plan.objective()) {
        return Ok(());
    }
    for (ti, alloc) in plan.allocations.iter().enumerate() {
        let t = &mut rts[ti];
        let m = alloc.placements.len().min(t.shards.len());
        let changed = t
            .shards
            .iter()
            .take(m)
            .zip(&alloc.placements)
            .any(|(s, (eps, _))| s.home_eps != *eps || s.dead)
            || t.shards.iter().skip(m).any(|s| !s.dead);
        if !changed {
            continue;
        }
        // 1. re-home the replicas the plan keeps; a dead one revives
        for (si, (eps, cfg)) in alloc.placements.iter().take(m).enumerate() {
            let was_dead = t.shards[si].dead;
            if t.shards[si].home_eps != *eps {
                rehome_replica(
                    &t.spec,
                    &mut t.shards[si],
                    sh,
                    ti,
                    si,
                    now,
                    plat,
                    eps.clone(),
                    cfg.clone(),
                    opts,
                );
            }
            t.shards[si].dead = false;
            if was_dead && t.shards[si].state != ReplicaState::Active {
                t.shards[si].reactivate();
                t.n_active += 1;
                t.shards[si].scale_log.push(ScaleEvent { t_s: now, to: ReplicaState::Active });
                sh.note(now, 6, pack_ts(ti, si), ReplicaState::Active.code(), || {
                    format!("{now:.6} scale {} r{si} active", t.spec.name)
                });
                sh.control(
                    ControlRecord {
                        t_s: now,
                        kind: ControlKind::Scale,
                        tenant: ti as u32,
                        shard: si as u32,
                        a: 0,
                        b: ReplicaState::Active.code(),
                    },
                    &[("revived", 1.0)],
                );
            }
        }
        // 2. surplus replicas: migrate their backlog into the surviving
        // replicas (cross-arena, zero loss) and park them dead
        let n_layers = t.spec.net.len();
        for si in m..t.shards.len() {
            let orphans = detach_replica(&mut t.shards[si], sh);
            let n_orphans = orphans.len();
            for (k, ix) in orphans.into_iter().enumerate() {
                let r = t.shards[si].arena[ix as usize].clone();
                t.shards[si].free_slots.push(ix);
                let ld = r.layers_done;
                // deterministic spread over the survivors, oldest first
                let sj = k % m;
                let dst = &mut t.shards[sj];
                let jx = dst.alloc(r.id, r.arrival_s);
                // migration preserves the full lifecycle state too
                dst.arena[jx as usize] = r;
                let stage = if ld >= n_layers {
                    dst.stages.len() - 1
                } else {
                    dst.config.stage_of_layer(ld).expect("layer in range")
                };
                dst.stages[stage].queue.push_back(jx);
                let l = dst.stages[stage].queue.len();
                if l > dst.max_queue_len {
                    dst.max_queue_len = l;
                }
            }
            if t.shards[si].state == ReplicaState::Active {
                t.n_active -= 1;
            }
            t.shards[si].dead = true;
            if t.shards[si].state != ReplicaState::Parked {
                t.shards[si].state = ReplicaState::Parked;
                t.shards[si].scale_log.push(ScaleEvent { t_s: now, to: ReplicaState::Parked });
                sh.note(now, 6, pack_ts(ti, si), ReplicaState::Parked.code(), || {
                    format!("{now:.6} scale {} r{si} parked", t.spec.name)
                });
                sh.control(
                    ControlRecord {
                        t_s: now,
                        kind: ControlKind::Scale,
                        tenant: ti as u32,
                        shard: si as u32,
                        a: 0,
                        b: ReplicaState::Parked.code(),
                    },
                    &[("surplus", 1.0), ("migrated_backlog", n_orphans as f64)],
                );
            }
        }
        debug_assert!(t.n_active >= 1, "a re-partition never leaves a tenant unservable");
        // routing restarts neutral over the new replica set
        for srt in &mut t.shards {
            srt.credit = 0.0;
        }
        t.repartitions += 1;
        sh.note(now, 8, pack_ts(ti, m), alloc.eps.len() as u64, || {
            format!(
                "{now:.6} repartition {} -> {} eps over {} replicas",
                t.spec.name,
                alloc.eps.len(),
                m
            )
        });
        sh.control(
            ControlRecord {
                t_s: now,
                kind: ControlKind::Repartition,
                tenant: ti as u32,
                shard: m as u32,
                a: alloc.eps.len() as u64,
                b: alloc.predicted.to_bits(),
            },
            &[
                ("live_objective", live),
                ("plan_objective", plan.objective()),
                ("min_gain_frac", opts.elastic.min_gain_frac),
                ("offered_rps", demands[ti].offered_rate),
                ("shed_rps", demands[ti].shed_rate),
                ("backlog", demands[ti].backlog as f64),
            ],
        );
        // queues moved across arenas and stage structures changed:
        // settle every replica of the tenant
        for si in 0..t.shards.len() {
            settle(
                &t.spec,
                &mut t.shards[si],
                &mut t.lc,
                sh,
                ti,
                si,
                now,
                opts.duration_s,
                u64::MAX,
                full_rescan,
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// the engine proper

/// Serve `tenants` (spec + initial pipeline configuration) on `plat` for
/// `opts.duration_s` simulated seconds. Deterministic for a fixed
/// `opts.seed`.
///
/// For tenants with `spec.shards > 1` the engine runs the shard-placement
/// search ([`shard::plan_shards`], itself deterministic) and serves the
/// planned replicas — unless the plan's total predicted throughput does
/// not beat the analytic throughput of the configuration the caller
/// passed in, in which case that configuration is served unsharded. The
/// caller's config is thus always the baseline candidate: opting into
/// sharding can never plan a slower deployment than it.
pub fn serve(
    plat: &Platform,
    tenants: Vec<(TenantSpec, PipelineConfig)>,
    opts: &ServeOptions,
) -> Result<ServeReport> {
    let (report, _, _) = serve_inner(plat, tenants, opts, None, false)?;
    Ok(report)
}

/// [`serve`] with the telemetry plane on: runs the identical simulation
/// (same `log_hash` — the observer taps the event funnel *beside* the
/// hash fold, never through it) and additionally returns the
/// [`ObsReport`]: per-epoch utilization samples, the control-plane
/// causality journal, the Prometheus snapshot and the self-profile.
pub fn serve_observed(
    plat: &Platform,
    tenants: Vec<(TenantSpec, PipelineConfig)>,
    opts: &ServeOptions,
) -> Result<(ServeReport, ObsReport)> {
    let (report, _, obs) = serve_inner(plat, tenants, opts, None, true)?;
    Ok((report, obs.expect("requested observer present")))
}

/// [`serve`] with the flight recorder on: runs the identical simulation
/// (same `log_hash` — capture taps the event funnel without adding hashed
/// events) and returns the report together with the assembled
/// [`Trace`], ready to [`Trace::save`] and later [`super::replay_full`]
/// or [`super::replay_whatif`].
pub fn serve_traced(
    plat: &Platform,
    tenants: Vec<(TenantSpec, PipelineConfig)>,
    opts: &ServeOptions,
) -> Result<(ServeReport, Trace)> {
    let inputs = tenants.clone();
    let (report, capture, _) = serve_inner(plat, tenants, opts, Some(Capture::new()), false)?;
    let capture = capture.unwrap_or_default();
    let trace = Trace::assemble(plat.clone(), inputs, opts.clone(), capture, &report);
    Ok((report, trace))
}

/// [`serve_traced`] and [`serve_observed`] in one run: record the trace
/// *and* sample telemetry. Used by `serve --record ... --metrics ...`,
/// and by the invariance tests proving the three planes never interfere.
pub fn serve_traced_observed(
    plat: &Platform,
    tenants: Vec<(TenantSpec, PipelineConfig)>,
    opts: &ServeOptions,
) -> Result<(ServeReport, Trace, ObsReport)> {
    let inputs = tenants.clone();
    let (report, capture, obs) =
        serve_inner(plat, tenants, opts, Some(Capture::new()), true)?;
    let capture = capture.unwrap_or_default();
    let trace = Trace::assemble(plat.clone(), inputs, opts.clone(), capture, &report);
    Ok((report, trace, obs.expect("requested observer present")))
}

/// The engine body behind [`serve`], [`serve_observed`] and
/// [`serve_traced`]: simulate; when `capture` is `Some`, record every
/// hashed event and control-plane decision into it; when `want_obs`,
/// sample telemetry beside the funnel and return the [`ObsReport`].
fn serve_inner(
    plat: &Platform,
    tenants: Vec<(TenantSpec, PipelineConfig)>,
    opts: &ServeOptions,
    mut capture: Option<Capture>,
    want_obs: bool,
) -> Result<(ServeReport, Option<Capture>, Option<ObsReport>)> {
    if tenants.is_empty() {
        bail!("serve: at least one tenant required");
    }
    opts.validate(plat)?;
    let model = CostModel::default();
    let mut master = Xoshiro256::seed_from(opts.seed);
    // Cross-tenant co-planning: one joint, disjoint EP allocation over
    // all tenants, computed up front (deterministic), replacing the
    // per-tenant placement logic below.
    let cluster_plan = if opts.coplan {
        let specs: Vec<TenantSpec> = tenants.iter().map(|(s, _)| s.clone()).collect();
        Some(coplan::coplan(plat, &specs)?)
    } else {
        None
    };
    if let (Some(cap), Some(plan)) = (&mut capture, &cluster_plan) {
        for (ti, alloc) in plan.allocations.iter().enumerate() {
            cap.control(ControlRecord {
                t_s: 0.0,
                kind: ControlKind::Coplan,
                tenant: ti as u32,
                shard: alloc.placements.len() as u32,
                a: alloc.eps.len() as u64,
                b: alloc.predicted.to_bits(),
            });
        }
    }
    let mut rts: Vec<TenantRt> = Vec::with_capacity(tenants.len());
    for (ti, (spec, config)) in tenants.into_iter().enumerate() {
        spec.validate(plat, &config)?;
        // shard placement: the tenant's slice of the cluster plan under
        // co-planning; otherwise identity for unsharded tenants, planned
        // per tenant for sharded ones. In the per-tenant case the
        // caller's configuration is always the baseline candidate — a
        // plan that does not predict strictly above it (e.g. the caller
        // pre-tuned harder than the planner's budget) falls back to
        // serving the provided config unsharded, so opting into sharding
        // can never plan a slower deployment than the configuration that
        // was passed in. (Under co-planning the budgets are disjoint by
        // construction, so the full-platform caller config is not a
        // candidate.)
        let identity: Vec<EpId> = (0..plat.n_eps()).collect();
        let placements: Vec<(Vec<EpId>, PipelineConfig)> = if let Some(plan) = &cluster_plan {
            plan.allocations[ti].placements.clone()
        } else if spec.shards > 1 {
            let plan = shard::plan_shards(&spec.net, plat, spec.shards)?;
            let provided_tp = {
                let db = PerfDb::build(&spec.net, plat, &model);
                simulator::throughput(&spec.net, plat, &db, &config)
            };
            if plan.total_predicted() > provided_tp {
                plan.partitions.into_iter().zip(plan.configs).collect()
            } else {
                vec![(identity, config.clone())]
            }
        } else {
            vec![(identity, config.clone())]
        };
        let mut shards = Vec::with_capacity(placements.len());
        for (ep_map, cfg) in placements {
            let subplat = plat.subset(&ep_map);
            if let Err(e) = cfg.validate(spec.net.len(), &subplat) {
                bail!("serve: tenant {}: invalid replica config: {e}", spec.name);
            }
            if cfg.n_stages() > 64 {
                bail!("serve: at most 64 pipeline stages supported (settle bitmask)");
            }
            let mut dbs = Vec::with_capacity(spec.batch);
            for b in 1..=spec.batch {
                dbs.push(if b == 1 {
                    PerfDb::build(&spec.net, &subplat, &model)
                } else {
                    batch::build_batched(&spec.net, &subplat, &model, b as u32)
                });
            }
            let scratch_db = dbs[spec.batch - 1].clone();
            let weight = simulator::throughput(&spec.net, &subplat, &dbs[0], &cfg);
            let controller =
                AdaptiveController::new(spec.net.clone(), subplat.clone(), model.clone());
            let bounds = cfg.stage_bounds();
            let n_stages = cfg.n_stages();
            let n_sub_eps = subplat.n_eps();
            shards.push(ShardRt {
                initial_config: cfg.clone(),
                config: cfg,
                bounds,
                dbs,
                stages: (0..n_stages).map(|_| StageRt::default()).collect(),
                controller,
                gen: 0,
                frozen_until: 0.0,
                thaw_pending: false,
                ep_slow: vec![1.0; n_sub_eps],
                arena: Vec::with_capacity(spec.queue_capacity + 1),
                free_slots: Vec::new(),
                buf_pool: Vec::new(),
                scratch_db,
                scale_buf: vec![1.0; n_sub_eps],
                weight,
                credit: 0.0,
                state: ReplicaState::Active,
                scale_log: Vec::new(),
                natal_eps: ep_map.clone(),
                home_eps: ep_map.clone(),
                dead: false,
                offered: 0,
                rejected: 0,
                dropped: 0,
                expired: 0,
                cancelled: 0,
                retried: 0,
                hedged: 0,
                hedge_wins: 0,
                completed: 0,
                slo_ok: 0,
                max_queue_len: 0,
                latency: QuantileSketch::new(),
                ep_offered: 0,
                ep_completed: 0,
                ep_slo_ok: 0,
                ep_rejected: 0,
                ep_dropped: 0,
                ep_expired: 0,
                ep_cancelled: 0,
                ep_retried: 0,
                ep_hedged: 0,
                baseline_goodput: 0.0,
                epochs_since_retune: opts.retune_cooldown_epochs,
                retunes: 0,
                retune_trials: 0,
                epochs: Vec::new(),
                subplat,
                ep_map,
            });
        }
        let sampler = spec.arrivals.sampler(master.fork());
        // the hedge delay starts at the policy minimum (at least the SLO
        // budget): the latency sketch is cold until the first epoch
        let lc = TenantLc {
            hedge_delay_s: spec
                .hedge
                .map_or(f64::INFINITY, |h| spec.slo_latency_s.max(h.min_delay_s)),
            ..TenantLc::default()
        };
        rts.push(TenantRt {
            sampler,
            next_id: 0,
            offered: 0,
            rr: 0,
            auto: AutoscaleState::default(),
            n_active: shards.len(),
            load_shed: false,
            repartitions: 0,
            lc,
            shards,
            spec,
        });
    }

    let mut sh = Shared {
        heap: BinaryHeap::new(),
        seq: 0,
        ep_busy: vec![0; plat.n_eps()],
        link_busy: 0,
        contention: opts.contention,
        n_events: 0,
        log_hash: 0xCBF2_9CE4_8422_2325,
        log: Vec::new(),
        record_log: opts.record_log,
        capture,
        obs: None,
        now: 0.0,
        ep_failed: vec![false; plat.n_eps()],
        ep_stall_until: vec![0.0; plat.n_eps()],
        ep_throttle: vec![1.0; plat.n_eps()],
        ep_throttle_until: vec![0.0; plat.n_eps()],
        link_cut_until: 0.0,
        link_throttle: 1.0,
        link_throttle_until: 0.0,
    };
    if want_obs {
        let roster: Vec<(String, usize)> =
            rts.iter().map(|t| (t.spec.name.clone(), t.shards.len())).collect();
        let lifecycle = rts.iter().any(|t| t.spec.lifecycle_active());
        let mut o = Obs::new(plat.n_eps(), &roster, lifecycle);
        // the co-plan decisions pre-date the first event; journal them at
        // t = 0 so the causality timeline starts with the initial
        // allocation (mirrors the Coplan seeds the capture records)
        if let Some(plan) = &cluster_plan {
            for (ti, alloc) in plan.allocations.iter().enumerate() {
                o.journal.push(
                    &ControlRecord {
                        t_s: 0.0,
                        kind: ControlKind::Coplan,
                        tenant: ti as u32,
                        shard: alloc.placements.len() as u32,
                        a: alloc.eps.len() as u64,
                        b: alloc.predicted.to_bits(),
                    },
                    &[
                        ("predicted_throughput", alloc.predicted),
                        ("weight", rts[ti].spec.weight),
                    ],
                );
            }
        }
        sh.obs = Some(Box::new(o));
    }

    // Failover and elastic re-planning share one subset-tuning memo: the
    // second failover onto the same surviving subset — and every elastic
    // re-probe of a budget the loop has already scored — is a cache hit.
    let plan_cache = PlanCache::new();
    // Fault plane: pre-schedule every scripted begin (and, for windowed
    // kinds, end) before the first arrival. An empty script schedules
    // nothing, so fault-free runs keep their exact event sequence numbers
    // and hashes.
    for (ix, fe) in opts.faults.events.iter().enumerate() {
        if fe.t_s <= opts.duration_s {
            sh.schedule(fe.t_s, EvKind::Fault { ix, begin: true });
            if let Some(d) = fe.kind.window_s() {
                let end = fe.t_s + d;
                if end <= opts.duration_s {
                    sh.schedule(end, EvKind::Fault { ix, begin: false });
                }
            }
        }
    }

    for (ti, t) in rts.iter_mut().enumerate() {
        if let Some(first) = t.sampler.next_after(0.0) {
            if first <= opts.duration_s {
                sh.schedule(first, EvKind::Arrival { tenant: ti });
            }
        }
    }
    if opts.control_epoch_s > 0.0 && opts.control_epoch_s <= opts.duration_s {
        sh.schedule(opts.control_epoch_s, EvKind::Epoch);
    }

    let full_rescan = opts.pump == PumpMode::FullRescan;
    let mut elastic_state = ElasticState::default();
    let mut truncated = false;
    let pump_t0 = sh.prof_start();
    while let Some(Reverse(ev)) = sh.heap.pop() {
        sh.n_events += 1;
        if sh.n_events > opts.max_events {
            truncated = true;
            break;
        }
        let now = ev.t;
        sh.now = now;
        match ev.kind {
            EvKind::Arrival { tenant } => {
                let t = &mut rts[tenant];
                let s = t.pick_shard(now);
                let id = t.next_id;
                sh.note(now, 1, pack_ts(tenant, s), id, || {
                    format!("{now:.6} arrival {}#{id}->r{s}", t.spec.name)
                });
                admit_request(t, &mut sh, opts, tenant, s, now, id, 1);
                if let Some(next) = t.sampler.next_after(now) {
                    if next <= opts.duration_s {
                        sh.schedule(next, EvKind::Arrival { tenant });
                    }
                }
                settle(
                    &t.spec,
                    &mut t.shards[s],
                    &mut t.lc,
                    &mut sh,
                    tenant,
                    s,
                    now,
                    opts.duration_s,
                    1,
                    full_rescan,
                );
                reap_hedge_losers(t, &mut sh, tenant, now, opts, full_rescan);
            }
            EvKind::StageDone { tenant, shard, stage, gen } => {
                let t = &mut rts[tenant];
                if gen != t.shards[shard].gen {
                    // the batch was interrupted by a reconfiguration
                    sh.note(now, 2, pack_ts(tenant, shard), stage as u64, || {
                        format!("{now:.6} stale-done {} r{shard}.s{stage}", t.spec.name)
                    });
                    continue;
                }
                sh.note(now, 3, pack_ts(tenant, shard), stage as u64, || {
                    format!("{now:.6} done {} r{shard}.s{stage}", t.spec.name)
                });
                let srt = &mut t.shards[shard];
                if let Some(inf) = srt.stages[stage].busy.as_mut() {
                    if !inf.completed {
                        inf.completed = true;
                        let la = inf.layers_after;
                        let (ep, uses_link, factor) = (inf.ep, inf.uses_link, inf.factor);
                        for &ix in inf.reqs.iter() {
                            srt.arena[ix as usize].layers_done = la;
                        }
                        let gep = srt.ep_map[ep];
                        sh.ep_release(gep, uses_link);
                        srt.ep_slow[ep] =
                            (1.0 - EWMA_GAIN) * srt.ep_slow[ep] + EWMA_GAIN * factor;
                    }
                }
                settle(
                    &t.spec,
                    &mut t.shards[shard],
                    &mut t.lc,
                    &mut sh,
                    tenant,
                    shard,
                    now,
                    opts.duration_s,
                    1u64 << stage,
                    full_rescan,
                );
                reap_hedge_losers(t, &mut sh, tenant, now, opts, full_rescan);
            }
            EvKind::Resume { tenant, shard } => {
                let t = &mut rts[tenant];
                sh.note(now, 4, pack_ts(tenant, shard), 0, || {
                    format!("{now:.6} resume {} r{shard}", t.spec.name)
                });
                settle(
                    &t.spec,
                    &mut t.shards[shard],
                    &mut t.lc,
                    &mut sh,
                    tenant,
                    shard,
                    now,
                    opts.duration_s,
                    u64::MAX,
                    full_rescan,
                );
                reap_hedge_losers(t, &mut sh, tenant, now, opts, full_rescan);
            }
            EvKind::Epoch => {
                sh.note(now, 5, 0, 0, || format!("{now:.6} epoch"));
                for (ti, t) in rts.iter_mut().enumerate() {
                    for si in 0..t.shards.len() {
                        epoch_tick(&t.spec, &mut t.shards[si], &mut sh, ti, si, now, opts);
                        settle(
                            &t.spec,
                            &mut t.shards[si],
                            &mut t.lc,
                            &mut sh,
                            ti,
                            si,
                            now,
                            opts.duration_s,
                            u64::MAX,
                            full_rescan,
                        );
                    }
                    reap_hedge_losers(t, &mut sh, ti, now, opts, full_rescan);
                    // re-derive the hedge-fire delay from the latency the
                    // tenant actually observed: merged across replicas,
                    // read at the policy's quantile, floored by its
                    // minimum, falling back to the SLO budget while cold
                    if let Some(h) = t.spec.hedge {
                        let mut merged = QuantileSketch::new();
                        for srt in &t.shards {
                            merged.merge(&srt.latency);
                        }
                        t.lc.hedge_delay_s = merged
                            .quantile_or(h.quantile, t.spec.slo_latency_s)
                            .max(h.min_delay_s);
                    }
                    // scale decisions run after every replica ticked, so
                    // they see the full epoch observation; transitions
                    // only change routing (and the EP meter), never queue
                    // contents, so no re-settle is needed here
                    if opts.autoscale.enabled && t.shards.len() > 1 {
                        autoscale_tick(t, &mut sh, ti, now, opts);
                    }
                }
                // graceful degradation runs after every tenant ticked so
                // it sees the full epoch's demand picture; it only flips
                // admission flags, never queue contents
                if !opts.faults.is_empty() {
                    degrade_tick(&mut rts, &mut sh, now, opts);
                }
                // the elastic loop runs last: it sees the epoch's full
                // demand picture and the autoscaler's state decisions,
                // and its migrations settle the replicas they touch
                if opts.elastic.enabled {
                    elastic_tick(
                        &mut rts,
                        &mut sh,
                        plat,
                        &mut elastic_state,
                        &plan_cache,
                        opts,
                        now,
                        full_rescan,
                    )?;
                }
                // telemetry sampling runs dead last, after every control
                // loop mutated what it will observe — pure reads, so the
                // simulation cannot see whether it ran
                obs_epoch_sample(&rts, &mut sh, now, plan_cache.stats());
                let next = now + opts.control_epoch_s;
                if next <= opts.duration_s {
                    sh.schedule(next, EvKind::Epoch);
                }
            }
            EvKind::Expire { tenant, id } => {
                // deadline check: reap every copy of `id` still waiting in
                // a queue (expired, tag 9); copies already in service run
                // on. A request that completed earlier simply isn't found
                // — the event is a silent no-op, so stale expiries from
                // freed ids never perturb the hash.
                let t = &mut rts[tenant];
                let retry = t.spec.retry;
                let mut reaped: Option<u32> = None;
                let mut dirty_shards = 0u64;
                for si in 0..t.shards.len() {
                    let srt = &mut t.shards[si];
                    for st_ix in 0..srt.stages.len() {
                        let pos = srt.stages[st_ix]
                            .queue
                            .iter()
                            .position(|&ix| srt.arena[ix as usize].id == id);
                        if let Some(p) = pos {
                            let ix =
                                srt.stages[st_ix].queue.remove(p).expect("position just found");
                            reaped = Some(srt.arena[ix as usize].attempt);
                            srt.expired += 1;
                            srt.ep_expired += 1;
                            srt.free_slots.push(ix);
                            sh.note(now, 9, pack_ts(tenant, si), id, || {
                                format!("{now:.6} expire {}#{id} r{si}", t.spec.name)
                            });
                            dirty_shards |= 1u64 << si;
                            break;
                        }
                    }
                }
                if let Some(attempt) = reaped {
                    // is any copy still being serviced? (hedged pair with
                    // the sibling copy in flight)
                    let mut live_left = false;
                    for srt in &t.shards {
                        for st in &srt.stages {
                            if let Some(inf) = &st.busy {
                                if inf.reqs[inf.taken..]
                                    .iter()
                                    .any(|&ix| srt.arena[ix as usize].id == id)
                                {
                                    live_left = true;
                                }
                            }
                        }
                    }
                    if t.lc.hedges.contains(&id) {
                        unhedge(t, id);
                    }
                    if !live_left {
                        // the logical request is fully gone: give the
                        // retry policy a chance to re-offer it
                        schedule_retry(&mut sh, retry, opts, tenant, id, attempt, now);
                    }
                    // a reaped queue slot can unblock an upstream delivery
                    for si in 0..t.shards.len() {
                        if dirty_shards & (1u64 << si) != 0 {
                            settle(
                                &t.spec,
                                &mut t.shards[si],
                                &mut t.lc,
                                &mut sh,
                                tenant,
                                si,
                                now,
                                opts.duration_s,
                                u64::MAX,
                                full_rescan,
                            );
                        }
                    }
                    reap_hedge_losers(t, &mut sh, tenant, now, opts, full_rescan);
                }
            }
            EvKind::Retry { tenant, attempt } => {
                // a backed-off re-arrival: a fresh id through the normal
                // front door (which may retry again, up to the budget)
                let t = &mut rts[tenant];
                let s = t.pick_shard(now);
                let id = t.next_id;
                sh.note(now, 10, pack_ts(tenant, s) | (u64::from(attempt) << 32), id, || {
                    format!("{now:.6} retry#{attempt} {}#{id}->r{s}", t.spec.name)
                });
                t.shards[s].retried += 1;
                t.shards[s].ep_retried += 1;
                admit_request(t, &mut sh, opts, tenant, s, now, id, attempt);
                settle(
                    &t.spec,
                    &mut t.shards[s],
                    &mut t.lc,
                    &mut sh,
                    tenant,
                    s,
                    now,
                    opts.duration_s,
                    1,
                    full_rescan,
                );
                reap_hedge_losers(t, &mut sh, tenant, now, opts, full_rescan);
            }
            EvKind::Hedge { tenant, id } => {
                // hedge check: fires once per admitted request, one hedge
                // delay after admission. Only a request still waiting in
                // an *entry* queue is a straggler worth duplicating —
                // anything in service or further down the pipeline is
                // making progress.
                let t = &mut rts[tenant];
                let mut primary: Option<(usize, usize)> = None;
                for (si, srt) in t.shards.iter().enumerate() {
                    if let Some(p) = srt.stages[0]
                        .queue
                        .iter()
                        .position(|&ix| srt.arena[ix as usize].id == id)
                    {
                        primary = Some((si, p));
                        break;
                    }
                }
                let Some((ps, pp)) = primary else { continue };
                if t.lc.hedges.contains(&id) {
                    continue;
                }
                // least-loaded live sibling with entry-queue room — a
                // hedge never evicts or displaces real work
                let cap = t.spec.queue_capacity;
                let candidates: Vec<(usize, u64)> = t
                    .shards
                    .iter()
                    .enumerate()
                    .filter(|&(i, srt)| {
                        i != ps
                            && srt.state == ReplicaState::Active
                            && !srt.dead
                            && now >= srt.frozen_until
                            && srt.stages[0].queue.len() < cap
                    })
                    .map(|(i, srt)| (i, srt.backlog()))
                    .collect();
                let Some(sib) = shard::hedge_sibling(ps, &candidates) else { continue };
                sh.note(now, 11, pack_ts(tenant, sib), id, || {
                    format!("{now:.6} hedge {}#{id} r{ps}->r{sib}", t.spec.name)
                });
                let (arrival_s, attempt) = {
                    let srt = &mut t.shards[ps];
                    let ix = srt.stages[0].queue[pp];
                    let r = &mut srt.arena[ix as usize];
                    r.hedged = true;
                    (r.arrival_s, r.attempt)
                };
                t.lc.hedges.insert(id);
                // the twin is one more offered entry on the sibling; it
                // keeps the primary's arrival time so whichever copy wins
                // reports the request's true latency
                t.offered += 1;
                sh.obs_admit(tenant, obs::ADM_ADMIT);
                let wtp = t.spec.balancer == BalancerPolicy::WeightedThroughput;
                let dst = &mut t.shards[sib];
                dst.offered += 1;
                dst.ep_offered += 1;
                dst.hedged += 1;
                dst.ep_hedged += 1;
                let jx = dst.alloc(id, arrival_s);
                {
                    let r = &mut dst.arena[jx as usize];
                    r.attempt = attempt;
                    r.hedged = true;
                    r.twin = true;
                }
                dst.stages[0].queue.push_back(jx);
                let l = dst.stages[0].queue.len();
                if l > dst.max_queue_len {
                    dst.max_queue_len = l;
                }
                if wtp {
                    // the twin bypassed the balancer: charge the sibling
                    // one smooth-WRR credit (refunded if the twin is
                    // reaped unserved)
                    dst.credit -= dst.weight;
                }
                let delay = t.lc.hedge_delay_s;
                sh.control(
                    ControlRecord {
                        t_s: now,
                        kind: ControlKind::Hedge,
                        tenant: tenant as u32,
                        shard: sib as u32,
                        a: ps as u64,
                        b: id,
                    },
                    &[
                        ("hedge_delay_s", delay),
                        ("sibling_backlog", t.shards[sib].backlog() as f64),
                    ],
                );
                settle(
                    &t.spec,
                    &mut t.shards[sib],
                    &mut t.lc,
                    &mut sh,
                    tenant,
                    sib,
                    now,
                    opts.duration_s,
                    1,
                    full_rescan,
                );
                reap_hedge_losers(t, &mut sh, tenant, now, opts, full_rescan);
            }
            EvKind::Fault { ix, begin } => {
                let fe = opts.faults.events[ix];
                let code = u64::from(fe.kind.code());
                sh.note(now, 7, ((ix as u64) << 8) | code, u64::from(begin), || {
                    format!(
                        "{now:.6} fault {} #{ix} {}",
                        if begin { "begin" } else { "end" },
                        fe.kind.name()
                    )
                });
                sh.control(
                    ControlRecord {
                        t_s: now,
                        kind: ControlKind::Fault,
                        tenant: 0,
                        shard: ix as u32,
                        a: code,
                        b: u64::from(begin),
                    },
                    &[
                        ("begin", f64::from(u8::from(begin))),
                        ("window_s", fe.kind.window_s().unwrap_or(f64::INFINITY)),
                    ],
                );
                if begin {
                    // apply the fault state, then fail affected replicas
                    // over when the fault takes EPs down
                    let mut downed = false;
                    match fe.kind {
                        FaultKind::EpFail { ep } => {
                            sh.ep_failed[ep] = true;
                            downed = true;
                        }
                        FaultKind::ChipFail { chiplet } => {
                            for (e, place) in plat.eps.iter().enumerate() {
                                if place.chiplet == chiplet {
                                    sh.ep_failed[e] = true;
                                }
                            }
                            downed = true;
                        }
                        FaultKind::EpStall { ep, down_s } => {
                            sh.ep_stall_until[ep] = now + down_s;
                            downed = true;
                        }
                        FaultKind::EpSlow { ep, factor, down_s } => {
                            sh.ep_throttle[ep] = factor;
                            sh.ep_throttle_until[ep] = now + down_s;
                        }
                        FaultKind::LinkSlow { factor, down_s } => {
                            sh.link_throttle = factor;
                            sh.link_throttle_until = now + down_s;
                        }
                        FaultKind::LinkCut { down_s } => {
                            sh.link_cut_until = now + down_s;
                        }
                    }
                    if downed {
                        fault_failover(
                            &mut rts, &mut sh, plat, &plan_cache, opts, now, full_rescan,
                        )?;
                    }
                } else {
                    match fe.kind {
                        FaultKind::EpStall { ep, .. } => {
                            // the stalled EP is back: re-adopt it
                            fault_recover(
                                &mut rts, &mut sh, plat, &plan_cache, opts, now, &[ep],
                            )?;
                        }
                        FaultKind::LinkCut { .. } => {
                            // transfers blocked during the cut can go again
                            for (ti, t) in rts.iter_mut().enumerate() {
                                for si in 0..t.shards.len() {
                                    settle(
                                        &t.spec,
                                        &mut t.shards[si],
                                        &mut t.lc,
                                        &mut sh,
                                        ti,
                                        si,
                                        now,
                                        opts.duration_s,
                                        u64::MAX,
                                        full_rescan,
                                    );
                                }
                                reap_hedge_losers(t, &mut sh, ti, now, opts, full_rescan);
                            }
                        }
                        // slowdown windows never blocked dispatch, so
                        // their ends need no settling
                        _ => {}
                    }
                }
            }
        }
    }

    sh.prof_end(Span::Pump, pump_t0);

    let capture = sh.capture.take();
    let obs_report = sh.obs.take().map(|o| o.finish(plan_cache.stats()));
    let tenants = rts.into_iter().map(tenant_report).collect();
    let report = ServeReport {
        duration_s: opts.duration_s,
        tenants,
        n_events: sh.n_events,
        log_hash: sh.log_hash,
        event_log: sh.log,
        truncated,
        plan_cache: plan_cache.stats(),
    };
    Ok((report, capture, obs_report))
}

/// Sample the telemetry registry into one [`EpochSample`]: flush the
/// utilization meters over the window that just closed and snapshot every
/// tenant and replica. Pure reads of the runtime state — a no-op when the
/// observer is off, and invisible to the simulation either way.
fn obs_epoch_sample(rts: &[TenantRt], sh: &mut Shared, now: f64, cache: CacheStats) {
    let Some(mut o) = sh.obs.take() else { return };
    let t0 = Prof::start();
    let (eps, link) = o.util.flush(now, &sh.ep_busy, sh.link_busy);
    let mut tenants = Vec::with_capacity(rts.len());
    for (ti, t) in rts.iter().enumerate() {
        let mut ts = TenantSample {
            offered: 0,
            completed: 0,
            slo_ok: 0,
            rejected: 0,
            dropped: 0,
            expired: 0,
            cancelled: 0,
            retried: 0,
            hedged: 0,
            goodput: 0.0,
            throughput: 0.0,
            backlog: 0,
            load_shed: t.load_shed,
            replicas: Vec::with_capacity(t.shards.len()),
        };
        for (si, srt) in t.shards.iter().enumerate() {
            if let Some(e) = srt.epochs.last() {
                ts.offered += e.offered;
                ts.completed += e.completed;
                ts.slo_ok += e.slo_ok;
                ts.rejected += e.rejected;
                ts.dropped += e.dropped;
                ts.expired += e.expired;
                ts.cancelled += e.cancelled;
                ts.retried += e.retried;
                ts.hedged += e.hedged;
                ts.goodput += e.goodput;
                ts.throughput += e.throughput;
                ts.backlog += e.backlog;
            }
            ts.replicas.push(ReplicaSample {
                state: srt.state.name(),
                dead: srt.dead,
                eps: srt.ep_map.len() as u64,
                queued: srt.queued(),
                stage_queue_hw: o.take_queue_hw(ti, si),
                slab_live: (srt.arena.len() - srt.free_slots.len()) as u64,
                slab_cap: srt.arena.len() as u64,
                retuned: srt.epochs.last().is_some_and(|e| e.retuned),
            });
        }
        tenants.push(ts);
    }
    o.push_sample(EpochSample { t_s: now, n_events: sh.n_events, cache, eps, link, tenants });
    o.prof.add(Span::Sample, t0);
    sh.obs = Some(o);
}

/// Fold a tenant runtime into its report: per-replica reports (configs
/// translated to global EP ids) plus tenant-level aggregates, including a
/// merged latency sketch and a per-epoch series summed across replicas
/// (every replica ticks at every epoch, so the series zip exactly).
fn tenant_report(t: TenantRt) -> TenantReport {
    let TenantRt { spec, shards, offered, repartitions, .. } = t;
    let mut shard_reports: Vec<ShardReport> = Vec::with_capacity(shards.len());
    let mut latency = QuantileSketch::new();
    for s in shards {
        let in_flight = s.backlog();
        latency.merge(&s.latency);
        shard_reports.push(ShardReport {
            // the initial config is local to the *planned-at-start*
            // subset; failover re-plans move `ep_map` and elastic
            // re-partitions move `home_eps` too, so translate through the
            // immutable natal set it was planned against
            initial_config: shard::to_global(&s.initial_config, &s.natal_eps),
            final_config: shard::to_global(&s.config, &s.ep_map),
            predicted_throughput: s.weight,
            offered: s.offered,
            rejected: s.rejected,
            dropped: s.dropped,
            expired: s.expired,
            cancelled: s.cancelled,
            retried: s.retried,
            hedged: s.hedged,
            hedge_wins: s.hedge_wins,
            completed: s.completed,
            slo_ok: s.slo_ok,
            in_flight,
            max_queue_len: s.max_queue_len,
            arena_peak: s.arena.len(),
            retunes: s.retunes,
            retune_trials: s.retune_trials,
            latency: s.latency,
            epochs: s.epochs,
            scale_events: s.scale_log,
            final_state: s.state,
            eps: s.ep_map,
        });
    }
    let n_epochs = shard_reports.first().map_or(0, |s| s.epochs.len());
    debug_assert!(
        shard_reports.iter().all(|s| s.epochs.len() == n_epochs),
        "replicas tick in lockstep"
    );
    let mut epochs = Vec::with_capacity(n_epochs);
    for e in 0..n_epochs {
        let mut agg = EpochStats {
            end_s: shard_reports[0].epochs[e].end_s,
            offered: 0,
            completed: 0,
            slo_ok: 0,
            rejected: 0,
            dropped: 0,
            expired: 0,
            cancelled: 0,
            retried: 0,
            hedged: 0,
            goodput: 0.0,
            throughput: 0.0,
            backlog: 0,
            retuned: false,
            retune_trials: 0,
            active_eps: 0,
        };
        for sr in &shard_reports {
            let ep = &sr.epochs[e];
            agg.offered += ep.offered;
            agg.completed += ep.completed;
            agg.slo_ok += ep.slo_ok;
            agg.rejected += ep.rejected;
            agg.dropped += ep.dropped;
            agg.expired += ep.expired;
            agg.cancelled += ep.cancelled;
            agg.retried += ep.retried;
            agg.hedged += ep.hedged;
            agg.goodput += ep.goodput;
            agg.throughput += ep.throughput;
            agg.backlog += ep.backlog;
            agg.retuned |= ep.retuned;
            agg.retune_trials += ep.retune_trials;
            agg.active_eps += ep.active_eps;
        }
        epochs.push(agg);
    }
    TenantReport {
        name: spec.name,
        initial_config: shard_reports[0].initial_config.clone(),
        final_config: shard_reports[0].final_config.clone(),
        offered,
        rejected: shard_reports.iter().map(|s| s.rejected).sum(),
        dropped: shard_reports.iter().map(|s| s.dropped).sum(),
        expired: shard_reports.iter().map(|s| s.expired).sum(),
        cancelled: shard_reports.iter().map(|s| s.cancelled).sum(),
        retried: shard_reports.iter().map(|s| s.retried).sum(),
        hedged: shard_reports.iter().map(|s| s.hedged).sum(),
        hedge_wins: shard_reports.iter().map(|s| s.hedge_wins).sum(),
        completed: shard_reports.iter().map(|s| s.completed).sum(),
        slo_ok: shard_reports.iter().map(|s| s.slo_ok).sum(),
        in_flight: shard_reports.iter().map(|s| s.in_flight).sum(),
        max_queue_len: shard_reports.iter().map(|s| s.max_queue_len).max().unwrap_or(0),
        arena_peak: shard_reports.iter().map(|s| s.arena_peak).sum(),
        latency,
        epochs,
        retunes: shard_reports.iter().map(|s| s.retunes).sum(),
        retune_trials: shard_reports.iter().map(|s| s.retune_trials).sum(),
        repartitions,
        shards: shard_reports,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::networks;
    use crate::pipeline::simulator;
    use crate::serve::arrivals::ArrivalProcess;

    /// synthnet_small split across the two EP classes of C1.
    fn small_tenant(name: &str, rate: f64) -> (TenantSpec, PipelineConfig) {
        let net = networks::synthnet_small();
        let cfg = PipelineConfig::new(vec![3, 3], vec![0, 1]);
        let spec = TenantSpec::new(name, net, ArrivalProcess::Poisson { rate });
        (spec, cfg)
    }

    fn capacity(spec: &TenantSpec, plat: &Platform, cfg: &PipelineConfig) -> f64 {
        let db = PerfDb::build(&spec.net, plat, &CostModel::default());
        simulator::throughput(&spec.net, plat, &db, cfg)
    }

    fn base_opts(duration_s: f64) -> ServeOptions {
        ServeOptions { duration_s, control: false, control_epoch_s: 0.0, ..Default::default() }
    }

    #[test]
    fn zero_rate_serves_nothing() {
        let plat = crate::platform::configs::c1();
        let (spec, cfg) = small_tenant("idle", 0.0);
        let report = serve(&plat, vec![(spec, cfg)], &base_opts(1.0)).unwrap();
        let t = &report.tenants[0];
        assert_eq!(t.offered, 0);
        assert_eq!(t.completed, 0);
        assert!(t.conserved());
    }

    #[test]
    fn underload_completes_everything_and_conserves() {
        let plat = crate::platform::configs::c1();
        let (spec, cfg) = small_tenant("t0", 0.0);
        let cap = capacity(&spec, &plat, &cfg);
        let (spec, cfg) = small_tenant("t0", 0.3 * cap);
        let spec = spec.with_slo(100.0 / cap);
        let dur = 200.0 / cap;
        let report = serve(&plat, vec![(spec, cfg)], &base_opts(dur)).unwrap();
        let t = &report.tenants[0];
        assert!(t.offered > 20, "expected real traffic, got {}", t.offered);
        assert!(t.conserved(), "conservation: {t:?}");
        assert_eq!(t.rejected + t.dropped, 0, "underload must not shed load");
        assert!(t.completed as f64 >= 0.8 * t.offered as f64);
        assert_eq!(t.slo_ok, t.completed, "generous SLO: everything on time");
        assert!(t.latency.p50() > 0.0);
        assert!(t.latency.p99() >= t.latency.p50());
    }

    #[test]
    fn overload_sheds_load_but_conserves() {
        let plat = crate::platform::configs::c1();
        let (probe, cfg) = small_tenant("x", 0.0);
        let cap = capacity(&probe, &plat, &cfg);
        for policy in [AdmissionPolicy::Reject, AdmissionPolicy::DropOldest] {
            let (spec, cfg) = small_tenant("t0", 4.0 * cap);
            let spec = spec.with_queue_capacity(16).with_admission(policy);
            let dur = 300.0 / cap;
            let report = serve(&plat, vec![(spec, cfg)], &base_opts(dur)).unwrap();
            let t = &report.tenants[0];
            assert!(t.conserved(), "conservation under {policy:?}: {t:?}");
            assert!(t.rejected + t.dropped > 0, "overload must shed load ({policy:?})");
            assert!(t.completed > 0);
            match policy {
                AdmissionPolicy::Reject => assert_eq!(t.dropped, 0),
                AdmissionPolicy::DropOldest => assert_eq!(t.rejected, 0),
            }
        }
    }

    #[test]
    fn queue_bound_respected_without_reconfig() {
        let plat = crate::platform::configs::c1();
        let (probe, cfg) = small_tenant("x", 0.0);
        let cap = capacity(&probe, &plat, &cfg);
        let (spec, cfg) = small_tenant("t0", 5.0 * cap);
        let spec = spec.with_queue_capacity(7);
        let report = serve(&plat, vec![(spec, cfg)], &base_opts(200.0 / cap)).unwrap();
        let t = &report.tenants[0];
        assert!(t.max_queue_len <= 7, "queue bound violated: {}", t.max_queue_len);
        assert!(t.conserved());
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let plat = crate::platform::configs::c2();
        let run = |seed: u64| {
            let (probe, cfg) = small_tenant("x", 0.0);
            let cap = capacity(&probe, &plat, &cfg);
            let (a, ca) = small_tenant("a", 0.8 * cap);
            let b_net = networks::synthnet_small();
            let b_spec = TenantSpec::new(
                "b",
                b_net,
                ArrivalProcess::Mmpp {
                    low_rate: 0.1 * cap,
                    high_rate: 1.5 * cap,
                    mean_low_s: 20.0 / cap,
                    mean_high_s: 10.0 / cap,
                },
            );
            let cb = PipelineConfig::new(vec![3, 3], vec![2, 3]);
            let mut opts = base_opts(300.0 / cap);
            opts.seed = seed;
            opts.record_log = true;
            serve(&plat, vec![(a, ca), (b_spec, cb)], &opts).unwrap()
        };
        let r1 = run(9);
        let r2 = run(9);
        assert_eq!(r1.log_hash, r2.log_hash, "event streams must be identical");
        assert_eq!(r1.event_log, r2.event_log);
        assert_eq!(r1.n_events, r2.n_events);
        for (a, b) in r1.tenants.iter().zip(&r2.tenants) {
            assert_eq!(a.offered, b.offered);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.latency.p99(), b.latency.p99());
        }
        let r3 = run(10);
        assert_ne!(r1.log_hash, r3.log_hash, "different seeds should differ");
    }

    #[test]
    fn event_driven_settle_matches_full_rescan() {
        // The event-driven worklist must reproduce the whole-pipeline
        // rescan bit-for-bit: contention, batching and backpressure all on.
        let plat = crate::platform::configs::c2();
        let (probe, cfg) = small_tenant("x", 0.0);
        let cap = capacity(&probe, &plat, &cfg);
        let run = |pump: PumpMode| {
            let (a, ca) = small_tenant("a", 2.0 * cap);
            let a = a.with_batch(3).with_queue_capacity(9);
            let (b, cb) = small_tenant("b", 0.7 * cap);
            let mut opts = base_opts(250.0 / cap);
            opts.pump = pump;
            opts.record_log = true;
            serve(&plat, vec![(a, ca), (b, cb)], &opts).unwrap()
        };
        let ev = run(PumpMode::EventDriven);
        let fr = run(PumpMode::FullRescan);
        assert_eq!(ev.log_hash, fr.log_hash, "event streams must be identical");
        assert_eq!(ev.event_log, fr.event_log);
        assert_eq!(ev.n_events, fr.n_events);
        for (x, y) in ev.tenants.iter().zip(&fr.tenants) {
            assert_eq!(x.offered, y.offered);
            assert_eq!(x.completed, y.completed);
            assert_eq!(x.slo_ok, y.slo_ok);
            assert_eq!(x.dropped, y.dropped);
            assert_eq!(x.rejected, y.rejected);
            assert_eq!(x.max_queue_len, y.max_queue_len);
            assert_eq!(x.latency.p99(), y.latency.p99());
        }
    }

    #[test]
    fn contention_halves_co_located_tenants() {
        let plat = crate::platform::configs::c1();
        let net = networks::synthnet_small();
        let cfg = PipelineConfig::single_stage(net.len(), 0);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        let dur = 400.0 / cap;
        let mk = |name: &str| {
            (
                TenantSpec::new(name, net.clone(), ArrivalProcess::Poisson { rate: 3.0 * cap })
                    .with_queue_capacity(16),
                cfg.clone(),
            )
        };
        let solo = serve(&plat, vec![mk("solo")], &base_opts(dur)).unwrap();
        let duo = serve(&plat, vec![mk("a"), mk("b")], &base_opts(dur)).unwrap();
        let c_solo = solo.tenants[0].completed as f64;
        let c_a = duo.tenants[0].completed as f64;
        let c_b = duo.tenants[1].completed as f64;
        assert!(
            c_a < 0.75 * c_solo && c_b < 0.75 * c_solo,
            "time-slicing must slow co-located tenants: solo {c_solo}, duo {c_a}/{c_b}"
        );
        assert!(
            (c_a + c_b) < 1.3 * c_solo,
            "shared EP cannot serve much more than its capacity"
        );
        for t in &duo.tenants {
            assert!(t.conserved());
        }
    }

    #[test]
    fn batching_reduces_event_count() {
        let plat = crate::platform::configs::c1();
        let (probe, cfg) = small_tenant("x", 0.0);
        let cap = capacity(&probe, &plat, &cfg);
        let run = |batch: usize| {
            let (spec, cfg) = small_tenant("t0", 2.0 * cap);
            let spec = spec.with_batch(batch).with_queue_capacity(64);
            serve(&plat, vec![(spec, cfg)], &base_opts(300.0 / cap)).unwrap()
        };
        let b1 = run(1);
        let b8 = run(8);
        assert!(b1.tenants[0].conserved());
        assert!(b8.tenants[0].conserved());
        assert!(b8.tenants[0].completed > 0);
        assert!(
            b8.n_events < b1.n_events,
            "batching must amortise events: {} vs {}",
            b8.n_events,
            b1.n_events
        );
        // batch-aware service amortises overhead: more goodput under load
        assert!(
            b8.tenants[0].completed as f64 > 0.8 * b1.tenants[0].completed as f64,
            "batched run should not collapse: {} vs {}",
            b8.tenants[0].completed,
            b1.tenants[0].completed
        );
    }

    #[test]
    fn arena_recycles_slots_under_sustained_load() {
        // The slab must stay bounded by the live-request watermark, not by
        // the offered-request count: completed slots are reused.
        let plat = crate::platform::configs::c1();
        let (probe, cfg) = small_tenant("x", 0.0);
        let cap = capacity(&probe, &plat, &cfg);
        let (spec, cfg) = small_tenant("t0", 0.5 * cap);
        let spec = spec.with_queue_capacity(8);
        let report = serve(&plat, vec![(spec, cfg)], &base_opts(500.0 / cap)).unwrap();
        let t = &report.tenants[0];
        assert!(t.offered > 100, "need sustained traffic, got {}", t.offered);
        assert!(t.conserved());
        // watermark bound: each of the 2 stages can hold at most 8 queued
        // requests plus one in-service batch (batch = 1); without slot
        // recycling the slab would instead grow to ~offered entries
        let watermark = 2 * (8 + 1);
        assert!(
            t.offered > 2 * watermark as u64,
            "scenario must offer well beyond the watermark"
        );
        assert!(
            t.arena_peak <= watermark,
            "slab must recycle slots: peak {} vs watermark {watermark} ({} offered)",
            t.arena_peak,
            t.offered
        );
    }

    #[test]
    fn epochs_recorded_when_enabled() {
        let plat = crate::platform::configs::c1();
        let (probe, cfg) = small_tenant("x", 0.0);
        let cap = capacity(&probe, &plat, &cfg);
        let (spec, cfg) = small_tenant("t0", 0.5 * cap);
        let mut opts = base_opts(100.0 / cap);
        opts.control_epoch_s = 20.0 / cap;
        let report = serve(&plat, vec![(spec, cfg)], &opts).unwrap();
        let t = &report.tenants[0];
        // 100/20 = 5 ticks, minus possibly one to floating-point accumulation
        assert!((4..=5).contains(&t.epochs.len()), "epochs {}", t.epochs.len());
        let total: u64 = t.epochs.iter().map(|e| e.offered).sum();
        assert!(total <= t.offered);
        assert!(t.epochs.iter().all(|e| !e.retuned), "control disabled");
    }

    #[test]
    fn rejects_invalid_inputs() {
        let plat = crate::platform::configs::c1();
        assert!(serve(&plat, vec![], &ServeOptions::default()).is_err());
        let (spec, cfg) = small_tenant("t0", 1.0);
        let opts = ServeOptions { duration_s: 0.0, ..Default::default() };
        assert!(serve(&plat, vec![(spec, cfg)], &opts).is_err());
        let (spec, _) = small_tenant("t0", 1.0);
        let bad = PipelineConfig::new(vec![2], vec![0]);
        assert!(serve(&plat, vec![(spec, bad)], &ServeOptions::default()).is_err());
        let (spec, cfg) = small_tenant("t0", 1.0);
        assert!(serve(&plat, vec![(spec.with_shards(0), cfg)], &ServeOptions::default()).is_err());
    }

    // --- sharding ---------------------------------------------------------

    /// SynthNet on C5: the fixture where replication provably beats one
    /// pipeline (the bottleneck layer caps any single pipeline at ~1/63ms
    /// while 4× (1 FEP + 1 SEP) replicas total ~10% more capacity).
    fn sharded_tenant(
        rate_factor: f64,
        shards: usize,
        balancer: BalancerPolicy,
    ) -> (Platform, TenantSpec, PipelineConfig, f64) {
        let plat = crate::platform::configs::c5();
        let net = networks::synthnet();
        let cfg = crate::serve::shisha_config(&net, &plat);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        let spec = TenantSpec::new("sharded", net, ArrivalProcess::Poisson {
            rate: rate_factor * cap,
        })
        .with_shards(shards)
        .with_balancer(balancer)
        .with_queue_capacity(16)
        .with_admission(AdmissionPolicy::DropOldest)
        .with_slo(200.0 / cap);
        (plat, spec, cfg, cap)
    }

    #[test]
    fn sharded_tenant_conserves_and_replicas_are_disjoint() {
        let (plat, spec, cfg, cap) = sharded_tenant(2.0, 2, BalancerPolicy::RoundRobin);
        let report = serve(&plat, vec![(spec, cfg)], &base_opts(200.0 / cap)).unwrap();
        let t = &report.tenants[0];
        assert!(t.conserved(), "conservation: {t:?}");
        assert!(t.completed > 0);
        assert_eq!(t.shards.len(), 2, "C5 SynthNet must actually replicate");
        // replicas own disjoint EP subsets
        let mut seen = vec![false; plat.n_eps()];
        for s in &t.shards {
            assert!(!s.eps.is_empty());
            for &e in &s.eps {
                assert!(e < plat.n_eps());
                assert!(!seen[e], "EP {e} owned by two replicas");
                seen[e] = true;
            }
            // global configs stay inside the replica's subset
            for ep in &s.final_config.assignment {
                assert!(s.eps.contains(ep), "final config escaped its subset");
            }
        }
        // replica counters sum to the tenant aggregates
        assert_eq!(t.offered, t.shards.iter().map(|s| s.offered).sum::<u64>());
        assert_eq!(t.completed, t.shards.iter().map(|s| s.completed).sum::<u64>());
        assert_eq!(t.slo_ok, t.shards.iter().map(|s| s.slo_ok).sum::<u64>());
        assert_eq!(
            t.in_flight,
            t.shards.iter().map(|s| s.in_flight).sum::<u64>()
        );
    }

    #[test]
    fn unsharded_tenant_reports_single_identity_replica() {
        let plat = crate::platform::configs::c1();
        let (spec, cfg) = small_tenant("t0", 0.0);
        let cap = capacity(&spec, &plat, &cfg);
        let (spec, cfg) = small_tenant("t0", 0.4 * cap);
        let report = serve(&plat, vec![(spec, cfg.clone())], &base_opts(100.0 / cap)).unwrap();
        let t = &report.tenants[0];
        assert_eq!(t.shards.len(), 1);
        let s = &t.shards[0];
        assert_eq!(s.eps, (0..plat.n_eps()).collect::<Vec<_>>());
        assert_eq!(s.initial_config, cfg, "identity map keeps global ids");
        assert_eq!(t.final_config, s.final_config);
        assert_eq!(t.offered, s.offered);
    }

    #[test]
    fn balancers_split_load_and_stay_deterministic() {
        for policy in [
            BalancerPolicy::RoundRobin,
            BalancerPolicy::JoinShortestQueue,
            BalancerPolicy::WeightedThroughput,
        ] {
            let run = || {
                let (plat, spec, cfg, cap) = sharded_tenant(1.5, 2, policy);
                let mut opts = base_opts(150.0 / cap);
                opts.record_log = true;
                serve(&plat, vec![(spec, cfg)], &opts).unwrap()
            };
            let a = run();
            let b = run();
            assert_eq!(a.log_hash, b.log_hash, "{policy:?}: nondeterministic");
            assert_eq!(a.event_log, b.event_log, "{policy:?}");
            let t = &a.tenants[0];
            assert!(t.conserved());
            for s in &t.shards {
                assert!(
                    s.offered > t.offered / 5,
                    "{policy:?}: replica starved ({} of {})",
                    s.offered,
                    t.offered
                );
            }
            if policy == BalancerPolicy::RoundRobin {
                let diff =
                    t.shards[0].offered.abs_diff(t.shards[1].offered);
                assert!(diff <= 1, "round-robin alternates exactly: {diff}");
            }
        }
    }

    #[test]
    fn sharded_plan_never_predicts_below_provided_config() {
        // Whether or not the planner replicates on this small fixture,
        // the served deployment's total predicted throughput must be at
        // least the analytic throughput of the caller's configuration
        // (the provided-config baseline of the placement decision).
        let plat = crate::platform::configs::c1();
        let (probe, cfg) = small_tenant("x", 0.0);
        let cap = capacity(&probe, &plat, &cfg);
        let (spec, cfg) = small_tenant("t0", 0.5 * cap);
        let spec = spec.with_shards(2);
        let report = serve(&plat, vec![(spec, cfg)], &base_opts(60.0 / cap)).unwrap();
        let t = &report.tenants[0];
        let total: f64 = t.shards.iter().map(|s| s.predicted_throughput).sum();
        assert!(
            total >= cap * (1.0 - 1e-12),
            "deployment predicts {total}, below the provided config's {cap}"
        );
        assert!(t.conserved());
    }

    // --- cluster: co-planning + autoscaling -------------------------------

    #[test]
    fn autoscale_requires_epochs() {
        let plat = crate::platform::configs::c1();
        let (spec, cfg) = small_tenant("t0", 1.0);
        let mut opts = base_opts(1.0); // control_epoch_s == 0
        opts.autoscale.enabled = true;
        assert!(serve(&plat, vec![(spec, cfg)], &opts).is_err());
    }

    #[test]
    fn autoscale_disabled_keeps_all_replicas_active() {
        let (plat, spec, cfg, cap) = sharded_tenant(1.5, 2, BalancerPolicy::RoundRobin);
        let mut opts = base_opts(100.0 / cap);
        opts.control_epoch_s = 10.0 / cap;
        let report = serve(&plat, vec![(spec, cfg)], &opts).unwrap();
        let t = &report.tenants[0];
        assert_eq!(t.shards.len(), 2);
        for s in &t.shards {
            assert!(s.scale_events.is_empty(), "no scale events without autoscaling");
            assert_eq!(s.final_state, ReplicaState::Active);
            assert!(
                s.epochs.iter().all(|e| e.active_eps == s.eps.len() as u64),
                "static replicas hold their EPs every epoch"
            );
        }
        assert_eq!(
            t.ep_epochs(),
            t.epochs.len() as u64 * plat.n_eps() as u64,
            "static deployment pays the full EP-epoch meter"
        );
    }

    #[test]
    fn autoscale_parks_idle_replicas_and_conserves() {
        // tidal MMPP on the C5 fixture: the low phase (well under one
        // replica's capacity) lets the autoscaler drain + park replicas,
        // the burst re-activates them; requests are conserved throughout
        let plat = crate::platform::configs::c5();
        let net = networks::synthnet();
        let cfg = crate::serve::shisha_config(&net, &plat);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        let spec = TenantSpec::new(
            "tidal",
            net,
            ArrivalProcess::Mmpp {
                low_rate: 0.2 * cap,
                high_rate: 1.3 * cap,
                mean_low_s: 100.0 / cap,
                mean_high_s: 100.0 / cap,
            },
        )
        .with_shards(4)
        .with_balancer(BalancerPolicy::JoinShortestQueue)
        .with_queue_capacity(32)
        .with_admission(AdmissionPolicy::DropOldest)
        .with_slo(500.0 / cap);
        let mut opts = base_opts(400.0 / cap);
        opts.control_epoch_s = 4.0 / cap;
        opts.autoscale.enabled = true;
        let report = serve(&plat, vec![(spec, cfg)], &opts).unwrap();
        let t = &report.tenants[0];
        assert!(t.conserved(), "conservation across scale transitions: {t:?}");
        assert!(
            t.epoch_conserved(),
            "per-epoch flow identity across scale transitions: {:?}",
            t.epochs
        );
        assert!(t.shards.len() > 1, "fixture must replicate");
        let events: usize = t.shards.iter().map(|s| s.scale_events.len()).sum();
        assert!(events > 0, "the tidal load must trigger scale events");
        assert!(
            t.epochs.iter().any(|e| e.active_eps < plat.n_eps() as u64),
            "some epoch must run with parked replicas: {:?}",
            t.epochs.iter().map(|e| e.active_eps).collect::<Vec<_>>()
        );
        assert!(
            t.ep_epochs() < t.epochs.len() as u64 * plat.n_eps() as u64,
            "autoscaling must save EP-epochs over always-on"
        );
        // replica counters still sum to the tenant aggregates
        assert_eq!(t.offered, t.shards.iter().map(|s| s.offered).sum::<u64>());
        assert_eq!(t.completed, t.shards.iter().map(|s| s.completed).sum::<u64>());
    }

    #[test]
    fn coplan_serves_tenants_on_disjoint_eps() {
        let plat = crate::platform::configs::c2();
        let net_a = networks::synthnet();
        let net_b = networks::synthnet_small();
        let cfg_a = crate::serve::shisha_config(&net_a, &plat);
        let cfg_b = crate::serve::shisha_config(&net_b, &plat);
        let db = PerfDb::build(&net_a, &plat, &CostModel::default());
        let cap = simulator::throughput(&net_a, &plat, &db, &cfg_a);
        let a = TenantSpec::new("a", net_a, ArrivalProcess::Poisson { rate: 0.4 * cap })
            .with_weight(2.0)
            .with_shards(2);
        let b = TenantSpec::new("b", net_b, ArrivalProcess::Poisson { rate: 0.4 * cap });
        let mut opts = base_opts(60.0 / cap);
        opts.coplan = true;
        let report = serve(&plat, vec![(a, cfg_a), (b, cfg_b)], &opts).unwrap();
        // all replica EP sets, across *both* tenants, are pairwise disjoint
        let mut seen = vec![false; plat.n_eps()];
        for t in &report.tenants {
            assert!(t.conserved(), "{}: conservation", t.name);
            assert!(t.completed > 0, "{}: starved by its budget", t.name);
            for s in &t.shards {
                for &e in &s.eps {
                    assert!(!seen[e], "EP {e} shared across the co-planned cluster");
                    seen[e] = true;
                }
                for ep in &s.final_config.assignment {
                    assert!(s.eps.contains(ep), "config escaped its budget");
                }
            }
        }
    }

    #[test]
    fn coplan_rejects_more_tenants_than_eps() {
        let plat = crate::platform::configs::c1(); // 2 EPs
        let mk = |n: &str| small_tenant(n, 1.0);
        let mut opts = base_opts(1.0);
        opts.coplan = true;
        let tenants = vec![mk("a"), mk("b"), mk("c")];
        assert!(serve(&plat, tenants, &opts).is_err());
    }

    #[test]
    fn sharding_increases_completions_under_overload() {
        // Offered load saturates every deployment; completions then track
        // capacity, which the placement search grows with the shard budget.
        let run = |shards: usize| {
            let (plat, spec, cfg, cap) = sharded_tenant(3.0, shards, BalancerPolicy::JoinShortestQueue);
            (serve(&plat, vec![(spec, cfg)], &base_opts(300.0 / cap)).unwrap(), cap)
        };
        let (r1, _) = run(1);
        let (r4, _) = run(4);
        let c1 = r1.tenants[0].completed as f64;
        let c4 = r4.tenants[0].completed as f64;
        assert!(r1.tenants[0].conserved() && r4.tenants[0].conserved());
        assert!(r4.tenants[0].shards.len() > 1, "budget of 4 must replicate");
        assert!(
            c4 > 1.02 * c1,
            "4-way sharding must add capacity: {c4} vs {c1}"
        );
    }

    // --- fault plane ------------------------------------------------------

    use crate::serve::fault::FaultEvent;

    #[test]
    fn post_horizon_faults_change_nothing() {
        // Events past the horizon schedule nothing, and an armed-but-idle
        // fault plane must not perturb the hashed stream.
        let plat = crate::platform::configs::c1();
        let (probe, cfg) = small_tenant("x", 0.0);
        let cap = capacity(&probe, &plat, &cfg);
        let run = |faults: FaultScript| {
            let (spec, cfg) = small_tenant("t0", 0.5 * cap);
            let mut opts = base_opts(100.0 / cap);
            opts.record_log = true;
            opts.control_epoch_s = 20.0 / cap;
            opts.faults = faults;
            serve(&plat, vec![(spec, cfg)], &opts).unwrap()
        };
        let clean = run(FaultScript::default());
        let post = run(FaultScript {
            events: vec![FaultEvent { t_s: 200.0 / cap, kind: FaultKind::EpFail { ep: 0 } }],
        });
        assert_eq!(clean.log_hash, post.log_hash, "idle fault plane must be invisible");
        assert_eq!(clean.event_log, post.event_log);
        assert_eq!(clean.n_events, post.n_events);
        assert_eq!(clean.tenants[0].completed, post.tenants[0].completed);
    }

    #[test]
    fn serve_rejects_invalid_fault_scripts() {
        let plat = crate::platform::configs::c1(); // 2 EPs, chiplets 0/1
        let try_script = |events: Vec<FaultEvent>| {
            let (spec, cfg) = small_tenant("t0", 1.0);
            let opts =
                ServeOptions { faults: FaultScript { events }, ..base_opts(1.0) };
            serve(&plat, vec![(spec, cfg)], &opts)
        };
        let ev = |t_s, kind| FaultEvent { t_s, kind };
        // out-of-range ids
        assert!(try_script(vec![ev(0.5, FaultKind::EpFail { ep: 9 })]).is_err());
        assert!(try_script(vec![ev(0.5, FaultKind::ChipFail { chiplet: 99 })]).is_err());
        // non-finite / negative time, non-positive window, senseless factor
        assert!(try_script(vec![ev(f64::NAN, FaultKind::EpFail { ep: 0 })]).is_err());
        assert!(try_script(vec![ev(-1.0, FaultKind::EpFail { ep: 0 })]).is_err());
        assert!(
            try_script(vec![ev(0.5, FaultKind::EpStall { ep: 0, down_s: 0.0 })]).is_err()
        );
        assert!(try_script(vec![
            ev(0.5, FaultKind::EpSlow { ep: 0, factor: 0.5, down_s: 1.0 })
        ])
        .is_err());
        // overlapping windows on one EP
        assert!(try_script(vec![
            ev(0.1, FaultKind::EpSlow { ep: 0, factor: 2.0, down_s: 0.5 }),
            ev(0.3, FaultKind::EpSlow { ep: 0, factor: 3.0, down_s: 0.2 }),
        ])
        .is_err());
        // fail-stop of the whole platform
        assert!(try_script(vec![
            ev(0.1, FaultKind::EpFail { ep: 0 }),
            ev(0.2, FaultKind::EpFail { ep: 1 }),
        ])
        .is_err());
        // a well-formed script passes the same gate
        assert!(try_script(vec![ev(0.5, FaultKind::EpFail { ep: 0 })]).is_ok());
    }

    #[test]
    fn epfail_fails_over_conserves_and_avoids_the_failed_ep() {
        let (plat, spec, cfg, cap) = sharded_tenant(1.0, 2, BalancerPolicy::JoinShortestQueue);
        let failed = plat.eps_by_rank()[0]; // the strongest EP dies mid-run
        let mut opts = base_opts(300.0 / cap);
        opts.control_epoch_s = 4.0 / cap;
        opts.record_log = true;
        opts.faults = FaultScript {
            events: vec![FaultEvent { t_s: 100.0 / cap, kind: FaultKind::EpFail { ep: failed } }],
        };
        let run = || serve_traced(&plat, vec![(spec.clone(), cfg.clone())], &opts).unwrap();
        let (report, trace) = run();
        let t = &report.tenants[0];
        assert!(t.conserved(), "zero request loss across failover: {t:?}");
        assert!(t.completed > 0);
        for s in &t.shards {
            if s.final_state == ReplicaState::Active {
                assert!(
                    !s.eps.contains(&failed),
                    "active replica still owns the failed EP: {:?}",
                    s.eps
                );
                for ep in &s.final_config.assignment {
                    assert_ne!(*ep, failed, "final config places a stage on the failed EP");
                }
            }
        }
        assert!(trace.controls.iter().any(|c| c.kind == ControlKind::Fault));
        assert!(
            trace.controls.iter().any(|c| c.kind == ControlKind::Failover),
            "the fail-stop must trigger a failover re-plan"
        );
        // the faulted run is as deterministic as a clean one
        let (again, _) = run();
        assert_eq!(report.log_hash, again.log_hash, "faulted runs must be deterministic");
        assert_eq!(report.event_log, again.event_log);
    }

    #[test]
    fn epstall_recovery_readopts_the_full_home_set() {
        let plat = crate::platform::configs::c1();
        let (probe, cfg) = small_tenant("x", 0.0);
        let cap = capacity(&probe, &plat, &cfg);
        let (spec, cfg) = small_tenant("t0", 0.5 * cap);
        let mut opts = base_opts(200.0 / cap);
        opts.control_epoch_s = 10.0 / cap;
        opts.faults = FaultScript {
            events: vec![FaultEvent {
                t_s: 50.0 / cap,
                kind: FaultKind::EpStall { ep: 1, down_s: 30.0 / cap },
            }],
        };
        let report = serve(&plat, vec![(spec, cfg)], &opts).unwrap();
        let t = &report.tenants[0];
        assert!(t.conserved(), "conservation across stall + recovery: {t:?}");
        assert!(t.completed > 0);
        let s = &t.shards[0];
        assert_eq!(s.eps, vec![0, 1], "recovery must re-adopt the stalled EP");
        assert_eq!(s.final_state, ReplicaState::Active);
    }

    #[test]
    fn epslow_throttles_completions_deterministically() {
        let plat = crate::platform::configs::c1();
        let (probe, cfg) = small_tenant("x", 0.0);
        let cap = capacity(&probe, &plat, &cfg);
        let run = |script: &str| {
            let (spec, cfg) = small_tenant("t0", 2.0 * cap);
            let spec =
                spec.with_queue_capacity(16).with_admission(AdmissionPolicy::DropOldest);
            let mut opts = base_opts(200.0 / cap);
            opts.record_log = true;
            opts.faults = FaultScript::parse(script).unwrap();
            serve(&plat, vec![(spec, cfg)], &opts).unwrap()
        };
        let t0 = 50.0 / cap;
        let w = 100.0 / cap;
        let script = format!("epslow:0x4@{t0}+{w}; epslow:1x4@{t0}+{w}");
        let slow = run(&script);
        let again = run(&script);
        assert_eq!(slow.log_hash, again.log_hash, "throttled runs must be deterministic");
        assert_eq!(slow.event_log, again.event_log);
        let clean = run("");
        let ts = &slow.tenants[0];
        assert!(ts.conserved(), "conservation under throttle: {ts:?}");
        assert!(
            (ts.completed as f64) < 0.85 * clean.tenants[0].completed as f64,
            "a 4x throttle over half the run must cost completions: {} vs {}",
            ts.completed,
            clean.tenants[0].completed
        );
    }

    #[test]
    fn linkcut_blocks_transfers_then_recovers_and_conserves() {
        let plat = crate::platform::configs::c1();
        let (probe, cfg) = small_tenant("x", 0.0);
        let cap = capacity(&probe, &plat, &cfg);
        let run = |faults: FaultScript| {
            // cfg [3,3]/[0,1] moves every request across the link
            let (spec, cfg) = small_tenant("t0", 0.6 * cap);
            let spec = spec.with_queue_capacity(64);
            let mut opts = base_opts(200.0 / cap);
            opts.faults = faults;
            serve(&plat, vec![(spec, cfg)], &opts).unwrap()
        };
        let faulted = run(FaultScript {
            events: vec![FaultEvent {
                t_s: 80.0 / cap,
                kind: FaultKind::LinkCut { down_s: 40.0 / cap },
            }],
        });
        let clean = run(FaultScript::default());
        let t = &faulted.tenants[0];
        assert!(t.conserved(), "conservation across the link cut: {t:?}");
        assert!(t.completed > 0, "the pipeline must resume after the cut");
        assert_eq!(t.rejected + t.dropped, 0, "pooled work must not be shed");
        assert!(
            t.latency.p99() > clean.tenants[0].latency.p99(),
            "requests pooled behind the cut must show up in the tail: {} vs {}",
            t.latency.p99(),
            clean.tenants[0].latency.p99()
        );
    }

    #[test]
    fn degradation_sheds_the_lowest_weight_tenant_and_readmits() {
        let plat = crate::platform::configs::c1();
        let (probe, cfg) = small_tenant("x", 0.0);
        let cap = capacity(&probe, &plat, &cfg);
        let mk = |name: &str, weight: f64| {
            let (spec, cfg) = small_tenant(name, 2.0 * cap);
            let spec = spec
                .with_weight(weight)
                .with_queue_capacity(16)
                .with_admission(AdmissionPolicy::DropOldest);
            (spec, cfg)
        };
        let mut opts = base_opts(300.0 / cap);
        opts.control_epoch_s = 10.0 / cap;
        opts.faults = FaultScript {
            events: vec![FaultEvent {
                t_s: 50.0 / cap,
                kind: FaultKind::EpStall { ep: 1, down_s: 150.0 / cap },
            }],
        };
        let (report, trace) =
            serve_traced(&plat, vec![mk("hi", 4.0), mk("lo", 1.0)], &opts).unwrap();
        for t in &report.tenants {
            assert!(t.conserved(), "{}: conservation under shedding: {t:?}", t.name);
            assert!(
                t.epoch_conserved(),
                "{}: shed arrivals must meter once per epoch: {:?}",
                t.name,
                t.epochs
            );
        }
        let shed_on = |ti: u32| {
            trace
                .controls
                .iter()
                .any(|c| c.kind == ControlKind::Shed && c.tenant == ti && c.b == 1)
        };
        let shed_off = |ti: u32| {
            trace
                .controls
                .iter()
                .any(|c| c.kind == ControlKind::Shed && c.tenant == ti && c.b == 0)
        };
        assert!(shed_on(1), "the light tenant must be shed during the stall");
        assert!(!shed_on(0), "the heavy tenant must keep serving (degraded beats none)");
        assert!(shed_off(1), "recovery must re-admit the shed tenant");
        assert!(report.tenants[1].rejected > 0, "shed arrivals count as rejected");
        assert!(report.tenants[0].completed > 0);
        assert!(report.tenants[1].completed > 0, "service resumes after re-admission");
    }

    #[test]
    fn equal_weight_degradation_sheds_the_higher_index_tenant() {
        // same stall as above but with *equal* weights: the documented
        // tie-break (equal weights sort by ascending tenant index, so the
        // lower index is covered first and the higher index sheds first)
        // must pick deterministically — and bit-identically across runs
        let plat = crate::platform::configs::c1();
        let (probe, cfg) = small_tenant("x", 0.0);
        let cap = capacity(&probe, &plat, &cfg);
        let mk = |name: &str| {
            let (spec, cfg) = small_tenant(name, 2.0 * cap);
            let spec = spec
                .with_weight(1.0)
                .with_queue_capacity(16)
                .with_admission(AdmissionPolicy::DropOldest);
            (spec, cfg)
        };
        let mut opts = base_opts(300.0 / cap);
        opts.control_epoch_s = 10.0 / cap;
        opts.record_log = true;
        opts.faults = FaultScript {
            events: vec![FaultEvent {
                t_s: 50.0 / cap,
                kind: FaultKind::EpStall { ep: 1, down_s: 150.0 / cap },
            }],
        };
        let run = || serve_traced(&plat, vec![mk("eq0"), mk("eq1")], &opts).unwrap();
        let (report, trace) = run();
        let shed_on = |ti: u32| {
            trace
                .controls
                .iter()
                .any(|c| c.kind == ControlKind::Shed && c.tenant == ti && c.b == 1)
        };
        assert!(shed_on(1), "on a weight tie the higher index must shed first");
        assert!(!shed_on(0), "the lower index wins the tie and keeps serving");
        for t in &report.tenants {
            assert!(t.conserved(), "{}: conservation under tied shedding", t.name);
        }
        let (again, _) = run();
        assert_eq!(report.log_hash, again.log_hash, "tie-break must be bit-stable");
        assert_eq!(report.event_log, again.event_log);
    }

    // --- elastic control loop ---------------------------------------------

    /// A minimal single-replica runtime on C1, built exactly like
    /// `serve_inner` builds one — for white-box tests of replica state
    /// transitions that a full serve run cannot reach into.
    fn mk_replica() -> ShardRt {
        let plat = crate::platform::configs::c1();
        let net = networks::synthnet_small();
        let model = CostModel::default();
        let ep_map: Vec<_> = (0..plat.n_eps()).collect();
        let subplat = plat.subset(&ep_map);
        let cfg = PipelineConfig::single_stage(net.len(), 0);
        let dbs = vec![PerfDb::build(&net, &subplat, &model)];
        let scratch_db = dbs[0].clone();
        let weight = simulator::throughput(&net, &subplat, &dbs[0], &cfg);
        let controller = AdaptiveController::new(net.clone(), subplat.clone(), model);
        let bounds = cfg.stage_bounds();
        let n_stages = cfg.n_stages();
        let n_sub_eps = subplat.n_eps();
        ShardRt {
            initial_config: cfg.clone(),
            config: cfg,
            bounds,
            dbs,
            stages: (0..n_stages).map(|_| StageRt::default()).collect(),
            controller,
            gen: 0,
            frozen_until: 0.0,
            thaw_pending: false,
            ep_slow: vec![1.0; n_sub_eps],
            arena: Vec::new(),
            free_slots: Vec::new(),
            buf_pool: Vec::new(),
            scratch_db,
            scale_buf: vec![1.0; n_sub_eps],
            weight,
            credit: 0.0,
            state: ReplicaState::Active,
            scale_log: Vec::new(),
            natal_eps: ep_map.clone(),
            home_eps: ep_map.clone(),
            dead: false,
            offered: 0,
            rejected: 0,
            dropped: 0,
            expired: 0,
            cancelled: 0,
            retried: 0,
            hedged: 0,
            hedge_wins: 0,
            completed: 0,
            slo_ok: 0,
            max_queue_len: 0,
            latency: QuantileSketch::new(),
            ep_offered: 0,
            ep_completed: 0,
            ep_slo_ok: 0,
            ep_rejected: 0,
            ep_dropped: 0,
            ep_expired: 0,
            ep_cancelled: 0,
            ep_retried: 0,
            ep_hedged: 0,
            baseline_goodput: 0.0,
            epochs_since_retune: 0,
            retunes: 0,
            retune_trials: 0,
            epochs: Vec::new(),
            subplat,
            ep_map,
        }
    }

    #[test]
    fn reactivation_relaxes_the_slowdown_ewma() {
        // the EWMA only updates on completions, so a parked replica's
        // slowdown history is frozen ghost contention; re-activation must
        // fully relax it (the park/re-activate staleness bug)
        let mut s = mk_replica();
        s.state = ReplicaState::Parked;
        for f in &mut s.ep_slow {
            *f = 4.0;
        }
        s.reactivate();
        assert_eq!(s.state, ReplicaState::Active);
        assert!(
            s.ep_slow.iter().all(|&f| f == 1.0),
            "stale EWMA must fully relax on re-activation: {:?}",
            s.ep_slow
        );
    }

    #[test]
    fn elastic_requires_coplan_and_epochs() {
        let plat = crate::platform::configs::c1();
        let mk = || small_tenant("t0", 1.0);
        let mut opts = base_opts(1.0);
        opts.elastic.enabled = true;
        assert!(serve(&plat, vec![mk()], &opts).is_err(), "elastic needs the co-planner");
        opts.coplan = true;
        assert!(serve(&plat, vec![mk()], &opts).is_err(), "elastic needs control epochs");
        opts.control_epoch_s = 0.25;
        assert!(serve(&plat, vec![mk()], &opts).is_ok());
    }

    #[test]
    fn elastic_repartitions_follow_the_tide_and_conserve() {
        // two equal-weight tenants on C5 with anti-phase piecewise load:
        // "ebb" is hot first, "flow" takes over halfway. The elastic loop
        // must move EP budget toward the pressure at least once, lose no
        // request across the live migrations, keep the per-epoch flow
        // identity, and stay bit-deterministic.
        let plat = crate::platform::configs::c5();
        let net = networks::synthnet_small();
        let cfg = crate::serve::shisha_config(&net, &plat);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        let flip = 100.0 / cap;
        let mk = |name: &str, early: f64, late: f64| {
            let spec = TenantSpec::new(
                name,
                net.clone(),
                ArrivalProcess::Piecewise { segments: vec![(0.0, early), (flip, late)] },
            )
            .with_queue_capacity(32)
            .with_admission(AdmissionPolicy::DropOldest)
            .with_slo(500.0 / cap);
            (spec, cfg.clone())
        };
        let hot = 0.9 * cap;
        let idle = 0.02 * cap;
        let mut opts = base_opts(200.0 / cap);
        opts.control_epoch_s = 4.0 / cap;
        opts.coplan = true;
        opts.elastic.enabled = true;
        opts.record_log = true;
        let run = || {
            serve_traced(&plat, vec![mk("ebb", hot, idle), mk("flow", idle, hot)], &opts)
                .unwrap()
        };
        let (report, trace) = run();
        let mut repartitions = 0;
        for t in &report.tenants {
            assert!(t.conserved(), "{}: conservation across re-partitions: {t:?}", t.name);
            assert!(
                t.epoch_conserved(),
                "{}: per-epoch flow identity: {:?}",
                t.name,
                t.epochs
            );
            assert!(t.completed > 0, "{}: starved", t.name);
            repartitions += t.repartitions;
        }
        assert!(repartitions >= 1, "the anti-phase tide must trigger a re-partition");
        assert!(
            trace.controls.iter().any(|c| c.kind == ControlKind::Repartition),
            "re-partitions must leave hashed control records"
        );
        // after any number of re-homings, live replicas still own
        // pairwise-disjoint EP subsets across the cluster
        let mut seen = vec![false; plat.n_eps()];
        for t in &report.tenants {
            for s in &t.shards {
                if s.final_state == ReplicaState::Active {
                    for &e in &s.eps {
                        assert!(!seen[e], "EP {e} owned twice after re-partitioning");
                        seen[e] = true;
                    }
                }
            }
        }
        let (again, _) = run();
        assert_eq!(report.log_hash, again.log_hash, "elastic runs must be deterministic");
        assert_eq!(report.event_log, again.event_log);
    }

    #[test]
    fn elastic_holds_under_uniform_demand() {
        // two identical tenants fed the *same* explicit arrival trace:
        // their observed pressures match, every demand factor is 1.0, the
        // observed plan reproduces the static co-plan, and the gain bar
        // never clears — an elastic run must not thrash re-partitions
        let plat = crate::platform::configs::c1();
        let (probe, cfg) = small_tenant("x", 0.0);
        let cap = capacity(&probe, &plat, &cfg);
        // each tenant gets one of C1's two EPs, so pace arrivals well
        // under a single EP's service rate
        let times: Vec<f64> = (1..=25).map(|i| i as f64 * 8.0 / cap).collect();
        let mk = |name: &str| {
            let spec = TenantSpec::new(
                name,
                networks::synthnet_small(),
                ArrivalProcess::Trace { times: times.clone() },
            )
            .with_queue_capacity(32)
            .with_slo(500.0 / cap);
            (spec, cfg.clone())
        };
        let mut opts = base_opts(250.0 / cap);
        opts.control_epoch_s = 10.0 / cap;
        opts.coplan = true;
        opts.elastic.enabled = true;
        let report = serve(&plat, vec![mk("a"), mk("b")], &opts).unwrap();
        for t in &report.tenants {
            assert!(t.conserved());
            assert!(t.completed > 0);
            assert_eq!(
                t.repartitions, 0,
                "{}: uniform demand must never clear the gain bar",
                t.name
            );
        }
    }
}
