//! SLO metrics: streaming latency quantiles, goodput and fairness.
//!
//! The serving engine completes millions of requests per simulated run, so
//! latencies are folded into a fixed-size **geometric histogram sketch**
//! ([`QuantileSketch`]) instead of being stored: buckets are log-spaced
//! between [`QuantileSketch::FLOOR_S`] and [`QuantileSketch::CEIL_S`]
//! (~2.8% relative width), insertion is O(1), and any quantile is read out
//! in O(#buckets) with a worst-case relative error of one bucket width.
//! The sketch is fully deterministic — identical insert sequences yield
//! identical quantiles — which the engine's determinism guarantee relies
//! on.
//!
//! [`jain_fairness`] is the standard Jain index over per-tenant goodputs:
//! 1.0 when all tenants receive equal goodput, → 1/n under starvation.

/// Streaming latency quantile sketch over a geometric bucket grid.
#[derive(Debug, Clone)]
pub struct QuantileSketch {
    counts: Vec<u64>,
    n: u64,
    sum_s: f64,
    min_s: f64,
    max_s: f64,
}

impl Default for QuantileSketch {
    fn default() -> Self {
        Self::new()
    }
}

impl QuantileSketch {
    /// Smallest resolvable latency (1 µs); anything below lands in bucket 0.
    pub const FLOOR_S: f64 = 1e-6;
    /// Largest resolvable latency (100 000 s); anything above saturates.
    pub const CEIL_S: f64 = 1e5;
    /// Buckets per decade (relative bucket width ≈ 10^(1/80) − 1 ≈ 2.9%).
    const PER_DECADE: usize = 80;
    /// Total bucket count: 11 decades × PER_DECADE + 1 overflow.
    const N_BUCKETS: usize = 11 * Self::PER_DECADE + 1;

    /// Empty sketch.
    pub fn new() -> Self {
        Self {
            counts: vec![0; Self::N_BUCKETS],
            n: 0,
            sum_s: 0.0,
            min_s: f64::INFINITY,
            max_s: 0.0,
        }
    }

    fn bucket_of(x: f64) -> usize {
        if x <= Self::FLOOR_S {
            return 0;
        }
        let idx = ((x / Self::FLOOR_S).log10() * Self::PER_DECADE as f64).floor() as usize;
        idx.min(Self::N_BUCKETS - 1)
    }

    /// Geometric midpoint of a bucket (representative value on readout).
    fn bucket_value(idx: usize) -> f64 {
        Self::FLOOR_S * 10f64.powf((idx as f64 + 0.5) / Self::PER_DECADE as f64)
    }

    /// Record one latency observation (seconds). Negative values clamp to 0.
    pub fn record(&mut self, latency_s: f64) {
        let x = latency_s.max(0.0);
        self.counts[Self::bucket_of(x)] += 1;
        self.n += 1;
        self.sum_s += x;
        self.min_s = self.min_s.min(x);
        self.max_s = self.max_s.max(x);
    }

    /// Number of observations.
    pub fn len(&self) -> u64 {
        self.n
    }

    /// True when nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Mean latency in seconds (0 when empty).
    pub fn mean_s(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_s / self.n as f64
        }
    }

    /// Exact maximum observed (0 when empty).
    pub fn max_s(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max_s
        }
    }

    /// Exact minimum observed (0 when empty).
    pub fn min_s(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min_s
        }
    }

    /// Quantile `q ∈ [0, 1]` in seconds (0 when empty). Returns the
    /// geometric midpoint of the bucket holding the q-th observation,
    /// clamped to the exact observed [min, max].
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        // rank ∈ [1, n]: the smallest observation has rank 1
        let rank = ((q * self.n as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_value(idx).clamp(self.min_s, self.max_s);
            }
        }
        self.max_s
    }

    /// Quantile with an explicit cold-sketch fallback: `default_s` when
    /// nothing has been recorded yet, [`QuantileSketch::quantile`]
    /// otherwise. The hedge controller derives its per-tenant hedge delay
    /// through this, falling back to the SLO budget until the first
    /// completions land. The sketch stays duplicate-completion-safe by
    /// construction: the engine records a latency only for the *winning*
    /// copy of a hedged pair (the loser is cancelled, never recorded), so
    /// quantiles are over logical requests, not copies.
    pub fn quantile_or(&self, q: f64, default_s: f64) -> f64 {
        if self.n == 0 {
            default_s
        } else {
            self.quantile(q)
        }
    }

    /// p50 shorthand (seconds).
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    /// p95 shorthand (seconds).
    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    /// p99 shorthand (seconds).
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Merge another sketch into this one (same grid by construction).
    pub fn merge(&mut self, other: &QuantileSketch) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.n += other.n;
        self.sum_s += other.sum_s;
        self.min_s = self.min_s.min(other.min_s);
        self.max_s = self.max_s.max(other.max_s);
    }
}

/// Jain's fairness index over per-tenant goodputs:
/// `(Σx)² / (n·Σx²)` ∈ [1/n, 1]. Returns 1.0 for empty or all-zero input
/// (nobody is being treated unfairly when nobody gets anything).
pub fn jain_fairness(goodputs: &[f64]) -> f64 {
    if goodputs.is_empty() {
        return 1.0;
    }
    let sum: f64 = goodputs.iter().sum();
    let sq_sum: f64 = goodputs.iter().map(|x| x * x).sum();
    if sq_sum <= 0.0 {
        return 1.0;
    }
    (sum * sum) / (goodputs.len() as f64 * sq_sum)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_sketch_reads_zero() {
        let s = QuantileSketch::new();
        assert!(s.is_empty());
        assert_eq!(s.p50(), 0.0);
        assert_eq!(s.mean_s(), 0.0);
        assert_eq!(s.max_s(), 0.0);
    }

    #[test]
    fn single_value_all_quantiles() {
        let mut s = QuantileSketch::new();
        s.record(0.125);
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            let v = s.quantile(q);
            assert!((v - 0.125).abs() / 0.125 < 0.05, "q={q} v={v}");
        }
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn uniform_grid_quantiles_within_bucket_error() {
        // 1..=1000 ms uniformly: p50 ≈ 0.5 s, p95 ≈ 0.95 s, p99 ≈ 0.99 s.
        let mut s = QuantileSketch::new();
        for i in 1..=1000 {
            s.record(i as f64 * 1e-3);
        }
        for (q, want) in [(0.50, 0.5), (0.95, 0.95), (0.99, 0.99)] {
            let got = s.quantile(q);
            assert!((got - want).abs() / want < 0.05, "q={q}: got {got}, want {want}");
        }
    }

    #[test]
    fn quantiles_monotone() {
        let mut s = QuantileSketch::new();
        let mut x = 1e-4;
        for _ in 0..500 {
            s.record(x);
            x *= 1.017;
        }
        assert!(s.p50() <= s.p95());
        assert!(s.p95() <= s.p99());
        assert!(s.p99() <= s.max_s() * (1.0 + 1e-12));
    }

    #[test]
    fn extremes_clamped_and_counted() {
        let mut s = QuantileSketch::new();
        s.record(0.0); // below floor
        s.record(1e9); // above ceiling
        assert_eq!(s.len(), 2);
        assert_eq!(s.min_s(), 0.0);
        assert_eq!(s.max_s(), 1e9);
        assert!(s.quantile(1.0) <= 1e9);
    }

    #[test]
    fn mean_and_sum_exact() {
        let mut s = QuantileSketch::new();
        for v in [0.1, 0.2, 0.3] {
            s.record(v);
        }
        assert!((s.mean_s() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn merge_matches_combined_stream() {
        let mut a = QuantileSketch::new();
        let mut b = QuantileSketch::new();
        let mut both = QuantileSketch::new();
        for i in 1..=100 {
            let x = i as f64 * 1e-3;
            if i % 2 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
            both.record(x);
        }
        a.merge(&b);
        assert_eq!(a.len(), both.len());
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(a.quantile(q), both.quantile(q));
        }
    }

    #[test]
    fn determinism_identical_streams() {
        let feed = |s: &mut QuantileSketch| {
            let mut x = 3e-3;
            for _ in 0..1000 {
                s.record(x);
                x = (x * 1.37) % 2.0 + 1e-4;
            }
        };
        let mut a = QuantileSketch::new();
        let mut b = QuantileSketch::new();
        feed(&mut a);
        feed(&mut b);
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.quantile(q), b.quantile(q));
        }
    }

    #[test]
    fn quantile_or_falls_back_only_when_cold() {
        let mut s = QuantileSketch::new();
        assert_eq!(s.quantile_or(0.95, 0.25), 0.25, "cold sketch yields the default");
        s.record(0.010);
        let v = s.quantile_or(0.95, 0.25);
        assert!((v - 0.010).abs() / 0.010 < 0.05, "warm sketch ignores the default: {v}");
    }

    #[test]
    fn jain_index_bounds() {
        assert_eq!(jain_fairness(&[]), 1.0);
        assert_eq!(jain_fairness(&[0.0, 0.0]), 1.0);
        assert!((jain_fairness(&[5.0, 5.0, 5.0]) - 1.0).abs() < 1e-12);
        let skew = jain_fairness(&[10.0, 0.0, 0.0]);
        assert!((skew - 1.0 / 3.0).abs() < 1e-12, "starvation → 1/n, got {skew}");
        let mid = jain_fairness(&[4.0, 2.0]);
        assert!(mid > 1.0 / 2.0 && mid < 1.0);
    }
}
