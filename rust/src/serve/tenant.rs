//! Multi-tenant workload description.
//!
//! A [`TenantSpec`] bundles everything the engine needs to serve one model
//! under load: the network, its arrival process, the SLO target, queueing
//! and batching parameters, and the admission policy. Tenants contend for
//! the *shared* [`crate::platform::Platform`]: each tenant owns a
//! [`crate::pipeline::PipelineConfig`] over the same EP set, and the
//! engine's contention model charges stages that execute concurrently on
//! one EP (or push transfers over the inter-chiplet link concurrently)
//! proportionally to the number of co-runners.

use anyhow::{bail, Result};

use crate::model::Network;
use crate::pipeline::PipelineConfig;
use crate::platform::Platform;

use super::arrivals::ArrivalProcess;
use super::lifecycle::{HedgePolicy, RetryPolicy};
use super::shard::BalancerPolicy;

/// What to do when a request arrives and the tenant's entry queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Reject the incoming request (counted as `rejected`).
    Reject,
    /// Drop the oldest queued request (counted as `dropped`) and admit the
    /// new one — bounds staleness under overload.
    DropOldest,
}

/// One tenant: a model served under an arrival process with an SLO.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    /// Display name (unique per run).
    pub name: String,
    /// The CNN this tenant serves.
    pub net: Network,
    /// Request arrival process.
    pub arrivals: ArrivalProcess,
    /// Latency SLO: completions within this bound count towards goodput.
    pub slo_latency_s: f64,
    /// Bound on every per-stage FIFO queue (≥ 1).
    pub queue_capacity: usize,
    /// Maximum images a stage services per slot (≥ 1; 1 = no batching).
    pub batch: usize,
    /// Admission policy at the entry queue.
    pub admission: AdmissionPolicy,
    /// Maximum pipeline replicas (≥ 1; 1 = unsharded). When > 1 the
    /// engine runs [`crate::serve::shard::plan_shards`] and serves the
    /// best placement with **at most** this many replicas on disjoint EP
    /// subsets — the planner never picks a sharded placement predicted to
    /// be slower than fewer shards, and counts beyond the platform's EP
    /// count are capped there.
    pub shards: usize,
    /// Front-end arrival routing across replicas (ignored when the plan
    /// ends up with a single replica).
    pub balancer: BalancerPolicy,
    /// Priority weight for cross-tenant co-planning
    /// ([`crate::serve::cluster::coplan`]): the joint objective maximised
    /// across tenants is `Σ weight × predicted throughput`, so a tenant
    /// with twice the weight is worth twice as much per unit of predicted
    /// throughput when EP budgets are allocated. Must be positive and
    /// finite; ignored unless co-planning is enabled.
    pub weight: f64,
    /// Per-request deadline budget, seconds, measured from each
    /// (re-)arrival. A request still **queued** when its budget runs out
    /// is reaped before it can waste a batch slot (counted as `expired`,
    /// distinct from sheds and drops). `f64::INFINITY` (the default)
    /// disables expiry entirely — no deadline events are ever scheduled.
    pub deadline_s: f64,
    /// Deterministic retry/backoff policy for rejected, dropped, and
    /// expired requests (see [`RetryPolicy`]). `None` (the default) means
    /// a refused request is simply lost, exactly as before this knob
    /// existed.
    pub retry: Option<RetryPolicy>,
    /// Hedged-request policy (see [`HedgePolicy`]): duplicate a queued
    /// straggler onto the least-loaded sibling replica once it has waited
    /// longer than the tenant's observed p9x latency; first completion
    /// wins. `None` (the default) disables hedging. Only meaningful with
    /// more than one replica.
    pub hedge: Option<HedgePolicy>,
}

impl TenantSpec {
    /// New tenant with serving defaults: 250 ms SLO, 64-deep queues, no
    /// batching, reject-on-full admission.
    pub fn new(name: impl Into<String>, net: Network, arrivals: ArrivalProcess) -> Self {
        Self {
            name: name.into(),
            net,
            arrivals,
            slo_latency_s: 0.250,
            queue_capacity: 64,
            batch: 1,
            admission: AdmissionPolicy::Reject,
            shards: 1,
            balancer: BalancerPolicy::RoundRobin,
            weight: 1.0,
            deadline_s: f64::INFINITY,
            retry: None,
            hedge: None,
        }
    }

    /// Any lifecycle policy active? When false the engine schedules no
    /// lifecycle events at all and every hash stays byte-identical to a
    /// pre-lifecycle build.
    pub fn lifecycle_active(&self) -> bool {
        self.deadline_s.is_finite()
            || self.retry.is_some_and(|r| r.max_attempts > 0)
            || self.hedge.is_some()
    }

    /// Builder-style SLO override.
    pub fn with_slo(mut self, slo_latency_s: f64) -> Self {
        self.slo_latency_s = slo_latency_s;
        self
    }

    /// Builder-style queue-capacity override.
    pub fn with_queue_capacity(mut self, cap: usize) -> Self {
        self.queue_capacity = cap;
        self
    }

    /// Builder-style batch override.
    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Builder-style admission-policy override.
    pub fn with_admission(mut self, policy: AdmissionPolicy) -> Self {
        self.admission = policy;
        self
    }

    /// Builder-style shard-count override (maximum replicas; see
    /// [`TenantSpec::shards`]).
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// Builder-style load-balancer override.
    pub fn with_balancer(mut self, balancer: BalancerPolicy) -> Self {
        self.balancer = balancer;
        self
    }

    /// Builder-style co-planning weight override (see
    /// [`TenantSpec::weight`]).
    pub fn with_weight(mut self, weight: f64) -> Self {
        self.weight = weight;
        self
    }

    /// Builder-style per-request deadline override, seconds (see
    /// [`TenantSpec::deadline_s`]).
    pub fn with_deadline(mut self, deadline_s: f64) -> Self {
        self.deadline_s = deadline_s;
        self
    }

    /// Builder-style retry-policy override (see [`TenantSpec::retry`]).
    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = Some(retry);
        self
    }

    /// Builder-style hedge-policy override (see [`TenantSpec::hedge`]).
    pub fn with_hedge(mut self, hedge: HedgePolicy) -> Self {
        self.hedge = Some(hedge);
        self
    }

    /// Validate the spec against the platform it will be served on.
    pub fn validate(&self, plat: &Platform, config: &PipelineConfig) -> Result<()> {
        if self.queue_capacity == 0 {
            bail!("tenant {}: queue capacity must be ≥ 1", self.name);
        }
        if self.batch == 0 {
            bail!("tenant {}: batch must be ≥ 1", self.name);
        }
        if self.slo_latency_s <= 0.0 {
            bail!("tenant {}: SLO latency must be positive", self.name);
        }
        if self.shards == 0 {
            bail!("tenant {}: shards must be ≥ 1", self.name);
        }
        if !(self.weight.is_finite() && self.weight > 0.0) {
            bail!("tenant {}: weight must be positive and finite", self.name);
        }
        if self.deadline_s.is_nan() || self.deadline_s <= 0.0 {
            bail!("tenant {}: deadline must be positive (∞ disables)", self.name);
        }
        if let Some(r) = &self.retry {
            if let Err(e) = r.validate() {
                bail!("tenant {}: invalid retry policy: {e}", self.name);
            }
        }
        if let Some(h) = &self.hedge {
            if let Err(e) = h.validate() {
                bail!("tenant {}: invalid hedge policy: {e}", self.name);
            }
        }
        if let Err(e) = config.validate(self.net.len(), plat) {
            bail!("tenant {}: invalid pipeline config: {e}", self.name);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::networks;
    use crate::platform::configs;

    fn spec() -> TenantSpec {
        TenantSpec::new("t0", networks::synthnet(), ArrivalProcess::Poisson { rate: 10.0 })
    }

    #[test]
    fn defaults_are_sane() {
        let s = spec();
        assert_eq!(s.queue_capacity, 64);
        assert_eq!(s.batch, 1);
        assert_eq!(s.admission, AdmissionPolicy::Reject);
        assert_eq!(s.shards, 1, "unsharded by default");
        assert_eq!(s.balancer, BalancerPolicy::RoundRobin);
        assert_eq!(s.weight, 1.0, "unit co-planning weight by default");
        assert!(s.slo_latency_s > 0.0);
        assert!(s.deadline_s.is_infinite() && s.retry.is_none() && s.hedge.is_none());
        assert!(!s.lifecycle_active(), "lifecycle must be fully off by default");
    }

    #[test]
    fn builders_override() {
        let s = spec()
            .with_slo(1.5)
            .with_queue_capacity(8)
            .with_batch(4)
            .with_admission(AdmissionPolicy::DropOldest)
            .with_shards(3)
            .with_balancer(BalancerPolicy::JoinShortestQueue)
            .with_weight(2.5);
        assert_eq!(s.slo_latency_s, 1.5);
        assert_eq!(s.queue_capacity, 8);
        assert_eq!(s.batch, 4);
        assert_eq!(s.admission, AdmissionPolicy::DropOldest);
        assert_eq!(s.shards, 3);
        assert_eq!(s.balancer, BalancerPolicy::JoinShortestQueue);
        assert_eq!(s.weight, 2.5);
    }

    #[test]
    fn validate_catches_bad_specs() {
        let plat = configs::c2();
        let cfg = PipelineConfig::new(vec![9, 9], vec![0, 1]);
        assert!(spec().validate(&plat, &cfg).is_ok());
        assert!(spec().with_queue_capacity(0).validate(&plat, &cfg).is_err());
        assert!(spec().with_batch(0).validate(&plat, &cfg).is_err());
        assert!(spec().with_slo(0.0).validate(&plat, &cfg).is_err());
        assert!(spec().with_shards(0).validate(&plat, &cfg).is_err());
        assert!(spec().with_shards(9).validate(&plat, &cfg).is_ok(), "counts above n_eps cap");
        assert!(spec().with_weight(0.0).validate(&plat, &cfg).is_err());
        assert!(spec().with_weight(f64::NAN).validate(&plat, &cfg).is_err());
        let bad_cfg = PipelineConfig::new(vec![5], vec![0]);
        assert!(spec().validate(&plat, &bad_cfg).is_err());
    }

    #[test]
    fn lifecycle_builders_activate_and_validate() {
        let plat = configs::c2();
        let cfg = PipelineConfig::new(vec![9, 9], vec![0, 1]);
        let s = spec()
            .with_deadline(0.4)
            .with_retry(RetryPolicy::default())
            .with_hedge(HedgePolicy::default());
        assert_eq!(s.deadline_s, 0.4);
        assert!(s.lifecycle_active());
        assert!(s.validate(&plat, &cfg).is_ok());
        assert!(spec().with_deadline(0.1).lifecycle_active(), "deadline alone activates");
        assert!(
            spec().with_retry(RetryPolicy::default()).lifecycle_active(),
            "retry alone activates"
        );
        assert!(
            !spec()
                .with_retry(RetryPolicy { max_attempts: 0, ..Default::default() })
                .lifecycle_active(),
            "zero-attempt retry is inert"
        );
        assert!(spec().with_deadline(0.0).validate(&plat, &cfg).is_err());
        assert!(spec().with_deadline(-1.0).validate(&plat, &cfg).is_err());
        assert!(spec().with_deadline(f64::NAN).validate(&plat, &cfg).is_err());
        let bad_retry = RetryPolicy { max_attempts: 3, base_s: 0.0, cap_s: 1.0 };
        assert!(spec().with_retry(bad_retry).validate(&plat, &cfg).is_err());
        let bad_hedge = HedgePolicy { quantile: 1.5, min_delay_s: 0.0 };
        assert!(spec().with_hedge(bad_hedge).validate(&plat, &cfg).is_err());
    }
}
