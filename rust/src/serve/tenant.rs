//! Multi-tenant workload description.
//!
//! A [`TenantSpec`] bundles everything the engine needs to serve one model
//! under load: the network, its arrival process, the SLO target, queueing
//! and batching parameters, and the admission policy. Tenants contend for
//! the *shared* [`crate::platform::Platform`]: each tenant owns a
//! [`crate::pipeline::PipelineConfig`] over the same EP set, and the
//! engine's contention model charges stages that execute concurrently on
//! one EP (or push transfers over the inter-chiplet link concurrently)
//! proportionally to the number of co-runners.

use anyhow::{bail, Result};

use crate::model::Network;
use crate::pipeline::PipelineConfig;
use crate::platform::Platform;

use super::arrivals::ArrivalProcess;
use super::shard::BalancerPolicy;

/// What to do when a request arrives and the tenant's entry queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Reject the incoming request (counted as `rejected`).
    Reject,
    /// Drop the oldest queued request (counted as `dropped`) and admit the
    /// new one — bounds staleness under overload.
    DropOldest,
}

/// One tenant: a model served under an arrival process with an SLO.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    /// Display name (unique per run).
    pub name: String,
    /// The CNN this tenant serves.
    pub net: Network,
    /// Request arrival process.
    pub arrivals: ArrivalProcess,
    /// Latency SLO: completions within this bound count towards goodput.
    pub slo_latency_s: f64,
    /// Bound on every per-stage FIFO queue (≥ 1).
    pub queue_capacity: usize,
    /// Maximum images a stage services per slot (≥ 1; 1 = no batching).
    pub batch: usize,
    /// Admission policy at the entry queue.
    pub admission: AdmissionPolicy,
    /// Maximum pipeline replicas (≥ 1; 1 = unsharded). When > 1 the
    /// engine runs [`crate::serve::shard::plan_shards`] and serves the
    /// best placement with **at most** this many replicas on disjoint EP
    /// subsets — the planner never picks a sharded placement predicted to
    /// be slower than fewer shards, and counts beyond the platform's EP
    /// count are capped there.
    pub shards: usize,
    /// Front-end arrival routing across replicas (ignored when the plan
    /// ends up with a single replica).
    pub balancer: BalancerPolicy,
    /// Priority weight for cross-tenant co-planning
    /// ([`crate::serve::cluster::coplan`]): the joint objective maximised
    /// across tenants is `Σ weight × predicted throughput`, so a tenant
    /// with twice the weight is worth twice as much per unit of predicted
    /// throughput when EP budgets are allocated. Must be positive and
    /// finite; ignored unless co-planning is enabled.
    pub weight: f64,
}

impl TenantSpec {
    /// New tenant with serving defaults: 250 ms SLO, 64-deep queues, no
    /// batching, reject-on-full admission.
    pub fn new(name: impl Into<String>, net: Network, arrivals: ArrivalProcess) -> Self {
        Self {
            name: name.into(),
            net,
            arrivals,
            slo_latency_s: 0.250,
            queue_capacity: 64,
            batch: 1,
            admission: AdmissionPolicy::Reject,
            shards: 1,
            balancer: BalancerPolicy::RoundRobin,
            weight: 1.0,
        }
    }

    /// Builder-style SLO override.
    pub fn with_slo(mut self, slo_latency_s: f64) -> Self {
        self.slo_latency_s = slo_latency_s;
        self
    }

    /// Builder-style queue-capacity override.
    pub fn with_queue_capacity(mut self, cap: usize) -> Self {
        self.queue_capacity = cap;
        self
    }

    /// Builder-style batch override.
    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Builder-style admission-policy override.
    pub fn with_admission(mut self, policy: AdmissionPolicy) -> Self {
        self.admission = policy;
        self
    }

    /// Builder-style shard-count override (maximum replicas; see
    /// [`TenantSpec::shards`]).
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// Builder-style load-balancer override.
    pub fn with_balancer(mut self, balancer: BalancerPolicy) -> Self {
        self.balancer = balancer;
        self
    }

    /// Builder-style co-planning weight override (see
    /// [`TenantSpec::weight`]).
    pub fn with_weight(mut self, weight: f64) -> Self {
        self.weight = weight;
        self
    }

    /// Validate the spec against the platform it will be served on.
    pub fn validate(&self, plat: &Platform, config: &PipelineConfig) -> Result<()> {
        if self.queue_capacity == 0 {
            bail!("tenant {}: queue capacity must be ≥ 1", self.name);
        }
        if self.batch == 0 {
            bail!("tenant {}: batch must be ≥ 1", self.name);
        }
        if self.slo_latency_s <= 0.0 {
            bail!("tenant {}: SLO latency must be positive", self.name);
        }
        if self.shards == 0 {
            bail!("tenant {}: shards must be ≥ 1", self.name);
        }
        if !(self.weight.is_finite() && self.weight > 0.0) {
            bail!("tenant {}: weight must be positive and finite", self.name);
        }
        if let Err(e) = config.validate(self.net.len(), plat) {
            bail!("tenant {}: invalid pipeline config: {e}", self.name);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::networks;
    use crate::platform::configs;

    fn spec() -> TenantSpec {
        TenantSpec::new("t0", networks::synthnet(), ArrivalProcess::Poisson { rate: 10.0 })
    }

    #[test]
    fn defaults_are_sane() {
        let s = spec();
        assert_eq!(s.queue_capacity, 64);
        assert_eq!(s.batch, 1);
        assert_eq!(s.admission, AdmissionPolicy::Reject);
        assert_eq!(s.shards, 1, "unsharded by default");
        assert_eq!(s.balancer, BalancerPolicy::RoundRobin);
        assert_eq!(s.weight, 1.0, "unit co-planning weight by default");
        assert!(s.slo_latency_s > 0.0);
    }

    #[test]
    fn builders_override() {
        let s = spec()
            .with_slo(1.5)
            .with_queue_capacity(8)
            .with_batch(4)
            .with_admission(AdmissionPolicy::DropOldest)
            .with_shards(3)
            .with_balancer(BalancerPolicy::JoinShortestQueue)
            .with_weight(2.5);
        assert_eq!(s.slo_latency_s, 1.5);
        assert_eq!(s.queue_capacity, 8);
        assert_eq!(s.batch, 4);
        assert_eq!(s.admission, AdmissionPolicy::DropOldest);
        assert_eq!(s.shards, 3);
        assert_eq!(s.balancer, BalancerPolicy::JoinShortestQueue);
        assert_eq!(s.weight, 2.5);
    }

    #[test]
    fn validate_catches_bad_specs() {
        let plat = configs::c2();
        let cfg = PipelineConfig::new(vec![9, 9], vec![0, 1]);
        assert!(spec().validate(&plat, &cfg).is_ok());
        assert!(spec().with_queue_capacity(0).validate(&plat, &cfg).is_err());
        assert!(spec().with_batch(0).validate(&plat, &cfg).is_err());
        assert!(spec().with_slo(0.0).validate(&plat, &cfg).is_err());
        assert!(spec().with_shards(0).validate(&plat, &cfg).is_err());
        assert!(spec().with_shards(9).validate(&plat, &cfg).is_ok(), "counts above n_eps cap");
        assert!(spec().with_weight(0.0).validate(&plat, &cfg).is_err());
        assert!(spec().with_weight(f64::NAN).validate(&plat, &cfg).is_err());
        let bad_cfg = PipelineConfig::new(vec![5], vec![0]);
        assert!(spec().validate(&plat, &bad_cfg).is_err());
    }
}
