//! Arrival processes for the serving engine.
//!
//! Four generators, all driven by the crate's [`Xoshiro256`] so a run is
//! reproducible from a single seed:
//!
//! * [`ArrivalProcess::Poisson`] — memoryless, constant rate;
//! * [`ArrivalProcess::Mmpp`] — two-state Markov-modulated Poisson
//!   process, the classic bursty-traffic model (low/high rate with
//!   exponentially distributed dwell times);
//! * [`ArrivalProcess::Diurnal`] — sinusoidally rate-modulated Poisson,
//!   sampled by Lewis–Shedler thinning (day/night load curves);
//! * [`ArrivalProcess::Piecewise`] — piecewise-constant rates with exact
//!   change points, the *arrival-rate drift* scenario that exercises the
//!   online re-tuning loop;
//! * [`ArrivalProcess::Trace`] — replay of explicit timestamps (e.g. from
//!   a production log), for exact reproduction of a recorded workload.
//!
//! Specs parse from compact CLI strings via [`ArrivalProcess::parse`]:
//! `poisson:200`, `mmpp:50,400,5,1`, `diurnal:200,0.8,60`,
//! `piecewise:100@0,400@30`, `trace:/path/to/times.txt`.

use anyhow::{bail, Context, Result};

use crate::rng::Xoshiro256;

/// A request arrival process (per tenant).
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Constant-rate Poisson arrivals, `rate` requests/second.
    Poisson {
        /// Mean arrival rate, requests/second.
        rate: f64,
    },
    /// Two-state Markov-modulated Poisson process.
    Mmpp {
        /// Arrival rate in the low state, requests/second.
        low_rate: f64,
        /// Arrival rate in the high (burst) state, requests/second.
        high_rate: f64,
        /// Mean dwell time in the low state, seconds.
        mean_low_s: f64,
        /// Mean dwell time in the high state, seconds.
        mean_high_s: f64,
    },
    /// Sinusoidally modulated Poisson: `rate(t) = base·(1 + amp·sin(2πt/period))`.
    Diurnal {
        /// Mean rate, requests/second.
        base_rate: f64,
        /// Relative modulation amplitude in [0, 1].
        amplitude: f64,
        /// Modulation period, seconds.
        period_s: f64,
    },
    /// Piecewise-constant rate: `(start_s, rate)` segments, sorted by start.
    /// The first segment should start at 0; rate 0 means silence.
    Piecewise {
        /// `(segment start time, rate)` pairs, ascending starts.
        segments: Vec<(f64, f64)>,
    },
    /// Replay of explicit arrival timestamps (seconds, ascending).
    Trace {
        /// Absolute arrival times, seconds.
        times: Vec<f64>,
    },
}

impl ArrivalProcess {
    /// Parse a compact spec string (see module docs for the grammar).
    pub fn parse(spec: &str) -> Result<ArrivalProcess> {
        let (kind, rest) = spec
            .split_once(':')
            .with_context(|| format!("arrival spec {spec:?}: expected kind:params"))?;
        let nums = |s: &str| -> Result<Vec<f64>> {
            s.split(',')
                .map(|x| {
                    x.trim()
                        .parse::<f64>()
                        .map_err(|e| anyhow::anyhow!("arrival spec {spec:?}: {x:?}: {e}"))
                })
                .collect()
        };
        match kind.trim().to_ascii_lowercase().as_str() {
            "poisson" => {
                let v = nums(rest)?;
                if v.len() != 1 || v[0] < 0.0 {
                    bail!("poisson wants one non-negative rate, got {rest:?}");
                }
                Ok(ArrivalProcess::Poisson { rate: v[0] })
            }
            "mmpp" => {
                let v = nums(rest)?;
                if v.len() != 4 {
                    bail!("mmpp wants low_rate,high_rate,mean_low_s,mean_high_s, got {rest:?}");
                }
                if v.iter().any(|&x| x < 0.0) || v[2] <= 0.0 || v[3] <= 0.0 {
                    bail!("mmpp rates must be ≥ 0 and dwell times > 0, got {rest:?}");
                }
                Ok(ArrivalProcess::Mmpp {
                    low_rate: v[0],
                    high_rate: v[1],
                    mean_low_s: v[2],
                    mean_high_s: v[3],
                })
            }
            "diurnal" => {
                let v = nums(rest)?;
                if v.len() != 3 {
                    bail!("diurnal wants base_rate,amplitude,period_s, got {rest:?}");
                }
                if v[0] < 0.0 || !(0.0..=1.0).contains(&v[1]) || v[2] <= 0.0 {
                    bail!("diurnal wants rate ≥ 0, amplitude ∈ [0,1], period > 0, got {rest:?}");
                }
                Ok(ArrivalProcess::Diurnal { base_rate: v[0], amplitude: v[1], period_s: v[2] })
            }
            "piecewise" => {
                let mut segments = Vec::new();
                for part in rest.split(',') {
                    let (r, t) = part
                        .split_once('@')
                        .with_context(|| format!("piecewise segment {part:?}: want rate@start"))?;
                    let rate: f64 = r.trim().parse().map_err(|e| {
                        anyhow::anyhow!("piecewise rate {r:?}: {e}")
                    })?;
                    let start: f64 = t.trim().parse().map_err(|e| {
                        anyhow::anyhow!("piecewise start {t:?}: {e}")
                    })?;
                    if rate < 0.0 || start < 0.0 {
                        bail!("piecewise segment {part:?}: negative value");
                    }
                    segments.push((start, rate));
                }
                if segments.is_empty() {
                    bail!("piecewise wants at least one rate@start segment");
                }
                if segments.windows(2).any(|w| w[0].0 >= w[1].0) {
                    bail!("piecewise segment starts must be strictly ascending");
                }
                Ok(ArrivalProcess::Piecewise { segments })
            }
            "trace" => {
                let text = std::fs::read_to_string(rest.trim())
                    .with_context(|| format!("reading arrival trace {rest:?}"))?;
                Self::parse_trace(&text)
            }
            other => bail!("unknown arrival kind {other:?} (poisson, mmpp, diurnal, piecewise, trace)"),
        }
    }

    /// Parse a trace body: one timestamp (seconds) per line; `#` comments
    /// and blank lines ignored. Timestamps must be non-negative ascending.
    pub fn parse_trace(text: &str) -> Result<ArrivalProcess> {
        let mut times = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let s = line.trim();
            if s.is_empty() || s.starts_with('#') {
                continue;
            }
            let t: f64 = s
                .parse()
                .map_err(|e| anyhow::anyhow!("trace line {}: {s:?}: {e}", ln + 1))?;
            if t < 0.0 {
                bail!("trace line {}: negative timestamp {t}", ln + 1);
            }
            times.push(t);
        }
        if times.windows(2).any(|w| w[0] > w[1]) {
            bail!("trace timestamps must be ascending");
        }
        Ok(ArrivalProcess::Trace { times })
    }

    /// Mean rate over `[0, horizon_s]` (for reporting / load estimates).
    pub fn mean_rate(&self, horizon_s: f64) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate } => *rate,
            ArrivalProcess::Mmpp { low_rate, high_rate, mean_low_s, mean_high_s } => {
                // stationary distribution of the two-state chain
                let p_high = mean_high_s / (mean_low_s + mean_high_s);
                low_rate * (1.0 - p_high) + high_rate * p_high
            }
            ArrivalProcess::Diurnal { base_rate, .. } => *base_rate,
            ArrivalProcess::Piecewise { segments } => {
                if horizon_s <= 0.0 {
                    return segments.first().map_or(0.0, |&(_, r)| r);
                }
                let mut acc = 0.0;
                for (i, &(start, rate)) in segments.iter().enumerate() {
                    let end = segments.get(i + 1).map_or(horizon_s, |&(s, _)| s).min(horizon_s);
                    if end > start {
                        acc += rate * (end - start);
                    }
                }
                acc / horizon_s
            }
            ArrivalProcess::Trace { times } => {
                if horizon_s <= 0.0 {
                    0.0
                } else {
                    times.iter().filter(|&&t| t <= horizon_s).count() as f64 / horizon_s
                }
            }
        }
    }

    /// Instantiate a sampler with its own RNG stream.
    pub fn sampler(&self, rng: Xoshiro256) -> ArrivalSampler {
        ArrivalSampler {
            proc: self.clone(),
            rng,
            mmpp_high: false,
            mmpp_switch_s: f64::NEG_INFINITY,
            trace_idx: 0,
        }
    }
}

/// Stateful arrival-time generator; yields strictly increasing timestamps.
#[derive(Debug, Clone)]
pub struct ArrivalSampler {
    proc: ArrivalProcess,
    rng: Xoshiro256,
    /// MMPP: currently in the high state?
    mmpp_high: bool,
    /// MMPP: time at which the current state ends.
    mmpp_switch_s: f64,
    /// Trace: next index to replay.
    trace_idx: usize,
}

/// Exponential variate with the given rate (mean 1/rate). Free function
/// over the RNG so the sampler can borrow its process parameters and its
/// RNG as disjoint fields (no per-sample clone of the process).
fn exp_var(rng: &mut Xoshiro256, rate: f64) -> f64 {
    // 1 − u ∈ (0, 1] so ln is finite
    -(1.0 - rng.gen_f64()).ln() / rate
}

impl ArrivalSampler {
    /// Next arrival after `now` (strictly after for the stochastic
    /// processes; traces replay entries **at or after** `now`, each entry
    /// exactly once, so a `t = 0` first arrival and simultaneous
    /// timestamps are preserved), or `None` when the process is exhausted
    /// (trace ended / rate zero forever).
    pub fn next_after(&mut self, now: f64) -> Option<f64> {
        match &self.proc {
            ArrivalProcess::Trace { times } => {
                while self.trace_idx < times.len() {
                    let t = times[self.trace_idx];
                    self.trace_idx += 1;
                    if t >= now {
                        return Some(t);
                    }
                }
                None
            }
            ArrivalProcess::Poisson { rate } => {
                if *rate <= 0.0 {
                    return None;
                }
                Some(now + exp_var(&mut self.rng, *rate))
            }
            ArrivalProcess::Mmpp { low_rate, high_rate, mean_low_s, mean_high_s } => {
                let (lo, hi, ml, mh) = (*low_rate, *high_rate, *mean_low_s, *mean_high_s);
                if lo <= 0.0 && hi <= 0.0 {
                    return None;
                }
                // initialise the state machine on first use
                if self.mmpp_switch_s == f64::NEG_INFINITY {
                    self.mmpp_high = false;
                    let dwell = exp_var(&mut self.rng, 1.0 / ml);
                    self.mmpp_switch_s = now + dwell;
                }
                let mut t = now;
                loop {
                    let rate = if self.mmpp_high { hi } else { lo };
                    let candidate = if rate > 0.0 {
                        let dt = exp_var(&mut self.rng, rate);
                        Some(t + dt)
                    } else {
                        None
                    };
                    match candidate {
                        // arrival lands inside the current state: accept
                        Some(c) if c < self.mmpp_switch_s => return Some(c),
                        // otherwise advance to the state switch and retry
                        // (the exponential is memoryless, so resampling in
                        // the new state is exact)
                        _ => {
                            t = self.mmpp_switch_s;
                            self.mmpp_high = !self.mmpp_high;
                            let mean = if self.mmpp_high { mh } else { ml };
                            let dwell = exp_var(&mut self.rng, 1.0 / mean);
                            self.mmpp_switch_s = t + dwell;
                        }
                    }
                }
            }
            ArrivalProcess::Diurnal { base_rate, amplitude, period_s } => {
                let (base, amp, period) = (*base_rate, *amplitude, *period_s);
                if base <= 0.0 {
                    return None;
                }
                // Lewis–Shedler thinning against λ_max = base·(1+amp)
                let lambda_max = base * (1.0 + amp);
                let mut t = now;
                for _ in 0..1_000_000 {
                    t += exp_var(&mut self.rng, lambda_max);
                    let lambda_t = base
                        * (1.0 + amp * (2.0 * std::f64::consts::PI * t / period).sin());
                    if self.rng.gen_f64() * lambda_max <= lambda_t {
                        return Some(t);
                    }
                }
                None // pathological parameters; treat as silence
            }
            ArrivalProcess::Piecewise { segments } => {
                let segs = segments;
                let mut t = now;
                loop {
                    // active segment at time t (last segment whose start ≤ t);
                    // before the first segment the rate is 0
                    let idx = match segs.iter().rposition(|&(s, _)| s <= t) {
                        Some(i) => i,
                        None => {
                            t = segs[0].0;
                            0
                        }
                    };
                    let (_, rate) = segs[idx];
                    let seg_end = segs.get(idx + 1).map_or(f64::INFINITY, |&(s, _)| s);
                    if rate <= 0.0 {
                        if seg_end.is_infinite() {
                            return None; // silent forever
                        }
                        t = seg_end;
                        continue;
                    }
                    let candidate = t + exp_var(&mut self.rng, rate);
                    if candidate < seg_end {
                        return Some(candidate);
                    }
                    t = seg_end; // memoryless: resample in the next segment
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn count_until(proc: &ArrivalProcess, seed: u64, horizon: f64) -> usize {
        let mut s = proc.sampler(Xoshiro256::seed_from(seed));
        let mut t = 0.0;
        let mut n = 0;
        while let Some(next) = s.next_after(t) {
            if next > horizon {
                break;
            }
            t = next;
            n += 1;
        }
        n
    }

    #[test]
    fn poisson_count_near_rate() {
        let p = ArrivalProcess::Poisson { rate: 100.0 };
        let n = count_until(&p, 42, 50.0) as f64;
        // 5000 expected, σ ≈ 71 — allow ±5σ
        assert!((4650.0..=5350.0).contains(&n), "poisson count {n}");
    }

    #[test]
    fn poisson_strictly_increasing_and_deterministic() {
        let p = ArrivalProcess::Poisson { rate: 50.0 };
        let run = |seed| {
            let mut s = p.sampler(Xoshiro256::seed_from(seed));
            let mut t = 0.0;
            let mut out = Vec::new();
            for _ in 0..200 {
                let next = s.next_after(t).unwrap();
                assert!(next > t);
                t = next;
                out.push(next);
            }
            out
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn zero_rate_is_silence() {
        assert_eq!(count_until(&ArrivalProcess::Poisson { rate: 0.0 }, 1, 100.0), 0);
    }

    #[test]
    fn mmpp_mixes_rates() {
        let p = ArrivalProcess::Mmpp {
            low_rate: 10.0,
            high_rate: 400.0,
            mean_low_s: 2.0,
            mean_high_s: 2.0,
        };
        let n = count_until(&p, 3, 200.0) as f64;
        let mean = p.mean_rate(200.0) * 200.0; // 205 · 200 = 41000
        assert!(n > 0.5 * mean && n < 1.5 * mean, "mmpp count {n} vs mean {mean}");
        // must exceed pure-low and undercut pure-high
        assert!(n > 10.0 * 200.0 * 1.5);
        assert!(n < 400.0 * 200.0 * 0.9);
    }

    #[test]
    fn diurnal_count_near_base_rate_over_full_periods() {
        let p = ArrivalProcess::Diurnal { base_rate: 100.0, amplitude: 0.8, period_s: 10.0 };
        // 20 full periods: modulation integrates out
        let n = count_until(&p, 11, 200.0) as f64;
        assert!((18000.0..=22000.0).contains(&n), "diurnal count {n}");
    }

    #[test]
    fn piecewise_rates_shift_at_boundaries() {
        let p = ArrivalProcess::Piecewise { segments: vec![(0.0, 100.0), (50.0, 0.0), (80.0, 400.0)] };
        let mut s = p.sampler(Xoshiro256::seed_from(5));
        let mut t = 0.0;
        let (mut n_a, mut n_b, mut n_c) = (0, 0, 0);
        while let Some(next) = s.next_after(t) {
            if next > 100.0 {
                break;
            }
            t = next;
            if t < 50.0 {
                n_a += 1;
            } else if t < 80.0 {
                n_b += 1;
            } else {
                n_c += 1;
            }
        }
        assert!((4000..=6000).contains(&n_a), "segment A {n_a}");
        assert_eq!(n_b, 0, "silent segment must produce nothing");
        assert!((7000..=9000).contains(&n_c), "segment C {n_c}");
    }

    #[test]
    fn trace_replays_exact_times() {
        let p = ArrivalProcess::parse_trace("# demo\n0.5\n1.0\n\n2.25\n").unwrap();
        let mut s = p.sampler(Xoshiro256::seed_from(0));
        assert_eq!(s.next_after(0.0), Some(0.5));
        assert_eq!(s.next_after(0.5), Some(1.0));
        assert_eq!(s.next_after(1.0), Some(2.25));
        assert_eq!(s.next_after(2.25), None);
    }

    #[test]
    fn trace_keeps_time_zero_and_simultaneous_arrivals() {
        let p = ArrivalProcess::parse_trace("0\n1.0\n1.0\n").unwrap();
        let mut s = p.sampler(Xoshiro256::seed_from(0));
        assert_eq!(s.next_after(0.0), Some(0.0), "t=0 entry must not be dropped");
        assert_eq!(s.next_after(0.0), Some(1.0));
        assert_eq!(s.next_after(1.0), Some(1.0), "duplicate timestamps each replay once");
        assert_eq!(s.next_after(1.0), None);
    }

    #[test]
    fn parse_specs_roundtrip() {
        assert_eq!(
            ArrivalProcess::parse("poisson:200").unwrap(),
            ArrivalProcess::Poisson { rate: 200.0 }
        );
        assert_eq!(
            ArrivalProcess::parse("mmpp:50,400,5,1").unwrap(),
            ArrivalProcess::Mmpp { low_rate: 50.0, high_rate: 400.0, mean_low_s: 5.0, mean_high_s: 1.0 }
        );
        assert_eq!(
            ArrivalProcess::parse("diurnal:200,0.8,60").unwrap(),
            ArrivalProcess::Diurnal { base_rate: 200.0, amplitude: 0.8, period_s: 60.0 }
        );
        assert_eq!(
            ArrivalProcess::parse("piecewise:100@0,400@30").unwrap(),
            ArrivalProcess::Piecewise { segments: vec![(0.0, 100.0), (30.0, 400.0)] }
        );
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "poisson",
            "poisson:-5",
            "mmpp:1,2,3",
            "diurnal:100,1.5,60",
            "piecewise:100@30,400@10",
            "warp:9",
        ] {
            assert!(ArrivalProcess::parse(bad).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn mean_rate_estimates() {
        let p = ArrivalProcess::Piecewise { segments: vec![(0.0, 100.0), (50.0, 300.0)] };
        assert!((p.mean_rate(100.0) - 200.0).abs() < 1e-9);
        let m = ArrivalProcess::Mmpp { low_rate: 0.0, high_rate: 100.0, mean_low_s: 1.0, mean_high_s: 1.0 };
        assert!((m.mean_rate(10.0) - 50.0).abs() < 1e-9);
    }
}
