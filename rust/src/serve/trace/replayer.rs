//! Replay: re-drive the engine from a captured [`Trace`].
//!
//! Two modes, per the flight-recorder contract:
//!
//! * **Full replay** ([`replay_full`]) re-simulates the recorded inputs
//!   and *asserts* the outcome is bit-identical to the live run — same
//!   event stream, same `log_hash`, same event count, same per-tenant
//!   counters. Any divergence is an error naming the first mismatch;
//!   success certifies the engine is still a pure function of the trace's
//!   inputs (the determinism property every golden test relies on).
//! * **What-if replay** ([`replay_whatif`]) keeps only the captured
//!   *arrival streams* — the workload — and re-simulates them under
//!   overridden policy ([`WhatIf`]: shard count, balancer, autoscale,
//!   co-planning): "would 3 shards have held p99 through yesterday's
//!   storm?". Request conservation (offered = captured arrivals, per
//!   tenant) is checked on every run.

use anyhow::{bail, ensure, Context, Result};

use crate::pipeline::PipelineConfig;
use crate::platform::Platform;

use super::super::arrivals::ArrivalProcess;
use super::super::cluster::{AutoscaleOptions, ElasticOptions};
use super::super::engine::{serve, serve_observed, serve_traced, ServeOptions, ServeReport};
use super::super::fault::FaultScript;
use super::super::lifecycle::HedgePolicy;
use super::super::obs::ObsReport;
use super::super::shard::BalancerPolicy;
use super::super::tenant::TenantSpec;
use super::recorder::Trace;

/// Full replay: re-simulate the trace's inputs and verify the outcome is
/// byte-identical to the recorded run.
///
/// Returns the replayed report (which equals the live one) on success;
/// errors with the first point of divergence otherwise.
pub fn replay_full(trace: &Trace) -> Result<ServeReport> {
    let (report, replayed) =
        serve_traced(&trace.platform, trace.tenants.clone(), &trace.opts)
            .context("re-simulating recorded inputs")?;

    if replayed.events.len() != trace.events.len() {
        bail!(
            "full replay diverged: recorded {} events, replay produced {}",
            trace.events.len(),
            replayed.events.len()
        );
    }
    for (i, (want, got)) in trace.events.iter().zip(&replayed.events).enumerate() {
        if want != got {
            bail!(
                "full replay diverged at event {i}: recorded tag {} a {} b {} t {:.9}, \
                 replay tag {} a {} b {} t {:.9}",
                want.tag,
                want.a,
                want.b,
                want.t_s,
                got.tag,
                got.a,
                got.b,
                got.t_s
            );
        }
    }
    ensure!(
        report.log_hash == trace.summary.log_hash,
        "full replay diverged: recorded log_hash {:016x}, replay {:016x}",
        trace.summary.log_hash,
        report.log_hash
    );
    ensure!(
        report.n_events == trace.summary.n_events,
        "full replay diverged: recorded {} engine events, replay {}",
        trace.summary.n_events,
        report.n_events
    );
    ensure!(
        report.truncated == trace.summary.truncated,
        "full replay diverged on the truncation flag"
    );
    ensure!(
        replayed.summary.tenants == trace.summary.tenants,
        "full replay diverged in per-tenant counters: recorded {:?}, replay {:?}",
        trace.summary.tenants,
        replayed.summary.tenants
    );
    Ok(report)
}

/// Observed replay — the `trace analyze` engine: re-simulate the trace's
/// inputs with the telemetry plane on, deriving the epoch time series and
/// the causality journal retroactively from any recorded trace.
///
/// Telemetry lives beside the hash funnel, so the replay must reproduce
/// the recording exactly: the `log_hash`, event count and truncation flag
/// are checked against the summary, and the derived journal must match
/// the recorded control section record-for-record (the journal adds the
/// triggering signals the binary format does not carry). The resulting
/// [`ObsReport::to_jsonl`] is byte-identical to what a live
/// `serve --metrics` run of the same inputs would have written.
pub fn replay_observed(trace: &Trace) -> Result<(ServeReport, ObsReport)> {
    let (report, obs) = serve_observed(&trace.platform, trace.tenants.clone(), &trace.opts)
        .context("re-simulating recorded inputs with telemetry")?;
    ensure!(
        report.log_hash == trace.summary.log_hash,
        "observed replay diverged: recorded log_hash {:016x}, replay {:016x}",
        trace.summary.log_hash,
        report.log_hash
    );
    ensure!(
        report.n_events == trace.summary.n_events,
        "observed replay diverged: recorded {} engine events, replay {}",
        trace.summary.n_events,
        report.n_events
    );
    ensure!(
        report.truncated == trace.summary.truncated,
        "observed replay diverged on the truncation flag"
    );
    ensure!(
        obs.journal.entries.len() == trace.controls.len(),
        "observed replay diverged: recorded {} control records, derived journal has {}",
        trace.controls.len(),
        obs.journal.entries.len()
    );
    for (i, (want, got)) in trace.controls.iter().zip(&obs.journal.entries).enumerate() {
        let same = want.t_s.to_bits() == got.t_s.to_bits()
            && want.kind == got.kind
            && want.tenant == got.tenant
            && want.shard == got.shard
            && want.a == got.a
            && want.b == got.b;
        ensure!(
            same,
            "observed replay diverged at control record {i}: recorded {want:?}, \
             derived t={} kind={} tenant={} shard={} a={} b={}",
            got.t_s,
            got.kind.name(),
            got.tenant,
            got.shard,
            got.a,
            got.b
        );
    }
    Ok((report, obs))
}

/// Policy overrides for arrivals-only what-if replay. Every field is
/// optional; unset fields keep the recorded run's value.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WhatIf {
    /// Override every tenant's maximum replica count.
    pub shards: Option<usize>,
    /// Override every tenant's load balancer.
    pub balancer: Option<BalancerPolicy>,
    /// Force the runtime autoscaler on or off.
    pub autoscale: Option<bool>,
    /// Override the autoscaler's active-replica floor.
    pub min_shards: Option<usize>,
    /// Force cross-tenant co-planning on or off.
    pub coplan: Option<bool>,
    /// Force the elastic re-planning loop on or off (turning it on also
    /// forces co-planning on — the loop re-partitions the co-plan).
    pub elastic: Option<bool>,
    /// Replace the recorded fault script: `faults=none` strips the
    /// recorded faults ("how would the run have gone without the
    /// outage?"), `faults=<script>` injects a different one (the
    /// [`FaultScript`] grammar is `;`-separated and comma-free, so it
    /// nests inside the comma-separated override list).
    pub faults: Option<FaultScript>,
    /// Force request hedging on or off: `hedge=off` strips every
    /// tenant's hedge policy ("would the storm have been survivable
    /// without hedging?"), `hedge=on` gives every multi-replica tenant
    /// the default [`HedgePolicy`] unless it already carries one.
    pub hedge: Option<bool>,
}

impl WhatIf {
    /// Parse a CLI override list: comma-separated `key=value` pairs with
    /// keys `shards`, `balancer`, `autoscale`, `min-shards`, `coplan`,
    /// `elastic`, `faults`, `hedge`
    /// (e.g. `shards=4,balancer=jsq,faults=none,hedge=off`).
    /// The `faults`
    /// value is either `none`/`off` (strip the recorded script) or a
    /// [`FaultScript`] spec — `;`-separated, so it fits in one pair.
    /// Unknown keys error by name.
    pub fn parse(s: &str) -> Result<Self> {
        let mut w = WhatIf::default();
        for pair in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some((key, value)) = pair.split_once('=') else {
                bail!("what-if override {pair:?} is not key=value");
            };
            let (key, value) = (key.trim(), value.trim());
            match key {
                "shards" => {
                    let k: usize = value
                        .parse()
                        .with_context(|| format!("what-if shards value {value:?}"))?;
                    ensure!(k >= 1, "what-if shards must be ≥ 1");
                    w.shards = Some(k);
                }
                "balancer" => w.balancer = Some(BalancerPolicy::parse(value)?),
                "autoscale" => w.autoscale = Some(parse_switch(key, value)?),
                "min-shards" | "min_shards" => {
                    let k: usize = value
                        .parse()
                        .with_context(|| format!("what-if min-shards value {value:?}"))?;
                    ensure!(k >= 1, "what-if min-shards must be ≥ 1");
                    w.min_shards = Some(k);
                }
                "coplan" => w.coplan = Some(parse_switch(key, value)?),
                "elastic" => w.elastic = Some(parse_switch(key, value)?),
                "hedge" => w.hedge = Some(parse_switch(key, value)?),
                "faults" => {
                    w.faults = Some(match value.to_ascii_lowercase().as_str() {
                        "none" | "off" => FaultScript::default(),
                        _ => FaultScript::parse(value)
                            .with_context(|| format!("what-if faults value {value:?}"))?,
                    });
                }
                other => bail!(
                    "unknown what-if key {other:?} (allowed: shards, balancer, autoscale, \
                     min-shards, coplan, elastic, faults, hedge)"
                ),
            }
        }
        Ok(w)
    }

    /// True when no override is set (what-if degenerates to re-serving the
    /// captured arrivals under the recorded policy).
    pub fn is_empty(&self) -> bool {
        *self == WhatIf::default()
    }

    /// Short display form, e.g. `shards=4 balancer=jsq`.
    pub fn describe(&self) -> String {
        let mut parts = Vec::new();
        if let Some(k) = self.shards {
            parts.push(format!("shards={k}"));
        }
        if let Some(b) = self.balancer {
            parts.push(format!("balancer={}", b.name()));
        }
        if let Some(on) = self.autoscale {
            parts.push(format!("autoscale={}", if on { "on" } else { "off" }));
        }
        if let Some(k) = self.min_shards {
            parts.push(format!("min-shards={k}"));
        }
        if let Some(on) = self.coplan {
            parts.push(format!("coplan={}", if on { "on" } else { "off" }));
        }
        if let Some(on) = self.elastic {
            parts.push(format!("elastic={}", if on { "on" } else { "off" }));
        }
        if let Some(f) = &self.faults {
            if f.is_empty() {
                parts.push("faults=none".into());
            } else {
                parts.push(format!("faults=[{}]", f.describe()));
            }
        }
        if let Some(on) = self.hedge {
            parts.push(format!("hedge={}", if on { "on" } else { "off" }));
        }
        if parts.is_empty() {
            "(no overrides)".into()
        } else {
            parts.join(" ")
        }
    }
}

fn parse_switch(key: &str, value: &str) -> Result<bool> {
    match value.to_ascii_lowercase().as_str() {
        "on" | "true" | "1" | "yes" => Ok(true),
        "off" | "false" | "0" | "no" => Ok(false),
        other => bail!("what-if {key} value {other:?} is not on/off"),
    }
}

/// Build the serve inputs for an arrivals-only what-if run: every tenant's
/// arrival process is replaced by its *captured* arrival timestamps
/// ([`ArrivalProcess::Trace`], replayed verbatim and RNG-free), then the
/// [`WhatIf`] overrides are applied on top of the recorded spec/options.
///
/// The returned inputs plug straight into [`serve`] or into a
/// [`crate::serve::sweep::Scenario`] (see
/// [`crate::serve::sweep::whatif_grid`]).
pub fn whatif_inputs(
    trace: &Trace,
    what_if: &WhatIf,
) -> Result<(Platform, Vec<(TenantSpec, PipelineConfig)>, ServeOptions)> {
    ensure!(!trace.tenants.is_empty(), "trace has no tenants");
    let mut tenants = Vec::with_capacity(trace.tenants.len());
    for (ti, (spec, config)) in trace.tenants.iter().enumerate() {
        let mut spec = spec.clone();
        spec.arrivals = ArrivalProcess::Trace { times: trace.arrival_times(ti) };
        if let Some(k) = what_if.shards {
            spec.shards = k;
        }
        if let Some(b) = what_if.balancer {
            spec.balancer = b;
        }
        match what_if.hedge {
            Some(false) => spec.hedge = None,
            Some(true) => {
                if spec.hedge.is_none() && spec.shards > 1 {
                    spec.hedge = Some(HedgePolicy::default());
                }
            }
            None => {}
        }
        tenants.push((spec, config.clone()));
    }
    let mut opts = trace.opts.clone();
    // The captured arrival stream is the workload; the replay needs no
    // human-readable log.
    opts.record_log = false;
    if let Some(on) = what_if.coplan {
        opts.coplan = on;
    }
    if let Some(on) = what_if.autoscale {
        if on && !opts.autoscale.enabled {
            opts.autoscale = AutoscaleOptions::enabled();
        }
        opts.autoscale.enabled = on;
    }
    if let Some(k) = what_if.min_shards {
        opts.autoscale.min_shards = k;
    }
    if let Some(on) = what_if.elastic {
        if on && !opts.elastic.enabled {
            opts.elastic = ElasticOptions::enabled();
        }
        opts.elastic.enabled = on;
        // the elastic loop re-partitions the co-plan, so forcing it on
        // pulls the co-planner (and a control epoch) in with it
        if on {
            opts.coplan = true;
            if opts.control_epoch_s <= 0.0 {
                opts.control_epoch_s = opts.duration_s / 20.0;
            }
        }
    }
    if let Some(f) = &what_if.faults {
        f.validate(&trace.platform).context("what-if fault script")?;
        opts.faults = f.clone();
    }
    Ok((trace.platform.clone(), tenants, opts))
}

/// Arrivals-only what-if replay: re-simulate the captured workload under
/// the overridden policy and verify request conservation — every captured
/// arrival is offered exactly once in the counterfactual run.
pub fn replay_whatif(trace: &Trace, what_if: &WhatIf) -> Result<ServeReport> {
    let (plat, tenants, opts) = whatif_inputs(trace, what_if)?;
    let report = serve(&plat, tenants, &opts)
        .with_context(|| format!("what-if replay ({})", what_if.describe()))?;
    if !report.truncated {
        for (ti, t) in report.tenants.iter().enumerate() {
            // `arrival_times` filters tag-1 events, so lifecycle
            // re-arrivals (retry, tag 10) and twins (hedge, tag 11) are
            // excluded from `captured` — they inflate `offered` in the
            // counterfactual run and must be added back to conserve.
            let captured = trace.arrival_times(ti).len() as u64;
            ensure!(
                t.offered == captured + t.retried + t.hedged,
                "what-if replay lost requests: tenant {ti} ({}) captured {captured} arrivals \
                 (+{} retries, +{} hedges) but the replay offered {}",
                t.name,
                t.retried,
                t.hedged,
                t.offered
            );
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn whatif_parse_round_trips() {
        let w = WhatIf::parse(
            "shards=4,balancer=jsq,autoscale=on,min-shards=2,coplan=off,elastic=on,hedge=off",
        )
        .unwrap();
        assert_eq!(w.shards, Some(4));
        assert_eq!(w.elastic, Some(true));
        assert_eq!(w.balancer, Some(BalancerPolicy::JoinShortestQueue));
        assert_eq!(w.autoscale, Some(true));
        assert_eq!(w.min_shards, Some(2));
        assert_eq!(w.coplan, Some(false));
        assert_eq!(w.hedge, Some(false));
        assert_eq!(
            w.describe(),
            "shards=4 balancer=jsq autoscale=on min-shards=2 coplan=off elastic=on hedge=off"
        );
    }

    #[test]
    fn whatif_parse_accepts_empty_and_whitespace() {
        assert!(WhatIf::parse("").unwrap().is_empty());
        assert!(WhatIf::parse(" , ").unwrap().is_empty());
        let w = WhatIf::parse(" shards = 2 ").unwrap();
        assert_eq!(w.shards, Some(2));
    }

    #[test]
    fn whatif_parse_faults_override() {
        // `none` strips the recorded script: the override is Some but empty.
        let w = WhatIf::parse("faults=none").unwrap();
        assert_eq!(w.faults, Some(FaultScript::default()));
        assert!(!w.is_empty());
        assert_eq!(w.describe(), "faults=none");

        // A `;`-separated script nests inside the comma-separated list.
        let w = WhatIf::parse("shards=2,faults=epfail:1@5; linkcut@8+2").unwrap();
        assert_eq!(w.shards, Some(2));
        let f = w.faults.as_ref().unwrap();
        assert_eq!(f.events.len(), 2);
        assert!(w.describe().starts_with("shards=2 faults=["), "{}", w.describe());

        // Malformed scripts error through the what-if parser.
        let err = WhatIf::parse("faults=epfail:bogus@5").unwrap_err().to_string();
        assert!(err.contains("faults"), "{err}");
    }

    #[test]
    fn whatif_parse_names_the_offending_key() {
        let err = WhatIf::parse("shard=4").unwrap_err().to_string();
        assert!(err.contains("shard"), "{err}");
        assert!(err.contains("allowed"), "{err}");
        assert!(WhatIf::parse("shards=zero").is_err());
        assert!(WhatIf::parse("shards=0").is_err());
        assert!(WhatIf::parse("autoscale=maybe").is_err());
        assert!(WhatIf::parse("balancer=xyz").is_err());
        assert!(WhatIf::parse("justaword").is_err());
    }
}
