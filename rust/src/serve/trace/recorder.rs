//! Flight-recorder capture: the in-engine event sink and the serialized
//! [`Trace`] container.
//!
//! [`Capture`] is what the engine writes into while serving: a pair of
//! preallocated vectors the hot path appends to (no per-event allocation,
//! no formatting — the human-readable log remains a separate, optional
//! channel). After the run, [`Trace::assemble`] freezes the capture
//! together with the run's *inputs* (platform, tenants, options — enough
//! to re-simulate from scratch) and a summary of its *outputs* (log hash,
//! per-tenant counters — enough to verify a replay without re-reading the
//! live report).

use anyhow::{bail, Context, Result};

use crate::model::{Layer, LayerKind, Network};
use crate::pipeline::PipelineConfig;
use crate::platform::{
    CoreType, ExecutionPlace, InterChipletLink, MemoryClass, MeshTopology, Platform,
};

use super::super::arrivals::ArrivalProcess;
use super::super::cluster::{AutoscaleOptions, ElasticOptions};
use super::super::obs;
use super::super::engine::{PumpMode, ServeOptions, ServeReport};
use super::super::fault::{FaultEvent, FaultKind, FaultScript};
use super::super::lifecycle::{HedgePolicy, RetryPolicy};
use super::super::shard::BalancerPolicy;
use super::super::tenant::{AdmissionPolicy, TenantSpec};
use super::format::{
    get_event, put_event, put_f64, put_section, put_str, put_varint, Reader, TraceEvent, MAGIC,
    MIN_VERSION, SEC_CONTROLS, SEC_EVENTS, SEC_INPUTS, SEC_SUMMARY, VERSION,
};

/// Which control-plane mechanism produced a [`ControlRecord`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControlKind {
    /// A warm re-tune attempt at an epoch tick (`a` = evaluator trials,
    /// `b` = 1 if the configuration actually changed).
    Retune,
    /// A co-plan allocation at serve start (`shard` = placement count,
    /// `a` = EP budget size, `b` = predicted throughput bits).
    Coplan,
    /// An autoscaler replica transition (`b` = the
    /// [`crate::serve::ReplicaState`] code entered).
    Scale,
    /// A scripted fault boundary fired (`shard` = script event index,
    /// `a` = the [`crate::serve::FaultKind`] wire code, `b` = 1 for the
    /// window begin / 0 for its end).
    Fault,
    /// A replica failed over (or recovered) onto a re-planned EP subset
    /// (`a` = surviving subset size, `b` = predicted throughput bits).
    Failover,
    /// Graceful degradation toggled a tenant's admission (`b` = 1 when
    /// the tenant is shed, 0 when re-admitted).
    Shed,
    /// The elastic loop re-partitioned a tenant's EP budget from observed
    /// demand (`shard` = surviving replica count, `a` = new EP budget
    /// size, `b` = predicted throughput bits).
    Repartition,
    /// The lifecycle layer hedged a straggler onto a sibling replica
    /// (`shard` = destination replica, `a` = source replica, `b` =
    /// request id). Since trace version 4.
    Hedge,
}

impl ControlKind {
    /// Stable wire code.
    pub fn code(self) -> u8 {
        match self {
            ControlKind::Retune => 1,
            ControlKind::Coplan => 2,
            ControlKind::Scale => 3,
            ControlKind::Fault => 4,
            ControlKind::Failover => 5,
            ControlKind::Shed => 6,
            ControlKind::Repartition => 7,
            ControlKind::Hedge => 8,
        }
    }

    /// Decode a wire code.
    pub fn from_code(code: u8) -> Result<Self> {
        match code {
            1 => Ok(ControlKind::Retune),
            2 => Ok(ControlKind::Coplan),
            3 => Ok(ControlKind::Scale),
            4 => Ok(ControlKind::Fault),
            5 => Ok(ControlKind::Failover),
            6 => Ok(ControlKind::Shed),
            7 => Ok(ControlKind::Repartition),
            8 => Ok(ControlKind::Hedge),
            other => bail!("unknown control-record kind code {other}"),
        }
    }

    /// Human-readable name (for `trace inspect`).
    pub fn name(self) -> &'static str {
        match self {
            ControlKind::Retune => "retune",
            ControlKind::Coplan => "coplan",
            ControlKind::Scale => "scale",
            ControlKind::Fault => "fault",
            ControlKind::Failover => "failover",
            ControlKind::Shed => "shed",
            ControlKind::Repartition => "repartition",
            ControlKind::Hedge => "hedge",
        }
    }
}

/// One control-plane decision, recorded beside (not inside) the hashed
/// event stream so capture can annotate *why* the engine acted without
/// perturbing the live run's `log_hash`.
#[derive(Debug, Clone, Copy)]
pub struct ControlRecord {
    /// Simulated time of the decision, seconds.
    pub t_s: f64,
    /// Which mechanism decided.
    pub kind: ControlKind,
    /// Tenant index.
    pub tenant: u32,
    /// Shard index (kind-specific meaning for [`ControlKind::Coplan`]).
    pub shard: u32,
    /// First kind-specific payload word.
    pub a: u64,
    /// Second kind-specific payload word.
    pub b: u64,
}

impl PartialEq for ControlRecord {
    fn eq(&self, other: &Self) -> bool {
        self.t_s.to_bits() == other.t_s.to_bits()
            && self.kind == other.kind
            && self.tenant == other.tenant
            && self.shard == other.shard
            && self.a == other.a
            && self.b == other.b
    }
}

/// The engine-side event sink: appended to on the hot path, drained into a
/// [`Trace`] after the run.
#[derive(Debug, Clone, Default)]
pub struct Capture {
    /// Every hashed engine event, in heap order.
    pub events: Vec<TraceEvent>,
    /// Control-plane decisions, in decision order.
    pub controls: Vec<ControlRecord>,
}

impl Capture {
    /// A capture with preallocated buffers (the hot path then amortizes
    /// growth over thousands of pushes instead of paying per event).
    pub fn new() -> Self {
        Self { events: Vec::with_capacity(4096), controls: Vec::with_capacity(64) }
    }

    /// Record one hashed engine event.
    #[inline]
    pub fn event(&mut self, t_s: f64, tag: u64, a: u64, b: u64) {
        self.events.push(TraceEvent { t_s, tag, a, b });
    }

    /// Record one control-plane decision.
    pub fn control(&mut self, rec: ControlRecord) {
        self.controls.push(rec);
    }
}

/// Per-tenant outcome counters frozen into the trace summary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantSummary {
    /// Tenant name.
    pub name: String,
    /// Total arrivals offered.
    pub offered: u64,
    /// Arrivals rejected at admission.
    pub rejected: u64,
    /// Admitted requests dropped later.
    pub dropped: u64,
    /// Requests fully completed.
    pub completed: u64,
    /// Completions within the SLO.
    pub slo_ok: u64,
    /// Requests still in flight at the horizon.
    pub in_flight: u64,
    /// Warm re-tunes triggered.
    pub retunes: u64,
    /// Autoscaler transitions across all replicas.
    pub scale_events: u64,
    /// Requests reaped on deadline expiry (0 in pre-v4 traces).
    pub expired: u64,
    /// Hedge-loser copies cancelled (0 in pre-v4 traces).
    pub cancelled: u64,
    /// Retry re-arrivals offered (0 in pre-v4 traces).
    pub retried: u64,
    /// Hedge twins placed (0 in pre-v4 traces).
    pub hedged: u64,
}

/// Outcome summary of the recorded run: what full replay must reproduce.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceSummary {
    /// The live run's event-log hash.
    pub log_hash: u64,
    /// Events the live run processed.
    pub n_events: u64,
    /// Whether the live run hit the `max_events` safety valve.
    pub truncated: bool,
    /// Per-tenant counters.
    pub tenants: Vec<TenantSummary>,
}

/// A complete flight-recorder trace: the inputs of a serving run, its
/// hashed event stream, its control-plane decisions, and its outcome
/// summary — everything needed to re-simulate it bit-identically
/// ([`super::replay_full`]) or counterfactually
/// ([`super::replay_whatif`]).
#[derive(Debug, Clone)]
pub struct Trace {
    /// Platform the run was served on.
    pub platform: Platform,
    /// Tenant specs and their initial pipeline configurations.
    pub tenants: Vec<(TenantSpec, PipelineConfig)>,
    /// Engine options the run used.
    pub opts: ServeOptions,
    /// The hashed event stream.
    pub events: Vec<TraceEvent>,
    /// Control-plane decision records.
    pub controls: Vec<ControlRecord>,
    /// Outcome summary.
    pub summary: TraceSummary,
}

impl Trace {
    /// Freeze a finished capture into a trace.
    pub fn assemble(
        platform: Platform,
        tenants: Vec<(TenantSpec, PipelineConfig)>,
        opts: ServeOptions,
        capture: Capture,
        report: &ServeReport,
    ) -> Self {
        let tenant_summaries = report
            .tenants
            .iter()
            .map(|t| TenantSummary {
                name: t.name.clone(),
                offered: t.offered,
                rejected: t.rejected,
                dropped: t.dropped,
                completed: t.completed,
                slo_ok: t.slo_ok,
                in_flight: t.in_flight,
                retunes: u64::from(t.retunes),
                scale_events: t
                    .shards
                    .iter()
                    .map(|s| s.scale_events.len() as u64)
                    .sum(),
                expired: t.expired,
                cancelled: t.cancelled,
                retried: t.retried,
                hedged: t.hedged,
            })
            .collect();
        Self {
            platform,
            tenants,
            opts,
            events: capture.events,
            controls: capture.controls,
            summary: TraceSummary {
                log_hash: report.log_hash,
                n_events: report.n_events,
                truncated: report.truncated,
                tenants: tenant_summaries,
            },
        }
    }

    /// The captured arrival timestamps of tenant `tenant`, in event order
    /// (ascending — the heap pops in time order). This is the stream
    /// what-if replay re-sources through [`ArrivalProcess::Trace`].
    pub fn arrival_times(&self, tenant: usize) -> Vec<f64> {
        self.events
            .iter()
            .filter(|ev| ev.tag == 1 && ev.tenant() == tenant)
            .map(|ev| ev.t_s)
            .collect()
    }

    /// Wire version this trace encodes as: [`VERSION`] (4) when any
    /// tenant carries a lifecycle policy, 3 otherwise — so a
    /// lifecycle-off capture's bytes are identical to a pre-lifecycle
    /// build's, and decode → re-encode stays canonical per version.
    pub fn wire_version(&self) -> u8 {
        if self.tenants.iter().any(|(spec, _)| spec.lifecycle_active()) {
            VERSION
        } else {
            3
        }
    }

    /// Serialize to the binary `.trace` format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let version = self.wire_version();
        let mut inputs = Vec::new();
        put_platform(&mut inputs, &self.platform);
        put_varint(&mut inputs, self.tenants.len() as u64);
        for (spec, config) in &self.tenants {
            put_tenant_spec(&mut inputs, spec, version);
            put_config(&mut inputs, config);
        }
        put_opts(&mut inputs, &self.opts);

        let mut events = Vec::with_capacity(self.events.len() * 12);
        put_varint(&mut events, self.events.len() as u64);
        for ev in &self.events {
            put_event(&mut events, ev);
        }

        let mut controls = Vec::new();
        put_varint(&mut controls, self.controls.len() as u64);
        for rec in &self.controls {
            controls.push(rec.kind.code());
            put_varint(&mut controls, u64::from(rec.tenant));
            put_varint(&mut controls, u64::from(rec.shard));
            put_varint(&mut controls, rec.a);
            put_varint(&mut controls, rec.b);
            put_f64(&mut controls, rec.t_s);
        }

        let mut summary = Vec::new();
        summary.extend_from_slice(&self.summary.log_hash.to_le_bytes());
        put_varint(&mut summary, self.summary.n_events);
        summary.push(u8::from(self.summary.truncated));
        put_varint(&mut summary, self.summary.tenants.len() as u64);
        for t in &self.summary.tenants {
            put_str(&mut summary, &t.name);
            for x in [
                t.offered, t.rejected, t.dropped, t.completed, t.slo_ok, t.in_flight, t.retunes,
                t.scale_events,
            ] {
                put_varint(&mut summary, x);
            }
            if version >= 4 {
                for x in [t.expired, t.cancelled, t.retried, t.hedged] {
                    put_varint(&mut summary, x);
                }
            }
        }

        let mut out = Vec::with_capacity(
            5 + inputs.len() + events.len() + controls.len() + summary.len() + 4 * 10,
        );
        out.extend_from_slice(&MAGIC);
        out.push(version);
        put_section(&mut out, SEC_INPUTS, &inputs);
        put_section(&mut out, SEC_EVENTS, &events);
        put_section(&mut out, SEC_CONTROLS, &controls);
        put_section(&mut out, SEC_SUMMARY, &summary);
        out
    }

    /// Deserialize from the binary `.trace` format, verifying the magic,
    /// version, and every section CRC. Never panics on malformed input.
    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let magic = r.bytes(4).context("reading trace magic")?;
        if magic != MAGIC {
            bail!("not a shisha trace (magic {magic:02x?}, expected {MAGIC:02x?})");
        }
        let version = r.u8().context("reading trace version")?;
        if !(MIN_VERSION..=VERSION).contains(&version) {
            bail!(
                "unsupported trace version {version} \
                 (this build reads versions {MIN_VERSION} through {VERSION})"
            );
        }

        let mut inputs = r.take_section(SEC_INPUTS).context("inputs section")?;
        let platform = get_platform(&mut inputs).context("decoding platform")?;
        let n_tenants = inputs.varint().context("reading tenant count")? as usize;
        let mut tenants = Vec::with_capacity(n_tenants.min(1024));
        for ti in 0..n_tenants {
            let spec = get_tenant_spec(&mut inputs, version)
                .with_context(|| format!("decoding tenant {ti} spec"))?;
            let config = get_config(&mut inputs)
                .with_context(|| format!("decoding tenant {ti} config"))?;
            tenants.push((spec, config));
        }
        let opts = get_opts(&mut inputs, version).context("decoding serve options")?;
        if !inputs.is_empty() {
            bail!("{} trailing bytes after serve options in inputs section", inputs.remaining());
        }

        let mut evr = r.take_section(SEC_EVENTS).context("events section")?;
        let n_events = evr.varint().context("reading event count")? as usize;
        let mut events = Vec::with_capacity(n_events.min(1 << 20));
        for i in 0..n_events {
            events.push(get_event(&mut evr).with_context(|| format!("decoding event {i}"))?);
        }
        if !evr.is_empty() {
            bail!("{} trailing bytes in events section", evr.remaining());
        }

        let mut ctr = r.take_section(SEC_CONTROLS).context("controls section")?;
        let n_controls = ctr.varint().context("reading control count")? as usize;
        let mut controls = Vec::with_capacity(n_controls.min(1 << 16));
        for i in 0..n_controls {
            let kind = ControlKind::from_code(ctr.u8()?)
                .with_context(|| format!("decoding control record {i}"))?;
            let tenant = u32::try_from(ctr.varint()?)
                .with_context(|| format!("control record {i} tenant out of range"))?;
            let shard = u32::try_from(ctr.varint()?)
                .with_context(|| format!("control record {i} shard out of range"))?;
            let a = ctr.varint()?;
            let b = ctr.varint()?;
            let t_s = ctr.f64()?;
            controls.push(ControlRecord { t_s, kind, tenant, shard, a, b });
        }
        if !ctr.is_empty() {
            bail!("{} trailing bytes in controls section", ctr.remaining());
        }

        let mut smr = r.take_section(SEC_SUMMARY).context("summary section")?;
        let hash_raw = smr.bytes(8).context("reading summary log hash")?;
        let mut arr = [0u8; 8];
        arr.copy_from_slice(hash_raw);
        let log_hash = u64::from_le_bytes(arr);
        let sum_events = smr.varint().context("reading summary event count")?;
        let truncated = match smr.u8().context("reading truncated flag")? {
            0 => false,
            1 => true,
            other => bail!("truncated flag must be 0 or 1, found {other}"),
        };
        let n_sum = smr.varint().context("reading summary tenant count")? as usize;
        let mut tsums = Vec::with_capacity(n_sum.min(1024));
        for i in 0..n_sum {
            let name = smr.str().with_context(|| format!("summary tenant {i} name"))?;
            let mut vals = [0u64; 12];
            let n_counters = if version >= 4 { 12 } else { 8 };
            for v in vals.iter_mut().take(n_counters) {
                *v = smr.varint().with_context(|| format!("summary tenant {i} counters"))?;
            }
            tsums.push(TenantSummary {
                name,
                offered: vals[0],
                rejected: vals[1],
                dropped: vals[2],
                completed: vals[3],
                slo_ok: vals[4],
                in_flight: vals[5],
                retunes: vals[6],
                scale_events: vals[7],
                expired: vals[8],
                cancelled: vals[9],
                retried: vals[10],
                hedged: vals[11],
            });
        }
        if !smr.is_empty() {
            bail!("{} trailing bytes in summary section", smr.remaining());
        }
        if !r.is_empty() {
            bail!("{} trailing bytes after summary section", r.remaining());
        }

        Ok(Self {
            platform,
            tenants,
            opts,
            events,
            controls,
            summary: TraceSummary { log_hash, n_events: sum_events, truncated, tenants: tsums },
        })
    }

    /// Write the trace to `path`.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing trace to {}", path.display()))
    }

    /// Read a trace from `path`.
    pub fn load(path: &std::path::Path) -> Result<Self> {
        let buf = std::fs::read(path)
            .with_context(|| format!("reading trace from {}", path.display()))?;
        Self::from_bytes(&buf).with_context(|| format!("parsing trace {}", path.display()))
    }

    /// Multi-line human-readable summary (the `trace inspect` output).
    pub fn describe(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "trace: platform {} ({} EPs), {} tenant(s), horizon {:.3}s, seed {}",
            self.platform.name,
            self.platform.n_eps(),
            self.tenants.len(),
            self.opts.duration_s,
            self.opts.seed,
        );
        let _ = writeln!(
            out,
            "  events {} (hash {:016x}{})  control records {}",
            self.events.len(),
            self.summary.log_hash,
            if self.summary.truncated { ", TRUNCATED" } else { "" },
            self.controls.len(),
        );
        // Per-tag event census.
        let mut tag_counts: Vec<(u64, u64)> = Vec::new();
        for ev in &self.events {
            match tag_counts.iter_mut().find(|(t, _)| *t == ev.tag) {
                Some((_, n)) => *n += 1,
                None => tag_counts.push((ev.tag, 1)),
            }
        }
        tag_counts.sort_by_key(|&(t, _)| t);
        let census: Vec<String> = tag_counts
            .iter()
            .map(|&(t, n)| format!("{} {n}", TraceEvent::tag_name(t)))
            .collect();
        let _ = writeln!(out, "  event census: {}", census.join(", "));
        // Every replica ticks at every control epoch, so the tag-5 count
        // is each tenant's epoch count.
        let n_epochs = self.events.iter().filter(|ev| ev.tag == 5).count();
        for (ti, ts) in self.summary.tenants.iter().enumerate() {
            let arrivals = self.arrival_times(ti).len();
            let _ = writeln!(
                out,
                "  tenant {ti} {:<12} epochs {n_epochs:<4} offered {:<6} completed {:<6} \
                 slo_ok {:<6} shed {:<5} in-flight {:<4} retunes {:<3} scale-events {:<3} \
                 (captured arrivals {arrivals})",
                ts.name,
                ts.offered,
                ts.completed,
                ts.slo_ok,
                ts.rejected + ts.dropped,
                ts.in_flight,
                ts.retunes,
                ts.scale_events,
            );
        }
        // The decision timeline renders through the same line formatter as
        // `trace analyze` (`ObsReport::analysis`); inspect has no journal,
        // so the signal column is empty here.
        for rec in &self.controls {
            let _ = writeln!(
                out,
                "  control {}",
                obs::decision_line(rec.t_s, rec.kind.name(), rec.tenant, rec.shard, rec.a, rec.b, &[])
            );
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Input (de)serializers. Wire codes are part of the format: decode bails on
// any code this build does not know.
// ---------------------------------------------------------------------------

fn core_type_code(ct: CoreType) -> u8 {
    match ct {
        CoreType::Big => 0,
        CoreType::Little => 1,
    }
}

fn core_type_from(code: u8) -> Result<CoreType> {
    match code {
        0 => Ok(CoreType::Big),
        1 => Ok(CoreType::Little),
        other => bail!("unknown core-type code {other}"),
    }
}

fn memory_code(m: MemoryClass) -> u8 {
    match m {
        MemoryClass::Fast => 0,
        MemoryClass::Slow => 1,
    }
}

fn memory_from(code: u8) -> Result<MemoryClass> {
    match code {
        0 => Ok(MemoryClass::Fast),
        1 => Ok(MemoryClass::Slow),
        other => bail!("unknown memory-class code {other}"),
    }
}

fn put_platform(out: &mut Vec<u8>, plat: &Platform) {
    put_str(out, &plat.name);
    put_varint(out, plat.eps.len() as u64);
    for ep in &plat.eps {
        put_varint(out, ep.id as u64);
        out.push(core_type_code(ep.core_type));
        put_varint(out, u64::from(ep.n_cores));
        out.push(memory_code(ep.memory));
        put_varint(out, u64::from(ep.chiplet));
    }
    put_f64(out, plat.link.latency_s);
    put_f64(out, plat.link.bandwidth_gbs);
    match plat.topology {
        Some(topo) => {
            out.push(1);
            put_varint(out, u64::from(topo.width));
            put_varint(out, u64::from(topo.height));
        }
        None => out.push(0),
    }
}

fn get_platform(r: &mut Reader<'_>) -> Result<Platform> {
    let name = r.str().context("platform name")?;
    let n_eps = r.varint().context("platform EP count")? as usize;
    let mut eps = Vec::with_capacity(n_eps.min(1024));
    for i in 0..n_eps {
        let id = r.varint().with_context(|| format!("EP {i} id"))? as usize;
        let core_type = core_type_from(r.u8()?)?;
        let n_cores = u32::try_from(r.varint()?).with_context(|| format!("EP {i} cores"))?;
        let memory = memory_from(r.u8()?)?;
        let chiplet = u32::try_from(r.varint()?).with_context(|| format!("EP {i} chiplet"))?;
        eps.push(ExecutionPlace::new(id, core_type, n_cores, memory, chiplet));
    }
    // Platform::new renumbers ids densely (matching the serialized order),
    // then link and topology are restored verbatim.
    let mut plat = Platform::new(name, eps);
    plat.link = InterChipletLink {
        latency_s: r.f64().context("link latency")?,
        bandwidth_gbs: r.f64().context("link bandwidth")?,
    };
    plat.topology = match r.u8().context("topology flag")? {
        0 => None,
        1 => {
            let width = u32::try_from(r.varint()?).context("topology width")?;
            let height = u32::try_from(r.varint()?).context("topology height")?;
            Some(MeshTopology { width, height })
        }
        other => bail!("topology flag must be 0 or 1, found {other}"),
    };
    Ok(plat)
}

fn put_network(out: &mut Vec<u8>, net: &Network) {
    put_str(out, &net.name);
    put_varint(out, net.layers.len() as u64);
    for layer in &net.layers {
        put_str(out, &layer.name);
        for x in [layer.h, layer.w, layer.c, layer.r, layer.s, layer.k, layer.stride, layer.pad] {
            put_varint(out, u64::from(x));
        }
        out.push(match layer.kind {
            LayerKind::Conv => 0,
            LayerKind::Dense => 1,
        });
    }
}

fn get_network(r: &mut Reader<'_>) -> Result<Network> {
    let name = r.str().context("network name")?;
    let n_layers = r.varint().context("layer count")? as usize;
    let mut layers = Vec::with_capacity(n_layers.min(4096));
    for i in 0..n_layers {
        let lname = r.str().with_context(|| format!("layer {i} name"))?;
        let mut dims = [0u32; 8];
        for d in &mut dims {
            *d = u32::try_from(r.varint()?).with_context(|| format!("layer {i} dims"))?;
        }
        let kind = match r.u8().with_context(|| format!("layer {i} kind"))? {
            0 => LayerKind::Conv,
            1 => LayerKind::Dense,
            other => bail!("unknown layer-kind code {other}"),
        };
        layers.push(Layer {
            name: lname,
            h: dims[0],
            w: dims[1],
            c: dims[2],
            r: dims[3],
            s: dims[4],
            k: dims[5],
            stride: dims[6],
            pad: dims[7],
            kind,
        });
    }
    Ok(Network::new(name, layers))
}

fn put_arrivals(out: &mut Vec<u8>, arr: &ArrivalProcess) {
    match arr {
        ArrivalProcess::Poisson { rate } => {
            out.push(0);
            put_f64(out, *rate);
        }
        ArrivalProcess::Mmpp { low_rate, high_rate, mean_low_s, mean_high_s } => {
            out.push(1);
            for &x in [low_rate, high_rate, mean_low_s, mean_high_s] {
                put_f64(out, x);
            }
        }
        ArrivalProcess::Diurnal { base_rate, amplitude, period_s } => {
            out.push(2);
            for &x in [base_rate, amplitude, period_s] {
                put_f64(out, x);
            }
        }
        ArrivalProcess::Piecewise { segments } => {
            out.push(3);
            put_varint(out, segments.len() as u64);
            for &(t, rate) in segments {
                put_f64(out, t);
                put_f64(out, rate);
            }
        }
        ArrivalProcess::Trace { times } => {
            out.push(4);
            put_varint(out, times.len() as u64);
            for &t in times {
                put_f64(out, t);
            }
        }
    }
}

fn get_arrivals(r: &mut Reader<'_>) -> Result<ArrivalProcess> {
    match r.u8().context("arrival-process code")? {
        0 => Ok(ArrivalProcess::Poisson { rate: r.f64()? }),
        1 => Ok(ArrivalProcess::Mmpp {
            low_rate: r.f64()?,
            high_rate: r.f64()?,
            mean_low_s: r.f64()?,
            mean_high_s: r.f64()?,
        }),
        2 => Ok(ArrivalProcess::Diurnal {
            base_rate: r.f64()?,
            amplitude: r.f64()?,
            period_s: r.f64()?,
        }),
        3 => {
            let n = r.varint()? as usize;
            let mut segments = Vec::with_capacity(n.min(1 << 16));
            for _ in 0..n {
                segments.push((r.f64()?, r.f64()?));
            }
            Ok(ArrivalProcess::Piecewise { segments })
        }
        4 => {
            let n = r.varint()? as usize;
            let mut times = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                times.push(r.f64()?);
            }
            Ok(ArrivalProcess::Trace { times })
        }
        other => bail!("unknown arrival-process code {other}"),
    }
}

fn put_tenant_spec(out: &mut Vec<u8>, spec: &TenantSpec, version: u8) {
    put_str(out, &spec.name);
    put_network(out, &spec.net);
    put_arrivals(out, &spec.arrivals);
    put_f64(out, spec.slo_latency_s);
    put_varint(out, spec.queue_capacity as u64);
    put_varint(out, spec.batch as u64);
    out.push(match spec.admission {
        AdmissionPolicy::Reject => 0,
        AdmissionPolicy::DropOldest => 1,
    });
    put_varint(out, spec.shards as u64);
    out.push(match spec.balancer {
        BalancerPolicy::RoundRobin => 0,
        BalancerPolicy::JoinShortestQueue => 1,
        BalancerPolicy::WeightedThroughput => 2,
    });
    put_f64(out, spec.weight);
    // v4 lifecycle tail: the deadline bit pattern (∞ = none) and optional
    // retry/hedge policies. The negotiated wire version omits this tail
    // entirely on lifecycle-off traces.
    if version >= 4 {
        put_f64(out, spec.deadline_s);
        match spec.retry {
            Some(rp) => {
                out.push(1);
                put_varint(out, u64::from(rp.max_attempts));
                put_f64(out, rp.base_s);
                put_f64(out, rp.cap_s);
            }
            None => out.push(0),
        }
        match spec.hedge {
            Some(h) => {
                out.push(1);
                put_f64(out, h.quantile);
                put_f64(out, h.min_delay_s);
            }
            None => out.push(0),
        }
    }
}

fn get_tenant_spec(r: &mut Reader<'_>, version: u8) -> Result<TenantSpec> {
    let name = r.str().context("tenant name")?;
    let net = get_network(r).context("tenant network")?;
    let arrivals = get_arrivals(r).context("tenant arrivals")?;
    let slo_latency_s = r.f64()?;
    let queue_capacity = r.varint()? as usize;
    let batch = r.varint()? as usize;
    let admission = match r.u8().context("admission code")? {
        0 => AdmissionPolicy::Reject,
        1 => AdmissionPolicy::DropOldest,
        other => bail!("unknown admission-policy code {other}"),
    };
    let shards = r.varint()? as usize;
    let balancer = match r.u8().context("balancer code")? {
        0 => BalancerPolicy::RoundRobin,
        1 => BalancerPolicy::JoinShortestQueue,
        2 => BalancerPolicy::WeightedThroughput,
        other => bail!("unknown balancer code {other}"),
    };
    let weight = r.f64()?;
    let (deadline_s, retry, hedge) = if version >= 4 {
        let deadline_s = r.f64().context("deadline")?;
        let retry = match r.u8().context("retry flag")? {
            0 => None,
            1 => Some(RetryPolicy {
                max_attempts: u32::try_from(r.varint()?).context("retry max_attempts")?,
                base_s: r.f64()?,
                cap_s: r.f64()?,
            }),
            other => bail!("retry flag must be 0 or 1, found {other}"),
        };
        let hedge = match r.u8().context("hedge flag")? {
            0 => None,
            1 => Some(HedgePolicy { quantile: r.f64()?, min_delay_s: r.f64()? }),
            other => bail!("hedge flag must be 0 or 1, found {other}"),
        };
        (deadline_s, retry, hedge)
    } else {
        (f64::INFINITY, None, None)
    };
    Ok(TenantSpec {
        name,
        net,
        arrivals,
        slo_latency_s,
        queue_capacity,
        batch,
        admission,
        shards,
        balancer,
        weight,
        deadline_s,
        retry,
        hedge,
    })
}

fn put_config(out: &mut Vec<u8>, cfg: &PipelineConfig) {
    put_varint(out, cfg.stages.len() as u64);
    for &n in &cfg.stages {
        put_varint(out, n as u64);
    }
    for &ep in &cfg.assignment {
        put_varint(out, ep as u64);
    }
}

fn get_config(r: &mut Reader<'_>) -> Result<PipelineConfig> {
    let n = r.varint().context("stage count")? as usize;
    let mut stages = Vec::with_capacity(n.min(1024));
    for _ in 0..n {
        stages.push(r.varint()? as usize);
    }
    let mut assignment = Vec::with_capacity(n.min(1024));
    for _ in 0..n {
        assignment.push(r.varint()? as usize);
    }
    Ok(PipelineConfig::new(stages, assignment))
}

fn put_opts(out: &mut Vec<u8>, opts: &ServeOptions) {
    put_f64(out, opts.duration_s);
    put_varint(out, opts.seed);
    out.push(u8::from(opts.control));
    put_f64(out, opts.control_epoch_s);
    put_f64(out, opts.retune_threshold);
    put_varint(out, u64::from(opts.retune_cooldown_epochs));
    put_f64(out, opts.reconfig_penalty_s);
    out.push(u8::from(opts.contention));
    out.push(u8::from(opts.record_log));
    put_varint(out, opts.max_events);
    out.push(match opts.pump {
        PumpMode::EventDriven => 0,
        PumpMode::FullRescan => 1,
    });
    out.push(u8::from(opts.coplan));
    let auto = &opts.autoscale;
    out.push(u8::from(auto.enabled));
    put_varint(out, auto.min_shards as u64);
    put_f64(out, auto.target_util);
    put_f64(out, auto.scale_down_util);
    put_f64(out, auto.backlog_frac);
    put_varint(out, u64::from(auto.up_epochs));
    put_varint(out, u64::from(auto.down_epochs));
    put_varint(out, u64::from(auto.cooldown_epochs));
    let elastic = &opts.elastic;
    out.push(u8::from(elastic.enabled));
    put_f64(out, elastic.min_gain_frac);
    put_varint(out, u64::from(elastic.cooldown_epochs));
    put_faults(out, &opts.faults);
}

fn put_faults(out: &mut Vec<u8>, faults: &FaultScript) {
    put_varint(out, faults.events.len() as u64);
    for fe in &faults.events {
        out.push(fe.kind.code());
        match fe.kind {
            FaultKind::EpFail { ep } => put_varint(out, ep as u64),
            FaultKind::EpStall { ep, down_s } => {
                put_varint(out, ep as u64);
                put_f64(out, down_s);
            }
            FaultKind::EpSlow { ep, factor, down_s } => {
                put_varint(out, ep as u64);
                put_f64(out, factor);
                put_f64(out, down_s);
            }
            FaultKind::ChipFail { chiplet } => put_varint(out, u64::from(chiplet)),
            FaultKind::LinkSlow { factor, down_s } => {
                put_f64(out, factor);
                put_f64(out, down_s);
            }
            FaultKind::LinkCut { down_s } => put_f64(out, down_s),
        }
        put_f64(out, fe.t_s);
    }
}

fn get_faults(r: &mut Reader<'_>) -> Result<FaultScript> {
    let n = r.varint().context("fault-event count")? as usize;
    let mut events = Vec::with_capacity(n.min(1 << 16));
    for i in 0..n {
        let code = r.u8().with_context(|| format!("fault event {i} kind code"))?;
        let kind = match code {
            1 => FaultKind::EpFail { ep: r.varint()? as usize },
            2 => FaultKind::EpStall { ep: r.varint()? as usize, down_s: r.f64()? },
            3 => FaultKind::EpSlow {
                ep: r.varint()? as usize,
                factor: r.f64()?,
                down_s: r.f64()?,
            },
            4 => FaultKind::ChipFail {
                chiplet: u32::try_from(r.varint()?)
                    .with_context(|| format!("fault event {i} chiplet"))?,
            },
            5 => FaultKind::LinkSlow { factor: r.f64()?, down_s: r.f64()? },
            6 => FaultKind::LinkCut { down_s: r.f64()? },
            other => bail!("unknown fault-kind code {other}"),
        };
        let t_s = r.f64().with_context(|| format!("fault event {i} time"))?;
        events.push(FaultEvent { t_s, kind });
    }
    Ok(FaultScript { events })
}

fn get_bool(r: &mut Reader<'_>, what: &str) -> Result<bool> {
    match r.u8().with_context(|| format!("reading {what}"))? {
        0 => Ok(false),
        1 => Ok(true),
        other => bail!("{what} must be 0 or 1, found {other}"),
    }
}

fn get_opts(r: &mut Reader<'_>, version: u8) -> Result<ServeOptions> {
    let duration_s = r.f64()?;
    let seed = r.varint()?;
    let control = get_bool(r, "control flag")?;
    let control_epoch_s = r.f64()?;
    let retune_threshold = r.f64()?;
    let retune_cooldown_epochs = u32::try_from(r.varint()?).context("retune cooldown")?;
    let reconfig_penalty_s = r.f64()?;
    let contention = get_bool(r, "contention flag")?;
    let record_log = get_bool(r, "record-log flag")?;
    let max_events = r.varint()?;
    let pump = match r.u8().context("pump-mode code")? {
        0 => PumpMode::EventDriven,
        1 => PumpMode::FullRescan,
        other => bail!("unknown pump-mode code {other}"),
    };
    let coplan = get_bool(r, "coplan flag")?;
    let autoscale = AutoscaleOptions {
        enabled: get_bool(r, "autoscale enabled flag")?,
        min_shards: r.varint()? as usize,
        target_util: r.f64()?,
        scale_down_util: r.f64()?,
        backlog_frac: r.f64()?,
        up_epochs: u32::try_from(r.varint()?).context("autoscale up_epochs")?,
        down_epochs: u32::try_from(r.varint()?).context("autoscale down_epochs")?,
        cooldown_epochs: u32::try_from(r.varint()?).context("autoscale cooldown")?,
    };
    // Version-gated tail: v1 traces end here (no elastic loop, no fault
    // plane existed), v2 adds the fault script, v3 the elastic options.
    let elastic = if version >= 3 {
        ElasticOptions {
            enabled: get_bool(r, "elastic enabled flag")?,
            min_gain_frac: r.f64()?,
            cooldown_epochs: u32::try_from(r.varint()?).context("elastic cooldown")?,
        }
    } else {
        ElasticOptions::default()
    };
    let faults = if version >= 2 {
        get_faults(r).context("decoding fault script")?
    } else {
        FaultScript::default()
    };
    Ok(ServeOptions {
        duration_s,
        seed,
        control,
        control_epoch_s,
        retune_threshold,
        retune_cooldown_epochs,
        reconfig_penalty_s,
        contention,
        record_log,
        max_events,
        pump,
        coplan,
        autoscale,
        elastic,
        faults,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::networks;
    use crate::platform::configs;

    fn sample_trace() -> Trace {
        let plat = configs::c2();
        let spec = TenantSpec::new(
            "t0",
            networks::synthnet_small(),
            ArrivalProcess::Mmpp { low_rate: 1.0, high_rate: 5.0, mean_low_s: 3.0, mean_high_s: 2.0 },
        )
        .with_batch(2)
        .with_admission(AdmissionPolicy::DropOldest)
        .with_shards(2)
        .with_balancer(BalancerPolicy::JoinShortestQueue)
        .with_weight(1.5);
        let config = PipelineConfig::new(vec![3, 3], vec![0, 1]);
        let faults = FaultScript::parse("epstall:1@2+1.5; linkslow:2.0@5+2").unwrap();
        let elastic =
            ElasticOptions { enabled: true, min_gain_frac: 0.05, cooldown_epochs: 3 };
        let opts =
            ServeOptions { duration_s: 10.0, seed: 9, faults, elastic, ..Default::default() };
        Trace {
            platform: plat,
            tenants: vec![(spec, config)],
            opts,
            events: vec![
                TraceEvent { t_s: 0.5, tag: 1, a: 0, b: 0 },
                TraceEvent { t_s: 0.75, tag: 3, a: 0, b: 1 },
                TraceEvent { t_s: 1.5, tag: 1, a: 0, b: 1 },
                TraceEvent { t_s: 2.0, tag: 7, a: 2, b: 1 },
            ],
            controls: vec![
                ControlRecord {
                    t_s: 5.0,
                    kind: ControlKind::Retune,
                    tenant: 0,
                    shard: 0,
                    a: 120,
                    b: 1,
                },
                ControlRecord {
                    t_s: 2.0,
                    kind: ControlKind::Fault,
                    tenant: 0,
                    shard: 0,
                    a: 2,
                    b: 1,
                },
                ControlRecord {
                    t_s: 2.0,
                    kind: ControlKind::Failover,
                    tenant: 0,
                    shard: 0,
                    a: 1,
                    b: 0,
                },
                ControlRecord {
                    t_s: 5.0,
                    kind: ControlKind::Shed,
                    tenant: 0,
                    shard: 0,
                    a: 0,
                    b: 1,
                },
                ControlRecord {
                    t_s: 6.0,
                    kind: ControlKind::Repartition,
                    tenant: 0,
                    shard: 2,
                    a: 3,
                    b: 4_618_441_417_868_443_648, // 6.0f64.to_bits()
                },
            ],
            summary: TraceSummary {
                log_hash: 0xDEAD_BEEF_0BAD_F00D,
                n_events: 3,
                truncated: false,
                tenants: vec![TenantSummary {
                    name: "t0".into(),
                    offered: 2,
                    rejected: 0,
                    dropped: 0,
                    completed: 1,
                    slo_ok: 1,
                    in_flight: 1,
                    retunes: 1,
                    scale_events: 0,
                    expired: 0,
                    cancelled: 0,
                    retried: 0,
                    hedged: 0,
                }],
            },
        }
    }

    #[test]
    fn trace_round_trips_byte_identically() {
        let tr = sample_trace();
        let bytes = tr.to_bytes();
        let back = Trace::from_bytes(&bytes).unwrap();
        // Re-serializing the decoded trace must reproduce the exact bytes:
        // the format has one canonical encoding.
        assert_eq!(back.to_bytes(), bytes);
        assert_eq!(back.events, tr.events);
        assert_eq!(back.controls, tr.controls);
        assert_eq!(back.summary, tr.summary);
        assert_eq!(back.tenants.len(), 1);
        assert_eq!(back.tenants[0].0.name, "t0");
        assert_eq!(back.tenants[0].0.batch, 2);
        assert_eq!(back.tenants[0].0.balancer, BalancerPolicy::JoinShortestQueue);
        assert_eq!(back.tenants[0].1, tr.tenants[0].1);
        assert_eq!(back.platform.n_eps(), tr.platform.n_eps());
        assert_eq!(back.platform.link, tr.platform.link);
        assert_eq!(back.opts.seed, 9);
        assert_eq!(back.opts.faults, tr.opts.faults);
        assert_eq!(back.opts.faults.events.len(), 2);
        assert!(back.opts.elastic.enabled);
        assert_eq!(back.opts.elastic.min_gain_frac.to_bits(), 0.05f64.to_bits());
        assert_eq!(back.opts.elastic.cooldown_epochs, 3);
    }

    #[test]
    fn old_version_traces_still_decode() {
        // Hand-encode a trace in the v1 and v2 layouts (options stop
        // after the autoscale block; v2 appends the fault script) and
        // check the version-gated decoder fills the missing tails with
        // defaults — `trace analyze` must read every trace ever recorded.
        let tr = sample_trace();
        let mut opts_v1 = Vec::new();
        let o = &tr.opts;
        put_f64(&mut opts_v1, o.duration_s);
        put_varint(&mut opts_v1, o.seed);
        opts_v1.push(u8::from(o.control));
        put_f64(&mut opts_v1, o.control_epoch_s);
        put_f64(&mut opts_v1, o.retune_threshold);
        put_varint(&mut opts_v1, u64::from(o.retune_cooldown_epochs));
        put_f64(&mut opts_v1, o.reconfig_penalty_s);
        opts_v1.push(u8::from(o.contention));
        opts_v1.push(u8::from(o.record_log));
        put_varint(&mut opts_v1, o.max_events);
        opts_v1.push(0); // pump: event-driven
        opts_v1.push(u8::from(o.coplan));
        let auto = &o.autoscale;
        opts_v1.push(u8::from(auto.enabled));
        put_varint(&mut opts_v1, auto.min_shards as u64);
        put_f64(&mut opts_v1, auto.target_util);
        put_f64(&mut opts_v1, auto.scale_down_util);
        put_f64(&mut opts_v1, auto.backlog_frac);
        put_varint(&mut opts_v1, u64::from(auto.up_epochs));
        put_varint(&mut opts_v1, u64::from(auto.down_epochs));
        put_varint(&mut opts_v1, u64::from(auto.cooldown_epochs));
        let mut opts_v2 = opts_v1.clone();
        put_faults(&mut opts_v2, &o.faults);

        for (version, opts_bytes, expect_faults) in
            [(1u8, &opts_v1, false), (2u8, &opts_v2, true)]
        {
            let mut inputs = Vec::new();
            put_platform(&mut inputs, &tr.platform);
            put_varint(&mut inputs, tr.tenants.len() as u64);
            for (spec, config) in &tr.tenants {
                put_tenant_spec(&mut inputs, spec, version);
                put_config(&mut inputs, config);
            }
            inputs.extend_from_slice(opts_bytes);
            let mut events = Vec::new();
            put_varint(&mut events, 1);
            put_event(&mut events, &TraceEvent { t_s: 0.5, tag: 1, a: 0, b: 0 });
            let mut controls = Vec::new();
            put_varint(&mut controls, 0);
            let mut summary = Vec::new();
            summary.extend_from_slice(&0x1234u64.to_le_bytes());
            put_varint(&mut summary, 1);
            summary.push(0);
            put_varint(&mut summary, 0);
            let mut bytes = Vec::new();
            bytes.extend_from_slice(&MAGIC);
            bytes.push(version);
            put_section(&mut bytes, SEC_INPUTS, &inputs);
            put_section(&mut bytes, SEC_EVENTS, &events);
            put_section(&mut bytes, SEC_CONTROLS, &controls);
            put_section(&mut bytes, SEC_SUMMARY, &summary);

            let back = Trace::from_bytes(&bytes)
                .unwrap_or_else(|e| panic!("v{version} trace must decode: {e:#}"));
            assert_eq!(back.opts.seed, tr.opts.seed, "v{version}");
            assert_eq!(back.opts.faults.is_empty(), !expect_faults, "v{version}");
            assert!(!back.opts.elastic.enabled, "v{version}: elastic defaults off");
            assert_eq!(back.events.len(), 1, "v{version}");
            assert_eq!(back.summary.log_hash, 0x1234, "v{version}");
        }
    }

    #[test]
    fn lifecycle_traces_negotiate_v4_and_round_trip() {
        use crate::serve::lifecycle::{HedgePolicy, RetryPolicy};
        let mut tr = sample_trace();
        assert_eq!(tr.wire_version(), 3, "no lifecycle policy → v3 wire format");
        let v3_bytes = tr.to_bytes();
        assert_eq!(v3_bytes[4], 3);

        tr.tenants[0].0 = tr.tenants[0]
            .0
            .clone()
            .with_deadline(0.75)
            .with_retry(RetryPolicy { max_attempts: 2, base_s: 0.02, cap_s: 0.5 })
            .with_hedge(HedgePolicy { quantile: 0.99, min_delay_s: 0.01 });
        tr.summary.tenants[0].expired = 3;
        tr.summary.tenants[0].cancelled = 1;
        tr.summary.tenants[0].retried = 2;
        tr.summary.tenants[0].hedged = 1;
        assert_eq!(tr.wire_version(), 4);
        let bytes = tr.to_bytes();
        assert_eq!(bytes[4], 4);
        let back = Trace::from_bytes(&bytes).unwrap();
        assert_eq!(back.to_bytes(), bytes, "v4 decode → re-encode is canonical");
        let spec = &back.tenants[0].0;
        assert_eq!(spec.deadline_s.to_bits(), 0.75f64.to_bits());
        assert_eq!(spec.retry, Some(RetryPolicy { max_attempts: 2, base_s: 0.02, cap_s: 0.5 }));
        assert_eq!(spec.hedge, Some(HedgePolicy { quantile: 0.99, min_delay_s: 0.01 }));
        assert_eq!(back.summary, tr.summary);
    }

    #[test]
    fn every_truncation_is_rejected_without_panic() {
        let bytes = sample_trace().to_bytes();
        for cut in 0..bytes.len() {
            let err = Trace::from_bytes(&bytes[..cut]);
            assert!(err.is_err(), "prefix of {cut} bytes should be rejected");
        }
    }

    #[test]
    fn corruption_is_rejected_with_checksum_error() {
        let bytes = sample_trace().to_bytes();
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(Trace::from_bytes(&bad).unwrap_err().to_string().contains("magic"));
        // Bad version.
        let mut bad = bytes.clone();
        bad[4] = 200;
        assert!(Trace::from_bytes(&bad).unwrap_err().to_string().contains("version"));
        // Flip a byte inside the first section payload: CRC must trip.
        let mut bad = bytes.clone();
        bad[10] ^= 0x10;
        let msg = Trace::from_bytes(&bad).unwrap_err().root_cause().to_string();
        assert!(
            msg.contains("checksum") || msg.contains("truncated") || msg.contains("unknown"),
            "unexpected error: {msg}"
        );
    }

    #[test]
    fn arrival_times_filters_by_tenant() {
        let mut tr = sample_trace();
        tr.events.push(TraceEvent { t_s: 2.0, tag: 1, a: 1 << 8, b: 0 });
        assert_eq!(tr.arrival_times(0), vec![0.5, 1.5]);
        assert_eq!(tr.arrival_times(1), vec![2.0]);
        assert!(tr.arrival_times(2).is_empty());
    }

    #[test]
    fn describe_mentions_tenants_and_controls() {
        let text = sample_trace().describe();
        assert!(text.contains("t0"), "{text}");
        assert!(text.contains("retune"), "{text}");
        assert!(text.contains("event census"), "{text}");
    }
}
