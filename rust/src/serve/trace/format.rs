//! Binary trace format: varint primitives, CRC-framed sections, records.
//!
//! # File layout
//!
//! A `.trace` file is a magic/version header followed by four sections in
//! a fixed order:
//!
//! ```text
//! "SHTR" [version u8]
//! [section id u8] [payload len varint] [payload bytes] [crc32 u32 LE]   × 4
//! ```
//!
//! Primitive encodings, used throughout every section:
//!
//! * **integers** — LEB128 varints;
//! * **floats** — the raw 8 LE bytes of [`f64::to_bits`], so replay
//!   inputs survive the round-trip bit-exactly (including `-0.0`/NaN);
//! * **strings** — varint length + UTF-8 bytes;
//! * **bools/enums** — one byte with a stable wire code; decoders bail
//!   on codes they do not know.
//!
//! Each section's payload carries its own CRC-32 (IEEE), so truncation or
//! corruption anywhere in the file is caught with a precise error instead
//! of a garbage replay. The encoding is canonical: decode → re-encode
//! reproduces the input bytes exactly.
//!
//! ## Section 1 — inputs ([`SEC_INPUTS`])
//!
//! Everything needed to re-simulate the run from scratch, in order:
//!
//! 1. **platform** — name, EP table (id, core type, core count, memory
//!    class, chiplet), inter-chiplet link (latency, bandwidth), optional
//!    mesh topology;
//! 2. **tenants** — count, then per tenant its spec (name, network
//!    layers, arrival process, SLO, queueing/batching/admission, shard
//!    count, balancer, weight) and initial pipeline configuration
//!    (stage sizes + EP assignment);
//! 3. **serve options** — horizon, seed, control-loop knobs, contention
//!    flag, pump mode, coplan flag, autoscale options, (since version 3)
//!    the **elastic options** (enabled flag, gain bar as f64, cooldown as
//!    varint), and (since version 2) the **fault script**: an event count
//!    followed by, per event, the [`crate::serve::FaultKind`] wire code
//!    (1 = epfail, 2 = epstall, 3 = epslow, 4 = chipfail, 5 = linkslow,
//!    6 = linkcut), its kind-specific fields (EP/chiplet ids as varints,
//!    factors and window lengths as f64), and the event time as f64.
//!
//! ## Section 2 — events ([`SEC_EVENTS`])
//!
//! The hashed engine event stream: a count, then per event varint
//! `tag`/`a`/`b` and the f64 time — exactly the words folded into
//! [`crate::serve::ServeReport::log_hash`], in heap order. See
//! [`TraceEvent`] for the tag table (fault boundaries are tag 7).
//!
//! ## Section 3 — controls ([`SEC_CONTROLS`])
//!
//! Control-plane decisions recorded *beside* the hashed stream (capture
//! never perturbs the live hash): a count, then per record the
//! [`super::ControlKind`] wire code (1 = retune, 2 = coplan, 3 = scale,
//! 4 = fault, 5 = failover, 6 = shed, 7 = repartition, 8 = hedge —
//! since version 4), tenant, shard, two payload words and the decision
//! time.
//!
//! ## Section 4 — summary ([`SEC_SUMMARY`])
//!
//! What a full replay must reproduce: the run's log hash (8 raw LE
//! bytes), event count, truncation flag, and per-tenant outcome counters
//! (offered/rejected/dropped/completed/slo_ok/in_flight/retunes/
//! scale_events).
//!
//! Everything here is allocation-light and panic-free on malformed input:
//! the [`Reader`] bounds-checks every access and returns `anyhow` errors.

use anyhow::{bail, Context, Result};

/// File magic: the first four bytes of every trace.
pub const MAGIC: [u8; 4] = *b"SHTR";

/// Current format version (bumped on any incompatible layout change).
/// Version 2 added the fault script to the serialized serve options and
/// the tag-7 fault records to the event stream. Version 3 added the
/// elastic-loop options and the tag-8 re-partition records. Version 4
/// added the per-tenant request-lifecycle policies (deadline, retry,
/// hedge), the tag 9–12 lifecycle events, the `hedge` control kind, and
/// the expired/cancelled/retried/hedged summary counters. The recorder
/// negotiates the wire version down to 3 when no tenant has a lifecycle
/// policy, so lifecycle-off captures stay byte-identical to a
/// pre-lifecycle build.
pub const VERSION: u8 = 4;

/// Oldest version this build still reads. Decoding is version-gated on
/// the serve-options layout (v1: no elastic, no faults; v2: faults but no
/// elastic; v3: no lifecycle policies); omitted sections decode to their
/// defaults, so `trace analyze` turns every trace ever recorded into an
/// observability artifact. Re-encoding preserves the negotiated version.
pub const MIN_VERSION: u8 = 1;

/// Section id: serialized serve inputs (platform, tenants, options).
pub const SEC_INPUTS: u8 = 1;
/// Section id: the hashed engine event stream.
pub const SEC_EVENTS: u8 = 2;
/// Section id: control-plane decision records.
pub const SEC_CONTROLS: u8 = 3;
/// Section id: run summary (log hash, event count, per-tenant counters).
pub const SEC_SUMMARY: u8 = 4;

/// One hashed engine event, exactly the tuple folded into
/// [`crate::serve::ServeReport::log_hash`]: `(tag, a, b, t)`.
///
/// The tag space mirrors the engine's `note()` calls:
///
/// | tag | meaning      | `a`                    | `b`            |
/// |-----|--------------|------------------------|----------------|
/// | 1   | arrival      | tenant « 8 \| shard    | request id     |
/// | 2   | stale done   | tenant « 8 \| shard    | stage          |
/// | 3   | stage done   | tenant « 8 \| shard    | stage          |
/// | 4   | resume       | tenant « 8 \| shard    | 0              |
/// | 5   | epoch tick   | 0                      | 0              |
/// | 6   | scale change | tenant « 8 \| shard    | replica state  |
/// | 7   | fault        | event ix « 8 \| kind   | begin (1/0)    |
/// | 8   | repartition  | tenant « 8 \| replicas | EP budget size |
/// | 9   | expire       | tenant « 8 \| shard    | request id     |
/// | 10  | retry        | attempt « 32 \| tenant « 8 \| shard | request id |
/// | 11  | hedge        | tenant « 8 \| sibling  | request id     |
/// | 12  | cancel       | tenant « 8 \| shard    | request id     |
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    /// Simulated time of the event, seconds.
    pub t_s: f64,
    /// Event tag (see the table above).
    pub tag: u64,
    /// First payload word.
    pub a: u64,
    /// Second payload word.
    pub b: u64,
}

// Bit-exact equality: full replay asserts the recorded and re-simulated
// streams match byte for byte, so `t_s` must compare via `to_bits` (the
// derived f64 PartialEq would treat -0.0 == 0.0 and NaN != NaN).
impl PartialEq for TraceEvent {
    fn eq(&self, other: &Self) -> bool {
        self.t_s.to_bits() == other.t_s.to_bits()
            && self.tag == other.tag
            && self.a == other.a
            && self.b == other.b
    }
}

impl TraceEvent {
    /// Tenant index for tags that pack one (1, 2, 3, 4, 6).
    pub fn tenant(&self) -> usize {
        (self.a >> 8) as usize
    }

    /// Shard index for tags that pack one (1, 2, 3, 4, 6).
    pub fn shard(&self) -> usize {
        (self.a & 0xFF) as usize
    }

    /// Human-readable tag name (for `trace inspect`).
    pub fn tag_name(tag: u64) -> &'static str {
        match tag {
            1 => "arrival",
            2 => "stale-done",
            3 => "stage-done",
            4 => "resume",
            5 => "epoch",
            6 => "scale",
            7 => "fault",
            8 => "repartition",
            9 => "expire",
            10 => "retry",
            11 => "hedge",
            12 => "cancel",
            _ => "unknown",
        }
    }
}

/// CRC-32 (IEEE 802.3, poly `0xEDB88320`), bitwise — no table, called once
/// per section so speed is irrelevant next to integrity.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc: u32 = 0xFFFF_FFFF;
    for &byte in bytes {
        crc ^= u32::from(byte);
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    crc ^ 0xFFFF_FFFF
}

/// Append a LEB128 varint.
pub fn put_varint(out: &mut Vec<u8>, mut x: u64) {
    loop {
        let byte = (x & 0x7F) as u8;
        x >>= 7;
        if x == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Append an `f64` as the raw LE bytes of its bit pattern.
pub fn put_f64(out: &mut Vec<u8>, x: f64) {
    out.extend_from_slice(&x.to_bits().to_le_bytes());
}

/// Append a string as varint length + UTF-8 bytes.
pub fn put_str(out: &mut Vec<u8>, s: &str) {
    put_varint(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

/// Frame a section: id byte, varint payload length, payload, CRC-32.
pub fn put_section(out: &mut Vec<u8>, id: u8, payload: &[u8]) {
    out.push(id);
    put_varint(out, payload.len() as u64);
    out.extend_from_slice(payload);
    out.extend_from_slice(&crc32(payload).to_le_bytes());
}

/// Bounds-checked cursor over a byte buffer. Every accessor returns a
/// descriptive error instead of panicking, so a truncated or corrupted
/// trace is rejected cleanly.
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Cursor at the start of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// True when the cursor has consumed the whole buffer.
    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    /// Next raw byte.
    pub fn u8(&mut self) -> Result<u8> {
        let Some(&byte) = self.buf.get(self.pos) else {
            bail!("trace truncated at byte {} (expected 1 more byte)", self.pos);
        };
        self.pos += 1;
        Ok(byte)
    }

    /// Next `n` raw bytes.
    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        let Some(slice) = self.buf.get(self.pos..self.pos + n) else {
            bail!(
                "trace truncated at byte {} (expected {n} more bytes, have {})",
                self.pos,
                self.remaining()
            );
        };
        self.pos += n;
        Ok(slice)
    }

    /// Next LEB128 varint.
    pub fn varint(&mut self) -> Result<u64> {
        let mut x: u64 = 0;
        for shift in (0..64).step_by(7) {
            let byte = self.u8().context("reading varint")?;
            x |= u64::from(byte & 0x7F) << shift;
            if byte & 0x80 == 0 {
                return Ok(x);
            }
        }
        bail!("varint longer than 10 bytes at offset {}", self.pos)
    }

    /// Next `f64` (8 LE bytes of the bit pattern).
    pub fn f64(&mut self) -> Result<f64> {
        let raw = self.bytes(8).context("reading f64")?;
        let mut arr = [0u8; 8];
        arr.copy_from_slice(raw);
        Ok(f64::from_bits(u64::from_le_bytes(arr)))
    }

    /// Next `u32` (4 LE bytes).
    pub fn u32(&mut self) -> Result<u32> {
        let raw = self.bytes(4).context("reading u32")?;
        let mut arr = [0u8; 4];
        arr.copy_from_slice(raw);
        Ok(u32::from_le_bytes(arr))
    }

    /// Next string (varint length + UTF-8).
    pub fn str(&mut self) -> Result<String> {
        let len = self.varint().context("reading string length")?;
        let raw = self.bytes(len as usize).context("reading string bytes")?;
        String::from_utf8(raw.to_vec()).context("trace string is not UTF-8")
    }

    /// Consume a framed section, verifying id and CRC; returns a reader
    /// over the payload.
    pub fn take_section(&mut self, want_id: u8) -> Result<Reader<'a>> {
        let id = self.u8().context("reading section id")?;
        if id != want_id {
            bail!("expected section id {want_id}, found {id}");
        }
        let len = self.varint().context("reading section length")? as usize;
        let payload = self
            .bytes(len)
            .with_context(|| format!("reading section {want_id} payload ({len} bytes)"))?;
        let stored = self
            .u32()
            .with_context(|| format!("reading section {want_id} checksum"))?;
        let actual = crc32(payload);
        if stored != actual {
            bail!(
                "section {want_id} checksum mismatch: stored {stored:#010x}, computed {actual:#010x} — trace is corrupted"
            );
        }
        Ok(Reader::new(payload))
    }
}

/// Serialize one event (varint tag/a/b, raw f64 time).
pub fn put_event(out: &mut Vec<u8>, ev: &TraceEvent) {
    put_varint(out, ev.tag);
    put_varint(out, ev.a);
    put_varint(out, ev.b);
    put_f64(out, ev.t_s);
}

/// Deserialize one event.
pub fn get_event(r: &mut Reader<'_>) -> Result<TraceEvent> {
    let tag = r.varint().context("reading event tag")?;
    let a = r.varint().context("reading event a")?;
    let b = r.varint().context("reading event b")?;
    let t_s = r.f64().context("reading event time")?;
    Ok(TraceEvent { t_s, tag, a, b })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn varint_round_trips_boundaries() {
        let cases = [0, 1, 127, 128, 255, 256, 16383, 16384, u64::MAX / 2, u64::MAX];
        let mut buf = Vec::new();
        for &x in &cases {
            put_varint(&mut buf, x);
        }
        let mut r = Reader::new(&buf);
        for &x in &cases {
            assert_eq!(r.varint().unwrap(), x);
        }
        assert!(r.is_empty());
    }

    #[test]
    fn varint_round_trips_randomized() {
        let mut rng = Xoshiro256::seed_from(0xF0F0);
        let xs: Vec<u64> = (0..500)
            .map(|_| {
                let shift = rng.gen_range(0, 64);
                (rng.gen_f64() * 1e18) as u64 >> shift
            })
            .collect();
        let mut buf = Vec::new();
        for &x in &xs {
            put_varint(&mut buf, x);
        }
        let mut r = Reader::new(&buf);
        for &x in &xs {
            assert_eq!(r.varint().unwrap(), x);
        }
    }

    #[test]
    fn f64_round_trips_bit_exactly() {
        let cases = [0.0, -0.0, 1.5, f64::MIN_POSITIVE, f64::MAX, f64::NAN, f64::INFINITY];
        let mut buf = Vec::new();
        for &x in &cases {
            put_f64(&mut buf, x);
        }
        let mut r = Reader::new(&buf);
        for &x in &cases {
            assert_eq!(r.f64().unwrap().to_bits(), x.to_bits());
        }
    }

    #[test]
    fn strings_round_trip() {
        let mut buf = Vec::new();
        put_str(&mut buf, "");
        put_str(&mut buf, "synthnet");
        put_str(&mut buf, "ünïcødé ✓");
        let mut r = Reader::new(&buf);
        assert_eq!(r.str().unwrap(), "");
        assert_eq!(r.str().unwrap(), "synthnet");
        assert_eq!(r.str().unwrap(), "ünïcødé ✓");
    }

    #[test]
    fn crc32_matches_known_vector() {
        // The classic IEEE check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn sections_verify_and_reject_corruption() {
        let mut buf = Vec::new();
        put_section(&mut buf, SEC_EVENTS, b"payload bytes");
        let mut r = Reader::new(&buf);
        let mut sec = r.take_section(SEC_EVENTS).unwrap();
        assert_eq!(sec.bytes(13).unwrap(), b"payload bytes");
        assert!(r.is_empty());

        // Wrong expected id.
        let mut r = Reader::new(&buf);
        assert!(r.take_section(SEC_SUMMARY).is_err());

        // Flip one payload byte: CRC must catch it.
        let mut bad = buf.clone();
        bad[4] ^= 0x40;
        let mut r = Reader::new(&bad);
        let err = r.take_section(SEC_EVENTS).unwrap_err();
        assert!(err.to_string().contains("checksum"), "unexpected error: {err}");

        // Truncate at every prefix: error, never panic.
        for cut in 0..buf.len() {
            let mut r = Reader::new(&buf[..cut]);
            assert!(r.take_section(SEC_EVENTS).is_err(), "prefix {cut} should fail");
        }
    }

    #[test]
    fn events_round_trip_and_compare_bit_exactly() {
        let evs = [
            TraceEvent { t_s: 0.0, tag: 1, a: (3 << 8) | 2, b: 77 },
            TraceEvent { t_s: 1.25e-3, tag: 5, a: 0, b: 0 },
            TraceEvent { t_s: -0.0, tag: 6, a: 1 << 8, b: 2 },
        ];
        let mut buf = Vec::new();
        for ev in &evs {
            put_event(&mut buf, ev);
        }
        let mut r = Reader::new(&buf);
        for ev in &evs {
            assert_eq!(&get_event(&mut r).unwrap(), ev);
        }
        // -0.0 and 0.0 differ bit-wise, so the events must not compare equal.
        let zero = TraceEvent { t_s: 0.0, tag: 6, a: 1 << 8, b: 2 };
        assert_ne!(evs[2], zero);
        assert_eq!(evs[0].tenant(), 3);
        assert_eq!(evs[0].shard(), 2);
        assert_eq!(TraceEvent::tag_name(1), "arrival");
        assert_eq!(TraceEvent::tag_name(99), "unknown");
    }
}
