//! Flight recorder: binary trace capture + deterministic replay.
//!
//! The serving engine is a pure function of its inputs — that is what the
//! golden fingerprint tests pin. This module turns that purity into a
//! product surface:
//!
//! * [`format`] — the compact `.trace` binary format: a `SHTR` magic +
//!   version header, then CRC-framed sections (inputs, hashed events,
//!   control records, summary) of varint-encoded records. Corruption or
//!   truncation anywhere yields a precise error, never a panic.
//! * [`recorder`] — the engine-side [`Capture`] sink (preallocated, no
//!   per-event allocation on the hot path) and the [`Trace`] container
//!   assembling inputs + events + control-plane decisions (re-tunes,
//!   co-plan allocations, autoscale transitions) + outcome summary.
//! * [`replayer`] — [`replay_full`] (re-simulate and assert bit-identical
//!   `log_hash`, event stream, and per-tenant counters),
//!   [`replay_whatif`] (re-simulate only the captured arrival streams
//!   under a [`WhatIf`] policy override: shard count, balancer,
//!   autoscale, co-planning — with request conservation checked), and
//!   [`replay_observed`] (re-simulate with the telemetry plane on —
//!   `trace analyze` — deriving the epoch time series and causality
//!   journal retroactively from any v1–v3 trace).
//!
//! Record with [`crate::serve::serve_traced`] (or `serve --record` on the
//! CLI), inspect with [`Trace::describe`] (`trace inspect`), fan a trace
//! across a policy grid with [`crate::serve::sweep::whatif_grid`].

pub mod format;
pub mod recorder;
pub mod replayer;

pub use format::TraceEvent;
pub use recorder::{Capture, ControlKind, ControlRecord, TenantSummary, Trace, TraceSummary};
pub use replayer::{replay_full, replay_observed, replay_whatif, whatif_inputs, WhatIf};
