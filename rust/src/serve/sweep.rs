//! Parallel scenario sweeps: run many independent serving scenarios
//! across CPU cores.
//!
//! A serving run ([`serve`]) is a pure function of its inputs, so a
//! scenario grid — tenant counts × offered-load factors × seeds — is
//! embarrassingly parallel. This module fans the grid out over a thread
//! pool and returns outcomes **in input order**, each byte-identical to a
//! sequential run (every scenario owns its RNG stream and report slot, so
//! thread count and scheduling cannot perturb results). This is the first
//! step towards the ROADMAP's sharded-serving item: the same machinery
//! that sweeps scenarios can evaluate shard placements side by side.
//!
//! Two execution engines:
//!
//! * default — a fixed pool of `std::thread`s pulling scenario indices
//!   from an atomic counter (no dependencies; builds in the offline
//!   container);
//! * `--features rayon` — a rayon work-stealing pool (requires
//!   uncommenting the `rayon` dependency in `Cargo.toml` on machines
//!   whose registry has it).
//!
//! The `shisha serve --sweep` CLI subcommand and `benches/serve_scale.rs`
//! both drive [`run_sweep`] over [`load_grid`] scenario sets.

use anyhow::Result;

use crate::model::Network;
use crate::perfdb::{CostModel, PerfDb};
use crate::pipeline::{simulator, PipelineConfig};
use crate::platform::Platform;

use super::arrivals::ArrivalProcess;
use super::engine::{serve, ServeOptions, ServeReport};
use super::fault::{FaultEvent, FaultKind, FaultScript};
use super::shard::BalancerPolicy;
use super::slo::QuantileSketch;
use super::tenant::TenantSpec;
use super::trace::{whatif_inputs, Trace, WhatIf};

/// One independent serving scenario: a platform, a tenant mix, and the
/// engine options to run them under.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Display name (unique within a sweep).
    pub name: String,
    /// The shared platform the tenants contend on.
    pub plat: Platform,
    /// Tenant specs with their initial pipeline configurations.
    pub tenants: Vec<(TenantSpec, PipelineConfig)>,
    /// Engine options (seed, horizon, control loop, pump mode).
    pub opts: ServeOptions,
}

/// Outcome of one scenario within a sweep.
#[derive(Debug)]
pub struct SweepOutcome {
    /// Scenario name, copied from the input.
    pub name: String,
    /// Wall-clock seconds the (single-threaded) serve run took.
    pub wall_s: f64,
    /// The serving report, or the engine's validation error.
    pub report: Result<ServeReport>,
}

impl SweepOutcome {
    /// Simulated events per wall-clock second (None on error runs).
    pub fn events_per_s(&self) -> Option<f64> {
        match &self.report {
            Ok(r) if self.wall_s > 0.0 => Some(r.n_events as f64 / self.wall_s),
            _ => None,
        }
    }
}

/// Aggregate view of one scenario report, merged across its tenants — the
/// shared row shape for the sweep CLI and `benches/serve_scale.rs`.
#[derive(Debug, Clone)]
pub struct ScenarioStats {
    /// Total arrivals offered.
    pub offered: u64,
    /// Arrivals rejected plus requests dropped.
    pub shed: u64,
    /// Completions within the SLO.
    pub slo_ok: u64,
    /// Warm re-tunes across all tenants.
    pub retunes: u32,
    /// Merged median latency, seconds.
    pub p50_s: f64,
    /// Merged 95th-percentile latency, seconds.
    pub p95_s: f64,
    /// Merged 99th-percentile latency, seconds.
    pub p99_s: f64,
    /// Merged maximum latency, seconds.
    pub max_s: f64,
    /// Aggregate SLO goodput, requests/second.
    pub goodput_rps: f64,
    /// Jain fairness across the scenario's tenants.
    pub fairness: f64,
    /// EP-epochs consumed across tenants (the autoscaler's resource
    /// meter; see [`crate::serve::TenantReport::ep_epochs`]).
    pub ep_epochs: u64,
    /// Autoscaler transitions across all replicas of all tenants.
    pub scale_events: u64,
    /// Elastic re-partitions across all tenants (see
    /// [`crate::serve::TenantReport::repartitions`]).
    pub repartitions: u64,
    /// Plan-cache hits across the run's control planes (failover +
    /// elastic re-plans; see [`crate::serve::ServeReport::plan_cache`]).
    pub cache_hits: u64,
    /// Plan-cache misses (each one paid a full placement search).
    pub cache_misses: u64,
    /// Deadline expiries across all tenants (see
    /// [`crate::serve::TenantReport::expired`]; 0 unless a lifecycle
    /// policy is set).
    pub expired: u64,
    /// Hedge-loser cancellations across all tenants.
    pub cancelled: u64,
    /// Retry re-arrivals across all tenants.
    pub retried: u64,
    /// Hedge twins placed across all tenants.
    pub hedged: u64,
}

impl ScenarioStats {
    /// Merge the per-tenant reports of one run.
    pub fn from_report(r: &ServeReport) -> Self {
        let mut sketch = QuantileSketch::new();
        let mut offered = 0u64;
        let mut shed = 0u64;
        let mut slo_ok = 0u64;
        let mut retunes = 0u32;
        let mut scale_events = 0u64;
        let mut repartitions = 0u64;
        let (mut expired, mut cancelled, mut retried, mut hedged) = (0u64, 0u64, 0u64, 0u64);
        for t in &r.tenants {
            sketch.merge(&t.latency);
            offered += t.offered;
            shed += t.rejected + t.dropped;
            slo_ok += t.slo_ok;
            retunes += t.retunes;
            scale_events +=
                t.shards.iter().map(|s| s.scale_events.len() as u64).sum::<u64>();
            repartitions += u64::from(t.repartitions);
            expired += t.expired;
            cancelled += t.cancelled;
            retried += t.retried;
            hedged += t.hedged;
        }
        Self {
            offered,
            shed,
            slo_ok,
            retunes,
            ep_epochs: r.ep_epochs(),
            scale_events,
            repartitions,
            cache_hits: r.plan_cache.hits,
            cache_misses: r.plan_cache.misses,
            expired,
            cancelled,
            retried,
            hedged,
            p50_s: sketch.p50(),
            p95_s: sketch.p95(),
            p99_s: sketch.p99(),
            max_s: sketch.max_s(),
            goodput_rps: if r.duration_s > 0.0 { slo_ok as f64 / r.duration_s } else { 0.0 },
            fairness: r.fairness(),
        }
    }

    /// Fraction of offered requests shed (rejected or dropped).
    pub fn drop_rate(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            self.shed as f64 / self.offered as f64
        }
    }

    /// The shared latency-percentile row for this scenario.
    pub fn latency_row(&self, label: impl Into<String>) -> crate::metrics::table::LatencyRow {
        crate::metrics::table::LatencyRow {
            label: label.into(),
            p50_s: self.p50_s,
            p95_s: self.p95_s,
            p99_s: self.p99_s,
            max_s: self.max_s,
            goodput_rps: self.goodput_rps,
            drop_rate: self.drop_rate(),
        }
    }
}

/// Number of hardware threads available to a sweep (≥ 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map_or(1, |n| n.get())
}

/// Build the standard load-sweep scenario grid: every combination of
/// `tenant_counts` × `rhos` × `seeds`, with each cell offering
/// `rho × capacity / n_tenants` Poisson traffic per tenant on copies of
/// `config` (capacity = the analytic steady-state throughput of `config`).
pub fn load_grid(
    plat: &Platform,
    net: &Network,
    config: &PipelineConfig,
    tenant_counts: &[usize],
    rhos: &[f64],
    seeds: &[u64],
    base: &ServeOptions,
) -> Vec<Scenario> {
    let db = PerfDb::build(net, plat, &CostModel::default());
    let cap = simulator::throughput(net, plat, &db, config);
    let mut out = Vec::with_capacity(tenant_counts.len() * rhos.len() * seeds.len());
    for &n_tenants in tenant_counts {
        for &rho in rhos {
            for &seed in seeds {
                let rate = if n_tenants > 0 { rho * cap / n_tenants as f64 } else { 0.0 };
                let tenants: Vec<(TenantSpec, PipelineConfig)> = (0..n_tenants)
                    .map(|i| {
                        (
                            TenantSpec::new(
                                format!("{}t{n_tenants}-rho{rho}-s{seed}-#{i}", net.name),
                                net.clone(),
                                ArrivalProcess::Poisson { rate },
                            ),
                            config.clone(),
                        )
                    })
                    .collect();
                let mut opts = base.clone();
                opts.seed = seed;
                out.push(Scenario {
                    name: format!("{} {n_tenants}t rho={rho} seed={seed}", net.name),
                    plat: plat.clone(),
                    tenants,
                    opts,
                });
            }
        }
    }
    out
}

/// Build the shard-scaling scenario grid: for every shard budget in
/// `shard_counts` (× load factor × seed), one tenant serves the same
/// **MMPP drift workload** — a low phase under a single pipeline's
/// capacity and a burst phase at `2.5 × rho × capacity` that saturates
/// every deployment — so goodput differences across cells isolate the
/// capacity added by replication under the identical contention model.
///
/// `capacity` is the analytic throughput of `config` (the unsharded
/// fallback, also served verbatim by the `shards = 1` cells); dwell times
/// split the horizon into ~6 alternating phases, and the SLO is set wide
/// (300 bottleneck periods) so bounded-queue completions count as goodput
/// for every shard count — the comparison measures throughput scaling,
/// not SLO tuning.
#[allow(clippy::too_many_arguments)]
pub fn shard_grid(
    plat: &Platform,
    net: &Network,
    config: &PipelineConfig,
    shard_counts: &[usize],
    balancer: BalancerPolicy,
    rhos: &[f64],
    seeds: &[u64],
    base: &ServeOptions,
) -> Vec<Scenario> {
    let db = PerfDb::build(net, plat, &CostModel::default());
    let cap = simulator::throughput(net, plat, &db, config);
    let dwell_s = (base.duration_s / 6.0).max(1e-6);
    let mut out = Vec::with_capacity(shard_counts.len() * rhos.len() * seeds.len());
    for &rho in rhos {
        for &seed in seeds {
            for &k in shard_counts {
                let arrivals = ArrivalProcess::Mmpp {
                    low_rate: 0.5 * rho * cap,
                    high_rate: 2.5 * rho * cap,
                    mean_low_s: dwell_s,
                    mean_high_s: dwell_s,
                };
                let spec = TenantSpec::new(
                    format!("{}-k{k}-rho{rho}-s{seed}", net.name),
                    net.clone(),
                    arrivals,
                )
                .with_shards(k)
                .with_balancer(balancer)
                .with_queue_capacity(16)
                .with_admission(super::tenant::AdmissionPolicy::DropOldest)
                .with_slo(300.0 / cap);
                let mut opts = base.clone();
                opts.seed = seed;
                out.push(Scenario {
                    name: format!(
                        "{} shards={k} rho={rho} seed={seed} {}",
                        net.name,
                        balancer.name()
                    ),
                    plat: plat.clone(),
                    tenants: vec![(spec, config.clone())],
                    opts,
                });
            }
        }
    }
    out
}

/// Build the static-vs-autoscaled comparison grid on an **MMPP tidal
/// workload**: a long lull well under one replica's capacity alternating
/// with a burst that saturates the largest static deployment (mean dwell
/// = a quarter of the horizon, so a run sees about two full tides).
///
/// For every `(rho, seed)` the grid emits one **static** cell per entry
/// of `shard_counts` (shard budget fixed, autoscaler off) plus one
/// **autoscaled** cell at the maximum budget (autoscaler on, defaults of
/// [`crate::serve::AutoscaleOptions`]). All cells of a `(rho, seed)` pair
/// share the identical arrival stream, so their goodput and
/// [`ScenarioStats::ep_epochs`] isolate exactly what the autoscaler
/// changes: the acceptance bar (asserted in `tests/cluster_autoscale.rs`
/// and tracked by `benches/serve_scale.rs`) is goodput within 2% of the
/// best static cell at strictly fewer EP-epochs than static max-k.
///
/// `capacity` is the analytic throughput of `config`; the SLO is set wide
/// (500 bottleneck periods) and queues deep (32, drop-oldest) so
/// bounded-queue completions count as goodput for every cell — the
/// comparison measures capacity adaptation, not SLO tuning. Callers pick
/// `base.control_epoch_s` well under the dwell time (the sweep CLI uses
/// horizon/40) so the controller gets enough epochs per phase.
#[allow(clippy::too_many_arguments)]
pub fn autoscale_grid(
    plat: &Platform,
    net: &Network,
    config: &PipelineConfig,
    shard_counts: &[usize],
    balancer: BalancerPolicy,
    rhos: &[f64],
    seeds: &[u64],
    base: &ServeOptions,
) -> Vec<Scenario> {
    let db = PerfDb::build(net, plat, &CostModel::default());
    let cap = simulator::throughput(net, plat, &db, config);
    let kmax = shard_counts.iter().copied().max().unwrap_or(1);
    let dwell_s = (base.duration_s / 4.0).max(1e-6);
    let mut out = Vec::with_capacity(rhos.len() * seeds.len() * (shard_counts.len() + 1));
    for &rho in rhos {
        for &seed in seeds {
            let arrivals = ArrivalProcess::Mmpp {
                low_rate: 0.25 * rho * cap,
                high_rate: 1.3 * rho * cap,
                mean_low_s: dwell_s,
                mean_high_s: dwell_s,
            };
            let mk_spec = |name: String, k: usize| {
                TenantSpec::new(name, net.clone(), arrivals.clone())
                    .with_shards(k)
                    .with_balancer(balancer)
                    .with_queue_capacity(32)
                    .with_admission(super::tenant::AdmissionPolicy::DropOldest)
                    .with_slo(500.0 / cap)
            };
            for &k in shard_counts {
                let name = format!("{} static-k{k} rho={rho} seed={seed}", net.name);
                let mut opts = base.clone();
                opts.seed = seed;
                opts.autoscale.enabled = false;
                out.push(Scenario {
                    name: name.clone(),
                    plat: plat.clone(),
                    tenants: vec![(mk_spec(name, k), config.clone())],
                    opts,
                });
            }
            let name = format!("{} autoscale-k{kmax} rho={rho} seed={seed}", net.name);
            let mut opts = base.clone();
            opts.seed = seed;
            opts.autoscale.enabled = true;
            out.push(Scenario {
                name: name.clone(),
                plat: plat.clone(),
                tenants: vec![(mk_spec(name, kmax), config.clone())],
                opts,
            });
        }
    }
    out
}

/// Build the elastic re-planning grid on an **anti-phase tidal
/// two-tenant workload**: tenant `ebb` is hot for the first half of the
/// horizon while tenant `flow` idles, then the tide flips (piecewise
/// Poisson with one exact change point at half the horizon). Both tenants
/// carry equal weight, so aggregate goodput doubles as the weighted
/// goodput of the cluster.
///
/// For every `(rho, seed)` the grid emits one **static** cell (co-plan
/// fixed at serve start) and one **live** cell (co-plan plus the elastic
/// loop, defaults of [`crate::serve::ElasticOptions`]). Both cells share
/// the identical arrival streams, so their goodput and
/// [`ScenarioStats::ep_epochs`] isolate exactly what demand-driven
/// re-partitioning changes: the acceptance bar (asserted in
/// `tests/cluster_autoscale.rs` and tracked by `benches/elastic_replan.rs`)
/// is live goodput ≥ static goodput at no more EP-epochs.
///
/// Queues are deep (32, drop-oldest) and the SLO wide (500 bottleneck
/// periods), so bounded-queue completions count as goodput — the
/// comparison measures budget adaptation, not SLO tuning. Callers pick
/// `base.control_epoch_s` well under half the horizon (the sweep CLI uses
/// horizon/40) so the elastic loop gets epochs on both sides of the flip.
pub fn elastic_grid(
    plat: &Platform,
    net: &Network,
    config: &PipelineConfig,
    rhos: &[f64],
    seeds: &[u64],
    base: &ServeOptions,
) -> Vec<Scenario> {
    let db = PerfDb::build(net, plat, &CostModel::default());
    let cap = simulator::throughput(net, plat, &db, config);
    let flip_s = base.duration_s / 2.0;
    let mut out = Vec::with_capacity(rhos.len() * seeds.len() * 2);
    for &rho in rhos {
        for &seed in seeds {
            let hot = rho * cap;
            let idle = 0.05 * rho * cap;
            let mk_spec = |name: String, early: f64, late: f64| {
                TenantSpec::new(name, net.clone(), ArrivalProcess::Piecewise {
                    segments: vec![(0.0, early), (flip_s, late)],
                })
                .with_queue_capacity(32)
                .with_admission(super::tenant::AdmissionPolicy::DropOldest)
                .with_slo(500.0 / cap)
            };
            let tenants = |prefix: &str| {
                vec![
                    (mk_spec(format!("{prefix}-ebb"), hot, idle), config.clone()),
                    (mk_spec(format!("{prefix}-flow"), idle, hot), config.clone()),
                ]
            };
            let mut opts = base.clone();
            opts.seed = seed;
            opts.coplan = true;
            opts.elastic.enabled = false;
            out.push(Scenario {
                name: format!("{} static rho={rho} seed={seed}", net.name),
                plat: plat.clone(),
                tenants: tenants("static"),
                opts,
            });
            let mut opts = base.clone();
            opts.seed = seed;
            opts.coplan = true;
            opts.elastic.enabled = true;
            out.push(Scenario {
                name: format!("{} elastic rho={rho} seed={seed}", net.name),
                plat: plat.clone(),
                tenants: tenants("elastic"),
                opts,
            });
        }
    }
    out
}

/// Build the fault-plane degradation grid on the same **MMPP tidal
/// workload** as [`autoscale_grid`]: for every `(rho, seed)` one
/// fault-free baseline cell, one **throttle** cell per entry of
/// `severities` (the strongest EP runs `severity`× slower for the middle
/// third of the horizon), and one **fail-stop** cell (the strongest EP
/// dies for good at a third of the horizon). All cells of a `(rho, seed)`
/// pair share the identical arrival stream, so goodput deltas against the
/// baseline isolate exactly what the fault costs after detect → drain →
/// re-plan failover (`benches/fault_recovery.rs` reports the same cells
/// as recovery envelopes).
///
/// Every cell serves a 2-replica JSQ deployment, queues deep (32,
/// drop-oldest) and the SLO wide (500 bottleneck periods), so
/// bounded-queue completions count as goodput — the comparison measures
/// surviving capacity, not SLO tuning. `severities` entries must be > 1
/// (they become [`FaultKind::EpSlow`] factors).
#[allow(clippy::too_many_arguments)]
pub fn fault_grid(
    plat: &Platform,
    net: &Network,
    config: &PipelineConfig,
    severities: &[f64],
    balancer: BalancerPolicy,
    rhos: &[f64],
    seeds: &[u64],
    base: &ServeOptions,
) -> Vec<Scenario> {
    let db = PerfDb::build(net, plat, &CostModel::default());
    let cap = simulator::throughput(net, plat, &db, config);
    let dwell_s = (base.duration_s / 4.0).max(1e-6);
    let target = plat.eps_by_rank()[0]; // faults hit the strongest EP
    let fault_t = base.duration_s / 3.0;
    let mut out = Vec::with_capacity(rhos.len() * seeds.len() * (severities.len() + 2));
    for &rho in rhos {
        for &seed in seeds {
            let arrivals = ArrivalProcess::Mmpp {
                low_rate: 0.25 * rho * cap,
                high_rate: 1.3 * rho * cap,
                mean_low_s: dwell_s,
                mean_high_s: dwell_s,
            };
            let mk_spec = |name: String| {
                TenantSpec::new(name, net.clone(), arrivals.clone())
                    .with_shards(2)
                    .with_balancer(balancer)
                    .with_queue_capacity(32)
                    .with_admission(super::tenant::AdmissionPolicy::DropOldest)
                    .with_slo(500.0 / cap)
            };
            let mut push = |label: String, faults: FaultScript| {
                let name = format!("{} {label} rho={rho} seed={seed}", net.name);
                let mut opts = base.clone();
                opts.seed = seed;
                opts.faults = faults;
                out.push(Scenario {
                    name: name.clone(),
                    plat: plat.clone(),
                    tenants: vec![(mk_spec(name), config.clone())],
                    opts,
                });
            };
            push("fault-free".to_string(), FaultScript::default());
            for &severity in severities {
                push(
                    format!("epslow-x{severity}"),
                    FaultScript {
                        events: vec![FaultEvent {
                            t_s: fault_t,
                            kind: FaultKind::EpSlow {
                                ep: target,
                                factor: severity,
                                down_s: fault_t,
                            },
                        }],
                    },
                );
            }
            push(
                "epfail".to_string(),
                FaultScript {
                    events: vec![FaultEvent {
                        t_s: fault_t,
                        kind: FaultKind::EpFail { ep: target },
                    }],
                },
            );
        }
    }
    out
}

/// Build the request-lifecycle robustness grid on the same **MMPP tidal
/// workload** as [`fault_grid`]: for every `(rho, seed)` and every fault
/// script in {fault-free, `epstall` on the strongest EP for the middle
/// third, `linkslow` ×3 for the middle third} the grid emits one
/// **blind** cell (no lifecycle policy — the pre-lifecycle engine,
/// byte-identical to a build without the layer) and one **lifecycle**
/// cell (deterministic retry with backoff + p95-tracking hedging on a
/// 2-replica JSQ deployment). All cells of a `(rho, seed)` pair share
/// the identical arrival stream, so goodput deltas isolate exactly what
/// retry + hedging buy back under each transient fault
/// (`benches/hedge_recovery.rs` reports the same cells as
/// goodput-retained ratios; the acceptance bar in `tests/lifecycle.rs`
/// is ≥ 95% of fault-free goodput at zero request loss).
///
/// Queues are deep (32, drop-oldest) and the SLO wide (500 bottleneck
/// periods), matching the sibling grids — the comparison measures what
/// the lifecycle layer recovers, not SLO tuning.
pub fn hedge_grid(
    plat: &Platform,
    net: &Network,
    config: &PipelineConfig,
    balancer: BalancerPolicy,
    rhos: &[f64],
    seeds: &[u64],
    base: &ServeOptions,
) -> Vec<Scenario> {
    use super::lifecycle::{HedgePolicy, RetryPolicy};
    let db = PerfDb::build(net, plat, &CostModel::default());
    let cap = simulator::throughput(net, plat, &db, config);
    let dwell_s = (base.duration_s / 4.0).max(1e-6);
    let target = plat.eps_by_rank()[0]; // transient faults hit the strongest EP
    let fault_t = base.duration_s / 3.0;
    let scripts = [
        ("fault-free", FaultScript::default()),
        (
            "epstall",
            FaultScript {
                events: vec![FaultEvent {
                    t_s: fault_t,
                    kind: FaultKind::EpStall { ep: target, down_s: fault_t },
                }],
            },
        ),
        (
            "linkslow-x3",
            FaultScript {
                events: vec![FaultEvent {
                    t_s: fault_t,
                    kind: FaultKind::LinkSlow { factor: 3.0, down_s: fault_t },
                }],
            },
        ),
    ];
    let mut out = Vec::with_capacity(rhos.len() * seeds.len() * scripts.len() * 2);
    for &rho in rhos {
        for &seed in seeds {
            let arrivals = ArrivalProcess::Mmpp {
                low_rate: 0.25 * rho * cap,
                high_rate: 1.3 * rho * cap,
                mean_low_s: dwell_s,
                mean_high_s: dwell_s,
            };
            let mk_spec = |name: String, lifecycle: bool| {
                let spec = TenantSpec::new(name, net.clone(), arrivals.clone())
                    .with_shards(2)
                    .with_balancer(balancer)
                    .with_queue_capacity(32)
                    .with_admission(super::tenant::AdmissionPolicy::DropOldest)
                    .with_slo(500.0 / cap);
                if lifecycle {
                    spec.with_retry(RetryPolicy::default())
                        .with_hedge(HedgePolicy::default())
                } else {
                    spec
                }
            };
            for (label, faults) in &scripts {
                for (policy, lifecycle) in [("blind", false), ("lifecycle", true)] {
                    let name =
                        format!("{} {label} {policy} rho={rho} seed={seed}", net.name);
                    let mut opts = base.clone();
                    opts.seed = seed;
                    opts.faults = faults.clone();
                    out.push(Scenario {
                        name: name.clone(),
                        plat: plat.clone(),
                        tenants: vec![(mk_spec(name, lifecycle), config.clone())],
                        opts,
                    });
                }
            }
        }
    }
    out
}

/// Fan one captured flight-recorder trace across a what-if policy grid:
/// every `shard_counts` × `balancers` cell re-simulates the trace's
/// captured arrival streams ([`whatif_inputs`]) under that policy. The
/// returned scenarios plug straight into [`run_sweep`] — counterfactual
/// cells run in parallel on the existing thread pool, so "would 3 shards
/// have held p99 through yesterday's storm?" costs one pass over the
/// grid.
pub fn whatif_grid(
    trace: &Trace,
    shard_counts: &[usize],
    balancers: &[BalancerPolicy],
) -> Result<Vec<Scenario>> {
    let mut out = Vec::with_capacity(shard_counts.len() * balancers.len());
    for &k in shard_counts {
        for &balancer in balancers {
            let what_if =
                WhatIf { shards: Some(k), balancer: Some(balancer), ..Default::default() };
            let (plat, tenants, opts) = whatif_inputs(trace, &what_if)?;
            out.push(Scenario {
                name: format!("whatif shards={k} {}", balancer.name()),
                plat,
                tenants,
                opts,
            });
        }
    }
    Ok(out)
}

fn run_one(sc: &Scenario) -> SweepOutcome {
    let t0 = std::time::Instant::now();
    let report = serve(&sc.plat, sc.tenants.clone(), &sc.opts);
    SweepOutcome { name: sc.name.clone(), wall_s: t0.elapsed().as_secs_f64(), report }
}

/// Run every scenario across up to `threads` worker threads; outcomes come
/// back in input order and are independent of the thread count.
pub fn run_sweep(scenarios: Vec<Scenario>, threads: usize) -> Vec<SweepOutcome> {
    let threads = threads.clamp(1, scenarios.len().max(1));
    if threads == 1 || scenarios.len() <= 1 {
        return scenarios.iter().map(run_one).collect();
    }
    run_parallel(&scenarios, threads)
}

#[cfg(not(feature = "rayon"))]
fn run_parallel(scenarios: &[Scenario], threads: usize) -> Vec<SweepOutcome> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<SweepOutcome>> = Vec::new();
    slots.resize_with(scenarios.len(), || None);
    let results = Mutex::new(slots);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let ix = next.fetch_add(1, Ordering::Relaxed);
                if ix >= scenarios.len() {
                    break;
                }
                let out = run_one(&scenarios[ix]);
                results.lock().expect("sweep mutex poisoned")[ix] = Some(out);
            });
        }
    });
    results
        .into_inner()
        .expect("sweep mutex poisoned")
        .into_iter()
        .map(|o| o.expect("every scenario index was claimed exactly once"))
        .collect()
}

#[cfg(feature = "rayon")]
fn run_parallel(scenarios: &[Scenario], threads: usize) -> Vec<SweepOutcome> {
    use rayon::prelude::*;
    let pool = rayon::ThreadPoolBuilder::new()
        .num_threads(threads)
        .build()
        .expect("rayon pool");
    pool.install(|| scenarios.par_iter().map(run_one).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::networks;
    use crate::platform::configs;

    fn grid(seeds: &[u64]) -> Vec<Scenario> {
        let plat = configs::c1();
        let net = networks::synthnet_small();
        let cfg = PipelineConfig::new(vec![3, 3], vec![0, 1]);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        let base = ServeOptions {
            duration_s: 80.0 / cap,
            control: false,
            control_epoch_s: 0.0,
            ..Default::default()
        };
        load_grid(&plat, &net, &cfg, &[1, 2], &[0.4], seeds, &base)
    }

    #[test]
    fn grid_covers_cross_product_with_unique_names() {
        let sc = grid(&[1, 2, 3]);
        assert_eq!(sc.len(), 2 * 3);
        let mut names: Vec<&str> = sc.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), sc.len(), "scenario names must be unique");
        assert_eq!(sc[0].opts.seed, 1);
        assert_eq!(sc[1].opts.seed, 2);
        assert_eq!(sc[3].tenants.len(), 2);
    }

    #[test]
    fn sweep_outcomes_in_input_order_and_thread_invariant() {
        let a = run_sweep(grid(&[5, 6]), 1);
        let b = run_sweep(grid(&[5, 6]), 4);
        assert_eq!(a.len(), 4);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name, "order must match input order");
            let rx = x.report.as_ref().expect("serve run");
            let ry = y.report.as_ref().expect("serve run");
            assert_eq!(rx.log_hash, ry.log_hash, "{}: thread count changed outcome", x.name);
            assert_eq!(rx.n_events, ry.n_events);
            assert_eq!(rx.tenants[0].completed, ry.tenants[0].completed);
            assert!(rx.tenants.iter().all(|t| t.conserved()));
        }
    }

    #[test]
    fn sweep_isolates_scenario_errors() {
        let mut sc = grid(&[9]);
        assert_eq!(sc.len(), 2);
        sc[0].opts.duration_s = 0.0; // invalid: engine must reject it
        let out = run_sweep(sc, 2);
        assert!(out[0].report.is_err(), "invalid scenario must error");
        assert!(out[1].report.is_ok(), "other scenarios must still run");
        assert!(out[1].events_per_s().unwrap_or(0.0) > 0.0);
    }

    #[test]
    fn shard_grid_covers_counts_and_same_seed_same_arrivals() {
        let plat = configs::c1();
        let net = networks::synthnet_small();
        let cfg = PipelineConfig::new(vec![3, 3], vec![0, 1]);
        let base = ServeOptions {
            duration_s: 2.0,
            control: false,
            control_epoch_s: 0.0,
            ..Default::default()
        };
        let sc = shard_grid(
            &plat,
            &net,
            &cfg,
            &[1, 2],
            crate::serve::BalancerPolicy::RoundRobin,
            &[1.0],
            &[7, 8],
            &base,
        );
        assert_eq!(sc.len(), 4);
        let mut names: Vec<&str> = sc.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 4, "cell names unique");
        // cells of one seed differ only in the shard budget
        assert_eq!(sc[0].opts.seed, sc[1].opts.seed);
        assert_eq!(sc[0].tenants[0].0.arrivals, sc[1].tenants[0].0.arrivals);
        assert_eq!(sc[0].tenants[0].0.shards, 1);
        assert_eq!(sc[1].tenants[0].0.shards, 2);
    }

    #[test]
    fn shard_grid_goodput_monotone_on_mmpp_drift() {
        // The ROADMAP headline: on C5/SynthNet, goodput under the same
        // MMPP drift workload must not decrease as the shard budget grows
        // {1, 2, 4} — the placement search guarantees the *predicted*
        // ordering (candidate sets nest), and the saturating burst phase
        // makes realized goodput track capacity.
        let plat = configs::c5();
        let net = networks::synthnet();
        let cfg = crate::serve::shisha_config(&net, &plat);
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let cap = simulator::throughput(&net, &plat, &db, &cfg);
        let base = ServeOptions {
            duration_s: 400.0 / cap,
            control: false,
            control_epoch_s: 0.0,
            ..Default::default()
        };
        let sc = shard_grid(
            &plat,
            &net,
            &cfg,
            &[1, 2, 4],
            crate::serve::BalancerPolicy::JoinShortestQueue,
            &[1.0],
            &[31],
            &base,
        );
        let out = run_sweep(sc, available_threads());
        let goodputs: Vec<f64> = out
            .iter()
            .map(|o| ScenarioStats::from_report(o.report.as_ref().expect("serve run")).goodput_rps)
            .collect();
        assert_eq!(goodputs.len(), 3);
        for w in goodputs.windows(2) {
            assert!(
                w[1] >= 0.999 * w[0],
                "goodput must not decrease with shard budget: {goodputs:?}"
            );
        }
        assert!(
            goodputs[2] > 1.01 * goodputs[0],
            "replication must add real capacity: {goodputs:?}"
        );
    }

    #[test]
    fn autoscale_grid_cells_share_arrivals() {
        let plat = configs::c1();
        let net = networks::synthnet_small();
        let cfg = PipelineConfig::new(vec![3, 3], vec![0, 1]);
        let base = ServeOptions {
            duration_s: 2.0,
            control: false,
            control_epoch_s: 0.1,
            ..Default::default()
        };
        let sc = autoscale_grid(
            &plat,
            &net,
            &cfg,
            &[1, 2],
            crate::serve::BalancerPolicy::JoinShortestQueue,
            &[1.0],
            &[3],
            &base,
        );
        assert_eq!(sc.len(), 3, "static k1, static k2, autoscaled kmax");
        assert!(sc[0].name.contains("static-k1"), "{}", sc[0].name);
        assert!(sc[1].name.contains("static-k2"), "{}", sc[1].name);
        assert!(sc[2].name.contains("autoscale-k2"), "{}", sc[2].name);
        assert!(!sc[0].opts.autoscale.enabled);
        assert!(!sc[1].opts.autoscale.enabled);
        assert!(sc[2].opts.autoscale.enabled);
        // every cell of one (rho, seed) pair sees the same arrival stream
        assert_eq!(sc[0].tenants[0].0.arrivals, sc[2].tenants[0].0.arrivals);
        assert_eq!(sc[0].opts.seed, sc[2].opts.seed);
        assert_eq!(sc[2].tenants[0].0.shards, 2, "autoscaled cell plans the max budget");
    }

    #[test]
    fn elastic_grid_pairs_static_and_live_cells() {
        let plat = configs::c1();
        let net = networks::synthnet_small();
        let cfg = PipelineConfig::new(vec![3, 3], vec![0, 1]);
        let base = ServeOptions {
            duration_s: 2.0,
            control: false,
            control_epoch_s: 0.1,
            ..Default::default()
        };
        let sc = elastic_grid(&plat, &net, &cfg, &[1.0], &[3, 4], &base);
        assert_eq!(sc.len(), 4, "one static + one elastic cell per seed");
        for pair in sc.chunks(2) {
            let (st, el) = (&pair[0], &pair[1]);
            assert!(st.name.contains("static"), "{}", st.name);
            assert!(el.name.contains("elastic"), "{}", el.name);
            assert!(st.opts.coplan && el.opts.coplan, "both cells co-plan");
            assert!(!st.opts.elastic.enabled);
            assert!(el.opts.elastic.enabled);
            assert_eq!(st.opts.seed, el.opts.seed);
            // the two cells of a seed share the identical workload
            assert_eq!(st.tenants.len(), 2);
            for (a, b) in st.tenants.iter().zip(&el.tenants) {
                assert_eq!(a.0.arrivals, b.0.arrivals);
            }
            // anti-phase: ebb and flow swap their piecewise segments
            let ArrivalProcess::Piecewise { segments: ebb } = &st.tenants[0].0.arrivals
            else {
                panic!("elastic grid must build piecewise arrivals");
            };
            let ArrivalProcess::Piecewise { segments: flow } = &st.tenants[1].0.arrivals
            else {
                panic!("elastic grid must build piecewise arrivals");
            };
            assert_eq!(ebb[0].1.to_bits(), flow[1].1.to_bits());
            assert_eq!(ebb[1].1.to_bits(), flow[0].1.to_bits());
        }
    }

    #[test]
    fn fault_grid_covers_cells_and_shares_arrivals() {
        let plat = configs::c1();
        let net = networks::synthnet_small();
        let cfg = PipelineConfig::new(vec![3, 3], vec![0, 1]);
        let base = ServeOptions {
            duration_s: 2.0,
            control: false,
            control_epoch_s: 0.1,
            ..Default::default()
        };
        let sc = fault_grid(
            &plat,
            &net,
            &cfg,
            &[2.0, 4.0],
            crate::serve::BalancerPolicy::JoinShortestQueue,
            &[1.0],
            &[5],
            &base,
        );
        assert_eq!(sc.len(), 4, "baseline, two throttle severities, fail-stop");
        assert!(sc[0].name.contains("fault-free"), "{}", sc[0].name);
        assert!(sc[1].name.contains("epslow-x2"), "{}", sc[1].name);
        assert!(sc[2].name.contains("epslow-x4"), "{}", sc[2].name);
        assert!(sc[3].name.contains("epfail"), "{}", sc[3].name);
        assert!(sc[0].opts.faults.is_empty());
        for s in &sc[1..] {
            assert_eq!(s.opts.faults.events.len(), 1, "{}", s.name);
            assert!(s.opts.faults.validate(&plat).is_ok(), "{}", s.name);
        }
        // every cell of one (rho, seed) pair sees the same arrival stream
        for s in &sc[1..] {
            assert_eq!(sc[0].tenants[0].0.arrivals, s.tenants[0].0.arrivals);
            assert_eq!(sc[0].opts.seed, s.opts.seed);
        }
        // the grid runs end to end and every cell conserves requests
        let out = run_sweep(sc, available_threads());
        for o in &out {
            let r = o.report.as_ref().expect("serve run");
            assert!(r.tenants.iter().all(|t| t.conserved()), "{}", o.name);
        }
    }

    #[test]
    fn hedge_grid_pairs_blind_and_lifecycle_cells() {
        let plat = configs::c1();
        let net = networks::synthnet_small();
        let cfg = PipelineConfig::new(vec![3, 3], vec![0, 1]);
        let base = ServeOptions {
            duration_s: 2.0,
            control: false,
            control_epoch_s: 0.1,
            ..Default::default()
        };
        let sc = hedge_grid(
            &plat,
            &net,
            &cfg,
            crate::serve::BalancerPolicy::JoinShortestQueue,
            &[1.0],
            &[5],
            &base,
        );
        assert_eq!(sc.len(), 6, "3 fault scripts × {{blind, lifecycle}}");
        for pair in sc.chunks(2) {
            let (blind, lc) = (&pair[0], &pair[1]);
            assert!(blind.name.contains("blind"), "{}", blind.name);
            assert!(lc.name.contains("lifecycle"), "{}", lc.name);
            assert!(!blind.tenants[0].0.lifecycle_active());
            assert!(lc.tenants[0].0.lifecycle_active());
            assert!(lc.tenants[0].0.retry.is_some() && lc.tenants[0].0.hedge.is_some());
            // the two cells of a script share workload and fault script
            assert_eq!(blind.tenants[0].0.arrivals, lc.tenants[0].0.arrivals);
            assert_eq!(blind.opts.faults, lc.opts.faults);
            assert_eq!(blind.opts.seed, lc.opts.seed);
        }
        assert!(sc[0].opts.faults.is_empty());
        assert_eq!(sc[2].opts.faults.events.len(), 1, "epstall cell");
        assert_eq!(sc[4].opts.faults.events.len(), 1, "linkslow cell");
        for s in &sc[2..] {
            assert!(s.opts.faults.validate(&plat).is_ok(), "{}", s.name);
        }
        // the grid runs end to end and every cell conserves requests
        let out = run_sweep(sc, available_threads());
        for o in &out {
            let r = o.report.as_ref().expect("serve run");
            assert!(r.tenants.iter().all(|t| t.conserved()), "{}", o.name);
            let stats = ScenarioStats::from_report(r);
            if o.name.contains("blind") {
                assert_eq!(
                    stats.retried + stats.hedged + stats.expired + stats.cancelled,
                    0,
                    "{}: blind cells must not exercise the lifecycle layer",
                    o.name
                );
            }
        }
    }

    #[test]
    fn scenario_stats_aggregate_tenants() {
        let out = run_sweep(grid(&[11]), 1);
        let r = out[1].report.as_ref().expect("serve run"); // 2-tenant cell
        let stats = ScenarioStats::from_report(r);
        let offered: u64 = r.tenants.iter().map(|t| t.offered).sum();
        assert_eq!(stats.offered, offered);
        assert!(stats.goodput_rps > 0.0);
        assert!(stats.p99_s >= stats.p50_s);
        assert!(stats.fairness > 0.0 && stats.fairness <= 1.0 + 1e-12);
        assert!(stats.drop_rate() <= 1.0);
    }
}
