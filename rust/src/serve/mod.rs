//! `serve` — a discrete-event multi-tenant serving engine with online
//! Shisha re-tuning.
//!
//! Where [`crate::pipeline::simulator`] answers "what is the steady-state
//! throughput of this configuration" and [`crate::coordinator`] runs a
//! real threaded pipeline, this subsystem evaluates Shisha schedules **the
//! way a production deployment experiences them**: timestamped requests,
//! bounded queues, batching, tail latency, multiple models contending for
//! the same chiplets, and arrival-rate drift that forces the scheduler to
//! adapt online.
//!
//! Module map:
//!
//! * [`arrivals`] — Poisson / Markov-modulated / diurnal / piecewise /
//!   trace-replay arrival processes, all reproducible from one seed;
//! * [`tenant`] — per-model serving specs (SLO, queueing, batching,
//!   admission policy);
//! * [`engine`] — the event-heap simulator plus the control loop that
//!   feeds observed per-EP slowdowns back into
//!   [`crate::coordinator::AdaptiveController::warm_retune`]; its steady
//!   state is allocation-free (request slab arena, recycled batch
//!   buffers, event-driven settling, scratch re-tune database — see the
//!   engine docs §Hot-path design);
//! * [`shard`] — sharded serving: the shard-placement search that
//!   partitions the platform's EPs into disjoint subsets, tunes one
//!   replica pipeline per subset, and the front-end [`BalancerPolicy`]
//!   the engine routes arrivals with (`TenantSpec::with_shards`);
//! * [`fault`] — the deterministic fault plane: scripted EP
//!   fail-stop/stall/slowdown and inter-chiplet link degradation/cut
//!   ([`FaultScript`], `serve --faults` / `--chaos`), injected as heap
//!   events, hashed into the event log and driving the engine's
//!   detect → drain → re-plan failover (see the crate docs §Fault
//!   tolerance & graceful degradation);
//! * [`lifecycle`] — request-lifecycle robustness: per-request deadlines
//!   (expired stragglers reaped from queues before they waste batch
//!   slots), deterministic retry with exponential backoff + decorrelated
//!   RNG-free jitter ([`RetryPolicy`]), and hedged requests duplicated
//!   onto the least-loaded sibling replica with first-completion-wins
//!   cancellation ([`HedgePolicy`]) — all hashed heap events (trace
//!   format v4), byte-identical to a pre-lifecycle build when disabled;
//! * [`cluster`] — cluster-level control: the cross-tenant **co-planner**
//!   ([`cluster::coplan`] — joint disjoint EP budgets, weighted
//!   water-filling, provably never worse than greedy first-come
//!   allocation) and the epoch-driven **shard autoscaler**
//!   ([`cluster::autoscale`] — replicas activate, drain and park with the
//!   load, with hysteresis), plus the **elastic control loop**
//!   (`serve --elastic`) that re-runs the co-planner every control epoch
//!   on observed demand and live-migrates queued requests onto the new
//!   EP partition, all enabled per run via [`ServeOptions`];
//! * [`sweep`] — parallel scenario sweeps: independent serving scenarios
//!   fanned out across CPU cores with order- and thread-count-invariant
//!   results (`shisha serve --sweep`), including side-by-side shard-count
//!   grids ([`sweep::shard_grid`], `shisha serve --sweep --shard-grid`)
//!   and what-if grids over one captured trace ([`sweep::whatif_grid`]);
//! * [`trace`] — the flight recorder: compact binary trace capture
//!   ([`serve_traced`], `serve --record`), bit-identical deterministic
//!   replay ([`replay_full`], `serve --replay`) and arrivals-only what-if
//!   re-simulation under a different policy ([`replay_whatif`],
//!   `--what-if shards=K,balancer=P,...`);
//! * [`obs`] — the telemetry plane: allocation-free metrics registry,
//!   per-epoch utilization time series, control-plane causality journal
//!   and engine self-profiling ([`serve_observed`], `serve --metrics` /
//!   `--prom`), plus retroactive trace analytics ([`replay_observed`],
//!   `trace analyze`) — all derived **beside** the event-hash funnel, so
//!   `log_hash` is byte-identical with telemetry on or off;
//! * [`slo`] — streaming latency-quantile sketch, goodput and Jain
//!   fairness.
//!
//! See the crate-level docs ("Serving" and "Performance") for the event
//! model and the contention assumptions.

pub mod arrivals;
pub mod cluster;
pub mod engine;
pub mod fault;
pub mod lifecycle;
pub mod obs;
pub mod shard;
pub mod slo;
pub mod sweep;
pub mod tenant;
pub mod trace;

pub use arrivals::{ArrivalProcess, ArrivalSampler};
pub use cluster::{
    AutoscaleOptions, ClusterPlan, ElasticOptions, ReplicaState, ScaleEvent, TenantDemand,
};
pub use engine::{
    serve, serve_observed, serve_traced, serve_traced_observed, EpochStats, PumpMode,
    ServeOptions, ServeReport, ShardReport, TenantReport,
};
pub use fault::{FaultEvent, FaultKind, FaultScript};
pub use lifecycle::{HedgePolicy, RetryPolicy};
pub use obs::{EpochSample, Journal, JournalEntry, ObsReport, ProfReport, Registry};
pub use shard::{plan_shards, plan_shards_with, BalancerPolicy, ShardPlan};
pub use slo::{jain_fairness, QuantileSketch};
pub use sweep::{run_sweep, whatif_grid, Scenario, ScenarioStats, SweepOutcome};
pub use tenant::{AdmissionPolicy, TenantSpec};
pub use trace::{
    replay_full, replay_observed, replay_whatif, Capture, ControlKind, ControlRecord, Trace,
    TraceEvent, WhatIf,
};

use crate::explore::shisha::{ShishaExplorer, ShishaOptions};
use crate::explore::{EvalOptions, Evaluator, Explorer};
use crate::model::Network;
use crate::perfdb::{CostModel, PerfDb};
use crate::pipeline::PipelineConfig;
use crate::platform::Platform;

/// Tune a tenant's initial pipeline configuration with Shisha (H3, bounded
/// evaluations) against the contention-free database — the natural starting
/// point before the serving engine's online loop takes over.
pub fn shisha_config(net: &Network, plat: &Platform) -> PipelineConfig {
    let db = PerfDb::build(net, plat, &CostModel::default());
    let opts = EvalOptions { max_evals: Some(500), ..Default::default() };
    let mut eval = Evaluator::with_options(net, plat, &db, opts);
    ShishaExplorer::new(ShishaOptions::default()).explore(&mut eval).best_config
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::networks;
    use crate::pipeline::simulator;
    use crate::platform::configs;

    #[test]
    fn shisha_config_is_valid_and_competitive() {
        let net = networks::synthnet();
        let plat = configs::c2();
        let cfg = shisha_config(&net, &plat);
        assert!(cfg.validate(net.len(), &plat).is_ok());
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let tuned = simulator::throughput(&net, &plat, &db, &cfg);
        let single = simulator::throughput(
            &net,
            &plat,
            &db,
            &PipelineConfig::single_stage(net.len(), 0),
        );
        assert!(tuned > single, "Shisha ({tuned}) must beat single-EP ({single})");
    }
}
