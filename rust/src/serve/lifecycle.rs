//! Request-lifecycle robustness policies: deadlines, deterministic
//! retry/backoff, and hedged requests.
//!
//! Three per-tenant knobs, all **off by default** — a tenant without any
//! of them schedules no lifecycle events and leaves every engine hash
//! byte-identical to a pre-lifecycle build:
//!
//! * **Deadline** ([`crate::serve::TenantSpec::with_deadline`]) — each
//!   admitted request carries a budget measured from its (re-)arrival;
//!   when it expires while the request is still *queued* the engine reaps
//!   it before it can waste a batch slot (tag-9 heap event, counted as
//!   `expired`, never as a shed or a drop). Requests already in service
//!   run to completion — interrupting silicon mid-batch buys nothing.
//! * **Retry** ([`RetryPolicy`]) — a rejected, dropped, or expired
//!   request re-arrives after an exponential backoff with decorrelated
//!   jitter. The jitter derives from an FNV-1a hash of
//!   `(seed, tenant, id, attempt)` — the same RNG-free trick the arrival
//!   trace replay uses — so a recorded run replays its retry schedule bit
//!   for bit. Attempt `k` (1-based) sleeps
//!   `min(cap, base·2^(k-1)) · (0.5 + 0.5·u)` with `u ∈ [0, 1)`.
//!   Graceful-degradation sheds do **not** retry: degradation exists to
//!   shed load, and retries would fight it.
//! * **Hedge** ([`HedgePolicy`]) — a request still waiting in its entry
//!   queue after the tenant's p9x-derived hedge delay is duplicated onto
//!   the least-loaded *sibling* replica; first completion wins and the
//!   loser is cancelled (queued loser reaped immediately, in-service
//!   loser doomed and discarded at delivery) with correct slab-arena
//!   recycling and WTP credit reversal. The hedge delay re-derives every
//!   control epoch from the tenant's merged latency sketch
//!   ([`crate::serve::QuantileSketch::quantile_or`]), so it tracks the
//!   observed tail, not a guess.
//!
//! All three fire as ordinary hashed heap events (trace tags 9–12), so
//! faulted-plus-hedged runs record, replay and what-if exactly like any
//! other run (trace format v4).

use anyhow::{bail, Context, Result};

/// Deterministic exponential-backoff retry policy for one tenant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Maximum re-arrivals per original request (0 disables retry).
    pub max_attempts: u32,
    /// Backoff base, seconds: attempt `k` waits `base · 2^(k-1)` before
    /// jitter.
    pub base_s: f64,
    /// Backoff ceiling, seconds: the un-jittered delay never exceeds it.
    pub cap_s: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { max_attempts: 3, base_s: 0.01, cap_s: 1.0 }
    }
}

impl RetryPolicy {
    /// Parse the CLI form `MAX[:BASE_S[:CAP_S]]`, e.g. `3`, `3:0.01`,
    /// `5:0.02:2.0`. Unspecified fields keep the defaults.
    pub fn parse(s: &str) -> Result<Self> {
        let mut out = RetryPolicy::default();
        let mut parts = s.split(':');
        let max = parts.next().context("retry spec is empty")?.trim();
        out.max_attempts =
            max.parse().with_context(|| format!("bad retry max-attempts {max:?}"))?;
        if let Some(base) = parts.next() {
            out.base_s = base
                .trim()
                .parse()
                .with_context(|| format!("bad retry base seconds {:?}", base.trim()))?;
        }
        if let Some(cap) = parts.next() {
            out.cap_s = cap
                .trim()
                .parse()
                .with_context(|| format!("bad retry cap seconds {:?}", cap.trim()))?;
        }
        if let Some(extra) = parts.next() {
            bail!("retry spec has trailing field {extra:?} (want MAX[:BASE_S[:CAP_S]])");
        }
        out.validate()?;
        Ok(out)
    }

    /// Render in the CLI grammar (`parse(describe())` round-trips).
    pub fn describe(&self) -> String {
        format!("{}:{}:{}", self.max_attempts, self.base_s, self.cap_s)
    }

    /// Reject non-finite or non-positive backoff parameters.
    pub fn validate(&self) -> Result<()> {
        if !self.base_s.is_finite() || self.base_s <= 0.0 {
            bail!("retry base {} must be finite and > 0 seconds", self.base_s);
        }
        if !self.cap_s.is_finite() || self.cap_s < self.base_s {
            bail!(
                "retry cap {} must be finite and ≥ the base ({})",
                self.cap_s,
                self.base_s
            );
        }
        Ok(())
    }

    /// Backoff before re-arrival attempt `k` (1-based), jittered by
    /// `u ∈ [0, 1)`: `min(cap, base·2^(k-1)) · (0.5 + 0.5u)`.
    pub fn delay_s(&self, attempt: u32, u: f64) -> f64 {
        let exp = self.base_s * f64::powi(2.0, attempt.saturating_sub(1).min(62) as i32);
        exp.min(self.cap_s) * (0.5 + 0.5 * u)
    }
}

/// Hedged-request policy for one tenant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HedgePolicy {
    /// Latency quantile the hedge delay tracks (strictly inside (0, 1);
    /// e.g. 0.95 hedges requests older than the observed p95).
    pub quantile: f64,
    /// Hedge-delay floor, seconds — guards against a cold sketch deriving
    /// a near-zero delay and hedging everything.
    pub min_delay_s: f64,
}

impl Default for HedgePolicy {
    fn default() -> Self {
        Self { quantile: 0.95, min_delay_s: 0.0 }
    }
}

impl HedgePolicy {
    /// Parse the CLI form `Q[:MIN_S]` where `Q` is `p95`, `p99`, or a
    /// bare quantile like `0.9`, e.g. `p95`, `0.99:0.002`.
    pub fn parse(s: &str) -> Result<Self> {
        let mut out = HedgePolicy::default();
        let (q, min) = match s.split_once(':') {
            Some((q, m)) => (q.trim(), Some(m.trim())),
            None => (s.trim(), None),
        };
        out.quantile = match q.to_ascii_lowercase().as_str() {
            "p50" => 0.50,
            "p90" => 0.90,
            "p95" => 0.95,
            "p99" => 0.99,
            other => other
                .parse()
                .with_context(|| format!("bad hedge quantile {other:?} (p95, p99, or 0-1)"))?,
        };
        if let Some(min) = min {
            out.min_delay_s = min
                .parse()
                .with_context(|| format!("bad hedge min-delay seconds {min:?}"))?;
        }
        out.validate()?;
        Ok(out)
    }

    /// Render in the CLI grammar (`parse(describe())` round-trips).
    pub fn describe(&self) -> String {
        format!("{}:{}", self.quantile, self.min_delay_s)
    }

    /// Reject quantiles outside (0, 1) and negative floors.
    pub fn validate(&self) -> Result<()> {
        if !self.quantile.is_finite() || self.quantile <= 0.0 || self.quantile >= 1.0 {
            bail!("hedge quantile {} must lie strictly inside (0, 1)", self.quantile);
        }
        if !self.min_delay_s.is_finite() || self.min_delay_s < 0.0 {
            bail!("hedge min-delay {} must be finite and ≥ 0 seconds", self.min_delay_s);
        }
        Ok(())
    }
}

/// Decorrelated-jitter source: a uniform in `[0, 1)` derived from an
/// FNV-1a hash of `(seed, tenant, id, attempt)`. Pure — the same inputs
/// always produce the same jitter, so a replayed run reconstructs the
/// exact retry schedule without any RNG state (the same discipline as
/// the hashed event stream itself).
pub fn jitter_u01(seed: u64, tenant: u64, id: u64, attempt: u32) -> f64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for x in [seed, tenant, id, u64::from(attempt)] {
        for byte in x.to_le_bytes() {
            h ^= u64::from(byte);
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    // Top 53 bits → uniform f64 in [0, 1).
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_parse_round_trips_and_validates() {
        let r = RetryPolicy::parse("3").unwrap();
        assert_eq!(r.max_attempts, 3);
        assert_eq!(r, RetryPolicy::parse(&r.describe()).unwrap());
        let r = RetryPolicy::parse("5:0.02:2.5").unwrap();
        assert_eq!(r, RetryPolicy { max_attempts: 5, base_s: 0.02, cap_s: 2.5 });
        assert_eq!(r, RetryPolicy::parse(&r.describe()).unwrap());
        for bad in ["", "x", "3:0", "3:-1", "3:0.5:0.1", "3:1:2:9", "3:nan"] {
            assert!(RetryPolicy::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn hedge_parse_round_trips_and_validates() {
        assert_eq!(HedgePolicy::parse("p95").unwrap().quantile, 0.95);
        assert_eq!(HedgePolicy::parse("p99").unwrap().quantile, 0.99);
        let h = HedgePolicy::parse("0.9:0.005").unwrap();
        assert_eq!(h, HedgePolicy { quantile: 0.9, min_delay_s: 0.005 });
        assert_eq!(h, HedgePolicy::parse(&h.describe()).unwrap());
        for bad in ["", "p101", "0", "1", "1.5", "0.9:-1", "0.9:inf"] {
            assert!(HedgePolicy::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn retry_delay_backs_off_exponentially_under_the_cap() {
        let r = RetryPolicy { max_attempts: 10, base_s: 0.01, cap_s: 0.1 };
        // u = 1 would give the full exponential; u = 0 halves it.
        assert!((r.delay_s(1, 0.0) - 0.005).abs() < 1e-12);
        assert!((r.delay_s(2, 0.0) - 0.01).abs() < 1e-12);
        assert!((r.delay_s(3, 0.0) - 0.02).abs() < 1e-12);
        // The cap bites at attempt 5 (0.16 → 0.1).
        assert!((r.delay_s(5, 0.0) - 0.05).abs() < 1e-12);
        assert!((r.delay_s(40, 0.999) - 0.1 * 0.9995).abs() < 1e-9, "huge attempts stay capped");
    }

    #[test]
    fn jitter_is_deterministic_uniform_and_decorrelated() {
        let a = jitter_u01(42, 0, 7, 1);
        assert_eq!(a.to_bits(), jitter_u01(42, 0, 7, 1).to_bits(), "pure function");
        assert!((0.0..1.0).contains(&a));
        // Neighbouring ids/attempts decorrelate (no lockstep retries).
        assert_ne!(a.to_bits(), jitter_u01(42, 0, 8, 1).to_bits());
        assert_ne!(a.to_bits(), jitter_u01(42, 0, 7, 2).to_bits());
        assert_ne!(a.to_bits(), jitter_u01(43, 0, 7, 1).to_bits());
        // Crude uniformity: the mean of a small sweep sits near 1/2.
        let mean: f64 =
            (0..1000).map(|i| jitter_u01(1, 2, i, 1)).sum::<f64>() / 1000.0;
        assert!((mean - 0.5).abs() < 0.05, "mean {mean}");
    }
}
