//! Table builders: markdown for terminal output, CSV for `results/`.
//!
//! Every bench harness prints the paper's rows through these so the output
//! format is uniform and machine-readable.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// A simple column-oriented table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given headers.
    pub fn new<S: Into<String>>(headers: impl IntoIterator<Item = S>) -> Self {
        Self { headers: headers.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append a row; must match the header count.
    pub fn row<S: Into<String>>(&mut self, cells: impl IntoIterator<Item = S>) -> &mut Self {
        let row: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.headers.len(), "row width mismatch");
        self.rows.push(row);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as a GitHub-flavoured markdown table.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(line, " {c:<w$} |");
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{:-<width$}|", "", width = w + 2);
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Render as CSV (RFC-4180-ish: quote cells containing commas/quotes).
    pub fn to_csv(&self) -> String {
        let esc = |c: &String| -> String {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(esc).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV form to `path`, creating parent directories.
    pub fn write_csv(&self, path: impl AsRef<Path>) -> io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.to_csv())
    }
}

/// One row of a latency-percentile table (times in seconds; rendered in
/// milliseconds). Shared by the `serve` CLI subcommand and the
/// `serve_scale` bench so per-tenant SLO results print identically
/// everywhere.
#[derive(Debug, Clone)]
pub struct LatencyRow {
    /// Row label (tenant or scenario name).
    pub label: String,
    /// Median latency, seconds.
    pub p50_s: f64,
    /// 95th-percentile latency, seconds.
    pub p95_s: f64,
    /// 99th-percentile latency, seconds.
    pub p99_s: f64,
    /// Maximum latency, seconds.
    pub max_s: f64,
    /// SLO goodput, requests/second.
    pub goodput_rps: f64,
    /// Fraction of offered requests rejected or dropped, in [0, 1].
    pub drop_rate: f64,
}

/// Canonical latency-percentile table: one [`LatencyRow`] per row.
pub fn latency_table(rows: impl IntoIterator<Item = LatencyRow>) -> Table {
    let mut t = Table::new([
        "tenant",
        "p50 (ms)",
        "p95 (ms)",
        "p99 (ms)",
        "max (ms)",
        "goodput (req/s)",
        "drop rate",
    ]);
    for r in rows {
        t.row([
            r.label,
            f(r.p50_s * 1e3, 3),
            f(r.p95_s * 1e3, 3),
            f(r.p99_s * 1e3, 3),
            f(r.max_s * 1e3, 3),
            f(r.goodput_rps, 2),
            pct(r.drop_rate),
        ]);
    }
    t
}

/// Format an f64 with `digits` significant decimals.
pub fn f(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

/// Format a ratio as `x.yz×`.
pub fn times(x: f64) -> String {
    format!("{x:.2}×")
}

/// Format a fraction as a percentage.
pub fn pct(x: f64) -> String {
    format!("{:.3}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_render() {
        let mut t = Table::new(["a", "bb"]);
        t.row(["1", "2"]).row(["333", "4"]);
        let md = t.to_markdown();
        assert!(md.contains("| a   | bb |"));
        assert!(md.lines().count() == 4);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(["x"]);
        t.row(["a,b"]).row(["q\"uote"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"q\"\"uote\""));
    }

    #[test]
    #[should_panic]
    fn row_width_enforced() {
        Table::new(["a", "b"]).row(["only one"]);
    }

    #[test]
    fn write_csv_to_tmp() {
        let mut t = Table::new(["n"]);
        t.row(["1"]);
        let path = std::env::temp_dir().join("shisha_table_test/out.csv");
        t.write_csv(&path).unwrap();
        assert!(std::fs::read_to_string(&path).unwrap().starts_with("n\n1"));
    }

    #[test]
    fn formatters() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(times(34.567), "34.57×");
        assert_eq!(pct(0.00123), "0.123%");
    }

    #[test]
    fn latency_table_renders_ms_and_pct() {
        let t = latency_table([LatencyRow {
            label: "tenant-0".into(),
            p50_s: 0.010,
            p95_s: 0.020,
            p99_s: 0.0405,
            max_s: 0.100,
            goodput_rps: 123.456,
            drop_rate: 0.05,
        }]);
        assert_eq!(t.len(), 1);
        let md = t.to_markdown();
        assert!(md.contains("p99 (ms)"), "{md}");
        assert!(md.contains("10.000"), "{md}");
        assert!(md.contains("40.500"), "{md}");
        assert!(md.contains("123.46"), "{md}");
        assert!(md.contains("5.000%"), "{md}");
    }
}
