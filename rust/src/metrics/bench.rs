//! Criterion-substitute micro-benchmark harness.
//!
//! The offline build environment does not ship criterion (DESIGN.md §5), so
//! the `cargo bench` targets use this small harness: warmup, fixed-duration
//! sampling, median + MAD reporting, and CSV output under `results/`.
//! [`JsonReport`] additionally emits the machine-readable `BENCH_*.json`
//! files at the repository root that track the perf trajectory across PRs
//! (CI runs the quick bench profiles and uploads them as artifacts).

use super::emit::{num as json_num, str_lit as json_str};
use super::{fmt_duration, Stats, Timer};
use std::hint::black_box;
use std::time::Instant;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Median time per iteration, seconds.
    pub median_s: f64,
    /// Median absolute deviation, seconds.
    pub mad_s: f64,
    /// Iterations measured.
    pub iters: u64,
}

impl BenchResult {
    /// Iterations per second at the median.
    pub fn throughput(&self) -> f64 {
        if self.median_s > 0.0 {
            1.0 / self.median_s
        } else {
            f64::INFINITY
        }
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    /// Warmup duration, seconds.
    pub warmup_s: f64,
    /// Measurement duration, seconds.
    pub measure_s: f64,
    /// Minimum sample batches.
    pub min_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup_s: 0.3, measure_s: 1.0, min_samples: 10 }
    }
}

impl Bencher {
    /// Quick profile for cheap CI runs.
    pub fn quick() -> Self {
        Self { warmup_s: 0.05, measure_s: 0.2, min_samples: 5 }
    }

    /// Run `f` repeatedly and report per-iteration statistics.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup + estimate batch size so each sample is >= ~1ms.
        let iters_per_batch = {
            let t0 = Instant::now();
            let mut n = 0u64;
            while t0.elapsed().as_secs_f64() < self.warmup_s {
                black_box(f());
                n += 1;
            }
            let per_iter = self.warmup_s / n.max(1) as f64;
            ((1e-3 / per_iter).ceil() as u64).max(1)
        };

        let mut stats = Stats::new();
        let mut total_iters = 0u64;
        let t_all = Timer::start();
        while t_all.elapsed_s() < self.measure_s || stats.len() < self.min_samples {
            let t0 = Instant::now();
            for _ in 0..iters_per_batch {
                black_box(f());
            }
            let dt = t0.elapsed().as_secs_f64() / iters_per_batch as f64;
            stats.push(dt);
            total_iters += iters_per_batch;
            if stats.len() > 100_000 {
                break; // pathological fast function; enough samples
            }
        }

        let res = BenchResult {
            name: name.to_string(),
            median_s: stats.median(),
            mad_s: stats.mad(),
            iters: total_iters,
        };
        println!(
            "bench {:<44} {:>12} / iter (± {}) [{} iters]",
            res.name,
            fmt_duration(res.median_s),
            fmt_duration(res.mad_s),
            res.iters
        );
        res
    }
}

/// Machine-readable benchmark report (`BENCH_*.json`).
///
/// One flat JSON object per file:
///
/// ```json
/// {
///   "schema": "shisha-bench-v1",
///   "note": "free text: units, baseline semantics",
///   "cases": { "case_name": { "metric": 1.23e4, ... }, ... }
/// }
/// ```
///
/// Metrics are plain `f64`s (ns/op, ops/s, events/s, …); non-finite
/// values serialise as `null`. No serde in the offline environment, so
/// the writer is hand-rolled — keep case and metric names free of
/// exotic characters and the output stays trivially parseable.
#[derive(Debug, Clone, Default)]
pub struct JsonReport {
    note: Option<String>,
    cases: Vec<(String, Vec<(String, f64)>)>,
}

impl JsonReport {
    /// Empty report.
    pub fn new() -> Self {
        Self::default()
    }

    /// Attach a free-text note (units, how to read the baselines).
    pub fn note(&mut self, text: impl Into<String>) -> &mut Self {
        self.note = Some(text.into());
        self
    }

    /// Record one metric under `case`, creating the case on first use.
    pub fn metric(&mut self, case: &str, key: &str, value: f64) -> &mut Self {
        if let Some((_, metrics)) = self.cases.iter_mut().find(|(c, _)| c == case) {
            metrics.push((key.to_string(), value));
        } else {
            self.cases.push((case.to_string(), vec![(key.to_string(), value)]));
        }
        self
    }

    /// Record a [`BenchResult`] under its own name: ns/op, MAD and ops/s.
    pub fn result(&mut self, r: &BenchResult) -> &mut Self {
        self.metric(&r.name, "ns_per_op", r.median_s * 1e9);
        self.metric(&r.name, "mad_ns", r.mad_s * 1e9);
        self.metric(&r.name, "ops_per_s", r.throughput())
    }

    /// Render the report as a JSON object string.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"schema\": \"shisha-bench-v1\"");
        if let Some(note) = &self.note {
            out.push_str(",\n  \"note\": ");
            out.push_str(&json_str(note));
        }
        out.push_str(",\n  \"cases\": {");
        for (i, (case, metrics)) in self.cases.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("\n    ");
            out.push_str(&json_str(case));
            out.push_str(": {");
            for (j, (key, value)) in metrics.iter().enumerate() {
                if j > 0 {
                    out.push_str(", ");
                }
                out.push_str(&json_str(key));
                out.push_str(": ");
                out.push_str(&json_num(*value));
            }
            out.push('}');
        }
        out.push_str("\n  }\n}\n");
        out
    }

    /// Write the JSON form to `path`, creating parent directories.
    pub fn write(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bencher { warmup_s: 0.01, measure_s: 0.05, min_samples: 3 };
        let r = b.run("noop-sum", || (0..100u64).sum::<u64>());
        assert!(r.median_s > 0.0);
        assert!(r.iters > 0);
        assert!(r.throughput() > 1000.0);
    }

    #[test]
    fn bench_ordering_sane() {
        let b = Bencher { warmup_s: 0.01, measure_s: 0.05, min_samples: 3 };
        let cheap = b.run("cheap", || (0..10u64).sum::<u64>());
        let costly = b.run("costly", || (0..100_000u64).sum::<u64>());
        assert!(costly.median_s > cheap.median_s);
    }

    #[test]
    fn json_report_renders_valid_shape() {
        let mut j = JsonReport::new();
        j.note("units: ns per \"op\"");
        j.metric("case_a", "ns_per_op", 123.0);
        j.metric("case_a", "ops_per_s", 1.5e6);
        j.metric("case_b", "events_per_s", f64::INFINITY);
        let s = j.to_json();
        assert!(s.contains("\"schema\": \"shisha-bench-v1\""), "{s}");
        assert!(s.contains("\"case_a\""), "{s}");
        assert!(s.contains("\"ns_per_op\": 1.23e2"), "{s}");
        assert!(s.contains("\\\"op\\\""), "quotes must be escaped: {s}");
        assert!(s.contains("null"), "non-finite must serialise as null: {s}");
        assert_eq!(s.matches('{').count(), s.matches('}').count(), "balanced braces: {s}");
    }

    #[test]
    fn json_report_records_bench_results() {
        let mut j = JsonReport::new();
        let r = BenchResult { name: "r".into(), median_s: 2e-6, mad_s: 1e-7, iters: 10 };
        j.result(&r);
        let s = j.to_json();
        assert!(s.contains("\"r\""), "{s}");
        for key in ["ns_per_op", "mad_ns", "ops_per_s"] {
            assert!(s.contains(&format!("\"{key}\"")), "missing {key}: {s}");
        }
        assert!(!s.contains("null"), "finite metrics must serialise as numbers: {s}");
    }

    #[test]
    fn json_report_writes_file() {
        let mut j = JsonReport::new();
        j.metric("c", "v", 1.0);
        let path = std::env::temp_dir().join("shisha_bench_json_test/BENCH_test.json");
        j.write(&path).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.starts_with('{') && s.trim_end().ends_with('}'));
    }

    #[test]
    fn committed_bench_json_copies_match_writer_shape() {
        // The BENCH_*.json copies at the repository root are refreshed
        // from CI bench artifacts; this guards their envelope against
        // rotting away from what JsonReport::to_json emits (CI
        // additionally diffs the per-case metric keys against a fresh
        // --quick run via scripts/check_bench_schema.py).
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("rust/ lives under the repo root");
        for name in [
            "BENCH_hotpath.json",
            "BENCH_serve.json",
            "BENCH_fig4.json",
            "BENCH_fig5.json",
            "BENCH_plan.json",
            "BENCH_replay.json",
            "BENCH_fault.json",
            "BENCH_elastic.json",
            "BENCH_obs.json",
        ] {
            let path = root.join(name);
            let s = std::fs::read_to_string(&path)
                .unwrap_or_else(|e| panic!("{name} must stay committed at the repo root: {e}"));
            assert!(
                s.contains("\"schema\": \"shisha-bench-v1\""),
                "{name}: schema tag missing"
            );
            assert!(s.contains("\"cases\""), "{name}: cases object missing");
            assert_eq!(
                s.matches('{').count(),
                s.matches('}').count(),
                "{name}: unbalanced braces"
            );
        }
    }
}
