//! Criterion-substitute micro-benchmark harness.
//!
//! The offline build environment does not ship criterion (DESIGN.md §5), so
//! the `cargo bench` targets use this small harness: warmup, fixed-duration
//! sampling, median + MAD reporting, and CSV output under `results/`.

use super::{fmt_duration, Stats, Timer};
use std::hint::black_box;
use std::time::Instant;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Median time per iteration, seconds.
    pub median_s: f64,
    /// Median absolute deviation, seconds.
    pub mad_s: f64,
    /// Iterations measured.
    pub iters: u64,
}

impl BenchResult {
    /// Iterations per second at the median.
    pub fn throughput(&self) -> f64 {
        if self.median_s > 0.0 {
            1.0 / self.median_s
        } else {
            f64::INFINITY
        }
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    /// Warmup duration, seconds.
    pub warmup_s: f64,
    /// Measurement duration, seconds.
    pub measure_s: f64,
    /// Minimum sample batches.
    pub min_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup_s: 0.3, measure_s: 1.0, min_samples: 10 }
    }
}

impl Bencher {
    /// Quick profile for cheap CI runs.
    pub fn quick() -> Self {
        Self { warmup_s: 0.05, measure_s: 0.2, min_samples: 5 }
    }

    /// Run `f` repeatedly and report per-iteration statistics.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup + estimate batch size so each sample is >= ~1ms.
        let iters_per_batch = {
            let t0 = Instant::now();
            let mut n = 0u64;
            while t0.elapsed().as_secs_f64() < self.warmup_s {
                black_box(f());
                n += 1;
            }
            let per_iter = self.warmup_s / n.max(1) as f64;
            ((1e-3 / per_iter).ceil() as u64).max(1)
        };

        let mut stats = Stats::new();
        let mut total_iters = 0u64;
        let t_all = Timer::start();
        while t_all.elapsed_s() < self.measure_s || stats.len() < self.min_samples {
            let t0 = Instant::now();
            for _ in 0..iters_per_batch {
                black_box(f());
            }
            let dt = t0.elapsed().as_secs_f64() / iters_per_batch as f64;
            stats.push(dt);
            total_iters += iters_per_batch;
            if stats.len() > 100_000 {
                break; // pathological fast function; enough samples
            }
        }

        let res = BenchResult {
            name: name.to_string(),
            median_s: stats.median(),
            mad_s: stats.mad(),
            iters: total_iters,
        };
        println!(
            "bench {:<44} {:>12} / iter (± {}) [{} iters]",
            res.name,
            fmt_duration(res.median_s),
            fmt_duration(res.mad_s),
            res.iters
        );
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bencher { warmup_s: 0.01, measure_s: 0.05, min_samples: 3 };
        let r = b.run("noop-sum", || (0..100u64).sum::<u64>());
        assert!(r.median_s > 0.0);
        assert!(r.iters > 0);
        assert!(r.throughput() > 1000.0);
    }

    #[test]
    fn bench_ordering_sane() {
        let b = Bencher { warmup_s: 0.01, measure_s: 0.05, min_samples: 3 };
        let cheap = b.run("cheap", || (0..10u64).sum::<u64>());
        let costly = b.run("costly", || (0..100_000u64).sum::<u64>());
        assert!(costly.median_s > cheap.median_s);
    }
}
