//! Metrics, statistics and reporting utilities.
//!
//! * [`Stats`] — streaming summary statistics (mean/min/max/stddev/percentiles);
//! * [`Timer`] — wall-clock scope timing;
//! * [`table`] — markdown/CSV table writers used by every bench harness;
//! * [`bench`] — a small criterion-substitute micro-benchmark harness
//!   (the offline environment has no criterion; see DESIGN.md §5);
//! * [`emit`] — hand-rolled JSON primitives shared by the bench writer
//!   and the telemetry exports, so their formatting cannot drift.

pub mod bench;
pub mod emit;
pub mod table;

use std::time::Instant;

/// Streaming summary statistics over f64 samples.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    samples: Vec<f64>,
}

impl Stats {
    /// Empty statistics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one sample.
    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Arithmetic mean (0 for empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Minimum (+inf for empty).
    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Maximum (−inf for empty).
    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Sample standard deviation (0 for < 2 samples).
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Percentile in [0, 100] by nearest-rank on the sorted samples.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    /// Median.
    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Median absolute deviation (robust spread).
    pub fn mad(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let med = self.median();
        let mut devs: Vec<f64> = self.samples.iter().map(|x| (x - med).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        devs[devs.len() / 2]
    }
}

/// Simple wall-clock timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Elapsed seconds.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Pretty-print a duration in adaptive units (ns/µs/ms/s).
pub fn fmt_duration(seconds: f64) -> String {
    let abs = seconds.abs();
    if abs >= 1.0 {
        format!("{seconds:.3} s")
    } else if abs >= 1e-3 {
        format!("{:.3} ms", seconds * 1e3)
    } else if abs >= 1e-6 {
        format!("{:.3} µs", seconds * 1e6)
    } else {
        format!("{:.1} ns", seconds * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.len(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.stddev() - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.median(), 3.0);
    }

    #[test]
    fn stats_empty_safe() {
        let s = Stats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.median(), 0.0);
        assert_eq!(s.stddev(), 0.0);
        assert!(s.is_empty());
    }

    #[test]
    fn percentiles_ordered() {
        let mut s = Stats::new();
        for i in 0..100 {
            s.push(i as f64);
        }
        assert!(s.percentile(10.0) < s.percentile(50.0));
        assert!(s.percentile(50.0) < s.percentile(90.0));
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 99.0);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let mut s = Stats::new();
        for x in [1.0, 1.1, 0.9, 1.0, 100.0] {
            s.push(x);
        }
        assert!(s.mad() < 0.2, "mad {} robust", s.mad());
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.5), "2.500 s");
        assert_eq!(fmt_duration(0.0025), "2.500 ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500 µs");
        assert_eq!(fmt_duration(2.5e-9), "2.5 ns");
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_s() >= 0.002);
    }
}
