//! Shared machine-readable emitters: hand-rolled JSON fragments.
//!
//! No serde in the offline environment, so both the bench trajectory
//! writer ([`super::bench::JsonReport`]) and the telemetry exports
//! ([`crate::serve::obs`]) build JSON by hand. The primitives live here
//! so the two surfaces cannot drift in escaping or number formatting —
//! the telemetry invariant (live `--metrics` JSONL equals `trace analyze`
//! output byte-for-byte) leans on [`num`] being a pure deterministic
//! function of the `f64` bits.

/// JSON string literal with the standard escapes.
pub fn str_lit(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON number: exponent form for finite values, `null` otherwise
/// (JSON has no NaN/Infinity; null keeps downstream parsers alive).
pub fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:e}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escapes_and_nulls() {
        assert_eq!(str_lit("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(str_lit("\u{1}"), "\"\\u0001\"");
        assert_eq!(num(1.5), "1.5e0");
        assert_eq!(num(f64::NAN), "null");
        assert_eq!(num(f64::INFINITY), "null");
        // Bit-determinism: same bits, same text.
        assert_eq!(num(0.1 + 0.2), num(0.30000000000000004));
    }
}
