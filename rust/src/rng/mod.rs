//! Deterministic PRNGs for exploration and testing.
//!
//! The offline build environment ships only `rand_core`, not `rand`, so this
//! module provides the two generators the rest of the crate needs:
//!
//! * [`SplitMix64`] — tiny, used to seed other generators.
//! * [`Xoshiro256`] — xoshiro256** 1.0 (Blackman/Vigna), the workhorse PRNG
//!   used by all stochastic explorers and the property-test framework.
//!
//! Both implement [`rand_core::RngCore`] so they interoperate with any
//! rand-style code, plus convenience helpers (`gen_range`, `gen_f64`,
//! `shuffle`, `choose`) that cover this crate's needs.

use rand_core::{impls, Error, RngCore, SeedableRng};

/// SplitMix64 — used to expand a 64-bit seed into xoshiro state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — public-domain algorithm by David Blackman and
/// Sebastiano Vigna (<https://prng.di.unimi.it/>).
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Deterministically seed from a single u64 via SplitMix64.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next();
        }
        // All-zero state is invalid; SplitMix64 cannot produce four zeros
        // from any seed, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Self { s }
    }

    #[inline]
    fn next_u64_impl(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64_impl() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [lo, hi) — panics if lo >= hi.
    #[inline]
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "gen_range: empty range [{lo}, {hi})");
        let span = (hi - lo) as u64;
        // Lemire's unbiased multiply-shift rejection method.
        loop {
            let x = self.next_u64_impl();
            let m = (x as u128).wrapping_mul(span as u128);
            let l = m as u64;
            if l >= span.wrapping_neg() % span {
                return lo + (m >> 64) as usize;
            }
        }
    }

    /// Uniform i64 in [lo, hi).
    #[inline]
    pub fn gen_range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.gen_range(0, (hi - lo) as usize) as i64
    }

    /// Bernoulli(p).
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Uniformly pick an element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(0, xs.len())]
    }

    /// Split off an independent generator (jump-free: reseed from output).
    pub fn fork(&mut self) -> Xoshiro256 {
        Xoshiro256::seed_from(self.next_u64_impl())
    }
}

impl RngCore for Xoshiro256 {
    fn next_u32(&mut self) -> u32 {
        (self.next_u64_impl() >> 32) as u32
    }
    fn next_u64(&mut self) -> u64 {
        self.next_u64_impl()
    }
    fn fill_bytes(&mut self, dest: &mut [u8]) {
        impls::fill_bytes_via_next(self, dest)
    }
    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), Error> {
        self.fill_bytes(dest);
        Ok(())
    }
}

impl SeedableRng for Xoshiro256 {
    type Seed = [u8; 32];
    fn from_seed(seed: Self::Seed) -> Self {
        let mut s = [0u64; 4];
        for (i, chunk) in seed.chunks_exact(8).enumerate() {
            s[i] = u64::from_le_bytes(chunk.try_into().unwrap());
        }
        if s == [0, 0, 0, 0] {
            return Xoshiro256::seed_from(0);
        }
        Self { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values for seed 0 (from the SplitMix64 reference impl).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn xoshiro_deterministic() {
        let mut a = Xoshiro256::seed_from(42);
        let mut b = Xoshiro256::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xoshiro_different_seeds_differ() {
        let mut a = Xoshiro256::seed_from(1);
        let mut b = Xoshiro256::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from(7);
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Xoshiro256::seed_from(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = r.gen_range(3, 13);
            assert!((3..13).contains(&x));
            seen[x - 3] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values in range hit");
    }

    #[test]
    fn gen_range_unbiased_roughly() {
        let mut r = Xoshiro256::seed_from(11);
        let n = 60_000;
        let mut counts = [0usize; 6];
        for _ in 0..n {
            counts[r.gen_range(0, 6)] += 1;
        }
        for &c in &counts {
            // each bucket ~10000; allow 5% deviation
            assert!((9_500..10_500).contains(&c), "count {c} out of tolerance");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "shuffle changed order");
    }

    #[test]
    fn fork_independent() {
        let mut a = Xoshiro256::seed_from(123);
        let mut f = a.fork();
        // forked stream differs from parent's continued stream
        let same = (0..64).filter(|_| a.next_u64() == f.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_bool_probability() {
        let mut r = Xoshiro256::seed_from(77);
        let hits = (0..100_000).filter(|_| r.gen_bool(0.25)).count();
        assert!((24_000..26_000).contains(&hits));
    }
}
