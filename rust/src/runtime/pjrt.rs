//! The real PJRT execution path (enabled by the `pjrt` cargo feature).
//!
//! See the module docs of [`crate::runtime`] for the load/compile/execute
//! pipeline and the per-thread ownership model.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactKind, ArtifactMeta, Manifest};

/// A compiled artifact: metadata + loaded PJRT executable.
pub struct Compiled {
    /// Artifact metadata from the manifest.
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// A per-thread PJRT runtime holding compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    compiled: HashMap<String, Compiled>,
}

impl Runtime {
    /// Create a CPU PJRT client with no artifacts loaded.
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, compiled: HashMap::new() })
    }

    /// Platform the client runs on (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile one artifact from the manifest.
    pub fn load(&mut self, manifest: &Manifest, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let meta = manifest
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))?
            .clone();
        let path = manifest.path_of(&meta);
        let path_str = path.to_str().context("non-utf8 artifact path")?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        self.compiled.insert(name.to_string(), Compiled { meta, exe });
        Ok(())
    }

    /// Load every artifact in the manifest.
    pub fn load_all(&mut self, manifest: &Manifest) -> Result<()> {
        for a in &manifest.artifacts {
            self.load(manifest, &a.name)?;
        }
        Ok(())
    }

    /// Names of loaded artifacts.
    pub fn loaded(&self) -> Vec<&str> {
        self.compiled.keys().map(String::as_str).collect()
    }

    /// Metadata of a loaded artifact.
    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.compiled.get(name).map(|c| &c.meta)
    }

    /// Execute a loaded artifact on raw f32 buffers with explicit shapes.
    /// Returns the flattened f32 output (artifacts are lowered with
    /// `return_tuple=True`, so the single result is unwrapped from a
    /// 1-tuple).
    pub fn execute_raw(&self, name: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        let c = self
            .compiled
            .get(name)
            .with_context(|| format!("artifact {name:?} not loaded"))?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data)
                .reshape(dims)
                .with_context(|| format!("reshaping input to {dims:?}"))?;
            literals.push(lit);
        }
        let result = c.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple result")?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute a *layer* artifact: `y = conv(x, w, b)`.
    pub fn execute_layer(&self, name: &str, x: &[f32], w: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let meta = self
            .meta(name)
            .with_context(|| format!("artifact {name:?} not loaded"))?
            .clone();
        if meta.kind != ArtifactKind::Layer {
            bail!("{name} is not a layer artifact");
        }
        let w_shape = meta.w_shape.clone().context("layer missing weight shape")?;
        let bias = meta.bias.context("layer missing bias length")?;
        if x.len() != meta.in_elems() {
            bail!("{name}: input has {} elems, expected {}", x.len(), meta.in_elems());
        }
        let out = self.execute_raw(name, &[(x, &meta.in_shape), (w, &w_shape), (b, &[bias])])?;
        debug_assert_eq!(out.len(), meta.out_elems());
        Ok(out)
    }

    /// Execute the fused whole-network *stage* artifact:
    /// `y = net(x, w0, b0, w1, b1, ...)`.
    pub fn execute_stage(
        &self,
        name: &str,
        x: &[f32],
        params: &[(Vec<f32>, Vec<i64>)],
    ) -> Result<Vec<f32>> {
        let meta = self
            .meta(name)
            .with_context(|| format!("artifact {name:?} not loaded"))?
            .clone();
        if meta.kind != ArtifactKind::Stage {
            bail!("{name} is not a stage artifact");
        }
        let mut inputs: Vec<(&[f32], &[i64])> = Vec::with_capacity(1 + params.len());
        inputs.push((x, &meta.in_shape));
        for (data, dims) in params {
            inputs.push((data.as_slice(), dims.as_slice()));
        }
        self.execute_raw(name, &inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_constructs() {
        let rt = Runtime::new().unwrap();
        assert!(rt.platform().to_lowercase().contains("pu"), "platform {}", rt.platform());
        assert!(rt.loaded().is_empty());
    }

    #[test]
    fn execute_unloaded_fails() {
        let rt = Runtime::new().unwrap();
        assert!(rt.execute_raw("nope", &[]).is_err());
    }
}
