//! Compile-time stub for the PJRT runtime (the `pjrt` feature is **off**).
//!
//! Mirrors the public surface of [`super::pjrt`] so that the coordinator,
//! CLI and examples compile without the `xla` crate. Every entry point that
//! would need a real PJRT client fails with [`PJRT_DISABLED`]; pure
//! metadata queries behave normally on the (necessarily empty) artifact
//! set.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::manifest::{ArtifactMeta, Manifest};

/// Error message returned by every stubbed execution entry point.
pub const PJRT_DISABLED: &str =
    "PJRT support not compiled in: rebuild with `cargo build --features pjrt` \
     (requires the xla crate and a local XLA/PJRT C library)";

/// Stub of the compiled-artifact handle. Never constructed without PJRT.
pub struct Compiled {
    /// Artifact metadata from the manifest.
    pub meta: ArtifactMeta,
}

/// Stub runtime: same API as the PJRT-backed one, but `new()` fails.
pub struct Runtime {
    compiled: HashMap<String, Compiled>,
}

impl Runtime {
    /// Always fails: there is no PJRT client in this build.
    pub fn new() -> Result<Self> {
        bail!(PJRT_DISABLED);
    }

    /// Platform name placeholder.
    pub fn platform(&self) -> String {
        "none (pjrt feature disabled)".to_string()
    }

    /// Always fails: artifacts cannot be compiled without PJRT.
    pub fn load(&mut self, _manifest: &Manifest, _name: &str) -> Result<()> {
        bail!(PJRT_DISABLED);
    }

    /// Always fails: artifacts cannot be compiled without PJRT.
    pub fn load_all(&mut self, _manifest: &Manifest) -> Result<()> {
        bail!(PJRT_DISABLED);
    }

    /// Names of loaded artifacts (always empty in the stub).
    pub fn loaded(&self) -> Vec<&str> {
        self.compiled.keys().map(String::as_str).collect()
    }

    /// Metadata of a loaded artifact (always `None` in the stub).
    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.compiled.get(name).map(|c| &c.meta)
    }

    /// Always fails in the stub.
    pub fn execute_raw(&self, _name: &str, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        bail!(PJRT_DISABLED);
    }

    /// Always fails in the stub.
    pub fn execute_layer(
        &self,
        _name: &str,
        _x: &[f32],
        _w: &[f32],
        _b: &[f32],
    ) -> Result<Vec<f32>> {
        bail!(PJRT_DISABLED);
    }

    /// Always fails in the stub.
    pub fn execute_stage(
        &self,
        _name: &str,
        _x: &[f32],
        _params: &[(Vec<f32>, Vec<i64>)],
    ) -> Result<Vec<f32>> {
        bail!(PJRT_DISABLED);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_constructor_reports_missing_feature() {
        let err = Runtime::new().err().expect("stub must fail");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
