//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! The artifact [`Manifest`] and parameter synthesis are always available;
//! the execution path itself wraps the `xla` crate (docs.rs/xla 0.1.6) and
//! is only compiled when the **`pjrt` cargo feature** is enabled, because
//! the crate needs a local XLA/PJRT C library that offline build
//! environments usually lack. Without the feature, [`Runtime`] is a stub
//! with the same API whose constructor returns a descriptive error, so all
//! higher layers (`coordinator::PipelineRuntime`, the `run` subcommand)
//! compile unchanged and fail gracefully at run time.
//!
//! With `--features pjrt` the real implementation follows the pattern of
//! /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Each artifact is compiled once and cached.
//!
//! Thread model: the `xla` crate's `PjRtClient` is `Rc`-based (not `Send`),
//! so a [`Runtime`] is **per-thread** — the coordinator gives every stage
//! worker its own `Runtime` holding only the executables of its layers
//! (cheap: the synthnet_small modules compile in milliseconds). This
//! mirrors the paper's platform model, where every EP owns its memory and
//! code.

pub mod manifest;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(not(feature = "pjrt"))]
mod stub;

pub use manifest::{ArtifactKind, ArtifactMeta, Manifest};

#[cfg(feature = "pjrt")]
pub use pjrt::{Compiled, Runtime};
#[cfg(not(feature = "pjrt"))]
pub use stub::{Compiled, Runtime};

use anyhow::{Context, Result};

/// Deterministic pseudo-random parameters for a layer artifact, matching
/// shapes (NOT values) of python's `init_params` — used by examples and
/// tests that only need *valid* parameters, not trained ones.
pub fn synth_params(meta: &ArtifactMeta, seed: u64) -> Result<(Vec<f32>, Vec<f32>)> {
    let w_shape = meta.w_shape.clone().context("not a layer artifact")?;
    let bias = meta.bias.context("not a layer artifact")? as usize;
    let n_w: usize = w_shape.iter().product::<i64>() as usize;
    let mut rng = crate::rng::Xoshiro256::seed_from(seed);
    let fan_in: i64 = w_shape[..3].iter().product();
    let scale = (2.0 / fan_in as f64).sqrt();
    let w: Vec<f32> = (0..n_w).map(|_| ((rng.gen_f64() * 2.0 - 1.0) * scale) as f32).collect();
    let b: Vec<f32> = vec![0.0; bias];
    Ok((w, b))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need real artifacts live in
    // rust/tests/runtime_roundtrip.rs (integration, after `make artifacts`,
    // with the `pjrt` feature enabled).

    #[test]
    fn synth_params_shapes_and_determinism() {
        let meta = ArtifactMeta {
            name: "conv_x".into(),
            file: "f".into(),
            kind: ArtifactKind::Layer,
            index: 0,
            in_shape: vec![8, 8, 3],
            out_shape: vec![8, 8, 4],
            w_shape: Some(vec![3, 3, 3, 4]),
            bias: Some(4),
            stride: Some(1),
            pad: Some(1),
            params: None,
        };
        let (w, b) = synth_params(&meta, 1).unwrap();
        assert_eq!(w.len(), 3 * 3 * 3 * 4);
        assert_eq!(b.len(), 4);
        let (w2, _) = synth_params(&meta, 1).unwrap();
        assert_eq!(w, w2);
    }
}
