//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! Wraps the `xla` crate (docs.rs/xla 0.1.6) following the pattern of
//! /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Each artifact is compiled once and cached.
//!
//! Thread model: the crate's `PjRtClient` is `Rc`-based (not `Send`), so a
//! [`Runtime`] is **per-thread** — the coordinator gives every stage worker
//! its own `Runtime` holding only the executables of its layers (cheap: the
//! synthnet_small modules compile in milliseconds). This mirrors the
//! paper's platform model, where every EP owns its memory and code.

pub mod manifest;

pub use manifest::{ArtifactKind, ArtifactMeta, Manifest};

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// A compiled artifact: metadata + loaded PJRT executable.
pub struct Compiled {
    /// Artifact metadata from the manifest.
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// A per-thread PJRT runtime holding compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    compiled: HashMap<String, Compiled>,
}

impl Runtime {
    /// Create a CPU PJRT client with no artifacts loaded.
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, compiled: HashMap::new() })
    }

    /// Platform the client runs on (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile one artifact from the manifest.
    pub fn load(&mut self, manifest: &Manifest, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let meta = manifest
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))?
            .clone();
        let path = manifest.path_of(&meta);
        let path_str = path.to_str().context("non-utf8 artifact path")?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        self.compiled.insert(name.to_string(), Compiled { meta, exe });
        Ok(())
    }

    /// Load every artifact in the manifest.
    pub fn load_all(&mut self, manifest: &Manifest) -> Result<()> {
        for a in &manifest.artifacts {
            self.load(manifest, &a.name)?;
        }
        Ok(())
    }

    /// Names of loaded artifacts.
    pub fn loaded(&self) -> Vec<&str> {
        self.compiled.keys().map(String::as_str).collect()
    }

    /// Metadata of a loaded artifact.
    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.compiled.get(name).map(|c| &c.meta)
    }

    /// Execute a loaded artifact on raw f32 buffers with explicit shapes.
    /// Returns the flattened f32 output (artifacts are lowered with
    /// `return_tuple=True`, so the single result is unwrapped from a
    /// 1-tuple).
    pub fn execute_raw(&self, name: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        let c = self
            .compiled
            .get(name)
            .with_context(|| format!("artifact {name:?} not loaded"))?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data)
                .reshape(dims)
                .with_context(|| format!("reshaping input to {dims:?}"))?;
            literals.push(lit);
        }
        let result = c.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple result")?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute a *layer* artifact: `y = conv(x, w, b)`.
    pub fn execute_layer(&self, name: &str, x: &[f32], w: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let meta = self
            .meta(name)
            .with_context(|| format!("artifact {name:?} not loaded"))?
            .clone();
        if meta.kind != ArtifactKind::Layer {
            bail!("{name} is not a layer artifact");
        }
        let w_shape = meta.w_shape.clone().context("layer missing weight shape")?;
        let bias = meta.bias.context("layer missing bias length")?;
        if x.len() != meta.in_elems() {
            bail!("{name}: input has {} elems, expected {}", x.len(), meta.in_elems());
        }
        let out = self.execute_raw(name, &[(x, &meta.in_shape), (w, &w_shape), (b, &[bias])])?;
        debug_assert_eq!(out.len(), meta.out_elems());
        Ok(out)
    }

    /// Execute the fused whole-network *stage* artifact:
    /// `y = net(x, w0, b0, w1, b1, ...)`.
    pub fn execute_stage(
        &self,
        name: &str,
        x: &[f32],
        params: &[(Vec<f32>, Vec<i64>)],
    ) -> Result<Vec<f32>> {
        let meta = self
            .meta(name)
            .with_context(|| format!("artifact {name:?} not loaded"))?
            .clone();
        if meta.kind != ArtifactKind::Stage {
            bail!("{name} is not a stage artifact");
        }
        let mut inputs: Vec<(&[f32], &[i64])> = Vec::with_capacity(1 + params.len());
        inputs.push((x, &meta.in_shape));
        for (data, dims) in params {
            inputs.push((data.as_slice(), dims.as_slice()));
        }
        self.execute_raw(name, &inputs)
    }
}

/// Deterministic pseudo-random parameters for a layer artifact, matching
/// shapes (NOT values) of python's `init_params` — used by examples and
/// tests that only need *valid* parameters, not trained ones.
pub fn synth_params(meta: &ArtifactMeta, seed: u64) -> Result<(Vec<f32>, Vec<f32>)> {
    let w_shape = meta.w_shape.clone().context("not a layer artifact")?;
    let bias = meta.bias.context("not a layer artifact")? as usize;
    let n_w: usize = w_shape.iter().product::<i64>() as usize;
    let mut rng = crate::rng::Xoshiro256::seed_from(seed);
    let fan_in: i64 = w_shape[..3].iter().product();
    let scale = (2.0 / fan_in as f64).sqrt();
    let w: Vec<f32> = (0..n_w).map(|_| ((rng.gen_f64() * 2.0 - 1.0) * scale) as f32).collect();
    let b: Vec<f32> = vec![0.0; bias];
    Ok((w, b))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need real artifacts live in
    // rust/tests/runtime_roundtrip.rs (integration, after `make artifacts`).

    #[test]
    fn runtime_constructs() {
        let rt = Runtime::new().unwrap();
        assert!(rt.platform().to_lowercase().contains("pu"), "platform {}", rt.platform());
        assert!(rt.loaded().is_empty());
    }

    #[test]
    fn execute_unloaded_fails() {
        let rt = Runtime::new().unwrap();
        assert!(rt.execute_raw("nope", &[]).is_err());
    }

    #[test]
    fn synth_params_shapes_and_determinism() {
        let meta = ArtifactMeta {
            name: "conv_x".into(),
            file: "f".into(),
            kind: ArtifactKind::Layer,
            index: 0,
            in_shape: vec![8, 8, 3],
            out_shape: vec![8, 8, 4],
            w_shape: Some(vec![3, 3, 3, 4]),
            bias: Some(4),
            stride: Some(1),
            pad: Some(1),
            params: None,
        };
        let (w, b) = synth_params(&meta, 1).unwrap();
        assert_eq!(w.len(), 3 * 3 * 3 * 4);
        assert_eq!(b.len(), 4);
        let (w2, _) = synth_params(&meta, 1).unwrap();
        assert_eq!(w, w2);
    }
}
