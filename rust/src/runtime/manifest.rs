//! Artifact manifest parser.
//!
//! `python -m compile.aot` writes `artifacts/manifest.txt` describing every
//! AOT-lowered HLO module. The grammar is line-oriented:
//!
//! ```text
//! # comment
//! version=1
//! network=synthnet_small
//! layers=6
//! layer_hash=abc123...
//! artifact name=conv_s0 file=conv_s0.hlo.txt kind=layer index=0 \
//!          in=32x32x3 out=32x32x16 w=3x3x3x16 bias=16 stride=1 pad=1
//! ```
//!
//! The rust model table (`model::synthnet_small`) is cross-checked against
//! the manifest shapes at load time so drift between the python and rust
//! layer tables is caught immediately.

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Kind of an AOT artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// One conv layer.
    Layer,
    /// A fused multi-layer stage.
    Stage,
    /// A bare GEMM probe (calibration).
    Gemm,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "layer" => ArtifactKind::Layer,
            "stage" => ArtifactKind::Stage,
            "gemm" => ArtifactKind::Gemm,
            other => bail!("unknown artifact kind {other:?}"),
        })
    }
}

/// Metadata of one artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Logical name, e.g. `conv_s0`.
    pub name: String,
    /// File name within the artifact directory.
    pub file: String,
    /// Artifact kind.
    pub kind: ArtifactKind,
    /// Layer index within the network (layers) or 0.
    pub index: usize,
    /// Input activation dims.
    pub in_shape: Vec<i64>,
    /// Output activation dims.
    pub out_shape: Vec<i64>,
    /// Weight dims (layers only).
    pub w_shape: Option<Vec<i64>>,
    /// Bias length (layers only).
    pub bias: Option<i64>,
    /// Stride (layers only).
    pub stride: Option<u32>,
    /// Padding (layers only).
    pub pad: Option<u32>,
    /// Parameter count for stages (2 per layer).
    pub params: Option<usize>,
}

impl ArtifactMeta {
    /// Number of f32 elements in the input activation.
    pub fn in_elems(&self) -> usize {
        self.in_shape.iter().product::<i64>() as usize
    }

    /// Number of f32 elements in the output activation.
    pub fn out_elems(&self) -> usize {
        self.out_shape.iter().product::<i64>() as usize
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Manifest format version.
    pub version: u32,
    /// Network name the layer artifacts belong to.
    pub network: String,
    /// Number of layers.
    pub layers: usize,
    /// Layer-geometry hash (drift detection).
    pub layer_hash: String,
    /// All artifacts in file order.
    pub artifacts: Vec<ArtifactMeta>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

fn parse_dims(s: &str) -> Result<Vec<i64>> {
    s.split('x')
        .map(|d| d.parse::<i64>().with_context(|| format!("bad dim {d:?} in {s:?}")))
        .collect()
}

impl Manifest {
    /// Parse manifest text (directory recorded for artifact paths).
    pub fn parse(text: &str, dir: impl Into<PathBuf>) -> Result<Manifest> {
        let mut version = 0u32;
        let mut network = String::new();
        let mut layers = 0usize;
        let mut layer_hash = String::new();
        let mut artifacts = Vec::new();

        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(rest) = line.strip_prefix("artifact ") {
                let mut kv: HashMap<&str, &str> = HashMap::new();
                for field in rest.split_whitespace() {
                    let (k, v) = field
                        .split_once('=')
                        .with_context(|| format!("line {}: bad field {field:?}", lineno + 1))?;
                    kv.insert(k, v);
                }
                let get = |k: &str| -> Result<&str> {
                    kv.get(k)
                        .copied()
                        .with_context(|| format!("line {}: missing key {k}", lineno + 1))
                };
                artifacts.push(ArtifactMeta {
                    name: get("name")?.to_string(),
                    file: get("file")?.to_string(),
                    kind: ArtifactKind::parse(get("kind")?)?,
                    index: get("index")?.parse()?,
                    in_shape: parse_dims(get("in")?)?,
                    out_shape: parse_dims(get("out")?)?,
                    w_shape: kv.get("w").map(|s| parse_dims(s)).transpose()?,
                    bias: kv.get("bias").map(|s| s.parse()).transpose()?,
                    stride: kv.get("stride").map(|s| s.parse()).transpose()?,
                    pad: kv.get("pad").map(|s| s.parse()).transpose()?,
                    params: kv.get("params").map(|s| s.parse()).transpose()?,
                });
            } else if let Some((k, v)) = line.split_once('=') {
                match k {
                    "version" => version = v.parse()?,
                    "network" => network = v.to_string(),
                    "layers" => layers = v.parse()?,
                    "layer_hash" => layer_hash = v.to_string(),
                    _ => {} // forward-compatible: ignore unknown keys
                }
            } else {
                bail!("line {}: unparseable {line:?}", lineno + 1);
            }
        }
        if version == 0 {
            bail!("manifest missing version");
        }
        Ok(Manifest { version, network, layers, layer_hash, artifacts, dir: dir.into() })
    }

    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.txt");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    /// Artifact by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Layer artifacts ordered by index.
    pub fn layer_artifacts(&self) -> Vec<&ArtifactMeta> {
        let mut v: Vec<&ArtifactMeta> =
            self.artifacts.iter().filter(|a| a.kind == ArtifactKind::Layer).collect();
        v.sort_by_key(|a| a.index);
        v
    }

    /// Absolute path of an artifact file.
    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// Cross-check against a rust-side network table: layer count and all
    /// activation/weight shapes must match.
    pub fn check_against(&self, net: &crate::model::Network) -> Result<()> {
        let las = self.layer_artifacts();
        if las.len() != net.len() {
            bail!("manifest has {} layers, rust table {}", las.len(), net.len());
        }
        for (meta, layer) in las.iter().zip(&net.layers) {
            let want_in = vec![layer.h as i64, layer.w as i64, layer.c as i64];
            let want_out = vec![layer.out_h() as i64, layer.out_w() as i64, layer.k as i64];
            if meta.in_shape != want_in {
                bail!("{}: in {:?} != rust {:?}", meta.name, meta.in_shape, want_in);
            }
            if meta.out_shape != want_out {
                bail!("{}: out {:?} != rust {:?}", meta.name, meta.out_shape, want_out);
            }
            if let Some(w) = &meta.w_shape {
                let want_w =
                    vec![layer.r as i64, layer.s as i64, layer.c as i64, layer.k as i64];
                if *w != want_w {
                    bail!("{}: w {:?} != rust {:?}", meta.name, w, want_w);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
version=1
network=synthnet_small
layers=2
layer_hash=cafebabe
artifact name=conv_a file=conv_a.hlo.txt kind=layer index=0 in=8x8x3 out=8x8x4 w=3x3x3x4 bias=4 stride=1 pad=1
artifact name=net file=net.hlo.txt kind=stage index=0 in=8x8x3 out=8x8x4 params=4
artifact name=gemm_probe file=g.hlo.txt kind=gemm index=0 in=8x8 out=8x8 k=8
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, "/tmp").unwrap();
        assert_eq!(m.version, 1);
        assert_eq!(m.network, "synthnet_small");
        assert_eq!(m.layers, 2);
        assert_eq!(m.artifacts.len(), 3);
        let a = m.get("conv_a").unwrap();
        assert_eq!(a.in_shape, vec![8, 8, 3]);
        assert_eq!(a.w_shape.as_deref(), Some(&[3, 3, 3, 4][..]));
        assert_eq!(a.bias, Some(4));
        assert_eq!(a.kind, ArtifactKind::Layer);
        assert_eq!(a.in_elems(), 192);
        assert_eq!(a.out_elems(), 256);
    }

    #[test]
    fn stage_and_gemm_kinds() {
        let m = Manifest::parse(SAMPLE, "/tmp").unwrap();
        assert_eq!(m.get("net").unwrap().kind, ArtifactKind::Stage);
        assert_eq!(m.get("net").unwrap().params, Some(4));
        assert_eq!(m.get("gemm_probe").unwrap().kind, ArtifactKind::Gemm);
    }

    #[test]
    fn layer_artifacts_ordered() {
        let txt = "version=1\n\
artifact name=b file=b kind=layer index=1 in=2 out=2\n\
artifact name=a file=a kind=layer index=0 in=2 out=2\n";
        let m = Manifest::parse(txt, "/tmp").unwrap();
        let names: Vec<&str> = m.layer_artifacts().iter().map(|a| a.name.as_str()).collect();
        assert_eq!(names, vec!["a", "b"]);
    }

    #[test]
    fn rejects_missing_version() {
        assert!(Manifest::parse("network=x\n", "/tmp").is_err());
    }

    #[test]
    fn rejects_bad_kind_and_dims() {
        assert!(Manifest::parse(
            "version=1\nartifact name=x file=f kind=zzz index=0 in=2 out=2\n",
            "/tmp"
        )
        .is_err());
        assert!(Manifest::parse(
            "version=1\nartifact name=x file=f kind=layer index=0 in=2xq out=2\n",
            "/tmp"
        )
        .is_err());
    }

    #[test]
    fn unknown_toplevel_keys_ignored() {
        let m = Manifest::parse("version=1\nfuture_key=hello\n", "/tmp").unwrap();
        assert_eq!(m.version, 1);
    }

    #[test]
    fn check_against_synthnet_small() {
        // build a manifest text from the rust table and verify round-trip
        let net = crate::model::networks::synthnet_small();
        let mut txt = String::from("version=1\nnetwork=synthnet_small\nlayers=6\n");
        for (i, l) in net.layers.iter().enumerate() {
            txt.push_str(&format!(
                "artifact name=conv_{} file=f{} kind=layer index={} in={}x{}x{} out={}x{}x{} w={}x{}x{}x{} bias={} stride={} pad={}\n",
                l.name, i, i, l.h, l.w, l.c, l.out_h(), l.out_w(), l.k, l.r, l.s, l.c, l.k, l.k, l.stride, l.pad
            ));
        }
        let m = Manifest::parse(&txt, "/tmp").unwrap();
        m.check_against(&net).unwrap();
    }

    #[test]
    fn check_against_detects_drift() {
        let net = crate::model::networks::synthnet_small();
        let txt = "version=1\nlayers=1\n\
artifact name=conv_x file=f kind=layer index=0 in=9x9x9 out=9x9x9\n";
        let m = Manifest::parse(txt, "/tmp").unwrap();
        assert!(m.check_against(&net).is_err());
    }
}
