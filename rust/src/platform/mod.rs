//! The heterogeneous chiplet platform model.
//!
//! The paper targets MCM/chiplet systems built from clusters of cores, each
//! attached to its own memory (Figure 3): *Fast Execution Places* (FEPs —
//! high-performance cores on high-bandwidth memory) and *Slow Execution
//! Places* (SEPs — slower cores on low-bandwidth memory). This module
//! provides:
//!
//! * [`CoreType`] / [`ExecutionPlace`] — the EP abstraction (a set of cores
//!   attached to one memory module, Table 1);
//! * [`InterChipletLink`] — the chip-to-chip interconnect (latency +
//!   bandwidth), swept in the paper's Figure 9;
//! * [`Platform`] — a named collection of EPs with ranking helpers
//!   (`H_e`, the performance-sorted EP list Algorithm 1 consumes);
//! * [`configs`] — the gem5 system configurations of Table 1 and the EP
//!   mixes C1–C5 of Table 3.

pub mod configs;
pub mod topology;

pub use topology::MeshTopology;

/// Identifier of an execution place within a [`Platform`].
pub type EpId = usize;

/// Core microarchitecture class (ARM big.LITTLE in the paper's gem5 setup).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CoreType {
    /// ARM "Big" (out-of-order, high frequency).
    Big,
    /// ARM "Little" (in-order, low power).
    Little,
}

impl CoreType {
    /// Peak single-core throughput in GFLOP/s used by the analytic cost
    /// model. The absolute values are calibration constants; what matters
    /// for reproducing the paper is the Big:Little ratio (~4×, consistent
    /// with Cortex-A15 vs A7 on NEON FP32).
    pub fn peak_gflops(self) -> f64 {
        match self {
            CoreType::Big => 16.0,
            CoreType::Little => 4.0,
        }
    }

    /// Short display name.
    pub fn name(self) -> &'static str {
        match self {
            CoreType::Big => "big",
            CoreType::Little => "little",
        }
    }
}

/// Memory class attached to an EP (Figure 3's "memory type X / Y").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemoryClass {
    /// High-bandwidth memory (40 GB/s in Table 1).
    Fast,
    /// Low-bandwidth memory (20 GB/s in Table 1).
    Slow,
}

impl MemoryClass {
    /// Peak bandwidth in GB/s per Table 1.
    pub fn bandwidth_gbs(self) -> f64 {
        match self {
            MemoryClass::Fast => 40.0,
            MemoryClass::Slow => 20.0,
        }
    }

    /// Short display name.
    pub fn name(self) -> &'static str {
        match self {
            MemoryClass::Fast => "fast",
            MemoryClass::Slow => "slow",
        }
    }
}

/// An Execution Place: a set of cores attached to one memory module,
/// residing on one chiplet. The unit Shisha maps pipeline stages onto.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutionPlace {
    /// Index within the owning platform.
    pub id: EpId,
    /// Core microarchitecture.
    pub core_type: CoreType,
    /// Number of cores in this EP.
    pub n_cores: u32,
    /// Attached memory class.
    pub memory: MemoryClass,
    /// Chiplet this EP lives on (transfers between different chiplets pay
    /// the inter-chiplet link cost).
    pub chiplet: u32,
}

impl ExecutionPlace {
    /// Construct an EP. Table-1 pairing: Big cores sit on fast memory,
    /// Little cores on slow memory, but mixed EPs are allowed.
    pub fn new(id: EpId, core_type: CoreType, n_cores: u32, memory: MemoryClass, chiplet: u32) -> Self {
        Self { id, core_type, n_cores, memory, chiplet }
    }

    /// Aggregate peak compute in GFLOP/s (before parallel-efficiency loss).
    pub fn peak_gflops(&self) -> f64 {
        self.core_type.peak_gflops() * self.n_cores as f64
    }

    /// Peak memory bandwidth in GB/s.
    pub fn bandwidth_gbs(&self) -> f64 {
        self.memory.bandwidth_gbs()
    }

    /// Scalar performance score used to rank EPs into the `H_e` list of
    /// Algorithm 1: geometric mean of compute and bandwidth, so an EP that
    /// is fast on both axes outranks one fast on only one.
    pub fn perf_score(&self) -> f64 {
        (self.peak_gflops() * self.bandwidth_gbs()).sqrt()
    }

    /// FEP = attached to fast memory (the paper's green EPs).
    pub fn is_fep(&self) -> bool {
        self.memory == MemoryClass::Fast
    }

    /// Short description, e.g. `EP2[big x4 @ fast]`.
    pub fn describe(&self) -> String {
        format!(
            "EP{}[{} x{} @ {}]",
            self.id,
            self.core_type.name(),
            self.n_cores,
            self.memory.name()
        )
    }
}

/// Chip-to-chip interconnect parameters. The paper's Figure 9 sweeps the
/// per-transfer latency from 1 ns to 1 s and finds throughput unaffected
/// below ~1 ms.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InterChipletLink {
    /// Per-hop latency in seconds.
    pub latency_s: f64,
    /// Link bandwidth in GB/s.
    pub bandwidth_gbs: f64,
}

impl Default for InterChipletLink {
    fn default() -> Self {
        // Interposer-class link: ~100 ns, 32 GB/s (Simba-like inter-chiplet
        // bandwidth is substantially below intra-chiplet bandwidth).
        Self { latency_s: 100e-9, bandwidth_gbs: 32.0 }
    }
}

impl InterChipletLink {
    /// Time to move `bytes` across the link once.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / (self.bandwidth_gbs * 1e9)
    }
}

/// A complete platform: a set of EPs plus the inter-chiplet link.
#[derive(Debug, Clone)]
pub struct Platform {
    /// Config name (e.g. `C3`).
    pub name: String,
    /// All execution places.
    pub eps: Vec<ExecutionPlace>,
    /// Inter-chiplet interconnect.
    pub link: InterChipletLink,
    /// Optional chiplet mesh; `None` = the paper's single-hop model.
    pub topology: Option<MeshTopology>,
}

impl Platform {
    /// Build a platform, re-numbering EP ids to be dense.
    pub fn new(name: impl Into<String>, mut eps: Vec<ExecutionPlace>) -> Self {
        for (i, ep) in eps.iter_mut().enumerate() {
            ep.id = i;
        }
        Self { name: name.into(), eps, link: InterChipletLink::default(), topology: None }
    }

    /// Number of EPs.
    pub fn n_eps(&self) -> usize {
        self.eps.len()
    }

    /// `H_e`: EP ids sorted in descending order of performance (ties broken
    /// by id for determinism) — the input list of Algorithm 1.
    pub fn eps_by_rank(&self) -> Vec<EpId> {
        let mut ids: Vec<EpId> = (0..self.eps.len()).collect();
        ids.sort_by(|&a, &b| {
            self.eps[b]
                .perf_score()
                .partial_cmp(&self.eps[a].perf_score())
                .unwrap()
                .then(a.cmp(&b))
        });
        ids
    }

    /// Ids of fast execution places (fast memory).
    pub fn fep_ids(&self) -> Vec<EpId> {
        self.eps.iter().filter(|e| e.is_fep()).map(|e| e.id).collect()
    }

    /// Ids of slow execution places.
    pub fn sep_ids(&self) -> Vec<EpId> {
        self.eps.iter().filter(|e| !e.is_fep()).map(|e| e.id).collect()
    }

    /// Whether two EPs live on different chiplets (and so transfers between
    /// them pay the link cost).
    pub fn crosses_chiplet(&self, a: EpId, b: EpId) -> bool {
        self.eps[a].chiplet != self.eps[b].chiplet
    }

    /// Restriction of this platform to the given EPs, **in the given
    /// order**: EP ids are renumbered densely (`subset[i]` becomes local id
    /// `i`), while chiplet ids, the inter-chiplet link and the optional
    /// mesh topology are preserved — so per-layer times and transfer costs
    /// computed on the sub-platform are identical to the same EPs on the
    /// full platform. This is the view a pipeline replica sees under
    /// sharded serving ([`crate::serve::shard`]): each shard schedules
    /// against its own disjoint EP subset.
    ///
    /// Panics if `eps` is empty, contains duplicates, or references an
    /// unknown EP.
    pub fn subset(&self, eps: &[EpId]) -> Platform {
        assert!(!eps.is_empty(), "subset: at least one EP required");
        let mut seen = vec![false; self.n_eps()];
        let picked: Vec<ExecutionPlace> = eps
            .iter()
            .map(|&id| {
                assert!(id < self.n_eps(), "subset: unknown EP {id}");
                assert!(!seen[id], "subset: duplicate EP {id}");
                seen[id] = true;
                self.eps[id].clone()
            })
            .collect();
        let mut plat = Platform::new(format!("{}[{}]", self.name, eps.len()), picked);
        plat.link = self.link;
        plat.topology = self.topology;
        plat
    }

    /// Markdown table of the platform (used by the bench harnesses).
    pub fn describe_table(&self) -> String {
        let mut out = String::from("| EP | cores | type | memory | GFLOP/s | GB/s |\n|---|---|---|---|---|---|\n");
        for ep in &self.eps {
            out.push_str(&format!(
                "| {} | {} | {} | {} | {:.0} | {:.0} |\n",
                ep.id,
                ep.n_cores,
                ep.core_type.name(),
                ep.memory.name(),
                ep.peak_gflops(),
                ep.bandwidth_gbs()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plat2() -> Platform {
        Platform::new(
            "t",
            vec![
                ExecutionPlace::new(0, CoreType::Little, 8, MemoryClass::Slow, 0),
                ExecutionPlace::new(0, CoreType::Big, 8, MemoryClass::Fast, 1),
            ],
        )
    }

    #[test]
    fn ids_renumbered_dense() {
        let p = plat2();
        assert_eq!(p.eps[0].id, 0);
        assert_eq!(p.eps[1].id, 1);
    }

    #[test]
    fn rank_puts_fep_first() {
        let p = plat2();
        assert_eq!(p.eps_by_rank(), vec![1, 0]);
    }

    #[test]
    fn fep_sep_split() {
        let p = plat2();
        assert_eq!(p.fep_ids(), vec![1]);
        assert_eq!(p.sep_ids(), vec![0]);
    }

    #[test]
    fn big_little_perf_ratio() {
        assert!((CoreType::Big.peak_gflops() / CoreType::Little.peak_gflops() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn table1_bandwidths() {
        assert_eq!(MemoryClass::Fast.bandwidth_gbs(), 40.0);
        assert_eq!(MemoryClass::Slow.bandwidth_gbs(), 20.0);
    }

    #[test]
    fn link_transfer_time() {
        let link = InterChipletLink { latency_s: 1e-6, bandwidth_gbs: 10.0 };
        let t = link.transfer_time(10_000_000_000);
        assert!((t - (1e-6 + 1.0)).abs() < 1e-9);
    }

    #[test]
    fn cross_chiplet_detection() {
        let p = plat2();
        assert!(p.crosses_chiplet(0, 1));
        assert!(!p.crosses_chiplet(0, 0));
    }

    #[test]
    fn perf_score_ordering() {
        let fast = ExecutionPlace::new(0, CoreType::Big, 8, MemoryClass::Fast, 0);
        let slow = ExecutionPlace::new(1, CoreType::Little, 8, MemoryClass::Slow, 1);
        assert!(fast.perf_score() > slow.perf_score());
    }

    #[test]
    fn subset_renumbers_but_preserves_hardware() {
        let p = configs::c5();
        let sub = p.subset(&[5, 0, 6]);
        assert_eq!(sub.n_eps(), 3);
        // local ids dense in the given order
        assert_eq!(sub.eps[0].id, 0);
        assert_eq!(sub.eps[1].id, 1);
        assert_eq!(sub.eps[2].id, 2);
        // hardware identity preserved: chiplet, cores, memory
        assert_eq!(sub.eps[0].chiplet, p.eps[5].chiplet);
        assert_eq!(sub.eps[0].core_type, p.eps[5].core_type);
        assert_eq!(sub.eps[1].memory, p.eps[0].memory);
        assert_eq!(sub.link, p.link);
        // cross-chiplet semantics carry over (every C5 EP owns a chiplet)
        assert!(sub.crosses_chiplet(0, 1));
    }

    #[test]
    #[should_panic(expected = "duplicate EP")]
    fn subset_rejects_duplicates() {
        configs::c2().subset(&[1, 1]);
    }

    #[test]
    #[should_panic(expected = "unknown EP")]
    fn subset_rejects_unknown() {
        configs::c1().subset(&[7]);
    }
}
