//! Named platform configurations from the paper.
//!
//! * Table 1 (gem5 system configuration) defines the four EP *kinds*:
//!   big×4 / big×8 on 40 GB/s memory, little×4 / little×8 on 20 GB/s.
//! * Table 3 defines the five EP *mixes* C1–C5 used in the sensitivity
//!   study (Figures 7–8).
//! * Figure 4 runs SynthNet on 8 EPs (we use C5: 4 FEP + 4 SEP);
//!   Figure 5 uses a 4-EP system (we use C2: 2 FEP + 2 SEP).

use super::{CoreType, ExecutionPlace, MemoryClass, Platform};

/// Table 1, row 1: 4 Big cores on fast memory.
pub fn ep_big4(chiplet: u32) -> ExecutionPlace {
    ExecutionPlace::new(0, CoreType::Big, 4, MemoryClass::Fast, chiplet)
}

/// Table 1, row 2: 8 Big cores on fast memory.
pub fn ep_big8(chiplet: u32) -> ExecutionPlace {
    ExecutionPlace::new(0, CoreType::Big, 8, MemoryClass::Fast, chiplet)
}

/// Table 1, row 3: 4 Little cores on slow memory.
pub fn ep_little4(chiplet: u32) -> ExecutionPlace {
    ExecutionPlace::new(0, CoreType::Little, 4, MemoryClass::Slow, chiplet)
}

/// Table 1, row 4: 8 Little cores on slow memory.
pub fn ep_little8(chiplet: u32) -> ExecutionPlace {
    ExecutionPlace::new(0, CoreType::Little, 8, MemoryClass::Slow, chiplet)
}

/// Table 3 C1: 1× 8-core FEP, 1× 8-core SEP.
pub fn c1() -> Platform {
    Platform::new("C1", vec![ep_big8(0), ep_little8(1)])
}

/// Table 3 C2: 2× 8-core FEP, 2× 8-core SEP.
pub fn c2() -> Platform {
    Platform::new("C2", vec![ep_big8(0), ep_big8(1), ep_little8(2), ep_little8(3)])
}

/// Table 3 C3: 4× 4-core FEP, 2× 8-core SEP.
pub fn c3() -> Platform {
    Platform::new(
        "C3",
        vec![ep_big4(0), ep_big4(1), ep_big4(2), ep_big4(3), ep_little8(4), ep_little8(5)],
    )
}

/// Table 3 C4: 2× 8-core FEP, 4× 4-core SEP.
pub fn c4() -> Platform {
    Platform::new(
        "C4",
        vec![ep_big8(0), ep_big8(1), ep_little4(2), ep_little4(3), ep_little4(4), ep_little4(5)],
    )
}

/// Table 3 C5: 4× 4-core FEP, 4× 4-core SEP (the 8-EP system of Figure 4).
pub fn c5() -> Platform {
    Platform::new(
        "C5",
        vec![
            ep_big4(0),
            ep_big4(1),
            ep_big4(2),
            ep_big4(3),
            ep_little4(4),
            ep_little4(5),
            ep_little4(6),
            ep_little4(7),
        ],
    )
}

/// All Table 3 configs in order.
pub fn all_c() -> Vec<Platform> {
    vec![c1(), c2(), c3(), c4(), c5()]
}

/// The 8-EP platform of Figure 4.
pub fn fig4_platform() -> Platform {
    let mut p = c5();
    p.name = "Fig4-8EP".into();
    p
}

/// The 4-EP platform of Figure 5 (ES feasible).
pub fn fig5_platform() -> Platform {
    let mut p = c2();
    p.name = "Fig5-4EP".into();
    p
}

/// Look up a platform by name: `c1`..`c5`, `fig4`/`8ep`, `fig5`/`4ep`.
pub fn by_name(name: &str) -> Option<Platform> {
    match name.to_ascii_lowercase().as_str() {
        "c1" => Some(c1()),
        "c2" => Some(c2()),
        "c3" => Some(c3()),
        "c4" => Some(c4()),
        "c5" => Some(c5()),
        "fig4" | "8ep" => Some(fig4_platform()),
        "fig5" | "4ep" => Some(fig5_platform()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_ep_counts() {
        assert_eq!(c1().n_eps(), 2);
        assert_eq!(c2().n_eps(), 4);
        assert_eq!(c3().n_eps(), 6);
        assert_eq!(c4().n_eps(), 6);
        assert_eq!(c5().n_eps(), 8);
    }

    #[test]
    fn table3_fep_sep_split() {
        assert_eq!(c3().fep_ids().len(), 4);
        assert_eq!(c3().sep_ids().len(), 2);
        assert_eq!(c4().fep_ids().len(), 2);
        assert_eq!(c4().sep_ids().len(), 4);
    }

    #[test]
    fn fig4_has_8_eps() {
        assert_eq!(fig4_platform().n_eps(), 8);
    }

    #[test]
    fn fig5_has_4_eps() {
        assert_eq!(fig5_platform().n_eps(), 4);
    }

    #[test]
    fn each_ep_own_chiplet() {
        for p in all_c() {
            let mut chiplets: Vec<u32> = p.eps.iter().map(|e| e.chiplet).collect();
            chiplets.dedup();
            assert_eq!(chiplets.len(), p.n_eps(), "{}: one chiplet per EP", p.name);
        }
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["c1", "c2", "c3", "c4", "c5", "fig4", "fig5"] {
            assert!(by_name(n).is_some());
        }
        assert!(by_name("c9").is_none());
    }

    #[test]
    fn ranking_feps_before_seps() {
        for p in all_c() {
            let rank = p.eps_by_rank();
            let n_fep = p.fep_ids().len();
            for &id in &rank[..n_fep] {
                assert!(p.eps[id].is_fep(), "{}: top ranks are FEPs", p.name);
            }
        }
    }
}
