//! Chiplet mesh topology (extension).
//!
//! The paper's transfer model charges one inter-chiplet hop per stage
//! boundary (Figure 9 sweeps that hop's latency). Real MCM packages —
//! Simba [28] is the paper's own example — arrange chiplets in a 2-D mesh
//! where chip-to-chip latency grows with Manhattan distance. This module
//! adds an optional [`MeshTopology`] to [`super::Platform`]: when present,
//! transfers pay `hops × latency + bytes/bandwidth`; when absent the
//! paper's single-hop model applies unchanged.
//!
//! `locality_order` provides the placement-aware refinement studied in
//! `examples/latency_sweep.rs`: within performance-equivalence classes,
//! EPs are ordered along a serpentine walk of the mesh so consecutive
//! pipeline stages land on adjacent chiplets.

use super::{EpId, Platform};

/// A 2-D mesh of chiplets; chiplet `c` sits at `(c % width, c / width)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MeshTopology {
    /// Mesh width (chiplets per row).
    pub width: u32,
    /// Mesh height.
    pub height: u32,
}

impl MeshTopology {
    /// Square-ish mesh large enough for `n` chiplets.
    pub fn for_chiplets(n: u32) -> Self {
        let width = (n as f64).sqrt().ceil() as u32;
        let height = n.div_ceil(width.max(1)).max(1);
        Self { width: width.max(1), height }
    }

    /// Coordinates of a chiplet.
    pub fn coords(&self, chiplet: u32) -> (u32, u32) {
        (chiplet % self.width, chiplet / self.width)
    }

    /// Manhattan hop count between two chiplets (0 when equal).
    pub fn hops(&self, a: u32, b: u32) -> u32 {
        let (ax, ay) = self.coords(a);
        let (bx, by) = self.coords(b);
        ax.abs_diff(bx) + ay.abs_diff(by)
    }

    /// Serpentine (boustrophedon) order of chiplet ids: consecutive
    /// positions in the returned order are mesh-adjacent.
    pub fn serpentine(&self, n_chiplets: u32) -> Vec<u32> {
        let mut order = Vec::with_capacity(n_chiplets as usize);
        for y in 0..self.height {
            let row: Vec<u32> = (0..self.width)
                .map(|x| y * self.width + x)
                .filter(|&c| c < n_chiplets)
                .collect();
            if y % 2 == 0 {
                order.extend(row);
            } else {
                order.extend(row.into_iter().rev());
            }
        }
        order
    }
}

/// Transfer time between two EPs on `plat` for `bytes`, honouring the
/// mesh when present (single hop otherwise). Same-chiplet transfers are
/// free, matching the paper's model.
pub fn transfer_time(plat: &Platform, from: EpId, to: EpId, bytes: u64) -> f64 {
    let a = plat.eps[from].chiplet;
    let b = plat.eps[to].chiplet;
    if a == b {
        return 0.0;
    }
    let hops = plat.topology.map_or(1, |m| m.hops(a, b).max(1));
    hops as f64 * plat.link.latency_s + bytes as f64 / (plat.link.bandwidth_gbs * 1e9)
}

/// Reorder an EP ranking for locality: stable within performance classes
/// (score ties broken by serpentine mesh position), so the seed generator
/// keeps its heterogeneity-aware order while consecutive same-class EPs
/// become mesh-adjacent.
pub fn locality_order(plat: &Platform) -> Vec<EpId> {
    let Some(mesh) = plat.topology else {
        return plat.eps_by_rank();
    };
    let serp = mesh.serpentine(plat.eps.iter().map(|e| e.chiplet + 1).max().unwrap_or(1));
    let pos = |ep: &EpId| serp.iter().position(|&c| c == plat.eps[*ep].chiplet).unwrap_or(0);
    let mut ids = plat.eps_by_rank();
    // stable sort by (perf class, serpentine position): classes keep rank
    // order, members inside a class follow the mesh walk.
    ids.sort_by(|a, b| {
        let pa = plat.eps[*a].perf_score();
        let pb = plat.eps[*b].perf_score();
        pb.partial_cmp(&pa).unwrap().then(pos(a).cmp(&pos(b)))
    });
    ids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::configs;

    #[test]
    fn mesh_shapes() {
        let m = MeshTopology::for_chiplets(8);
        assert_eq!((m.width, m.height), (3, 3));
        assert_eq!(MeshTopology::for_chiplets(4).width, 2);
        assert_eq!(MeshTopology::for_chiplets(1).width, 1);
    }

    #[test]
    fn hops_manhattan() {
        let m = MeshTopology { width: 3, height: 3 };
        assert_eq!(m.hops(0, 0), 0);
        assert_eq!(m.hops(0, 1), 1);
        assert_eq!(m.hops(0, 8), 4); // (0,0) -> (2,2)
        assert_eq!(m.hops(2, 6), 4); // (2,0) -> (0,2)
    }

    #[test]
    fn serpentine_adjacency() {
        let m = MeshTopology { width: 3, height: 3 };
        let order = m.serpentine(9);
        assert_eq!(order.len(), 9);
        for w in order.windows(2) {
            assert_eq!(m.hops(w[0], w[1]), 1, "consecutive {w:?} adjacent");
        }
    }

    #[test]
    fn transfer_single_hop_without_mesh() {
        let plat = configs::c2();
        let t = transfer_time(&plat, 0, 1, 1_000_000);
        let expect = plat.link.latency_s + 1_000_000.0 / (plat.link.bandwidth_gbs * 1e9);
        assert!((t - expect).abs() < 1e-15);
    }

    #[test]
    fn transfer_scales_with_hops() {
        let mut plat = configs::c5(); // 8 chiplets
        plat.topology = Some(MeshTopology { width: 3, height: 3 });
        plat.link.latency_s = 1e-3; // make latency dominate
        let near = transfer_time(&plat, 0, 1, 1);
        let far = transfer_time(&plat, 0, 7, 1); // chiplet 0 (0,0) -> 7 (1,2): 3 hops
        assert!((far / near - 3.0).abs() < 1e-6, "near {near} far {far}");
    }

    #[test]
    fn same_chiplet_free() {
        let mut plat = configs::c1();
        plat.eps[1].chiplet = plat.eps[0].chiplet;
        assert_eq!(transfer_time(&plat, 0, 1, 1 << 30), 0.0);
    }

    #[test]
    fn locality_order_keeps_class_ranks() {
        let mut plat = configs::c5();
        plat.topology = Some(MeshTopology::for_chiplets(8));
        let order = locality_order(&plat);
        // first four must still be the FEPs
        for &id in &order[..4] {
            assert!(plat.eps[id].is_fep());
        }
        // the locality order must not be worse than plain rank order in
        // total consecutive-pair hop distance
        let m = plat.topology.unwrap();
        let path = |ids: &[crate::platform::EpId]| -> u32 {
            ids.windows(2)
                .map(|w| m.hops(plat.eps[w[0]].chiplet, plat.eps[w[1]].chiplet))
                .sum()
        };
        assert!(path(&order) <= path(&plat.eps_by_rank()), "{order:?}");
    }

    #[test]
    fn locality_order_without_mesh_is_rank_order() {
        let plat = configs::c3();
        assert_eq!(locality_order(&plat), plat.eps_by_rank());
    }
}
