//! Experiment configuration files — a hand-rolled TOML-subset parser
//! (the offline environment has no serde facade; DESIGN.md §5).
//!
//! Supported grammar (enough for `configs/*.toml` experiment files):
//!
//! ```toml
//! # comment
//! [section]
//! key = "string"
//! n = 42
//! x = 2.5
//! flag = true
//! list = [1, 2, 3]
//! names = ["a", "b"]
//! ```
//!
//! Values live in [`Value`]; [`Config`] maps `section.key` → value with
//! typed getters. [`ExperimentConfig`] is the typed view the CLI consumes.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A configuration value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Integer.
    Int(i64),
    /// Float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Homogeneous-ish list of scalars.
    List(Vec<Value>),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::List(xs) => {
                write!(f, "[")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

fn parse_scalar(tok: &str) -> Result<Value> {
    let tok = tok.trim();
    if let Some(stripped) = tok.strip_prefix('"') {
        let inner = stripped.strip_suffix('"').context("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    match tok {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = tok.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(x) = tok.parse::<f64>() {
        return Ok(Value::Float(x));
    }
    bail!("unparseable value {tok:?}")
}

fn parse_value(raw: &str) -> Result<Value> {
    let raw = raw.trim();
    if let Some(stripped) = raw.strip_prefix('[') {
        let inner = stripped.strip_suffix(']').context("unterminated list")?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            // split at commas not inside quotes
            let mut depth_quote = false;
            let mut cur = String::new();
            for ch in inner.chars() {
                match ch {
                    '"' => {
                        depth_quote = !depth_quote;
                        cur.push(ch);
                    }
                    ',' if !depth_quote => {
                        items.push(parse_scalar(&cur)?);
                        cur.clear();
                    }
                    _ => cur.push(ch),
                }
            }
            if !cur.trim().is_empty() {
                items.push(parse_scalar(&cur)?);
            }
        }
        return Ok(Value::List(items));
    }
    parse_scalar(raw)
}

/// Parsed config: flat map `section.key` → [`Value`].
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    /// Parse config text.
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            // strip comments (naive: # outside quotes)
            let mut in_quote = false;
            let mut line = String::new();
            for ch in raw.chars() {
                if ch == '"' {
                    in_quote = !in_quote;
                }
                if ch == '#' && !in_quote {
                    break;
                }
                line.push(ch);
            }
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name.strip_suffix(']').with_context(|| format!("line {}: bad section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(
                key,
                parse_value(v).with_context(|| format!("line {}", lineno + 1))?,
            );
        }
        Ok(Config { values })
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    /// Raw value.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    /// String getter.
    pub fn str(&self, key: &str) -> Option<&str> {
        match self.get(key) {
            Some(Value::Str(s)) => Some(s),
            _ => None,
        }
    }

    /// Integer getter (accepts Int).
    pub fn int(&self, key: &str) -> Option<i64> {
        match self.get(key) {
            Some(Value::Int(i)) => Some(*i),
            _ => None,
        }
    }

    /// Float getter (accepts Int or Float).
    pub fn float(&self, key: &str) -> Option<f64> {
        match self.get(key) {
            Some(Value::Float(x)) => Some(*x),
            Some(Value::Int(i)) => Some(*i as f64),
            _ => None,
        }
    }

    /// Bool getter.
    pub fn bool(&self, key: &str) -> Option<bool> {
        match self.get(key) {
            Some(Value::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    /// List-of-strings getter.
    pub fn str_list(&self, key: &str) -> Option<Vec<&str>> {
        match self.get(key) {
            Some(Value::List(xs)) => xs
                .iter()
                .map(|v| match v {
                    Value::Str(s) => Some(s.as_str()),
                    _ => None,
                })
                .collect(),
            _ => None,
        }
    }

    /// All keys (sorted).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(String::as_str)
    }
}

/// Typed experiment configuration consumed by `shisha explore --config`.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Network name (model registry).
    pub network: String,
    /// Platform name (platform registry).
    pub platform: String,
    /// Algorithms to run.
    pub algorithms: Vec<String>,
    /// Shisha α.
    pub alpha: u32,
    /// Probe inputs per online trial.
    pub probe_inputs: u64,
    /// Optional virtual-time limit.
    pub time_limit_s: Option<f64>,
    /// RNG seed.
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            network: "synthnet".into(),
            platform: "c2".into(),
            algorithms: vec!["shisha".into()],
            alpha: 10,
            probe_inputs: 10,
            time_limit_s: None,
            seed: 42,
        }
    }
}

impl ExperimentConfig {
    /// Extract from a parsed [`Config`] (section `[experiment]`).
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let mut out = ExperimentConfig::default();
        if let Some(s) = cfg.str("experiment.network") {
            out.network = s.to_string();
        }
        if let Some(s) = cfg.str("experiment.platform") {
            out.platform = s.to_string();
        }
        if let Some(xs) = cfg.str_list("experiment.algorithms") {
            out.algorithms = xs.into_iter().map(String::from).collect();
        }
        if let Some(i) = cfg.int("experiment.alpha") {
            out.alpha = u32::try_from(i).context("alpha must be positive")?;
        }
        if let Some(i) = cfg.int("experiment.probe_inputs") {
            out.probe_inputs = u64::try_from(i).context("probe_inputs must be positive")?;
        }
        if let Some(x) = cfg.float("experiment.time_limit_s") {
            out.time_limit_s = Some(x);
        }
        if let Some(i) = cfg.int("experiment.seed") {
            out.seed = i as u64;
        }
        // validate against registries
        if crate::model::networks::by_name(&out.network).is_none() {
            bail!("unknown network {:?}", out.network);
        }
        if crate::platform::configs::by_name(&out.platform).is_none() {
            bail!("unknown platform {:?}", out.platform);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment file
[experiment]
network = "resnet50"
platform = "c3"
algorithms = ["shisha", "sa", "hc"]
alpha = 12
probe_inputs = 20
time_limit_s = 600.5
seed = 7

[other]
flag = true
ratio = 0.25
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str("experiment.network"), Some("resnet50"));
        assert_eq!(c.int("experiment.alpha"), Some(12));
        assert_eq!(c.float("experiment.time_limit_s"), Some(600.5));
        assert_eq!(c.bool("other.flag"), Some(true));
        assert_eq!(c.float("other.ratio"), Some(0.25));
        assert_eq!(
            c.str_list("experiment.algorithms"),
            Some(vec!["shisha", "sa", "hc"])
        );
    }

    #[test]
    fn int_promotes_to_float() {
        let c = Config::parse("x = 3\n").unwrap();
        assert_eq!(c.float("x"), Some(3.0));
        assert_eq!(c.int("x"), Some(3));
    }

    #[test]
    fn comments_and_blank_lines() {
        let c = Config::parse("# only a comment\n\nk = 1 # trailing\n").unwrap();
        assert_eq!(c.int("k"), Some(1));
    }

    #[test]
    fn hash_inside_string_kept() {
        let c = Config::parse("s = \"a#b\"\n").unwrap();
        assert_eq!(c.str("s"), Some("a#b"));
    }

    #[test]
    fn errors_on_garbage() {
        assert!(Config::parse("key without equals\n").is_err());
        assert!(Config::parse("k = [1, 2\n").is_err());
        assert!(Config::parse("k = \"unterminated\n").is_err());
    }

    #[test]
    fn experiment_config_roundtrip() {
        let c = Config::parse(SAMPLE).unwrap();
        let e = ExperimentConfig::from_config(&c).unwrap();
        assert_eq!(e.network, "resnet50");
        assert_eq!(e.platform, "c3");
        assert_eq!(e.algorithms.len(), 3);
        assert_eq!(e.alpha, 12);
        assert_eq!(e.time_limit_s, Some(600.5));
    }

    #[test]
    fn experiment_config_validates_names() {
        let c = Config::parse("[experiment]\nnetwork = \"nope\"\n").unwrap();
        assert!(ExperimentConfig::from_config(&c).is_err());
    }

    #[test]
    fn defaults_applied() {
        let c = Config::parse("").unwrap();
        let e = ExperimentConfig::from_config(&c).unwrap();
        assert_eq!(e.network, "synthnet");
        assert_eq!(e.alpha, 10);
    }

    #[test]
    fn empty_list() {
        let c = Config::parse("xs = []\n").unwrap();
        assert_eq!(c.get("xs"), Some(&Value::List(vec![])));
    }

    #[test]
    fn display_roundtrip_shapes() {
        assert_eq!(Value::Int(3).to_string(), "3");
        assert_eq!(Value::List(vec![Value::Int(1), Value::Bool(true)]).to_string(), "[1, true]");
    }
}
