//! Pipeline configurations and their evaluation.
//!
//! A pipeline configuration (paper §5) has two components:
//!
//! 1. a partition of the CNN's `L` layers into `N ≤ #EPs` **contiguous**
//!    pipeline stages (layers form a chain DAG, so only consecutive layers
//!    may be merged — §5.1), recorded as per-stage layer counts;
//! 2. an injective assignment of stages to Execution Places.
//!
//! [`simulator`] computes per-stage times and steady-state throughput for a
//! configuration against a [`crate::perfdb::PerfDb`] (the paper's database
//! mode) including inter-chiplet transfer costs; [`space`] counts and
//! enumerates the design space for Exhaustive Search and the paper's
//! "explored %" metric.

pub mod objective;
pub mod simulator;
pub mod space;

use crate::platform::{EpId, Platform};

/// A pipeline configuration: stage sizes + stage-to-EP assignment.
#[derive(Debug, PartialEq, Eq, Hash)]
pub struct PipelineConfig {
    /// Layers per stage; `stages.len() == N`, `sum(stages) == L`, all ≥ 1.
    pub stages: Vec<usize>,
    /// EP assigned to each stage; distinct, `assignment.len() == N`.
    pub assignment: Vec<EpId>,
}

// Hand-written so `clone_from` reuses the destination's Vec allocations:
// the evaluator's best-so-far update (`Evaluator::evaluate`) runs in every
// explorer inner loop, and the derived impl would discard and reallocate
// both vectors on each improvement.
impl Clone for PipelineConfig {
    fn clone(&self) -> Self {
        Self { stages: self.stages.clone(), assignment: self.assignment.clone() }
    }

    fn clone_from(&mut self, source: &Self) {
        self.stages.clone_from(&source.stages);
        self.assignment.clone_from(&source.assignment);
    }
}

/// Validation failure for a [`PipelineConfig`].
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum ConfigError {
    /// No stages at all.
    #[error("configuration has zero stages")]
    Empty,
    /// A stage with zero layers.
    #[error("stage {0} has zero layers")]
    EmptyStage(usize),
    /// Stage sizes don't sum to the layer count.
    #[error("stage sizes sum to {got}, network has {want} layers")]
    WrongLayerTotal {
        /// Sum of stage sizes.
        got: usize,
        /// Network layer count.
        want: usize,
    },
    /// Assignment length mismatch.
    #[error("{stages} stages but {eps} assigned EPs")]
    AssignmentLength {
        /// Number of stages.
        stages: usize,
        /// Number of assigned EPs.
        eps: usize,
    },
    /// An EP referenced that the platform does not have.
    #[error("assigned EP {0} does not exist on the platform")]
    UnknownEp(EpId),
    /// The same EP assigned to two stages.
    #[error("EP {0} assigned to more than one stage")]
    DuplicateEp(EpId),
}

impl PipelineConfig {
    /// Construct without validation.
    pub fn new(stages: Vec<usize>, assignment: Vec<EpId>) -> Self {
        Self { stages, assignment }
    }

    /// Single-stage configuration: the whole network on one EP.
    pub fn single_stage(n_layers: usize, ep: EpId) -> Self {
        Self { stages: vec![n_layers], assignment: vec![ep] }
    }

    /// Number of pipeline stages `N`.
    #[inline]
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Total layers covered.
    #[inline]
    pub fn n_layers(&self) -> usize {
        self.stages.iter().sum()
    }

    /// Per-stage `[lo, hi)` layer-index bounds.
    pub fn stage_bounds(&self) -> Vec<(usize, usize)> {
        let mut bounds = Vec::with_capacity(self.stages.len());
        let mut lo = 0;
        for &n in &self.stages {
            bounds.push((lo, lo + n));
            lo += n;
        }
        bounds
    }

    /// The stage containing layer index `layer`, if covered.
    pub fn stage_of_layer(&self, layer: usize) -> Option<usize> {
        let mut lo = 0;
        for (si, &n) in self.stages.iter().enumerate() {
            if layer < lo + n {
                return Some(si);
            }
            lo += n;
        }
        None
    }

    /// Validate against a network size and platform.
    pub fn validate(&self, n_layers: usize, plat: &Platform) -> Result<(), ConfigError> {
        if self.stages.is_empty() {
            return Err(ConfigError::Empty);
        }
        if let Some(si) = self.stages.iter().position(|&n| n == 0) {
            return Err(ConfigError::EmptyStage(si));
        }
        let got = self.n_layers();
        if got != n_layers {
            return Err(ConfigError::WrongLayerTotal { got, want: n_layers });
        }
        if self.assignment.len() != self.stages.len() {
            return Err(ConfigError::AssignmentLength {
                stages: self.stages.len(),
                eps: self.assignment.len(),
            });
        }
        let mut seen = vec![false; plat.n_eps()];
        for &ep in &self.assignment {
            if ep >= plat.n_eps() {
                return Err(ConfigError::UnknownEp(ep));
            }
            if seen[ep] {
                return Err(ConfigError::DuplicateEp(ep));
            }
            seen[ep] = true;
        }
        Ok(())
    }

    /// Move one layer from stage `from` to the adjacent stage `to`
    /// (`|from − to| == 1`), shrinking `from` by one layer on the shared
    /// boundary. Returns `None` if the move would empty `from` or the
    /// stages are not adjacent.
    pub fn move_layer(&self, from: usize, to: usize) -> Option<PipelineConfig> {
        if from >= self.stages.len() || to >= self.stages.len() {
            return None;
        }
        if from.abs_diff(to) != 1 || self.stages[from] <= 1 {
            return None;
        }
        let mut next = self.clone();
        next.stages[from] -= 1;
        next.stages[to] += 1;
        Some(next)
    }

    /// Merge stage `s` with `s+1`, freeing the EP of `s+1`.
    /// Returns `None` when out of range or only one stage remains.
    pub fn merge_stages(&self, s: usize) -> Option<PipelineConfig> {
        if self.stages.len() < 2 || s + 1 >= self.stages.len() {
            return None;
        }
        let mut next = self.clone();
        next.stages[s] += next.stages[s + 1];
        next.stages.remove(s + 1);
        next.assignment.remove(s + 1);
        Some(next)
    }

    /// Split stage `s` after `left` layers, assigning the new right half to
    /// `new_ep` (which must be unused). Returns `None` when illegal.
    pub fn split_stage(&self, s: usize, left: usize, new_ep: EpId) -> Option<PipelineConfig> {
        if s >= self.stages.len() || left == 0 || left >= self.stages[s] {
            return None;
        }
        if self.assignment.contains(&new_ep) {
            return None;
        }
        let mut next = self.clone();
        let right = next.stages[s] - left;
        next.stages[s] = left;
        next.stages.insert(s + 1, right);
        next.assignment.insert(s + 1, new_ep);
        Some(next)
    }

    /// Swap the EPs of stages `a` and `b`.
    pub fn swap_eps(&self, a: usize, b: usize) -> Option<PipelineConfig> {
        if a >= self.stages.len() || b >= self.stages.len() || a == b {
            return None;
        }
        let mut next = self.clone();
        next.assignment.swap(a, b);
        Some(next)
    }

    /// Reassign stage `s` to a currently unused EP.
    pub fn reassign(&self, s: usize, ep: EpId) -> Option<PipelineConfig> {
        if s >= self.stages.len() || self.assignment.contains(&ep) {
            return None;
        }
        let mut next = self.clone();
        next.assignment[s] = ep;
        Some(next)
    }

    /// Compact display, e.g. `[3@EP0, 7@EP2, 8@EP1]`.
    pub fn describe(&self) -> String {
        let parts: Vec<String> = self
            .stages
            .iter()
            .zip(&self.assignment)
            .map(|(n, ep)| format!("{n}@EP{ep}"))
            .collect();
        format!("[{}]", parts.join(", "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::configs;

    fn cfg() -> PipelineConfig {
        PipelineConfig::new(vec![3, 7, 8], vec![0, 2, 1])
    }

    #[test]
    fn bounds_partition_layers() {
        let c = cfg();
        assert_eq!(c.stage_bounds(), vec![(0, 3), (3, 10), (10, 18)]);
        assert_eq!(c.n_layers(), 18);
    }

    #[test]
    fn stage_of_layer_lookup() {
        let c = cfg();
        assert_eq!(c.stage_of_layer(0), Some(0));
        assert_eq!(c.stage_of_layer(3), Some(1));
        assert_eq!(c.stage_of_layer(17), Some(2));
        assert_eq!(c.stage_of_layer(18), None);
    }

    #[test]
    fn validate_accepts_good_config() {
        let c = cfg();
        assert_eq!(c.validate(18, &configs::c2()), Ok(()));
    }

    #[test]
    fn validate_rejects_bad_total() {
        let c = cfg();
        assert!(matches!(
            c.validate(20, &configs::c2()),
            Err(ConfigError::WrongLayerTotal { got: 18, want: 20 })
        ));
    }

    #[test]
    fn validate_rejects_duplicate_ep() {
        let c = PipelineConfig::new(vec![9, 9], vec![1, 1]);
        assert!(matches!(c.validate(18, &configs::c2()), Err(ConfigError::DuplicateEp(1))));
    }

    #[test]
    fn validate_rejects_unknown_ep() {
        let c = PipelineConfig::new(vec![18], vec![9]);
        assert!(matches!(c.validate(18, &configs::c2()), Err(ConfigError::UnknownEp(9))));
    }

    #[test]
    fn validate_rejects_empty_stage() {
        let c = PipelineConfig::new(vec![18, 0], vec![0, 1]);
        assert!(matches!(c.validate(18, &configs::c2()), Err(ConfigError::EmptyStage(1))));
    }

    #[test]
    fn move_layer_adjacent_only() {
        let c = cfg();
        let m = c.move_layer(1, 0).unwrap();
        assert_eq!(m.stages, vec![4, 6, 8]);
        assert!(c.move_layer(0, 2).is_none(), "non-adjacent");
    }

    #[test]
    fn move_layer_never_empties() {
        let c = PipelineConfig::new(vec![1, 17], vec![0, 1]);
        assert!(c.move_layer(0, 1).is_none());
    }

    #[test]
    fn merge_and_split_roundtrip() {
        let c = cfg();
        let merged = c.merge_stages(1).unwrap();
        assert_eq!(merged.stages, vec![3, 15]);
        assert_eq!(merged.assignment, vec![0, 2]);
        let split = merged.split_stage(1, 7, 1).unwrap();
        assert_eq!(split.stages, vec![3, 7, 8]);
        assert_eq!(split.assignment, vec![0, 2, 1]);
    }

    #[test]
    fn split_rejects_used_ep() {
        let c = cfg();
        assert!(c.split_stage(2, 4, 0).is_none());
    }

    #[test]
    fn swap_and_reassign() {
        let c = cfg();
        let s = c.swap_eps(0, 2).unwrap();
        assert_eq!(s.assignment, vec![1, 2, 0]);
        let c2 = PipelineConfig::new(vec![9, 9], vec![0, 1]);
        let r = c2.reassign(0, 3).unwrap();
        assert_eq!(r.assignment, vec![3, 1]);
        assert!(c2.reassign(0, 1).is_none(), "EP 1 already used");
    }

    #[test]
    fn describe_format() {
        assert_eq!(cfg().describe(), "[3@EP0, 7@EP2, 8@EP1]");
    }
}
