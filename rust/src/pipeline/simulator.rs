//! Steady-state pipeline simulator.
//!
//! Evaluates a [`PipelineConfig`] against the per-layer time database:
//!
//! * **stage compute time** — sum of its layers' times on its EP (O(1) via
//!   the database prefix sums);
//! * **stage transfer time** — receiving the previous stage's output across
//!   the inter-chiplet link (latency + bytes/bandwidth), §7.6;
//! * **throughput** — `1 / max_stage_time` (images/s): in steady state the
//!   pipeline is limited by its slowest stage;
//! * **makespan** — fill latency plus `(k−1)` bottleneck periods for `k`
//!   inputs, used to charge explorers the *online* cost of trying a
//!   configuration (slow configurations cost more wall-clock to test —
//!   the effect that separates Shisha from blind search in Figure 4).

use super::PipelineConfig;
use crate::model::Network;
use crate::perfdb::PerfDb;
use crate::platform::{EpId, Platform};

/// Per-stage evaluation breakdown.
#[derive(Debug, Clone, PartialEq)]
pub struct StageEval {
    /// Stage index.
    pub stage: usize,
    /// Compute time on the assigned EP, seconds.
    pub compute_s: f64,
    /// Inbound transfer time (0 for the first stage or same-chiplet), seconds.
    pub transfer_s: f64,
}

impl StageEval {
    /// Total stage service time.
    #[inline]
    pub fn total(&self) -> f64 {
        self.compute_s + self.transfer_s
    }
}

/// Full evaluation of one pipeline configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineEval {
    /// Per-stage breakdown.
    pub stages: Vec<StageEval>,
    /// Bottleneck stage service time, seconds.
    pub bottleneck_s: f64,
    /// Steady-state throughput, images/s.
    pub throughput: f64,
    /// Pipeline fill latency (sum of all stage times), seconds.
    pub latency_s: f64,
}

/// Compute and transfer time of the contiguous stage `[lo, hi)` served on
/// `ep`, receiving its input from `from_ep` (`None` for the entry stage),
/// with `batch` images per pipeline slot.
///
/// This is the **single source of truth** for per-stage service math: the
/// steady-state evaluators below and the serving engine's dispatch path
/// ([`crate::serve::engine`]) both call it, so the discrete-event
/// contention model cannot silently drift from the analytic model. The
/// transfer term charges the previous stage's last layer's output crossing
/// the NoC (`batch` images per slot); `db` must already be batch-aware for
/// the compute term (see [`crate::perfdb::batch`]).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn stage_service_time(
    net: &Network,
    plat: &Platform,
    db: &PerfDb,
    lo: usize,
    hi: usize,
    ep: EpId,
    from_ep: Option<EpId>,
    batch: u64,
) -> (f64, f64) {
    let compute_s = db.range_time(lo, hi, ep);
    let transfer_s = match from_ep {
        None => 0.0,
        Some(prev_ep) => crate::platform::topology::transfer_time(
            plat,
            prev_ep,
            ep,
            net.layers[lo - 1].output_bytes() * batch,
        ),
    };
    (compute_s, transfer_s)
}

/// Evaluate `cfg` on `net`/`plat` using the time database `db`.
///
/// `db` rows must correspond to `plat.eps` and columns to `net.layers`.
pub fn evaluate(net: &Network, plat: &Platform, db: &PerfDb, cfg: &PipelineConfig) -> PipelineEval {
    debug_assert_eq!(db.n_layers(), net.len());
    let bounds = cfg.stage_bounds();
    let mut stages = Vec::with_capacity(bounds.len());
    for (si, &(lo, hi)) in bounds.iter().enumerate() {
        let ep = cfg.assignment[si];
        let from_ep = if si == 0 { None } else { Some(cfg.assignment[si - 1]) };
        let (compute_s, transfer_s) = stage_service_time(net, plat, db, lo, hi, ep, from_ep, 1);
        stages.push(StageEval { stage: si, compute_s, transfer_s });
    }
    let bottleneck_s = stages.iter().map(StageEval::total).fold(0.0, f64::max);
    let latency_s = stages.iter().map(StageEval::total).sum();
    PipelineEval {
        stages,
        bottleneck_s,
        throughput: if bottleneck_s > 0.0 { 1.0 / bottleneck_s } else { f64::INFINITY },
        latency_s,
    }
}

/// Steady-state throughput only (hot path for explorers).
#[inline]
pub fn throughput(net: &Network, plat: &Platform, db: &PerfDb, cfg: &PipelineConfig) -> f64 {
    // Specialised: avoid allocating StageEval vec.
    let mut lo = 0usize;
    let mut bottleneck = 0.0f64;
    for (si, &n) in cfg.stages.iter().enumerate() {
        let hi = lo + n;
        let ep = cfg.assignment[si];
        let from_ep = if si == 0 { None } else { Some(cfg.assignment[si - 1]) };
        let (compute_s, transfer_s) = stage_service_time(net, plat, db, lo, hi, ep, from_ep, 1);
        let t = compute_s + transfer_s;
        if t > bottleneck {
            bottleneck = t;
        }
        lo = hi;
    }
    if bottleneck > 0.0 {
        1.0 / bottleneck
    } else {
        f64::INFINITY
    }
}

/// Index of the slowest stage (Algorithm 2, line 5).
pub fn slowest_stage(net: &Network, plat: &Platform, db: &PerfDb, cfg: &PipelineConfig) -> usize {
    let eval = evaluate(net, plat, db, cfg);
    eval.stages
        .iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| a.total().partial_cmp(&b.total()).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

/// Wall-clock time to push `k` inputs through the pipeline: fill latency +
/// `(k−1)` bottleneck periods. This is what an *online* tuner pays to test
/// a configuration with `k` probe inputs.
pub fn makespan(net: &Network, plat: &Platform, db: &PerfDb, cfg: &PipelineConfig, k: u64) -> f64 {
    let eval = evaluate(net, plat, db, cfg);
    eval.latency_s + (k.saturating_sub(1)) as f64 * eval.bottleneck_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::networks;
    use crate::perfdb::CostModel;
    use crate::platform::configs;

    fn setup() -> (Network, Platform, PerfDb) {
        let net = networks::synthnet();
        let plat = configs::c2();
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        (net, plat, db)
    }

    #[test]
    fn throughput_is_inverse_bottleneck() {
        let (net, plat, db) = setup();
        let cfg = PipelineConfig::new(vec![9, 9], vec![0, 2]);
        let eval = evaluate(&net, &plat, &db, &cfg);
        assert!((eval.throughput - 1.0 / eval.bottleneck_s).abs() < 1e-12);
    }

    #[test]
    fn fast_path_matches_full_eval() {
        let (net, plat, db) = setup();
        for cfg in [
            PipelineConfig::new(vec![18], vec![0]),
            PipelineConfig::new(vec![9, 9], vec![0, 2]),
            PipelineConfig::new(vec![5, 6, 7], vec![1, 0, 3]),
            PipelineConfig::new(vec![4, 4, 5, 5], vec![3, 2, 1, 0]),
        ] {
            let full = evaluate(&net, &plat, &db, &cfg).throughput;
            let fast = throughput(&net, &plat, &db, &cfg);
            assert!((full - fast).abs() < 1e-12 * full.max(1.0), "{}", cfg.describe());
        }
    }

    #[test]
    fn stage_service_time_is_the_shared_formula() {
        let (net, plat, db) = setup();
        let cfg = PipelineConfig::new(vec![9, 9], vec![0, 2]);
        let eval = evaluate(&net, &plat, &db, &cfg);
        let (c0, x0) = stage_service_time(&net, &plat, &db, 0, 9, 0, None, 1);
        assert_eq!(c0, eval.stages[0].compute_s);
        assert_eq!(x0, 0.0);
        let (c1, x1) = stage_service_time(&net, &plat, &db, 9, 18, 2, Some(0), 1);
        assert_eq!(c1, eval.stages[1].compute_s);
        assert_eq!(x1, eval.stages[1].transfer_s);
        // batching multiplies the transferred bytes, not the compute term
        // (the engine passes a batch-aware db for compute)
        let (c1b, x1b) = stage_service_time(&net, &plat, &db, 9, 18, 2, Some(0), 4);
        assert_eq!(c1b, c1);
        assert!(x1b > x1, "batched transfer must move more bytes");
    }

    #[test]
    fn single_stage_has_no_transfer() {
        let (net, plat, db) = setup();
        let cfg = PipelineConfig::single_stage(18, 0);
        let eval = evaluate(&net, &plat, &db, &cfg);
        assert_eq!(eval.stages.len(), 1);
        assert_eq!(eval.stages[0].transfer_s, 0.0);
        assert!((eval.stages[0].compute_s - db.network_time(0)).abs() < 1e-12);
    }

    #[test]
    fn pipelining_beats_single_stage() {
        // With two equally loaded halves on two fast EPs, throughput must
        // exceed the single-EP configuration.
        let (net, plat, db) = setup();
        let single = throughput(&net, &plat, &db, &PipelineConfig::single_stage(18, 0));
        let dual = throughput(&net, &plat, &db, &PipelineConfig::new(vec![9, 9], vec![0, 1]));
        assert!(dual > single, "dual {dual} vs single {single}");
    }

    #[test]
    fn transfer_charged_across_chiplets() {
        let (net, plat, db) = setup();
        let cfg = PipelineConfig::new(vec![9, 9], vec![0, 1]);
        let eval = evaluate(&net, &plat, &db, &cfg);
        assert!(eval.stages[1].transfer_s > 0.0);
    }

    #[test]
    fn huge_link_latency_hurts_throughput() {
        // Figure 9's mechanism: throughput insensitive to small latencies,
        // crushed by >= 1ms-scale latencies.
        let (net, mut plat, _) = setup();
        let cfg = PipelineConfig::new(vec![9, 9], vec![0, 1]);
        plat.link.latency_s = 1e-9;
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let fast = throughput(&net, &plat, &db, &cfg);
        plat.link.latency_s = 1.0;
        let slow = throughput(&net, &plat, &db, &cfg);
        assert!(slow < fast / 10.0, "1s latency must dominate: {slow} vs {fast}");
        plat.link.latency_s = 1e-6;
        let micro = throughput(&net, &plat, &db, &cfg);
        assert!((micro - fast).abs() / fast < 0.01, "1us latency negligible");
    }

    #[test]
    fn slowest_stage_identified() {
        let (net, plat, db) = setup();
        // Put 17 layers on a slow EP, 1 on a fast: stage 0 is the bottleneck.
        let cfg = PipelineConfig::new(vec![17, 1], vec![2, 0]);
        assert_eq!(slowest_stage(&net, &plat, &db, &cfg), 0);
    }

    #[test]
    fn makespan_scales_linearly_in_k() {
        let (net, plat, db) = setup();
        let cfg = PipelineConfig::new(vec![9, 9], vec![0, 2]);
        let eval = evaluate(&net, &plat, &db, &cfg);
        let m1 = makespan(&net, &plat, &db, &cfg, 1);
        let m11 = makespan(&net, &plat, &db, &cfg, 11);
        assert!((m1 - eval.latency_s).abs() < 1e-12);
        assert!((m11 - m1 - 10.0 * eval.bottleneck_s).abs() < 1e-12);
    }

    #[test]
    fn balanced_beats_imbalanced_on_same_eps() {
        let (net, plat, db) = setup();
        let imb = throughput(&net, &plat, &db, &PipelineConfig::new(vec![1, 17], vec![0, 1]));
        let bal = throughput(&net, &plat, &db, &PipelineConfig::new(vec![9, 9], vec![0, 1]));
        assert!(bal > imb);
    }
}
