//! Steady-state pipeline simulator.
//!
//! Evaluates a [`PipelineConfig`] against the per-layer time database:
//!
//! * **stage compute time** — sum of its layers' times on its EP (O(1) via
//!   the database prefix sums);
//! * **stage transfer time** — receiving the previous stage's output across
//!   the inter-chiplet link (latency + bytes/bandwidth), §7.6;
//! * **throughput** — `1 / max_stage_time` (images/s): in steady state the
//!   pipeline is limited by its slowest stage;
//! * **makespan** — fill latency plus `(k−1)` bottleneck periods for `k`
//!   inputs, used to charge explorers the *online* cost of trying a
//!   configuration (slow configurations cost more wall-clock to test —
//!   the effect that separates Shisha from blind search in Figure 4).

use super::PipelineConfig;
use crate::model::Network;
use crate::perfdb::PerfDb;
use crate::platform::{EpId, Platform};

/// Per-stage evaluation breakdown.
#[derive(Debug, Clone, PartialEq)]
pub struct StageEval {
    /// Stage index.
    pub stage: usize,
    /// Compute time on the assigned EP, seconds.
    pub compute_s: f64,
    /// Inbound transfer time (0 for the first stage or same-chiplet), seconds.
    pub transfer_s: f64,
}

impl StageEval {
    /// Total stage service time.
    #[inline]
    pub fn total(&self) -> f64 {
        self.compute_s + self.transfer_s
    }
}

/// Full evaluation of one pipeline configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineEval {
    /// Per-stage breakdown.
    pub stages: Vec<StageEval>,
    /// Bottleneck stage service time, seconds.
    pub bottleneck_s: f64,
    /// Steady-state throughput, images/s.
    pub throughput: f64,
    /// Pipeline fill latency (sum of all stage times), seconds.
    pub latency_s: f64,
}

/// Compute and transfer time of the contiguous stage `[lo, hi)` served on
/// `ep`, receiving its input from `from_ep` (`None` for the entry stage),
/// with `batch` images per pipeline slot.
///
/// This is the **single source of truth** for per-stage service math: the
/// steady-state evaluators below and the serving engine's dispatch path
/// ([`crate::serve::engine`]) both call it, so the discrete-event
/// contention model cannot silently drift from the analytic model. The
/// transfer term charges the previous stage's last layer's output crossing
/// the NoC (`batch` images per slot); `db` must already be batch-aware for
/// the compute term (see [`crate::perfdb::batch`]).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn stage_service_time(
    net: &Network,
    plat: &Platform,
    db: &PerfDb,
    lo: usize,
    hi: usize,
    ep: EpId,
    from_ep: Option<EpId>,
    batch: u64,
) -> (f64, f64) {
    let compute_s = db.range_time(lo, hi, ep);
    let transfer_s = match from_ep {
        None => 0.0,
        Some(prev_ep) => crate::platform::topology::transfer_time(
            plat,
            prev_ep,
            ep,
            net.layers[lo - 1].output_bytes() * batch,
        ),
    };
    (compute_s, transfer_s)
}

/// Evaluate `cfg` on `net`/`plat` using the time database `db`.
///
/// `db` rows must correspond to `plat.eps` and columns to `net.layers`.
pub fn evaluate(net: &Network, plat: &Platform, db: &PerfDb, cfg: &PipelineConfig) -> PipelineEval {
    debug_assert_eq!(db.n_layers(), net.len());
    let bounds = cfg.stage_bounds();
    let mut stages = Vec::with_capacity(bounds.len());
    for (si, &(lo, hi)) in bounds.iter().enumerate() {
        let ep = cfg.assignment[si];
        let from_ep = if si == 0 { None } else { Some(cfg.assignment[si - 1]) };
        let (compute_s, transfer_s) = stage_service_time(net, plat, db, lo, hi, ep, from_ep, 1);
        stages.push(StageEval { stage: si, compute_s, transfer_s });
    }
    let bottleneck_s = stages.iter().map(StageEval::total).fold(0.0, f64::max);
    let latency_s = stages.iter().map(StageEval::total).sum();
    PipelineEval {
        stages,
        bottleneck_s,
        throughput: if bottleneck_s > 0.0 { 1.0 / bottleneck_s } else { f64::INFINITY },
        latency_s,
    }
}

/// Steady-state throughput only (hot path for explorers).
#[inline]
pub fn throughput(net: &Network, plat: &Platform, db: &PerfDb, cfg: &PipelineConfig) -> f64 {
    // Specialised: avoid allocating StageEval vec.
    let mut lo = 0usize;
    let mut bottleneck = 0.0f64;
    for (si, &n) in cfg.stages.iter().enumerate() {
        let hi = lo + n;
        let ep = cfg.assignment[si];
        let from_ep = if si == 0 { None } else { Some(cfg.assignment[si - 1]) };
        let (compute_s, transfer_s) = stage_service_time(net, plat, db, lo, hi, ep, from_ep, 1);
        let t = compute_s + transfer_s;
        if t > bottleneck {
            bottleneck = t;
        }
        lo = hi;
    }
    if bottleneck > 0.0 {
        1.0 / bottleneck
    } else {
        f64::INFINITY
    }
}

/// Index of the slowest stage (Algorithm 2, line 5).
pub fn slowest_stage(net: &Network, plat: &Platform, db: &PerfDb, cfg: &PipelineConfig) -> usize {
    let eval = evaluate(net, plat, db, cfg);
    eval.stages
        .iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| a.total().partial_cmp(&b.total()).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

/// Wall-clock time to push `k` inputs through the pipeline: fill latency +
/// `(k−1)` bottleneck periods. This is what an *online* tuner pays to test
/// a configuration with `k` probe inputs.
pub fn makespan(net: &Network, plat: &Platform, db: &PerfDb, cfg: &PipelineConfig, k: u64) -> f64 {
    let eval = evaluate(net, plat, db, cfg);
    eval.latency_s + (k.saturating_sub(1)) as f64 * eval.bottleneck_s
}

/// Incrementally maintained per-stage service times (batch 1) — the
/// explorers' evaluation scratch.
///
/// Every entry is the value [`stage_service_time`] would compute for that
/// stage, so any aggregate read off this struct is **bit-identical** to
/// the full recompute ([`throughput`] / [`evaluate`] / [`makespan`]): a
/// stage's service time is a pure function of `(lo, hi, ep, from_ep)`, and
/// the struct only ever stores values produced by that function. The point
/// is what is *not* recomputed — a single boundary move
/// ([`StageTimes::apply_move`]) touches two compute terms and one transfer
/// term instead of re-deriving all `S` stages, and a jump to an arbitrary
/// nearby configuration ([`StageTimes::refresh`]) recomputes only the
/// stages whose identifying tuple changed. Shisha's Algorithm-2 walk, SA
/// proposals and HC neighbourhood scans all mutate one boundary or one
/// assignment at a time, so their per-trial evaluation cost drops from
/// O(S) service-time derivations to O(1) plus a trivial O(S) max/sum fold
/// over stored floats. A property test pins all reads bit-identical to the
/// full recompute across randomized move sequences.
#[derive(Debug, Default)]
pub struct StageTimes {
    /// Stage `[lo, hi)` layer bounds.
    lo: Vec<usize>,
    hi: Vec<usize>,
    /// Assigned EP per stage.
    ep: Vec<EpId>,
    /// Stage compute time, seconds.
    compute: Vec<f64>,
    /// Inbound transfer time, seconds (0 for the first stage).
    transfer: Vec<f64>,
}

// Hand-written so `clone_from` reuses the destination's buffers: HC/SA
// re-seed a candidate scratch from the current configuration's times once
// per trial, and the derived impl would reallocate all five vectors.
impl Clone for StageTimes {
    fn clone(&self) -> Self {
        Self {
            lo: self.lo.clone(),
            hi: self.hi.clone(),
            ep: self.ep.clone(),
            compute: self.compute.clone(),
            transfer: self.transfer.clone(),
        }
    }

    fn clone_from(&mut self, source: &Self) {
        self.lo.clone_from(&source.lo);
        self.hi.clone_from(&source.hi);
        self.ep.clone_from(&source.ep);
        self.compute.clone_from(&source.compute);
        self.transfer.clone_from(&source.transfer);
    }
}

/// Undo record for one [`StageTimes::apply_move`]; restores the exact
/// pre-move bits.
#[derive(Debug, Clone, Copy)]
pub struct StageMove {
    from: usize,
    to: usize,
    compute_from: f64,
    compute_to: f64,
    transfer_b: f64,
}

impl StageTimes {
    /// Empty scratch; populate with [`StageTimes::rebuild`].
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of tracked stages.
    #[inline]
    pub fn n_stages(&self) -> usize {
        self.lo.len()
    }

    /// Layer count of stage `s`.
    #[inline]
    pub fn stage_len(&self, s: usize) -> usize {
        self.hi[s] - self.lo[s]
    }

    /// EP assigned to stage `s`.
    #[inline]
    pub fn stage_ep(&self, s: usize) -> EpId {
        self.ep[s]
    }

    /// Total service time of stage `s` (compute + inbound transfer).
    #[inline]
    pub fn total(&self, s: usize) -> f64 {
        self.compute[s] + self.transfer[s]
    }

    /// Full recompute from `cfg` (also resizes; reuses buffers).
    pub fn rebuild(&mut self, net: &Network, plat: &Platform, db: &PerfDb, cfg: &PipelineConfig) {
        self.lo.clear();
        self.hi.clear();
        self.ep.clear();
        self.compute.clear();
        self.transfer.clear();
        let mut lo = 0usize;
        for (si, &n) in cfg.stages.iter().enumerate() {
            let hi = lo + n;
            let ep = cfg.assignment[si];
            let from_ep = if si == 0 { None } else { Some(cfg.assignment[si - 1]) };
            let (c, x) = stage_service_time(net, plat, db, lo, hi, ep, from_ep, 1);
            self.lo.push(lo);
            self.hi.push(hi);
            self.ep.push(ep);
            self.compute.push(c);
            self.transfer.push(x);
            lo = hi;
        }
    }

    /// Diff-based refresh towards `cfg`: recompute only the stages whose
    /// `(lo, hi, ep)` changed and the transfer terms whose `(lo, ep,
    /// predecessor ep)` changed; a stage-count change falls back to
    /// [`StageTimes::rebuild`]. Handles every explorer move kind (boundary
    /// moves, swaps, reassignments, merges, splits) without the caller
    /// naming the move.
    pub fn refresh(&mut self, net: &Network, plat: &Platform, db: &PerfDb, cfg: &PipelineConfig) {
        if self.lo.len() != cfg.n_stages() {
            self.rebuild(net, plat, db, cfg);
            return;
        }
        let mut lo = 0usize;
        let mut prev_new: Option<EpId> = None;
        let mut prev_old: Option<EpId> = None;
        for (si, &n) in cfg.stages.iter().enumerate() {
            let hi = lo + n;
            let ep = cfg.assignment[si];
            let (old_lo, old_hi, old_ep) = (self.lo[si], self.hi[si], self.ep[si]);
            if !(old_lo == lo && old_hi == hi && old_ep == ep) {
                self.compute[si] = db.range_time(lo, hi, ep);
            }
            if !(old_lo == lo && old_ep == ep && prev_old == prev_new) {
                self.transfer[si] = match prev_new {
                    None => 0.0,
                    Some(p) => crate::platform::topology::transfer_time(
                        plat,
                        p,
                        ep,
                        net.layers[lo - 1].output_bytes(),
                    ),
                };
            }
            self.lo[si] = lo;
            self.hi[si] = hi;
            self.ep[si] = ep;
            prev_old = Some(old_ep);
            prev_new = Some(ep);
            lo = hi;
        }
    }

    /// Apply a single boundary move (one layer from stage `from` to the
    /// adjacent stage `to`, mirroring [`PipelineConfig::move_layer`]):
    /// recomputes exactly the two touched compute terms and the right-hand
    /// stage's transfer term. Returns an undo record restoring the exact
    /// pre-move bits. `from` must keep at least one layer.
    pub fn apply_move(
        &mut self,
        net: &Network,
        plat: &Platform,
        db: &PerfDb,
        from: usize,
        to: usize,
    ) -> StageMove {
        debug_assert_eq!(from.abs_diff(to), 1, "apply_move: stages must be adjacent");
        debug_assert!(self.stage_len(from) >= 2, "apply_move: would empty stage {from}");
        let b = from.max(to);
        let undo = StageMove {
            from,
            to,
            compute_from: self.compute[from],
            compute_to: self.compute[to],
            transfer_b: self.transfer[b],
        };
        if to < from {
            self.hi[to] += 1;
            self.lo[from] += 1;
        } else {
            self.hi[from] -= 1;
            self.lo[to] -= 1;
        }
        self.compute[from] = db.range_time(self.lo[from], self.hi[from], self.ep[from]);
        self.compute[to] = db.range_time(self.lo[to], self.hi[to], self.ep[to]);
        // only the right stage's inbound boundary layer moved; b >= 1 by
        // adjacency, and downstream transfers are untouched (their lo and
        // both endpoint EPs are unchanged)
        self.transfer[b] = crate::platform::topology::transfer_time(
            plat,
            self.ep[b - 1],
            self.ep[b],
            net.layers[self.lo[b] - 1].output_bytes(),
        );
        undo
    }

    /// Revert an [`StageTimes::apply_move`]; bit-exact (the undo record
    /// carries the original floats).
    pub fn undo(&mut self, m: StageMove) {
        let b = m.from.max(m.to);
        if m.to < m.from {
            self.hi[m.to] -= 1;
            self.lo[m.from] -= 1;
        } else {
            self.hi[m.from] += 1;
            self.lo[m.to] += 1;
        }
        self.compute[m.from] = m.compute_from;
        self.compute[m.to] = m.compute_to;
        self.transfer[b] = m.transfer_b;
    }

    /// True when the tracked bounds/assignment correspond to `cfg`.
    pub fn matches(&self, cfg: &PipelineConfig) -> bool {
        if self.lo.len() != cfg.n_stages() {
            return false;
        }
        let mut lo = 0usize;
        for (si, &n) in cfg.stages.iter().enumerate() {
            if self.lo[si] != lo || self.hi[si] != lo + n || self.ep[si] != cfg.assignment[si] {
                return false;
            }
            lo += n;
        }
        true
    }

    /// Bottleneck stage service time — same fold as [`evaluate`]
    /// (`fold(0.0, f64::max)` in stage order), so bits match.
    pub fn bottleneck_s(&self) -> f64 {
        self.compute
            .iter()
            .zip(&self.transfer)
            .map(|(c, x)| c + x)
            .fold(0.0, f64::max)
    }

    /// Fill latency — same sum order as [`evaluate`].
    pub fn latency_s(&self) -> f64 {
        self.compute.iter().zip(&self.transfer).map(|(c, x)| c + x).sum()
    }

    /// Steady-state throughput — bit-identical to [`throughput`] on the
    /// matching configuration.
    pub fn throughput(&self) -> f64 {
        let b = self.bottleneck_s();
        if b > 0.0 {
            1.0 / b
        } else {
            f64::INFINITY
        }
    }

    /// Index of the slowest stage — same last-maximum tie-break as
    /// [`slowest_stage`] (`Iterator::max_by` keeps the last maximal
    /// element).
    pub fn slowest_stage(&self) -> usize {
        debug_assert!(!self.lo.is_empty(), "slowest_stage on empty StageTimes");
        let mut best = f64::NEG_INFINITY;
        let mut ix = 0usize;
        for s in 0..self.lo.len() {
            let t = self.total(s);
            if t >= best {
                best = t;
                ix = s;
            }
        }
        ix
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::networks;
    use crate::perfdb::CostModel;
    use crate::platform::configs;

    fn setup() -> (Network, Platform, PerfDb) {
        let net = networks::synthnet();
        let plat = configs::c2();
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        (net, plat, db)
    }

    #[test]
    fn throughput_is_inverse_bottleneck() {
        let (net, plat, db) = setup();
        let cfg = PipelineConfig::new(vec![9, 9], vec![0, 2]);
        let eval = evaluate(&net, &plat, &db, &cfg);
        assert!((eval.throughput - 1.0 / eval.bottleneck_s).abs() < 1e-12);
    }

    #[test]
    fn fast_path_matches_full_eval() {
        let (net, plat, db) = setup();
        for cfg in [
            PipelineConfig::new(vec![18], vec![0]),
            PipelineConfig::new(vec![9, 9], vec![0, 2]),
            PipelineConfig::new(vec![5, 6, 7], vec![1, 0, 3]),
            PipelineConfig::new(vec![4, 4, 5, 5], vec![3, 2, 1, 0]),
        ] {
            let full = evaluate(&net, &plat, &db, &cfg).throughput;
            let fast = throughput(&net, &plat, &db, &cfg);
            assert!((full - fast).abs() < 1e-12 * full.max(1.0), "{}", cfg.describe());
        }
    }

    #[test]
    fn stage_service_time_is_the_shared_formula() {
        let (net, plat, db) = setup();
        let cfg = PipelineConfig::new(vec![9, 9], vec![0, 2]);
        let eval = evaluate(&net, &plat, &db, &cfg);
        let (c0, x0) = stage_service_time(&net, &plat, &db, 0, 9, 0, None, 1);
        assert_eq!(c0, eval.stages[0].compute_s);
        assert_eq!(x0, 0.0);
        let (c1, x1) = stage_service_time(&net, &plat, &db, 9, 18, 2, Some(0), 1);
        assert_eq!(c1, eval.stages[1].compute_s);
        assert_eq!(x1, eval.stages[1].transfer_s);
        // batching multiplies the transferred bytes, not the compute term
        // (the engine passes a batch-aware db for compute)
        let (c1b, x1b) = stage_service_time(&net, &plat, &db, 9, 18, 2, Some(0), 4);
        assert_eq!(c1b, c1);
        assert!(x1b > x1, "batched transfer must move more bytes");
    }

    #[test]
    fn single_stage_has_no_transfer() {
        let (net, plat, db) = setup();
        let cfg = PipelineConfig::single_stage(18, 0);
        let eval = evaluate(&net, &plat, &db, &cfg);
        assert_eq!(eval.stages.len(), 1);
        assert_eq!(eval.stages[0].transfer_s, 0.0);
        assert!((eval.stages[0].compute_s - db.network_time(0)).abs() < 1e-12);
    }

    #[test]
    fn pipelining_beats_single_stage() {
        // With two equally loaded halves on two fast EPs, throughput must
        // exceed the single-EP configuration.
        let (net, plat, db) = setup();
        let single = throughput(&net, &plat, &db, &PipelineConfig::single_stage(18, 0));
        let dual = throughput(&net, &plat, &db, &PipelineConfig::new(vec![9, 9], vec![0, 1]));
        assert!(dual > single, "dual {dual} vs single {single}");
    }

    #[test]
    fn transfer_charged_across_chiplets() {
        let (net, plat, db) = setup();
        let cfg = PipelineConfig::new(vec![9, 9], vec![0, 1]);
        let eval = evaluate(&net, &plat, &db, &cfg);
        assert!(eval.stages[1].transfer_s > 0.0);
    }

    #[test]
    fn huge_link_latency_hurts_throughput() {
        // Figure 9's mechanism: throughput insensitive to small latencies,
        // crushed by >= 1ms-scale latencies.
        let (net, mut plat, _) = setup();
        let cfg = PipelineConfig::new(vec![9, 9], vec![0, 1]);
        plat.link.latency_s = 1e-9;
        let db = PerfDb::build(&net, &plat, &CostModel::default());
        let fast = throughput(&net, &plat, &db, &cfg);
        plat.link.latency_s = 1.0;
        let slow = throughput(&net, &plat, &db, &cfg);
        assert!(slow < fast / 10.0, "1s latency must dominate: {slow} vs {fast}");
        plat.link.latency_s = 1e-6;
        let micro = throughput(&net, &plat, &db, &cfg);
        assert!((micro - fast).abs() / fast < 0.01, "1us latency negligible");
    }

    #[test]
    fn slowest_stage_identified() {
        let (net, plat, db) = setup();
        // Put 17 layers on a slow EP, 1 on a fast: stage 0 is the bottleneck.
        let cfg = PipelineConfig::new(vec![17, 1], vec![2, 0]);
        assert_eq!(slowest_stage(&net, &plat, &db, &cfg), 0);
    }

    #[test]
    fn makespan_scales_linearly_in_k() {
        let (net, plat, db) = setup();
        let cfg = PipelineConfig::new(vec![9, 9], vec![0, 2]);
        let eval = evaluate(&net, &plat, &db, &cfg);
        let m1 = makespan(&net, &plat, &db, &cfg, 1);
        let m11 = makespan(&net, &plat, &db, &cfg, 11);
        assert!((m1 - eval.latency_s).abs() < 1e-12);
        assert!((m11 - m1 - 10.0 * eval.bottleneck_s).abs() < 1e-12);
    }

    #[test]
    fn balanced_beats_imbalanced_on_same_eps() {
        let (net, plat, db) = setup();
        let imb = throughput(&net, &plat, &db, &PipelineConfig::new(vec![1, 17], vec![0, 1]));
        let bal = throughput(&net, &plat, &db, &PipelineConfig::new(vec![9, 9], vec![0, 1]));
        assert!(bal > imb);
    }

    /// All StageTimes reads must match the full recompute bit-for-bit.
    fn assert_times_pinned(
        st: &StageTimes,
        net: &Network,
        plat: &Platform,
        db: &PerfDb,
        cfg: &PipelineConfig,
    ) -> Result<(), String> {
        if !st.matches(cfg) {
            return Err(format!("desync at {}", cfg.describe()));
        }
        let full = evaluate(net, plat, db, cfg);
        for (s, ev) in full.stages.iter().enumerate() {
            if st.total(s).to_bits() != ev.total().to_bits() {
                return Err(format!(
                    "stage {s} total {} != {} at {}",
                    st.total(s),
                    ev.total(),
                    cfg.describe()
                ));
            }
        }
        let checks = [
            ("throughput", st.throughput(), throughput(net, plat, db, cfg)),
            ("bottleneck", st.bottleneck_s(), full.bottleneck_s),
            ("latency", st.latency_s(), full.latency_s),
        ];
        for (name, got, want) in checks {
            if got.to_bits() != want.to_bits() {
                return Err(format!("{name} {got} != {want} at {}", cfg.describe()));
            }
        }
        if st.slowest_stage() != slowest_stage(net, plat, db, cfg) {
            return Err(format!("slowest stage mismatch at {}", cfg.describe()));
        }
        Ok(())
    }

    #[test]
    fn stage_times_rebuild_matches_full_eval() {
        let (net, plat, db) = setup();
        let mut st = StageTimes::new();
        for cfg in [
            PipelineConfig::new(vec![18], vec![0]),
            PipelineConfig::new(vec![9, 9], vec![0, 2]),
            PipelineConfig::new(vec![5, 6, 7], vec![1, 0, 3]),
            PipelineConfig::new(vec![4, 4, 5, 5], vec![3, 2, 1, 0]),
        ] {
            st.rebuild(&net, &plat, &db, &cfg);
            assert_times_pinned(&st, &net, &plat, &db, &cfg).unwrap();
        }
    }

    #[test]
    fn stage_times_apply_move_and_undo_are_exact() {
        let (net, plat, db) = setup();
        let cfg = PipelineConfig::new(vec![5, 6, 7], vec![1, 0, 3]);
        let mut st = StageTimes::new();
        st.rebuild(&net, &plat, &db, &cfg);
        let before = st.clone();
        for (from, to) in [(1usize, 0usize), (1, 2), (0, 1), (2, 1)] {
            let undo = st.apply_move(&net, &plat, &db, from, to);
            let moved = cfg.move_layer(from, to).unwrap();
            assert_times_pinned(&st, &net, &plat, &db, &moved).unwrap();
            st.undo(undo);
            for s in 0..st.n_stages() {
                assert_eq!(st.total(s).to_bits(), before.total(s).to_bits(), "undo stage {s}");
            }
            assert_times_pinned(&st, &net, &plat, &db, &cfg).unwrap();
        }
    }

    #[test]
    fn stage_times_pinned_bit_identical_property() {
        // the acceptance pin: across randomized platforms/networks and
        // random move sequences (incremental boundary moves via
        // apply_move/undo, arbitrary neighbourhood jumps via refresh —
        // including merges and splits that change the stage count), every
        // StageTimes read stays bit-identical to the full recompute.
        crate::testutil::check("stage times incremental", 0x57A6E7, 60, |g| {
            let plat = g.platform(2, 7);
            let net = g.network(3, 20);
            let db = PerfDb::build(&net, &plat, &CostModel::default());
            let mut cfg = g.config(net.len(), &plat);
            let mut st = StageTimes::new();
            st.rebuild(&net, &plat, &db, &cfg);
            for _ in 0..25 {
                if g.rng().gen_bool(0.5) && cfg.n_stages() >= 2 {
                    // boundary move on a random movable stage pair
                    let n = cfg.n_stages();
                    let from = g.usize(0, n);
                    let to = if from == 0 {
                        1
                    } else if from == n - 1 {
                        n - 2
                    } else if g.rng().gen_bool(0.5) {
                        from - 1
                    } else {
                        from + 1
                    };
                    if cfg.stages[from] < 2 {
                        continue;
                    }
                    let undo = st.apply_move(&net, &plat, &db, from, to);
                    if g.rng().gen_bool(0.3) {
                        // exercise undo: revert and re-apply
                        st.undo(undo);
                        assert_times_pinned(&st, &net, &plat, &db, &cfg)?;
                        st.apply_move(&net, &plat, &db, from, to);
                    }
                    cfg.stages[from] -= 1;
                    cfg.stages[to] += 1;
                } else {
                    // arbitrary neighbourhood jump (swap / reassign /
                    // merge / split / move), applied via diff refresh
                    let Some(next) = crate::explore::random_move(&cfg, &plat, g.rng()) else {
                        continue;
                    };
                    st.refresh(&net, &plat, &db, &next);
                    cfg = next;
                }
                assert_times_pinned(&st, &net, &plat, &db, &cfg)?;
            }
            Ok(())
        });
    }

    #[test]
    fn stage_times_clone_from_reuses_state() {
        let (net, plat, db) = setup();
        let a_cfg = PipelineConfig::new(vec![9, 9], vec![0, 2]);
        let b_cfg = PipelineConfig::new(vec![4, 4, 5, 5], vec![3, 2, 1, 0]);
        let mut a = StageTimes::new();
        a.rebuild(&net, &plat, &db, &a_cfg);
        let mut b = StageTimes::new();
        b.rebuild(&net, &plat, &db, &b_cfg);
        b.clone_from(&a);
        assert_times_pinned(&b, &net, &plat, &db, &a_cfg).unwrap();
    }
}
